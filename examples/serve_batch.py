"""Batched serving example (continuous batching over decode slots).

    PYTHONPATH=src python examples/serve_batch.py
"""
import subprocess, sys, os
subprocess.run([sys.executable, "-m", "repro.launch.serve",
                "--arch", "llama3p2_1b", "--requests", "6",
                "--slots", "3", "--max-tokens", "8"],
               check=True, env={"PYTHONPATH": "src", **os.environ})
