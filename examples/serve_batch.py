"""**LM decode** serving example (continuous batching over decode slots).

    PYTHONPATH=src python examples/serve_batch.py

This drives the language-model serving engine (serving/engine.py) via
repro.launch.serve — it has nothing to do with path queries.  For HcPE
query serving see the similarly-named siblings:
  * examples/batch_serving.py — sync HcPE batch front-end (HcPEServer).
  * examples/async_serving.py — async deadline-aware HcPE front-end
    (AsyncHcPEServer).
  * examples/multi_tenant_serving.py — many tenant graphs behind one
    HcPE server (GraphRegistry, DESIGN.md §8).
"""
import subprocess, sys, os
subprocess.run([sys.executable, "-m", "repro.launch.serve",
                "--arch", "llama3p2_1b", "--requests", "6",
                "--slots", "3", "--max-tokens", "8"],
               check=True, env={"PYTHONPATH": "src", **os.environ})
