"""Batch **HcPE** serving demo: dedup + warm index cache, sync front-end.

    PYTHONPATH=src python examples/batch_serving.py

Builds a hub-heavy graph, simulates a production query log (many requests
hitting a small set of hot s-t pairs), serves it twice through HcPEServer
and prints the serving report — throughput, latency percentiles, and the
index-cache reuse that makes the second batch cheap.

``HcPEServer(g)`` here is the single-graph convenience form: the bare
graph wraps into a one-tenant ``GraphRegistry`` under the default
``graph_id`` (DESIGN.md §8), so this demo is byte-identical to the
pre-tenancy server.

Not to be confused with its similarly-named siblings:
  * examples/serve_batch.py — **LM decode** serving (continuous batching
    over decode slots, serving/engine.py); no path queries involved.
  * examples/async_serving.py — the **async** HcPE front-end
    (AsyncHcPEServer: admission control + deadline-aware micro-batching)
    layered over the same engine this demo drives synchronously.
  * examples/multi_tenant_serving.py — the **multi-graph** registry flow
    (GraphRegistry: many tenant graphs, per-tenant quotas/stats) over
    both front-ends.
"""
import numpy as np

from repro.core import BatchPathEnum, PathEnum, power_law
from repro.serving import HcPEServer, PathQueryRequest

g = power_law(2000, 6.0, seed=3)
k = 4

# hot query pool: high-degree endpoints (the paper's V' sets, §7.1)
deg = np.diff(g.indptr)
hubs = np.argsort(deg)[-40:]
rng = np.random.default_rng(0)
pool = []
while len(pool) < 10:
    s, t = rng.choice(hubs, 2, replace=False)
    if (int(s), int(t)) not in pool:
        pool.append((int(s), int(t)))

# a 50-request batch over 10 hot pairs -> 80% duplicates
requests = [PathQueryRequest(uid=i, s=pool[j][0], t=pool[j][1], k=k)
            for i, j in enumerate(rng.integers(0, len(pool), size=50))]

server = HcPEServer(g, BatchPathEnum())
responses, report = server.serve(requests)
print(f"cold batch: {report.batch_size} queries "
      f"({report.distinct_queries} distinct), "
      f"{report.total_results} paths, "
      f"{report.throughput_qps:,.0f} q/s")
print(f"  latency p50={report.p50_ms:.3f}ms p90={report.p90_ms:.3f}ms "
      f"p99={report.p99_ms:.3f}ms")
print(f"  index cache: {report.cache.hits} hits / "
      f"{report.cache.misses} misses (hit rate "
      f"{report.cache.hit_rate:.0%})")

# same workload again: every index now comes out of the warm LRU
responses2, report2 = server.serve(requests)
print(f"warm batch: {report2.throughput_qps:,.0f} q/s, "
      f"hit rate {report2.cache.hit_rate:.0%}")

# counts must be byte-identical to the sequential engine
seq = PathEnum()
for r in responses:
    req = requests[r.uid]
    assert r.count == seq.count(g, req.s, req.t, req.k)
print(f"sequential cross-check: OK ({len(responses)} responses)")
