"""Multi-graph tenancy demo: one server, many tenant graphs (DESIGN.md §8).

    PYTHONPATH=src python examples/multi_tenant_serving.py

Two tenants — "acme" (a hub-heavy fraud graph with a tight cache quota)
and "globex" (a sparser social graph) — register with one
``GraphRegistry`` and serve through one ``HcPEServer`` and one shared
engine.  The demo shows the tenant dimension end to end:

  * interleaved per-tenant requests grouped into per-graph engine
    batches, counts byte-identical to dedicated single-tenant servers;
  * the same (s, t, k) queried on both graphs building two separate
    cache entries (no cross-tenant index reuse — it would answer one
    tenant's query on the other's topology);
  * per-tenant cache stats in the serve report, quota-bounded churn, and
    retirement purging a tenant's cache slice;
  * the async front-end rejecting a flooding tenant with
    ``STATUS_REJECTED_TENANT_QUOTA`` while its neighbor is unaffected.

This file is the runnable mirror of the README "Multi-tenant
quickstart".  Siblings: examples/batch_serving.py (single-graph sync),
examples/async_serving.py (single-graph async + SLOs),
examples/serve_batch.py (LM decode serving, unrelated to HcPE).
"""
import asyncio

import numpy as np

from repro.core import PathEnum, erdos_renyi, power_law
from repro.serving import (AsyncHcPEServer, GraphRegistry, HcPEServer,
                           PathQueryRequest, STATUS_REJECTED_TENANT_QUOTA)


def hot_requests(g, graph_id, count, rng, k=4, uid0=0):
    deg = np.diff(g.indptr)
    hubs = np.argsort(deg)[-30:]
    pool = []
    while len(pool) < 8:
        s, t = rng.choice(hubs, 2, replace=False)
        if (int(s), int(t)) not in pool:
            pool.append((int(s), int(t)))
    picks = rng.integers(0, len(pool), size=count)
    return [PathQueryRequest(uid=uid0 + i, s=pool[j][0], t=pool[j][1], k=k,
                             graph_id=graph_id)
            for i, j in enumerate(picks)]


def main():
    rng = np.random.default_rng(0)
    g_acme = power_law(1500, 6.0, seed=3)      # fraud rings: hub-heavy
    g_globex = erdos_renyi(1200, 4.0, seed=7)  # social: uniform sparse

    registry = GraphRegistry()
    registry.register("acme", g_acme, cache_quota=16)
    registry.register("globex", g_globex)
    server = HcPEServer(registry)

    acme = hot_requests(g_acme, "acme", 25, rng)
    globex = hot_requests(g_globex, "globex", 25, rng, uid0=25)
    interleaved = [r for pair in zip(acme, globex) for r in pair]
    responses, report = server.serve(interleaved)
    print(f"one server, two tenants: {report.batch_size} queries, "
          f"{report.throughput_qps:,.0f} q/s")
    for gid in ("acme", "globex"):
        c = report.tenant_cache[gid]
        print(f"  {gid:7s} cache: {c.hits} hits / {c.misses} misses "
              f"(hit rate {c.hit_rate:.0%}), "
              f"{server.engine.cache.tenant_len(gid)} entries resident")

    # byte-identical to dedicated single-tenant servers
    seq = PathEnum()
    graphs = {"acme": g_acme, "globex": g_globex}
    for r in responses:
        req = interleaved[[q.uid for q in interleaved].index(r.uid)]
        assert r.count == seq.count(graphs[req.graph_id], req.s, req.t, req.k)
    print("per-tenant counts match dedicated engines: OK")

    # same (s, t, k) on both tenants -> two cache entries, two answers
    # (hub s by out-degree, hub t by in-degree; ids valid on both graphs)
    n_shared = g_globex.n
    s = int(np.argsort(np.diff(g_acme.indptr)[:n_shared])[-1])
    t = int(np.argsort(np.diff(g_acme.rindptr)[:n_shared])[-3])
    twin = [PathQueryRequest(uid=100, s=s, t=t, k=4, graph_id="acme"),
            PathQueryRequest(uid=101, s=s, t=t, k=4, graph_id="globex")]
    (ra, rg), rep = server.serve(twin)
    print(f"same ({s}, {t}, 4) on both tenants: acme={ra.count} "
          f"globex={rg.count} (misses={rep.cache.misses} — no sharing)")

    # retiring a tenant purges its cache slice; queries start rejecting
    registry.retire("acme")
    (late,), _ = server.serve([twin[0]])
    print(f"after retire('acme'): cache entries="
          f"{server.engine.cache.tenant_len('acme')}, "
          f"late request -> {late.status}")

    # async: a flooding tenant is shed by its in-flight quota
    reg2 = GraphRegistry()
    reg2.register("flooder", g_acme, max_pending=2)
    reg2.register("steady", g_globex)

    async def drive():
        async with AsyncHcPEServer(reg2, batch_window_ms=10.0) as srv:
            flood = [PathQueryRequest(uid=i, s=0, t=1 + i, k=3,
                                      graph_id="flooder") for i in range(6)]
            steady = [PathQueryRequest(uid=10 + i, s=0, t=1 + i, k=3,
                                       graph_id="steady") for i in range(3)]
            return await srv.serve(flood + steady), srv.stats

    resps, stats = asyncio.run(drive())
    shed = sum(r.status == STATUS_REJECTED_TENANT_QUOTA for r in resps)
    ok_steady = sum(r.status == "ok" for r in resps if r.graph_id == "steady")
    print(f"async quota: flooder shed {shed}/6, steady served "
          f"{ok_steady}/3 ({stats.rejected_tenant_quota} tenant-quota "
          f"rejections)")


if __name__ == "__main__":
    main()
