"""Async deadline-aware HcPE serving demo: tight-SLO queries jump the queue.

    PYTHONPATH=src python examples/async_serving.py

A mixed workload — one heavy enumeration (k=8 on a dense region, ~10^6
paths) plus a swarm of light point lookups with tight deadlines — is
served twice:

  * through the blocking ``HcPEServer.serve`` (every response waits for
    the whole batch, heavy query included), then
  * through ``AsyncHcPEServer``: admission control, a micro-batching
    window, earliest-deadline-first dispatch, enumeration in a worker
    thread.  The tight-SLO lights are grouped, scheduled, and answered
    before the heavy query runs; result counts are identical.

``AsyncHcPEServer(g, ...)`` uses the single-graph convenience form (the
graph wraps into a one-tenant ``GraphRegistry``, DESIGN.md §8); the
per-uid quota shown here is the client-level sibling of the per-tenant
``max_pending`` quota the registry flow adds.

Siblings: examples/batch_serving.py (the sync HcPE batch front-end),
examples/multi_tenant_serving.py (many tenant graphs behind one server,
per-tenant quotas) and examples/serve_batch.py (LM decode serving,
unrelated to HcPE).
"""
import asyncio
import time

import numpy as np

from repro.core import BatchPathEnum, erdos_renyi
from repro.serving import AsyncHcPEServer, HcPEServer, PathQueryRequest


def make_workload(g, rng):
    heavy = PathQueryRequest(uid=0, s=0, t=1, k=8, deadline_ms=60_000.0)
    lights = []
    while len(lights) < 20:
        s, t = rng.integers(0, g.n, 2)
        if s != t:
            lights.append(PathQueryRequest(uid=1 + len(lights), s=int(s),
                                           t=int(t), k=3, deadline_ms=50.0))
    return [heavy] + lights        # heavy first: worst case for FIFO


def pct(xs, q):
    return float(np.percentile(np.asarray(xs) * 1e3, q))


async def run_async(g, workload):
    async with AsyncHcPEServer(g, BatchPathEnum(),
                               batch_window_ms=2.0) as server:
        t0 = time.perf_counter()

        async def timed(req):
            resp = await server.submit(req)
            return resp, time.perf_counter() - t0

        done = await asyncio.gather(*(timed(r) for r in workload))
        stats = server.stats
    return done, stats


def main():
    g = erdos_renyi(200, 12.0, seed=3)
    workload = make_workload(g, np.random.default_rng(11))

    t0 = time.perf_counter()
    sync_resps, _ = HcPEServer(g, BatchPathEnum()).serve(workload)
    sync_wall = time.perf_counter() - t0
    print(f"sync  HcPEServer.serve: every response after {sync_wall*1e3:8.1f} ms "
          f"(heavy query blocks all {len(workload) - 1} lights)")

    done, stats = asyncio.run(run_async(g, workload))
    lights = [(r, dt) for r, dt in done if r.uid != 0]
    heavy_dt = next(dt for r, dt in done if r.uid == 0)
    light_dts = [dt for _, dt in lights]
    met = sum(1 for r, _ in lights if r.slo_met)
    print(f"async AsyncHcPEServer:  light p50={pct(light_dts, 50):6.1f} ms  "
          f"p99={pct(light_dts, 99):6.1f} ms  heavy={heavy_dt*1e3:8.1f} ms")
    print(f"  SLO (50 ms) met on {met}/{len(lights)} lights; "
          f"{stats.micro_batches} micro-batches, "
          f"{stats.rejected_queue_full + stats.rejected_quota} rejected")

    sync_counts = {r.uid: r.count for r in sync_resps}
    async_counts = {r.uid: r.count for r, _ in done}
    assert async_counts == sync_counts
    print(f"  result counts identical to sync engine "
          f"({sum(sync_counts.values()):,} paths total)")


if __name__ == "__main__":
    main()
