"""Motivation example 2 of the paper: e-commerce fraud cycles.

New edge (v, v') triggers cycle detection = q(v', v, k-1) plus the edge;
edges carry a transaction-type label and the paths must satisfy an
attribute predicate (Appendix E, constraints on predicates).

    PYTHONPATH=src python examples/fraud_detection.py
"""
import numpy as np

from repro.core import PathEnum, erdos_renyi
from repro.core.constraints import AccumulativeValue

rng = np.random.default_rng(3)
g = erdos_renyi(300, 8.0, seed=3)
engine = PathEnum()

# transaction amounts as edge weights; flag cycles whose total >= threshold
amounts = rng.uniform(10.0, 5000.0, size=g.m)

new_edges = []
for _ in range(200):
    u = int(rng.integers(0, g.n))
    nb = g.neighbors(u)
    if len(nb):
        new_edges.append((u, int(nb[rng.integers(0, len(nb))])))
    if len(new_edges) >= 10:
        break

k = 5
flagged = 0
for (v, v2) in new_edges:
    # cycles through the new edge = paths v2 -> v of length <= k-1
    cons = AccumulativeValue(weights=amounts, op=np.add, init=0.0,
                             accept=lambda b: b >= 4000.0)
    try:
        out = engine.query(g, v2, v, k - 1, mode="dfs", constraint=cons)
    except ValueError:
        continue  # v2 == v (self-loop edge)
    if out.result.count:
        flagged += 1
        print(f"edge ({v}->{v2}): {out.result.count} high-value cycles, "
              f"e.g. {out.result.as_tuples()[0]}")
print(f"flagged {flagged}/{len(new_edges)} new edges")
