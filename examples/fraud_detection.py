"""Motivation example 2 of the paper: e-commerce fraud cycles, ranked.

New edge (v, v') triggers cycle detection = q(v', v, k-1) plus the edge.
Edges carry transaction amounts; an investigator doesn't want *a* cycle,
they want the **highest-value** cycles first — so this example uses
ranked enumeration (``order="weight"``, DESIGN.md §10) instead of an
Appendix-E threshold constraint: the top-ranked paths under the amount
weighting come back in deterministic best-first order, and ``first_n``
returns exactly the top-n without enumerating the rest.

``order="weight"`` ranks cheapest-first, so to surface the *largest*
cycles we rank by headroom (max_amount - amount per edge): the paths
whose total headroom is smallest are the ones that moved the most money.

    PYTHONPATH=src python examples/fraud_detection.py
"""
import numpy as np

from repro.core import PathEnum, erdos_renyi

rng = np.random.default_rng(3)
g = erdos_renyi(300, 8.0, seed=3)
engine = PathEnum()

# transaction amounts as edge weights; rank cycles by total value
amounts = rng.uniform(10.0, 5000.0, size=g.m)
headroom = amounts.max() - amounts          # cheapest headroom = most money

new_edges = []
for _ in range(200):
    u = int(rng.integers(0, g.n))
    nb = g.neighbors(u)
    if len(nb):
        new_edges.append((u, int(nb[rng.integers(0, len(nb))])))
    if len(new_edges) >= 10:
        break

k = 5
amap = {(int(a), int(b)): float(w)
        for a, b, w in zip(g.esrc, g.edst, amounts)}
flagged = 0
for (v, v2) in new_edges:
    # cycles through the new edge = paths v2 -> v of length <= k-1,
    # best (highest-value) three first — no threshold to tune
    try:
        out = engine.query(g, v2, v, k - 1, mode="dfs", order="weight",
                           weights=headroom, first_n=3)
    except ValueError:
        continue  # v2 == v (self-loop edge)
    if out.result.count:
        flagged += 1
        top = out.result.as_tuples()[0]
        value = sum(amap[e] for e in zip(top, top[1:]))
        print(f"edge ({v}->{v2}): top cycle moves {value:,.0f} "
              f"across {len(top) - 1} hops: {top}")
print(f"flagged {flagged}/{len(new_edges)} new edges")
