"""Quickstart: the paper's pipeline end to end on a small graph.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import PathEnum, erdos_renyi, layered_dag, oracle

# 1. build a workload graph and a query q(s, t, k)
g = layered_dag(layers=4, width=8, fanout=3.0, seed=7)
s, t, k = g.n - 2, g.n - 1, 5

# 2. run PathEnum (index -> optimize -> enumerate)
engine = PathEnum(tau=100)     # low tau to show the full optimizer path
out = engine.query(g, s, t, k)

print(f"query q(s={s}, t={t}, k={k}) on |V|={g.n} |E|={g.m}")
print(f"  plan: {out.plan.method} (cut={out.plan.cut}, "
      f"T_dfs={out.plan.t_dfs}, T_join={out.plan.t_join})")
print(f"  results: {out.result.count} paths")
print(f"  index: {out.index.num_index_edges} edges "
      f"({out.index.memory_bytes()/1024:.1f} KiB), "
      f"built in {out.timing.index_seconds*1e3:.2f} ms")
print(f"  enumerate: {out.timing.enumerate_seconds*1e3:.2f} ms")

# 3. cross-check against the reference oracle
want = oracle.enumerate_paths(g, s, t, k)
got = sorted(out.result.as_tuples())
assert got == want, "engine must match the oracle exactly"
print(f"  oracle check: OK ({len(want)} paths)")

# 4. first-1000-results response-time mode (the paper's response metric)
resp = engine.query(g, s, t, k, mode="dfs", first_n=10)
print(f"  first-10 response: {resp.timing.enumerate_seconds*1e3:.2f} ms "
      f"(exhausted={resp.result.exhausted})")
