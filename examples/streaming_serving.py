"""Streaming graphs + the metrics control plane, end to end (DESIGN.md §12).

    PYTHONPATH=src python examples/streaming_serving.py

A fraud graph serves while its edge set changes underneath it.  The demo
walks the full streaming story and asserts every claim, so CI runs it as
a smoke test:

  * ``GraphRegistry.mutate`` streams edge inserts/deletes into a live
    tenant — ``Graph.with_edges`` returns a versioned copy, the version
    lands in every cache key, and the tenant's stale entries are purged
    from the bound engine: post-mutation answers are asserted identical
    to a cold engine on the mutated graph (never a pre-mutation index);
  * ``register`` over a live id is the hot-swap path (v2 in, v1 entries
    out) for bulk rebuilds;
  * ``snapshot(server)`` captures the metrics control plane's read side
    — per-tenant cache counters, merged Fig.-6 enumeration totals,
    admission/SLO stats on the async front-end — exported as JSON and
    Prometheus text, with ``violations()`` re-checking every counter
    identity;
  * ``set_cache_quota`` / ``set_max_pending`` are its write side: live
    quota adjustment, no restart.

Siblings: examples/multi_tenant_serving.py (the static tenancy story),
examples/async_serving.py (single-graph async + SLOs).
"""
import asyncio
import json

import numpy as np

from repro.core import BatchPathEnum, erdos_renyi
from repro.serving import (AsyncHcPEServer, GraphRegistry, HcPEServer,
                           PathQueryRequest, STATUS_OK,
                           STATUS_REJECTED_TENANT_QUOTA, snapshot)


def requests(g, graph_id, count, rng, uid0=0, **kw):
    out = []
    while len(out) < count:
        s, t = map(int, rng.choice(g.n, 2, replace=False))
        out.append(PathQueryRequest(uid=uid0 + len(out), s=s, t=t, k=4,
                                    graph_id=graph_id, **kw))
    return out


def main():
    rng = np.random.default_rng(0)
    g_v0 = erdos_renyi(800, 5.0, seed=3)

    registry = GraphRegistry()
    registry.register("fraud", g_v0, cache_quota=32)
    server = HcPEServer(registry)
    reqs = requests(g_v0, "fraud", 20, rng)

    # -- serve on v0, then stream a mutation in ----------------------------
    server.serve(reqs)
    resp0, _ = server.serve(reqs)                 # warm pass, all hits
    print(f"v0: {len(resp0)} responses, "
          f"{server.engine.cache.tenant_len('fraud')} cached indexes")

    new_edges = np.array([[0, 1], [1, 0], [2, 700], [700, 2]])
    drop = g_v0.edge_list()[rng.choice(g_v0.m, 400, replace=False)]
    entry = registry.mutate("fraud", add=new_edges, remove=drop)
    print(f"mutate: fraud now version {entry.graph.version}, "
          f"m {g_v0.m} -> {entry.graph.m}, cache purged to "
          f"{server.engine.cache.tenant_len('fraud')} entries")
    assert entry.graph.version == 1
    assert server.engine.cache.tenant_len("fraud") == 0

    # post-mutation answers == a cold engine on the mutated graph: the
    # pre-mutation indexes are unreachable (version is in the cache key)
    resp1, _ = server.serve(reqs)
    cold = BatchPathEnum().run(entry.graph, [(q.s, q.t, q.k) for q in reqs])
    assert [r.count for r in resp1] == cold.counts.tolist()
    changed = sum(1 for a, b in zip(resp0, resp1) if a.count != b.count)
    print(f"v1: counts match a cold engine; {changed}/{len(reqs)} "
          f"queries changed answers across the mutation")

    # -- hot-swap: a bulk rebuild replaces the graph wholesale --------------
    g_rebuilt = entry.graph.with_edges(add=np.array([[3, 4]]))
    registry.register("fraud", g_rebuilt, cache_quota=32)
    assert server.engine.cache.tenant_len("fraud") == 0
    print(f"hot-swap: registered rebuild at version "
          f"{registry.entry('fraud').graph.version}, cache purged again")

    # -- metrics: the sync snapshot -----------------------------------------
    server.serve(reqs)
    snap = snapshot(server)
    assert snap.violations() == []
    tm = snap.tenants["fraud"]
    doc = json.loads(snap.to_json())
    assert doc["tenants"]["fraud"]["cache"]["hits"] == tm.cache.hits
    print(f"snapshot: fraud hit_rate={tm.cache.hit_rate:.2f} "
          f"entries={tm.cache_entries}/{tm.cache_quota} "
          f"enum_results={snap.enum_stats.results}, violations=[]")

    # -- live quota adjustment (the control plane's write path) -------------
    registry.set_cache_quota("fraud", 4)
    assert server.engine.cache.tenant_len("fraud") == 4
    print("set_cache_quota(4): cache shed to 4 entries live")

    # -- async front-end: admission stats + Prometheus export ---------------
    async def drive():
        async with AsyncHcPEServer(registry, batch_window_ms=1.0) as asrv:
            registry.set_max_pending("fraud", 2)       # throttle live
            flood = requests(registry.get("fraud"), "fraud", 12, rng,
                             uid0=100, deadline_ms=500.0)
            resps = await asrv.serve(flood)
            return snapshot(asrv), resps

    asnap, resps = asyncio.run(drive())
    ok = sum(1 for r in resps if r.status == STATUS_OK)
    shed = sum(1 for r in resps if r.status == STATUS_REJECTED_TENANT_QUOTA)
    s = asnap.serve
    assert s.submitted == s.accepted + s.rejected_total == 12
    assert asnap.violations() == []
    print(f"async: {ok} served, {shed} shed by max_pending=2; "
          f"admission identity holds ({s.submitted} == {s.accepted} + "
          f"{s.rejected_total})")

    prom = asnap.to_prometheus()
    assert "pathenum_serve_submitted_total 12" in prom.splitlines()
    print(f"prometheus export: {len(prom.splitlines())} lines, e.g.")
    for line in prom.splitlines()[:4]:
        print(f"  {line}")
    print("OK")


if __name__ == "__main__":
    main()
