"""End-to-end LM training driver (deliverable (b)): ~100M params.

Short demo by default; pass --steps 300 for the full run.

    PYTHONPATH=src python examples/train_lm.py [--steps N]
"""
import subprocess
import sys

steps = "30"
if "--steps" in sys.argv:
    steps = sys.argv[sys.argv.index("--steps") + 1]
subprocess.run([sys.executable, "-m", "repro.launch.train",
                "--preset", "lm100m", "--steps", steps,
                "--batch", "4", "--seq", "128",
                "--metrics-out", "/tmp/lm100m_metrics.json"],
               check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                **__import__("os").environ})
