"""Motivation example 3: paths as training signal for KG completion.

PathEnum enumerates hop-constrained paths between entity pairs; the data
pipeline tokenizes them; a small LM trains on the path corpus.

    PYTHONPATH=src python examples/kg_completion.py
"""
import jax

from repro.configs.base import ArchConfig
from repro.core import power_law
from repro.data.pipeline import PathCorpus
from repro.optim import adamw
from repro.training.trainer import Trainer, TrainerConfig

graph = power_law(500, 5.0, seed=11)
data = PathCorpus(graph=graph, k=4, seq_len=32, global_batch=8)

cfg = ArchConfig(name="kg_lm", family="dense", num_layers=2, d_model=128,
                 num_heads=4, kv_heads=2, d_ff=256, vocab=data.vocab,
                 head_dim=32, attn_chunk=32, tie_embeddings=True)
opt = adamw.OptimizerConfig(peak_lr=1e-3, warmup_steps=5, total_steps=30)
trainer = Trainer(cfg, opt, TrainerConfig(steps=30, log_every=5))
trainer.fit(data)
first, last = trainer.metrics_log[0], trainer.metrics_log[-1]
print(f"path-LM loss: step {first['step']}: {first['loss']:.3f} -> "
      f"step {last['step']}: {last['loss']:.3f}")
assert last["loss"] < first["loss"], "training on path corpus must learn"
print("OK")
