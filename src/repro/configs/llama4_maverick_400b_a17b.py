"""llama4-maverick-400b-a17b — MoE 128 experts top-1, interleaved every
other layer (dense FFN between), early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4_maverick_400b_a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, kv_heads=8,
    d_ff=8192, vocab=202048, head_dim=128,
    num_experts=128, top_k=1, moe_every=2,
    source="[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
)
