"""starcoder2-7b — GQA kv=4, RoPE. [arXiv:2402.19173; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2_7b", family="dense",
    num_layers=32, d_model=4608, num_heads=36, kv_heads=4,
    d_ff=18432, vocab=49152, head_dim=128,
    source="[arXiv:2402.19173; hf]",
)
