"""qwen3-moe-30b-a3b — 128 experts top-8, expert d_ff=768, every layer.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3_moe_30b_a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, kv_heads=4,
    d_ff=768, vocab=151936, head_dim=64,
    num_experts=128, top_k=8, moe_every=1,
    source="[hf:Qwen/Qwen3-30B-A3B; hf]",
)
