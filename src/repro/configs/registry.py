"""Registry mapping --arch ids to configs (one module per assigned arch)."""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import ArchConfig, SHAPES, ShapeConfig

ARCH_IDS: List[str] = [
    "phi3_vision_4p2b",
    "mistral_large_123b",
    "llama3p2_1b",
    "starcoder2_7b",
    "internlm2_1p8b",
    "llama4_maverick_400b_a17b",
    "qwen3_moe_30b_a3b",
    "mamba2_780m",
    "recurrentgemma_9b",
    "musicgen_large",
]

# accept the assignment-sheet spellings too
ALIASES = {
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "mistral-large-123b": "mistral_large_123b",
    "llama3.2-1b": "llama3p2_1b",
    "starcoder2-7b": "starcoder2_7b",
    "internlm2-1.8b": "internlm2_1p8b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "mamba2-780m": "mamba2_780m",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "musicgen-large": "musicgen_large",
}


def get_arch(name: str) -> ArchConfig:
    name = ALIASES.get(name, name)
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def all_archs() -> Dict[str, ArchConfig]:
    return {a: get_arch(a) for a in ARCH_IDS}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]
