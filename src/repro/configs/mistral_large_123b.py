"""mistral-large-123b — dense GQA. [hf:mistralai/Mistral-Large-Instruct-2407;
unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mistral_large_123b", family="dense",
    num_layers=88, d_model=12288, num_heads=96, kv_heads=8,
    d_ff=28672, vocab=32768, head_dim=128,
    source="[hf:mistralai/Mistral-Large-Instruct-2407; unverified]",
)
