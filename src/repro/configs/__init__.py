from .base import ArchConfig, ShapeConfig, SHAPES
from .registry import ARCH_IDS, ALIASES, all_archs, get_arch, get_shape
