"""phi-3-vision-4.2b — phi3-mini backbone + CLIP vision frontend (stubbed).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi3_vision_4p2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, kv_heads=32,
    d_ff=8192, vocab=32064, head_dim=96,
    frontend="vision_stub", frontend_len=256,
    source="[hf:microsoft/Phi-3-vision-128k-instruct; hf]",
)
