"""mamba2-780m — attention-free SSD (state-space duality); runs long_500k.
[arXiv:2405.21060; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2_780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=1, kv_heads=1,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
    sub_quadratic=True, tie_embeddings=True,
    source="[arXiv:2405.21060; unverified]",
)
