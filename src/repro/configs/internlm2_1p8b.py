"""internlm2-1.8b — GQA kv=8. [arXiv:2403.17297; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2_1p8b", family="dense",
    num_layers=24, d_model=2048, num_heads=16, kv_heads=8,
    d_ff=8192, vocab=92544, head_dim=128,
    source="[arXiv:2403.17297; hf]",
)
