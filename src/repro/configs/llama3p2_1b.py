"""llama3.2-1b — small llama3, GQA kv=8, tied embeddings.
[hf:meta-llama/Llama-3.2-1B; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama3p2_1b", family="dense",
    num_layers=16, d_model=2048, num_heads=32, kv_heads=8,
    d_ff=8192, vocab=128256, head_dim=64,
    rope_theta=500_000.0, tie_embeddings=True,
    source="[hf:meta-llama/Llama-3.2-1B; unverified]",
)
