"""Architecture config schema + the four assigned input shapes.

Every assigned architecture is a frozen ``ArchConfig``; ``reduced()``
derives the family-preserving smoke config (small widths/layers/experts)
used by tests — the full configs are exercised only through the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 1           # MoE layer every N layers (llama4: 2)
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    conv_width: int = 4
    # --- hybrid (RG-LRU + local attention) ---
    attn_window: int = 0         # 0 -> full attention
    pattern: Tuple[str, ...] = ()  # e.g. ("rec","rec","attn")
    rnn_width: int = 0
    # --- modality frontend stubs ---
    frontend: str = "none"       # none | vision_stub | audio_stub
    frontend_len: int = 0        # prefix positions fed by the stub
    # --- misc ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    sub_quadratic: bool = False  # eligible for long_500k
    remat: bool = True
    attn_chunk: int = 1024       # q-chunk for the XLA attention path
    # analysis-only: unroll layer loops so XLA cost analysis (which counts
    # while-loop bodies ONCE, verified empirically) reports true totals.
    unroll: bool = False
    # §Perf lever: shard layer-boundary residuals over (dp, model-on-seq) —
    # Megatron sequence parallelism; divides saved-activation memory by the
    # model-axis size at the cost of seq all-gathers at attention inputs.
    seq_shard_activations: bool = False
    source: str = ""             # provenance note [source; tier]

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_attn = d * (self.num_heads * self.hd) + 2 * d * (self.kv_heads * self.hd) \
            + (self.num_heads * self.hd) * d
        total = emb
        for li in range(self.num_layers):
            if self.family in ("dense", "vlm", "audio", "moe"):
                total += per_attn + 2 * d  # attn + 2 norms
                if self.family == "moe" and (li % self.moe_every == 0):
                    total += self.num_experts * 3 * d * f + d * self.num_experts
                else:
                    total += 3 * d * f
            elif self.family == "ssm":
                di, ns = self.d_inner, self.ssm_state
                total += d * (2 * di + 2 * ns + self.ssm_heads) + di * d \
                    + 2 * d + self.ssm_heads * 2 + di * self.conv_width
            elif self.family == "hybrid":
                kind = self.pattern[li % len(self.pattern)] if self.pattern else "attn"
                total += 2 * d
                if kind == "attn":
                    total += per_attn
                else:
                    w = self.rnn_width or d
                    total += 2 * d * w + w * d + 3 * w + w * self.conv_width
                total += 3 * d * f
        if self.frontend != "none":
            total += self.d_model * self.d_model  # stub projection
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top_k only)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        total = self.param_count()
        for li in range(self.num_layers):
            if li % self.moe_every == 0:
                total -= self.num_experts * 3 * d * f
                total += self.top_k * 3 * d * f
        return int(total)

    def reduced(self) -> "ArchConfig":
        """Family-preserving smoke config (CPU: one step in seconds)."""
        return dataclasses.replace(
            self,
            num_layers=min(self.num_layers, 3 if not self.pattern else len(self.pattern)),
            d_model=128,
            num_heads=4,
            kv_heads=max(1, min(self.kv_heads, 2)) if self.kv_heads < self.num_heads else 4,
            d_ff=256,
            vocab=512,
            head_dim=32,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=16,
            attn_window=min(self.attn_window, 16) if self.attn_window else 0,
            rnn_width=128 if self.rnn_width else 0,
            frontend_len=min(self.frontend_len, 4) if self.frontend_len else 0,
            attn_chunk=32,
        )

    def shape_supported(self, shape: ShapeConfig) -> Tuple[bool, str]:
        """(supported, reason) — long_500k only for sub-quadratic archs."""
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False, "skipped(full-attention): no sub-quadratic mechanism"
        return True, ""
