"""musicgen-large — decoder-only over EnCodec tokens; text-conditioning
frontend stubbed as precomputed frame embeddings. [arXiv:2306.05284; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen_large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, kv_heads=32,
    d_ff=8192, vocab=2048, head_dim=64,
    frontend="audio_stub", frontend_len=64,
    source="[arXiv:2306.05284; hf]",
)
