"""recurrentgemma-9b — RG-LRU + local attention (window 2048), pattern
(rec, rec, attn) = 1 attn : 2 rec; runs long_500k.
[arXiv:2402.19427; unverified]  38 layers = 12 super-blocks + 2 tail rec.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma_9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256,
    attn_window=2048, pattern=("rec", "rec", "attn"), rnn_width=4096,
    sub_quadratic=True,
    source="[arXiv:2402.19427; unverified]",
)
