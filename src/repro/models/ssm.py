"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD algorithm, the TPU-friendly form: the sequence is split into
chunks of ``ssm_chunk``; within a chunk the output is a masked quadratic
(attention-like) term that runs on the MXU, and chunk-to-chunk interaction
is a first-order recurrence over per-chunk states (lax.scan over the
*chunk* axis — k/chunk steps instead of k, so the sequential depth is tiny
even at 500k tokens, which is exactly why this arch runs long_500k).

Decode is the O(1) recurrent form: h ← dA·h + dt·B·x, y = C·h + D·x.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import causal_conv1d, causal_conv1d_step, init_dense, rms_norm


def init_ssm(key, cfg: ArchConfig, dtype=jnp.float32):
    d, di, ns, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 5)
    return {
        # projections: [z (gate) | x | B | C | dt]
        "in_proj": init_dense(ks[0], (d, 2 * di + 2 * ns + nh), dtype=dtype),
        "out_proj": init_dense(ks[1], (di, d), dtype=dtype),
        "conv_w": init_dense(ks[2], (di + 2 * ns, cfg.conv_width),
                             scale=0.5, dtype=dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),      # A = -exp(A_log) in (-1,0)
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((di,), dtype=dtype),
    }


def _split_proj(cfg: ArchConfig, proj):
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * ns]
    dt = proj[..., di + di + 2 * ns:]
    return z, xbc, dt


def ssd_forward(params, x: jnp.ndarray, cfg: ArchConfig):
    """x (B, L, D) -> (B, L, D).  L must be a multiple of ssm_chunk."""
    Bsz, L, _ = x.shape
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Q = cfg.ssm_chunk
    Lp = ((L + Q - 1) // Q) * Q
    if Lp != L:
        # causal: zero-padding the tail never affects earlier outputs
        x = jnp.pad(x, ((0, 0), (0, Lp - L), (0, 0)))
    out = _ssd_forward_aligned(params, x, cfg)
    return out[:, :L]


def _ssd_forward_aligned(params, x: jnp.ndarray, cfg: ArchConfig):
    Bsz, L, _ = x.shape
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Q = cfg.ssm_chunk
    nc = L // Q

    from ..distributed import constraints as con

    proj = con.constrain(jnp.einsum("bld,de->ble", x, params["in_proj"]),
                         con.act_bsf)
    z, xbc, dt = _split_proj(cfg, proj)
    xbc = causal_conv1d(xbc, params["conv_w"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di].reshape(Bsz, L, nh, hd)
    Bv = xbc[..., di:di + ns]                       # (B, L, N)
    Cv = xbc[..., di + ns:]                         # (B, L, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,L,H)
    A = -jnp.exp(params["A_log"])                   # (H,)
    dA = dt * A                                     # (B, L, H) log-decay

    # --- chunked SSD ---
    xs_c = xs.reshape(Bsz, nc, Q, nh, hd)
    B_c = Bv.reshape(Bsz, nc, Q, ns)
    C_c = Cv.reshape(Bsz, nc, Q, ns)
    dA_c = dA.reshape(Bsz, nc, Q, nh)
    dt_c = dt.reshape(Bsz, nc, Q, nh)

    seg = jnp.cumsum(dA_c, axis=2)                  # (B, nc, Q, H) running log-decay
    # intra-chunk quadratic term: y_intra[t] = Σ_{s<=t} C_t·B_s exp(seg_t-seg_s) dt_s x_s
    decay = seg[:, :, :, None, :] - seg[:, :, None, :, :]      # (B,nc,Q,Q,H) t,s
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    gmat = jnp.where(tri[None, None, :, :, None], jnp.exp(decay), 0.0)
    gmat = con.constrain(gmat, con.ssd_intra)  # heads over model: the (Q,Q,H)
    cb = jnp.einsum("bctn,bcsn->bcts", C_c, B_c)               # (B,nc,Q,Q)
    y_intra = jnp.einsum("bcts,bctsh,bcsh,bcshp->bcthp",
                         cb, gmat, dt_c, xs_c)

    # per-chunk final state: S_c = Σ_s exp(seg_Q - seg_s) dt_s B_s ⊗ x_s
    tail = seg[:, :, -1:, :] - seg                              # (B,nc,Q,H)
    st = jnp.einsum("bcsh,bcsh,bcsn,bcshp->bchnp",
                    jnp.exp(tail), dt_c, B_c, xs_c)             # (B,nc,H,N,P)
    chunk_decay = jnp.exp(seg[:, :, -1, :])                     # (B,nc,H)

    # inter-chunk recurrence over chunk states
    def scan_fn(h, inp):
        s_c, dec = inp                                          # (B,H,N,P),(B,H)
        h_new = h * dec[..., None, None] + s_c
        return h_new, h

    init = jnp.zeros((Bsz, nh, ns, hd), jnp.float32)
    _, h_prev = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(st, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                         # (B,nc,H,N,P)

    # inter-chunk contribution: y_inter[t] = C_t · exp(seg_t) · h_prev(chunk)
    y_inter = jnp.einsum("bctn,bcth,bchnp->bcthp",
                         C_c, jnp.exp(seg), h_prev)

    y = (y_intra + y_inter).reshape(Bsz, L, nh, hd)
    y = y + xs * params["D"][None, None, :, None]
    y = y.reshape(Bsz, L, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    return con.constrain(out, con.act_bsd)


def ssd_decode_step(params, x_t: jnp.ndarray, state, cfg: ArchConfig):
    """x_t (B, D); state = (conv_state (B, W-1, C), h (B, H, N, P))."""
    conv_state, h = state
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = jnp.einsum("bd,de->be", x_t, params["in_proj"])
    z, xbc, dt = _split_proj(cfg, proj)
    xbc, conv_state = causal_conv1d_step(xbc, conv_state, params["conv_w"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di].reshape(-1, nh, hd)
    Bv = xbc[..., di:di + ns]
    Cv = xbc[..., di + ns:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    dA = jnp.exp(dt * (-jnp.exp(params["A_log"])))                    # (B,H)
    h = h * dA[..., None, None] + jnp.einsum("bh,bn,bhp->bhnp", dt, Bv, xs)
    y = jnp.einsum("bn,bhnp->bhp", Cv, h) + xs * params["D"][None, :, None]
    y = y.reshape(-1, di).astype(x_t.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return jnp.einsum("be,ed->bd", y, params["out_proj"]), (conv_state, h)


def init_ssm_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    di, ns = cfg.d_inner, cfg.ssm_state
    conv = jnp.zeros((batch, cfg.conv_width - 1, di + 2 * ns), dtype)
    h = jnp.zeros((batch, cfg.ssm_heads, ns, cfg.ssm_head_dim), jnp.float32)
    return (conv, h)
