"""Shared neural building blocks (pure-JAX, shard-friendly)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x (..., L, H, D) with positions (..., L)."""
    freqs = rope_freqs(x.shape[-1], theta)                     # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., L, D/2)
    cos = jnp.cos(angles)[..., None, :]                         # (..., L, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    from ..distributed import constraints as con

    def proj_spec(rules, shape):
        # (..., F): features over model, batch (leading dim) over dp
        lead = rules.dp(shape[0]) if len(shape) >= 2 else None
        mids = (None,) * max(len(shape) - 2, 0)
        return con.P(lead, *mids, rules.tp(shape[-1]))

    g = con.constrain(jnp.einsum("...d,df->...f", x, w_gate), proj_spec)
    u = con.constrain(jnp.einsum("...d,df->...f", x, w_up), proj_spec)
    out = jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)

    def out_spec(rules, shape):
        lead = rules.dp(shape[0]) if len(shape) >= 2 else None
        return con.P(lead, *((None,) * (len(shape) - 1)))

    return con.constrain(out, out_spec)


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv.  x (B, L, C); w (C, W)."""
    W = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    stacked = jnp.stack([xp[:, i:i + x.shape[1]] for i in range(W)], axis=-1)
    return jnp.einsum("blcw,cw->blc", stacked, w)


def causal_conv1d_step(x_t: jnp.ndarray, conv_state: jnp.ndarray,
                       w: jnp.ndarray):
    """One decode step.  x_t (B, C); conv_state (B, W-1, C)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,W,C)
    y = jnp.einsum("bwc,cw->bc", window, w)
    return y, window[:, 1:]


def init_dense(key, shape, scale: float | None = None, dtype=jnp.float32):
    fan_in = shape[0]
    if scale is None:
        scale = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
