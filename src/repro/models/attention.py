"""GQA attention — XLA path (q-chunked, shard-friendly) + Pallas path.

The XLA path is what the multi-pod dry-run lowers: a lax.scan over query
chunks keeps the logits working set to (B, H, chunk, L) so long-context
prefill fits HBM (§Perf lever `attn_chunk`).  The Pallas flash kernel is
the TPU-target hot path, validated in interpret mode; both are numerically
interchangeable (tests/test_models.py asserts parity).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels import ops as kops
from .layers import apply_rope, init_dense

NEG_INF = -1e30


def init_attention(key, cfg: ArchConfig, dtype=jnp.float32):
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], (d, h * hd), dtype=dtype),
        "wk": init_dense(ks[1], (d, hkv * hd), dtype=dtype),
        "wv": init_dense(ks[2], (d, hkv * hd), dtype=dtype),
        "wo": init_dense(ks[3], (h * hd, d), dtype=dtype),
    }


def _xla_attention(q, k, v, *, causal: bool, window: Optional[int],
                   q_chunk: int, q_offset: int = 0) -> jnp.ndarray:
    """q (B, Lq, H, D); k/v (B, Lk, Hkv, D).  Chunked over Lq.

    KV is repeated to the full head count *after* a head-sharding
    constraint, so each model shard materializes only its own heads'
    replicas (bytes: B·L·(H/tp)·hd — small) and the (B, H, qc, Lk) logits
    tensor shards over heads (sequence-parallel fallback when H doesn't
    divide; see distributed/constraints.py).  Without these constraints
    GSPMD replicates the logits — measured +100 GB/device on train_4k.
    """
    from ..distributed import constraints as con

    B, Lq, H, D = q.shape
    _, Lk, Hkv, _ = k.shape
    group = H // Hkv
    scale = 1.0 / (D ** 0.5)
    qc = min(q_chunk, Lq)
    if Lq % qc != 0:
        qc = Lq
    nq = Lq // qc
    q = con.constrain(q, con.act_heads)
    kq = jnp.repeat(k, group, axis=2) if group > 1 else k
    vq = jnp.repeat(v, group, axis=2) if group > 1 else v
    kq = con.constrain(kq, con.act_heads)
    vq = con.constrain(vq, con.act_heads)
    qr = q.reshape(B, nq, qc, H, D)
    ki = jnp.arange(Lk)

    def chunk(ci):
        qi = qr[:, ci]                                      # (B, qc, H, D)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qi, kq).astype(jnp.float32)
        logits = con.constrain(logits, con.logits_bhqk) * scale
        rows = ci * qc + jnp.arange(qc) + q_offset
        if causal:
            mask = rows[:, None] >= ki[None, :]
            if window:
                mask &= (rows[:, None] - ki[None, :]) < window
            logits = jnp.where(mask[None, None], logits, NEG_INF)
        p = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vq.dtype), vq)
        return con.constrain(o, con.act_heads)

    out = jax.lax.map(chunk, jnp.arange(nq))                # (nq, B, qc, H, D)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Lq, H, D)
    return con.constrain(out, con.act_heads)


def attention(params, x, cfg: ArchConfig, positions, *, impl: str = "xla",
              window: Optional[int] = None, kv_cache=None,
              cache_len=None, valid_len=None):
    """Self-attention over x (B, L, D).

    Training/prefill: kv_cache None -> returns (out, (k, v)) so prefill can
    seed the cache.  Decode: x is (B, 1, D), kv_cache=(k, v) with static S,
    cache_len (B,) insertion slots; ``valid_len`` (B,) optionally overrides
    the number of valid cache entries (ring buffers for windowed attention:
    the cache *is* the window, so all min(pos+1, S) entries are live and no
    extra window mask applies — entry positions were RoPE'd at insert).
    Returns (out, (k, v) updated).
    """
    from ..distributed import constraints as con

    B, L, D = x.shape
    h, hkv, hd = cfg.num_heads, cfg.kv_heads, cfg.hd
    q = con.constrain(jnp.einsum("bld,de->ble", x, params["wq"]),
                      con.act_bsf).reshape(B, L, h, hd)
    k = con.constrain(jnp.einsum("bld,de->ble", x, params["wk"]),
                      con.act_bsf).reshape(B, L, hkv, hd)
    v = con.constrain(jnp.einsum("bld,de->ble", x, params["wv"]),
                      con.act_bsf).reshape(B, L, hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    win = window if window else (cfg.attn_window or None)
    if kv_cache is None:
        if impl == "flash":
            out = kops.flash_attention(q, k, v, causal=True, window=win)
        else:
            out = _xla_attention(q, k, v, causal=True, window=win,
                                 q_chunk=cfg.attn_chunk)
        new_cache = (k, v)
    else:
        ck, cv = kv_cache                                   # (B, S, Hkv, hd)
        S = ck.shape[1]
        pos_idx = cache_len                                  # (B,) insert slot
        bidx = jnp.arange(B)
        ck = ck.at[bidx, pos_idx].set(k[:, 0])
        cv = cv.at[bidx, pos_idx].set(v[:, 0])
        lengths = (cache_len + 1) if valid_len is None else valid_len
        if impl == "flash":
            out = kops.decode_attention(q[:, 0], ck, cv, lengths)[:, None]
        else:
            scale = 1.0 / (hd ** 0.5)
            group = h // hkv
            qg = q[:, 0].reshape(B, hkv, group, hd)
            logits = jnp.einsum("bhgd,bshd->bhgs", qg, ck).astype(jnp.float32)
            logits *= scale
            sidx = jnp.arange(S)
            mask = sidx[None, :] < lengths[:, None]
            logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
            p = jax.nn.softmax(logits, axis=-1)
            out = jnp.einsum("bhgs,bshd->bhgd", p.astype(cv.dtype), cv)
            out = out.reshape(B, 1, h, hd)
        new_cache = (ck, cv)

    Lo = out.shape[1]
    out = jnp.einsum("ble,ed->bld", out.reshape(B, Lo, h * hd), params["wo"])
    out = con.constrain(out, con.act_bsd)
    return out, new_cache
