"""Mixture-of-Experts FFN with capacity-bounded gather dispatch.

Dispatch strategy (TPU-native, EP-shardable): tokens are routed top-k, then
each expert gathers up to C = ceil(tokens·top_k/E · capacity_factor) token
slots (deterministic position-in-expert ranking via cumsum — the standard
capacity formulation).  Expert weights are stacked (E, ...) so the expert
dimension shards over the `model`/`expert` mesh axis; the gather/combine
pair lowers to all-to-all under SPMD (visible in the dry-run collective
dump).  Overflowed tokens fall through with zero update (residual carries
them), the usual capacity-dropping semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import init_dense


def init_moe(key, cfg: ArchConfig, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": init_dense(ks[0], (d, e), dtype=jnp.float32),
        "w_gate": init_dense(ks[1], (e, d, f), dtype=dtype),
        "w_up": init_dense(ks[2], (e, d, f), dtype=dtype),
        "w_down": init_dense(ks[3], (e, f, d), dtype=dtype),
    }


def moe_ffn(params, x: jnp.ndarray, cfg: ArchConfig, decode: bool = False):
    """x (B, L, D) -> (B, L, D), plus aux losses dict.

    decode=True switches to the exact per-token expert gather (no capacity):
    decode batches are small, so gathering K expert weight slices per token
    is cheap and removes the batch-dependent capacity-drop nondeterminism.
    """
    B, L, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * L
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)          # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    if decode:
        wg = params["w_gate"][expert_ids]                    # (T, K, D, F)
        wu = params["w_up"][expert_ids]
        wd = params["w_down"][expert_ids]
        g = jnp.einsum("td,tkdf->tkf", xt, wg)
        u = jnp.einsum("td,tkdf->tkf", xt, wu)
        y = jnp.einsum("tkf,tkfd->tkd", jax.nn.silu(g) * u, wd)
        out = (y * gate_vals[..., None]).sum(axis=1)
        me = probs.mean(axis=0)
        ce = jax.nn.one_hot(expert_ids[:, 0], E).mean(axis=0)
        aux = {"moe_balance": (E * (me * ce).sum()).astype(jnp.float32)}
        return out.reshape(B, L, D).astype(x.dtype), aux

    cap = int(max(1, round(T * K / E * cfg.capacity_factor)))

    # position of each (token, k) within its expert queue — sort-based
    # ranking.  The textbook one-hot cumsum builds a (T·K, E) tensor and a
    # full-length prefix scan; measured on qwen3 (T=1M, K=8, E=128) it
    # dominated the layer's HLO flops by >100×.  Sorting the T·K expert
    # keys and ranking within runs is O(T·K log) and SPMD-friendly
    # (§Perf iteration 6).
    e_flat_all = expert_ids.reshape(-1)                        # (T*K,)
    order = jnp.argsort(e_flat_all, stable=True)
    sorted_e = e_flat_all[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))      # (E,)
    rank_sorted = jnp.arange(T * K) - seg_start[sorted_e]
    pos = jnp.zeros(T * K, jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32)).reshape(T, K)
    keep = pos < cap

    # scatter token ids into (E, cap) slots
    slot_tok = jnp.zeros((E, cap), dtype=jnp.int32)
    slot_gate = jnp.zeros((E, cap), dtype=jnp.float32)
    slot_valid = jnp.zeros((E, cap), dtype=jnp.bool_)
    e_flat = expert_ids.reshape(-1)
    k_keep = keep.reshape(-1)
    tok_ids = jnp.repeat(jnp.arange(T), K)
    # overflowed (token,k) pairs get position == cap, an out-of-bounds index
    # that mode="drop" discards — capacity dropping in one scatter.
    p_idx = jnp.where(k_keep, pos.reshape(-1), cap)
    slot_tok = slot_tok.at[e_flat, p_idx].set(tok_ids, mode="drop")
    slot_gate = slot_gate.at[e_flat, p_idx].set(gate_vals.reshape(-1),
                                                mode="drop")
    slot_valid = slot_valid.at[e_flat, p_idx].set(True, mode="drop")

    from ..distributed import constraints as con

    xe = con.constrain(xt[slot_tok], con.moe_slots)           # (E, cap, D)
    g = con.constrain(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"]),
                      con.moe_slots)
    u = con.constrain(jnp.einsum("ecd,edf->ecf", xe, params["w_up"]),
                      con.moe_slots)
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, params["w_down"])
    ye = con.constrain(ye * slot_gate[..., None] * slot_valid[..., None],
                       con.moe_slots)

    out = jnp.zeros((T, D), dtype=ye.dtype).at[slot_tok.reshape(-1)].add(
        ye.reshape(-1, D))
    out = con.constrain(out, lambda r, s: con.P(r.dp(s[0]), None))

    # load-balance aux loss (Switch-style)
    me = probs.mean(axis=0)                                   # (E,)
    ce = jax.nn.one_hot(expert_ids[:, 0], E).mean(axis=0)
    aux = {"moe_balance": (E * (me * ce).sum()).astype(jnp.float32)}
    return out.reshape(B, L, D).astype(x.dtype), aux
