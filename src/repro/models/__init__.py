from . import attention, layers, moe, rglru, ssm, transformer
from .transformer import (decode_step, forward, init_cache, init_params,
                          layer_plan, loss_fn, prefill)
