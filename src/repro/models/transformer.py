"""Unified decoder assembly for all assigned families.

One params schema + three entry points (`forward` for train, `prefill`,
`decode_step`) covering dense / moe / ssm / hybrid / vlm / audio.  Layers
are *stacked pytrees* consumed by ``lax.scan`` so the HLO holds one layer
body regardless of depth (essential for 88-layer dry-runs), with
``jax.checkpoint`` around the body when cfg.remat (save only layer
boundaries).  Heterogeneous stacks (llama4's moe-every-2, RecurrentGemma's
rec/rec/attn pattern) scan over *super-blocks* — the smallest repeating
group — plus an explicit tail for non-divisible depths.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as attn_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .layers import init_dense, rms_norm, swiglu

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_mlp(key, d, f, dtype):
    ks = jax.random.split(key, 3)
    return {"w_gate": init_dense(ks[0], (d, f), dtype=dtype),
            "w_up": init_dense(ks[1], (d, f), dtype=dtype),
            "w_down": init_dense(ks[2], (f, d), dtype=dtype)}


def _init_block(key, cfg: ArchConfig, kind: str, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    blk = {"ln1": jnp.zeros((d,), dtype)}
    if kind == "attn":
        blk["attn"] = attn_mod.init_attention(ks[0], cfg, dtype)
    elif kind == "rec":
        blk["rec"] = rglru_mod.init_rglru(ks[0], cfg, dtype)
    elif kind == "ssm":
        blk["ssm"] = ssm_mod.init_ssm(ks[0], cfg, dtype)
        return blk  # mamba2 blocks have no separate MLP
    if kind in ("attn", "rec"):
        blk["ln2"] = jnp.zeros((d,), dtype)
        if cfg.family == "moe" and kind == "attn_moe":
            pass
        blk["mlp"] = _init_mlp(ks[1], d, cfg.d_ff, dtype)
    return blk


def _init_moe_block(key, cfg: ArchConfig, dtype, use_moe: bool):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    blk = {"ln1": jnp.zeros((d,), dtype),
           "attn": attn_mod.init_attention(ks[0], cfg, dtype),
           "ln2": jnp.zeros((d,), dtype)}
    if use_moe:
        blk["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
    else:
        blk["mlp"] = _init_mlp(ks[1], d, cfg.d_ff, dtype)
    return blk


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def layer_plan(cfg: ArchConfig) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    """(super_pattern, num_supers, tail_pattern) for the scan layout."""
    L = cfg.num_layers
    if cfg.family == "ssm":
        return ("ssm",), L, ()
    if cfg.family == "hybrid":
        pat = cfg.pattern or ("rec", "rec", "attn")
        ns = L // len(pat)
        tail = tuple(pat[: L - ns * len(pat)])
        return pat, ns, tail
    if cfg.family == "moe":
        pat = tuple("moe" if i == 0 else "dense" for i in range(cfg.moe_every))
        ns = L // len(pat)
        tail = tuple(pat[: L - ns * len(pat)])
        return pat, ns, tail
    return ("attn",), L, ()


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> Params:
    d, v = cfg.d_model, cfg.vocab
    keys = jax.random.split(key, cfg.num_layers + 4)
    params: Params = {
        "embed": init_dense(keys[0], (v, d), scale=0.02, dtype=dtype),
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = init_dense(keys[1], (d, v), dtype=dtype)
    if cfg.frontend != "none":
        params["frontend_proj"] = init_dense(keys[2], (d, d), dtype=dtype)

    pat, ns, tail = layer_plan(cfg)

    def make(kind, key):
        if kind == "moe":
            return _init_moe_block(key, cfg, dtype, use_moe=True)
        if kind == "dense":
            return _init_moe_block(key, cfg, dtype, use_moe=False)
        return _init_block(key, cfg, kind, dtype)

    li = 0
    supers = []
    for si in range(ns):
        sup = {}
        for j, kind in enumerate(pat):
            sup[f"b{j}_{kind}"] = make(kind, keys[3 + li])
            li += 1
        supers.append(sup)
    params["supers"] = _stack(supers)
    if tail:
        tail_blk = {}
        for j, kind in enumerate(tail):
            tail_blk[f"b{j}_{kind}"] = make(kind, keys[3 + li])
            li += 1
        params["tail"] = tail_blk
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _apply_block(blk, name: str, x, cfg: ArchConfig, positions, aux):
    from ..distributed import constraints as con

    kind = name.split("_", 1)[1]
    if cfg.seq_shard_activations:
        x = con.constrain(x, con.act_bsd_sp)
    h = rms_norm(x, blk["ln1"], cfg.norm_eps)
    if kind in ("attn", "moe", "dense"):
        window = cfg.attn_window or None
        o, _ = attn_mod.attention(blk["attn"], h, cfg, positions,
                                  window=window)
        x = x + o
        h2 = rms_norm(x, blk["ln2"], cfg.norm_eps)
        if kind == "moe":
            o2, a = moe_mod.moe_ffn(blk["moe"], h2, cfg)
            aux = {**aux, "moe_balance": aux.get("moe_balance", 0.0)
                   + a["moe_balance"]}
        else:
            o2 = swiglu(h2, **blk["mlp"])
        x = x + o2
    elif kind == "rec":
        o = rglru_mod.rglru_forward(blk["rec"], h, cfg)
        x = x + o
        h2 = rms_norm(x, blk["ln2"], cfg.norm_eps)
        x = x + swiglu(h2, **blk["mlp"])
    elif kind == "ssm":
        o = ssm_mod.ssd_forward(blk["ssm"], h, cfg)
        x = x + o
    else:
        raise ValueError(kind)
    return x, aux


def _embed(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray]):
    from ..distributed import constraints as con

    tokens = batch["tokens"]
    x = params["embed"][tokens]
    if cfg.frontend != "none" and "prefix_emb" in batch:
        pre = jnp.einsum("bpd,de->bpe", batch["prefix_emb"],
                         params["frontend_proj"]).astype(x.dtype)
        P = pre.shape[1]
        x = jnp.concatenate([pre, x[:, P:]], axis=1)
    if x.ndim == 3:
        x = con.constrain(x, con.act_bsd)
    return x


def forward_hidden(params: Params, cfg: ArchConfig,
                   batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Backbone only: batch["tokens"] (B, S) -> final hidden (B, S, D)."""
    x = _embed(params, cfg, batch)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    aux: Dict[str, jnp.ndarray] = {}
    pat, ns, tail = layer_plan(cfg)

    def body(carry, sup):
        h, aux_moe = carry
        a = {"moe_balance": aux_moe}
        for j, kind in enumerate(pat):
            h, a = _apply_block(sup[f"b{j}_{kind}"], f"b_{kind}", h, cfg,
                                positions, a)
        return (h, a.get("moe_balance", aux_moe)), None

    scan_body = jax.checkpoint(body) if cfg.remat else body
    if cfg.unroll:
        # analysis lowering: identical math, layer loop in Python so XLA
        # cost analysis counts every layer (while bodies count once).
        carry = (x, jnp.float32(0.0))
        for i in range(ns):
            sup = jax.tree.map(lambda v: v[i], params["supers"])
            carry, _ = scan_body(carry, sup)
        x, moe_bal = carry
    else:
        (x, moe_bal), _ = jax.lax.scan(scan_body, (x, jnp.float32(0.0)),
                                       params["supers"])
    if tail:
        a = {"moe_balance": moe_bal}
        for j, kind in enumerate(tail):
            x, a = _apply_block(params["tail"][f"b{j}_{kind}"], f"b_{kind}",
                                x, cfg, positions, a)
        moe_bal = a.get("moe_balance", moe_bal)
    aux["moe_balance"] = moe_bal

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def forward(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Training/prefill forward.  batch["tokens"] (B, S) -> logits (B, S, V)."""
    x, aux = forward_hidden(params, cfg, batch)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, aux


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray]):
    """Vocab-parallel cross entropy.

    CE = logsumexp_v(logits) − logit[label].  logsumexp reduces *over* the
    (model-sharded) vocab axis — cheap psums — and the label logit is
    recovered as ⟨hidden, head_row(label)⟩, an embedding-style row gather
    that never materializes a vocab-replicated (B, S, V) tensor.  Without
    this, take_along_axis over a sharded V forces XLA to all-gather the full
    logits (measured: +100 GB temp on llama3.2-1b train_4k — see
    EXPERIMENTS.md §Perf).
    """
    from ..distributed import constraints as con

    x, aux = forward_hidden(params, cfg, batch)
    labels = batch["labels"]
    head = params["embed"].T if cfg.tie_embeddings else params["head"]

    xs = con.constrain(x[:, :-1].astype(jnp.float32), con.act_bsd)
    logits = jnp.einsum("bsd,dv->bsv", xs, head.astype(jnp.float32))
    logits = con.constrain(logits, con.logits_bsv)
    lse = jax.nn.logsumexp(logits, axis=-1)                  # (B, S-1)

    safe = jnp.maximum(labels[:, 1:], 0)
    rows = con.constrain(head.T[safe].astype(jnp.float32), con.act_bsd)
    lbl_logit = jnp.einsum("bsd,bsd->bs", xs, rows)

    mask = (labels[:, 1:] >= 0).astype(jnp.float32)
    loss = ((lse - lbl_logit) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    if cfg.family == "moe":
        loss = loss + 0.01 * aux["moe_balance"] / max(cfg.num_layers, 1)
    metrics = {"loss": loss, "tokens": mask.sum()}
    return loss, metrics


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def _kind_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int, dtype):
    if kind in ("attn", "moe", "dense"):
        hkv, hd = cfg.kv_heads, cfg.hd
        S = min(max_len, cfg.attn_window) if cfg.attn_window else max_len
        return (jnp.zeros((batch, S, hkv, hd), dtype),
                jnp.zeros((batch, S, hkv, hd), dtype))
    if kind == "rec":
        return rglru_mod.init_rglru_state(cfg, batch, dtype)
    if kind == "ssm":
        return ssm_mod.init_ssm_state(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.float32):
    pat, ns, tail = layer_plan(cfg)
    one_super = {f"b{j}_{kind}": _kind_cache(cfg, kind, batch, max_len, dtype)
                 for j, kind in enumerate(pat)}
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (ns,) + x.shape), one_super)
    cache = {"supers": stacked}
    if tail:
        cache["tail"] = {f"b{j}_{kind}": _kind_cache(cfg, kind, batch,
                                                     max_len, dtype)
                         for j, kind in enumerate(tail)}
    return cache


def _decode_block(blk, name: str, x_t, cfg: ArchConfig, cache, pos):
    """x_t (B, D); cache per kind; pos (B,) current length."""
    kind = name.split("_", 1)[1]
    h = rms_norm(x_t, blk["ln1"], cfg.norm_eps)
    if kind in ("attn", "moe", "dense"):
        window = cfg.attn_window or None
        if window:
            S = cache[0].shape[1]
            slot = pos % S                  # ring buffer: cache == window
            valid = jnp.minimum(pos + 1, S)
        else:
            slot = pos
            valid = None
        o, cache = attn_mod.attention(
            blk["attn"], h[:, None], cfg, pos[:, None], window=window,
            kv_cache=cache, cache_len=slot, valid_len=valid)
        o = o[:, 0]
        x_t = x_t + o
        h2 = rms_norm(x_t, blk["ln2"], cfg.norm_eps)
        if kind == "moe":
            o2, _ = moe_mod.moe_ffn(blk["moe"], h2[:, None], cfg, decode=True)
            o2 = o2[:, 0]
        else:
            o2 = swiglu(h2, **blk["mlp"])
        x_t = x_t + o2
    elif kind == "rec":
        o, cache = rglru_mod.rglru_decode_step(blk["rec"], h, cache, cfg)
        x_t = x_t + o
        h2 = rms_norm(x_t, blk["ln2"], cfg.norm_eps)
        x_t = x_t + swiglu(h2, **blk["mlp"])
    elif kind == "ssm":
        o, cache = ssm_mod.ssd_decode_step(blk["ssm"], h, cache, cfg)
        x_t = x_t + o
    return x_t, cache


def decode_step(params: Params, cfg: ArchConfig, token: jnp.ndarray,
                cache, cache_len: jnp.ndarray):
    """One decode step.  token (B,) int32; cache_len (B,) current lengths.

    Returns (logits (B, V), new_cache).
    """
    x = params["embed"][token]
    pat, ns, tail = layer_plan(cfg)

    def body(carry, xs):
        h = carry
        sup, ch = xs
        new_ch = {}
        for j, kind in enumerate(pat):
            nm = f"b{j}_{kind}"
            h, new_ch[nm] = _decode_block(sup[nm], f"b_{kind}", h, cfg,
                                          ch[nm], cache_len)
        return h, new_ch

    if cfg.unroll:
        outs = []
        for i in range(ns):
            sup = jax.tree.map(lambda v: v[i], params["supers"])
            ch = jax.tree.map(lambda v: v[i], cache["supers"])
            x, nch = body(x, (sup, ch))
            outs.append(nch)
        new_supers = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *outs)
    else:
        x, new_supers = jax.lax.scan(body, x,
                                     (params["supers"], cache["supers"]))
    new_cache = {"supers": new_supers}
    if tail:
        new_tail = {}
        for j, kind in enumerate(tail):
            nm = f"b{j}_{kind}"
            x, new_tail[nm] = _decode_block(params["tail"][nm], f"b_{kind}",
                                            x, cfg, cache["tail"][nm],
                                            cache_len)
        new_cache["tail"] = new_tail
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bd,dv->bv", x, head)
    return logits, new_cache


def prefill(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            dtype=jnp.float32):
    """Prefill forward: returns (logits, cache, lengths).

    For attention families the per-layer (k, v) tensors ARE the cache; we
    re-run the projections per layer inside a scan collecting them (cost
    identical to forward — the dry-run lowers this for prefill_32k).
    """
    x = _embed(params, cfg, batch)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    pat, ns, tail = layer_plan(cfg)

    def body(h, sup):
        caches = {}
        for j, kind in enumerate(pat):
            nm = f"b{j}_{kind}"
            blk = sup[nm]
            hn = rms_norm(h, blk["ln1"], cfg.norm_eps)
            if kind in ("attn", "moe", "dense"):
                window = cfg.attn_window or None
                o, kv = attn_mod.attention(blk["attn"], hn, cfg, positions,
                                           window=window)
                caches[nm] = kv
                h = h + o
                h2 = rms_norm(h, blk["ln2"], cfg.norm_eps)
                if kind == "moe":
                    o2, _ = moe_mod.moe_ffn(blk["moe"], h2, cfg)
                else:
                    o2 = swiglu(h2, **blk["mlp"])
                h = h + o2
            elif kind == "rec":
                o = rglru_mod.rglru_forward(blk["rec"], hn, cfg)
                caches[nm] = None
                h = h + o
                h2 = rms_norm(h, blk["ln2"], cfg.norm_eps)
                h = h + swiglu(h2, **blk["mlp"])
            elif kind == "ssm":
                o = ssm_mod.ssd_forward(blk["ssm"], hn, cfg)
                caches[nm] = None
                h = h + o
        return h, caches

    if cfg.unroll:
        kv_list = []
        for i in range(ns):
            sup = jax.tree.map(lambda v: v[i], params["supers"])
            x, kv = body(x, sup)
            kv_list.append(kv)
        kv_stacks = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *kv_list)
    else:
        x, kv_stacks = jax.lax.scan(body, x, params["supers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x[:, -1:], head)
    lengths = jnp.full((B,), S, jnp.int32)
    return logits, kv_stacks, lengths
