"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence: h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t ⊙ x_t), with
a_t = exp(−c·softplus(Λ)·σ(r_t)).  First-order linear ⇒ implemented with
``jax.lax.associative_scan`` (log-depth on TPU, shardable along batch /
width).  The block wraps the RG-LRU with the Griffin recipe: linear in,
depthwise causal conv, gated output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import causal_conv1d, causal_conv1d_step, init_dense

_C = 8.0  # Griffin's fixed scaling constant


def init_rglru(key, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    w = cfg.rnn_width or d
    ks = jax.random.split(key, 5)
    return {
        "w_x": init_dense(ks[0], (d, w), dtype=dtype),
        "w_gate_out": init_dense(ks[1], (d, w), dtype=dtype),
        "w_out": init_dense(ks[2], (w, d), dtype=dtype),
        "conv_w": init_dense(ks[3], (w, cfg.conv_width), scale=0.5, dtype=dtype),
        # per-channel recurrence params
        "lam": jnp.full((w,), 4.0, jnp.float32),   # softplus(4) ≈ 4.02
        "w_in_gate": init_dense(ks[4], (w, w), dtype=dtype),
        "w_rec_gate": init_dense(jax.random.fold_in(key, 7), (w, w), dtype=dtype),
    }


def _gates(params, x):
    i_t = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", x, params["w_in_gate"]))
    r_t = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", x, params["w_rec_gate"]))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r_t.astype(jnp.float32)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return i_t, a, mult


def rglru_forward(params, x: jnp.ndarray, cfg: ArchConfig):
    """x (B, L, D) -> (B, L, D)."""
    from ..distributed import constraints as con

    xb = con.constrain(jnp.einsum("bld,dw->blw", x, params["w_x"]),
                       con.act_bsf)
    xb = causal_conv1d(xb, params["conv_w"])
    i_t, a, mult = _gates(params, xb)
    v = (mult * (i_t * xb).astype(jnp.float32))                   # (B,L,W)

    # associative scan over first-order recurrence h = a*h_prev + v
    def combine(c1, c2):
        a1, v1 = c1
        a2, v2 = c2
        return a1 * a2, v1 * a2 + v2

    a_s, h = jax.lax.associative_scan(combine, (a, v), axis=1)
    del a_s
    gate = jax.nn.gelu(con.constrain(
        jnp.einsum("bld,dw->blw", x, params["w_gate_out"]), con.act_bsf))
    out = (h.astype(x.dtype) * gate)
    out = jnp.einsum("blw,wd->bld", out, params["w_out"])
    return con.constrain(out, con.act_bsd)


def rglru_decode_step(params, x_t: jnp.ndarray, state, cfg: ArchConfig):
    """x_t (B, D); state = (conv_state, h (B, W))."""
    conv_state, h = state
    xb = jnp.einsum("bd,dw->bw", x_t, params["w_x"])
    xb, conv_state = causal_conv1d_step(xb, conv_state, params["conv_w"])
    i_t, a, mult = _gates(params, xb)
    h = a * h + mult * (i_t * xb).astype(jnp.float32)
    gate = jax.nn.gelu(jnp.einsum("bd,dw->bw", x_t, params["w_gate_out"]))
    out = (h.astype(x_t.dtype) * gate)
    return jnp.einsum("bw,wd->bd", out, params["w_out"]), (conv_state, h)


def init_rglru_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    w = cfg.rnn_width or cfg.d_model
    conv = jnp.zeros((batch, cfg.conv_width - 1, w), dtype)
    h = jnp.zeros((batch, w), jnp.float32)
    return (conv, h)
