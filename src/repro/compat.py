"""jax version-dispatch layer — the only place allowed to touch skew APIs.

The container baseline is jax 0.4.37; the code targets the modern (≥ 0.6)
sharding surface.  Every symbol whose name, location, or signature moved
between those lines is re-exported from here with one spelling, so call
sites never version-branch themselves (DESIGN.md §6).  The sweep that
produced this list checked every ``jax.*`` attribute the repo references;
the skew surface is exactly:

    ============================  ==========================  =================
    modern (≥ 0.6)                0.4.x equivalent            exported here as
    ============================  ==========================  =================
    jax.shard_map                 jax.experimental.shard_map  shard_map
      (check_vma=...)               (check_rep=...)             (check=...)
    jax.make_mesh(axis_types=..)  jax.make_mesh (no kwarg)    make_mesh
    jax.sharding.AxisType         (absent; GSPMD == Auto)     AxisType
    jax.set_mesh(mesh) context    ``with mesh:`` legacy ctx   set_mesh
    jax.sharding.                 thread_resources.env.       get_abstract_mesh
      get_abstract_mesh()           physical_mesh
    ============================  ==========================  =================

Dispatch is by capability probe (``hasattr``), not version compare, so
intermediate releases that grew one API but not another still resolve
correctly.  Policy: a new jax API enters the codebase *only* by adding a
row here first; tests/test_compat.py pins the dispatch behaviour on
whichever side of the skew the installed jax falls.
"""
from __future__ import annotations

import contextlib
import re
from typing import Optional, Sequence, Tuple

import jax


def _parse_version(v: str) -> Tuple[int, int, int]:
    """Leading-digit parse so pre-release tags ('0.7.0rc1') don't crash
    package import; dispatch itself never consults the version."""
    out = []
    for part in v.split(".")[:3]:
        m = re.match(r"\d+", part)
        out.append(int(m.group()) if m else 0)
    return tuple(out + [0] * (3 - len(out)))


JAX_VERSION = _parse_version(jax.__version__)

# -- capability probes (exported so tests can assert the dispatch taken) -----
HAS_SHARD_MAP = hasattr(jax, "shard_map")
HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")
HAS_SET_MESH = hasattr(jax, "set_mesh")
HAS_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")


# -- shard_map ---------------------------------------------------------------
# ≥ 0.6 promoted shard_map out of jax.experimental and renamed the
# replication/varying-manual-axes check kwarg check_rep → check_vma.
if HAS_SHARD_MAP:
    def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
else:
    from jax.experimental.shard_map import shard_map as _shard_map_04x

    def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
        return _shard_map_04x(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check)


# -- axis types --------------------------------------------------------------
if HAS_AXIS_TYPES:
    AxisType = jax.sharding.AxisType
else:
    import enum

    class AxisType(enum.Enum):
        """Stand-in mirroring jax.sharding.AxisType's members.

        On 0.4.x there are no typed mesh axes — GSPMD treats every axis
        as what ≥ 0.6 calls Auto — so the values are accepted (and
        dropped) by :func:`make_mesh` purely for signature parity.
        """
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None, axis_types: Optional[Sequence] = None
              ) -> jax.sharding.Mesh:
    """jax.make_mesh with Auto-typed axes on both sides of the skew.

    ≥ 0.6 requires ``axis_types`` to opt the mesh out of explicit-sharding
    mode; 0.4.x's make_mesh rejects the kwarg but behaves as all-Auto
    anyway, so the intent is identical.
    """
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    if HAS_AXIS_TYPES:
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(axis_shapes, axis_names, devices=devices,
                             axis_types=tuple(axis_types))
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


# -- ambient mesh ------------------------------------------------------------
if HAS_SET_MESH:
    set_mesh = jax.set_mesh
else:
    @contextlib.contextmanager
    def set_mesh(mesh: jax.sharding.Mesh):
        """0.4.x: the legacy ``with mesh:`` resource context is the ambient
        mesh — with_sharding_constraint resolves bare PartitionSpecs
        against it during pjit tracing, same as ≥ 0.6's set_mesh scope."""
        with mesh:
            yield mesh


if HAS_ABSTRACT_MESH:
    get_abstract_mesh = jax.sharding.get_abstract_mesh
else:
    def get_abstract_mesh():
        """0.4.x: the thread-local physical mesh set by ``with mesh:``.

        Returns a concrete Mesh rather than ≥ 0.6's AbstractMesh; both
        carry the ``.empty`` / ``.shape`` surface callers rely on
        (distributed/constraints.py), and outside any mesh context the
        returned mesh is empty — matching ≥ 0.6's no-op contract.
        """
        from jax._src import mesh as _mesh_lib
        return _mesh_lib.thread_resources.env.physical_mesh
