"""CLI for repro-lint: ``python -m repro.analysis`` (DESIGN.md §11).

Default run walks the repo (src/, tests/, benchmarks/, examples/,
minus tests/fixtures) with every registered pass and exits 1 on any
error-severity finding; ``--strict`` fails on warnings too (the CI
mode).  Explicit paths bypass the scope patterns — that is how the
fixture tests aim one rule at a known-bad snippet:

    python -m repro.analysis --rules kernel-contract \\
        tests/fixtures/repro_lint/kernel_contract_bad.py
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

from .framework import run_passes
from .passes import ALL_PASSES, PASS_BY_NAME


def main(argv: Optional[List[str]] = None) -> int:
    """Parse arguments, run the selected passes, print the report, and
    return the process exit code."""
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: project-invariant static analysis "
                    "(rule catalogue: DESIGN.md §11)")
    parser.add_argument("paths", nargs="*", type=Path,
                        help="explicit files to lint (bypasses rule "
                             "scoping; default: walk the repo)")
    parser.add_argument("--strict", action="store_true",
                        help="exit 1 on warnings as well as errors "
                             "(the CI mode)")
    parser.add_argument("--json", action="store_true",
                        help="machine-readable JSON report on stdout")
    parser.add_argument("--rules", default=None,
                        help="comma-separated rule names to run "
                             "(default: all)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalogue and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        width = max(len(p.name) for p in ALL_PASSES)
        for p in ALL_PASSES:
            print(f"{p.name:<{width}}  {p.description}")
        return 0

    if args.rules is not None:
        names = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in names if r not in PASS_BY_NAME]
        if unknown:
            print(f"repro-lint: unknown rule(s): {', '.join(unknown)} "
                  f"(see --list-rules)", file=sys.stderr)
            return 2
        passes = [PASS_BY_NAME[r] for r in names]
    else:
        passes = ALL_PASSES

    report = run_passes(passes, paths=args.paths or None)
    print(report.render_json() if args.json else report.render())
    return report.exit_code(strict=args.strict)


if __name__ == "__main__":
    sys.exit(main())
