"""repro-lint pass framework (DESIGN.md §11).

The repo's layers carry invariants that unit tests cannot guard cheaply
— the Pallas kernel dtype/PAD contracts (§9), the compat-only jax
version boundary (§6), the cooperative-deadline chunk loops (§7), the
float64 rank-cost arithmetic (§10).  Each invariant is a small, purely
syntactic property of the source tree, so the natural guard is a static
pass over the AST, run the same way locally and in CI:

    python -m repro.analysis --strict

This module is the machinery every pass shares: ``SourceFile`` (text +
parsed AST + suppression comments), ``Finding`` (one diagnostic),
``LintPass`` (the per-file/aggregate hook pair), ``LintContext`` (the
selected file set), and ``run_passes`` (collect, filter suppressed,
report).  The passes themselves live in ``repro.analysis.passes`` — one
module per rule family, registered in ``passes.ALL_PASSES``.

Suppressions are explicit and greppable: a trailing
``# repro-lint: disable=<rule>[,<rule>...]`` comment silences matching
findings on that line only, and a ``# repro-lint: disable-file=<rule>``
comment anywhere in the file silences the whole file for that rule;
``all`` matches every rule.  Suppressed findings are counted (shown in
the summary line) so a suppression can never hide silently.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import json
import re
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

#: severity levels; ``--strict`` fails on both, the default exit code
#: only on errors.
SEVERITIES = ("error", "warning")

# the subtrees a repo-wide walk visits (mirrors test_compat's old scan)
WALK_SUBDIRS = ("src", "tests", "benchmarks", "examples")
# lint fixtures are deliberately-bad snippets: never walk them
WALK_EXCLUDE = ("tests/fixtures",)

_SUPPRESS_LINE = re.compile(r"#\s*repro-lint:\s*disable=([\w,\-]+)")
_SUPPRESS_FILE = re.compile(r"#\s*repro-lint:\s*disable-file=([\w,\-]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: ``rule`` names the pass (and the suppression
    token), ``path`` is repo-relative, ``line`` is 1-based (0 for
    whole-file findings)."""
    rule: str
    path: str
    line: int
    message: str
    severity: str = "error"

    def render(self) -> str:
        """The human one-liner: ``path:line: [rule] message``."""
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_json(self) -> Dict[str, object]:
        """The JSON-output shape (stable keys, machine-consumable)."""
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message, "severity": self.severity}


class SourceFile:
    """One file under lint: text, lines, lazily parsed AST, and the
    parsed suppression comments.  ``rel`` is the repo-relative posix
    path every scope pattern and finding uses."""

    def __init__(self, path: Path, rel: str):
        self.path = path
        self.rel = rel
        self.text = path.read_text()
        self.lines = self.text.splitlines()
        self._tree: Optional[ast.Module] = None
        self._parse_error: Optional[SyntaxError] = None
        self._line_disables: Dict[int, Set[str]] = {}
        self._file_disables: Set[str] = set()
        for ln, line in enumerate(self.lines, 1):
            m = _SUPPRESS_LINE.search(line)
            if m:
                self._line_disables[ln] = set(m.group(1).split(","))
            m = _SUPPRESS_FILE.search(line)
            if m:
                self._file_disables |= set(m.group(1).split(","))

    @property
    def tree(self) -> Optional[ast.Module]:
        """The parsed module, or None when the file does not parse (the
        runner reports a ``parse-error`` finding instead)."""
        if self._tree is None and self._parse_error is None:
            try:
                self._tree = ast.parse(self.text)
            except SyntaxError as exc:
                self._parse_error = exc
        return self._tree

    @property
    def parse_error(self) -> Optional[SyntaxError]:
        """The SyntaxError raised while parsing, if any."""
        self.tree  # noqa: B018 — force the lazy parse
        return self._parse_error

    def suppressed(self, line: int, rule: str) -> bool:
        """True when a finding of ``rule`` at ``line`` is silenced by a
        line- or file-level ``# repro-lint: disable`` comment."""
        if self._file_disables & {rule, "all"}:
            return True
        return bool(self._line_disables.get(line, set()) & {rule, "all"})


@dataclasses.dataclass
class LintContext:
    """What one lint run sees: the repo root and the selected files.
    ``explicit`` is True when the caller named files on the command
    line — scope patterns are then bypassed, so a fixture snippet can
    be linted as if it lived in the directory its rule guards."""
    root: Path
    files: List[SourceFile]
    explicit: bool = False

    def files_for(self, lint_pass: "LintPass") -> List[SourceFile]:
        """The files this pass examines: everything (explicit mode) or
        the scope-pattern matches."""
        if self.explicit:
            return self.files
        return [sf for sf in self.files if lint_pass.applies_to(sf.rel)]

    def read(self, rel: str) -> Optional[str]:
        """Text of a repo file by relative path, None if absent."""
        p = self.root / rel
        return p.read_text() if p.exists() else None


class LintPass:
    """Base class for one rule family.

    Subclasses set ``name`` (the rule id and suppression token),
    ``description`` (one line for ``--list-rules``) and ``scope``
    (repo-relative fnmatch patterns), then implement ``check`` for
    per-file rules and/or ``check_aggregate`` for rules that need the
    whole file set at once (coverage thresholds, cross-file link
    integrity).  Findings must use the pass's own ``name`` as rule so
    suppression comments resolve.
    """

    name: str = "abstract"
    description: str = ""
    scope: Tuple[str, ...] = ()

    def applies_to(self, rel: str) -> bool:
        """Scope test for one repo-relative path."""
        return any(fnmatch.fnmatch(rel, pat) for pat in self.scope)

    def check(self, sf: SourceFile) -> Iterator[Finding]:
        """Per-file hook; default: no findings."""
        return iter(())

    def check_aggregate(self, ctx: LintContext,
                        files: List[SourceFile]) -> Iterator[Finding]:
        """Whole-file-set hook (``files`` already scope-filtered);
        default: no findings."""
        return iter(())

    def finding(self, sf: SourceFile, node_or_line, message: str,
                severity: str = "error") -> Finding:
        """Build a Finding anchored at an AST node or a line number."""
        line = getattr(node_or_line, "lineno", node_or_line)
        return Finding(rule=self.name, path=sf.rel, line=int(line),
                       message=message, severity=severity)


def repo_root(start: Optional[Path] = None) -> Path:
    """The repository root: the nearest ancestor holding ``src/repro``
    (works from any cwd inside the tree; falls back to this package's
    own grandparent layout)."""
    here = (start or Path(__file__)).resolve()
    for cand in (here, *here.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    raise RuntimeError("cannot locate repo root (no src/repro ancestor)")


def walk_repo(root: Path) -> List[SourceFile]:
    """The default file set: every ``*.py`` under the walked subtrees,
    minus the excluded fixture directories, sorted by relative path."""
    out: List[SourceFile] = []
    for sub in WALK_SUBDIRS:
        base = root / sub
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            if any(rel.startswith(excl + "/") or rel == excl
                   for excl in WALK_EXCLUDE):
                continue
            out.append(SourceFile(path, rel))
    return out


@dataclasses.dataclass
class LintReport:
    """One run's outcome: surviving findings, the suppressed count, and
    the file count examined."""
    findings: List[Finding]
    suppressed: int
    files: int

    @property
    def errors(self) -> List[Finding]:
        """The error-severity subset (the default-mode exit gate)."""
        return [f for f in self.findings if f.severity == "error"]

    def exit_code(self, strict: bool = False) -> int:
        """0 when clean; 1 on any error, or on any finding at all under
        ``--strict``."""
        gate = self.findings if strict else self.errors
        return 1 if gate else 0

    def render(self) -> str:
        """Human output: one line per finding plus the summary."""
        lines = [f.render() for f in self.findings]
        lines.append(f"repro-lint: {len(self.findings)} finding(s) "
                     f"({self.suppressed} suppressed) "
                     f"across {self.files} file(s)")
        return "\n".join(lines)

    def render_json(self) -> str:
        """Machine output: findings + counters as one JSON object."""
        return json.dumps({
            "findings": [f.to_json() for f in self.findings],
            "suppressed": self.suppressed, "files": self.files}, indent=2)


def run_passes(passes: Sequence[LintPass], root: Optional[Path] = None,
               paths: Optional[Sequence[Path]] = None) -> LintReport:
    """Run ``passes`` over the repo walk (or over ``paths``, bypassing
    scope patterns) and fold the results into a LintReport.

    Suppression comments are applied here — passes yield every finding
    they see and never read the comments themselves — so the counting
    (and the policy) lives in exactly one place.
    """
    root = root or repo_root()
    if paths is not None:
        files = [SourceFile(Path(p), Path(p).resolve().relative_to(
            root).as_posix() if Path(p).resolve().is_relative_to(root)
            else Path(p).name) for p in paths]
        ctx = LintContext(root=root, files=files, explicit=True)
    else:
        ctx = LintContext(root=root, files=walk_repo(root))

    findings: List[Finding] = []
    suppressed = 0
    by_rel = {sf.rel: sf for sf in ctx.files}
    for sf in ctx.files:
        if sf.parse_error is not None:
            findings.append(Finding(
                rule="parse-error", path=sf.rel,
                line=sf.parse_error.lineno or 0,
                message=f"file does not parse: {sf.parse_error.msg}"))
    for lint_pass in passes:
        selected = ctx.files_for(lint_pass)
        raw: List[Finding] = []
        for sf in selected:
            if sf.parse_error is None:
                raw.extend(lint_pass.check(sf))
        raw.extend(lint_pass.check_aggregate(ctx, selected))
        for f in raw:
            sf = by_rel.get(f.path)
            if sf is not None and sf.suppressed(f.line, f.rule):
                suppressed += 1
            else:
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return LintReport(findings=findings, suppressed=suppressed,
                      files=len(ctx.files))
