"""compat-boundary: jax version-skew symbols only in compat.py (DESIGN.md §6, §11).

The repo supports both sides of the jax 0.4.x ↔ latest API skew through
exactly one dispatch layer, ``src/repro/compat.py``.  Any call site that
spells a skew API directly — modern-only (``jax.shard_map``,
``jax.sharding.AxisType``, …) or 0.4.x-only (``jax.experimental
.shard_map``, the ``check_rep``/``check_vma`` kwargs) — silently breaks
one CI matrix leg.  This pass is the mechanical half of the §6 policy,
migrated from the ad-hoc scan that used to live in
``tests/test_compat.py`` (the test is now a thin wrapper over this
pass).

``compat.py`` itself is exempt (it *is* the boundary), as is
``tests/test_compat.py`` (it pins the dispatch by asserting against
both spellings).
"""
from __future__ import annotations

import re
from typing import Iterator

from ..framework import Finding, LintPass, SourceFile

# This module necessarily spells the forbidden symbols (docstring and
# pattern source), so it suppresses itself — the mechanism the rest of
# the repo uses for intentional one-off exemptions.
# repro-lint: disable-file=compat-boundary

SKEW_PATTERN = re.compile(
    # modern-only spellings
    r"jax\.set_mesh|jax\.shard_map|jax\.make_mesh"
    r"|jax\.sharding\.AxisType|jax\.sharding\.get_abstract_mesh"
    r"|jax\.sharding\.use_mesh"
    # 0.4.x-only spellings
    r"|jax\.experimental\.shard_map"
    r"|check_vma|check_rep")

# the boundary itself and the test that pins both of its sides
EXEMPT_BASENAMES = ("compat.py", "test_compat.py")


class CompatBoundaryPass(LintPass):
    """Line scan for skew jax APIs outside the compat layer."""

    name = "compat-boundary"
    description = ("jax version-skew symbols (shard_map/make_mesh/"
                   "AxisType/check_rep/...) appear only in "
                   "src/repro/compat.py (DESIGN.md §6)")
    scope = ("src/*.py", "src/**/*.py", "tests/*.py", "tests/**/*.py",
             "benchmarks/*.py", "benchmarks/**/*.py",
             "examples/*.py", "examples/**/*.py")

    def applies_to(self, rel: str) -> bool:
        if rel.rsplit("/", 1)[-1] in EXEMPT_BASENAMES:
            return False
        return super().applies_to(rel)

    def check(self, sf: SourceFile) -> Iterator[Finding]:
        for ln, line in enumerate(sf.lines, 1):
            m = SKEW_PATTERN.search(line)
            if m:
                yield self.finding(sf, ln, (
                    f"skew jax API {m.group(0)!r} outside repro/compat.py "
                    f"— route it through the compat layer (DESIGN.md §6)"))


PASSES = [CompatBoundaryPass()]
