"""rank-cost-dtype: rank-cost arithmetic stays float64 (DESIGN.md §10, §11).

Ranked enumeration's cross-backend bit-for-bit guarantee — every engine
(heap, buckets, join) and the oracle emit the *same* ordered sequence —
rests on one numeric convention: path costs accumulate left-to-right in
float64, everywhere.  A single ``float32`` cast in the cost path breaks
tie resolution a few ulps at a time: the ordered-sequence fuzz suite
catches it eventually, but only on inputs whose costs happen to collide,
and the failure reads as a mysterious swap deep in a 200-seed sweep.

The rule, over ``core/rank.py`` and ``core/join.py`` (the two modules
that own cost arithmetic): no 32/16-bit float dtype may be spelled at
all — neither as an attribute (``np.float32``, ``jnp.float16``) nor as
a string dtype (``astype("float32")``).  Integer dtypes are untouched
(path matrices are int32 by the §9 kernel contract).
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..framework import Finding, LintPass, SourceFile

_NARROW_FLOATS = frozenset({"float32", "float16", "bfloat16"})


class RankCostDtypePass(LintPass):
    """AST scan for narrow float dtypes in the rank-cost modules."""

    name = "rank-cost-dtype"
    description = ("no float32/float16 spelled in core/rank.py or "
                   "core/join.py — rank costs accumulate in float64 "
                   "(DESIGN.md §10)")
    scope = ("src/repro/core/rank.py", "src/repro/core/join.py")

    def check(self, sf: SourceFile) -> Iterator[Finding]:
        tree = sf.tree
        assert tree is not None
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute) \
                    and node.attr in _NARROW_FLOATS:
                yield self.finding(sf, node, (
                    f"{node.attr} in a rank-cost module — cost "
                    f"accumulation is float64 end to end; a narrow cast "
                    f"breaks cross-backend tie resolution (DESIGN.md §10)"))
            elif isinstance(node, ast.Constant) \
                    and isinstance(node.value, str) \
                    and node.value in _NARROW_FLOATS:
                yield self.finding(sf, node, (
                    f"string dtype {node.value!r} in a rank-cost module — "
                    f"cost accumulation is float64 end to end "
                    f"(DESIGN.md §10)"))


PASSES = [RankCostDtypePass()]
