"""docstring-coverage + doc-links: the documentation gates (DESIGN.md §11).

Migrated from the ad-hoc AST scans that used to live in
``tests/test_docs.py`` (the tests are now thin wrappers over these
passes).  Two rule families:

  * **docstring-coverage** — the public surface of the audited modules
    (``serving/*.py`` + ``core/batch.py``) is fully documented: module
    docstring, public classes, public functions/methods (nested defs
    excluded, mirroring ``interrogate``).  Coverage was measured at
    100% when the gate migrated here, so the threshold is *every slot*:
    each missing docstring is its own finding.  Each audited module's
    docstring must also carry its ``DESIGN.md §N`` anchor, so every
    public module is reachable from the design doc.
  * **doc-links** — every ``DESIGN.md §N`` anchor spelled in the top
    docs or a source/test/example file names a section that exists, and
    every relative markdown link in README/DESIGN/EXPERIMENTS points at
    a real file.
"""
from __future__ import annotations

import ast
import re
from typing import Iterator, List, Tuple

from ..framework import Finding, LintContext, LintPass, SourceFile

#: the audited set: the serving surface + the batch engine it fronts
AUDITED_SCOPE = (
    "src/repro/serving/*.py",
    "src/repro/core/batch.py",
    "src/repro/core/sharing.py",
)

_ANCHOR = re.compile(r"DESIGN\.md §(\d+)(?:-(\d+))?")
_MD_LINK = re.compile(r"\]\(([^)]+)\)")
_SECTION = re.compile(r"^## §(\d+)", re.MULTILINE)

#: the top-level docs whose relative links must resolve
TOP_DOCS = ("README.md", "DESIGN.md", "EXPERIMENTS.md")


def public_docstring_slots(
        tree: ast.Module) -> Iterator[Tuple[str, int, bool]]:
    """Yield (qualname, line, has_docstring) for the module, public
    classes and public functions/methods — nested defs excluded, like
    ``interrogate``.  Shared with tests/test_docs.py."""
    yield "<module>", 1, ast.get_docstring(tree) is not None
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and not node.name.startswith("_"):
            yield node.name, node.lineno, ast.get_docstring(node) is not None
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and not sub.name.startswith("_"):
                    yield (f"{node.name}.{sub.name}", sub.lineno,
                           ast.get_docstring(sub) is not None)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and not node.name.startswith("_"):
            yield node.name, node.lineno, ast.get_docstring(node) is not None


class DocstringCoveragePass(LintPass):
    """Full public-surface docstring coverage on the audited modules,
    plus the per-module DESIGN.md anchor."""

    name = "docstring-coverage"
    description = ("every public slot in serving/*.py and core/batch.py "
                   "carries a docstring, and each module docstring "
                   "anchors into DESIGN.md §N")
    scope = AUDITED_SCOPE

    def check(self, sf: SourceFile) -> Iterator[Finding]:
        tree = sf.tree
        assert tree is not None
        for qualname, line, has_doc in public_docstring_slots(tree):
            if not has_doc:
                yield self.finding(sf, line, (
                    f"public slot {qualname} has no docstring — the "
                    f"audited surface is documented in full"))
        doc = ast.get_docstring(tree) or ""
        if doc and not _ANCHOR.search(doc):
            yield self.finding(sf, 1, (
                "module docstring lacks a 'DESIGN.md §N' anchor — every "
                "audited module is reachable from the design doc"))


class DocLinksPass(LintPass):
    """Cross-file link integrity: §N anchors resolve, relative links in
    the top docs point at real files."""

    name = "doc-links"
    description = ("DESIGN.md §N references resolve to real sections; "
                   "relative markdown links in README/DESIGN/EXPERIMENTS "
                   "resolve to real files")
    # anchors may be spelled anywhere the repo walk visits
    scope = ("src/*.py", "tests/*.py", "benchmarks/*.py", "examples/*.py")

    def check_aggregate(self, ctx: LintContext,
                        files: List[SourceFile]) -> Iterator[Finding]:
        design = ctx.read("DESIGN.md") or ""
        sections = {int(m) for m in _SECTION.findall(design)}
        if not sections:
            yield Finding(rule=self.name, path="DESIGN.md", line=0,
                          message="DESIGN.md defines no '## §N' sections")
            return
        # §N anchors in the walked source files
        for sf in files:
            for ln, line in enumerate(sf.lines, 1):
                for m in _ANCHOR.finditer(line):
                    lo = int(m.group(1))
                    hi = int(m.group(2)) if m.group(2) else lo
                    for n in range(lo, hi + 1):
                        if n not in sections:
                            yield self.finding(sf, ln, (
                                f"dangling reference DESIGN.md §{n} — "
                                f"no such section"))
        # §N anchors and relative links in the top-level docs
        for name in TOP_DOCS:
            text = ctx.read(name)
            if text is None:
                continue
            for ln, line in enumerate(text.splitlines(), 1):
                for m in _ANCHOR.finditer(line):
                    lo = int(m.group(1))
                    hi = int(m.group(2)) if m.group(2) else lo
                    for n in range(lo, hi + 1):
                        if n not in sections:
                            yield Finding(
                                rule=self.name, path=name, line=ln,
                                message=(f"dangling reference DESIGN.md "
                                         f"§{n} — no such section"))
                for m in _MD_LINK.finditer(line):
                    target = m.group(1).split("#")[0].strip()
                    if not target or target.startswith(
                            ("http://", "https://", "mailto:")):
                        continue
                    if not (ctx.root / target).exists():
                        yield Finding(
                            rule=self.name, path=name, line=ln,
                            message=(f"broken relative link "
                                     f"({m.group(1)}) — target does not "
                                     f"exist"))


PASSES = [DocstringCoveragePass(), DocLinksPass()]
