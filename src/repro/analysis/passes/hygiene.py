"""Hygiene passes: unused-import, mutable-default, bare-except (DESIGN.md §11).

General-purpose cleanliness rules over the library source.  These are
the rules a stock linter would also give us; they ship here so the repo
needs exactly one lint entry point (``python -m repro.analysis``) and so
their scoping matches the project layout (``__init__.py`` re-export
modules are exempt from unused-import, string-quoted annotations count
as uses).

  * **unused-import** — an imported name never referenced by the module.
    A name counts as used when it appears as a ``Name`` node *or* as an
    identifier inside any string constant — the latter covers quoted
    annotations (``"collections.OrderedDict[QueryKey, ...]"``) and
    ``__all__`` entries.  ``from __future__`` imports and ``__init__.py``
    files (re-export surfaces) are exempt.
  * **mutable-default** — a ``list``/``dict``/``set`` literal (or
    constructor call) as a parameter default: shared across calls,
    a classic aliasing bug.
  * **bare-except** — ``except:`` with no exception class swallows
    ``KeyboardInterrupt``/``SystemExit``; name the exceptions (or
    ``BaseException`` when the breadth is deliberate).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, Set, Tuple

from ..framework import Finding, LintPass, SourceFile

_IDENT = re.compile(r"[A-Za-z_][A-Za-z0-9_]*")

_HYGIENE_SCOPE = ("src/repro/*.py",)


def _used_names(tree: ast.Module) -> Set[str]:
    """Every identifier the module references: Name nodes plus the
    identifiers inside string constants (quoted annotations, __all__)."""
    used: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            used.update(_IDENT.findall(node.value))
    return used


class UnusedImportPass(LintPass):
    """Imports never referenced in the module body."""

    name = "unused-import"
    description = ("imported names are referenced (Name nodes or quoted "
                   "annotations); __init__.py re-export modules exempt")
    scope = _HYGIENE_SCOPE

    def applies_to(self, rel: str) -> bool:
        if rel.rsplit("/", 1)[-1] == "__init__.py":
            return False
        return super().applies_to(rel)

    def check(self, sf: SourceFile) -> Iterator[Finding]:
        tree = sf.tree
        assert tree is not None
        imported: Dict[str, Tuple[int, str]] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    bound = alias.asname or alias.name.split(".")[0]
                    imported[bound] = (node.lineno, alias.name)
            elif isinstance(node, ast.ImportFrom):
                if node.module == "__future__":
                    continue
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    bound = alias.asname or alias.name
                    imported[bound] = (node.lineno, alias.name)
        used = _used_names(tree)
        for bound, (lineno, target) in sorted(imported.items(),
                                              key=lambda kv: kv[1][0]):
            if bound not in used:
                yield self.finding(sf, lineno, (
                    f"'{bound}' imported but never used"))


class MutableDefaultPass(LintPass):
    """list/dict/set literals (or constructors) as parameter defaults."""

    name = "mutable-default"
    description = ("no mutable default arguments (list/dict/set literal "
                   "or constructor) — defaults are shared across calls")
    scope = _HYGIENE_SCOPE

    def check(self, sf: SourceFile) -> Iterator[Finding]:
        tree = sf.tree
        assert tree is not None
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            defaults = list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None]
            for dflt in defaults:
                if self._is_mutable(dflt):
                    yield self.finding(sf, dflt, (
                        f"mutable default argument in {node.name} — one "
                        f"shared object across every call; default to "
                        f"None and construct inside"))

    @staticmethod
    def _is_mutable(node: ast.AST) -> bool:
        if isinstance(node, (ast.List, ast.Dict, ast.Set)):
            return True
        return (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id in ("list", "dict", "set"))


class BareExceptPass(LintPass):
    """``except:`` clauses with no exception class."""

    name = "bare-except"
    description = ("no bare 'except:' — it swallows KeyboardInterrupt/"
                   "SystemExit; name the exceptions")
    scope = _HYGIENE_SCOPE

    def check(self, sf: SourceFile) -> Iterator[Finding]:
        tree = sf.tree
        assert tree is not None
        for node in ast.walk(tree):
            if isinstance(node, ast.ExceptHandler) and node.type is None:
                yield self.finding(sf, node, (
                    "bare 'except:' swallows KeyboardInterrupt/SystemExit "
                    "— name the exceptions (BaseException if the breadth "
                    "is deliberate)"))


PASSES = [UnusedImportPass(), MutableDefaultPass(), BareExceptPass()]
