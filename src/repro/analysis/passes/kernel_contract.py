"""kernel-contract: the Pallas kernel layout/dtype contracts (DESIGN.md §9, §11).

Every kernel under ``src/repro/kernels/`` rides one set of conventions
the host/device parity harness depends on:

  * every ``pallas_call`` site declares an explicit ``grid=`` and plumbs
    an ``interpret=`` switch, so the CPU validation container can run the
    same call through the Pallas interpreter (the kernels' CI leg pins
    ``JAX_PLATFORMS=cpu`` and relies on it);
  * the ``PAD`` sentinel is shared: a kernels module that re-declares
    ``PAD`` must pin it to −1 (``core.graph.PAD`` — inert-row semantics
    break bit-for-bit parity if the sentinels diverge);
  * path/index matrices are int32 end to end — wider or unsigned integer
    dtypes (``int64``/``uint32``/…) in kernel code silently double VMEM
    footprints or break the offset gathers on TPU;
  * every public wrapper in ``ops.py`` that dispatches to a Pallas entry
    (``*_pallas``) must also register the pure-jnp oracle path from
    ``ref.py`` (the ``REPRO_PALLAS=off`` A/B fallback) and forward the
    ``interpret=`` switch to the kernel.
"""
from __future__ import annotations

import ast
from typing import Iterator, List

from ..framework import Finding, LintPass, SourceFile

# the shared sentinel, pinned by core.graph.PAD and
# tests/test_frontier_kernel.py
PAD_VALUE = -1

_BAD_INT_DTYPES = frozenset({
    "int64", "int16", "int8", "uint8", "uint16", "uint32", "uint64"})


def _is_pallas_call(node: ast.Call) -> bool:
    """True for ``pl.pallas_call(...)`` / ``pallas_call(...)`` sites."""
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr == "pallas_call"
    return isinstance(fn, ast.Name) and fn.id == "pallas_call"


def _kwarg_names(node: ast.Call) -> List[str]:
    return [kw.arg for kw in node.keywords if kw.arg is not None]


def _calls_pallas_entry(node: ast.Call) -> bool:
    """True for calls to a ``*_pallas`` alias (the ops.py convention for
    imported kernel entry points)."""
    fn = node.func
    name = fn.id if isinstance(fn, ast.Name) else (
        fn.attr if isinstance(fn, ast.Attribute) else "")
    return name.endswith("_pallas")


class KernelContractPass(LintPass):
    """AST checks for the §9 kernel conventions over ``kernels/*.py``."""

    name = "kernel-contract"
    description = ("pallas_call sites declare grid=/interpret=, PAD stays "
                   "-1, integer matrices stay int32, and ops.py wrappers "
                   "register a ref.py oracle fallback (DESIGN.md §9)")
    scope = ("src/repro/kernels/*.py",)

    def check(self, sf: SourceFile) -> Iterator[Finding]:
        tree = sf.tree
        assert tree is not None
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and _is_pallas_call(node):
                kwargs = _kwarg_names(node)
                if "interpret" not in kwargs:
                    yield self.finding(sf, node, (
                        "pallas_call without an interpret= switch — the "
                        "CPU validation path (Pallas interpreter) must "
                        "stay reachable"))
                if "grid" not in kwargs:
                    yield self.finding(sf, node, (
                        "pallas_call without an explicit grid= — implicit "
                        "grids hide the block layout the parity harness "
                        "pins"))
            if isinstance(node, ast.Attribute) \
                    and node.attr in _BAD_INT_DTYPES:
                yield self.finding(sf, node, (
                    f"integer dtype {node.attr} in kernel code — path and "
                    f"index matrices are int32 by contract (DESIGN.md §9)"))
        # module-level PAD re-declarations must agree with core.graph.PAD
        for node in tree.body:
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and tgt.id == "PAD":
                        yield from self._check_pad(sf, node)
        if sf.rel.rsplit("/", 1)[-1] == "ops.py":
            yield from self._check_ops_registration(sf, tree)

    def _check_pad(self, sf: SourceFile,
                   node: ast.Assign) -> Iterator[Finding]:
        value = node.value
        ok = (isinstance(value, ast.UnaryOp)
              and isinstance(value.op, ast.USub)
              and isinstance(value.operand, ast.Constant)
              and value.operand.value == -PAD_VALUE)
        ok = ok or (isinstance(value, ast.Constant)
                    and value.value == PAD_VALUE)
        if not ok:
            yield self.finding(sf, node, (
                f"PAD re-declared with a value other than {PAD_VALUE} — "
                f"the sentinel is shared with core.graph.PAD; divergence "
                f"breaks PAD-row inertness and host/device parity"))

    def _check_ops_registration(self, sf: SourceFile,
                                tree: ast.Module) -> Iterator[Finding]:
        """Every ops.py function calling a ``*_pallas`` entry must also
        reference the ``ref`` oracle module and forward ``interpret=``."""
        for fn in tree.body:
            if not isinstance(fn, ast.FunctionDef):
                continue
            pallas_calls = [n for n in ast.walk(fn)
                            if isinstance(n, ast.Call)
                            and _calls_pallas_entry(n)]
            if not pallas_calls:
                continue
            uses_ref = any(
                isinstance(n, ast.Attribute) and isinstance(n.value, ast.Name)
                and n.value.id == "ref" for n in ast.walk(fn))
            if not uses_ref:
                yield self.finding(sf, fn, (
                    f"{fn.name} dispatches to a Pallas kernel but never "
                    f"references the ref.py oracle — the REPRO_PALLAS=off "
                    f"fallback path is unregistered"))
            for call in pallas_calls:
                if "interpret" not in _kwarg_names(call):
                    yield self.finding(sf, call, (
                        f"{fn.name} calls a Pallas entry without "
                        f"forwarding interpret= — the CPU container "
                        f"would try to compile Mosaic"))


PASSES = [KernelContractPass()]
