"""async-safety: no blocking calls inside ``async def`` (DESIGN.md §7, §11).

The async front-end's whole contract is that the event loop keeps
admitting (and rejecting) requests while enumeration runs in worker
threads.  One blocking call inside an ``async def`` body — a
``time.sleep``, a direct ``engine.run(...)``, a jax
``.block_until_ready()`` — stalls every pending future at once, and no
unit test reliably catches it (the tests still pass, just N times
slower and with the admission-control behavior silently gone).

Flagged inside ``async def`` bodies under ``serving/``:

  * ``time.sleep(...)`` — use ``asyncio.sleep``;
  * direct calls to ``<...>engine.run(...)`` — dispatch through
    ``asyncio.to_thread(self.engine.run, ...)`` (passing the bound
    method *as an argument* is fine and is exactly the sanctioned
    pattern);
  * ``.block_until_ready()`` — device sync belongs in the worker
    thread, never on the loop.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..framework import Finding, LintPass, SourceFile


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression (``self.engine`` ->
    'self.engine'); empty for non-name shapes."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


class AsyncSafetyPass(LintPass):
    """AST walk over async function bodies in the serving layer."""

    name = "async-safety"
    description = ("no blocking calls (time.sleep, direct engine.run, "
                   ".block_until_ready) inside async def bodies in "
                   "serving/ (DESIGN.md §7)")
    scope = ("src/repro/serving/*.py",)

    def check(self, sf: SourceFile) -> Iterator[Finding]:
        tree = sf.tree
        assert tree is not None
        for node in ast.walk(tree):
            if isinstance(node, ast.AsyncFunctionDef):
                yield from self._check_async_body(sf, node)

    def _check_async_body(self, sf: SourceFile,
                          fn: ast.AsyncFunctionDef) -> Iterator[Finding]:
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            callee = node.func
            if not isinstance(callee, ast.Attribute):
                continue
            owner = _dotted(callee.value)
            if callee.attr == "sleep" and owner == "time":
                yield self.finding(sf, node, (
                    f"time.sleep inside async def {fn.name} blocks the "
                    f"event loop — use asyncio.sleep"))
            elif callee.attr == "block_until_ready":
                yield self.finding(sf, node, (
                    f".block_until_ready() inside async def {fn.name} "
                    f"stalls the loop on device sync — move it into the "
                    f"worker thread"))
            elif callee.attr == "run" and "engine" in owner.split("."):
                yield self.finding(sf, node, (
                    f"direct {owner}.run(...) inside async def {fn.name} "
                    f"runs enumeration on the event loop — dispatch via "
                    f"asyncio.to_thread({owner}.run, ...)"))


PASSES = [AsyncSafetyPass()]
