"""deadline-hook: emitting loops consult the cooperative deadline (DESIGN.md §7, §11).

The serving stack's anytime contract rests on one convention in the
enumeration core: every loop that emits results or processes chunks in
a function taking a ``deadline`` parameter must consult that deadline,
so an in-flight batch stops at the next chunk/key-group boundary after
its budget expires.  The convention is easy to break silently — a new
driver loop that forgets the check still returns correct results, it
just stops honoring SLOs, and only a timing-sensitive test could
notice.

The rule, over ``core/enumerate.py`` and ``core/join.py``: in any
function with a ``deadline`` parameter, every *outermost* loop whose
body touches the enumeration counters (``stats.chunks`` /
``stats.results`` / ``stats.pairs``) must, somewhere in its body,
either reference ``deadline`` directly or call a ``_expired()`` helper
(the join module's local idiom, itself closed over ``deadline``).
Inner loops ride on their enclosing loop's check — the deadline is a
chunk-granularity budget, not a per-row one (DESIGN.md §7).
"""
from __future__ import annotations

import ast
from typing import Iterator, List

from ..framework import Finding, LintPass, SourceFile

_LOOP = (ast.For, ast.While, ast.AsyncFor)
_FUNC = (ast.FunctionDef, ast.AsyncFunctionDef)
_COUNTERS = frozenset({"chunks", "results", "pairs"})


def _outermost_loops(fn: ast.AST) -> List[ast.AST]:
    """The loops of ``fn`` not nested inside another loop (nested
    function bodies are separate scopes and are skipped)."""
    loops: List[ast.AST] = []

    def visit(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, _LOOP):
                loops.append(child)
            elif isinstance(child, _FUNC):
                continue
            else:
                visit(child)

    visit(fn)
    return loops


def _touches_counters(loop: ast.AST) -> bool:
    """True when the loop body reads/writes an EnumStats counter on a
    ``*stats`` object — the signature of an emitting/chunking loop."""
    for node in ast.walk(loop):
        if isinstance(node, ast.Attribute) and node.attr in _COUNTERS \
                and isinstance(node.value, ast.Name) \
                and node.value.id.endswith("stats"):
            return True
    return False


def _consults_deadline(loop: ast.AST) -> bool:
    """True when the loop body references ``deadline`` or calls the
    ``_expired`` helper idiom."""
    for node in ast.walk(loop):
        if isinstance(node, ast.Name) and node.id == "deadline":
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "_expired":
            return True
    return False


class DeadlineHookPass(LintPass):
    """AST check over the enumeration drivers' loop structure."""

    name = "deadline-hook"
    description = ("outermost emitting loops in core/enumerate.py and "
                   "core/join.py consult the cooperative deadline hook "
                   "(DESIGN.md §7)")
    scope = ("src/repro/core/enumerate.py", "src/repro/core/join.py")

    def check(self, sf: SourceFile) -> Iterator[Finding]:
        tree = sf.tree
        assert tree is not None
        for node in ast.walk(tree):
            if not isinstance(node, _FUNC):
                continue
            args = node.args
            names = [a.arg for a in (args.posonlyargs + args.args
                                     + args.kwonlyargs)]
            if "deadline" not in names:
                continue
            for loop in _outermost_loops(node):
                if _touches_counters(loop) and not _consults_deadline(loop):
                    yield self.finding(sf, loop, (
                        f"emitting loop in {node.name} never consults the "
                        f"deadline hook — a deadline-carrying batch would "
                        f"run to completion past its budget (DESIGN.md §7)"))


PASSES = [DeadlineHookPass()]
