"""The repro-lint rule registry (DESIGN.md §11).

One module per rule family; each exports a ``PASSES`` list, folded here
into ``ALL_PASSES`` — the set ``python -m repro.analysis`` runs by
default.  To add a rule: write the pass module, append its ``PASSES``
here, pair it with good/bad fixtures under ``tests/fixtures/repro_lint/``
and a catalogue row in DESIGN.md §11.
"""
from __future__ import annotations

from typing import Dict, List

from ..framework import LintPass
from . import (async_safety, compat_boundary, deadline_hook, docs,
               hygiene, kernel_contract, rank_dtype)

ALL_PASSES: List[LintPass] = [
    *kernel_contract.PASSES,
    *compat_boundary.PASSES,
    *async_safety.PASSES,
    *deadline_hook.PASSES,
    *rank_dtype.PASSES,
    *docs.PASSES,
    *hygiene.PASSES,
]

PASS_BY_NAME: Dict[str, LintPass] = {p.name: p for p in ALL_PASSES}

__all__ = ["ALL_PASSES", "PASS_BY_NAME"]
