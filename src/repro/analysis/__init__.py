"""repro-lint: project-invariant static analysis (DESIGN.md §11).

The repo's cross-layer conventions — the Pallas kernel contracts (§9),
the compat-only jax boundary (§6), the cooperative-deadline loops (§7),
float64 rank costs (§10), the documented serving surface — are purely
syntactic properties of the source tree, so they are guarded by AST
passes rather than by tests that can only sample them.  One entry
point, identical locally and in CI:

    python -m repro.analysis --strict

Programmatic surface: ``lint_repo()`` runs the full registry over the
repo walk and returns a ``LintReport``; ``run_passes`` is the
lower-level hook the tests use to aim individual passes at fixture
files.  Rule catalogue and suppression policy: DESIGN.md §11.
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional, Sequence

from .framework import (Finding, LintContext, LintPass, LintReport,
                        SourceFile, repo_root, run_passes, walk_repo)
from .passes import ALL_PASSES, PASS_BY_NAME

__all__ = [
    "ALL_PASSES", "PASS_BY_NAME", "Finding", "LintContext", "LintPass",
    "LintReport", "SourceFile", "lint_repo", "repo_root", "run_passes",
    "walk_repo",
]


def lint_repo(root: Optional[Path] = None,
              rules: Optional[Sequence[str]] = None) -> LintReport:
    """Run the full registry (or the named ``rules``) over the repo walk
    and return the report.  Raises KeyError on an unknown rule name."""
    passes = ALL_PASSES if rules is None else [
        PASS_BY_NAME[r] for r in rules]
    return run_passes(passes, root=root)
