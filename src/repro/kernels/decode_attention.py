"""Single-token GQA decode attention over a (possibly long) KV cache.

The decode_32k / long_500k shapes are memory-bound: one query row must
stream S·Hkv·D·2 cache bytes.  The kernel tiles the cache sequence in
BS=512 blocks, keeps the online-softmax state in VMEM scratch, and — the
GQA trick that matters at kv=1..8 — processes *all* heads of one KV group
against each streamed KV tile, so cache bytes are read once per group
rather than once per head (arithmetic intensity × group).

Grid = (B, Hkv, S/BS): per (batch, kv-head) the cache tiles stream in
order; the query block is the (group, D) slice of that head group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale, bs, group):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]
    base = si * bs

    @pl.when(base < length)
    def _step():
        q = q_ref[...].reshape(group, -1).astype(jnp.float32)   # (G, D)
        k = k_ref[...].reshape(bs, -1).astype(jnp.float32)      # (BS, D)
        v = v_ref[...].reshape(bs, -1).astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale          # (G, BS)
        valid = (base + jax.lax.broadcasted_iota(jnp.int32, (group, bs), 1)
                 ) < length
        logits = jnp.where(valid, logits, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=1))
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l[:, None]).reshape(o_ref.shape).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bs", "interpret"))
def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, lengths: jnp.ndarray, *,
                     scale: float | None = None, bs: int = 512,
                     interpret: bool = False) -> jnp.ndarray:
    """q (B, H, D); k_cache/v_cache (B, S, Hkv, D); lengths (B,) int32."""
    B, H, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    assert H % Hkv == 0
    group = H // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    assert S % bs == 0, "ops.py pads the cache to a bs multiple"
    grid = (B, Hkv, S // bs)
    # view q as (B, Hkv, group, D) so one block = one KV group's queries
    qg = q.reshape(B, Hkv, group, D)
    kernel = functools.partial(_decode_kernel, scale=scale, bs=bs, group=group)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, si: (b,)),
            pl.BlockSpec((1, 1, group, D), lambda b, h, si: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, si: (b, si, h, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, si: (b, si, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, D), lambda b, h, si: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache).reshape(B, H, D)
