"""Blocked online-softmax (flash) attention, GQA-aware — LM hot spot.

Tiling: Q rows in BQ=128 blocks, KV in BK=128 blocks (VMEM working set
per step: BQ·D + 2·BK·D + BQ·BK floats — well under the 16 MiB v5e VMEM
for D ≤ 256).  Grid = (B, H, Lq/BQ, Lk/BK); the kv dimension is the
innermost ("arbitrary") axis so the f32 scratch accumulators (running max
m, denominator l, weighted acc) persist across it.  GQA is handled in the
K/V index_map (kv head = h // group) so no repeated-KV materialization
ever happens — the kernel reads each KV tile once per query-head group.

Causal + sliding-window masking is applied from global indices; fully
masked KV tiles are skipped by an early `pl.when` guard (this is the
block-sparsity that makes window attention (RecurrentGemma) linear-cost).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, window, bq, bk, lk_offset):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # global row/col positions (rows are offset when Lq < Lk: decode windows)
    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + lk_offset
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    if causal:
        mask = rows >= cols
        if window is not None:
            mask &= (rows - cols) < window
    else:
        mask = jnp.ones((bq, bk), dtype=jnp.bool_)

    def _step():
        q = q_ref[...].reshape(bq, -1).astype(jnp.float32)
        k = k_ref[...].reshape(bk, -1).astype(jnp.float32)
        v = v_ref[...].reshape(bk, -1).astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=1))
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip KV tiles strictly above the diagonal band
        first_row = qi * bq + lk_offset
        last_row = first_row + bq - 1
        first_col = ki * bk
        last_col = first_col + bk - 1
        visible = first_col <= last_row
        if window is not None:
            visible &= last_col > first_row - window
        pl.when(visible)(_step)
    else:
        _step()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l[:, None]).reshape(o_ref.shape).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q (B, Lq, H, D); k/v (B, Lk, Hkv, D); returns (B, Lq, H, D).

    Lq % bq == 0 and Lk % bk == 0 (ops.py pads + re-slices).
    """
    B, Lq, H, D = q.shape
    _, Lk, Hkv, _ = k.shape
    assert H % Hkv == 0, "GQA requires H divisible by Hkv"
    group = H // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    assert Lq % bq == 0 and Lk % bk == 0
    grid = (B, H, Lq // bq, Lk // bk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, lk_offset=Lk - Lq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, qi, ki, g=group: (b, ki, h // g, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, qi, ki, g=group: (b, ki, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Lq, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),   # running max m
            pltpu.VMEM((bq,), jnp.float32),   # running denominator l
            pltpu.VMEM((bq, D), jnp.float32), # weighted accumulator
        ],
        interpret=interpret,
    )(q, k, v)
