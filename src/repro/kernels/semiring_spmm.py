"""Semiring SpMM Pallas kernels — the PathEnum device hot spot.

The paper's profile (Fig. 12a) shows index construction, dominated by the
two BFS passes, bounding response time on billion-edge graphs; the
full-fledged estimator adds k more edge sweeps (Alg. 5).  On TPU both are
k applications of a semiring matrix-vector product over the adjacency
matrix (DESIGN.md §2):

  * BFS relaxation  — (min, +):  dist' = min(dist, Aᵀ ⊕ dist)
  * walk-count DP   — (+, ×):    c'    = A ⊗ c          (Eq. 7)

Blocking: 128×128 adjacency tiles streamed HBM→VMEM.  min-plus has no MXU
form (the MXU is a multiply-accumulate array); it runs on the VPU over the
same tiling.  The counting semiring IS an MXU matmul: adjacency tiles are
{0,1} f32/bf16 masks and the DP vector a (n, q) block (q = batched queries),
so walk counting for a whole query batch is one tiled matmul per DP level.

Hardware-alignment contract (asserted): n multiple of BLOCK (wrappers in
ops.py pad), BLOCK multiple of 128 for MXU-native shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128


# ---------------------------------------------------------------------------
# min-plus SpMV:  out[v] = min(dist[v], min_u (adj[u, v] + dist[u]))
# ---------------------------------------------------------------------------

def _minplus_kernel(adj_ref, dist_in_ref, dist_keep_ref, out_ref, *, inf):
    i = pl.program_id(1)  # reduction block index (rows u)
    blk = adj_ref[...] + dist_in_ref[...].reshape(-1, 1)   # (BI, BJ)
    part = jnp.min(blk, axis=0)                            # (BJ,)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.minimum(dist_keep_ref[...], inf)

    out_ref[...] = jnp.minimum(out_ref[...], part)


@functools.partial(jax.jit, static_argnames=("inf", "interpret", "block"))
def minplus_spmv(adj: jnp.ndarray, dist: jnp.ndarray, *, inf: float,
                 interpret: bool = False, block: int = BLOCK) -> jnp.ndarray:
    """One bounded-BFS relaxation over a dense (n, n) adjacency.

    adj[u, v] = edge weight (1.0) or ``inf``; dist (n,) f32.
    """
    n = adj.shape[0]
    assert adj.shape == (n, n) and dist.shape == (n,)
    assert n % block == 0, f"pad n={n} to a multiple of {block} (ops.py does)"
    nb = n // block
    grid = (nb, nb)  # (j: output block, i: reduction block)
    return pl.pallas_call(
        functools.partial(_minplus_kernel, inf=inf),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda j, i: (i, j)),
            pl.BlockSpec((block,), lambda j, i: (i,)),
            pl.BlockSpec((block,), lambda j, i: (j,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), dist.dtype),
        interpret=interpret,
    )(adj, dist, dist)


# ---------------------------------------------------------------------------
# counting SpMM:  out = adj_mask @ counts      (plus-times, MXU path)
# ---------------------------------------------------------------------------

def _counting_kernel(adj_ref, cnt_ref, out_ref):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(adj_ref[...], cnt_ref[...],
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def counting_spmm(adj_mask: jnp.ndarray, counts: jnp.ndarray, *,
                  interpret: bool = False, block: int = BLOCK) -> jnp.ndarray:
    """Walk-count DP level:  (n, n) {0,1} mask  @  (n, q) counts -> (n, q).

    q is the query-batch dimension — the engine runs the DP for a whole
    batch of concurrent queries in one MXU pass (beyond-paper batching,
    EXPERIMENTS.md §Perf).
    """
    n, q = counts.shape
    assert adj_mask.shape == (n, n)
    assert n % block == 0 and q % block == 0, "ops.py pads to block multiples"
    nm, nq, nk = n // block, q // block, n // block
    return pl.pallas_call(
        _counting_kernel,
        grid=(nm, nq, nk),
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block, block), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, q), jnp.float32),
        interpret=interpret,
    )(adj_mask.astype(jnp.float32), counts.astype(jnp.float32))
