"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: kernel tests sweep shapes/dtypes and
assert allclose against these functions (tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Semiring SpMM (PathEnum BFS relaxation + walk-count DP)
# ---------------------------------------------------------------------------

def minplus_spmv_ref(adj: jnp.ndarray, dist: jnp.ndarray,
                     inf: float) -> jnp.ndarray:
    """One min-plus relaxation: out[v] = min(dist[v], min_u adj[u,v]+dist[u]).

    adj is a dense (n, n) matrix with 1.0 where an edge u->v exists and
    ``inf`` elsewhere (weights generalize to weighted graphs).
    """
    cand = jnp.min(adj + dist[:, None], axis=0)
    return jnp.minimum(dist, jnp.minimum(cand, inf))


def counting_spmv_ref(adj_mask: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """One plus-times pass of the walk DP: out[u] = Σ_v adj[u,v] * counts[v].

    adj_mask is (n, n) {0,1}; counts float32.  This is Eq. 7's inner sum.
    """
    return adj_mask.astype(counts.dtype) @ counts


def counting_spmm_ref(adj_mask: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """Batched walk DP: counts (n, q) — q independent queries at once."""
    return adj_mask.astype(counts.dtype) @ counts


# ---------------------------------------------------------------------------
# Flash attention (LM prefill / train)
# ---------------------------------------------------------------------------

def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
            causal: bool = True, scale: float | None = None,
            window: int | None = None) -> jnp.ndarray:
    """Reference attention.  q (B, Lq, H, D), k/v (B, Lk, Hkv, D) with GQA
    broadcast when H != Hkv.  Optional causal mask and local window."""
    B, Lq, H, D = q.shape
    _, Lk, Hkv, _ = k.shape
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    group = H // Hkv
    kq = jnp.repeat(k, group, axis=2) if group > 1 else k
    vq = jnp.repeat(v, group, axis=2) if group > 1 else v
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kq).astype(jnp.float32) * scale
    if causal:
        qi = jnp.arange(Lq)[:, None] + (Lk - Lq)
        ki = jnp.arange(Lk)[None, :]
        mask = qi >= ki
        if window is not None:
            mask &= (qi - ki) < window
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vq.dtype), vq)
    return out.astype(q.dtype)


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, lengths: jnp.ndarray,
                         scale: float | None = None) -> jnp.ndarray:
    """Single-token GQA decode.  q (B, H, D); caches (B, S, Hkv, D);
    lengths (B,) valid prefix lengths."""
    B, H, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    group = H // Hkv
    kq = jnp.repeat(k_cache, group, axis=2) if group > 1 else k_cache
    vq = jnp.repeat(v_cache, group, axis=2) if group > 1 else v_cache
    logits = jnp.einsum("bhd,bshd->bhs", q, kq).astype(jnp.float32) * scale
    mask = jnp.arange(S)[None, None, :] < lengths[:, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p.astype(vq.dtype), vq)
    return out.astype(q.dtype)
