"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: kernel tests sweep shapes/dtypes and
assert allclose against these functions (tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Semiring SpMM (PathEnum BFS relaxation + walk-count DP)
# ---------------------------------------------------------------------------

def minplus_spmv_ref(adj: jnp.ndarray, dist: jnp.ndarray,
                     inf: float) -> jnp.ndarray:
    """One min-plus relaxation: out[v] = min(dist[v], min_u adj[u,v]+dist[u]).

    adj is a dense (n, n) matrix with 1.0 where an edge u->v exists and
    ``inf`` elsewhere (weights generalize to weighted graphs).
    """
    cand = jnp.min(adj + dist[:, None], axis=0)
    return jnp.minimum(dist, jnp.minimum(cand, inf))


def counting_spmv_ref(adj_mask: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """One plus-times pass of the walk DP: out[u] = Σ_v adj[u,v] * counts[v].

    adj_mask is (n, n) {0,1}; counts float32.  This is Eq. 7's inner sum.
    """
    return adj_mask.astype(counts.dtype) @ counts


def counting_spmm_ref(adj_mask: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """Batched walk DP: counts (n, q) — q independent queries at once."""
    return adj_mask.astype(counts.dtype) @ counts


# ---------------------------------------------------------------------------
# IDX-DFS frontier expansion (PathEnum Algorithm 4 hot loop)
# ---------------------------------------------------------------------------

def frontier_masks_ref(paths, begin, endb, dst, depth, t, max_deg: int,
                       pad: int = -1):
    """Pure-jnp oracle for kernels/frontier_expand._frontier_kernel.

    paths (C, k+1) int32 (PAD rows inert); begin/endb (n,) int32 offsets
    (endb pre-sliced to budget b = k - depth - 1); dst (mf,) int32;
    depth/t scalar int32.  Returns (vnew, emit, cont, counters) with the
    same shapes, masking and Fig.-6 counter semantics as the kernel.
    """
    C, k1 = paths.shape
    mf = dst.shape[0]
    last = jnp.take(paths, depth, axis=1)
    valid = last != pad
    lastc = jnp.where(valid, last, 0)
    bsel = jnp.take(begin, lastc)
    esel = jnp.take(endb, lastc)
    cnt = jnp.where(valid, esel - bsel, 0)
    slot = jnp.arange(max_deg, dtype=jnp.int32)[None, :]
    in_range = slot < cnt[:, None]
    pos = jnp.clip(bsel[:, None] + slot, 0, mf - 1)
    vnew = jnp.take(dst, pos)
    on_prefix = jnp.arange(k1, dtype=jnp.int32) <= depth        # (k1,)
    dup = ((paths[:, :, None] == vnew[:, None, :])
           & on_prefix[None, :, None]).any(axis=1)
    is_t = vnew == t
    emit = in_range & ~dup & is_t
    cont = in_range & ~dup & ~is_t
    alive = (emit | cont).any(axis=1)
    dead = valid & ~alive
    edges = jnp.sum(cnt)
    invalid = (jnp.sum((dup & in_range).astype(jnp.int32))
               + jnp.sum(dead.astype(jnp.int32)))
    counters = jnp.stack([edges, edges, invalid, jnp.int32(0)])
    return (jnp.where(emit | cont, vnew, pad), emit.astype(jnp.int32),
            cont.astype(jnp.int32), counters)


def frontier_fused_masks_ref(paths, rank, tvec, depthv, begin, endb, dst,
                             max_deg: int, pad: int = -1):
    """Pure-jnp oracle for kernels/frontier_expand._frontier_fused_kernel.

    paths (C, k1max) int32 rows packed member-rank-ascending (PAD rows
    inert); rank (C,) int32 member tags; tvec/depthv (m,) int32 per-member
    target/depth; begin/endb (m·n,) and dst (m·mfm,) int32 flattened
    per-member tables (endb pre-sliced to each member's budget column).
    Returns (vnew, emit, cont, counters) with counters (m, 4) per-member
    Fig.-6 rows — same semantics as the fused kernel.
    """
    C, k1 = paths.shape
    m = tvec.shape[0]
    n = begin.shape[0] // m
    mfm = dst.shape[0] // m
    depth = jnp.take(depthv, rank)
    t = jnp.take(tvec, rank)
    last = jnp.take_along_axis(paths, depth[:, None], axis=1)[:, 0]
    valid = last != pad
    lastc = jnp.where(valid, last, 0)
    flat = rank * jnp.int32(n) + lastc
    bsel = jnp.take(begin, flat)
    esel = jnp.take(endb, flat)
    cnt = jnp.where(valid, esel - bsel, 0)
    slot = jnp.arange(max_deg, dtype=jnp.int32)[None, :]
    in_range = slot < cnt[:, None]
    pos = (jnp.clip(bsel[:, None] + slot, 0, mfm - 1)
           + rank[:, None] * jnp.int32(mfm))
    vnew = jnp.take(dst, pos)
    on_prefix = (jnp.arange(k1, dtype=jnp.int32)[None, :]
                 <= depth[:, None])                          # (C, k1)
    dup = ((paths[:, :, None] == vnew[:, None, :])
           & on_prefix[:, :, None]).any(axis=1)
    is_t = vnew == t[:, None]
    emit = in_range & ~dup & is_t
    cont = in_range & ~dup & ~is_t
    alive = (emit | cont).any(axis=1)
    dead = valid & ~alive
    edges_row = cnt
    invalid_row = (jnp.sum((dup & in_range).astype(jnp.int32), axis=1)
                   + dead.astype(jnp.int32))
    onehot = jnp.arange(m, dtype=jnp.int32)[None, :] == rank[:, None]
    edges_m = jnp.sum(jnp.where(onehot, edges_row[:, None], 0), axis=0)
    invalid_m = jnp.sum(jnp.where(onehot, invalid_row[:, None], 0), axis=0)
    counters = jnp.stack([edges_m, edges_m, invalid_m,
                          jnp.zeros_like(edges_m)], axis=1)
    return (jnp.where(emit | cont, vnew, pad), emit.astype(jnp.int32),
            cont.astype(jnp.int32), counters)


# ---------------------------------------------------------------------------
# Flash attention (LM prefill / train)
# ---------------------------------------------------------------------------

def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
            causal: bool = True, scale: float | None = None,
            window: int | None = None) -> jnp.ndarray:
    """Reference attention.  q (B, Lq, H, D), k/v (B, Lk, Hkv, D) with GQA
    broadcast when H != Hkv.  Optional causal mask and local window."""
    B, Lq, H, D = q.shape
    _, Lk, Hkv, _ = k.shape
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    group = H // Hkv
    kq = jnp.repeat(k, group, axis=2) if group > 1 else k
    vq = jnp.repeat(v, group, axis=2) if group > 1 else v
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kq).astype(jnp.float32) * scale
    if causal:
        qi = jnp.arange(Lq)[:, None] + (Lk - Lq)
        ki = jnp.arange(Lk)[None, :]
        mask = qi >= ki
        if window is not None:
            mask &= (qi - ki) < window
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vq.dtype), vq)
    return out.astype(q.dtype)


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, lengths: jnp.ndarray,
                         scale: float | None = None) -> jnp.ndarray:
    """Single-token GQA decode.  q (B, H, D); caches (B, S, Hkv, D);
    lengths (B,) valid prefix lengths."""
    B, H, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    group = H // Hkv
    kq = jnp.repeat(k_cache, group, axis=2) if group > 1 else k_cache
    vq = jnp.repeat(v_cache, group, axis=2) if group > 1 else v_cache
    logits = jnp.einsum("bhd,bshd->bhs", q, kq).astype(jnp.float32) * scale
    mask = jnp.arange(S)[None, None, :] < lengths[:, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p.astype(vq.dtype), vq)
    return out.astype(q.dtype)
