"""Device-resident IDX-DFS frontier expansion (DESIGN.md §9).

Algorithm 4's hot loop — the per-hop offset gather from the light-weight
index (``fwd_begin`` / ``fwd_end`` / ``fwd_dst``), the vectorized
simple-path prefix compare, and the emit/continue partition — as one
Pallas kernel over fixed-width ``(chunk, k+1)`` int32 path matrices.

Layout contract (the padding/bucketing rules live in ops.frontier_expand):
  * path rows are partial s→v walks at one common ``depth``; columns past
    the depth hold PAD, and whole PAD rows (``paths[:, depth] == PAD``)
    are inert padding — no candidates, no counter contributions.
  * each row fans out into ``max_deg`` candidate slots; slot j of row r
    is real iff ``j < |I_t(v_r, k - depth - 1)|`` (the O(1) budget read
    off the offset matrix, done in-kernel).
  * outputs are the candidate-vertex matrix plus emit/continue masks;
    compaction into dense row matrices (and the device scalars n_emit /
    n_cont) happens in the jit'd wrapper, ops.frontier_expand.
  * the Fig.-6 counters accumulate across the row-block grid into one
    ``(4,)`` int32 vector ``[edges_accessed, partials_generated,
    invalid_partials, 0]`` — bit-identical to the host ``EnumStats``
    deltas of core/enumerate._expand_chunk
    (tests/test_frontier_kernel.py asserts the parity).

On CPU the kernel runs through the Pallas interpreter (numerics only);
on TPU the same call site compiles to Mosaic.  The gathers are dynamic
(``jnp.take`` over the on-chip index arrays), so the kernel targets the
small-k regime where the per-query index fits in VMEM — exactly the
regime the §9 auto-selection rule routes here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Must agree with core.graph.PAD; tests/test_frontier_kernel.py pins it.
PAD = -1

# Row-block height of the expansion grid.  Chunks narrower than this run
# as a single block; wider chunks (ops.frontier_expand pads rows to a
# power of two) stream block by block.
BLOCK_ROWS = 128


def _frontier_kernel(meta_ref, paths_ref, begin_ref, endb_ref, dst_ref,
                     vnew_ref, emit_ref, cont_ref, counters_ref, *,
                     k1: int, max_deg: int, mf: int, pad: int):
    """One row-block of one hop: gather → prefix-dedup → partition."""
    depth = meta_ref[0]
    t = meta_ref[1]
    paths = paths_ref[...]                                  # (BR, k1)
    last = jnp.take(paths, depth, axis=1)                   # (BR,)
    valid = last != pad
    lastc = jnp.where(valid, last, 0)
    begin = jnp.take(begin_ref[...], lastc)                 # (BR,)
    end = jnp.take(endb_ref[...], lastc)
    cnt = jnp.where(valid, end - begin, 0)                  # |I_t(v, b)|
    slot = jax.lax.broadcasted_iota(jnp.int32, (paths.shape[0], max_deg), 1)
    in_range = slot < cnt[:, None]
    pos = jnp.clip(begin[:, None] + slot, 0, mf - 1)
    vnew = jnp.take(dst_ref[...], pos)                      # (BR, max_deg)

    # simple-path check: v' must not appear in the row's depth+1 prefix
    # (unrolled over the static path width; columns past `depth` masked)
    dup = jnp.zeros_like(in_range)
    for c in range(k1):
        on_prefix = jnp.int32(c) <= depth
        dup = dup | (on_prefix & (paths[:, c][:, None] == vnew))

    is_t = vnew == t
    emit = in_range & ~dup & is_t
    cont = in_range & ~dup & ~is_t

    # Fig. 6 deltas, matching core/enumerate._expand_chunk exactly:
    # dup-pruned expansions plus rows none of whose expansions survived
    alive = (emit | cont).any(axis=1)
    dead = valid & ~alive
    edges = jnp.sum(cnt)
    invalid = (jnp.sum((dup & in_range).astype(jnp.int32))
               + jnp.sum(dead.astype(jnp.int32)))

    vnew_ref[...] = jnp.where(emit | cont, vnew, pad)
    emit_ref[...] = emit.astype(jnp.int32)
    cont_ref[...] = cont.astype(jnp.int32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        counters_ref[...] = jnp.zeros_like(counters_ref)

    counters_ref[...] += jnp.stack([edges, edges, invalid, jnp.int32(0)])


def _frontier_fused_kernel(tvec_ref, depthv_ref, rank_ref, paths_ref,
                           begin_ref, endb_ref, dst_ref,
                           vnew_ref, emit_ref, cont_ref, counters_ref, *,
                           k1: int, max_deg: int, n: int, mfm: int,
                           m: int, pad: int):
    """One row-block of one *fused* hop: many queries, one launch.

    The single-query kernel above expands one chunk of one query; this
    variant packs chunks from ``m`` queries (an async micro-batch, or a
    merged sharing group's member views) into one row matrix, tagged by
    member rank, and expands them all in a single dispatch (DESIGN.md
    §9).  Per-member state rides flattened tables indexed by rank:

      * ``tvec``/``depthv`` (m,) — each member's target and the common
        depth of its packed chunk (all rows of one chunk share a depth);
      * ``rank`` (BR,) — each row's member; PAD rows carry rank 0 and
        stay inert (their path row is all PAD, so ``valid`` is False);
      * ``begin``/``endb`` (m·n,) — each member's offset vectors,
        ``endb`` pre-sliced to the member's budget column b = k−depth−1
        by the wrapper; row r gathers at ``rank_r·n + last_r``;
      * ``dst`` (m·mfm,) — each member's adjacency slab, padded to the
        common ``mfm``; candidate positions clip *within* the member's
        slab before the rank offset is added, so no row can read a
        neighbor member's edges.

    Masking, dedup and the Fig.-6 counter semantics are the single-query
    kernel's, applied per row with per-row depth/t — except counters
    accumulate into an (m, 4) matrix, one row per member, via a rank
    one-hot, so the host driver can credit each query's ``EnumStats``
    exactly as if it had run solo (tests/test_fused_launch.py pins the
    bit-parity).
    """
    rank = rank_ref[...]                                    # (BR,)
    depth = jnp.take(depthv_ref[...], rank)                 # (BR,)
    t = jnp.take(tvec_ref[...], rank)                       # (BR,)
    paths = paths_ref[...]                                  # (BR, k1)
    # per-row column gather, unrolled over the static path width
    last = jnp.full(rank.shape, pad, jnp.int32)
    for c in range(k1):
        last = jnp.where(depth == jnp.int32(c), paths[:, c], last)
    valid = last != pad
    lastc = jnp.where(valid, last, 0)
    flat = rank * jnp.int32(n) + lastc
    begin = jnp.take(begin_ref[...], flat)                  # (BR,)
    end = jnp.take(endb_ref[...], flat)
    cnt = jnp.where(valid, end - begin, 0)                  # |I_t(v, b)|
    slot = jax.lax.broadcasted_iota(jnp.int32, (paths.shape[0], max_deg), 1)
    in_range = slot < cnt[:, None]
    pos = (jnp.clip(begin[:, None] + slot, 0, mfm - 1)
           + rank[:, None] * jnp.int32(mfm))
    vnew = jnp.take(dst_ref[...], pos)                      # (BR, max_deg)

    dup = jnp.zeros_like(in_range)
    for c in range(k1):
        on_prefix = jnp.int32(c) <= depth
        dup = dup | (on_prefix[:, None] & (paths[:, c][:, None] == vnew))

    is_t = vnew == t[:, None]
    emit = in_range & ~dup & is_t
    cont = in_range & ~dup & ~is_t

    alive = (emit | cont).any(axis=1)
    dead = valid & ~alive
    edges_row = cnt                                         # (BR,)
    invalid_row = (jnp.sum((dup & in_range).astype(jnp.int32), axis=1)
                   + dead.astype(jnp.int32))

    vnew_ref[...] = jnp.where(emit | cont, vnew, pad)
    emit_ref[...] = emit.astype(jnp.int32)
    cont_ref[...] = cont.astype(jnp.int32)

    # per-member counter rows via a rank one-hot (PAD rows land on
    # member 0 but contribute zeros: cnt == 0 and dead is False)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (paths.shape[0], m), 1)
              == rank[:, None])
    edges_m = jnp.sum(jnp.where(onehot, edges_row[:, None], 0), axis=0)
    invalid_m = jnp.sum(jnp.where(onehot, invalid_row[:, None], 0), axis=0)
    zeros_m = jnp.zeros_like(edges_m)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        counters_ref[...] = jnp.zeros_like(counters_ref)

    counters_ref[...] += jnp.stack([edges_m, edges_m, invalid_m, zeros_m],
                                   axis=1)


@functools.partial(jax.jit, static_argnames=("max_deg", "interpret"))
def frontier_fused_masks(paths, rank, tvec, depthv, begin, endb, dst, *,
                         max_deg: int, interpret: bool = False):
    """Raw fused-kernel entry: masks + per-member counters, no compaction.

    paths (C, k1max) int32 rows packed member-rank-ascending (PAD rows
    inert); rank (C,) int32; tvec/depthv (m,) int32; begin/endb (m·n,)
    int32; dst (m·mfm,) int32.  Returns (vnew, emit, cont, counters)
    with counters (m, 4) — see ``_frontier_fused_kernel`` for layout.
    """
    C, k1 = paths.shape
    m = tvec.shape[0]
    n = begin.shape[0] // m
    mfm = dst.shape[0] // m
    br = C if C < BLOCK_ROWS else BLOCK_ROWS
    assert C % br == 0, f"pad chunk rows C={C} to a multiple of {br}"
    grid = (C // br,)
    kern = functools.partial(_frontier_fused_kernel, k1=k1, max_deg=max_deg,
                             n=n, mfm=mfm, m=m, pad=PAD)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),            # tvec
            pl.BlockSpec((m,), lambda i: (0,)),            # depthv
            pl.BlockSpec((br,), lambda i: (i,)),           # rank
            pl.BlockSpec((br, k1), lambda i: (i, 0)),
            pl.BlockSpec((m * n,), lambda i: (0,)),
            pl.BlockSpec((m * n,), lambda i: (0,)),
            pl.BlockSpec((m * mfm,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, max_deg), lambda i: (i, 0)),
            pl.BlockSpec((br, max_deg), lambda i: (i, 0)),
            pl.BlockSpec((br, max_deg), lambda i: (i, 0)),
            pl.BlockSpec((m, 4), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, max_deg), jnp.int32),
            jax.ShapeDtypeStruct((C, max_deg), jnp.int32),
            jax.ShapeDtypeStruct((C, max_deg), jnp.int32),
            jax.ShapeDtypeStruct((m, 4), jnp.int32),
        ],
        interpret=interpret,
    )(tvec, depthv, rank, paths, begin, endb, dst)


@functools.partial(jax.jit, static_argnames=("max_deg", "interpret"))
def frontier_expand_masks(paths, begin, endb, dst, meta, *, max_deg: int,
                          interpret: bool = False):
    """Raw kernel entry: masks + counters, no compaction.

    paths (C, k+1) int32 with C a multiple of the row block (or smaller);
    begin/endb (n,) int32 offset vectors (endb already sliced to the
    budget column); dst (mf,) int32; meta = [depth, t] int32.  Returns
    (vnew, emit, cont, counters) — see the module docstring for layout.
    """
    C, k1 = paths.shape
    n = begin.shape[0]
    mf = dst.shape[0]
    br = C if C < BLOCK_ROWS else BLOCK_ROWS
    assert C % br == 0, f"pad chunk rows C={C} to a multiple of {br}"
    grid = (C // br,)
    kern = functools.partial(_frontier_kernel, k1=k1, max_deg=max_deg,
                             mf=mf, pad=PAD)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),            # meta [depth, t]
            pl.BlockSpec((br, k1), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((mf,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, max_deg), lambda i: (i, 0)),
            pl.BlockSpec((br, max_deg), lambda i: (i, 0)),
            pl.BlockSpec((br, max_deg), lambda i: (i, 0)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, max_deg), jnp.int32),
            jax.ShapeDtypeStruct((C, max_deg), jnp.int32),
            jax.ShapeDtypeStruct((C, max_deg), jnp.int32),
            jax.ShapeDtypeStruct((4,), jnp.int32),
        ],
        interpret=interpret,
    )(meta, paths, begin, endb, dst)
