"""Public jit'd wrappers around the Pallas kernels.

Responsibilities: shape/alignment padding (kernels demand block multiples),
dtype plumbing, and the interpret switch — on the CPU validation container
kernels execute via ``interpret=True`` (the Pallas interpreter runs the
kernel body in Python); on TPU the same call sites compile to Mosaic.
Set REPRO_PALLAS=off to route every op to its pure-jnp reference instead
(used to A/B the kernels inside the full system).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .decode_attention import decode_attention as _decode_pallas
from .flash_attention import flash_attention as _flash_pallas
from .frontier_expand import PAD, frontier_expand_masks as _frontier_pallas
from .semiring_spmm import BLOCK, counting_spmm as _counting_pallas
from .semiring_spmm import minplus_spmv as _minplus_pallas


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _enabled() -> bool:
    return os.environ.get("REPRO_PALLAS", "on") != "off"


def _pad_to(x: jnp.ndarray, axis: int, mult: int,
            value: float) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# PathEnum semiring ops
# ---------------------------------------------------------------------------

def minplus_spmv(adj: jnp.ndarray, dist: jnp.ndarray, *, inf: float,
                 block: int = BLOCK) -> jnp.ndarray:
    """BFS relaxation step; pads n to the tile size."""
    if not _enabled():
        return ref.minplus_spmv_ref(adj, dist, inf)
    n = adj.shape[0]
    adj_p = _pad_to(_pad_to(adj, 0, block, inf), 1, block, inf)
    dist_p = _pad_to(dist, 0, block, inf)
    out = _minplus_pallas(adj_p, dist_p, inf=inf, interpret=_interpret(),
                          block=block)
    return out[:n]


def counting_spmm(adj_mask: jnp.ndarray, counts: jnp.ndarray, *,
                  block: int = BLOCK) -> jnp.ndarray:
    """Walk-count DP level for a query batch; pads (n, q) to tiles."""
    if not _enabled():
        return ref.counting_spmm_ref(adj_mask, counts)
    n, q = counts.shape
    adj_p = _pad_to(_pad_to(adj_mask, 0, block, 0), 1, block, 0)
    cnt_p = _pad_to(_pad_to(counts, 0, block, 0), 1, block, 0)
    out = _counting_pallas(adj_p, cnt_p, interpret=_interpret(), block=block)
    return out[:n, :q]


def bfs_dense(adj: jnp.ndarray, src: int | jnp.ndarray, k: int, *,
              inf: float = 1e9, block: int = BLOCK) -> jnp.ndarray:
    """Bounded BFS over a dense adjacency via k min-plus relaxations.

    This is the Pallas-kernel twin of core.bfs.bfs_edge_relax for the
    dense-tile regime (small/medium graphs, or per-partition tiles of the
    distributed engine).
    """
    n = adj.shape[0]
    dist = jnp.full((n,), inf, dtype=jnp.float32).at[src].set(0.0)

    def body(_: int, d: jnp.ndarray) -> jnp.ndarray:
        return minplus_spmv(adj, d, inf=inf, block=block)

    return jax.lax.fori_loop(0, k, body, dist)


# ---------------------------------------------------------------------------
# IDX-DFS frontier expansion (device-resident enumeration, DESIGN.md §9)
# ---------------------------------------------------------------------------

def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length() if x > 1 else 1


def _children(paths: jnp.ndarray, vflat: jnp.ndarray, idxs: jnp.ndarray,
              depth: jnp.ndarray, max_deg: int) -> jnp.ndarray:
    """Materialize child rows for the compacted candidate indices: gather
    each candidate's parent row and write its vertex at column depth+1."""
    rows = jnp.take(paths, idxs // max_deg, axis=0)          # (cap, k1)
    col = jax.lax.broadcasted_iota(jnp.int32, rows.shape, 1)
    return jnp.where(col == depth + 1, jnp.take(vflat, idxs)[:, None], rows)


@functools.partial(jax.jit,
                   static_argnames=("max_deg", "interpret", "use_ref",
                                    "want_cont"))
def _frontier_expand_jit(
        paths: jnp.ndarray, begin: jnp.ndarray, end: jnp.ndarray,
        dst: jnp.ndarray, meta: jnp.ndarray, *, max_deg: int,
        interpret: bool, use_ref: bool, want_cont: bool
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
           jnp.ndarray]:
    """Masks (Pallas kernel or jnp ref) + compaction, one fused jit."""
    C, k1 = paths.shape
    depth = meta[0]
    b = jnp.clip(k1 - 2 - depth, 0, k1 - 1)   # budget k - depth - 1
    endb = jnp.take(end, b, axis=1)
    if use_ref:
        vnew, emit, cont, counters = ref.frontier_masks_ref(
            paths, begin, endb, dst, depth, meta[1], max_deg, PAD)
    else:
        vnew, emit, cont, counters = _frontier_pallas(
            paths, begin, endb, dst, meta, max_deg=max_deg,
            interpret=interpret)
    cap = C * max_deg
    vflat = vnew.reshape(-1)
    flat_emit = emit.reshape(-1) != 0
    eidx = jnp.nonzero(flat_emit, size=cap, fill_value=0)[0]
    emit_rows = _children(paths, vflat, eidx, depth, max_deg)
    n_emit = jnp.sum(flat_emit.astype(jnp.int32))
    if want_cont:
        flat_cont = cont.reshape(-1) != 0
        cidx = jnp.nonzero(flat_cont, size=cap, fill_value=0)[0]
        cont_rows = _children(paths, vflat, cidx, depth, max_deg)
        n_cont = jnp.sum(flat_cont.astype(jnp.int32))
    else:
        # last hop: survivors can never extend, so skip the (cap, k+1)
        # gather the caller would discard (counters still see them)
        cont_rows = paths[:0]
        n_cont = jnp.int32(0)
    return emit_rows, cont_rows, n_emit, n_cont, counters


def frontier_expand(
        paths: np.ndarray | jnp.ndarray, fwd_begin: np.ndarray,
        fwd_end: np.ndarray, fwd_dst: np.ndarray, *, depth: int,
        t: int, max_deg: int, want_cont: bool = True
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
           jnp.ndarray]:
    """One IDX-DFS hop for a whole chunk, on device (DESIGN.md §9).

    paths is the (rows, k+1) int32 partial-path matrix at ``depth`` (PAD
    past the depth column); fwd_begin (n,) / fwd_end (n, k+1) / fwd_dst
    (mf,) are the int32 index arrays (``LightweightIndex.device_arrays``).
    ``max_deg`` is the chunk's max fan-out (callers read it off the host
    offset arrays; it must be ≥ 1 — zero-fanout chunks are the host
    driver's shortcut).

    Returns ``(emit_rows, cont_rows, n_emit, n_cont, counters)`` — all
    device-resident: the first ``n_emit`` rows of ``emit_rows`` are the
    completed paths (t written at depth+1) in exact host emission order,
    the first ``n_cont`` rows of ``cont_rows`` the surviving partials,
    and ``counters`` the (4,) int32 ``[edges_accessed,
    partials_generated, invalid_partials, 0]`` Fig.-6 scalars matching
    the host ``EnumStats`` deltas bit-for-bit.  ``want_cont=False``
    (the last hop, where survivors cannot extend) skips the continue
    compaction and returns an empty ``cont_rows`` with ``n_cont == 0``;
    counters are unaffected.

    Shapes are bucketed to powers of two (rows and fan-out) to bound jit
    recompiles; padded rows are PAD and inert.  ``REPRO_PALLAS=off``
    routes the mask stage to the pure-jnp reference.

    Ranked enumeration (DESIGN.md §10) reuses this kernel *unchanged*:
    the rank-bucketed driver (core/enumerate._drive_ranked_buckets)
    decides which chunks to expand and in what order — one hop-bound
    bucket at a time — but each launch is the same hop this docstring
    describes.  Rank awareness lives entirely in host scheduling.
    """
    paths = np.asarray(paths, dtype=np.int32)
    rows, k1 = paths.shape
    assert depth + 2 <= k1, f"depth {depth} leaves no column for the hop"
    assert max_deg >= 1, "zero-fanout chunks never reach the device"
    C = _next_pow2(max(rows, 8))
    if C != rows:
        paths = np.pad(paths, ((0, C - rows), (0, 0)), constant_values=PAD)
    meta = jnp.asarray([depth, t], jnp.int32)
    return _frontier_expand_jit(
        jnp.asarray(paths), jnp.asarray(fwd_begin), jnp.asarray(fwd_end),
        jnp.asarray(fwd_dst), meta, max_deg=_next_pow2(max_deg),
        interpret=_interpret(), use_ref=not _enabled(),
        want_cont=want_cont)


# ---------------------------------------------------------------------------
# LM attention ops
# ---------------------------------------------------------------------------

def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, bq: int = 128,
                    bk: int = 128) -> jnp.ndarray:
    if not _enabled():
        return ref.mha_ref(q, k, v, causal=causal, scale=scale, window=window)
    B, Lq, H, D = q.shape
    _, Lk, Hkv, _ = k.shape
    bq_eff = min(bq, max(8, Lq))
    bk_eff = min(bk, max(8, Lk))
    needs_pad = (Lq % bq_eff != 0) or (Lk % bk_eff != 0)
    if needs_pad and (not causal or Lq != Lk):
        # Padding shifts the causal diagonal when Lq != Lk; production
        # shapes (4k/32k/500k) are tile-aligned so this fallback only
        # serves ragged test shapes.
        return ref.mha_ref(q, k, v, causal=causal, scale=scale, window=window)
    if needs_pad:
        # Lq == Lk: pad both ends equally.  Padded KV columns sit past every
        # real row index so the causal mask removes them; padded Q rows are
        # sliced off below.
        q = _pad_to(q, 1, bq_eff, 0)
        k = _pad_to(k, 1, bk_eff, 0)
        v = _pad_to(v, 1, bk_eff, 0)
        if q.shape[1] != k.shape[1]:
            pad_len = max(q.shape[1], k.shape[1])
            q = _pad_to(q, 1, pad_len, 0)
            k = _pad_to(k, 1, pad_len, 0)
            v = _pad_to(v, 1, pad_len, 0)
    out = _flash_pallas(q, k, v, causal=causal, window=window,
                        scale=scale, bq=bq_eff, bk=bk_eff,
                        interpret=_interpret())
    return out[:, :Lq]


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, lengths: jnp.ndarray, *,
                     scale: float | None = None,
                     bs: int = 512) -> jnp.ndarray:
    if not _enabled():
        return ref.decode_attention_ref(q, k_cache, v_cache, lengths,
                                        scale=scale)
    B, S, Hkv, D = k_cache.shape
    bs_eff = min(bs, max(8, S))
    k_p = _pad_to(k_cache, 1, bs_eff, 0)
    v_p = _pad_to(v_cache, 1, bs_eff, 0)
    return _decode_pallas(q, k_p, v_p, lengths, scale=scale, bs=bs_eff,
                          interpret=_interpret())
