"""Public jit'd wrappers around the Pallas kernels.

Responsibilities: shape/alignment padding (kernels demand block multiples),
dtype plumbing, and the interpret switch — on the CPU validation container
kernels execute via ``interpret=True`` (the Pallas interpreter runs the
kernel body in Python); on TPU the same call sites compile to Mosaic.
Set REPRO_PALLAS=off to route every op to its pure-jnp reference instead
(used to A/B the kernels inside the full system).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .decode_attention import decode_attention as _decode_pallas
from .flash_attention import flash_attention as _flash_pallas
from .semiring_spmm import BLOCK, counting_spmm as _counting_pallas
from .semiring_spmm import minplus_spmv as _minplus_pallas


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _enabled() -> bool:
    return os.environ.get("REPRO_PALLAS", "on") != "off"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# PathEnum semiring ops
# ---------------------------------------------------------------------------

def minplus_spmv(adj: jnp.ndarray, dist: jnp.ndarray, *, inf: float,
                 block: int = BLOCK) -> jnp.ndarray:
    """BFS relaxation step; pads n to the tile size."""
    if not _enabled():
        return ref.minplus_spmv_ref(adj, dist, inf)
    n = adj.shape[0]
    adj_p = _pad_to(_pad_to(adj, 0, block, inf), 1, block, inf)
    dist_p = _pad_to(dist, 0, block, inf)
    out = _minplus_pallas(adj_p, dist_p, inf=inf, interpret=_interpret(),
                          block=block)
    return out[:n]


def counting_spmm(adj_mask: jnp.ndarray, counts: jnp.ndarray, *,
                  block: int = BLOCK) -> jnp.ndarray:
    """Walk-count DP level for a query batch; pads (n, q) to tiles."""
    if not _enabled():
        return ref.counting_spmm_ref(adj_mask, counts)
    n, q = counts.shape
    adj_p = _pad_to(_pad_to(adj_mask, 0, block, 0), 1, block, 0)
    cnt_p = _pad_to(_pad_to(counts, 0, block, 0), 1, block, 0)
    out = _counting_pallas(adj_p, cnt_p, interpret=_interpret(), block=block)
    return out[:n, :q]


def bfs_dense(adj: jnp.ndarray, src: int | jnp.ndarray, k: int, *,
              inf: float = 1e9, block: int = BLOCK) -> jnp.ndarray:
    """Bounded BFS over a dense adjacency via k min-plus relaxations.

    This is the Pallas-kernel twin of core.bfs.bfs_edge_relax for the
    dense-tile regime (small/medium graphs, or per-partition tiles of the
    distributed engine).
    """
    n = adj.shape[0]
    dist = jnp.full((n,), inf, dtype=jnp.float32).at[src].set(0.0)

    def body(_, d):
        return minplus_spmv(adj, d, inf=inf, block=block)

    return jax.lax.fori_loop(0, k, body, dist)


# ---------------------------------------------------------------------------
# LM attention ops
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    scale: float | None = None, bq: int = 128,
                    bk: int = 128) -> jnp.ndarray:
    if not _enabled():
        return ref.mha_ref(q, k, v, causal=causal, scale=scale, window=window)
    B, Lq, H, D = q.shape
    _, Lk, Hkv, _ = k.shape
    bq_eff = min(bq, max(8, Lq))
    bk_eff = min(bk, max(8, Lk))
    needs_pad = (Lq % bq_eff != 0) or (Lk % bk_eff != 0)
    if needs_pad and (not causal or Lq != Lk):
        # Padding shifts the causal diagonal when Lq != Lk; production
        # shapes (4k/32k/500k) are tile-aligned so this fallback only
        # serves ragged test shapes.
        return ref.mha_ref(q, k, v, causal=causal, scale=scale, window=window)
    if needs_pad:
        # Lq == Lk: pad both ends equally.  Padded KV columns sit past every
        # real row index so the causal mask removes them; padded Q rows are
        # sliced off below.
        q = _pad_to(q, 1, bq_eff, 0)
        k = _pad_to(k, 1, bk_eff, 0)
        v = _pad_to(v, 1, bk_eff, 0)
        if q.shape[1] != k.shape[1]:
            pad_len = max(q.shape[1], k.shape[1])
            q = _pad_to(q, 1, pad_len, 0)
            k = _pad_to(k, 1, pad_len, 0)
            v = _pad_to(v, 1, pad_len, 0)
    out = _flash_pallas(q, k, v, causal=causal, window=window,
                        scale=scale, bq=bq_eff, bk=bk_eff,
                        interpret=_interpret())
    return out[:, :Lq]


def decode_attention(q, k_cache, v_cache, lengths, *, scale: float | None = None,
                     bs: int = 512) -> jnp.ndarray:
    if not _enabled():
        return ref.decode_attention_ref(q, k_cache, v_cache, lengths,
                                        scale=scale)
    B, S, Hkv, D = k_cache.shape
    bs_eff = min(bs, max(8, S))
    k_p = _pad_to(k_cache, 1, bs_eff, 0)
    v_p = _pad_to(v_cache, 1, bs_eff, 0)
    return _decode_pallas(q, k_p, v_p, lengths, scale=scale, bs=bs_eff,
                          interpret=_interpret())
