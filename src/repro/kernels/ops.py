"""Public jit'd wrappers around the Pallas kernels.

Responsibilities: shape/alignment padding (kernels demand block multiples),
dtype plumbing, and the interpret switch — on the CPU validation container
kernels execute via ``interpret=True`` (the Pallas interpreter runs the
kernel body in Python); on TPU the same call sites compile to Mosaic.
Set REPRO_PALLAS=off to route every op to its pure-jnp reference instead
(used to A/B the kernels inside the full system).
"""
from __future__ import annotations

import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .decode_attention import decode_attention as _decode_pallas
from .flash_attention import flash_attention as _flash_pallas
from .frontier_expand import PAD, frontier_expand_masks as _frontier_pallas
from .frontier_expand import frontier_fused_masks as _frontier_fused_pallas
from .semiring_spmm import BLOCK, counting_spmm as _counting_pallas
from .semiring_spmm import minplus_spmv as _minplus_pallas

# Monotone count of frontier-expansion device dispatches (single-query,
# fused and deque-round launches alike).  The fused-launch and deque
# tests assert on deltas of this counter — it is the ground truth for
# "one dispatch per expansion round" (DESIGN.md §9).
_dispatch_count: int = 0


def device_dispatch_count() -> int:
    """Total frontier-expansion kernel dispatches since process start."""
    return _dispatch_count


def _count_dispatch() -> None:
    global _dispatch_count
    _dispatch_count += 1


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _enabled() -> bool:
    return os.environ.get("REPRO_PALLAS", "on") != "off"


def _pad_to(x: jnp.ndarray, axis: int, mult: int,
            value: float) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# PathEnum semiring ops
# ---------------------------------------------------------------------------

def minplus_spmv(adj: jnp.ndarray, dist: jnp.ndarray, *, inf: float,
                 block: int = BLOCK) -> jnp.ndarray:
    """BFS relaxation step; pads n to the tile size."""
    if not _enabled():
        return ref.minplus_spmv_ref(adj, dist, inf)
    n = adj.shape[0]
    adj_p = _pad_to(_pad_to(adj, 0, block, inf), 1, block, inf)
    dist_p = _pad_to(dist, 0, block, inf)
    out = _minplus_pallas(adj_p, dist_p, inf=inf, interpret=_interpret(),
                          block=block)
    return out[:n]


def counting_spmm(adj_mask: jnp.ndarray, counts: jnp.ndarray, *,
                  block: int = BLOCK) -> jnp.ndarray:
    """Walk-count DP level for a query batch; pads (n, q) to tiles."""
    if not _enabled():
        return ref.counting_spmm_ref(adj_mask, counts)
    n, q = counts.shape
    adj_p = _pad_to(_pad_to(adj_mask, 0, block, 0), 1, block, 0)
    cnt_p = _pad_to(_pad_to(counts, 0, block, 0), 1, block, 0)
    out = _counting_pallas(adj_p, cnt_p, interpret=_interpret(), block=block)
    return out[:n, :q]


def bfs_dense(adj: jnp.ndarray, src: int | jnp.ndarray, k: int, *,
              inf: float = 1e9, block: int = BLOCK) -> jnp.ndarray:
    """Bounded BFS over a dense adjacency via k min-plus relaxations.

    This is the Pallas-kernel twin of core.bfs.bfs_edge_relax for the
    dense-tile regime (small/medium graphs, or per-partition tiles of the
    distributed engine).
    """
    n = adj.shape[0]
    dist = jnp.full((n,), inf, dtype=jnp.float32).at[src].set(0.0)

    def body(_: int, d: jnp.ndarray) -> jnp.ndarray:
        return minplus_spmv(adj, d, inf=inf, block=block)

    return jax.lax.fori_loop(0, k, body, dist)


# ---------------------------------------------------------------------------
# IDX-DFS frontier expansion (device-resident enumeration, DESIGN.md §9)
# ---------------------------------------------------------------------------

def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length() if x > 1 else 1


def _children(paths: jnp.ndarray, vflat: jnp.ndarray, idxs: jnp.ndarray,
              depth: jnp.ndarray, max_deg: int) -> jnp.ndarray:
    """Materialize child rows for the compacted candidate indices: gather
    each candidate's parent row and write its vertex at column depth+1."""
    rows = jnp.take(paths, idxs // max_deg, axis=0)          # (cap, k1)
    col = jax.lax.broadcasted_iota(jnp.int32, rows.shape, 1)
    return jnp.where(col == depth + 1, jnp.take(vflat, idxs)[:, None], rows)


@functools.partial(jax.jit,
                   static_argnames=("max_deg", "interpret", "use_ref",
                                    "want_cont"))
def _frontier_expand_jit(
        paths: jnp.ndarray, begin: jnp.ndarray, end: jnp.ndarray,
        dst: jnp.ndarray, meta: jnp.ndarray, *, max_deg: int,
        interpret: bool, use_ref: bool, want_cont: bool
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
           jnp.ndarray]:
    """Masks (Pallas kernel or jnp ref) + compaction, one fused jit."""
    C, k1 = paths.shape
    depth = meta[0]
    b = jnp.clip(k1 - 2 - depth, 0, k1 - 1)   # budget k - depth - 1
    endb = jnp.take(end, b, axis=1)
    if use_ref:
        vnew, emit, cont, counters = ref.frontier_masks_ref(
            paths, begin, endb, dst, depth, meta[1], max_deg, PAD)
    else:
        vnew, emit, cont, counters = _frontier_pallas(
            paths, begin, endb, dst, meta, max_deg=max_deg,
            interpret=interpret)
    cap = C * max_deg
    vflat = vnew.reshape(-1)
    flat_emit = emit.reshape(-1) != 0
    eidx = jnp.nonzero(flat_emit, size=cap, fill_value=0)[0]
    emit_rows = _children(paths, vflat, eidx, depth, max_deg)
    n_emit = jnp.sum(flat_emit.astype(jnp.int32))
    if want_cont:
        flat_cont = cont.reshape(-1) != 0
        cidx = jnp.nonzero(flat_cont, size=cap, fill_value=0)[0]
        cont_rows = _children(paths, vflat, cidx, depth, max_deg)
        n_cont = jnp.sum(flat_cont.astype(jnp.int32))
    else:
        # last hop: survivors can never extend, so skip the (cap, k+1)
        # gather the caller would discard (counters still see them)
        cont_rows = paths[:0]
        n_cont = jnp.int32(0)
    return emit_rows, cont_rows, n_emit, n_cont, counters


def frontier_expand(
        paths: np.ndarray | jnp.ndarray, fwd_begin: np.ndarray,
        fwd_end: np.ndarray, fwd_dst: np.ndarray, *, depth: int,
        t: int, max_deg: int, want_cont: bool = True
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
           jnp.ndarray]:
    """One IDX-DFS hop for a whole chunk, on device (DESIGN.md §9).

    paths is the (rows, k+1) int32 partial-path matrix at ``depth`` (PAD
    past the depth column); fwd_begin (n,) / fwd_end (n, k+1) / fwd_dst
    (mf,) are the int32 index arrays (``LightweightIndex.device_arrays``).
    ``max_deg`` is the chunk's max fan-out (callers read it off the host
    offset arrays; it must be ≥ 1 — zero-fanout chunks are the host
    driver's shortcut).

    Returns ``(emit_rows, cont_rows, n_emit, n_cont, counters)`` — all
    device-resident: the first ``n_emit`` rows of ``emit_rows`` are the
    completed paths (t written at depth+1) in exact host emission order,
    the first ``n_cont`` rows of ``cont_rows`` the surviving partials,
    and ``counters`` the (4,) int32 ``[edges_accessed,
    partials_generated, invalid_partials, 0]`` Fig.-6 scalars matching
    the host ``EnumStats`` deltas bit-for-bit.  ``want_cont=False``
    (the last hop, where survivors cannot extend) skips the continue
    compaction and returns an empty ``cont_rows`` with ``n_cont == 0``;
    counters are unaffected.

    Shapes are bucketed to powers of two (rows and fan-out) to bound jit
    recompiles; padded rows are PAD and inert.  ``REPRO_PALLAS=off``
    routes the mask stage to the pure-jnp reference.

    Ranked enumeration (DESIGN.md §10) reuses this kernel *unchanged*:
    the rank-bucketed driver (core/enumerate._drive_ranked_buckets)
    decides which chunks to expand and in what order — one hop-bound
    bucket at a time — but each launch is the same hop this docstring
    describes.  Rank awareness lives entirely in host scheduling.
    """
    paths = np.asarray(paths, dtype=np.int32)
    rows, k1 = paths.shape
    assert depth + 2 <= k1, f"depth {depth} leaves no column for the hop"
    assert max_deg >= 1, "zero-fanout chunks never reach the device"
    C = _next_pow2(max(rows, 8))
    if C != rows:
        paths = np.pad(paths, ((0, C - rows), (0, 0)), constant_values=PAD)
    meta = jnp.asarray([depth, t], jnp.int32)
    _count_dispatch()
    return _frontier_expand_jit(
        jnp.asarray(paths), jnp.asarray(fwd_begin), jnp.asarray(fwd_end),
        jnp.asarray(fwd_dst), meta, max_deg=_next_pow2(max_deg),
        interpret=_interpret(), use_ref=not _enabled(),
        want_cont=want_cont)


def _children_fused(paths: jnp.ndarray, vflat: jnp.ndarray,
                    idxs: jnp.ndarray, depth_rows: jnp.ndarray,
                    max_deg: int) -> jnp.ndarray:
    """`_children` with a per-parent-row depth vector (fused launches mix
    members whose chunks sit at different depths)."""
    parents = idxs // max_deg
    rows = jnp.take(paths, parents, axis=0)                  # (cap, k1)
    col = jax.lax.broadcasted_iota(jnp.int32, rows.shape, 1)
    dsel = jnp.take(depth_rows, parents)
    return jnp.where(col == dsel[:, None] + 1,
                     jnp.take(vflat, idxs)[:, None], rows)


@functools.partial(jax.jit,
                   static_argnames=("max_deg", "interpret", "use_ref"))
def _frontier_fused_jit(
        paths: jnp.ndarray, rank: jnp.ndarray, tvec: jnp.ndarray,
        depthv: jnp.ndarray, begin: jnp.ndarray, endb: jnp.ndarray,
        dst: jnp.ndarray, wantc: jnp.ndarray, *, max_deg: int,
        interpret: bool, use_ref: bool
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
           jnp.ndarray]:
    """Fused masks (Pallas kernel or jnp ref) + compaction, one jit.

    Compaction runs over the *flat* candidate order (row-major), and the
    wrapper packs rows member-rank-ascending, so the compacted emit and
    cont matrices are per-member contiguous segments in each member's
    exact solo emission order — the host slices them apart with the
    per-member counts.  Last-hop continue suppression happens HERE (the
    ``wantc`` per-member mask), after the kernel: the kernel always
    computes the full cont mask so dead-row and counter accounting
    matches the single-query kernel bit-for-bit.
    """
    C, _k1 = paths.shape
    m = tvec.shape[0]
    if use_ref:
        vnew, emit, cont, counters = ref.frontier_fused_masks_ref(
            paths, rank, tvec, depthv, begin, endb, dst, max_deg, PAD)
    else:
        vnew, emit, cont, counters = _frontier_fused_pallas(
            paths, rank, tvec, depthv, begin, endb, dst,
            max_deg=max_deg, interpret=interpret)
    cap = C * max_deg
    vflat = vnew.reshape(-1)
    rankflat = jnp.repeat(rank, max_deg)
    depth_rows = jnp.take(depthv, rank)
    flat_emit = emit.reshape(-1) != 0
    eidx = jnp.nonzero(flat_emit, size=cap, fill_value=0)[0]
    emit_rows = _children_fused(paths, vflat, eidx, depth_rows, max_deg)
    n_emit_m = jnp.zeros((m,), jnp.int32).at[rankflat].add(
        flat_emit.astype(jnp.int32))
    flat_cont = (cont.reshape(-1) != 0) & jnp.take(wantc, rankflat)
    cidx = jnp.nonzero(flat_cont, size=cap, fill_value=0)[0]
    cont_rows = _children_fused(paths, vflat, cidx, depth_rows, max_deg)
    n_cont_m = jnp.zeros((m,), jnp.int32).at[rankflat].add(
        flat_cont.astype(jnp.int32))
    return emit_rows, cont_rows, n_emit_m, n_cont_m, counters


def frontier_expand_fused(
        paths: np.ndarray, rank: np.ndarray, tvec: np.ndarray,
        depthv: np.ndarray, begin: jnp.ndarray, endb: jnp.ndarray,
        dst: jnp.ndarray, wantc: np.ndarray, *, max_deg: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
           jnp.ndarray]:
    """One fused IDX-DFS hop for chunks of many queries (DESIGN.md §9).

    ``paths`` (rows, k1max) int32 packs one chunk per member, rows in
    ascending member order, each member's rows at its own common depth
    (columns past a member's own k+1 stay PAD); ``rank`` (rows,) int32
    tags each row's member; ``tvec``/``depthv`` (m,) int32 carry each
    member's target and chunk depth; ``begin``/``endb`` (m·n,) int32 are
    the flattened per-member offset tables (``endb`` pre-sliced to each
    member's budget column b = k − depth − 1); ``dst`` (m·mfm,) int32
    the flattened adjacency slabs (PAD-padded to the common ``mfm``);
    ``wantc`` (m,) bool is per-member ``want_cont`` (False on a member's
    last hop — suppression happens after the kernel so counters still
    see the candidates, exactly like the single-query path).

    Returns ``(emit_rows, cont_rows, n_emit_m, n_cont_m, counters)``:
    emit/cont row matrices in flat order (member-contiguous — slice
    member i's segment with the exclusive cumsum of ``n_emit_m`` /
    ``n_cont_m``), and ``counters`` the (m, 4) per-member Fig.-6 rows.
    All device-resident; one kernel dispatch per call.
    """
    paths = np.asarray(paths, dtype=np.int32)
    rows, _k1 = paths.shape
    assert max_deg >= 1, "zero-fanout chunks never reach the device"
    C = _next_pow2(max(rows, 8))
    if C != rows:
        paths = np.pad(paths, ((0, C - rows), (0, 0)), constant_values=PAD)
        rank = np.pad(np.asarray(rank, np.int32), (0, C - rows))
    _count_dispatch()
    return _frontier_fused_jit(
        jnp.asarray(paths), jnp.asarray(rank, dtype=jnp.int32),
        jnp.asarray(tvec, dtype=jnp.int32),
        jnp.asarray(depthv, dtype=jnp.int32), begin, endb, dst,
        jnp.asarray(wantc, dtype=bool), max_deg=_next_pow2(max_deg),
        interpret=_interpret(), use_ref=not _enabled())


# ---------------------------------------------------------------------------
# Device-resident work deque (DESIGN.md §9): the IDX-DFS chunk stack
# lives in a device arena, and one jit'd while_loop pops/expands/pushes
# many chunks per host round-trip — the host syncs only to drain emitted
# paths and check the cooperative deadline.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DequeConfig:
    """Static geometry of the device-resident work deque.

    The arena is a row stack: live chunk rows occupy ``[0, top)`` and
    chunk ``j`` (meta slot ``j``, bottom to top) spans the rows between
    the cumulative lengths of its predecessors; pops read from the top,
    pushes scatter continue pieces back so the solo driver's reversed
    piece order is preserved (piece 0 topmost).  All capacities are
    static so one jit serves every round; the rows past ``arena_cap``
    (and the meta slots past ``max_chunks``) are scratch targets for
    masked scatters and are never read back.
    """
    k1: int              # path width k + 1
    chunk_size: int      # the driver's chunk split (cs)
    block_rows: int      # B: pow2 row height of one pop (>= chunk_size)
    max_deg: int         # pow2 fan-out bound of the whole index
    cap: int             # block_rows * max_deg candidate slots
    arena_cap: int       # live arena rows (stack region)
    arena_rows: int      # arena_cap + cap (scratch tail)
    emit_cap: int        # emitted rows one round may buffer
    max_chunks: int      # live meta slots
    max_pieces: int      # pow-bound on pieces one push can create
    round_pops: int      # pops per host round-trip


def deque_config(k1: int, chunk_size: int, max_deg: int,
                 round_pops: int = 64) -> DequeConfig:
    """Size a ``DequeConfig`` for one index/driver combination."""
    B = _next_pow2(max(chunk_size, 8))
    md = _next_pow2(max(max_deg, 1))
    cap = B * md
    arena_cap = max(8 * cap, 4 * B)
    emit_cap = max(4 * cap, 4 * B)
    maxp = cap // max(chunk_size, 1) + 2
    maxc = max(4096, 8 * maxp)
    return DequeConfig(k1=k1, chunk_size=chunk_size, block_rows=B,
                       max_deg=md, cap=cap, arena_cap=arena_cap,
                       arena_rows=arena_cap + cap, emit_cap=emit_cap,
                       max_chunks=maxc, max_pieces=maxp,
                       round_pops=round_pops)


def frontier_deque_init(root: np.ndarray, *, cfg: DequeConfig
                        ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                   jnp.ndarray, jnp.ndarray]:
    """Fresh deque state holding one root chunk (the (k+1,) root row)."""
    arena = jnp.full((cfg.arena_rows, cfg.k1), PAD, jnp.int32)
    arena = arena.at[0].set(jnp.asarray(root, jnp.int32))
    meta_depth = jnp.zeros((cfg.max_chunks + cfg.max_pieces,), jnp.int32)
    meta_len = meta_depth.at[0].set(1)
    return arena, meta_depth, meta_len, jnp.int32(1), jnp.int32(1)


@functools.partial(jax.jit, static_argnames=("cfg", "interpret", "use_ref"))
def _deque_round_jit(
        arena: jnp.ndarray, meta_depth: jnp.ndarray, meta_len: jnp.ndarray,
        top: jnp.ndarray, n_chunks: jnp.ndarray, begin: jnp.ndarray,
        end: jnp.ndarray, dst: jnp.ndarray, t: jnp.ndarray, *,
        cfg: DequeConfig, interpret: bool, use_ref: bool
) -> tuple[jnp.ndarray, ...]:
    """One device round: a while_loop of in-arena pop → expand → push.

    Each iteration pops the top chunk, runs the mask stage (Pallas
    kernel or the jnp ref oracle), appends completed paths to the
    round's emit buffer, and scatters the surviving partials back into
    the arena as ``chunk_size`` pieces in the solo driver's reversed
    piece order — so the pop sequence, the chunk split and therefore
    every Fig.-6 counter are bit-identical to the host-looped device
    path.  The loop stops at ``round_pops``, an empty deque, or a
    conservative capacity guard (arena/emit/meta margin smaller than
    one worst-case push) — the host detects the zero-pop stall and
    rebuilds its own work list from the arena.
    """
    cs = cfg.chunk_size
    cap = cfg.cap
    B = cfg.block_rows
    k1 = cfg.k1

    def cond(state: tuple[jnp.ndarray, ...]) -> jnp.ndarray:
        _a, _md, _ml, s_top, s_nc, _eb, _el, s_ne, _c, s_pops = state
        return ((s_nc > 0) & (s_pops < cfg.round_pops)
                & (s_top + cap <= cfg.arena_cap)
                & (s_ne + cap <= cfg.emit_cap)
                & (s_nc + cfg.max_pieces <= cfg.max_chunks))

    def body(state: tuple[jnp.ndarray, ...]) -> tuple[jnp.ndarray, ...]:
        s_arena, s_md, s_ml, s_top, s_nc, s_eb, s_el, s_ne, s_ctr, \
            s_pops = state
        cidx = s_nc - 1
        clen = s_ml[cidx]
        cdepth = s_md[cidx]
        cstart = s_top - clen
        block = jax.lax.dynamic_slice(s_arena, (cstart, jnp.int32(0)),
                                      (B, k1))
        rowid = jnp.arange(B, dtype=jnp.int32)
        paths = jnp.where((rowid < clen)[:, None], block, PAD)
        s_top = cstart
        s_nc = cidx
        s_pops = s_pops + 1

        b = jnp.clip(k1 - 2 - cdepth, 0, k1 - 1)
        endb = jnp.take(end, b, axis=1)
        if use_ref:
            vnew, emit, cont, ctr1 = ref.frontier_masks_ref(
                paths, begin, endb, dst, cdepth, t, cfg.max_deg, PAD)
        else:
            meta = jnp.stack([cdepth, t]).astype(jnp.int32)
            vnew, emit, cont, ctr1 = _frontier_pallas(
                paths, begin, endb, dst, meta, max_deg=cfg.max_deg,
                interpret=interpret)
        s_ctr = s_ctr + ctr1
        vflat = vnew.reshape(-1)

        flat_emit = emit.reshape(-1) != 0
        eidx = jnp.nonzero(flat_emit, size=cap, fill_value=0)[0]
        echild = _children(paths, vflat, eidx, cdepth, cfg.max_deg)
        ne_new = jnp.sum(flat_emit.astype(jnp.int32))
        s_eb = jax.lax.dynamic_update_slice(s_eb, echild,
                                            (s_ne, jnp.int32(0)))
        s_el = jax.lax.dynamic_update_slice(
            s_el, jnp.full((cap,), cdepth + 1, jnp.int32), (s_ne,))
        s_ne = s_ne + ne_new

        # push: scatter cont children so piece 0 lands on top (the solo
        # driver pushes pieces reversed) with intra-piece order intact
        wantc = cdepth + 1 < jnp.int32(k1 - 1)
        flat_cont = (cont.reshape(-1) != 0) & wantc
        n_cont = jnp.sum(flat_cont.astype(jnp.int32))
        crank = jnp.cumsum(flat_cont.astype(jnp.int32)) - 1
        piece = crank // cs
        np_pieces = (n_cont + cs - 1) // cs
        dest = (s_top + n_cont - jnp.minimum((piece + 1) * cs, n_cont)
                + (crank - piece * cs))
        dest = jnp.where(flat_cont, dest,
                         cfg.arena_cap + jnp.arange(cap, dtype=jnp.int32))
        children = _children(paths, vflat,
                             jnp.arange(cap, dtype=jnp.int32), cdepth,
                             cfg.max_deg)
        s_arena = s_arena.at[dest].set(children)
        pj = jnp.arange(cfg.max_pieces, dtype=jnp.int32)
        valid_p = pj < np_pieces
        slot = jnp.where(valid_p, s_nc + np_pieces - 1 - pj,
                         cfg.max_chunks + pj)
        s_md = s_md.at[slot].set(cdepth + 1)
        s_ml = s_ml.at[slot].set(jnp.clip(n_cont - pj * cs, 0, cs))
        s_top = s_top + n_cont
        s_nc = s_nc + np_pieces
        return (s_arena, s_md, s_ml, s_top, s_nc, s_eb, s_el, s_ne,
                s_ctr, s_pops)

    emitbuf = jnp.full((cfg.emit_cap + cap, k1), PAD, jnp.int32)
    emitlen = jnp.zeros((cfg.emit_cap + cap,), jnp.int32)
    state0 = (arena, meta_depth, meta_len, top, n_chunks, emitbuf,
              emitlen, jnp.int32(0), jnp.zeros((4,), jnp.int32),
              jnp.int32(0))
    return jax.lax.while_loop(cond, body, state0)


def frontier_deque_round(
        arena: jnp.ndarray, meta_depth: jnp.ndarray, meta_len: jnp.ndarray,
        top: jnp.ndarray, n_chunks: jnp.ndarray, begin: jnp.ndarray,
        end: jnp.ndarray, dst: jnp.ndarray, t: int, *, cfg: DequeConfig
) -> tuple[jnp.ndarray, ...]:
    """One host round-trip of the device-resident deque (DESIGN.md §9).

    Runs up to ``cfg.round_pops`` pop→expand→push iterations entirely on
    device and returns the updated deque state plus the round's outputs:
    ``(arena, meta_depth, meta_len, top, n_chunks, emitbuf, emitlen,
    n_emit, counters, pops)``.  The first ``n_emit`` rows of ``emitbuf``
    are the paths completed this round (``emitlen`` their hop counts);
    ``counters`` is the summed (4,) Fig.-6 vector and ``pops`` the
    number of chunks consumed (the driver's ``stats.chunks`` delta).  A
    round returning ``pops == 0`` with ``n_chunks > 0`` is a capacity
    stall: the caller rebuilds its host work list from ``arena[:top]``
    and the bottom ``n_chunks`` meta slots and resumes the host-looped
    driver.  ``REPRO_PALLAS=off`` routes the mask stage to the ref
    oracle; counted as one device dispatch per round.
    """
    _count_dispatch()
    return _deque_round_jit(arena, meta_depth, meta_len, top, n_chunks,
                            begin, end, dst, jnp.asarray(t, jnp.int32),
                            cfg=cfg, interpret=_interpret(),
                            use_ref=not _enabled())


# ---------------------------------------------------------------------------
# LM attention ops
# ---------------------------------------------------------------------------

def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, bq: int = 128,
                    bk: int = 128) -> jnp.ndarray:
    if not _enabled():
        return ref.mha_ref(q, k, v, causal=causal, scale=scale, window=window)
    B, Lq, H, D = q.shape
    _, Lk, Hkv, _ = k.shape
    bq_eff = min(bq, max(8, Lq))
    bk_eff = min(bk, max(8, Lk))
    needs_pad = (Lq % bq_eff != 0) or (Lk % bk_eff != 0)
    if needs_pad and (not causal or Lq != Lk):
        # Padding shifts the causal diagonal when Lq != Lk; production
        # shapes (4k/32k/500k) are tile-aligned so this fallback only
        # serves ragged test shapes.
        return ref.mha_ref(q, k, v, causal=causal, scale=scale, window=window)
    if needs_pad:
        # Lq == Lk: pad both ends equally.  Padded KV columns sit past every
        # real row index so the causal mask removes them; padded Q rows are
        # sliced off below.
        q = _pad_to(q, 1, bq_eff, 0)
        k = _pad_to(k, 1, bk_eff, 0)
        v = _pad_to(v, 1, bk_eff, 0)
        if q.shape[1] != k.shape[1]:
            pad_len = max(q.shape[1], k.shape[1])
            q = _pad_to(q, 1, pad_len, 0)
            k = _pad_to(k, 1, pad_len, 0)
            v = _pad_to(v, 1, pad_len, 0)
    out = _flash_pallas(q, k, v, causal=causal, window=window,
                        scale=scale, bq=bq_eff, bk=bk_eff,
                        interpret=_interpret())
    return out[:, :Lq]


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, lengths: jnp.ndarray, *,
                     scale: float | None = None,
                     bs: int = 512) -> jnp.ndarray:
    if not _enabled():
        return ref.decode_attention_ref(q, k_cache, v_cache, lengths,
                                        scale=scale)
    B, S, Hkv, D = k_cache.shape
    bs_eff = min(bs, max(8, S))
    k_p = _pad_to(k_cache, 1, bs_eff, 0)
    v_p = _pad_to(v_cache, 1, bs_eff, 0)
    return _decode_pallas(q, k_p, v_p, lengths, scale=scale, bs=bs_eff,
                          interpret=_interpret())
