"""Pallas TPU kernels for the perf-critical hot spots (DESIGN.md §5, §9).

semiring_spmm   — PathEnum BFS relaxation (min-plus) + walk-count DP (+,×)
frontier_expand — IDX-DFS frontier expansion (Algorithm 4's hot loop)
flash_attention — blocked online-softmax GQA attention (train/prefill)
decode_attention— single-token GQA decode over long KV caches

Validated on CPU via interpret=True against the pure-jnp oracles in ref.py.
"""
from . import ops, ref
from .ops import (bfs_dense, counting_spmm, decode_attention, flash_attention,
                  frontier_expand, minplus_spmv)
