"""Production mesh construction (dry-run target: TPU v5e pods).

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax call.
"""
from __future__ import annotations

import jax

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """(16, 16) data×model single pod; (2, 16, 16) pod×data×model for 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (CPU tests / single host)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel),
                     ("data", "model"))


HARDWARE = {
    # TPU v5e per chip
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bandwidth": 819e9,      # B/s
    "ici_bandwidth": 50e9,       # B/s per link
    "hbm_bytes": 16e9,
}
