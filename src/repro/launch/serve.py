"""Serving launcher: batched decode over a (reduced) arch config.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3p2_1b \
      --requests 8 --max-tokens 12
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3p2_1b")
    ap.add_argument("--preset", default="reduced", choices=["reduced", "full"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-tokens", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    from ..configs import get_arch
    from ..models import transformer
    from ..serving.engine import Request, ServeEngine

    cfg = get_arch(args.arch)
    if args.preset == "reduced":
        cfg = cfg.reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_slots=args.slots,
                         max_len=args.max_len,
                         temperature=args.temperature)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for uid in range(args.requests):
        prompt = rng.integers(3, cfg.vocab, size=rng.integers(2, 8))
        engine.submit(Request(uid=uid, prompt=prompt.astype(np.int32),
                              max_tokens=args.max_tokens))
    results = engine.run()
    wall = time.time() - t0
    toks = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {toks} tokens in {wall:.2f}s "
          f"({toks/max(wall,1e-9):.1f} tok/s, {engine.steps_run} engine steps)")
    for uid in sorted(results):
        print(f"  req {uid}: {results[uid]}")


if __name__ == "__main__":
    main()
