import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces (written incrementally to
experiments/dryrun/<cell>.json):
  * memory_analysis  — per-device argument/output/temp bytes (fits HBM?)
  * cost_analysis    — HLO flops / bytes accessed (per device, SPMD module)
  * collective bytes — summed operand sizes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute
                       parsed from the compiled HLO (per device)
  * the sharding decisions actually taken (kv_shard fallbacks etc.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2_780m \
      --shape long_500k --mesh multi
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import numpy as np

from ..compat import set_mesh


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"\s(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|"
                       r"f64|c64|c128)\[([0-9,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_V1_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _line_group_size(line: str) -> int:
    m = _GROUP_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUP_V1_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device *operand* bytes per collective kind, from the partitioned
    HLO.  Post-opt HLO prints operands as bare %refs, so sizes come from the
    result type: operand == result for all-reduce / all-to-all / permute;
    result/group for all-gather; result*group for reduce-scatter.  Also
    records ring-model wire bytes (what actually crosses ICI per device):
    ag/rs ≈ operand*(g-1) resp. result*(g-1); ar ≈ 2*operand*(g-1)/g.
    """
    out: Dict[str, float] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        lhs = line[:m.start()]
        if "=" not in lhs:
            continue
        kind = m.group(1)
        # result type(s) sit between '=' and the op name; tuple types may
        # carry /*index=N*/ comments, so just collect every dtype[shape]
        restype = lhs.split("=", 1)[1]
        rbytes = 0
        for sm in _SHAPE_RE.finditer(restype):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            rbytes += n * _DTYPE_BYTES[dt]
        g = max(_line_group_size(line), 1)
        if kind == "all-gather":
            operand = rbytes / g
            wire += operand * (g - 1)
        elif kind == "reduce-scatter":
            operand = rbytes * g
            wire += rbytes * (g - 1)
        elif kind == "all-reduce":
            operand = rbytes
            wire += 2.0 * rbytes * (g - 1) / g
        else:  # all-to-all / collective-permute
            operand = rbytes
            wire += rbytes
        out[kind] = out.get(kind, 0) + operand
    out["total_operand"] = sum(v for k, v in out.items())
    out["wire_bytes"] = wire
    return out


def spec_tree_to_json(specs) -> Any:
    return jax.tree.map(
        lambda s: str(s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def _costs_of(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    out = {"flops": ca.get("flops", 0.0),
           "bytes": ca.get("bytes accessed", 0.0),
           "transcendentals": ca.get("transcendentals", 0.0)}
    for k, v in coll.items():
        out[f"coll/{k}"] = v
    return out


def extrapolate_costs(c1: Dict[str, float], c2: Dict[str, float],
                      ns: int) -> Dict[str, float]:
    """Layer-linear cost model: f(ns) = f(1) + (ns-1)·(f(2)-f(1)).

    XLA cost analysis counts while-loop bodies once (verified empirically),
    so scanned production lowerings undercount per-layer work.  The
    analysis twins unroll 1 and 2 super-blocks (identical math, Python
    layer loop, single-chunk attention); their difference is exactly one
    super-block's true cost, and the stack is homogeneous by construction.
    """
    out = {}
    for k in c1:
        body = max(c2.get(k, 0.0) - c1[k], 0.0)
        out[k] = c1[k] + (ns - 1) * body
    return out


def analyze_memory(compiled) -> Dict[str, Any]:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_estimate_bytes": ma.argument_size_in_bytes
        + ma.output_size_in_bytes + ma.temp_size_in_bytes
        - ma.alias_size_in_bytes,
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             microbatches: int = 1, out_dir: str = "experiments/dryrun",
             attn_chunk: int | None = None,
             seq_shard: bool = False,
             unroll_accum: bool = False) -> Dict[str, Any]:
    from ..configs import get_arch, get_shape
    from ..distributed import sharding as shard_mod
    from ..launch import specs as specs_mod
    from ..launch.mesh import make_production_mesh
    from ..optim import adamw
    from ..training import step as step_mod
    import dataclasses

    cfg = get_arch(arch)
    if attn_chunk:
        cfg = dataclasses.replace(cfg, attn_chunk=attn_chunk)
    if seq_shard:
        cfg = dataclasses.replace(cfg, seq_shard_activations=True)
    shape = get_shape(shape_name)
    supported, reason = cfg.shape_supported(shape)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "microbatches": microbatches,
        "params_B": cfg.param_count() / 1e9,
        "active_params_B": cfg.active_param_count() / 1e9,
    }
    if not supported:
        record["status"] = "skipped"
        record["reason"] = reason
        return record

    from ..models.transformer import layer_plan

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    record["chips"] = chips
    rules = shard_mod.ShardingRules(mesh)

    def build(c):
        params_t = specs_mod.param_specs(c)
        pspecs = shard_mod.tree_specs(params_t, rules.param_spec)
        pshard = shard_mod.tree_shardings(mesh, pspecs)
        inputs = specs_mod.input_specs(c, shape)
        if shape.kind == "train":
            opt_t = specs_mod.opt_specs(params_t)
            oshard = shard_mod.tree_shardings(
                mesh, shard_mod.opt_shardings(pspecs, opt_t))
            bshard = shard_mod.tree_shardings(
                mesh, shard_mod.tree_specs(inputs["batch"],
                                           rules.batch_spec))
            fn = step_mod.make_train_step(c, adamw.OptimizerConfig(),
                                          microbatches=microbatches,
                                          unroll_accum=unroll_accum)
            jf = jax.jit(fn, in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
            return jf.lower(params_t, opt_t, inputs["batch"]), pspecs
        if shape.kind == "prefill":
            bshard = shard_mod.tree_shardings(
                mesh, shard_mod.tree_specs(inputs["batch"],
                                           rules.batch_spec))
            fn = step_mod.make_prefill(c)
            jf = jax.jit(fn, in_shardings=(pshard, bshard))
            return jf.lower(params_t, inputs["batch"]), pspecs
        cshard = shard_mod.tree_shardings(
            mesh, shard_mod.tree_specs(inputs["cache"], rules.cache_spec))
        bshard_tok = shard_mod.tree_shardings(
            mesh, shard_mod.tree_specs(inputs["token"], rules.batch_spec))
        lenshard = shard_mod.tree_shardings(
            mesh, shard_mod.tree_specs(inputs["cache_len"],
                                       rules.batch_spec))
        fn = step_mod.make_serve_step(c)
        jf = jax.jit(
            fn,
            in_shardings=(pshard, bshard_tok, cshard, lenshard, None),
            out_shardings=(bshard_tok, cshard, None),
            donate_argnums=(2,))
        return jf.lower(params_t, inputs["token"], inputs["cache"],
                        inputs["cache_len"], inputs["rng"]), pspecs

    # analysis twins: unrolled 1- and 2-super stacks (identical per-layer
    # math, Python layer loop, single-chunk attention); per-super costs
    # extrapolate linearly — see extrapolate_costs.
    pat, ns, tail = layer_plan(cfg)
    an_chunk = max(cfg.attn_chunk, shape.seq_len)
    cfg1 = dataclasses.replace(cfg, unroll=True, attn_chunk=an_chunk,
                               num_layers=len(pat) + len(tail))
    cfg2 = dataclasses.replace(cfg, unroll=True, attn_chunk=an_chunk,
                               num_layers=2 * len(pat) + len(tail))

    t0 = time.time()
    with set_mesh(mesh):
        lowered, pspecs = build(cfg)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        t0 = time.time()
        try:
            c1 = _costs_of(build(cfg1)[0].compile())
            c2 = _costs_of(build(cfg2)[0].compile())
            costs = extrapolate_costs(c1, c2, ns)
            if microbatches > 1:
                # the microbatch scan body (one microbatch, all layers) is
                # counted once — identical microbatches scale linearly; the
                # optimizer-update tail is over-scaled by the same factor
                # (small vs per-microbatch work, noted in EXPERIMENTS.md).
                costs = {k: v * microbatches for k, v in costs.items()}
            record["cost_source"] = "unrolled-extrapolated"
        except Exception as e:  # noqa: BLE001 — fall back to scan costs
            costs = _costs_of(compiled)
            record["cost_source"] = "scan(undercounted)"
            record["analysis_error"] = repr(e)[:300]
        t_analysis = time.time() - t0

    record["memory"] = analyze_memory(compiled)
    record["cost"] = {
        "flops_per_device": costs["flops"],
        "bytes_accessed_per_device": costs["bytes"],
        "transcendentals": costs["transcendentals"],
    }
    record["collectives_per_device_bytes"] = {
        k.split("/", 1)[1]: v for k, v in costs.items()
        if k.startswith("coll/")}
    record["status"] = "ok"
    record["lower_seconds"] = round(t_lower, 2)
    record["compile_seconds"] = round(t_compile, 2)
    record["analysis_compile_seconds"] = round(t_analysis, 2)
    record["param_spec_sample"] = {
        "embed": str(jax.tree.leaves(
            jax.tree.map(str, spec_tree_to_json(pspecs)))[0]),
    }
    # GQA fallback visibility
    record["kv_shard"] = ("heads" if cfg.kv_heads % 16 == 0 else "head_dim")
    return record


ALL_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-parallel residuals (§Perf lever)")
    ap.add_argument("--unroll-accum", action="store_true",
                    help="Python-loop microbatch accumulation (partitioner "
                         "workaround for vocab-fallback archs)")
    ap.add_argument("--suffix", default="",
                    help="output-file suffix for hillclimb variants")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    from ..configs import ARCH_IDS

    os.makedirs(args.out, exist_ok=True)
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = ALL_SHAPES if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                cell = f"{arch}__{shape}__{mesh_kind}{args.suffix}"
                path = os.path.join(args.out, cell + ".json")
                if os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            print(f"[skip-cached] {cell}")
                            continue
                t0 = time.time()
                try:
                    rec = run_cell(arch, shape, mesh_kind,
                                   microbatches=args.microbatches,
                                   out_dir=args.out,
                                   seq_shard=args.seq_shard,
                                   unroll_accum=args.unroll_accum)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-2000:]}
                    failures += 1
                rec["variant"] = args.suffix.lstrip("_") or "baseline"
                rec["wall_seconds"] = round(time.time() - t0, 2)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2, default=float)
                print(f"[{rec['status']:7s}] {cell} "
                      f"({rec['wall_seconds']}s)", flush=True)
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
