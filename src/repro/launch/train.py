"""End-to-end training launcher.

CPU-scale driver for the reduced/medium configs plus the mesh plumbing the
pod launcher uses (the full configs go through dryrun.py — this entry point
actually executes steps).

  PYTHONPATH=src python -m repro.launch.train --arch llama3p2_1b \
      --preset reduced --steps 50 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --preset lm100m --steps 200 \
      --data path_corpus        # trains on PathEnum-generated paths
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time


def build_arch(args):
    from ..configs import get_arch
    from ..configs.base import ArchConfig

    if args.preset == "lm100m":
        # ~100M-param llama-style model for the end-to-end example
        return ArchConfig(
            name="lm100m", family="dense", num_layers=8, d_model=1024,
            num_heads=16, kv_heads=4, d_ff=2816, vocab=16384, head_dim=64,
            attn_chunk=256, tie_embeddings=True)  # ≈107M params
    cfg = get_arch(args.arch)
    if args.preset == "reduced":
        cfg = cfg.reduced()
    return cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3p2_1b")
    ap.add_argument("--preset", default="reduced",
                    choices=["reduced", "full", "lm100m"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", default="synthetic",
                    choices=["synthetic", "path_corpus"])
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args()

    import jax
    from ..data.pipeline import PathCorpus, SyntheticLM
    from ..optim import adamw
    from ..training.trainer import Trainer, TrainerConfig

    cfg = build_arch(args)
    if args.data == "path_corpus":
        from ..core.graph import power_law
        g = power_law(2000, 6.0, seed=1)
        data = PathCorpus(graph=g, k=5, seq_len=args.seq,
                          global_batch=args.batch)
        cfg = dataclasses.replace(cfg, vocab=max(cfg.vocab, data.vocab))
    else:
        data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch)

    opt_cfg = adamw.OptimizerConfig(peak_lr=args.lr, warmup_steps=20,
                                    total_steps=args.steps)
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir,
                         microbatches=args.microbatches,
                         log_every=max(1, args.steps // 20))
    trainer = Trainer(cfg, opt_cfg, tcfg)
    t0 = time.time()
    trainer.fit(data)
    wall = time.time() - t0

    n_params = sum(x.size for x in jax.tree.leaves(trainer.init_state()[0]))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M steps={args.steps} "
          f"wall={wall:.1f}s stragglers={trainer.straggler_steps}")
    for rec in trainer.metrics_log:
        print(json.dumps(rec))
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({"config": cfg.name, "params": n_params,
                       "log": trainer.metrics_log}, f, indent=2)


if __name__ == "__main__":
    main()
