"""ShapeDtypeStruct stand-ins for every (arch × shape) cell — the dry-run
never allocates real arrays (weak-type-correct, shardable).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..models import transformer
from ..optim import adamw

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Model inputs for the given cell (train batch / prefill batch /
    decode state)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": SDS((B, S), jnp.int32),
                 "labels": SDS((B, S), jnp.int32)}
        if cfg.frontend != "none":
            batch["prefix_emb"] = SDS((B, cfg.frontend_len, cfg.d_model),
                                      dtype)
        if shape.kind == "prefill":
            batch.pop("labels")
        return {"batch": batch}
    # decode: one new token against a seq_len-deep cache
    cache = jax.eval_shape(
        functools.partial(transformer.init_cache, cfg, B, S, dtype=dtype))
    return {
        "token": SDS((B,), jnp.int32),
        "cache": cache,
        "cache_len": SDS((B,), jnp.int32),
        "rng": SDS((2,), jnp.uint32),
    }


def param_specs(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0),
                                        dtype=dtype))


def opt_specs(params_template):
    return jax.eval_shape(adamw.init, params_template)
