from .manager import CheckpointManager
