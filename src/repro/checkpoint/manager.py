"""Checkpointing + restart for fault tolerance.

Design (multi-thousand-node requirements, DESIGN.md §5):
  * **Atomic**: write to ``<dir>/tmp-<step>`` then rename — a node failure
    mid-save never corrupts the latest checkpoint.
  * **Manifest-driven restart**: ``manifest.json`` records step, data-stream
    position, mesh shape and the tree structure; ``latest_step`` +
    ``restore`` are all a restarted job needs.  The mesh shape in the
    manifest is *advisory*: params are saved unsharded (gathered) host-side,
    so a restart may use a different mesh (elastic re-shard on load — the
    new in_shardings re-partition on device_put).
  * **Emergency save**: ``install_signal_handler`` hooks SIGTERM (the
    preemption signal on TPU pods) to flush a checkpoint before eviction.
  * **Retention**: keep_last bounds disk usage.

Storage is plain .npz per pytree (no external deps in this container);
the Writer abstraction keeps a tensorstore/ocdbt backend pluggable.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import signal
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    flat = {}
    leaves_with_path = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: Dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep_last: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._emergency_cb: Optional[Callable[[], None]] = None

    # ---------------- save ----------------
    def save(self, step: int, trees: Dict[str, Any],
             extra: Optional[Dict[str, Any]] = None) -> str:
        tmp = os.path.join(self.directory, f"tmp-{step}")
        final = os.path.join(self.directory, f"step-{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for name, tree in trees.items():
            np.savez(os.path.join(tmp, f"{name}.npz"), **_flatten(tree))
        manifest = {
            "step": step,
            "saved_at": time.time(),
            "trees": sorted(trees.keys()),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.directory, f"step-{s:010d}"),
                          ignore_errors=True)

    # ---------------- restore ----------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step-"):
                out.append(int(d.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def manifest(self, step: int) -> Dict[str, Any]:
        path = os.path.join(self.directory, f"step-{step:010d}",
                            "manifest.json")
        with open(path) as f:
            return json.load(f)

    def restore(self, step: int, templates: Dict[str, Any]
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        base = os.path.join(self.directory, f"step-{step:010d}")
        out = {}
        for name, template in templates.items():
            with np.load(os.path.join(base, f"{name}.npz")) as z:
                flat = {k: z[k] for k in z.files}
            out[name] = _unflatten(template, flat)
        return out, self.manifest(step)

    # ---------------- fault tolerance ----------------
    def install_signal_handler(self, save_cb: Callable[[], None]):
        """SIGTERM (preemption) -> emergency checkpoint before eviction."""
        self._emergency_cb = save_cb

        def handler(signum, frame):
            if self._emergency_cb is not None:
                self._emergency_cb()
            raise SystemExit(128 + signum)

        signal.signal(signal.SIGTERM, handler)
