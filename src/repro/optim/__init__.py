from . import adamw
