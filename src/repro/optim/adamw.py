"""AdamW + global-norm clip + warmup-cosine schedule (pure pytree impl).

State layout keeps first/second moments in f32 regardless of param dtype
(bf16 training), sharded like the params (the sharding rules in
distributed/sharding.py apply to the state pytree verbatim, which is what
gives ZeRO-style partitioning under FSDP specs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    mult = jnp.where(step < cfg.warmup_steps, warm,
                     cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)
    return cfg.peak_lr * mult


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def update(cfg: OptimizerConfig, grads, state: AdamWState, params
           ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                      state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu), {
        "lr": lr, "grad_norm": gnorm}
