"""HcPE batch serving front-end (DESIGN.md §4).

Request/response dataclasses around core.batch.BatchPathEnum: a server owns
one graph + one engine (whose index LRU persists across batches — the hot
s-t pairs of a production workload keep their indexes warm), turns a list
of ``PathQueryRequest`` into ``PathQueryResponse`` objects, and reports
batch-level serving metrics: latency percentiles, throughput, and cache
reuse.  This is the paper's "online scenario" (§7.1: 1000-query sets,
response time = first results out) expressed as a service API; the LM
serving analogue with continuous batching lives in serving/engine.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.batch import (BatchItem, BatchOutput, BatchPathEnum, BatchTiming,
                          CacheStats)
from ..core.graph import Graph


# Response statuses.  Rejections are *responses*, not exceptions: an
# admission-controlled server must answer every request it saw, and a
# client telling rejected from crashed needs the distinction in-band.
STATUS_OK = "ok"
STATUS_REJECTED_QUEUE_FULL = "rejected_queue_full"
STATUS_REJECTED_QUOTA = "rejected_quota"
STATUS_REJECTED_SHUTDOWN = "rejected_shutdown"


@dataclasses.dataclass
class PathQueryRequest:
    """One HcPE query q(s, t, k) plus serving options.

    ``deadline_ms`` is the per-request SLO (relative to submission).  The
    sync server ignores it; the async front-end (async_server.py) uses it
    for earliest-deadline-first scheduling and the ``slo_met`` flag, and —
    when deadline enforcement is on — as the cooperative enumeration
    budget of its micro-batch.
    """
    uid: int
    s: int
    t: int
    k: int
    count_only: bool = True
    first_n: Optional[int] = None     # response-time mode: first-n results
    deadline_ms: Optional[float] = None


@dataclasses.dataclass
class PathQueryResponse:
    uid: int
    count: int
    paths: Optional[np.ndarray]       # (r, k+1) int32 when materialized
    plan_method: str
    index_cached: bool                # served off the warm index LRU
    deduplicated: bool                # shared an identical in-batch query
    latency_ms: float                 # attributable engine work for this query
    exhausted: bool = True            # False: truncated by first_n / deadline
    status: str = STATUS_OK
    # end-to-end latency split (async front-end; sync leaves queue at 0)
    queue_ms: float = 0.0             # submission -> micro-batch dispatch
    service_ms: float = 0.0           # dispatch -> response ready
    total_ms: float = 0.0             # submission -> response ready
    slo_met: Optional[bool] = None    # None: request carried no deadline

    @property
    def rejected(self) -> bool:
        return self.status != STATUS_OK


@dataclasses.dataclass
class BatchServeReport:
    """Per-batch serving metrics (the paper's Table-3 axes, batch form)."""
    batch_size: int
    distinct_queries: int
    total_results: int
    wall_seconds: float
    throughput_qps: float             # queries / s for the batch
    results_per_second: float
    p50_ms: float
    p90_ms: float
    p99_ms: float
    cache: CacheStats                 # hits/misses/evictions for this batch

    @classmethod
    def from_output(cls, out: BatchOutput) -> "BatchServeReport":
        pct = out.latency_percentiles((50, 90, 99))
        wall = out.timing.total_seconds
        return cls(batch_size=len(out.items),
                   distinct_queries=out.distinct_queries,
                   total_results=out.total_results,
                   wall_seconds=wall,
                   throughput_qps=out.throughput_qps,
                   results_per_second=out.total_results / max(wall, 1e-12),
                   p50_ms=pct["p50_ms"], p90_ms=pct["p90_ms"],
                   p99_ms=pct["p99_ms"], cache=out.cache_stats)


# ---------------------------------------------------------------------------
# Grouping / response assembly — one code path shared by the sync server
# below and the async front-end (async_server.py)
# ---------------------------------------------------------------------------

GroupKey = Tuple[bool, Optional[int]]  # (count_only, first_n)


def request_group_key(req: PathQueryRequest) -> GroupKey:
    """The engine-batch compatibility key: requests sharing it can be
    served by one ``BatchPathEnum.run`` call (the engine takes
    count_only / first_n per batch, not per query).  Both front-ends
    derive their grouping from this one function — extend it here, never
    inline."""
    return (req.count_only, req.first_n)


def group_requests(requests: Sequence[PathQueryRequest],
                   ) -> Dict[GroupKey, List[int]]:
    """Positions of ``requests`` grouped by their serving options;
    positions let the caller reassemble responses in request order."""
    groups: Dict[GroupKey, List[int]] = {}
    for pos, req in enumerate(requests):
        groups.setdefault(request_group_key(req), []).append(pos)
    return groups


def response_from_item(req: PathQueryRequest,
                       item: BatchItem) -> PathQueryResponse:
    """Fold one engine ``BatchItem`` into the wire response for ``req``."""
    return PathQueryResponse(
        uid=req.uid, count=item.result.count,
        paths=None if req.count_only else item.result.paths,
        plan_method=item.plan.method,
        index_cached=item.index_cached,
        deduplicated=item.deduplicated,
        latency_ms=item.latency_seconds * 1e3,
        exhausted=item.result.exhausted)


def rejection_response(req: PathQueryRequest, status: str,
                       queue_ms: float = 0.0) -> PathQueryResponse:
    """An admission-control rejection as a well-formed response."""
    slo_met = False if req.deadline_ms is not None else None
    return PathQueryResponse(
        uid=req.uid, count=0, paths=None, plan_method="none",
        index_cached=False, deduplicated=False, latency_ms=0.0,
        exhausted=False, status=status, queue_ms=queue_ms,
        service_ms=0.0, total_ms=queue_ms, slo_met=slo_met)


class HcPEServer:
    """Batch HcPE serving over one graph.

    Groups requests by their (count_only, first_n) serving options — each
    group is one BatchPathEnum.run — and reassembles responses in request
    order.  The engine (and therefore the index LRU) is shared across
    groups and across serve() calls.  The call blocks until the whole
    batch finishes; for an online workload with per-request SLOs use
    ``AsyncHcPEServer`` (async_server.py), which shares these helpers.
    """

    def __init__(self, graph: Graph, engine: Optional[BatchPathEnum] = None):
        self.graph = graph
        self.engine = engine or BatchPathEnum()

    def serve(self, requests: Sequence[PathQueryRequest],
              ) -> Tuple[List[PathQueryResponse], BatchServeReport]:
        responses: List[Optional[PathQueryResponse]] = [None] * len(requests)
        outputs: List[BatchOutput] = []
        for (count_only, first_n), positions in group_requests(requests).items():
            queries = [(requests[p].s, requests[p].t, requests[p].k)
                       for p in positions]
            out = self.engine.run(self.graph, queries, count_only=count_only,
                                  first_n=first_n)
            outputs.append(out)
            for p, item in zip(positions, out.items):
                resp = response_from_item(requests[p], item)
                resp.service_ms = resp.total_ms = resp.latency_ms
                responses[p] = resp
        report = BatchServeReport.from_output(_merge_outputs(outputs))
        # the per-group sum double-counts a (s,t,k) served under several
        # serving options; the request list is the truth
        report.distinct_queries = len({(r.s, r.t, r.k) for r in requests})
        return list(responses), report  # type: ignore[arg-type]


def _interval_union_seconds(spans: List[Tuple[float, float]]) -> float:
    """Total length covered by a set of [start, end] intervals."""
    total = 0.0
    hi = -math.inf
    for start, end in sorted(spans):
        if end <= hi:
            continue
        total += end - max(start, hi)
        hi = end
    return total


def _merge_outputs(outputs: List[BatchOutput]) -> BatchOutput:
    """Fold the per-group outputs into one batch-level view.

    ``serve([])`` produces no groups, hence no outputs: fold to a
    well-formed zero output so BatchServeReport.from_output reports
    all-zero percentiles/throughput rather than taking statistics of an
    empty latency list.

    Wall time merges as the *union of the groups' busy intervals* in
    perf_counter coordinates: concurrent groups (the async scheduler) do
    not double-count their overlap the way summing per-group walls would,
    and idle gaps between micro-batches (a drained async server between
    traffic bursts) are not billed as serving time the way a max-end
    minus min-start span would.  For back-to-back sequential groups the
    union equals the sum.  Component times (distance/index/optimize/
    enumerate) remain sums: they are attributable CPU work, not elapsed
    time.  Outputs lacking span timestamps (hand-built, e.g. in tests)
    fall back to the sum.
    """
    if not outputs:
        return BatchOutput(items=[], timing=BatchTiming(),
                           cache_stats=CacheStats(), distinct_queries=0)
    if len(outputs) == 1:
        return outputs[0]
    items = [it for o in outputs for it in o.items]
    timing = dataclasses.replace(outputs[0].timing)
    for o in outputs[1:]:
        timing.distance_seconds += o.timing.distance_seconds
        timing.index_seconds += o.timing.index_seconds
        timing.optimize_seconds += o.timing.optimize_seconds
        timing.enumerate_seconds += o.timing.enumerate_seconds
        timing.total_seconds += o.timing.total_seconds
    if all(o.timing.ended_at > o.timing.started_at > 0.0 for o in outputs):
        timing.started_at = min(o.timing.started_at for o in outputs)
        timing.ended_at = max(o.timing.ended_at for o in outputs)
        timing.total_seconds = _interval_union_seconds(
            [(o.timing.started_at, o.timing.ended_at) for o in outputs])
    cache = CacheStats()
    for o in outputs:
        cache.hits += o.cache_stats.hits
        cache.misses += o.cache_stats.misses
        cache.evictions += o.cache_stats.evictions
    return BatchOutput(items=items, timing=timing, cache_stats=cache,
                       distinct_queries=sum(o.distinct_queries
                                            for o in outputs))
