"""HcPE batch serving front-end (DESIGN.md §4).

Request/response dataclasses around core.batch.BatchPathEnum: a server owns
one graph + one engine (whose index LRU persists across batches — the hot
s-t pairs of a production workload keep their indexes warm), turns a list
of ``PathQueryRequest`` into ``PathQueryResponse`` objects, and reports
batch-level serving metrics: latency percentiles, throughput, and cache
reuse.  This is the paper's "online scenario" (§7.1: 1000-query sets,
response time = first results out) expressed as a service API; the LM
serving analogue with continuous batching lives in serving/engine.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.batch import BatchOutput, BatchPathEnum, BatchTiming, CacheStats
from ..core.graph import Graph


@dataclasses.dataclass
class PathQueryRequest:
    """One HcPE query q(s, t, k) plus serving options."""
    uid: int
    s: int
    t: int
    k: int
    count_only: bool = True
    first_n: Optional[int] = None     # response-time mode: first-n results


@dataclasses.dataclass
class PathQueryResponse:
    uid: int
    count: int
    paths: Optional[np.ndarray]       # (r, k+1) int32 when materialized
    plan_method: str
    index_cached: bool                # served off the warm index LRU
    deduplicated: bool                # shared an identical in-batch query
    latency_ms: float


@dataclasses.dataclass
class BatchServeReport:
    """Per-batch serving metrics (the paper's Table-3 axes, batch form)."""
    batch_size: int
    distinct_queries: int
    total_results: int
    wall_seconds: float
    throughput_qps: float             # queries / s for the batch
    results_per_second: float
    p50_ms: float
    p90_ms: float
    p99_ms: float
    cache: CacheStats                 # hits/misses/evictions for this batch

    @classmethod
    def from_output(cls, out: BatchOutput) -> "BatchServeReport":
        pct = out.latency_percentiles((50, 90, 99))
        wall = out.timing.total_seconds
        return cls(batch_size=len(out.items),
                   distinct_queries=out.distinct_queries,
                   total_results=out.total_results,
                   wall_seconds=wall,
                   throughput_qps=out.throughput_qps,
                   results_per_second=out.total_results / max(wall, 1e-12),
                   p50_ms=pct["p50_ms"], p90_ms=pct["p90_ms"],
                   p99_ms=pct["p99_ms"], cache=out.cache_stats)


class HcPEServer:
    """Batch HcPE serving over one graph.

    Groups requests by their (count_only, first_n) serving options — each
    group is one BatchPathEnum.run — and reassembles responses in request
    order.  The engine (and therefore the index LRU) is shared across
    groups and across serve() calls.
    """

    def __init__(self, graph: Graph, engine: Optional[BatchPathEnum] = None):
        self.graph = graph
        self.engine = engine or BatchPathEnum()

    def serve(self, requests: Sequence[PathQueryRequest],
              ) -> Tuple[List[PathQueryResponse], BatchServeReport]:
        groups: Dict[Tuple[bool, Optional[int]], List[int]] = {}
        for pos, req in enumerate(requests):
            groups.setdefault((req.count_only, req.first_n), []).append(pos)

        responses: List[Optional[PathQueryResponse]] = [None] * len(requests)
        outputs: List[BatchOutput] = []
        for (count_only, first_n), positions in groups.items():
            queries = [(requests[p].s, requests[p].t, requests[p].k)
                       for p in positions]
            out = self.engine.run(self.graph, queries, count_only=count_only,
                                  first_n=first_n)
            outputs.append(out)
            for p, item in zip(positions, out.items):
                responses[p] = PathQueryResponse(
                    uid=requests[p].uid, count=item.result.count,
                    paths=None if count_only else item.result.paths,
                    plan_method=item.plan.method,
                    index_cached=item.index_cached,
                    deduplicated=item.deduplicated,
                    latency_ms=item.latency_seconds * 1e3)
        report = BatchServeReport.from_output(_merge_outputs(outputs))
        # the per-group sum double-counts a (s,t,k) served under several
        # serving options; the request list is the truth
        report.distinct_queries = len({(r.s, r.t, r.k) for r in requests})
        return list(responses), report  # type: ignore[arg-type]


def _merge_outputs(outputs: List[BatchOutput]) -> BatchOutput:
    """Fold the per-group outputs into one batch-level view.

    ``serve([])`` produces no groups, hence no outputs: fold to a
    well-formed zero output so BatchServeReport.from_output reports
    all-zero percentiles/throughput rather than taking statistics of an
    empty latency list.
    """
    if not outputs:
        return BatchOutput(items=[], timing=BatchTiming(),
                           cache_stats=CacheStats(), distinct_queries=0)
    if len(outputs) == 1:
        return outputs[0]
    items = [it for o in outputs for it in o.items]
    timing = dataclasses.replace(outputs[0].timing)
    for o in outputs[1:]:
        timing.distance_seconds += o.timing.distance_seconds
        timing.index_seconds += o.timing.index_seconds
        timing.optimize_seconds += o.timing.optimize_seconds
        timing.enumerate_seconds += o.timing.enumerate_seconds
        timing.total_seconds += o.timing.total_seconds
    cache = CacheStats()
    for o in outputs:
        cache.hits += o.cache_stats.hits
        cache.misses += o.cache_stats.misses
        cache.evictions += o.cache_stats.evictions
    return BatchOutput(items=items, timing=timing, cache_stats=cache,
                       distinct_queries=sum(o.distinct_queries
                                            for o in outputs))
