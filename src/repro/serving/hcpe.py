"""HcPE batch serving front-end (DESIGN.md §4, tenancy §8).

Request/response dataclasses around core.batch.BatchPathEnum: a server
owns a ``GraphRegistry`` of tenant graphs (or one bare graph, wrapped)
plus one engine (whose tenant-keyed index LRU persists across batches —
the hot s-t pairs of a production workload keep their indexes warm),
turns a list of ``PathQueryRequest`` into ``PathQueryResponse`` objects,
and reports batch-level serving metrics: latency percentiles, throughput,
and cache reuse (global and per tenant).  This is the paper's "online
scenario" (§7.1: 1000-query sets, response time = first results out)
expressed as a service API; the README "API reference" section documents
the public surface; the LM serving analogue with continuous batching
lives in serving/engine.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, TYPE_CHECKING, Union

import numpy as np

from ..core.batch import (BatchItem, BatchOutput, BatchPathEnum, BatchTiming,
                          CacheStats, DEFAULT_GRAPH_ID)
from ..core.enumerate import EnumStats
from ..core.graph import Graph
from .registry import GraphRegistry

if TYPE_CHECKING:  # deferred: metrics imports this module at runtime
    from .metrics import MetricsSnapshot


# Response statuses.  Rejections are *responses*, not exceptions: an
# admission-controlled server must answer every request it saw, and a
# client telling rejected from crashed needs the distinction in-band.
STATUS_OK = "ok"
STATUS_REJECTED_QUEUE_FULL = "rejected_queue_full"
STATUS_REJECTED_QUOTA = "rejected_quota"
STATUS_REJECTED_TENANT_QUOTA = "rejected_tenant_quota"
STATUS_REJECTED_UNKNOWN_GRAPH = "rejected_unknown_graph"
STATUS_REJECTED_SHUTDOWN = "rejected_shutdown"
STATUS_REJECTED_NO_WEIGHTS = "rejected_no_weights"


@dataclasses.dataclass
class PathQueryRequest:
    """One HcPE query q(s, t, k) plus serving options (DESIGN.md §4, §8).

    ``graph_id`` names the tenant graph the query runs against; the
    default id is the single-graph compatibility contract — servers built
    from a bare ``Graph`` serve it under ``DEFAULT_GRAPH_ID`` and every
    pre-tenancy call site works unchanged.

    ``deadline_ms`` is the per-request SLO (relative to submission).  The
    sync server ignores it; the async front-end (async_server.py) uses it
    for earliest-deadline-first scheduling and the ``slo_met`` flag, and —
    when deadline enforcement is on — as the cooperative enumeration
    budget of its micro-batch.

    ``order`` requests ranked (any-k) enumeration (DESIGN.md §10):
    ``"hops"`` needs nothing extra; ``"weight"`` ranks by the tenant's
    registered ``edge_weights`` — tenants without weights reject such
    requests with ``STATUS_REJECTED_NO_WEIGHTS``.  Under ``order``,
    ``first_n`` means the top-n and every deadline truncation is a
    rank-optimal prefix, which is what turns the async server's EDF
    truncations from "some paths" into "the best paths seen so far".
    """
    uid: int
    s: int
    t: int
    k: int
    count_only: bool = True
    first_n: Optional[int] = None     # response-time mode: first-n results
    deadline_ms: Optional[float] = None
    graph_id: str = DEFAULT_GRAPH_ID  # tenant graph (DESIGN.md §8)
    order: Optional[str] = None       # ranked mode (DESIGN.md §10)


@dataclasses.dataclass
class PathQueryResponse:
    """The wire response for one ``PathQueryRequest`` (DESIGN.md §4, §8):
    result payload, plan/cache observability, the end-to-end latency
    split, and the admission status (``STATUS_*``; ``rejected`` requests
    carry zero results, never an exception)."""
    uid: int
    count: int
    paths: Optional[np.ndarray]       # (r, k+1) int32 when materialized
    plan_method: str
    index_cached: bool                # served off the warm index LRU
    deduplicated: bool                # shared an identical in-batch query
    latency_ms: float                 # attributable engine work for this query
    exhausted: bool = True            # False: truncated by first_n / deadline
    status: str = STATUS_OK
    # end-to-end latency split (async front-end; sync leaves queue at 0)
    queue_ms: float = 0.0             # submission -> micro-batch dispatch
    service_ms: float = 0.0           # dispatch -> response ready
    total_ms: float = 0.0             # submission -> response ready
    slo_met: Optional[bool] = None    # None: request carried no deadline
    graph_id: str = DEFAULT_GRAPH_ID  # tenant that served (or rejected) it

    @property
    def rejected(self) -> bool:
        """True when the request was shed at admission (any non-OK
        status): no engine work happened for it."""
        return self.status != STATUS_OK


@dataclasses.dataclass
class BatchServeReport:
    """Per-batch serving metrics (the paper's Table-3 axes, batch form;
    DESIGN.md §4).  ``cache`` is the batch-level delta; ``tenant_cache``
    splits it by ``graph_id`` so per-tenant reuse (and eviction churn) is
    observable per serve call (DESIGN.md §8).  ``enum_stats`` carries the
    merged Fig.-6 enumeration counters of the batch's distinct results —
    including ``chunks``, the one field earlier aggregation dropped."""
    batch_size: int
    distinct_queries: int
    total_results: int
    wall_seconds: float
    throughput_qps: float             # queries / s for the batch
    results_per_second: float
    p50_ms: float
    p90_ms: float
    p99_ms: float
    cache: CacheStats                 # hits/misses/evictions for this batch
    enum_stats: EnumStats = dataclasses.field(
        default_factory=EnumStats)    # merged Fig.-6 enumeration counters
    tenant_cache: Dict[str, CacheStats] = dataclasses.field(
        default_factory=dict)         # the same delta, split per graph_id
    sharing_groups: int = 0           # structure-sharing groups (§13)
    shared_queries: int = 0           # queries served off a shared walk

    @property
    def chunks(self) -> int:
        """Enumeration chunks processed for this batch's distinct results
        — the work-granularity counter behind the cooperative deadline
        budget, surfaced from ``enum_stats`` so chunk-level load is
        observable per serve call."""
        return self.enum_stats.chunks

    @classmethod
    def from_output(cls, out: BatchOutput) -> "BatchServeReport":
        """Fold one (possibly merged) engine output into a report."""
        pct = out.latency_percentiles((50, 90, 99))
        wall = out.timing.total_seconds
        return cls(batch_size=len(out.items),
                   distinct_queries=out.distinct_queries,
                   total_results=out.total_results,
                   wall_seconds=wall,
                   throughput_qps=out.throughput_qps,
                   results_per_second=out.total_results / max(wall, 1e-12),
                   p50_ms=pct["p50_ms"], p90_ms=pct["p90_ms"],
                   p99_ms=pct["p99_ms"], cache=out.cache_stats,
                   enum_stats=out.enum_stats,
                   sharing_groups=out.sharing_groups,
                   shared_queries=out.shared_queries)

    @classmethod
    def from_outputs(cls, outputs: List[BatchOutput]) -> "BatchServeReport":
        """Merge per-group outputs (``_merge_outputs`` semantics) and keep
        the per-tenant cache-delta split that the merge would flatten."""
        report = cls.from_output(_merge_outputs(outputs))
        tenant: Dict[str, CacheStats] = {}
        for o in outputs:
            agg = tenant.setdefault(o.graph_id, CacheStats())
            agg.hits += o.cache_stats.hits
            agg.misses += o.cache_stats.misses
            agg.evictions += o.cache_stats.evictions
        report.tenant_cache = tenant
        return report


# ---------------------------------------------------------------------------
# Grouping / response assembly — one code path shared by the sync server
# below and the async front-end (async_server.py)
# ---------------------------------------------------------------------------

# (graph_id, count_only, first_n, order)
GroupKey = Tuple[str, bool, Optional[int], Optional[str]]


def request_group_key(req: PathQueryRequest) -> GroupKey:
    """The engine-batch compatibility key: requests sharing it can be
    served by one ``BatchPathEnum.run`` call (the engine takes the graph,
    count_only, first_n and order per batch, not per query — so the
    tenant dimension groups first, DESIGN.md §8).  Both front-ends derive
    their grouping from this one function — extend it here, never
    inline."""
    return (req.graph_id, req.count_only, req.first_n, req.order)


def group_requests(requests: Sequence[PathQueryRequest],
                   ) -> Dict[GroupKey, List[int]]:
    """Positions of ``requests`` grouped by their serving options;
    positions let the caller reassemble responses in request order."""
    groups: Dict[GroupKey, List[int]] = {}
    for pos, req in enumerate(requests):
        groups.setdefault(request_group_key(req), []).append(pos)
    return groups


def response_from_item(req: PathQueryRequest,
                       item: BatchItem) -> PathQueryResponse:
    """Fold one engine ``BatchItem`` into the wire response for ``req``."""
    return PathQueryResponse(
        uid=req.uid, count=item.result.count,
        paths=None if req.count_only else item.result.paths,
        plan_method=item.plan.method,
        index_cached=item.index_cached,
        deduplicated=item.deduplicated,
        latency_ms=item.latency_seconds * 1e3,
        exhausted=item.result.exhausted,
        graph_id=req.graph_id)


def rejection_response(req: PathQueryRequest, status: str,
                       queue_ms: float = 0.0) -> PathQueryResponse:
    """An admission-control rejection as a well-formed response."""
    slo_met = False if req.deadline_ms is not None else None
    return PathQueryResponse(
        uid=req.uid, count=0, paths=None, plan_method="none",
        index_cached=False, deduplicated=False, latency_ms=0.0,
        exhausted=False, status=status, queue_ms=queue_ms,
        service_ms=0.0, total_ms=queue_ms, slo_met=slo_met,
        graph_id=req.graph_id)


class HcPEServer:
    """Batch HcPE serving over a registry of tenant graphs (DESIGN.md §4,
    §8) — or one bare graph, which wraps into a single-tenant registry
    under ``DEFAULT_GRAPH_ID`` (the pre-tenancy call sites run unchanged).

    Groups requests by their (graph_id, count_only, first_n) serving
    options — each group is one BatchPathEnum.run against its tenant's
    graph — and reassembles responses in request order.  Requests naming
    an unregistered ``graph_id`` come back as
    ``STATUS_REJECTED_UNKNOWN_GRAPH`` responses, never exceptions.  The
    engine (and therefore the tenant-keyed index LRU) is shared across
    groups, tenants and serve() calls.  The call blocks until the whole
    batch finishes; for an online workload with per-request SLOs use
    ``AsyncHcPEServer`` (async_server.py), which shares these helpers.
    """

    def __init__(self, graph: Union[Graph, GraphRegistry],
                 engine: Optional[BatchPathEnum] = None,
                 backend: str = "host",
                 sharing: str = "auto") -> None:
        self.registry = GraphRegistry.wrap(graph)
        # `backend` configures the default-constructed engine's DFS
        # expansion (DESIGN.md §9) and `sharing` its cross-query
        # structure sharing (DESIGN.md §13); callers handing their own
        # engine set both knobs there instead.
        self.engine = engine or BatchPathEnum(backend=backend,
                                              sharing=sharing)
        self.registry.bind_engine(self.engine)
        # lifetime Fig.-6 counters across serve() calls, feeding the
        # metrics control plane (serving/metrics.py, DESIGN.md §12)
        self.enum_totals = EnumStats()

    def metrics_snapshot(self) -> "MetricsSnapshot":
        """One consistent ``serving.metrics.MetricsSnapshot`` of this
        server: per-tenant cache and quota state, graph versions, and
        lifetime Fig.-6 enumeration totals (DESIGN.md §12).  The sync
        server has no admission control, so the snapshot's ``serve``
        block is absent (None)."""
        from .metrics import snapshot
        return snapshot(self)

    @property
    def graph(self) -> Optional[Graph]:
        """The default tenant's graph (back-compat accessor for
        single-graph callers); None when no default tenant exists."""
        if DEFAULT_GRAPH_ID in self.registry:
            return self.registry.get(DEFAULT_GRAPH_ID)
        return None

    def serve(self, requests: Sequence[PathQueryRequest],
              ) -> Tuple[List[PathQueryResponse], BatchServeReport]:
        """Serve one request batch; responses come back in request order,
        alongside the batch-level ``BatchServeReport`` (latency
        percentiles, throughput, cache deltas global + per tenant)."""
        responses: List[Optional[PathQueryResponse]] = [None] * len(requests)
        outputs: List[BatchOutput] = []
        for key, positions in group_requests(requests).items():
            graph_id, count_only, first_n, order = key
            if graph_id not in self.registry:
                for p in positions:
                    responses[p] = rejection_response(
                        requests[p], STATUS_REJECTED_UNKNOWN_GRAPH)
                continue
            weights = None
            if order == "weight":
                weights = self.registry.entry(graph_id).edge_weights
                if weights is None:
                    for p in positions:
                        responses[p] = rejection_response(
                            requests[p], STATUS_REJECTED_NO_WEIGHTS)
                    continue
            queries = [(requests[p].s, requests[p].t, requests[p].k)
                       for p in positions]
            out = self.engine.run(self.registry.get(graph_id), queries,
                                  count_only=count_only, first_n=first_n,
                                  graph_id=graph_id, order=order,
                                  weights=weights)
            outputs.append(out)
            self.enum_totals.merge(out.enum_stats)
            for p, item in zip(positions, out.items):
                resp = response_from_item(requests[p], item)
                resp.service_ms = resp.total_ms = resp.latency_ms
                responses[p] = resp
        report = BatchServeReport.from_outputs(outputs)
        # the per-group sum double-counts a (s,t,k) served under several
        # serving options; the request list is the truth (rejected
        # requests did no engine work and don't count)
        report.distinct_queries = len(
            {(r.graph_id, r.s, r.t, r.k) for r in requests
             if r.graph_id in self.registry})
        return list(responses), report  # type: ignore[arg-type]


def _interval_union_seconds(spans: List[Tuple[float, float]]) -> float:
    """Total length covered by a set of [start, end] intervals."""
    total = 0.0
    hi = -math.inf
    for start, end in sorted(spans):
        if end <= hi:
            continue
        total += end - max(start, hi)
        hi = end
    return total


def _merge_outputs(outputs: List[BatchOutput]) -> BatchOutput:
    """Fold the per-group outputs into one batch-level view.

    ``serve([])`` produces no groups, hence no outputs: fold to a
    well-formed zero output so BatchServeReport.from_output reports
    all-zero percentiles/throughput rather than taking statistics of an
    empty latency list.

    Wall time merges as the *union of the groups' busy intervals* in
    perf_counter coordinates: concurrent groups (the async scheduler) do
    not double-count their overlap the way summing per-group walls would,
    and idle gaps between micro-batches (a drained async server between
    traffic bursts) are not billed as serving time the way a max-end
    minus min-start span would.  For back-to-back sequential groups the
    union equals the sum.  Component times (distance/index/optimize/
    enumerate) remain sums: they are attributable CPU work, not elapsed
    time.  Outputs lacking span timestamps (hand-built, e.g. in tests)
    fall back to the sum.
    """
    if not outputs:
        return BatchOutput(items=[], timing=BatchTiming(),
                           cache_stats=CacheStats(), distinct_queries=0)
    if len(outputs) == 1:
        return outputs[0]
    items = [it for o in outputs for it in o.items]
    timing = dataclasses.replace(outputs[0].timing)
    for o in outputs[1:]:
        timing.distance_seconds += o.timing.distance_seconds
        timing.index_seconds += o.timing.index_seconds
        timing.optimize_seconds += o.timing.optimize_seconds
        timing.enumerate_seconds += o.timing.enumerate_seconds
        timing.total_seconds += o.timing.total_seconds
    if all(o.timing.ended_at > o.timing.started_at > 0.0 for o in outputs):
        timing.started_at = min(o.timing.started_at for o in outputs)
        timing.ended_at = max(o.timing.ended_at for o in outputs)
        timing.total_seconds = _interval_union_seconds(
            [(o.timing.started_at, o.timing.ended_at) for o in outputs])
    cache = CacheStats()
    for o in outputs:
        cache.hits += o.cache_stats.hits
        cache.misses += o.cache_stats.misses
        cache.evictions += o.cache_stats.evictions
    return BatchOutput(items=items, timing=timing, cache_stats=cache,
                       distinct_queries=sum(o.distinct_queries
                                            for o in outputs),
                       sharing_groups=sum(o.sharing_groups
                                          for o in outputs),
                       shared_queries=sum(o.shared_queries
                                          for o in outputs))
