"""Async deadline-aware HcPE serving front-end (DESIGN.md §7).

The paper's headline metric is *response time* — time to the first 1000
results under an online workload (§7.1) — but ``HcPEServer.serve`` is a
blocking batch call: one heavy (s, t, k) query stalls every request
queued behind it.  This module puts an asyncio front-end over the same
``BatchPathEnum`` engine:

  * **request queue + admission control** — ``submit`` bounds the queue
    (``max_queue_depth``) and the per-uid in-flight count
    (``max_pending_per_uid``); rejected requests get an explicit
    ``PathQueryResponse`` status (hcpe.STATUS_REJECTED_*), never an
    exception, so clients can tell shed load from a crashed server.
  * **deadline-aware micro-batching** — accepted requests accumulate for
    a batching window, then coalesce into engine batches of identical
    ``(count_only, first_n)`` serving options (the same grouping rule as
    ``HcPEServer.serve``, via the shared ``hcpe.group_requests``
    contract) *and* nearby deadlines (``deadline_slack_ms``), the
    deadline-grouped micro-batching of batch-HcPE serving
    (arXiv:2312.01424).
  * **earliest-deadline-first dispatch** — the pending set is re-sorted
    by absolute deadline before every micro-batch, so a tight-SLO query
    that arrives while a batch is in flight jumps everything looser the
    moment the worker frees up.
  * **non-blocking service** — each micro-batch runs in a worker thread
    via ``asyncio.to_thread``; the event loop keeps accepting (and
    rejecting) requests while enumeration is busy.

Every response carries the queue/service/total latency split and an
``slo_met`` flag.  With ``enforce_deadlines=True`` the group's deadline
is also handed to ``BatchPathEnum.run`` as the cooperative enumeration
budget (core/batch.py), so an in-flight batch stops at the next chunk
boundary past its deadline and reports ``exhausted=False`` — the anytime
contract of ``first_n`` (ranked-enumeration style, arXiv:1911.05582),
keyed on time.  Left off (the default), deadlines shape *scheduling
order and reporting only* and results stay byte-identical to the sync
engine.

Requests carrying ``order=`` run ranked (DESIGN.md §10), which upgrades
those enforced-deadline truncations from "some paths" to "the best
paths seen so far": the engine emits in non-decreasing rank, so the
truncated prefix is rank-optimal.  ``order="weight"`` requires the
tenant's registry entry to carry ``edge_weights``; submissions against
weightless tenants resolve to ``STATUS_REJECTED_NO_WEIGHTS``.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import itertools
import math
from typing import Deque, Dict, List, Optional, Sequence, Tuple, TYPE_CHECKING

from typing import Union

if TYPE_CHECKING:  # deferred: metrics imports this module at runtime
    from .metrics import MetricsSnapshot

from ..core import clock
from ..core.batch import BatchOutput, BatchPathEnum, DEFAULT_GRAPH_ID
from ..core.enumerate import EnumStats
from ..core.graph import Graph
from ..core.rank import ORDERS
from .hcpe import (BatchServeReport, PathQueryRequest, PathQueryResponse,
                   STATUS_REJECTED_NO_WEIGHTS, STATUS_REJECTED_QUEUE_FULL,
                   STATUS_REJECTED_QUOTA, STATUS_REJECTED_SHUTDOWN,
                   STATUS_REJECTED_TENANT_QUOTA,
                   STATUS_REJECTED_UNKNOWN_GRAPH, rejection_response,
                   request_group_key, response_from_item)
from .registry import GraphRegistry


@dataclasses.dataclass
class AsyncServeStats:
    """Counters over the server's lifetime (admission + SLO outcomes;
    DESIGN.md §7, tenancy §8, metrics §12).

    Two exact identities hold at every instant, and the metrics control
    plane exports and re-checks them
    (serving/metrics.MetricsSnapshot.violations, DESIGN.md §12):

      * **admission**: ``submitted == accepted + rejected_total`` —
        ``submit`` bumps ``submitted`` and exactly one of ``accepted`` /
        ``rejected_*`` before it returns or parks.  The ``rejected_*``
        counters are admission-time only.
      * **settlement**: ``accepted == completed + rejected_mid_flight +
        cancelled + failed + inflight`` — every admitted request ends in
        exactly one bucket: a served response, a dispatch-time rejection
        (tenant retired / weights dropped between admission and
        dispatch; the response still carries the ``STATUS_REJECTED_*``
        status), a caller-cancelled future, an engine-raised exception,
        or it is still in flight (``AsyncHcPEServer.queue_depth``).

    The ``*_ms_total`` fields accumulate the queue/service/total latency
    split over completed responses (``completed`` is their shared
    denominator), so an exporter can derive lifetime means without
    retaining per-response data."""
    submitted: int = 0
    accepted: int = 0
    completed: int = 0
    rejected_queue_full: int = 0
    rejected_quota: int = 0
    rejected_tenant_quota: int = 0
    rejected_unknown_graph: int = 0
    rejected_shutdown: int = 0
    rejected_no_weights: int = 0
    rejected_mid_flight: int = 0   # accepted, then shed at dispatch
    cancelled: int = 0             # accepted, future cancelled by caller
    failed: int = 0                # accepted, engine raised
    micro_batches: int = 0
    slo_met: int = 0
    slo_missed: int = 0
    # completed-response latency split, accumulated (ms); mean = /completed
    queue_ms_total: float = 0.0
    service_ms_total: float = 0.0
    total_ms_total: float = 0.0

    @property
    def rejected_total(self) -> int:
        """Sum of the admission-time rejection counters — the shed side
        of ``submitted == accepted + rejected_total``
        (``rejected_mid_flight`` is a settlement bucket, not an
        admission one, and is deliberately excluded)."""
        return (self.rejected_queue_full + self.rejected_quota
                + self.rejected_tenant_quota + self.rejected_unknown_graph
                + self.rejected_shutdown + self.rejected_no_weights)


@dataclasses.dataclass
class _Pending:
    req: PathQueryRequest
    enqueued_at: float                 # core.clock.now() at admission
    deadline_at: Optional[float]       # absolute core.clock; None = no SLO
    seq: int                           # arrival order, the EDF tiebreak
    future: "asyncio.Future[PathQueryResponse]"

    @property
    def edf_key(self) -> Tuple[float, int]:
        return (self.deadline_at if self.deadline_at is not None else math.inf,
                self.seq)


class AsyncHcPEServer:
    """Asyncio front-end over a tenant-graph registry + one
    ``BatchPathEnum`` engine (DESIGN.md §7, tenancy §8).

    Usage::

        async with AsyncHcPEServer(graph_or_registry) as server:
            resp = await server.submit(PathQueryRequest(uid=0, s=3, t=9, k=4,
                                                        deadline_ms=50.0))

    A bare ``Graph`` wraps into a single-tenant registry under
    ``DEFAULT_GRAPH_ID``, so pre-tenancy call sites run unchanged.  The
    engine — and therefore the tenant-keyed index LRU — is shared across
    all micro-batches and tenants, exactly as it is across
    ``HcPEServer.serve`` calls.  Micro-batches group by
    ``(graph_id, count_only, first_n, order)``: one engine batch never
    mixes tenants or ranking modes.

    Parameters
    ----------
    batch_window_ms:
        How long the scheduler lets a micro-batch accumulate after work
        becomes available, trading first-request latency for batch
        sharing (dedup / stacked BFS).
    max_queue_depth:
        Admission bound on requests queued or in flight; past it,
        ``submit`` resolves immediately to STATUS_REJECTED_QUEUE_FULL.
    max_pending_per_uid:
        Per-uid (client) in-flight quota → STATUS_REJECTED_QUOTA.
    max_pending_per_graph:
        Per-tenant-graph in-flight quota → STATUS_REJECTED_TENANT_QUOTA.
        ``None`` (default) leaves tenants unbounded unless their registry
        entry carries its own ``max_pending``, which always wins over
        this server-wide default.
    deadline_slack_ms:
        Two requests share a micro-batch only if their absolute deadlines
        are within this slack (and their serving options match) — keeps a
        loose-deadline heavy query from riding in a tight group, whose
        members would otherwise wait on it.
    default_deadline_ms:
        Applied to requests that carry no ``deadline_ms``; ``None`` means
        such requests have no deadline (they schedule last, FIFO).
    enforce_deadlines:
        Hand each group's deadline to the engine as a cooperative stop
        (truncated results, ``exhausted=False``).  Off by default: then
        deadlines order the work and grade SLOs, but never change results.
    backend:
        DFS-expansion backend ("host" / "device" / "auto", DESIGN.md §9)
        for the default-constructed engine; callers handing their own
        ``engine`` set the knob there instead.
    sharing:
        Cross-query structure sharing for the default-constructed engine
        ("auto" / "off", DESIGN.md §13); micro-batches group eligible
        same-tenant queries through one shared walk.
    """

    def __init__(self, graph: Union[Graph, GraphRegistry],
                 engine: Optional[BatchPathEnum] = None,
                 *, batch_window_ms: float = 2.0, max_queue_depth: int = 1024,
                 max_pending_per_uid: int = 256,
                 max_pending_per_graph: Optional[int] = None,
                 deadline_slack_ms: float = 25.0,
                 default_deadline_ms: Optional[float] = None,
                 enforce_deadlines: bool = False,
                 report_capacity: int = 256,
                 backend: str = "host",
                 sharing: str = "auto") -> None:
        self.registry = GraphRegistry.wrap(graph)
        self.engine = engine or BatchPathEnum(backend=backend,
                                              sharing=sharing)
        self.registry.bind_engine(self.engine)
        self.batch_window_ms = batch_window_ms
        self.max_queue_depth = max_queue_depth
        self.max_pending_per_uid = max_pending_per_uid
        self.max_pending_per_graph = max_pending_per_graph
        self.deadline_slack_ms = deadline_slack_ms
        self.default_deadline_ms = default_deadline_ms
        self.enforce_deadlines = enforce_deadlines
        self.stats = AsyncServeStats()
        self._pending: List[_Pending] = []
        self._inflight = 0                 # admitted, response not yet sent
        self._per_uid: Dict[int, int] = {}
        self._per_graph: Dict[str, int] = {}
        self._seq = itertools.count()
        # drain_report's source, capped: count_only=False outputs hold the
        # full path arrays, so an undrained server must not retain every
        # micro-batch forever — past capacity the oldest outputs fall off
        self._outputs: Deque[BatchOutput] = collections.deque(
            maxlen=report_capacity)
        # lifetime Fig.-6 counters: every micro-batch's enum_stats merged
        # as it completes — unlike _outputs this never drains or caps, so
        # the metrics control plane (serving/metrics.py, DESIGN.md §12)
        # exports engine work since server construction
        self.enum_totals = EnumStats()
        self._wakeup: Optional[asyncio.Event] = None
        self._stop_evt: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._closing = False

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> None:
        """Start the scheduler task; ``async with`` calls this for you."""
        if self._task is not None:
            raise RuntimeError("server already started")
        self._closing = False
        self._wakeup = asyncio.Event()
        self._stop_evt = asyncio.Event()
        self._task = asyncio.create_task(self._scheduler())

    async def stop(self) -> None:
        """Drain the queue (every admitted request gets its response),
        then stop the scheduler.  Submissions after stop() begins resolve
        to STATUS_REJECTED_SHUTDOWN.  Drain latency is service-bound, not
        window-bound: the scheduler's batching window is interrupted (and
        skipped for later rounds) the moment stop() is called — there is
        nothing left to accumulate for once admissions are shut."""
        if self._task is None:
            return
        self._closing = True
        self._wakeup.set()
        self._stop_evt.set()
        await self._task
        self._task = None

    async def __aenter__(self) -> "AsyncHcPEServer":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- submission ---------------------------------------------------------

    @property
    def queue_depth(self) -> int:
        """Requests admitted whose responses have not been sent yet."""
        return self._inflight

    def inflight_by_graph(self) -> Dict[str, int]:
        """Per-tenant admitted-but-unanswered request counts — the live
        numerator of each tenant's ``max_pending`` quota, exported by the
        metrics control plane (DESIGN.md §12)."""
        return dict(self._per_graph)

    def metrics_snapshot(self) -> "MetricsSnapshot":
        """One consistent ``serving.metrics.MetricsSnapshot`` of this
        server: admission/SLO/latency counters, per-tenant cache and
        quota state, graph versions, and lifetime Fig.-6 enumeration
        totals (DESIGN.md §12).  Safe to call at any point in the
        server's lifecycle (counters are read, never reset)."""
        from .metrics import snapshot
        return snapshot(self)

    @property
    def graph(self) -> Optional[Graph]:
        """The default tenant's graph (back-compat accessor for
        single-graph callers); None when no default tenant exists."""
        if DEFAULT_GRAPH_ID in self.registry:
            return self.registry.get(DEFAULT_GRAPH_ID)
        return None

    def _tenant_quota(self, graph_id: str) -> Optional[int]:
        """The in-flight quota for one tenant: its registry entry's
        ``max_pending`` if set, else the server-wide default."""
        entry = self.registry.entry(graph_id)
        return (entry.max_pending if entry.max_pending is not None
                else self.max_pending_per_graph)

    async def submit(self, req: PathQueryRequest) -> PathQueryResponse:
        """Admit one request and await its response.

        Admission failures — queue depth, per-uid quota, per-tenant
        quota, unknown ``graph_id``, shutdown — *return* a rejection
        response; malformed queries (k < 2, s == t, s/t out of range for
        the tenant's graph) raise ValueError like the engine would.
        """
        if self._task is None:
            raise RuntimeError("server not started (use `async with` or "
                               "await start())")
        # full validation up front: a malformed query must fail its own
        # submit, never reach engine.run and poison an entire micro-batch
        if req.k < 2:
            raise ValueError("paper assumes k >= 2")
        if req.s == req.t:
            raise ValueError("s and t must be distinct")
        if req.order is not None and req.order not in ORDERS:
            raise ValueError(f"unknown order {req.order!r}; expected one "
                             f"of {ORDERS} or None")
        if req.graph_id not in self.registry:
            # admission, not validation: tenants register/retire at
            # runtime, so an unknown graph is load-shed state the client
            # must see in-band (a retired tenant is not a client bug)
            self.stats.submitted += 1
            self.stats.rejected_unknown_graph += 1
            return self._rejected(req, STATUS_REJECTED_UNKNOWN_GRAPH)
        graph = self.registry.get(req.graph_id)
        # range check before the submitted counter: a ValueError is a
        # client bug, not traffic — submitted must stay equal to
        # accepted + sum(rejected_*)
        if not (0 <= req.s < graph.n and 0 <= req.t < graph.n):
            raise ValueError(f"s/t out of range for graph "
                             f"{req.graph_id!r} with n={graph.n}")
        if req.order == "weight" and \
                self.registry.entry(req.graph_id).edge_weights is None:
            # admission, not validation: weights are tenant configuration
            # (registered at runtime), so their absence is in-band state
            self.stats.submitted += 1
            self.stats.rejected_no_weights += 1
            return self._rejected(req, STATUS_REJECTED_NO_WEIGHTS)
        self.stats.submitted += 1
        if self._closing:
            self.stats.rejected_shutdown += 1
            return self._rejected(req, STATUS_REJECTED_SHUTDOWN)
        if self._inflight >= self.max_queue_depth:
            self.stats.rejected_queue_full += 1
            return self._rejected(req, STATUS_REJECTED_QUEUE_FULL)
        if self._per_uid.get(req.uid, 0) >= self.max_pending_per_uid:
            self.stats.rejected_quota += 1
            return self._rejected(req, STATUS_REJECTED_QUOTA)
        tenant_quota = self._tenant_quota(req.graph_id)
        if tenant_quota is not None and \
                self._per_graph.get(req.graph_id, 0) >= tenant_quota:
            self.stats.rejected_tenant_quota += 1
            return self._rejected(req, STATUS_REJECTED_TENANT_QUOTA)

        # admission timestamp and absolute deadline both read the engine's
        # deadline clock (core.clock) — the same source the enumeration
        # drivers compare against, so enforced truncation can't be skewed
        # by a clock-origin mismatch (tests/test_deadline_clock.py)
        now = clock.now()
        dl_ms = (req.deadline_ms if req.deadline_ms is not None
                 else self.default_deadline_ms)
        pending = _Pending(
            req=req, enqueued_at=now,
            deadline_at=now + dl_ms / 1e3 if dl_ms is not None else None,
            seq=next(self._seq),
            future=asyncio.get_running_loop().create_future())
        self.stats.accepted += 1
        self._inflight += 1
        self._per_uid[req.uid] = self._per_uid.get(req.uid, 0) + 1
        self._per_graph[req.graph_id] = \
            self._per_graph.get(req.graph_id, 0) + 1
        self._pending.append(pending)
        self._wakeup.set()
        return await pending.future

    def _rejected(self, req: PathQueryRequest,
                  status: str) -> PathQueryResponse:
        """A rejection response, with the SLO counters kept in agreement:
        a shed deadline-carrying request is a missed SLO in the stats,
        exactly as its response reports."""
        resp = rejection_response(req, status)
        if resp.slo_met is False:
            self.stats.slo_missed += 1
        return resp

    async def serve(self, requests: Sequence[PathQueryRequest],
                    ) -> List[PathQueryResponse]:
        """Burst-submit a batch and gather responses in request order —
        the async mirror of ``HcPEServer.serve`` (sans report)."""
        return list(await asyncio.gather(*(self.submit(r) for r in requests)))

    def drain_report(self) -> BatchServeReport:
        """Merge (and clear) the engine outputs accumulated since the last
        call — at most the ``report_capacity`` most recent micro-batches —
        into one ``BatchServeReport``; concurrent spans merge as
        max-of-overlapping wall time (hcpe._merge_outputs) and the cache
        delta stays split per tenant (``tenant_cache``)."""
        outputs = list(self._outputs)
        self._outputs.clear()
        return BatchServeReport.from_outputs(outputs)

    # -- scheduling ---------------------------------------------------------

    def _pop_edf_group(self) -> List[_Pending]:
        """Remove and return the next micro-batch: the earliest-deadline
        request plus every pending request with the same serving options
        whose deadline is within ``deadline_slack_ms`` of it."""
        self._pending.sort(key=lambda p: p.edf_key)
        head = self._pending[0]
        opts = request_group_key(head.req)
        slack = self.deadline_slack_ms / 1e3
        group: List[_Pending] = []
        rest: List[_Pending] = []
        for p in self._pending:
            close = (head.deadline_at is None if p.deadline_at is None
                     else (head.deadline_at is not None
                           and p.deadline_at - head.deadline_at <= slack))
            if request_group_key(p.req) == opts and close:
                group.append(p)
            else:
                rest.append(p)
        self._pending = rest
        return group

    async def _scheduler(self) -> None:
        while True:
            if not self._pending:
                if self._closing:
                    return
                self._wakeup.clear()
                await self._wakeup.wait()
                continue
            if self.batch_window_ms > 0 and not self._closing:
                # let the micro-batch fill; new arrivals during the window
                # (and during service below) join the EDF sort next round.
                # The wait is interruptible: stop() sets _stop_evt, so a
                # drain never sits out the rest of a batching window — no
                # new admissions can arrive to fill it anyway
                try:
                    await asyncio.wait_for(self._stop_evt.wait(),
                                           self.batch_window_ms / 1e3)
                except asyncio.TimeoutError:
                    pass
            while self._pending:
                await self._serve_group(self._pop_edf_group())

    async def _serve_group(self, group: List[_Pending]) -> None:
        """Run one micro-batch (all members share a ``request_group_key``,
        so one tenant graph) in a worker thread and settle its futures.
        A tenant retired between admission and dispatch fails soft: its
        group resolves to ``STATUS_REJECTED_UNKNOWN_GRAPH`` responses."""
        self.stats.micro_batches += 1
        head = group[0].req
        count_only, first_n, order = head.count_only, head.first_n, head.order
        if head.graph_id not in self.registry:
            # dispatch-time shed: these were *accepted*, so they settle
            # as rejected_mid_flight — the admission rejected_* counters
            # must keep submitted == accepted + rejected_total exact
            self._reject_group_mid_flight(group,
                                          STATUS_REJECTED_UNKNOWN_GRAPH)
            return
        graph = self.registry.get(head.graph_id)
        weights = None
        if order == "weight":
            weights = self.registry.entry(head.graph_id).edge_weights
            if weights is None:
                # tenant re-registered without weights between admission
                # and dispatch: fail soft, like a retired tenant
                self._reject_group_mid_flight(group,
                                              STATUS_REJECTED_NO_WEIGHTS)
                return
        deadline = None
        if self.enforce_deadlines:
            deadlines = [p.deadline_at for p in group]
            if all(d is not None for d in deadlines):
                # the group's deadline: when its last member's SLO expires
                deadline = max(deadlines)
        queries = [(p.req.s, p.req.t, p.req.k) for p in group]
        dispatched = clock.now()
        try:
            out = await asyncio.to_thread(
                self.engine.run, graph, queries, count_only=count_only,
                first_n=first_n, deadline=deadline,
                graph_id=head.graph_id, order=order, weights=weights)
        except BaseException as exc:  # engine bug: fail the group, not the loop
            for p in group:
                if not p.future.done():
                    p.future.set_exception(exc)
                    self.stats.failed += 1
                else:
                    self.stats.cancelled += 1
                self._settle(p)
            return
        done = clock.now()
        self._outputs.append(out)
        self.enum_totals.merge(out.enum_stats)
        for p, item in zip(group, out.items):
            if p.future.done():      # submit cancelled (e.g. wait_for timeout)
                self.stats.cancelled += 1
                self._settle(p)      # — drop the response, keep the scheduler
                continue
            resp = response_from_item(p.req, item)
            resp.queue_ms = (dispatched - p.enqueued_at) * 1e3
            resp.service_ms = (done - dispatched) * 1e3
            resp.total_ms = (done - p.enqueued_at) * 1e3
            if p.deadline_at is not None:
                resp.slo_met = done <= p.deadline_at
                if resp.slo_met:
                    self.stats.slo_met += 1
                else:
                    self.stats.slo_missed += 1
            self.stats.completed += 1
            self.stats.queue_ms_total += resp.queue_ms
            self.stats.service_ms_total += resp.service_ms
            self.stats.total_ms_total += resp.total_ms
            p.future.set_result(resp)
            self._settle(p)

    def _reject_group_mid_flight(self, group: List[_Pending],
                                 status: str) -> None:
        """Settle a whole micro-batch as dispatch-time rejections (tenant
        retired / weights dropped between admission and dispatch): every
        live future resolves to a ``status`` rejection response counted
        under ``rejected_mid_flight``; already-cancelled futures settle
        under ``cancelled``."""
        for p in group:
            if not p.future.done():
                self.stats.rejected_mid_flight += 1
                p.future.set_result(self._rejected(p.req, status))
            else:
                self.stats.cancelled += 1
            self._settle(p)

    def _settle(self, p: _Pending) -> None:
        self._inflight -= 1
        left = self._per_uid.get(p.req.uid, 0) - 1
        if left > 0:
            self._per_uid[p.req.uid] = left
        else:
            self._per_uid.pop(p.req.uid, None)
        gleft = self._per_graph.get(p.req.graph_id, 0) - 1
        if gleft > 0:
            self._per_graph[p.req.graph_id] = gleft
        else:
            self._per_graph.pop(p.req.graph_id, None)
