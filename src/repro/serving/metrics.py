"""Metrics control plane: one consistent snapshot of a serving stack,
exportable as JSON or Prometheus text (DESIGN.md §12).

A production deployment needs an operational surface, not a debugger:
per-tenant cache hit rates, admission/SLO counters, the queue/service
latency split, and the paper's Fig.-6 enumeration counters — all of
which the engine and servers already compute — plus a write path for
live quota adjustment.  This module is that surface:

  * ``snapshot(server)`` captures a ``MetricsSnapshot`` from either
    front-end (``HcPEServer`` or ``AsyncHcPEServer``).  Every counter is
    a *value copy* taken at capture time, so a snapshot is immutable
    evidence: tests assert it bit-matches the live engine/server
    counters, and two snapshots diff cleanly across a traffic window.
  * ``MetricsSnapshot.to_json()`` / ``to_prometheus()`` export the same
    numbers as a JSON document or Prometheus text-format lines
    (``pathenum_*`` metric families, tenants as ``graph_id`` labels) —
    the two shapes an admin gateway scrapes.
  * ``MetricsSnapshot.violations()`` re-checks the counter identities
    the stack promises (admission: ``submitted == accepted +
    rejected_total``; settlement; global cache == Σ per-tenant cache) —
    the fuzzed property suite (tests/test_metrics.py) feeds traffic and
    asserts the list stays empty.

The write path lives on the registry (``GraphRegistry.set_cache_quota``
/ ``set_max_pending``), keeping this module read-only: capturing metrics
can never perturb the system it observes.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Optional, Union

from ..core.batch import CacheStats
from ..core.enumerate import EnumStats
from .async_server import AsyncHcPEServer, AsyncServeStats
from .hcpe import HcPEServer


@dataclasses.dataclass
class TenantMetrics:
    """One tenant's slice of a ``MetricsSnapshot`` (DESIGN.md §12):
    graph shape and streaming version, cache occupancy/quota/counters,
    and — on the async front-end — the live in-flight count its
    ``max_pending`` quota meters.  ``registered`` is False for a tenant
    that only survives as historical cache stats (retired, but its
    counters kept for post-mortems, DESIGN.md §8)."""
    graph_id: str
    registered: bool
    graph_version: int = -1        # -1: tenant not registered
    vertices: int = 0
    edges: int = 0
    cache_entries: int = 0
    cache_quota: Optional[int] = None
    cache: CacheStats = dataclasses.field(default_factory=CacheStats)
    max_pending: Optional[int] = None
    inflight: int = 0


@dataclasses.dataclass
class MetricsSnapshot:
    """A point-in-time value copy of every operational counter a serving
    stack exposes (DESIGN.md §12): global + per-tenant index-cache
    stats, merged Fig.-6 enumeration totals, and — for the async
    front-end — admission/SLO/latency counters and queue depth.
    ``serve`` is None for the sync server (it has no admission plane).
    """
    captured_at: float             # time.time() at capture
    cache: CacheStats              # global engine cache counters
    cache_entries: int
    cache_capacity: int
    enum_stats: EnumStats          # lifetime Fig.-6 totals (server scope)
    tenants: Dict[str, TenantMetrics]
    serve: Optional[AsyncServeStats] = None
    queue_depth: int = 0

    def to_dict(self) -> Dict[str, object]:
        """The snapshot as plain nested dicts/lists — ``json.loads
        (snapshot.to_json())`` equals this, and tests diff it against
        ground-truth counters."""
        return dataclasses.asdict(self)

    def to_json(self, indent: Optional[int] = None) -> str:
        """JSON export (the admin-API shape); ``indent`` pretty-prints."""
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def to_prometheus(self) -> str:
        """Prometheus text-format export: ``pathenum_*`` metric families,
        one ``# TYPE`` header each, tenants as ``graph_id`` labels.
        Counters export as ``*_total``; occupancy, quotas, versions and
        queue depth as gauges (an unset quota exports no sample rather
        than a fake bound)."""
        lines: List[str] = []

        def counter(name: str, value: Union[int, float],
                    label: Optional[str] = None) -> None:
            self._sample(lines, name, "counter", value, label)

        def gauge(name: str, value: Union[int, float],
                  label: Optional[str] = None) -> None:
            self._sample(lines, name, "gauge", value, label)

        counter("pathenum_cache_hits_total", self.cache.hits)
        counter("pathenum_cache_misses_total", self.cache.misses)
        counter("pathenum_cache_evictions_total", self.cache.evictions)
        gauge("pathenum_cache_entries", self.cache_entries)
        gauge("pathenum_cache_capacity", self.cache_capacity)
        for fld in dataclasses.fields(EnumStats):
            counter(f"pathenum_enum_{fld.name}_total",
                    getattr(self.enum_stats, fld.name))
        if self.serve is not None:
            for fld in dataclasses.fields(AsyncServeStats):
                suffix = "" if fld.name.endswith("_total") else "_total"
                counter(f"pathenum_serve_{fld.name}{suffix}",
                        getattr(self.serve, fld.name))
            counter("pathenum_serve_rejected_total",
                    self.serve.rejected_total)
            gauge("pathenum_serve_queue_depth", self.queue_depth)
        for gid, tm in self.tenants.items():
            counter("pathenum_tenant_cache_hits_total", tm.cache.hits, gid)
            counter("pathenum_tenant_cache_misses_total", tm.cache.misses,
                    gid)
            counter("pathenum_tenant_cache_evictions_total",
                    tm.cache.evictions, gid)
            gauge("pathenum_tenant_cache_entries", tm.cache_entries, gid)
            if tm.cache_quota is not None:
                gauge("pathenum_tenant_cache_quota", tm.cache_quota, gid)
            if tm.registered:
                gauge("pathenum_tenant_graph_version", tm.graph_version, gid)
                gauge("pathenum_tenant_graph_edges", tm.edges, gid)
                if tm.max_pending is not None:
                    gauge("pathenum_tenant_max_pending", tm.max_pending, gid)
            if self.serve is not None:
                gauge("pathenum_tenant_inflight", tm.inflight, gid)
        return "\n".join(lines) + "\n"

    def _sample(self, lines: List[str], name: str, kind: str,
                value: Union[int, float], label: Optional[str]) -> None:
        header = f"# TYPE {name} {kind}"
        if header not in lines:
            lines.append(header)
        if label is None:
            lines.append(f"{name} {value}")
        else:
            esc = (label.replace("\\", r"\\").replace('"', r"\"")
                   .replace("\n", r"\n"))
            lines.append(f'{name}{{graph_id="{esc}"}} {value}')

    def violations(self) -> List[str]:
        """Re-check the counter identities the serving stack promises
        (AsyncServeStats' admission and settlement identities, and the
        per-tenant/global cache agreement the tenant-stat drift bug used
        to break).  Returns human-readable violation strings — an empty
        list is the invariant the fuzzed property suite asserts."""
        out: List[str] = []
        agg = CacheStats()
        for tm in self.tenants.values():
            agg.hits += tm.cache.hits
            agg.misses += tm.cache.misses
            agg.evictions += tm.cache.evictions
        for fld in ("hits", "misses", "evictions"):
            got, want = getattr(agg, fld), getattr(self.cache, fld)
            if got != want:
                out.append(f"cache {fld}: global {want} != tenant sum {got}")
        entry_sum = sum(tm.cache_entries for tm in self.tenants.values())
        if entry_sum != self.cache_entries:
            out.append(f"cache entries: global {self.cache_entries} != "
                       f"tenant sum {entry_sum}")
        s = self.serve
        if s is not None:
            if s.submitted != s.accepted + s.rejected_total:
                out.append(f"admission: submitted {s.submitted} != accepted "
                           f"{s.accepted} + rejected {s.rejected_total}")
            settled = (s.completed + s.rejected_mid_flight + s.cancelled
                       + s.failed)
            if settled + self.queue_depth != s.accepted:
                out.append(f"settlement: accepted {s.accepted} != settled "
                           f"{settled} + inflight {self.queue_depth}")
            if s.slo_met + s.slo_missed > s.submitted:
                out.append(f"slo: met {s.slo_met} + missed {s.slo_missed} "
                           f"> submitted {s.submitted}")
            inflight_sum = sum(tm.inflight for tm in self.tenants.values())
            if inflight_sum != self.queue_depth:
                out.append(f"inflight: queue depth {self.queue_depth} != "
                           f"tenant sum {inflight_sum}")
        return out


def snapshot(server: Union[HcPEServer, AsyncHcPEServer]) -> MetricsSnapshot:
    """Capture a ``MetricsSnapshot`` from either HcPE front-end
    (DESIGN.md §12).

    Reads the server's registry, engine cache and — on the async
    front-end — its ``AsyncServeStats``; every counter lands in the
    snapshot as a value copy (``CacheStats.snapshot`` /
    ``dataclasses.replace``), so later traffic never mutates captured
    evidence.  Tenants are the union of registered ids and ids with
    surviving cache stats (a retired tenant appears with
    ``registered=False``).
    """
    cache = server.engine.cache
    inflight: Dict[str, int] = {}
    serve: Optional[AsyncServeStats] = None
    queue_depth = 0
    if isinstance(server, AsyncHcPEServer):
        inflight = server.inflight_by_graph()
        serve = dataclasses.replace(server.stats)
        queue_depth = server.queue_depth
    ids = dict.fromkeys(server.registry.graph_ids())
    ids.update(dict.fromkeys(cache.tenant_ids()))
    ids.update(dict.fromkeys(inflight))
    tenants: Dict[str, TenantMetrics] = {}
    for gid in ids:
        tm = TenantMetrics(
            graph_id=gid, registered=gid in server.registry,
            cache_entries=cache.tenant_len(gid),
            cache_quota=cache.quota_for(gid),
            cache=cache.stats_for(gid).snapshot(),
            inflight=inflight.get(gid, 0))
        if tm.registered:
            entry = server.registry.entry(gid)
            tm.graph_version = int(entry.graph.version)
            tm.vertices = int(entry.graph.n)
            tm.edges = int(entry.graph.m)
            tm.cache_quota = entry.cache_quota
            tm.max_pending = entry.max_pending
        tenants[gid] = tm
    enum_totals = EnumStats()
    enum_totals.merge(server.enum_totals)
    return MetricsSnapshot(
        captured_at=time.time(),
        cache=cache.stats.snapshot(),
        cache_entries=len(cache),
        cache_capacity=cache.capacity,
        enum_stats=enum_totals,
        tenants=tenants,
        serve=serve,
        queue_depth=queue_depth)
