"""Batched serving engine: fixed-slot continuous batching over decode_step
(DESIGN.md §5).

A minimal-but-real scheduler: B decode slots, a FIFO request queue, slot
re-fill on completion (continuous batching), per-request max_tokens and
EOS.  Prefill for attention families seeds the cache via
transformer.prefill; SSM/hybrid prompts replay through decode_step (their
prefill-to-state handoff is sequential by construction — see
transformer.prefill docstring).

This is the serving analogue of the paper's "online scenario" and doubles
as the harness for decode-shape validation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import transformer
from ..training import step as step_mod


@dataclasses.dataclass
class Request:
    """One LM decode request: prompt tokens in, generated tokens out
    (the decode-slot analogue of hcpe.PathQueryRequest; DESIGN.md §5)."""
    uid: int
    prompt: np.ndarray            # (L,) int32
    max_tokens: int = 16
    eos_id: Optional[int] = None
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-slot continuous-batching decode engine (DESIGN.md §5): B
    decode slots over one jitted decode step, FIFO admission, slot
    re-fill on completion.  Not tenant-aware — multi-graph tenancy is an
    HcPE-serving concern (DESIGN.md §8); this engine serves one model."""

    def __init__(self, cfg: ArchConfig, params: Any, batch_slots: int = 4,
                 max_len: int = 512, temperature: float = 0.0,
                 seed: int = 0) -> None:
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.step_fn = jax.jit(step_mod.make_serve_step(cfg, temperature))
        self.cache = transformer.init_cache(cfg, batch_slots, max_len)
        self.lens = jnp.zeros((batch_slots,), jnp.int32)
        self.cur_tok = jnp.zeros((batch_slots,), jnp.int32)
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self.rng = jax.random.PRNGKey(seed)
        self.steps_run = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Enqueue one request; it is admitted to a slot on the next
        ``run`` iteration with a free slot (FIFO)."""
        self.queue.append(req)

    def _reset_slot(self, slot: int) -> None:
        """Zero a slot's cache + length before re-use (previous occupant's
        KV/state must not leak into the next request)."""
        def zero(x: jnp.ndarray) -> jnp.ndarray:
            if x.ndim >= 2 and x.shape[1] == self.B:      # (layers, B, ...)
                return x.at[:, slot].set(0)
            if x.ndim >= 1 and x.shape[0] == self.B:      # (B, ...)
                return x.at[slot].set(0)
            return x
        self.cache = jax.tree.map(zero, self.cache)
        self.lens = self.lens.at[slot].set(0)

    def _admit(self) -> None:
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                self._reset_slot(i)
                # replay the prompt through decode steps to build state
                for tok in req.prompt[:-1]:
                    self._step_single_slot(i, int(tok))
                self.cur_tok = self.cur_tok.at[i].set(int(req.prompt[-1]))

    def _step_single_slot(self, slot: int, token: int) -> None:
        # feed one prompt token for one slot: run a full batched step but
        # only advance that slot's length (others replay their current
        # token with unchanged length — a masked no-op for their caches is
        # not free; production would use per-slot prefill, this keeps the
        # reference engine simple and exact).
        toks = self.cur_tok.at[slot].set(token)
        self.rng, sub = jax.random.split(self.rng)
        _, cache, _ = self.step_fn(self.params, toks, self.cache, self.lens,
                                   sub)
        # commit only the target slot's cache advance
        def commit(new: jnp.ndarray, old: jnp.ndarray) -> jnp.ndarray:
            return jnp.concatenate([old[:slot], new[slot:slot + 1],
                                    old[slot + 1:]], axis=0) \
                if new.ndim >= 1 and new.shape[0] == self.B else new
        # caches are stacked (layers, B, ...) — commit along the B axis
        def commit_tree(new: jnp.ndarray,
                        old: jnp.ndarray) -> jnp.ndarray:
            if new.ndim >= 2 and new.shape[1] == self.B:
                return jnp.concatenate(
                    [old[:, :slot], new[:, slot:slot + 1], old[:, slot + 1:]],
                    axis=1)
            if new.ndim >= 1 and new.shape[0] == self.B:
                return commit(new, old)
            return new
        self.cache = jax.tree.map(commit_tree, cache, self.cache)
        self.lens = self.lens.at[slot].add(1)

    def run(self, max_steps: int = 256) -> Dict[int, List[int]]:
        """Drive until queue and slots drain (or max_steps)."""
        results: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            self._admit()
            if all(s is None for s in self.slots) and not self.queue:
                break
            self.rng, sub = jax.random.split(self.rng)
            nxt, cache, _ = self.step_fn(self.params, self.cur_tok,
                                         self.cache, self.lens, sub)
            self.cache = cache
            self.lens = self.lens + jnp.array(
                [1 if s is not None else 0 for s in self.slots], jnp.int32)
            nxt_np = np.asarray(nxt)
            self.steps_run += 1
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                tok = int(nxt_np[i])
                req.output.append(tok)
                if (req.eos_id is not None and tok == req.eos_id) or \
                        len(req.output) >= req.max_tokens or \
                        int(self.lens[i]) >= self.max_len - 1:
                    req.done = True
                    results[req.uid] = req.output
                    self.slots[i] = None
                else:
                    self.cur_tok = self.cur_tok.at[i].set(tok)
        for req in [s for s in self.slots if s is not None]:
            results[req.uid] = req.output
        return results
