"""GraphRegistry — the tenant dimension of the serving stack (DESIGN.md §8).

One deployment serves many tenant graphs (fraud rings per customer,
per-region social graphs) behind one front-end; the batch-HcPE follow-up
work (arXiv:2312.01424) argues the sharing wins compound when queries
against them run through one engine.  The registry is the authority on
which ``graph_id``s exist:

  * **register / retire** — tenants come and go at runtime; retiring a
    tenant also drops its entries (and quota) from every engine cache
    bound to the registry, so a retired graph cannot keep serving stale
    indexes.
  * **per-tenant knobs** — each entry may carry an index-cache entry
    quota (``cache_quota``, enforced by ``core.batch.IndexCache``) and an
    in-flight request quota (``max_pending``, enforced at admission by
    ``AsyncHcPEServer``); both are adjustable live through
    ``set_cache_quota`` / ``set_max_pending`` (the metrics control
    plane's write path, DESIGN.md §12).
  * **streaming mutation** — ``mutate`` applies incremental edge
    inserts/deletes to a tenant's graph (``Graph.with_edges``, which
    bumps the monotone ``Graph.version`` folded into every cache key)
    and purges the tenant's now-stale cache entries from every bound
    engine; ``register`` over an existing id is the hot-swap path
    (register v2 → drain v1 traffic → the old graph object simply drops
    out of scope).  Either way a pre-mutation index can never answer a
    post-mutation query (DESIGN.md §12).
  * **single-graph compatibility** — ``GraphRegistry.wrap(graph)`` puts a
    bare graph under ``DEFAULT_GRAPH_ID``; both servers accept either a
    ``Graph`` or a registry, so every pre-tenancy call site runs
    unchanged.

The registry is deliberately host-local and synchronous: it names graphs
and owns their quotas, nothing else.  Scheduling lives in the servers,
caching in the engine; the sharded (cross-host) cache on the ROADMAP will
consistent-hash on the same ``(graph_id, s, t, k, edge_mask_hash,
graph_version)`` keys.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Dict, Optional, Tuple, Union

import numpy as np

from ..core.batch import BatchPathEnum, DEFAULT_GRAPH_ID
from ..core.graph import Graph


@dataclasses.dataclass
class TenantEntry:
    """One registered tenant: its graph plus per-tenant serving knobs
    (DESIGN.md §8).  ``cache_quota`` bounds the tenant's index-cache
    entries; ``max_pending`` bounds its admitted-but-unanswered requests
    in the async front-end (None = the server's default applies).
    ``edge_weights`` (graph edge order, non-negative) makes the tenant
    servable under ``order="weight"`` ranked queries (DESIGN.md §10);
    tenants without weights reject those requests at admission."""
    graph_id: str
    graph: Graph
    cache_quota: Optional[int] = None
    max_pending: Optional[int] = None
    edge_weights: Optional[np.ndarray] = None


class GraphRegistry:
    """Mutable ``graph_id -> TenantEntry`` map shared by the serving
    front-ends (DESIGN.md §8).

    Engines *bind* to the registry (``bind_engine``): binding pushes each
    tenant's ``cache_quota`` into the engine's ``IndexCache``, and
    ``retire`` drops the tenant's cache entries from every bound engine.
    Both servers bind their engine automatically.
    """

    def __init__(self, default_graph: Optional[Graph] = None) -> None:
        self._entries: Dict[str, TenantEntry] = {}
        # weak: a registry outliving its servers (per-batch HcPEServer
        # over a long-lived registry) must not pin their engines/caches
        self._engines: "weakref.WeakSet[BatchPathEnum]" = weakref.WeakSet()
        if default_graph is not None:
            self.register(DEFAULT_GRAPH_ID, default_graph)

    @classmethod
    def wrap(cls, graph_or_registry: Union[Graph, "GraphRegistry"],
             ) -> "GraphRegistry":
        """The single-graph compatibility shim: a bare ``Graph`` becomes a
        one-tenant registry under ``DEFAULT_GRAPH_ID``; a registry passes
        through untouched."""
        if isinstance(graph_or_registry, GraphRegistry):
            return graph_or_registry
        return cls(default_graph=graph_or_registry)

    # -- tenant lifecycle ---------------------------------------------------

    def register(self, graph_id: str, graph: Graph, *,
                 cache_quota: Optional[int] = None,
                 max_pending: Optional[int] = None,
                 edge_weights: Optional[np.ndarray] = None) -> TenantEntry:
        """Add (or replace) one tenant; quotas propagate to every bound
        engine's cache immediately.  Replacing a tenant's graph drops its
        old cache entries first — indexes built against the old graph must
        not answer queries against the new one.  ``edge_weights`` (one
        non-negative float per graph edge) enables ``order="weight"``
        ranked serving for the tenant (DESIGN.md §10)."""
        if not graph_id:
            raise ValueError("graph_id must be a non-empty string")
        if edge_weights is not None:
            edge_weights = np.asarray(edge_weights, dtype=np.float64)
            if edge_weights.shape != (graph.m,):
                raise ValueError(
                    f"edge_weights must have shape ({graph.m},), got "
                    f"{edge_weights.shape}")
        if graph_id in self._entries:
            self._drop_from_engines(graph_id)
        entry = TenantEntry(graph_id=graph_id, graph=graph,
                            cache_quota=cache_quota, max_pending=max_pending,
                            edge_weights=edge_weights)
        self._entries[graph_id] = entry
        for engine in self._engines:
            engine.cache.set_quota(graph_id, cache_quota)
        return entry

    def retire(self, graph_id: str) -> TenantEntry:
        """Remove one tenant and purge its entries from every bound
        engine cache.  In-flight requests already grouped against the
        graph finish; requests admitted after retirement are rejected
        with ``STATUS_REJECTED_UNKNOWN_GRAPH``."""
        entry = self._entries.pop(graph_id)
        self._drop_from_engines(graph_id)
        return entry

    def mutate(self, graph_id: str, *,
               add: Optional[np.ndarray] = None,
               remove: Optional[np.ndarray] = None,
               edge_weights: Optional[np.ndarray] = None) -> TenantEntry:
        """Stream edge inserts/deletes into one tenant's graph
        (DESIGN.md §12).

        Applies ``Graph.with_edges(add=..., remove=...)`` — the copy's
        ``version`` bump makes every pre-mutation cache entry
        unreachable — then purges the tenant's stale entries from every
        bound engine (the version guarantees correctness; the purge
        returns the capacity).  Quotas survive unchanged.  A tenant
        registered with ``edge_weights`` must supply the new per-edge
        weights here (the edge set changed, so the old vector no longer
        lines up); weightless tenants may also supply weights to become
        weight-servable.  Returns the updated entry; its
        ``entry.graph.version`` is the new epoch.
        """
        entry = self._entries[graph_id]
        new_graph = entry.graph.with_edges(add=add, remove=remove)
        if entry.edge_weights is not None and edge_weights is None:
            raise ValueError(
                f"tenant {graph_id!r} serves order='weight': mutate() "
                f"needs the new edge_weights (one per edge of the "
                f"mutated graph)")
        if edge_weights is not None:
            edge_weights = np.asarray(edge_weights, dtype=np.float64)
            if edge_weights.shape != (new_graph.m,):
                raise ValueError(
                    f"edge_weights must have shape ({new_graph.m},) for "
                    f"the mutated graph, got {edge_weights.shape}")
        entry = dataclasses.replace(entry, graph=new_graph,
                                    edge_weights=edge_weights)
        self._entries[graph_id] = entry
        self._drop_from_engines(graph_id)
        for engine in self._engines:
            engine.cache.set_quota(graph_id, entry.cache_quota)
        return entry

    def set_cache_quota(self, graph_id: str,
                        quota: Optional[int]) -> TenantEntry:
        """Adjust one tenant's index-cache entry quota live (the metrics
        control plane's write path, DESIGN.md §12).  Pushes to every
        bound engine immediately — a tenant over the new quota sheds its
        LRU entries now — and updates the registry entry so later-bound
        engines inherit it.  ``None`` removes the bound."""
        entry = dataclasses.replace(self._entries[graph_id],
                                    cache_quota=quota)
        self._entries[graph_id] = entry
        for engine in self._engines:
            engine.cache.set_quota(graph_id, quota)
        return entry

    def set_max_pending(self, graph_id: str,
                        max_pending: Optional[int]) -> TenantEntry:
        """Adjust one tenant's in-flight admission quota live
        (DESIGN.md §12).  The async front-end reads the entry at every
        admission, so the new bound applies to the next ``submit``;
        already-admitted requests are never shed retroactively.  ``None``
        falls back to the server-wide default."""
        entry = dataclasses.replace(self._entries[graph_id],
                                    max_pending=max_pending)
        self._entries[graph_id] = entry
        return entry

    def _drop_from_engines(self, graph_id: str) -> None:
        for engine in self._engines:
            engine.cache.drop_tenant(graph_id)
            # merged group indexes (DESIGN.md §13) key on the members'
            # tenant-qualified QueryKeys; stale groups are unreachable
            # already — this frees their memory on retire/mutate.
            engine.group_cache.drop_tenant(graph_id)

    # -- lookup -------------------------------------------------------------

    def get(self, graph_id: str) -> Graph:
        """The tenant's graph; raises KeyError for unknown ids (the
        servers translate that into a rejection response)."""
        return self._entries[graph_id].graph

    def entry(self, graph_id: str) -> TenantEntry:
        """The tenant's full entry (graph + quotas); KeyError if unknown."""
        return self._entries[graph_id]

    def graph_ids(self) -> Tuple[str, ...]:
        """All registered ids, registration order."""
        return tuple(self._entries)

    def __contains__(self, graph_id: str) -> bool:
        return graph_id in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    # -- engine binding -----------------------------------------------------

    def bind_engine(self, engine: BatchPathEnum) -> None:
        """Attach one engine: current tenants' cache quotas are applied to
        its ``IndexCache`` now, and future register/retire calls keep it
        in sync.  Idempotent per engine object; the reference is weak, so
        a short-lived server's engine unbinds itself by being collected."""
        if engine in self._engines:
            return
        self._engines.add(engine)
        for entry in self._entries.values():
            engine.cache.set_quota(entry.graph_id, entry.cache_quota)
