"""Serving layer: continuous-batching LM decode (engine.py), the HcPE
batch query front-end (hcpe.py, DESIGN.md §4), the async deadline-aware
HcPE front-end (async_server.py, DESIGN.md §7), and the tenant-graph
registry behind both HcPE front-ends (registry.py, DESIGN.md §8).  The
public surface is documented in the README "API reference" section."""

from . import engine  # noqa: F401
from .async_server import AsyncHcPEServer, AsyncServeStats
from .hcpe import (BatchServeReport, HcPEServer, PathQueryRequest,
                   PathQueryResponse, STATUS_OK, STATUS_REJECTED_QUEUE_FULL,
                   STATUS_REJECTED_QUOTA, STATUS_REJECTED_SHUTDOWN,
                   STATUS_REJECTED_NO_WEIGHTS, STATUS_REJECTED_TENANT_QUOTA,
                   STATUS_REJECTED_UNKNOWN_GRAPH)
from .registry import GraphRegistry, TenantEntry

__all__ = ["engine", "HcPEServer", "PathQueryRequest", "PathQueryResponse",
           "BatchServeReport", "AsyncHcPEServer", "AsyncServeStats",
           "GraphRegistry", "TenantEntry",
           "STATUS_OK", "STATUS_REJECTED_QUEUE_FULL", "STATUS_REJECTED_QUOTA",
           "STATUS_REJECTED_TENANT_QUOTA", "STATUS_REJECTED_UNKNOWN_GRAPH",
           "STATUS_REJECTED_SHUTDOWN", "STATUS_REJECTED_NO_WEIGHTS"]
