"""Serving layer: continuous-batching LM decode (engine.py) and the HcPE
batch query front-end (hcpe.py) — DESIGN.md §4."""

from . import engine  # noqa: F401
from .hcpe import (BatchServeReport, HcPEServer, PathQueryRequest,
                   PathQueryResponse)

__all__ = ["engine", "HcPEServer", "PathQueryRequest", "PathQueryResponse",
           "BatchServeReport"]
