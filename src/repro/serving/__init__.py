"""Serving layer: continuous-batching LM decode (engine.py), the HcPE
batch query front-end (hcpe.py, DESIGN.md §4), and the async
deadline-aware HcPE front-end (async_server.py, DESIGN.md §7)."""

from . import engine  # noqa: F401
from .async_server import AsyncHcPEServer, AsyncServeStats
from .hcpe import (BatchServeReport, HcPEServer, PathQueryRequest,
                   PathQueryResponse, STATUS_OK, STATUS_REJECTED_QUEUE_FULL,
                   STATUS_REJECTED_QUOTA, STATUS_REJECTED_SHUTDOWN)

__all__ = ["engine", "HcPEServer", "PathQueryRequest", "PathQueryResponse",
           "BatchServeReport", "AsyncHcPEServer", "AsyncServeStats",
           "STATUS_OK", "STATUS_REJECTED_QUEUE_FULL", "STATUS_REJECTED_QUOTA",
           "STATUS_REJECTED_SHUTDOWN"]
