"""Serving layer: continuous-batching LM decode (engine.py), the HcPE
batch query front-end (hcpe.py, DESIGN.md §4), the async deadline-aware
HcPE front-end (async_server.py, DESIGN.md §7), the tenant-graph
registry behind both HcPE front-ends (registry.py, DESIGN.md §8 — now
also the streaming-mutation and live-quota write path, §12), and the
metrics control plane (metrics.py, DESIGN.md §12).  The public surface
is documented in the README "API reference" section."""

from . import engine  # noqa: F401
from .async_server import AsyncHcPEServer, AsyncServeStats
from .hcpe import (BatchServeReport, HcPEServer, PathQueryRequest,
                   PathQueryResponse, STATUS_OK, STATUS_REJECTED_QUEUE_FULL,
                   STATUS_REJECTED_QUOTA, STATUS_REJECTED_SHUTDOWN,
                   STATUS_REJECTED_NO_WEIGHTS, STATUS_REJECTED_TENANT_QUOTA,
                   STATUS_REJECTED_UNKNOWN_GRAPH)
from .metrics import MetricsSnapshot, TenantMetrics, snapshot
from .registry import GraphRegistry, TenantEntry

__all__ = ["engine", "HcPEServer", "PathQueryRequest", "PathQueryResponse",
           "BatchServeReport", "AsyncHcPEServer", "AsyncServeStats",
           "GraphRegistry", "TenantEntry",
           "MetricsSnapshot", "TenantMetrics", "snapshot",
           "STATUS_OK", "STATUS_REJECTED_QUEUE_FULL", "STATUS_REJECTED_QUOTA",
           "STATUS_REJECTED_TENANT_QUOTA", "STATUS_REJECTED_UNKNOWN_GRAPH",
           "STATUS_REJECTED_SHUTDOWN", "STATUS_REJECTED_NO_WEIGHTS"]
