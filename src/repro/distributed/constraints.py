"""Activation sharding constraints (Megatron-style, GSPMD-mediated).

``constrain(x, builder)`` applies jax.lax.with_sharding_constraint using the
*ambient* mesh (repro.compat.set_mesh context).  Outside any mesh — CPU unit
tests,
the quickstart examples — it is a no-op, so model code can sprinkle
constraints unconditionally.  Builders get a ShardingRules so every axis
choice inherits the divisibility fallbacks.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from ..compat import get_abstract_mesh
from .sharding import ShardingRules


def current_rules() -> Optional[ShardingRules]:
    mesh = get_abstract_mesh()
    if mesh.empty:
        return None
    return ShardingRules(mesh)


def constrain(x, builder: Callable[[ShardingRules, Tuple[int, ...]], P]):
    rules = current_rules()
    if rules is None:
        return x
    spec = builder(rules, x.shape)
    return jax.lax.with_sharding_constraint(x, spec)


# -- common builders ---------------------------------------------------------

def act_bsd(rules: ShardingRules, shape) -> P:
    """(B, S, D) layer-boundary activation: batch over the dp group."""
    return P(rules.dp(shape[0]), None, None)


def act_bsd_sp(rules: ShardingRules, shape) -> P:
    """(B, S, D) residual with sequence parallelism: seq over model."""
    return P(rules.dp(shape[0]), rules.tp(shape[1]), None)


def act_bsf(rules: ShardingRules, shape) -> P:
    """(B, S, F) projected activation: batch over dp, features over model.

    Without this constraint GSPMD resolves the FSDP-weight × batch-sharded
    activation contraction conflict by *replicating the batch* — measured
    +50 GB/device of all-reduce on a 2-layer llama3.2 train step.
    """
    return P(rules.dp(shape[0]), None, rules.tp(shape[-1]))


def act_tokens_f(rules: ShardingRules, shape) -> P:
    """(T, F) flattened-token activation (MoE router / dispatch)."""
    return P(rules.dp(shape[0]), rules.tp(shape[-1]))


def moe_slots(rules: ShardingRules, shape) -> P:
    """(E, cap, D) expert dispatch slots: experts over model (EP)."""
    return P(rules.tp(shape[0]), None, None)


def ssd_intra(rules: ShardingRules, shape) -> P:
    """(B, nc, Q, Q, H) SSD intra-chunk tensors: heads over model."""
    return P(rules.dp(shape[0]), None, None, None, rules.tp(shape[-1]))


def logits_bsv(rules: ShardingRules, shape) -> P:
    """(B, S, V) LM logits: batch over dp, vocab over model."""
    return P(rules.dp(shape[0]), None, rules.tp(shape[-1]))


def act_heads(rules: ShardingRules, shape) -> P:
    """(B, L, H, hd): shard heads over model, else sequence, else batch only.

    The head fallback chain is the GQA story: H ∈ {36, 40} (starcoder2,
    llama4) does not divide a 16-way model axis, so those archs run
    sequence-parallel attention instead (context parallelism) — recorded
    per-cell by the dry-run.
    """
    b, l, h, hd = shape
    if rules.tp(h):
        return P(rules.dp(b), None, rules.tp(h), None)
    if rules.tp(l):
        return P(rules.dp(b), rules.tp(l), None, None)
    return P(rules.dp(b), None, None, None)


def logits_bhqk(rules: ShardingRules, shape) -> P:
    """(B, H, Q, K) attention logits: follow the same head/seq fallback."""
    b, h, q, k = shape
    if rules.tp(h):
        return P(rules.dp(b), rules.tp(h), None, None)
    if rules.tp(q):
        return P(rules.dp(b), None, rules.tp(q), None)
    return P(rules.dp(b), None, None, None)
