"""Distributed PathEnum — the paper's pipeline sharded over a mesh.

Decomposition (DESIGN.md §2, last bullet):
  * **query axis = `data`** — HcPE queries are independent; a batch of
    queries (the paper's online workload: 1000-query sets, §7.1) shards
    across the data axis, each shard running the full per-query pipeline.
  * **graph axis = `model`** — for graphs too large for one device's HBM
    (the paper's tm, 1.96B edges ≈ 16 GB in CSR), the *edge list* shards
    1-D across the model axis; BFS relaxation and the walk-count DP become
    local scatter-min / scatter-add followed by an element-wise cross-shard
    combine (`pmin` / `psum`) on the (n,) frontier vector — the classic
    distributed-SpMV decomposition.

These device kernels cover the two phases that bound the paper's response
time at scale (Fig. 12a: BFS dominates index build; Alg. 5 is k more edge
sweeps).  Enumeration itself is output-bound and embarrassingly parallel
across queries; each query's frontier expansion runs on its data-shard
(host-driven chunks, core/enumerate.py).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map as _shard_map
from ..core.batch import BatchOutput, BatchPathEnum, DEFAULT_GRAPH_ID
from ..core.graph import Graph


def _pad_edges(esrc: np.ndarray, edst: np.ndarray, shards: int):
    m = esrc.shape[0]
    pad = (-m) % shards
    if pad:
        # self-loops on vertex 0 are inert for BFS (min) and masked for DP
        esrc = np.concatenate([esrc, np.zeros(pad, esrc.dtype)])
        edst = np.concatenate([edst, np.zeros(pad, edst.dtype)])
    valid = np.ones(esrc.shape[0], bool)
    if pad:
        valid[-pad:] = False
    return esrc, edst, valid


def make_distributed_bfs(mesh: Mesh, n: int, k: int):
    """Returns bfs(esrc, edst, valid, srcs, excludeds) -> (Q, n) distances.

    Edges shard over `model`; queries shard over `data`.  Inside the
    shard_map each device relaxes its edge slice for its query slice, then
    a `pmin` over `model` merges the per-shard distance vectors.
    """
    INF = jnp.int32(k + 1)

    def one_query(esrc_l, edst_l, valid_l, src, excluded):
        dist = jnp.full((n,), INF, jnp.int32).at[src].set(0)

        def body(_, dist):
            cand = jnp.where((esrc_l == excluded) | ~valid_l, INF,
                             dist[esrc_l] + 1)
            new = dist.at[edst_l].min(cand)
            new = jnp.minimum(new, INF)
            return jax.lax.pmin(new, "model")

        return jax.lax.fori_loop(0, k, body, dist)

    def kernel(esrc_l, edst_l, valid_l, srcs_l, exc_l):
        f = jax.vmap(one_query, in_axes=(None, None, None, 0, 0))
        return f(esrc_l, edst_l, valid_l, srcs_l, exc_l)

    mapped = _shard_map(
        kernel, mesh=mesh,
        in_specs=(P("model"), P("model"), P("model"), P("data"), P("data")),
        out_specs=P("data"))
    return jax.jit(mapped)


def make_distributed_walk_dp(mesh: Mesh, n: int, k: int):
    """Returns dp(esrc, edst, valid, dist_s (Q,n), dist_t (Q,n)) ->
    (q_prefix (Q,k+1), q_suffix (Q,k+1), total (Q,)) — Alg. 5 at scale.

    Counting-semiring SpMV per level with `psum` over the edge shards; the
    (t,t) self-loop is applied on the host-visible t slot via the dist_t==0
    mask (dist_t[t] = 0 uniquely identifies t).
    """

    def one_query(esrc_l, edst_l, valid_l, ds, dt):
        lvl = lambda i: (ds <= i) & (dt <= (k - i))
        is_t = (dt == 0).astype(jnp.float32)

        def bwd_step(i, c):
            # c = c_k^{i+1}; produce c_k^i
            m = valid_l & (dt[edst_l] <= (k - i - 1))
            contrib = jnp.zeros((n,), jnp.float32).at[esrc_l].add(
                jnp.where(m, c[edst_l], 0.0))
            contrib = jax.lax.psum(contrib, "model")
            contrib = contrib + is_t * c  # (t,t) self-loop
            return jnp.where(lvl(i), contrib, 0.0)

        def fwd_step(i, c):
            m = valid_l & (ds[esrc_l] <= (i - 1))
            contrib = jnp.zeros((n,), jnp.float32).at[edst_l].add(
                jnp.where(m, c[esrc_l], 0.0))
            contrib = jax.lax.psum(contrib, "model")
            contrib = contrib + is_t * c
            return jnp.where(lvl(i), contrib, 0.0)

        c_to = jnp.where(lvl(k), 1.0, 0.0)
        q_suffix = jnp.zeros((k + 1,), jnp.float32).at[k].set(c_to.sum())
        def bwd_loop(idx, carry):
            c, qs = carry
            i = k - 1 - idx
            c = bwd_step(i, c)
            return c, qs.at[i].set(c.sum())
        c_to, q_suffix = jax.lax.fori_loop(0, k, bwd_loop, (c_to, q_suffix))

        c_from = jnp.where(lvl(0), 1.0, 0.0)
        q_prefix = jnp.zeros((k + 1,), jnp.float32).at[0].set(c_from.sum())
        def fwd_loop(i, carry):
            c, qp = carry
            c = fwd_step(i, c)
            return c, qp.at[i].set(c.sum())
        c_from, q_prefix = jax.lax.fori_loop(1, k + 1, fwd_loop,
                                             (c_from, q_prefix))
        total = (c_from * is_t).sum()
        return q_prefix, q_suffix, total

    def kernel(esrc_l, edst_l, valid_l, ds_l, dt_l):
        f = jax.vmap(one_query, in_axes=(None, None, None, 0, 0))
        return f(esrc_l, edst_l, valid_l, ds_l, dt_l)

    mapped = _shard_map(
        kernel, mesh=mesh,
        in_specs=(P("model"), P("model"), P("model"), P("data"), P("data")),
        out_specs=(P("data"), P("data"), P("data")))
    return jax.jit(mapped)


class DistributedPathEnum:
    """Query-batched index distances + cardinality estimation on a mesh."""

    def __init__(self, mesh: Mesh, graph: Graph, k: int):
        self.mesh = mesh
        self.graph = graph
        self.k = k
        shards = mesh.shape["model"]
        es, ed, valid = _pad_edges(graph.esrc, graph.edst, shards)
        eshard = NamedSharding(mesh, P("model"))
        self.esrc = jax.device_put(jnp.asarray(es), eshard)
        self.edst = jax.device_put(jnp.asarray(ed), eshard)
        self.valid = jax.device_put(jnp.asarray(valid), eshard)
        self._bfs = make_distributed_bfs(mesh, graph.n, k)
        self._dp = make_distributed_walk_dp(mesh, graph.n, k)

    def query_batch_stats(self, queries: np.ndarray):
        """queries (Q, 2) of (s, t) — Q must divide the data axis.

        Returns (q_prefix, q_suffix, totals) per query; `totals` is δ_W,
        the full-fledged estimator output (exact walk counts).
        """
        q = np.asarray(queries, np.int32)
        srcs, tgts = jnp.asarray(q[:, 0]), jnp.asarray(q[:, 1])
        dshard = NamedSharding(self.mesh, P("data"))
        srcs = jax.device_put(srcs, dshard)
        tgts = jax.device_put(tgts, dshard)
        ds = self._bfs(self.esrc, self.edst, self.valid, srcs, tgts)
        # reverse BFS: swap edge direction by swapping src/dst arrays
        dt = self._bfs(self.edst, self.esrc, self.valid, tgts, srcs)
        qp, qs, tot = self._dp(self.esrc, self.edst, self.valid, ds, dt)
        return np.asarray(qp), np.asarray(qs), np.asarray(tot), (
            np.asarray(ds), np.asarray(dt))

    def enumerate_batch(self, queries: np.ndarray, count_only: bool = True,
                        first_n: Optional[int] = None,
                        engine: Optional[BatchPathEnum] = None,
                        graph_id: str = DEFAULT_GRAPH_ID,
                        sharing: Optional[str] = None) -> BatchOutput:
        """Batch entry point: mesh distances, host enumeration.

        ``queries`` is (Q, 2) of (s, t); the hop bound is the engine's k.
        The query list is padded to a multiple of the ``data`` axis and
        sharded across it; each device runs the stacked BFS for its query
        slice (the distance pass dominates index build, Fig. 12a).  The
        (Q, n) distance matrices then feed core.batch.BatchPathEnum as
        precomputed distances, so the host pipeline skips its own BFS and
        goes straight to index assembly, planning and enumeration — with
        the engine's dedup and index LRU still applying across the batch.

        ``graph_id`` names the tenant this instance's graph belongs to
        (DESIGN.md §8): it keys the precomputed-distance hand-off and the
        engine's LRU, so a shared host engine keeps tenants' entries
        apart.  Multi-tenant routing across instances lives in
        ``DistributedTenantRouter``.

        ``sharing`` forwards to the host engine's structure-sharing knob
        (DESIGN.md §13; None keeps the engine's own setting): the mesh
        computes every member's distances, the host engine still groups
        shared-endpoint queries through one merged index and walk.
        """
        engine = engine or BatchPathEnum()
        q = np.asarray(queries, np.int64).reshape(-1, 2)
        triples = [(int(s), int(t), self.k) for (s, t) in q]
        if q.shape[0] == 0:
            return engine.run(self.graph, [], graph_id=graph_id,
                              sharing=sharing)
        dsize = self.mesh.shape["data"]
        pad = (-q.shape[0]) % dsize
        padded = np.concatenate([q, np.repeat(q[:1], pad, axis=0)]) \
            if pad else q
        _, _, _, (ds, dt) = self.query_batch_stats(padded)
        pre = {(graph_id, s, t, k, 0, self.graph.version):
               (ds[i].astype(np.int32), dt[i].astype(np.int32))
               for i, (s, t, k) in enumerate(triples)}
        return engine.run(self.graph, triples, count_only=count_only,
                          first_n=first_n, graph_id=graph_id,
                          sharing=sharing,
                          _precomputed_distances=pre)


class DistributedTenantRouter:
    """Per-graph routing over a set of ``DistributedPathEnum`` instances
    (DESIGN.md §8's distributed leg).

    One mesh hosts several tenant graphs, each sharded over ``model`` by
    its own ``DistributedPathEnum``; one *shared* host ``BatchPathEnum``
    (one LRU, tenant-keyed) serves them all.  ``enumerate`` takes queries
    tagged ``(graph_id, s, t)``, groups them per graph, routes each group
    through its tenant's mesh BFS across the ``data`` axis, and
    reassembles the per-query items in input order.
    """

    def __init__(self, tenants: Dict[str, DistributedPathEnum],
                 engine: Optional[BatchPathEnum] = None):
        self.tenants = dict(tenants)
        self.engine = engine or BatchPathEnum()

    def enumerate(self, tagged_queries: Sequence[Tuple[str, int, int]],
                  count_only: bool = True,
                  first_n: Optional[int] = None,
                  sharing: Optional[str] = None,
                  ) -> Tuple[List[object], Dict[str, BatchOutput]]:
        """Serve ``(graph_id, s, t)`` queries; unknown ids raise KeyError.

        Returns ``(items, outputs)``: per-query ``BatchItem``s in input
        order plus the per-tenant ``BatchOutput`` each group produced
        (timing / cache-delta observability per tenant).
        """
        groups: Dict[str, List[int]] = {}
        for pos, (gid, _s, _t) in enumerate(tagged_queries):
            if gid not in self.tenants:
                raise KeyError(f"unknown graph_id {gid!r}")
            groups.setdefault(gid, []).append(pos)
        items: List[object] = [None] * len(tagged_queries)
        outputs: Dict[str, BatchOutput] = {}
        for gid, positions in groups.items():
            q = np.array([[tagged_queries[p][1], tagged_queries[p][2]]
                          for p in positions], np.int64)
            out = self.tenants[gid].enumerate_batch(
                q, count_only=count_only, first_n=first_n,
                engine=self.engine, graph_id=gid, sharing=sharing)
            outputs[gid] = out
            for p, item in zip(positions, out.items):
                items[p] = item
        return items, outputs
