"""Gradient compression: int8 quantization + error feedback.

Beyond-paper distributed-optimization trick (EXPERIMENTS.md §Perf): the
data-parallel gradient reduction dominates the collective roofline term for
small models at high chip counts; quantizing the payload to int8 with a
per-tensor scale cuts those bytes 4× (f32) / 2× (bf16), and the error-
feedback residual keeps SGD unbiased in the long run (the standard 1-bit
Adam / EF-SGD recipe).

``compressed_psum_tree`` is the shard_map building block: quantize → psum
int32 (accumulate in int32 to avoid overflow at ≤ 2^23 summands) →
dequantize.  ``make_compressed_grad_fn`` wraps a per-device loss into a
data-parallel gradient with compressed reduction, used by the train-step
variant benchmarked in benchmarks/collectives.py.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map


def quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def quantize_with_feedback(x, residual):
    """Error feedback: compress (x + residual), keep the new residual."""
    target = x + residual
    q, scale = quantize(target)
    deq = dequantize(q, scale)
    return q, scale, target - deq


def compressed_psum_tree(tree, axis_name: str):
    """int8-grid, int16-carried psum of a gradient pytree along a mesh axis.

    Call inside shard_map.  The quantization grid is shared across ranks
    (axis-max scale), each rank contributes int8 values in [-127, 127], and
    the wire carries **int16**: the sum of ≤257 int8 contributions fits
    int16 exactly (127·257 < 2^15), so accumulation is lossless and the
    all-reduce payload halves vs f32 gradients (measured in
    benchmarks/collectives.py).  True int8-wire schemes need per-hop
    requantization inside the collective (custom Pallas remote-DMA ring),
    which XLA's all-reduce primitive cannot express — documented trade-off.
    """
    def one(x):
        scale = jax.lax.pmax(jnp.max(jnp.abs(x)) / 127.0 + 1e-12, axis_name)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int16)
        s = jax.lax.psum(q, axis_name)
        return s.astype(jnp.float32) * scale

    return jax.tree.map(one, tree)


def make_compressed_grad_fn(loss_fn: Callable, mesh: Mesh,
                            data_axis: str = "data"):
    """Data-parallel value_and_grad with int8-compressed all-reduce.

    loss_fn(params, batch) -> (loss, aux); params replicated across
    ``data_axis``, batch sharded on its leading dim.  Returns
    f(params, batch) -> (loss, grads) with grads replicated.
    """
    def local(params, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        axis = data_axis
        loss = jax.lax.pmean(loss, axis)
        n = jax.lax.psum(1, axis)
        grads = jax.tree.map(lambda g: g / n, grads)
        grads = compressed_psum_tree(grads, axis)
        return loss, grads

    pspec = P()
    bspec = P(data_axis)
    mapped = shard_map(
        local, mesh=mesh,
        in_specs=(pspec, bspec), out_specs=(pspec, pspec))
    return jax.jit(mapped)
