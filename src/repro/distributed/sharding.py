"""Sharding rules: FSDP(data[,pod]) × TP(model) with divisibility fallbacks.

Strategy (DESIGN.md §5):
  * train — parameters/optimizer state shard over BOTH the fsdp group
    (``("pod","data")`` when multi-pod) and ``model`` (ZeRO-3 × tensor
    parallel).  Column-parallel in-projections (D→F sharded on F), row-
    parallel out-projections (F→D sharded on F), expert dimension of MoE
    stacks over ``model`` (expert parallelism), batch over the fsdp group.
  * serve — same param specs work (XLA re-shards activations); KV caches
    shard batch over the fsdp group and heads (or head_dim when the GQA
    head count doesn't divide — kv∈{1,4,8} < 16) over ``model``; the
    long_500k cell (batch=1) falls back to sequence-sharded caches.

Every rule goes through ``_pick`` — the first candidate axis (group) that
divides the dimension wins, else the dim is replicated.  This is what lets
one rule set serve vocab 50280 (mamba2, ∤16) and vocab 202048 alike; the
dry-run JSON records the chosen spec per cell so the fallbacks are visible.
"""
from __future__ import annotations

import re
from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig


def mesh_axis_size(mesh: Mesh, names) -> int:
    size = 1
    for n in ([names] if isinstance(names, str) else names):
        size *= mesh.shape[n]
    return size


class ShardingRules:
    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        names = mesh.axis_names
        self.fsdp_group: Tuple[str, ...] = tuple(
            n for n in ("pod", "data") if n in names)
        self.model_axis = "model" if "model" in names else None

    # -- candidate pickers ----------------------------------------------
    def _div(self, dim: int, names) -> bool:
        return dim % mesh_axis_size(self.mesh, names) == 0

    def fsdp(self, dim: int):
        for cand in (self.fsdp_group, ("data",), ("pod",)):
            cand = tuple(n for n in cand if n in self.mesh.axis_names)
            if cand and self._div(dim, cand):
                return cand if len(cand) > 1 else cand[0]
        return None

    def tp(self, dim: int):
        if self.model_axis and self._div(dim, self.model_axis):
            return self.model_axis
        return None

    def dp(self, dim: int):
        return self.fsdp(dim)

    # -- parameter rules --------------------------------------------------
    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        parts = path.split("/")
        name = parts[-1]
        stacked = parts[0] == "supers"
        dims = shape[1:] if stacked else shape
        lead = (None,) if stacked else ()

        def spec(*axes):
            return P(*(lead + tuple(axes)))

        # --- scalars / norms / per-channel vectors: replicate ---
        if name in ("ln1", "ln2", "norm", "final_norm", "lam", "A_log", "D",
                    "dt_bias", "step") or len(dims) <= 1:
            return spec(*(None,) * len(dims))
        in_moe = "moe" in parts[-2:-1] or (len(parts) >= 2 and parts[-2] == "moe")
        if in_moe and name in ("w_gate", "w_up") and len(dims) == 3:
            e, d, f = dims
            return spec(self.tp(e), self.fsdp(d), None)
        if in_moe and name == "w_down" and len(dims) == 3:
            e, f, d = dims
            return spec(self.tp(e), None, self.fsdp(d))
        if name == "router":
            d, e = dims
            return spec(self.fsdp(d), self.tp(e))
        if name == "embed":
            # vocab-parallel (tp on V): logits inherit model-sharded vocab so
            # the (B, S, V) loss tensor never replicates — critical for the
            # tied-embedding archs where embed.T is the LM head.  Odd vocabs
            # (mamba2's 50280 ∤ 16) fall back to fsdp-sharded V, else fully
            # replicated — NEVER model-sharded D: a D-sharded gather output
            # being resharded inside a loop body trips the SPMD partitioner
            # (hlo-verifier dynamic-slice fault, see EXPERIMENTS.md §Perf).
            v, d = dims
            tv = self.tp(v)
            if tv:
                return spec(tv, self.fsdp(d))
            fv = self.fsdp(v)
            if fv:
                return spec(fv, None)
            return spec(None, None)
        if name == "head":
            d, v = dims
            return spec(self.fsdp(d), self.tp(v))
        if name in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj", "w_x",
                    "w_gate_out", "frontend_proj", "w_in_gate", "w_rec_gate"):
            d, f = dims
            return spec(self.fsdp(d), self.tp(f))
        if name in ("wo", "w_down", "out_proj", "w_out"):
            f, d = dims
            return spec(self.tp(f), self.fsdp(d))
        if name == "conv_w":
            c, w = dims
            return spec(self.tp(c), None)
        # default: replicate
        return spec(*(None,) * len(dims))

    # -- cache rules -------------------------------------------------------
    def cache_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        parts = path.split("/")
        stacked = parts[0] == "supers"
        dims = shape[1:] if stacked else shape
        lead = (None,) if stacked else ()

        def spec(*axes):
            return P(*(lead + tuple(axes)))

        if "ssm" in path and len(dims) == 4:  # ssd state (B, H, N, P)
            b, nh, ns_, hd = dims
            return spec(self.dp(b), self.tp(nh), None, None)
        if len(dims) == 4:  # kv cache (B, S, Hkv, hd)
            b, s, hkv, hd = dims
            bspec = self.dp(b)
            sspec = None if bspec is not None else self.dp(s)
            hspec = self.tp(hkv)
            dspec = None if hspec is not None else self.tp(hd)
            return spec(bspec, sspec, hspec, dspec)
        if len(dims) == 3:  # conv state (B, W-1, C)
            b, w, c = dims
            return spec(self.dp(b), None, self.tp(c))
        if len(dims) == 2:  # rec h (B, W)
            b, w = dims
            return spec(self.dp(b), self.tp(w))
        if len(dims) == 5:  # ssm h stacked oddity safeguard
            return spec(*(None,) * len(dims))
        return spec(*(None,) * len(dims))

    # -- batch rules ---------------------------------------------------------
    def batch_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        b = shape[0]
        return P(self.dp(b), *(None,) * (len(shape) - 1))


def path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def tree_specs(tree, rule) -> Any:
    """Map a (template) pytree to PartitionSpecs via rule(path, shape)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, leaf: rule(path_str(p), np.shape(leaf)), tree)


def tree_shardings(mesh: Mesh, specs) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def param_shardings(mesh: Mesh, cfg: ArchConfig, params_template):
    rules = ShardingRules(mesh)
    return tree_specs(params_template, rules.param_spec)


def opt_shardings(param_specs, opt_template):
    """Optimizer state reuses param specs for mu/nu, replicates step."""
    from ..optim.adamw import AdamWState
    return AdamWState(step=P(), mu=param_specs, nu=param_specs)


def cache_shardings(mesh: Mesh, cfg: ArchConfig, cache_template):
    rules = ShardingRules(mesh)
    return tree_specs(cache_template, rules.cache_spec)


def batch_shardings(mesh: Mesh, batch_template):
    rules = ShardingRules(mesh)
    return tree_specs(batch_template, rules.batch_spec)
