from . import sharding
