from . import step
