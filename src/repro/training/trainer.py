"""Training loop with fault tolerance, restart, and straggler telemetry.

Production behaviors implemented here (exercised by tests + the train
launcher):
  * **checkpoint/restart** — atomic checkpoints every ``ckpt_every`` steps;
    on (re)start the trainer resumes from the latest manifest, including
    the data-stream position (no sample skew after preemption).
  * **emergency save** — SIGTERM triggers a final checkpoint (TPU pod
    preemption signal).
  * **elastic re-shard** — checkpoints are stored unsharded; a restart may
    bring up a different mesh and the in_shardings re-partition on load.
  * **straggler telemetry** — per-step wall times feed an EWMA; steps
    slower than ``straggler_factor``× the EWMA are logged with their step
    index.  On a real pod this signal drives re-slicing / hot-spare swap;
    in-process we record it (see DESIGN.md §5 — the chunked PathEnum
    frontier bounds the blast radius of a slow worker the same way).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..checkpoint.manager import CheckpointManager
from ..configs.base import ArchConfig
from ..models import transformer
from ..optim import adamw
from . import step as step_mod


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    microbatches: int = 1
    straggler_factor: float = 3.0
    seed: int = 0
    param_dtype: Any = jnp.float32


class Trainer:
    def __init__(self, cfg: ArchConfig, opt_cfg: adamw.OptimizerConfig,
                 tcfg: TrainerConfig, mesh=None, shardings=None):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir)
                     if tcfg.ckpt_dir else None)
        self.step_fn = jax.jit(step_mod.make_train_step(
            cfg, opt_cfg, microbatches=tcfg.microbatches))
        self.metrics_log: List[Dict[str, float]] = []
        self.straggler_steps: List[int] = []

    # ------------------------------------------------------------------
    def init_state(self):
        key = jax.random.PRNGKey(self.tcfg.seed)
        params = transformer.init_params(self.cfg, key,
                                         dtype=self.tcfg.param_dtype)
        opt_state = adamw.init(params)
        return params, opt_state

    def restore_or_init(self):
        params, opt_state = self.init_state()
        start_step = 0
        if self.ckpt is not None:
            latest = self.ckpt.latest_step()
            if latest is not None:
                trees, manifest = self.ckpt.restore(
                    latest, {"params": params, "opt": opt_state})
                params, opt_state = trees["params"], trees["opt"]
                start_step = manifest["step"]
        return params, opt_state, start_step

    # ------------------------------------------------------------------
    def fit(self, data, start_step: Optional[int] = None):
        params, opt_state, resumed = self.restore_or_init()
        step0 = resumed if start_step is None else start_step

        if self.ckpt is not None:
            state_ref = {"params": params, "opt": opt_state, "step": step0}
            self.ckpt.install_signal_handler(
                lambda: self.ckpt.save(state_ref["step"],
                                       {"params": state_ref["params"],
                                        "opt": state_ref["opt"]},
                                       extra={"emergency": True}))

        ewma = None
        for step in range(step0, self.tcfg.steps):
            batch_np = data.batch_at(step)
            batch = jax.tree.map(jnp.asarray, batch_np)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0

            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > self.tcfg.straggler_factor * ewma and step > step0 + 3:
                self.straggler_steps.append(step)

            if step % self.tcfg.log_every == 0 or step == self.tcfg.steps - 1:
                rec = {"step": step,
                       "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "lr": float(metrics["lr"]),
                       "sec_per_step": dt}
                self.metrics_log.append(rec)

            if self.ckpt is not None:
                state_ref = {"params": params, "opt": opt_state,
                             "step": step + 1}
                if (step + 1) % self.tcfg.ckpt_every == 0:
                    self.ckpt.save(step + 1,
                                   {"params": params, "opt": opt_state},
                                   extra={"data_step": step + 1})

        if self.ckpt is not None:
            self.ckpt.save(self.tcfg.steps,
                           {"params": params, "opt": opt_state},
                           extra={"data_step": self.tcfg.steps,
                                  "final": True})
        return params, opt_state
