"""Train / serve step factories.

``make_train_step`` builds the jit-able update: loss → grads → AdamW, with
optional gradient accumulation over microbatches (a lax.scan over batch
slices — the §Perf memory lever: peak activation memory scales with
B/microbatches while arithmetic is unchanged).

``make_serve_step`` builds the single-token decode step (greedy or
temperature sampling) used by the serving engine and the decode dry-runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import transformer
from ..optim import adamw


def make_loss_fn(cfg: ArchConfig):
    def loss(params, batch):
        return transformer.loss_fn(params, cfg, batch)
    return loss


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.OptimizerConfig,
                    microbatches: int = 1, unroll_accum: bool = False):
    """unroll_accum: accumulate microbatches in a Python loop instead of
    lax.scan — works around an XLA SPMD partitioner fault when a D-sharded
    embedding gather (the vocab∤16 fallback, e.g. mamba2's 50280) is
    resharded inside a while-loop body (hlo-verifier dynamic-slice error)."""
    loss_fn = make_loss_fn(cfg)

    def train_step(params, opt_state, batch):
        if microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def to_mb(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mbs = jax.tree.map(to_mb, batch)

            def acc(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                    gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            if unroll_accum:
                carry = (g0, jnp.float32(0.0))
                for i in range(microbatches):
                    mb = jax.tree.map(lambda x: x[i], mbs)
                    carry, _ = acc(carry, mb)
                gsum, lsum = carry
            else:
                (gsum, lsum), _ = jax.lax.scan(acc, (g0, jnp.float32(0.0)),
                                               mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {"loss": loss}

        new_params, new_opt, opt_metrics = adamw.update(
            opt_cfg, grads, opt_state, params)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return new_params, new_opt, metrics

    return train_step


def make_serve_step(cfg: ArchConfig, temperature: float = 0.0):
    def serve_step(params, token, cache, cache_len, rng):
        logits, cache = transformer.decode_step(params, cfg, token, cache,
                                                cache_len)
        if temperature > 0.0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), cache, logits
    return serve_step


def make_prefill(cfg: ArchConfig):
    def prefill_step(params, batch):
        return transformer.prefill(params, cfg, batch)
    return prefill_step
