"""Fused multi-query device enumeration (DESIGN.md §9).

The batch engine's device path used to run one query at a time: each
query's chunk walk issued its own sequence of kernel dispatches, so an
async micro-batch of N device-eligible queries paid N dispatch streams.
This driver packs the frontier walks of many queries into *fused
launches*: every expansion round pops one chunk from each active
query's LIFO deque, tags the rows with the query's member rank, and
expands them all through ONE ``ops.frontier_expand_fused`` dispatch
(tests/test_fused_launch.py asserts the launch count).

Per-query semantics are `core.enumerate._drive`'s, replicated exactly:

  * each query owns its own LIFO work deque, popped in the same order
    as a solo run (rounds interleave queries, but one query's chunk
    sequence — and therefore its ``stats.chunks``, emission blocks and
    ``first_n`` prefix — is untouched by its co-tenants);
  * the zero-fanout host shortcut, chunk_size splitting with reversed
    pushes, per-chunk ``first_n`` trim, canonical exhausted sort and
    the cooperative deadline all match the solo driver;
  * Fig.-6 counters come back as per-member rows of the fused kernel's
    (m, 4) counter matrix, bit-identical to each query's solo run.

Queries with constraints, ranked order or a non-dfs plan never reach
this module — `core.batch.BatchPathEnum` gates eligibility and falls
back to the solo per-query path (DESIGN.md §9 fallback matrix).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from . import clock
from .enumerate import (DEVICE_SLOT_BUDGET, EnumResult, EnumStats,
                        _fanout_segments, _finalize, _trim_to_first_n)
from .graph import PAD
from .index import LightweightIndex


class _MemberState:
    """One query's private driver state inside a fused run."""
    __slots__ = ("idx", "dev", "stats", "out_paths", "out_lens", "count",
                 "work", "result")

    def __init__(self, idx: LightweightIndex) -> None:
        self.idx = idx
        self.dev = idx.device_arrays()
        self.stats = EnumStats()
        self.out_paths: List[np.ndarray] = []
        self.out_lens: List[np.ndarray] = []
        self.count = 0
        root = np.full((1, idx.k + 1), PAD, dtype=np.int32)
        root[0, 0] = idx.s
        self.work: List[Tuple[np.ndarray, int]] = [(root, 0)]
        self.result: Optional[EnumResult] = None

    def finish(self, exhausted: bool, canonical: bool = False) -> None:
        self.result = _finalize(self.idx, self.out_paths, self.out_lens,
                                self.count, self.stats, exhausted=exhausted,
                                canonical=canonical)


def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length() if x > 1 else 1


def enumerate_fused_device(
    indexes: List[LightweightIndex],
    chunk_size: int = 16384,
    count_only: bool = False,
    first_n: Optional[int] = None,
    deadline: Optional[float] = None,
) -> List[EnumResult]:
    """Enumerate many queries' P(s,t,k,G) through fused device launches.

    Returns one ``EnumResult`` per index, in input order, each
    byte-identical (paths, count, stats, chunk accounting) to a solo
    ``enumerate_paths_idx(idx, backend="device")`` run — the fusion
    changes dispatch granularity, never per-query semantics.  All
    indexes must come from one graph (equal ``n``).  ``first_n`` is
    per-query (each member trims and finishes independently); the
    ``deadline`` (absolute ``core.clock.now()``) is checked once per
    fused round, finalizing every unfinished member with
    ``exhausted=False``.
    """
    from ..kernels import ops as kops   # lazy: pallas only on this path
    import jax.numpy as jnp
    if not indexes:
        return []
    n = indexes[0].n
    if any(ix.n != n for ix in indexes):
        raise ValueError("fused launches require one common graph")
    states = [_MemberState(ix) for ix in indexes]
    k1max = max(ix.k for ix in indexes) + 1
    mfm = _next_pow2(max(int(st.dev.dst.shape[0]) for st in states))

    while True:
        active = [st for st in states if st.result is None]
        if not active:
            break
        if deadline is not None and clock.expired(deadline):
            for st in active:
                st.finish(exhausted=False)
            break

        # pop one chunk per active member; the host zero-fanout shortcut
        # (solo: _device_step returns None without a launch) keeps dead
        # chunks out of the dispatch entirely
        members: List[Tuple[_MemberState, np.ndarray, int, np.ndarray]] = []
        for st in active:
            paths, depth = st.work.pop()
            st.stats.chunks += 1
            k = st.idx.k
            last = paths[:, depth].astype(np.int64)
            b = k - depth - 1
            cnt = (st.idx.fwd_end[last, b] - st.idx.fwd_begin[last]) \
                if b >= 0 else np.zeros(paths.shape[0], np.int64)
            if int(cnt.sum()) == 0:
                st.stats.invalid_partials += paths.shape[0]
                if not st.work:
                    st.finish(exhausted=True, canonical=True)
                continue
            members.append((st, paths, depth, cnt))
        if not members:
            continue

        packed, ranks, cnts = [], [], []
        for i, (st, paths, depth, cnt) in enumerate(members):
            if paths.shape[1] < k1max:
                paths = np.pad(paths,
                               ((0, 0), (0, k1max - paths.shape[1])),
                               constant_values=PAD)
            packed.append(paths)
            ranks.append(np.full(paths.shape[0], i, np.int32))
            cnts.append(cnt)
        packed_paths = np.concatenate(packed, axis=0)
        rank = np.concatenate(ranks)
        packed_cnt = np.concatenate(cnts)

        m = _next_pow2(len(members))
        tvec = np.full(m, -1, np.int32)
        depthv = np.zeros(m, np.int32)
        wantc = np.zeros(m, bool)
        begin_parts: List[object] = []
        endb_parts: List[object] = []
        dst_parts: List[object] = []
        for i, (st, _paths, depth, _cnt) in enumerate(members):
            k = st.idx.k
            tvec[i] = st.idx.t
            depthv[i] = depth
            wantc[i] = depth + 1 < k
            begin_parts.append(st.dev.begin)
            endb_parts.append(st.dev.end[:, k - depth - 1])
            mf = int(st.dev.dst.shape[0])
            dst_parts.append(jnp.pad(st.dev.dst, (0, mfm - mf),
                                     constant_values=PAD)
                             if mf < mfm else st.dev.dst)
        zero_col = jnp.zeros((n,), jnp.int32)
        pad_dst = jnp.full((mfm,), PAD, jnp.int32)
        for _ in range(m - len(members)):
            begin_parts.append(zero_col)
            endb_parts.append(zero_col)
            dst_parts.append(pad_dst)
        begin_flat = jnp.concatenate(begin_parts)
        endb_flat = jnp.concatenate(endb_parts)
        dst_flat = jnp.concatenate(dst_parts)

        # the solo path's slot-budget segmentation, over the packed rows:
        # a hub member splits the round into several dispatches exactly
        # as it would have split its own solo chunk
        emit_parts: List[List[np.ndarray]] = [[] for _ in members]
        cont_parts: List[List[np.ndarray]] = [[] for _ in members]
        for lo, hi in _fanout_segments(packed_cnt, DEVICE_SLOT_BUDGET):
            emit_rows, cont_rows, n_emit_m, n_cont_m, counters = \
                kops.frontier_expand_fused(
                    packed_paths[lo:hi], rank[lo:hi], tvec, depthv,
                    begin_flat, endb_flat, dst_flat, wantc,
                    max_deg=max(int(packed_cnt[lo:hi].max()), 1))
            ne_m = np.asarray(n_emit_m).astype(np.int64)
            nc_m = np.asarray(n_cont_m).astype(np.int64)
            ctr = np.asarray(counters)
            e_lo = np.concatenate([[0], np.cumsum(ne_m)[:-1]])
            c_lo = np.concatenate([[0], np.cumsum(nc_m)[:-1]])
            emit_np = np.asarray(emit_rows)
            cont_np = np.asarray(cont_rows)
            for i, (st, _paths, _depth, _cnt) in enumerate(members):
                st.stats.edges_accessed += int(ctr[i, 0])
                st.stats.partials_generated += int(ctr[i, 1])
                st.stats.invalid_partials += int(ctr[i, 2])
                w = st.idx.k + 1
                if ne_m[i]:
                    emit_parts[i].append(
                        emit_np[e_lo[i]:e_lo[i] + ne_m[i], :w])
                if nc_m[i]:
                    cont_parts[i].append(
                        cont_np[c_lo[i]:c_lo[i] + nc_m[i], :w])

        # per-member driver tail — the exact _drive emit/push sequence
        for i, (st, _paths, depth, _cnt) in enumerate(members):
            if emit_parts[i]:
                emit_cat = np.concatenate(emit_parts[i], axis=0)
                st.count += emit_cat.shape[0]
                st.stats.results += emit_cat.shape[0]
                if not count_only:
                    st.out_paths.append(emit_cat)
                    st.out_lens.append(np.full(emit_cat.shape[0],
                                               depth + 1, np.int32))
                if first_n is not None and st.count >= first_n:
                    st.count = _trim_to_first_n(
                        st.out_paths, st.out_lens, st.count, first_n,
                        count_only, st.stats)
                    st.finish(exhausted=False)
                    continue
            if cont_parts[i]:
                cont_cat = np.concatenate(cont_parts[i], axis=0)
                pieces = range(0, cont_cat.shape[0], chunk_size)
                for piece in reversed(list(pieces)):
                    st.work.append(
                        (cont_cat[piece:piece + chunk_size], depth + 1))
            if not st.work:
                st.finish(exhausted=True, canonical=True)

    return [st.result for st in states]  # type: ignore[misc]
