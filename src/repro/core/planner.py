"""Cost-based query optimizer (Section 6 / Figure 2).

Two-phase, exactly as the paper:
  1. preliminary estimator (Eq. 5, O(k²)) — if T̂ ≤ τ, go straight to
     IDX-DFS (short queries mustn't pay optimization overhead);
  2. otherwise run the full-fledged DP (Alg. 5), find the cut i*, compare
     T_DFS = Σ|Q[0:i]| against T_JOIN = |Q| + … (§6.3), pick the cheaper.

τ defaults to 1e5, the value the paper calibrates in §6.2 (time to find 1e5
results ≈ optimization time on their workloads); ``calibrate_tau`` re-runs
the paper's calibration procedure on this machine.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

from . import estimator as est
from .index import LightweightIndex
from .join import hop_count_dp

DEFAULT_TAU = 1e5


@dataclasses.dataclass
class Plan:
    method: str                 # "dfs" | "join"
    cut: Optional[int]          # i* when method == "join"
    preliminary: float          # T̂ from Eq. 5
    used_full_estimator: bool
    t_dfs: Optional[float] = None
    t_join: Optional[float] = None
    est_results: Optional[float] = None
    dp: Optional[est.WalkCountDP] = None
    optimize_seconds: float = 0.0


def plan_query(index: LightweightIndex, tau: float = DEFAULT_TAU,
               backend: Optional[str] = None) -> Plan:
    """Two-phase plan for one query.  ``backend`` (host|device|auto, §9)
    picks where the full-fledged DP runs when the τ gate trips — the
    device leg is the semiring-kernel build of join.hop_count_dp, which
    is bit-identical to the host build (it promotes itself to the host
    on f32 overflow), so the *plan* never depends on the backend, only
    the derivation cost does.  The O(k²) preliminary estimate is host
    scalar math always."""
    t0 = time.perf_counter()
    t_hat = est.preliminary_estimate(index)
    if t_hat <= tau:
        return Plan(method="dfs", cut=None, preliminary=t_hat,
                    used_full_estimator=False,
                    optimize_seconds=time.perf_counter() - t0)

    dp = hop_count_dp(index, backend)
    cut = dp.cut
    # a cut at the boundary degenerates to the left-deep plan
    if cut <= 0 or cut >= index.k or dp.t_dfs <= dp.t_join:
        return Plan(method="dfs", cut=None, preliminary=t_hat,
                    used_full_estimator=True, t_dfs=dp.t_dfs,
                    t_join=dp.t_join, est_results=dp.q_total, dp=dp,
                    optimize_seconds=time.perf_counter() - t0)
    return Plan(method="join", cut=cut, preliminary=t_hat,
                used_full_estimator=True, t_dfs=dp.t_dfs, t_join=dp.t_join,
                est_results=dp.q_total, dp=dp,
                optimize_seconds=time.perf_counter() - t0)


def calibrate_tau(graph, queries, k: int = 6, start: float = 10.0,
                  limit: float = 1e7) -> float:
    """The paper's τ calibration (§6.2): grow τ by 10× until the time to find
    τ results exceeds the join-plan optimization time for most queries."""
    from .index import build_index
    from .enumerate import enumerate_paths_idx

    tau = start
    while tau < limit:
        slower = 0
        for (s, t) in queries:
            idx = build_index(graph, s, t, k)
            t0 = time.perf_counter()
            est.walk_count_dp(idx)
            opt_time = time.perf_counter() - t0
            t0 = time.perf_counter()
            try:
                enumerate_paths_idx(idx, first_n=int(tau), count_only=False)
            except Exception:
                pass
            enum_time = time.perf_counter() - t0
            if enum_time > opt_time:
                slower += 1
        if slower >= len(queries) * 0.5:
            return tau
        tau *= 10
    return tau
