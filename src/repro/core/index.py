"""The light-weight query-dependent index (Section 4.2 / Algorithm 3).

Semantics preserved exactly:
  * ``dist_s[v] = S(s, v | G - {t})`` and ``dist_t[v] = S(v, t | G - {s})``
    (two bounded BFS passes, bfs.py).
  * level sets ``C_i = {v : dist_s[v] <= i  and  dist_t[v] <= k - i}``.
  * ``I_t(v, b)``: out-neighbors v' of v with ``dist_t[v'] <= b`` in O(1) —
    edges are kept only when ``dist_s[u] + 1 + dist_t[v] <= k`` (the paper's
    hash-table H membership rule), sorted by ``(u, dist_t[v])`` and addressed
    through a dense ``(n, k+1)`` end-offset matrix.
  * ``I_s(v, b)``: symmetric reverse index sorted by ``(v, dist_s[u])`` —
    used by the backward DP of Algorithm 5.

TPU adaptation (recorded in DESIGN.md §2): the paper's hash table + counting
sort become one lexsort + scatter-add histogram + cumulative sum; lookups
stay O(1) via the offset matrix.  ``build_index`` is the host (numpy) build;
``build_index_jax`` is the jit-compatible build with identical outputs
(tests/test_index.py asserts bit-equality), enabling on-device index
construction when queries are sharded across a mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import bfs
from .graph import Graph


@dataclasses.dataclass
class DeviceIndexArrays:
    """Device (int32) copies of the forward index for the Pallas frontier
    kernel (DESIGN.md §9): ``begin`` (n,), ``end`` (n, k+1) and ``dst``
    (mf,).  ``dst`` is padded to at least one element so the kernel's
    gather always has a valid extent; rows/fan-out padding is the
    kernel wrapper's job (kernels/ops.frontier_expand)."""
    begin: jnp.ndarray
    end: jnp.ndarray
    dst: jnp.ndarray


@dataclasses.dataclass
class LightweightIndex:
    n: int
    k: int
    s: int
    t: int
    dist_s: np.ndarray        # (n,) int32, sentinel k+1
    dist_t: np.ndarray        # (n,) int32, sentinel k+1
    # forward: edges (u -> v) sorted by (u, dist_t[v]); only index edges kept
    fwd_dst: np.ndarray       # (mf,) int32
    fwd_eid: np.ndarray       # (mf,) int64 — original edge id (constraints ext.)
    fwd_begin: np.ndarray     # (n,) int64
    fwd_end: np.ndarray       # (n, k+1) int64 — end offset for budget b
    # reverse: edges (u -> v) sorted by (v, dist_s[u])
    rev_src: np.ndarray       # (mf,) int32
    rev_begin: np.ndarray     # (n,) int64
    rev_end: np.ndarray       # (n, k+1) int64 — end offset for budget b
    level_count: np.ndarray   # (k+1,) int64 — |C_i|
    gamma: np.ndarray         # (k,) float64 — gamma_hat_j (Eq. 5 statistic)

    # -- O(1) lookups (host convenience; jitted code uses the arrays directly)
    def it(self, v: int, b: int) -> np.ndarray:
        """I_t(v, b): neighbors v' of v with dist_t[v'] <= b."""
        if b < 0:
            return self.fwd_dst[0:0]
        b = min(b, self.k)
        return self.fwd_dst[self.fwd_begin[v]:self.fwd_end[v, b]]

    def is_(self, v: int, b: int) -> np.ndarray:
        """I_s(v, b): in-neighbors v' of v with dist_s[v'] <= b."""
        if b < 0:
            return self.rev_src[0:0]
        b = min(b, self.k)
        return self.rev_src[self.rev_begin[v]:self.rev_end[v, b]]

    def level(self, i: int) -> np.ndarray:
        """I(i) = C_i as a vertex-id array."""
        mask = (self.dist_s <= i) & (self.dist_t <= self.k - i)
        return np.nonzero(mask)[0].astype(np.int32)

    def it_count(self, v, b) -> np.ndarray:
        """|I_t(v, b)| vectorized over v (b scalar)."""
        if b < 0:
            return np.zeros(np.shape(v), dtype=np.int64)
        b = min(b, self.k)
        return self.fwd_end[v, b] - self.fwd_begin[v]

    @property
    def num_index_edges(self) -> int:
        return int(self.fwd_dst.shape[0])

    def device_arrays(self) -> DeviceIndexArrays:
        """The forward index as int32 device arrays for the frontier
        kernel, built once and cached on the index (indexes are immutable
        once built, DESIGN.md §9).  ``dst`` pads to the next power of two
        with an inert −1 fill: its length is a traced shape of the jitted
        kernel, so bucketing it keeps recompiles logarithmic in index
        size instead of one per distinct (s, t, k) query."""
        cached = self.__dict__.get("_device_arrays")
        if cached is None:
            mf = max(int(self.fwd_dst.shape[0]), 1)
            mf_pad = 1 << (mf - 1).bit_length()
            dst = np.full(mf_pad, -1, np.int32)
            dst[: self.fwd_dst.shape[0]] = self.fwd_dst
            cached = DeviceIndexArrays(
                begin=jnp.asarray(self.fwd_begin.astype(np.int32)),
                end=jnp.asarray(self.fwd_end.astype(np.int32)),
                dst=jnp.asarray(dst))
            self.__dict__["_device_arrays"] = cached
        return cached

    def memory_bytes(self) -> int:
        tot = 0
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, np.ndarray):
                tot += v.nbytes
        return tot


def _offsets_from_sorted(keys_primary: np.ndarray, keys_secondary: np.ndarray,
                         n: int, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """begin (n,), end (n, k+1) over arrays already sorted by (primary, sec)."""
    # fusing (primary, clipped secondary) into one key turns both tables
    # into searchsorted lookups — begin[v] counts edges with primary < v,
    # end[v, b] additionally admits primary == v with secondary <= b —
    # replacing the dense (n, k+2) scatter + cumsum passes, which dominate
    # the build for sparse selections (the common case for group members,
    # DESIGN.md §13)
    width = np.int64(k + 2)
    fused = (keys_primary.astype(np.int64) * width
             + np.minimum(keys_secondary.astype(np.int64), k + 1))
    grid = np.arange(n, dtype=np.int64) * width
    begin = np.searchsorted(fused, grid, side="left")
    probes = grid[:, None] + np.arange(k + 1, dtype=np.int64)[None, :]
    end = np.searchsorted(fused, probes.reshape(-1),
                          side="right").reshape(n, k + 1)
    return begin, end


def build_index(graph: Graph, s: int, t: int, k: int,
                dist_fn=bfs.index_distances_np,
                edge_mask: Optional[np.ndarray] = None) -> LightweightIndex:
    """Algorithm 3, host build.

    ``edge_mask`` implements the Appendix-E predicate extension: edges whose
    mask entry is False are filtered before the distance BFS, so constrained
    queries reuse the whole machinery unchanged.
    """
    g = graph
    if edge_mask is not None:
        keep = np.asarray(edge_mask, dtype=bool)
        edges = np.stack([g.esrc[keep], g.edst[keep]], axis=1)
        from .graph import from_edges
        g = from_edges(g.n, edges, dedup=False)
    dist_s, dist_t = dist_fn(g, s, t, k)
    dist_s = np.asarray(dist_s, dtype=np.int32)
    dist_t = np.asarray(dist_t, dtype=np.int32)

    u, v = g.esrc.astype(np.int64), g.edst.astype(np.int64)
    # distance rule (Prop 4.3) + relation-construction rules of §3.1:
    # no edge re-enters s (middle relations live in G-{s}, R_k demands v≠s)
    # and no edge leaves t (only the virtual (t,t) padding, handled by the
    # join enumerator explicitly).
    keep = ((dist_s[u] + 1 + dist_t[v]) <= k) & (v != s) & (u != t)
    keep_ids = np.nonzero(keep)[0]
    fu, fv = u[keep], v[keep]

    # forward: sort by (u, dist_t[v])
    order_f = np.lexsort((dist_t[fv], fu))
    fu_s, fv_s = fu[order_f], fv[order_f]
    fwd_eid = keep_ids[order_f]
    fwd_begin, fwd_end = _offsets_from_sorted(fu_s, dist_t[fv_s], g.n, k)

    # reverse: sort by (v, dist_s[u])
    order_r = np.lexsort((dist_s[fu], fv))
    ru_s, rv_s = fu[order_r], fv[order_r]
    rev_begin, rev_end = _offsets_from_sorted(rv_s, dist_s[ru_s], g.n, k)

    ii = np.arange(k + 1)
    lvl = (dist_s[None, :] <= ii[:, None]) & (dist_t[None, :] <= (k - ii)[:, None])
    level_count = lvl.sum(axis=1).astype(np.int64)

    gamma = np.zeros(k, dtype=np.float64)
    for j in range(k):
        cj = np.nonzero(lvl[j])[0]
        if cj.size:
            b = k - j - 1
            cnts = fwd_end[cj, b] - fwd_begin[cj]
            gamma[j] = float(cnts.mean())

    return LightweightIndex(
        n=g.n, k=k, s=s, t=t, dist_s=dist_s, dist_t=dist_t,
        fwd_dst=fv_s.astype(np.int32), fwd_eid=fwd_eid,
        fwd_begin=fwd_begin, fwd_end=fwd_end,
        rev_src=ru_s.astype(np.int32), rev_begin=rev_begin, rev_end=rev_end,
        level_count=level_count, gamma=gamma)


# ---------------------------------------------------------------------------
# jit-compatible build (identical outputs, static shapes)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n", "k"))
def _build_index_jax(esrc, edst, n: int, k: int, s, t):
    INF = jnp.int32(k + 1)
    dist_s = bfs.bfs_edge_relax(esrc, edst, n, k, s, t)
    dist_t = bfs.bfs_edge_relax(edst, esrc, n, k, t, s)

    u = esrc.astype(jnp.int32)
    v = edst.astype(jnp.int32)
    keep = ((dist_s[u] + 1 + dist_t[v]) <= k) & (v != s) & (u != t)
    # invalid edges sort to the end: primary key n, secondary k+1
    pf = jnp.where(keep, u, n)
    sf = jnp.where(keep, dist_t[v], k + 1)
    order_f = jnp.lexsort((sf, pf))
    fv_s = jnp.where(keep[order_f], v[order_f], -1)
    fu_s = pf[order_f]
    feid = jnp.where(keep[order_f], order_f, -1)

    def offsets(primary, secondary):
        cnt2d = jnp.zeros((n + 1, k + 2), dtype=jnp.int32)
        sec = jnp.minimum(secondary, k + 1)
        cnt2d = cnt2d.at[primary, sec].add(1)
        cnt2d = cnt2d[:n]
        per_v = cnt2d.sum(axis=1)
        begin = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(per_v)[:-1]])
        end = begin[:, None] + jnp.cumsum(cnt2d[:, : k + 1], axis=1)
        return begin, end

    fwd_begin, fwd_end = offsets(fu_s, jnp.where(fv_s >= 0, dist_t[fv_s], k + 1))

    pr = jnp.where(keep, v, n)
    sr = jnp.where(keep, dist_s[u], k + 1)
    order_r = jnp.lexsort((sr, pr))
    ru_s = jnp.where(keep[order_r], u[order_r], -1)
    rv_s = pr[order_r]
    rev_begin, rev_end = offsets(rv_s, jnp.where(ru_s >= 0, dist_s[ru_s], k + 1))

    ii = jnp.arange(k + 1)
    lvl = (dist_s[None, :] <= ii[:, None]) & (dist_t[None, :] <= (k - ii)[:, None])
    level_count = lvl.sum(axis=1)

    jj = jnp.arange(k)
    budgets = k - jj - 1  # (k,)
    cnt_all = fwd_end[:, :] - fwd_begin[:, None]          # (n, k+1)
    sel = cnt_all[:, budgets].T.astype(jnp.float32)       # (k, n)
    gsum = jnp.where(lvl[:k], sel, 0.0).sum(axis=1)
    gamma = gsum / jnp.maximum(level_count[:k].astype(jnp.float32), 1.0)

    return (dist_s, dist_t, fv_s, feid, fwd_begin, fwd_end, ru_s, rev_begin,
            rev_end, level_count, gamma)


def build_index_jax(graph: Graph, s: int, t: int, k: int) -> LightweightIndex:
    out = _build_index_jax(jnp.asarray(graph.esrc), jnp.asarray(graph.edst),
                           graph.n, k, jnp.int32(s), jnp.int32(t))
    (dist_s, dist_t, fv_s, feid, fwd_begin, fwd_end, ru_s, rev_begin, rev_end,
     level_count, gamma) = map(np.asarray, out)
    mf = int((fv_s >= 0).sum())
    return LightweightIndex(
        n=graph.n, k=k, s=s, t=t,
        dist_s=dist_s.astype(np.int32), dist_t=dist_t.astype(np.int32),
        fwd_dst=fv_s[:mf].astype(np.int32), fwd_eid=feid[:mf].astype(np.int64),
        fwd_begin=fwd_begin.astype(np.int64), fwd_end=fwd_end.astype(np.int64),
        rev_src=ru_s[:mf].astype(np.int32),
        rev_begin=rev_begin.astype(np.int64), rev_end=rev_end.astype(np.int64),
        level_count=level_count.astype(np.int64), gamma=gamma.astype(np.float64))
