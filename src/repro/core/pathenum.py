"""PathEnum facade — Figure 2's pipeline as a single entry point.

    index build  →  preliminary estimate  →  (maybe) full DP + cut  →
    IDX-DFS or IDX-JOIN  →  PathBatch

`PathEnum.query` is the paper's q(s,t,k); constrained variants pass an
Appendix-E constraint object.  All stages expose their timings so the
benchmark harness can reproduce the paper's breakdowns (Fig. 7 / Fig. 17).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from . import planner as planner_mod
from .enumerate import EnumResult, enumerate_paths_idx
from .graph import Graph
from .index import LightweightIndex, build_index, build_index_jax
from .join import enumerate_paths_join
from .planner import DEFAULT_TAU, Plan


@dataclasses.dataclass
class QueryTiming:
    index_seconds: float = 0.0
    optimize_seconds: float = 0.0
    enumerate_seconds: float = 0.0

    @property
    def total_seconds(self) -> float:
        return self.index_seconds + self.optimize_seconds + self.enumerate_seconds


@dataclasses.dataclass
class QueryOutput:
    result: EnumResult
    plan: Plan
    index: LightweightIndex
    timing: QueryTiming


class PathEnum:
    """Engine facade.  mode: "auto" (paper's optimizer), "dfs", "join".

    ``backend`` selects where device-capable stages run (DESIGN.md §9):
    "host" (numpy, default), "device" (Pallas kernels) or "auto".  It
    steers both the IDX-DFS frontier expansion (frontier kernel) and the
    join/count plan's hop-count DP (semiring kernels, via
    join.hop_count_dp); the join's sort-merge enumeration itself stays on
    the host.  Results and plans are bit-identical across backends.
    """

    def __init__(self, tau: float = DEFAULT_TAU, chunk_size: int = 16384,
                 use_jax_index: bool = False,
                 max_partials: Optional[int] = 20_000_000,
                 backend: str = "host"):
        self.tau = tau
        self.chunk_size = chunk_size
        self.use_jax_index = use_jax_index
        self.max_partials = max_partials
        self.backend = backend

    def build(self, graph: Graph, s: int, t: int, k: int,
              edge_mask=None) -> LightweightIndex:
        if self.use_jax_index and edge_mask is None:
            return build_index_jax(graph, s, t, k)
        return build_index(graph, s, t, k, edge_mask=edge_mask)

    def query(self, graph: Graph, s: int, t: int, k: int,
              mode: str = "auto", count_only: bool = False,
              first_n: Optional[int] = None, constraint=None,
              edge_mask=None, cut: Optional[int] = None,
              backend: Optional[str] = None,
              order: Optional[str] = None,
              weights: Optional[np.ndarray] = None,
              deadline: Optional[float] = None) -> QueryOutput:
        """Run q(s,t,k) and return paths, plan, index and timings.

        ``order`` requests ranked (any-k) enumeration (DESIGN.md §10):
        ``"hops"`` ranks by hop count, ``"weight"`` by edge-weight sum
        (``weights``: one non-negative float per graph edge), both with
        the lexicographic vertex sequence as tie-break, so every
        mode/backend returns the identical ordered list.  Under ranked
        order, ``first_n`` means the top-n and a ``deadline`` (absolute
        ``core.clock.now()``) truncation is a rank-optimal prefix.
        """
        if k < 2:
            raise ValueError("paper assumes k >= 2")
        timing = QueryTiming()
        t0 = time.perf_counter()
        idx = self.build(graph, s, t, k, edge_mask=edge_mask)
        timing.index_seconds = time.perf_counter() - t0

        if mode == "auto":
            plan = planner_mod.plan_query(idx, tau=self.tau,
                                          backend=backend or self.backend)
        elif mode == "dfs":
            plan = Plan(method="dfs", cut=None, preliminary=-1.0,
                        used_full_estimator=False)
        elif mode == "join":
            if cut is None:
                dp_plan = planner_mod.plan_query(idx, tau=-1.0,
                                                 backend=backend
                                                 or self.backend)
                cut = dp_plan.cut if dp_plan.cut else max(1, k // 2)
            plan = Plan(method="join", cut=cut, preliminary=-1.0,
                        used_full_estimator=True)
        else:
            raise ValueError(f"unknown mode {mode!r}")
        timing.optimize_seconds = plan.optimize_seconds

        t0 = time.perf_counter()
        if plan.method == "dfs":
            res = enumerate_paths_idx(idx, chunk_size=self.chunk_size,
                                      count_only=count_only, first_n=first_n,
                                      constraint=constraint,
                                      backend=backend or self.backend,
                                      order=order, weights=weights,
                                      deadline=deadline)
        else:
            res = enumerate_paths_join(idx, cut=plan.cut,
                                       count_only=count_only,
                                       first_n=first_n,
                                       max_partials=self.max_partials,
                                       constraint=constraint,
                                       order=order, weights=weights,
                                       deadline=deadline)
        timing.enumerate_seconds = time.perf_counter() - t0
        return QueryOutput(result=res, plan=plan, index=idx, timing=timing)

    def count(self, graph: Graph, s: int, t: int, k: int, **kw) -> int:
        return self.query(graph, s, t, k, count_only=True, **kw).result.count
