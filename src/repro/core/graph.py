"""Graph container + generators for the PathEnum engine.

The engine's canonical representation is a static CSR pair (forward and
reverse) plus flat edge lists.  Vertices are int32 ids in [0, n).  All arrays
are host numpy; ``DeviceGraph`` mirrors them as jnp arrays for the jitted /
distributed paths.  Distances are bounded by the hop constraint ``k`` so the
sentinel ``INF_DIST`` is any value > k; we use 0x3FFF_FFFF to stay addition-
safe in int32.

Graphs are immutable values, but deployments stream (DESIGN.md §12): a
fraud graph ingests live transactions between queries.  Mutation is
therefore *versioned copying* — ``with_edges`` (and the ``add_edges`` /
``remove_edges`` conveniences) rebuild the CSR around the new edge set
and return a new ``Graph`` whose monotone ``version`` is bumped by one.
Every index-cache key derived from a graph folds the version in
(core/batch.py), so an index built against version v can never answer a
query against version v+1 — the streaming invalidation contract.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

INF_DIST = np.int32(0x3FFFFFFF)
PAD = np.int32(-1)


@dataclasses.dataclass(frozen=True)
class Graph:
    """Directed graph in CSR (forward + reverse) with flat edge lists.

    ``version`` is the streaming-mutation epoch (DESIGN.md §12): 0 for a
    freshly built graph, and bumped by one on every ``with_edges`` /
    ``add_edges`` / ``remove_edges`` copy.  It is monotone per mutation
    *lineage* — the engine folds it into every index-cache key, so
    pre-mutation indexes are unreachable the instant a mutated copy
    starts serving.
    """

    n: int
    # forward CSR
    indptr: np.ndarray    # (n+1,) int64
    indices: np.ndarray   # (m,)   int32, dst sorted within each src slice
    # reverse CSR
    rindptr: np.ndarray   # (n+1,) int64
    rindices: np.ndarray  # (m,)   int32
    # flat edge list (same order as forward CSR)
    esrc: np.ndarray      # (m,) int32
    edst: np.ndarray      # (m,) int32
    # streaming-mutation epoch (DESIGN.md §12); part of the cache key
    version: int = 0

    @property
    def m(self) -> int:
        return int(self.indices.shape[0])

    def out_degree(self, v: int) -> int:
        return int(self.indptr[v + 1] - self.indptr[v])

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def in_neighbors(self, v: int) -> np.ndarray:
        return self.rindices[self.rindptr[v]:self.rindptr[v + 1]]

    def reverse(self) -> "Graph":
        return Graph(self.n, self.rindptr, self.rindices, self.indptr,
                     self.indices, self.rindices_src(), self.redst())

    def rindices_src(self) -> np.ndarray:
        return np.repeat(np.arange(self.n, dtype=np.int32),
                         np.diff(self.rindptr).astype(np.int64))

    def redst(self) -> np.ndarray:
        return self.rindices

    # -- streaming mutation (DESIGN.md §12) ---------------------------------

    def edge_list(self) -> np.ndarray:
        """The edge set as an (m, 2) int64 array in forward-CSR order."""
        return np.stack([self.esrc.astype(np.int64),
                         self.edst.astype(np.int64)], axis=1)

    def with_edges(self, add: Optional[np.ndarray] = None,
                   remove: Optional[np.ndarray] = None) -> "Graph":
        """Versioned copy with ``add`` edges inserted and ``remove``
        edges deleted (DESIGN.md §12).

        Both arguments are (r, 2) arrays of directed ``(src, dst)``
        pairs; endpoints must lie in [0, n).  Removals run first, then
        insertions, so passing the same edge in both re-inserts it.
        Removing an edge the graph does not hold raises ValueError (a
        streaming feed out of sync with its graph is a bug worth
        catching, not masking); inserting an edge that already exists is
        a no-op (the edge relation is a set, like ``from_edges``), and
        self-loops are dropped as everywhere else.  The copy's
        ``version`` is ``self.version + 1`` even when the edge set ends
        up unchanged — callers observing the version see every mutation.
        """
        edges = self.edge_list()
        if remove is not None:
            rem = np.asarray(remove, dtype=np.int64).reshape(-1, 2)
            self._check_range(rem, "remove")
            if rem.size:
                cur_keys = edges[:, 0] * self.n + edges[:, 1]
                rem_keys = rem[:, 0] * self.n + rem[:, 1]
                present = np.isin(rem_keys, cur_keys)
                if not present.all():
                    missing = rem[~present][0]
                    raise ValueError(
                        f"cannot remove edge ({int(missing[0])}, "
                        f"{int(missing[1])}): not in the graph")
                edges = edges[~np.isin(cur_keys, rem_keys)]
        if add is not None:
            ins = np.asarray(add, dtype=np.int64).reshape(-1, 2)
            self._check_range(ins, "add")
            edges = np.concatenate([edges, ins], axis=0)
        rebuilt = from_edges(self.n, edges)
        return dataclasses.replace(rebuilt, version=self.version + 1)

    def add_edges(self, edges: np.ndarray) -> "Graph":
        """``with_edges(add=edges)`` — the streaming-insert convenience."""
        return self.with_edges(add=edges)

    def remove_edges(self, edges: np.ndarray) -> "Graph":
        """``with_edges(remove=edges)`` — the streaming-delete
        convenience; every edge must currently exist."""
        return self.with_edges(remove=edges)

    def _check_range(self, pairs: np.ndarray, what: str) -> None:
        if pairs.size and not ((pairs >= 0).all() and (pairs < self.n).all()):
            raise ValueError(f"{what} edges must have endpoints in "
                             f"[0, {self.n})")


def from_edges(n: int, edges: np.ndarray, dedup: bool = True) -> Graph:
    """Build a Graph from an (m, 2) int array of directed edges.

    Self-loops are dropped (a simple path never uses one); duplicate edges are
    deduplicated by default (the edge relation of the join model is a set).
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if edges.size:
        keep = edges[:, 0] != edges[:, 1]
        edges = edges[keep]
    if dedup and edges.size:
        edges = np.unique(edges, axis=0)
    src = edges[:, 0] if edges.size else np.zeros(0, np.int64)
    dst = edges[:, 1] if edges.size else np.zeros(0, np.int64)

    def csr(a, b):
        order = np.lexsort((b, a))
        a_s, b_s = a[order], b[order]
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, a_s + 1, 1)
        np.cumsum(indptr, out=indptr)
        return indptr, b_s.astype(np.int32), a_s.astype(np.int32)

    indptr, indices, esrc = csr(src, dst)
    rindptr, rindices, _ = csr(dst, src)
    return Graph(n=n, indptr=indptr, indices=indices, rindptr=rindptr,
                 rindices=rindices, esrc=esrc, edst=indices)


# ---------------------------------------------------------------------------
# Generators (benchmark + test workloads; real datasets are not bundled)
# ---------------------------------------------------------------------------

def erdos_renyi(n: int, avg_deg: float, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    return from_edges(n, np.stack([src, dst], axis=1))


def power_law(n: int, avg_deg: float, alpha: float = 1.2, seed: int = 0) -> Graph:
    """Directed preferential-attachment-ish graph (heavy-tailed out/in degree).

    Mirrors the paper's social/web workloads where high-degree hubs create
    large search spaces (the `s,t in V'` query sets of Section 7.1).
    """
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg)
    # Zipfian endpoint sampling
    ranks = np.arange(1, n + 1, dtype=np.float64)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    perm_out = rng.permutation(n)
    perm_in = rng.permutation(n)
    src = perm_out[rng.choice(n, size=m, p=probs)]
    dst = perm_in[rng.choice(n, size=m, p=probs)]
    return from_edges(n, np.stack([src, dst], axis=1))


def layered_dag(layers: int, width: int, fanout: float, seed: int = 0) -> Graph:
    """Layered DAG with dense inter-layer wiring: many s-t paths, no cycles.

    This is the walk==path regime of Example 5.2 (G0): every walk the engine
    generates is a valid path, so invalid-partial counts are ~0.
    """
    rng = np.random.default_rng(seed)
    n = layers * width + 2
    s, t = n - 2, n - 1
    edges = []
    first = np.arange(width)
    for v in first:
        edges.append((s, v))
    for l in range(layers - 1):
        base_a, base_b = l * width, (l + 1) * width
        cnt = int(width * fanout)
        a = rng.integers(0, width, size=cnt) + base_a
        b = rng.integers(0, width, size=cnt) + base_b
        edges.extend(zip(a.tolist(), b.tolist()))
    for v in range((layers - 1) * width, layers * width):
        edges.append((v, t))
    return from_edges(n, np.array(edges, dtype=np.int64))


def grid(rows: int, cols: int, bidirectional: bool = True) -> Graph:
    n = rows * cols
    edges = []
    for r in range(rows):
        for c in range(cols):
            v = r * cols + c
            if c + 1 < cols:
                edges.append((v, v + 1))
                if bidirectional:
                    edges.append((v + 1, v))
            if r + 1 < rows:
                edges.append((v, v + cols))
                if bidirectional:
                    edges.append((v + cols, v))
    return from_edges(n, np.array(edges, dtype=np.int64))


def complete(n: int) -> Graph:
    src, dst = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    return from_edges(n, np.stack([src.ravel(), dst.ravel()], axis=1))


def random_graph_suite(seed: int = 0) -> dict:
    """Small named workload suite used by tests and benchmarks."""
    return {
        "er_small": erdos_renyi(64, 3.0, seed),
        "er_dense": erdos_renyi(48, 6.0, seed + 1),
        "pl_hub": power_law(96, 4.0, seed=seed + 2),
        "dag": layered_dag(4, 8, 3.0, seed + 3),
        "grid": grid(6, 6),
    }
