"""Ranked (any-k) enumeration support (DESIGN.md §10).

PathEnum's anytime contracts (``first_n``, deadlines) historically
returned an *arbitrary* prefix of P(s,t,k,G).  Ranked enumeration — the
any-k contract of Tziavelis et al. (arXiv:1911.05582) — upgrades that to
the *best* prefix: paths come back in non-decreasing rank, so a
truncation is always the top of the result set.  This module is the
shared vocabulary of that contract; the drivers live in enumerate.py
(best-first host heap, rank-bucketed device scheduling) and join.py
(cost-ordered key groups).

Rank of a path ``p``:

  * ``order="hops"``   — the hop count (number of edges).
  * ``order="weight"`` — the edge-weight sum, accumulated left-to-right
    in float64 (the *canonical accumulation order*: every engine path
    and the oracle sum in the same order, so ties and near-ties agree
    bit-for-bit across backends).

Ties break on the **lexicographic vertex sequence** (PAD-padded rows
compare exactly like Python tuples: a shorter sequence sorts before its
extensions).  The combined key ``(cost, sequence)`` is a total order, so
every backend — dfs host, dfs device, join — emits the *same* ordered
sequence of paths, not merely the same set.

``order="weight"`` demands non-negative finite weights (aligned with the
graph's edge order, like ``constraints.AccumulativeValue``): the
best-first lower bounds are only admissible for monotone non-negative
accumulation, the same Appendix-E caveat the constraint machinery
honors.  Parallel edges are out of scope (``from_edges`` dedups them).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Tuple

import numpy as np

if TYPE_CHECKING:
    from .index import LightweightIndex

ORDERS = ("hops", "weight")

# Relative slack treating two float path costs as a potential tie
# (DESIGN.md §10).  Canonical left-to-right accumulation makes equal
# *paths* cost bit-identical everywhere, but a *lower bound* (acc +
# wdist_t, or a join group's min_a + min_b) sums in a different
# association order, so it may sit a few ulps off the cost it bounds.
# Emission gates therefore require a result to clear the bound by this
# margin; costs within it are resolved exactly by waiting for the
# bounded partials to finish.  The margin only delays emission — it
# never reorders it.
WEIGHT_TIE_SLACK = 1e-9


def weight_slack(bound: float) -> float:
    """The absolute emission margin at a given bound magnitude."""
    return WEIGHT_TIE_SLACK * (1.0 + abs(float(bound)))


@dataclasses.dataclass(frozen=True)
class RankSpec:
    """A validated ranking request: ``order`` plus (for weight ranking)
    the float64 edge-weight array in graph edge order."""
    order: str
    weights: Optional[np.ndarray] = None

    @property
    def is_weight(self) -> bool:
        """True for ``order="weight"`` (float costs, slack-gated
        emission); False for hop ranking (exact integer costs)."""
        return self.order == "weight"


def make_rank_spec(order: Optional[str],
                   weights: Optional[np.ndarray]) -> Optional[RankSpec]:
    """Validate an ``order=`` request into a RankSpec (None stays None).

    ``order="weight"`` requires ``weights``: one finite non-negative
    value per graph edge (graph edge order, like
    ``constraints.AccumulativeValue``).  Negative or non-finite weights
    are rejected — the best-first lower bounds would stop being
    admissible and the ranked contract would silently break.
    """
    if order is None:
        return None
    if order not in ORDERS:
        raise ValueError(f"unknown order {order!r}; expected one of "
                         f"{ORDERS} or None")
    if order == "hops":
        return RankSpec(order="hops")
    if weights is None:
        raise ValueError("order='weight' requires an edge-weight array "
                         "(graph edge order)")
    w = np.asarray(weights, dtype=np.float64)
    if w.ndim != 1:
        raise ValueError(f"weights must be 1-D, got shape {w.shape}")
    if not np.all(np.isfinite(w)):
        raise ValueError("order='weight' requires finite weights")
    if w.size and float(w.min()) < 0.0:
        raise ValueError("order='weight' requires non-negative weights "
                         "(the Appendix-E monotonicity caveat)")
    return RankSpec(order="weight", weights=w)


# ---------------------------------------------------------------------------
# canonical ordering
# ---------------------------------------------------------------------------

def canonical_perm(paths: np.ndarray, costs: np.ndarray) -> np.ndarray:
    """The permutation sorting ``paths`` rows by ``(cost, sequence)``.

    Stable lexsort: primary key ``costs``, then vertex columns left to
    right.  PAD (−1) tail padding sorts before any vertex id, so a
    shorter sequence precedes its extensions — exactly Python tuple
    comparison on the unpadded sequences.
    """
    cols = tuple(paths[:, j] for j in range(paths.shape[1] - 1, -1, -1))
    return np.lexsort(cols + (costs,))


def index_edge_table(idx: "LightweightIndex", values: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """A vectorized (u, v) -> value lookup table over *index* edges.

    Returns ``(keys, vals)`` with ``keys = u * n + v`` sorted ascending
    and ``vals`` the per-edge values (``values`` in graph edge order,
    mapped through ``idx.fwd_eid``).  Every edge an enumerator walks is
    an index edge by construction, so ``np.searchsorted(keys, u*n+v)``
    always hits.
    """
    n = np.int64(idx.n)
    counts = (idx.fwd_end[:, idx.k] - idx.fwd_begin).astype(np.int64)
    eu = np.repeat(np.arange(idx.n, dtype=np.int64), counts)
    keys = eu * n + idx.fwd_dst.astype(np.int64)
    vals = np.asarray(values, dtype=np.float64)[idx.fwd_eid]
    order = np.argsort(keys, kind="stable")
    return keys[order], vals[order]


def path_costs(idx: "LightweightIndex", paths: np.ndarray,
               lengths: np.ndarray,
               spec: Optional[RankSpec]) -> np.ndarray:
    """Canonical per-row costs for finished path rows.

    Hop ranking (and the ``order=None`` canonicalization) costs a row
    its length; weight ranking re-accumulates each row's edge weights
    left to right in float64 — the one accumulation order every backend
    and the oracle share, so identical paths cost bit-identical floats.
    """
    if spec is None or not spec.is_weight:
        return np.asarray(lengths, dtype=np.int64)
    keys, vals = index_edge_table(idx, spec.weights)
    n = np.int64(idx.n)
    costs = np.zeros(paths.shape[0], dtype=np.float64)
    for j in range(paths.shape[1] - 1):
        act = np.asarray(lengths) > j
        if not act.any():
            break
        q = paths[act, j].astype(np.int64) * n + paths[act, j + 1]
        costs[act] = costs[act] + vals[np.searchsorted(keys, q)]
    return costs


def remaining_lower_bound(idx: "LightweightIndex",
                          spec: RankSpec) -> np.ndarray:
    """Admissible per-vertex lower bound on the cost still needed to
    reach ``t`` (the best-first heuristic of DESIGN.md §10).

    * hops: the index's exact BFS distance-to-t array.
    * weight: a k-round min-plus relaxation over the index edges —
      ``wd[v] = min(w(v,u) + wd[u])`` — so ``wd[v]`` is the cheapest
      ≤k-hop walk cost v→t.  Simple paths are a subset of walks and
      weights are non-negative, so the bound is admissible (never above
      the true remaining cost).  Unreachable vertices carry +inf.
    """
    if not spec.is_weight:
        return idx.dist_t.astype(np.int64)
    counts = (idx.fwd_end[:, idx.k] - idx.fwd_begin).astype(np.int64)
    eu = np.repeat(np.arange(idx.n, dtype=np.int64), counts)
    ew = np.asarray(spec.weights, dtype=np.float64)[idx.fwd_eid]
    dst = idx.fwd_dst.astype(np.int64)
    wd = np.full(idx.n, np.inf, dtype=np.float64)
    wd[idx.t] = 0.0
    for _ in range(idx.k):
        if eu.size == 0:
            break
        cand = ew + wd[dst]
        new = wd.copy()
        np.minimum.at(new, eu, cand)
        if np.array_equal(new, wd):
            break
        wd = new
    return wd


def edge_step_costs(idx: "LightweightIndex", spec: RankSpec,
                    pos: np.ndarray) -> np.ndarray:
    """Per-candidate incremental cost for index positions ``pos`` (the
    frontier expansion's gather offsets): 1 for hops, the edge weight
    for weight ranking."""
    if not spec.is_weight:
        return np.ones(pos.shape[0], dtype=np.int64)
    return np.asarray(spec.weights, dtype=np.float64)[idx.fwd_eid[pos]]
