"""Algorithm 2 — relation construction + full reducer (dangling-tuple
elimination).  This is the paper's *baseline* pruning method, kept for the
pruning-power comparison of Appendix B: after the full reducer,
``R_i(u_{i-1}:v, u_i)`` must equal ``I_t(v, k-i)`` for every non-t vertex v
appearing in R_i — tests/test_relations.py asserts exactly that equivalence
against the light-weight index.
"""
from __future__ import annotations

from typing import List, Set

import numpy as np

from .graph import Graph


def build_relations(graph: Graph, s: int, t: int, k: int) -> List[np.ndarray]:
    """Returns R_1..R_k as (m_i, 2) int arrays after the full reducer.

    The virtual (t,t) tuple of rule (3) is represented explicitly.
    """
    u, v = graph.esrc.astype(np.int64), graph.edst.astype(np.int64)
    rels: List[np.ndarray] = []
    # (1)/(2): initialize
    r1 = np.stack([u[u == s], v[u == s]], axis=1)
    rels.append(r1)
    for i in range(2, k):
        keep = (u != s) & (v != s) & (u != t)  # E(G-{s}) and v != t as src
        ri = np.stack([u[keep], v[keep]], axis=1)
        ri = np.concatenate([ri, [[t, t]]], axis=0)
        rels.append(ri)
    keep = (v == t) & (u != s) & (u != t)
    rk = np.stack([u[keep], v[keep]], axis=1)
    rk = np.concatenate([rk, [[t, t]]], axis=0)
    rels.append(rk)

    # full reducer — forward sweep (Alg. 2 L5-8)
    for i in range(k - 1):
        c = set(rels[i][:, 1].tolist())
        nxt = rels[i + 1]
        mask = np.fromiter((int(x) in c for x in nxt[:, 0]), bool,
                           count=nxt.shape[0])
        rels[i + 1] = nxt[mask]
    # backward sweep (Alg. 2 L9-12)
    for i in range(k - 2, -1, -1):
        c = set(rels[i + 1][:, 0].tolist())
        cur = rels[i]
        mask = np.fromiter((int(x) in c for x in cur[:, 1]), bool,
                           count=cur.shape[0])
        rels[i] = cur[mask]
    return rels


def relation_sizes(rels: List[np.ndarray]) -> List[int]:
    return [int(r.shape[0]) for r in rels]


def relation_neighbors(rels: List[np.ndarray], i: int, v: int) -> Set[int]:
    """R_i(u_{i-1}:v, u_i) — successors of v in relation R_i (1-based i)."""
    r = rels[i - 1]
    return set(int(x) for x in r[r[:, 0] == v][:, 1])
