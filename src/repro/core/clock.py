"""The single monotonic deadline clock (DESIGN.md §7/§9).

Cooperative deadline truncation crosses layers: serving computes an
*absolute* deadline at admission (``async_server.submit``), the engine
threads it untouched through ``BatchPathEnum.run`` into the enumeration
drivers, and the drivers compare against it between chunks
(``_drive`` / ``_drive_ranked_*`` / the join ``_expired`` hooks / the
shared walk).  That contract only works if producer and consumers read
the *same* clock: a deadline minted from one time origin and compared
against another is silently never-expiring (truncation disabled) or
always-expired (every query truncates to nothing) depending on the
sign of the origin skew.

Historically each side called ``time.perf_counter()`` directly — the
same source today, but nothing *enforced* it, and any drift (a module
switching to ``time.monotonic()``, a test freezing one side) would
split the origins without a single failing assertion.  This module is
the enforcement point: every deadline is minted by :func:`deadline_in`
/ :func:`now` and every check goes through :func:`expired`, all reading
one patchable ``_source``.  The regression suite
(``tests/test_deadline_clock.py``) skews ``_source`` far from
``time.perf_counter()`` and asserts truncation still behaves, which
fails the moment any producer or consumer bypasses this module.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

# The one time source.  Monotonic by contract; tests monkeypatch this to
# skew or freeze the clock for *both* producers and consumers at once.
_source: Callable[[], float] = time.perf_counter


def now() -> float:
    """Current time on the deadline clock (absolute, monotonic)."""
    return _source()


def deadline_in(budget_seconds: Optional[float]) -> Optional[float]:
    """Absolute deadline ``budget_seconds`` from now (None = no deadline)."""
    if budget_seconds is None:
        return None
    return _source() + budget_seconds


def expired(deadline: Optional[float]) -> bool:
    """Has ``deadline`` (absolute, from this clock) passed?  None never
    expires."""
    return deadline is not None and _source() >= deadline
