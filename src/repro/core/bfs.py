"""Bounded BFS distances — the first stage of index construction (Alg. 3 L1).

TPU adaptation: the queue BFS of the paper becomes k rounds of edge-parallel
relaxation (`scatter-min`), i.e. k applications of a min-plus SpMV over the
edge list.  This is jit-compatible with static (n, m, k) and shards along the
edge/vertex dimension under ``shard_map`` (see distributed/engine.py).  The
blocked Pallas min-plus kernel in kernels/semiring_spmm.py implements the
same relaxation over 128x128 adjacency tiles for the dense-tile regime.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph


@functools.partial(jax.jit, static_argnames=("n", "k"))
def bfs_edge_relax(esrc: jnp.ndarray, edst: jnp.ndarray, n: int, k: int,
                   src: jnp.ndarray, excluded: jnp.ndarray) -> jnp.ndarray:
    """Distances from ``src`` within ``k`` hops, vertex ``excluded`` removed.

    ``G - {v}`` in the paper forbids v as a *transit* vertex: the excluded
    vertex may still be reached (it is the other query endpoint and needs a
    distance so that C_0 = {s} and t in C_k hold), but no path may continue
    through it.  Hence contributions *from* ``excluded`` are masked while
    writes *to* it remain allowed.

    Returns int32 (n,) with k+1 as the unreachable sentinel.  ``src`` and
    ``excluded`` are traced scalars so one compiled program serves every
    query (online scenario: compile once, run per query).
    """
    INF = jnp.int32(k + 1)
    dist = jnp.full((n,), INF, dtype=jnp.int32)
    dist = dist.at[src].set(0)

    def body(_, dist):
        cand = jnp.where(esrc == excluded, INF, dist[esrc] + 1)
        new = dist.at[edst].min(cand)
        return jnp.minimum(new, INF)

    return jax.lax.fori_loop(0, k, body, dist)


def index_distances(graph: Graph, s: int, t: int, k: int):
    """(dist_s, dist_t) per Prop. 4.3: S(s,·|G−{t}) and S(·,t|G−{s})."""
    esrc = jnp.asarray(graph.esrc)
    edst = jnp.asarray(graph.edst)
    ds = bfs_edge_relax(esrc, edst, graph.n, k, jnp.int32(s), jnp.int32(t))
    # reverse graph: swap roles of src/dst
    dt = bfs_edge_relax(edst, esrc, graph.n, k, jnp.int32(t), jnp.int32(s))
    return np.asarray(ds), np.asarray(dt)


def index_distances_np(graph: Graph, s: int, t: int, k: int):
    """Host reference (queue BFS) — used to cross-check the jitted relaxation."""
    from .oracle import bfs_dist_np
    ds = bfs_dist_np(graph, s, k, reverse=False, excluded=t)
    dt = bfs_dist_np(graph, t, k, reverse=True, excluded=s)
    return ds, dt
