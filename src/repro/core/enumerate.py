"""IDX-DFS adapted to frontiers (Algorithm 4 → chunked level-synchronous).

The recursive DFS of the paper becomes a *chunked depth-first frontier*
walk: partial results are rows of a fixed-width int32 matrix, one hop
expands every row of a chunk simultaneously (gather from the index via the
O(1) offset lookup), and a LIFO deque of chunks preserves the depth-first
memory bound — the live set is O(chunk · k · max_branch/chunk) rather than
the paper's O(k), the standard accelerator transformation (DESIGN.md §2).

Semantics are identical to Algorithm 4:
  * candidates come from I_t(v, k - L(M) - 1)   (budget read off the index)
  * the simple-path check `v' ∉ M` is the vectorized prefix compare
  * a row reaching t is emitted

Instrumentation mirrors the paper's Fig. 6 metrics: #edges accessed,
#invalid partials (generated partials that never reach any result — here:
dup-pruned expansions plus dead-end rows), #results.

Two expansion backends share this driver loop (DESIGN.md §9): ``host``
runs `_expand_chunk` in numpy; ``device`` runs the same hop as a Pallas
kernel (kernels/frontier_expand, via kernels/ops.frontier_expand) over
fixed-width PAD-padded chunks, with the Fig.-6 counters coming back as
device scalars.  ``auto`` picks the device for small k and dense
frontiers and falls back to the host otherwise (`resolve_backend`).
Results, stats and chunk boundaries are bit-identical across backends.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Iterator, List, Optional, Tuple

import numpy as np

from .graph import PAD
from .index import LightweightIndex

# Auto-selection rule for backend="auto" (DESIGN.md §9): the device wins
# when chunks are wide (dense frontiers — many index edges feeding each
# hop) and the path matrix is narrow (small k keeps the fixed-width
# layout and the prefix compare cheap).  On CPU the kernel only runs in
# interpret mode, so auto never picks it there unless forced for CI
# (REPRO_DEVICE_ENUM=force).
DEVICE_AUTO_MAX_K = 8
DEVICE_AUTO_MIN_EDGES = 2048


def resolve_backend(idx: LightweightIndex, backend: Optional[str],
                    constraint=None) -> str:
    """Resolve a requested backend to the one that will run (DESIGN.md §9
    fallback matrix).  Constraints are host-only state machines, so any
    constrained query runs on the host; ``auto`` additionally requires
    small k, a dense-enough index, and a real accelerator (or
    ``REPRO_DEVICE_ENUM=force``, which lets CPU CI cover the device leg
    in interpret mode)."""
    if backend is not None and backend not in ("host", "device", "auto"):
        raise ValueError(f"unknown backend {backend!r}")
    if backend is None or backend == "host":
        return "host"
    if constraint is not None:
        return "host"
    if backend == "device":
        return "device"
    # backend == "auto"
    if idx.k > DEVICE_AUTO_MAX_K:
        return "host"
    if idx.num_index_edges < DEVICE_AUTO_MIN_EDGES:
        return "host"
    if os.environ.get("REPRO_DEVICE_ENUM") == "force":
        return "device"
    import jax
    return "device" if jax.default_backend() != "cpu" else "host"


class EngineLimit(RuntimeError):
    """Raised when a configured result/partial budget would be exceeded."""


@dataclasses.dataclass
class EnumStats:
    edges_accessed: int = 0
    invalid_partials: int = 0
    partials_generated: int = 0
    results: int = 0
    chunks: int = 0

    def merge(self, other: "EnumStats") -> None:
        self.edges_accessed += other.edges_accessed
        self.invalid_partials += other.invalid_partials
        self.partials_generated += other.partials_generated
        self.results += other.results
        self.chunks += other.chunks


@dataclasses.dataclass
class EnumResult:
    paths: np.ndarray          # (r, k+1) int32, PAD after the t column
    lengths: np.ndarray        # (r,) int32 — number of edges
    count: int                 # total results (== r unless count_only)
    stats: EnumStats
    exhausted: bool = True     # False when stopped early by first_n

    def as_tuples(self) -> List[Tuple[int, ...]]:
        out = []
        for row, l in zip(self.paths, self.lengths):
            out.append(tuple(int(x) for x in row[: l + 1]))
        return out


def _expand_chunk(idx: LightweightIndex, paths: np.ndarray, depth: int,
                  stats: EnumStats):
    """One hop for every row of `paths` (all at the same depth).

    Returns (emit_rows, cont_rows, parent_of_cont, parent_of_emit).
    """
    k, t = idx.k, idx.t
    last = paths[:, depth].astype(np.int64)
    b = k - depth - 1
    begin = idx.fwd_begin[last]
    end = idx.fwd_end[last, max(b, 0)] if b >= 0 else begin
    cnt = (end - begin).astype(np.int64)
    total = int(cnt.sum())
    stats.edges_accessed += total
    if total == 0:
        stats.invalid_partials += paths.shape[0]
        return None
    parent = np.repeat(np.arange(paths.shape[0], dtype=np.int64), cnt)
    offs = np.zeros(paths.shape[0], dtype=np.int64)
    np.cumsum(cnt[:-1], out=offs[1:])
    pos = np.arange(total, dtype=np.int64) - offs[parent] + begin[parent]
    vnew = idx.fwd_dst[pos].astype(np.int32)

    prefix = paths[parent, : depth + 1]
    dup = (prefix == vnew[:, None]).any(axis=1)
    is_t = vnew == t
    emit = is_t & ~dup
    cont = ~is_t & ~dup

    stats.partials_generated += total
    stats.invalid_partials += int(dup.sum())
    # rows whose every expansion died contribute to invalid partials
    alive = np.zeros(paths.shape[0], dtype=bool)
    alive[parent[emit | cont]] = True
    stats.invalid_partials += int((~alive).sum())
    return parent, pos, vnew, emit, cont


def enumerate_paths_idx(
    idx: LightweightIndex,
    chunk_size: int = 16384,
    count_only: bool = False,
    first_n: Optional[int] = None,
    max_results: Optional[int] = None,
    constraint=None,
    deadline: Optional[float] = None,
    backend: Optional[str] = None,
) -> EnumResult:
    """Enumerate P(s,t,k,G) from the light-weight index (Algorithm 4).

    ``constraint`` is an optional Appendix-E extension object (see
    constraints.py) carrying vectorized per-partial state.

    ``deadline`` is a cooperative chunk budget: an absolute
    ``time.perf_counter()`` timestamp checked between chunks.  Once it
    passes, the results emitted so far come back with ``exhausted=False``
    — the anytime contract of ``first_n``, keyed on time instead of
    count.  Emitted results are never discarded, so the return value is
    always a correct (possibly partial) subset of the full result set.

    ``backend`` selects where frontier expansion runs (DESIGN.md §9):
    ``"host"``/None (numpy, the default), ``"device"`` (the Pallas
    frontier kernel; constrained queries fall back to the host), or
    ``"auto"`` (`resolve_backend`'s small-k/dense-frontier rule).  Both
    backends plug an expansion step into the one driver loop below, so
    paths, counts, ``EnumStats`` and chunk boundaries are identical by
    construction — only the expansion engine changes.
    """
    if resolve_backend(idx, backend, constraint) == "device":
        step = _device_step(idx)          # resolve guarantees no constraint
        constraint = None
    else:
        step = _host_step(idx, constraint)
    return _drive(idx, step, chunk_size=chunk_size, count_only=count_only,
                  first_n=first_n, max_results=max_results,
                  constraint=constraint, deadline=deadline)


def _drive(idx: LightweightIndex, step, chunk_size: int, count_only: bool,
           first_n: Optional[int], max_results: Optional[int], constraint,
           deadline: Optional[float]) -> EnumResult:
    """The backend-independent IDX-DFS driver (DESIGN.md §9).

    Owns every anytime contract — the LIFO chunk walk, the per-chunk
    deadline check, first_n's exact-n trim, the max_results limit, and
    chunk_size splitting — so host and device expansion cannot diverge
    on them.  ``step(paths, depth, cstate, stats, want_cont)`` performs
    one hop for one chunk and returns ``None`` (chunk fully dead, stats
    already updated) or ``(emit_rows, cont_rows, cont_state)`` with rows
    in emission order; ``want_cont`` is False on the last hop, where
    survivors could never be extended.
    """
    k, s = idx.k, idx.s
    stats = EnumStats()
    out_paths: List[np.ndarray] = []
    out_lens: List[np.ndarray] = []
    count = 0

    root = np.full((1, k + 1), PAD, dtype=np.int32)
    root[0, 0] = s
    cstate0 = constraint.init(1) if constraint is not None else None
    # LIFO deque of (paths, depth, constraint_state) — deepest first = DFS
    work: List[Tuple[np.ndarray, int, object]] = [(root, 0, cstate0)]

    while work:
        if deadline is not None and time.perf_counter() >= deadline:
            return _finalize(idx, out_paths, out_lens, count, stats,
                             exhausted=False)
        paths, depth, cstate = work.pop()
        stats.chunks += 1
        expanded = step(paths, depth, cstate, stats, depth + 1 < k)
        if expanded is None:
            continue
        emit_rows, cont_rows, cont_state = expanded

        if emit_rows is not None and emit_rows.shape[0]:
            count += emit_rows.shape[0]
            stats.results += emit_rows.shape[0]
            if not count_only:
                out_paths.append(emit_rows)
                out_lens.append(np.full(emit_rows.shape[0], depth + 1,
                                        np.int32))
            if max_results is not None and count > max_results:
                raise EngineLimit(f"more than {max_results} results")
            if first_n is not None and count >= first_n:
                count = _trim_to_first_n(out_paths, out_lens, count,
                                         first_n, count_only, stats)
                return _finalize(idx, out_paths, out_lens, count, stats,
                                 exhausted=False)

        if cont_rows is not None and cont_rows.shape[0]:
            # split into chunks; push in reverse so earlier rows pop first
            pieces = range(0, cont_rows.shape[0], chunk_size)
            for st in reversed(list(pieces)):
                sl = slice(st, st + chunk_size)
                piece_cs = constraint.slice(cont_state, sl) \
                    if constraint is not None else None
                work.append((cont_rows[sl], depth + 1, piece_cs))

    return _finalize(idx, out_paths, out_lens, count, stats, exhausted=True)


def _host_step(idx: LightweightIndex, constraint):
    """The numpy expansion step: `_expand_chunk` plus the Appendix-E
    constraint machinery (extend/accept/gather), folded to the driver's
    (emit_rows, cont_rows, cont_state) contract."""

    def step(paths, depth, cstate, stats, want_cont):
        expanded = _expand_chunk(idx, paths, depth, stats)
        if expanded is None:
            return None
        parent, pos, vnew, emit, cont = expanded

        if constraint is not None:
            eids = idx.fwd_eid[pos]
            cstate_new, keep = constraint.extend(cstate, parent, eids, vnew)
            pruned = (emit | cont) & ~keep
            stats.invalid_partials += int(pruned.sum())
            emit = emit & keep
            cont = cont & keep
        else:
            cstate_new = None

        def rows_of(sel):
            rows = paths[parent[sel]].copy()
            rows[:, depth + 1] = vnew[sel]
            return rows

        emit_rows = None
        if emit.any():
            sel = np.nonzero(emit)[0]
            if constraint is not None:
                acc = constraint.accept(cstate_new, sel)
                stats.invalid_partials += int((~acc).sum())
                sel = sel[acc]
            if sel.size:
                emit_rows = rows_of(sel)

        cont_rows, cont_state = None, None
        if want_cont and cont.any():
            sel = np.nonzero(cont)[0]
            cont_rows = rows_of(sel)
            cont_state = constraint.gather(cstate_new, sel) \
                if constraint is not None else None
        return emit_rows, cont_rows, cont_state

    return step


# Per-kernel-launch candidate-slot budget: a chunk whose (rows × padded
# fan-out) rectangle exceeds it is cut into contiguous row segments, so
# one hub vertex in a wide chunk cannot inflate the dense slot matrices
# past memory (the host path's work is proportional to actual candidates;
# the device rectangle is rows × max fan-out).  Segment outputs
# concatenate in row order, so emission order — and therefore every
# first_n prefix — is unchanged.
DEVICE_SLOT_BUDGET = 1 << 19


def _fanout_segments(cnt: np.ndarray, budget: int) -> List[Tuple[int, int]]:
    """Contiguous [start, end) row segments whose rows × next-pow2(max
    fan-out) rectangles each fit the slot budget (single rows always
    form a valid segment)."""
    # common case first, vectorized: the whole chunk's rectangle fits,
    # so the O(rows) scan below never runs on ordinary chunks
    whole = 1 << (max(int(cnt.max(initial=0)), 1) - 1).bit_length()
    if cnt.shape[0] * whole <= budget:
        return [(0, cnt.shape[0])]
    segments: List[Tuple[int, int]] = []
    start, seg_max = 0, 1
    for i in range(cnt.shape[0]):
        c = max(int(cnt[i]), 1)
        new_max = max(seg_max, 1 << (c - 1).bit_length())
        if i > start and (i - start + 1) * new_max > budget:
            segments.append((start, i))
            start, seg_max = i, 1 << (c - 1).bit_length()
        else:
            seg_max = new_max
    segments.append((start, cnt.shape[0]))
    return segments


def _device_step(idx: LightweightIndex):
    """The Pallas expansion step (DESIGN.md §9): one kernel launch per
    fan-out segment of the chunk, Fig.-6 counters accumulated from the
    kernel's device scalars.  The host keeps two cheap responsibilities:
    sizing segments off the offset arrays (which also shortcuts all-dead
    chunks without a launch), and the driver's usual splitting."""
    from ..kernels import ops as kops   # lazy: pallas only on this path
    k, t = idx.k, idx.t
    dev = idx.device_arrays()

    def step(paths, depth, cstate, stats, want_cont):
        last = paths[:, depth].astype(np.int64)
        b = k - depth - 1
        cnt = (idx.fwd_end[last, b] - idx.fwd_begin[last]) if b >= 0 \
            else np.zeros(paths.shape[0], np.int64)
        if int(cnt.sum()) == 0:
            stats.invalid_partials += paths.shape[0]
            return None
        emit_parts: List[np.ndarray] = []
        cont_parts: List[np.ndarray] = []
        for lo, hi in _fanout_segments(cnt, DEVICE_SLOT_BUDGET):
            emit_rows, cont_rows, n_emit, n_cont, counters = \
                kops.frontier_expand(paths[lo:hi], dev.begin, dev.end,
                                     dev.dst, depth=depth, t=t,
                                     max_deg=max(int(cnt[lo:hi].max()), 1),
                                     want_cont=want_cont)
            edges, partials, invalid, _ = (int(x) for x in
                                           np.asarray(counters))
            stats.edges_accessed += edges
            stats.partials_generated += partials
            stats.invalid_partials += invalid
            ne, nc = int(n_emit), int(n_cont)
            if ne:
                emit_parts.append(np.asarray(emit_rows[:ne]))
            if want_cont and nc:
                cont_parts.append(np.asarray(cont_rows[:nc]))
        # one array per chunk, like the host step: _trim_to_first_n
        # trims only the driver's last appended block
        emit_out = (np.concatenate(emit_parts, axis=0)
                    if emit_parts else None)
        cont_out = (np.concatenate(cont_parts, axis=0)
                    if cont_parts else None)
        return emit_out, cont_out, None

    return step


def _trim_to_first_n(out_paths, out_lens, count, first_n, count_only,
                     stats) -> int:
    """Drop the over-emitted tail of the last chunk so exactly ``first_n``
    results come back — the first-n counts then agree between the DFS and
    join paths regardless of either path's emission granularity."""
    excess = count - first_n
    if excess > 0:
        stats.results -= excess
        if not count_only:
            out_paths[-1] = out_paths[-1][:-excess]
            out_lens[-1] = out_lens[-1][:-excess]
        count = first_n
    return count


def _finalize(idx, out_paths, out_lens, count, stats, exhausted) -> EnumResult:
    k = idx.k
    if out_paths:
        paths = np.concatenate(out_paths, axis=0)
        lens = np.concatenate(out_lens, axis=0)
    else:
        paths = np.zeros((0, k + 1), dtype=np.int32)
        lens = np.zeros((0,), dtype=np.int32)
    return EnumResult(paths=paths, lengths=lens, count=count, stats=stats,
                      exhausted=exhausted)
