"""IDX-DFS adapted to frontiers (Algorithm 4 → chunked level-synchronous).

The recursive DFS of the paper becomes a *chunked depth-first frontier*
walk: partial results are rows of a fixed-width int32 matrix, one hop
expands every row of a chunk simultaneously (gather from the index via the
O(1) offset lookup), and a LIFO deque of chunks preserves the depth-first
memory bound — the live set is O(chunk · k · max_branch/chunk) rather than
the paper's O(k), the standard accelerator transformation (DESIGN.md §2).

Semantics are identical to Algorithm 4:
  * candidates come from I_t(v, k - L(M) - 1)   (budget read off the index)
  * the simple-path check `v' ∉ M` is the vectorized prefix compare
  * a row reaching t is emitted

Instrumentation mirrors the paper's Fig. 6 metrics: #edges accessed,
#invalid partials (generated partials that never reach any result — here:
dup-pruned expansions plus dead-end rows), #results.

Two expansion backends share this driver loop (DESIGN.md §9): ``host``
runs `_expand_chunk` in numpy; ``device`` runs the same hop as a Pallas
kernel (kernels/frontier_expand, via kernels/ops.frontier_expand) over
fixed-width PAD-padded chunks, with the Fig.-6 counters coming back as
device scalars.  ``auto`` picks the device for small k and dense
frontiers and falls back to the host otherwise (`resolve_backend`).
Results, stats and chunk boundaries are bit-identical across backends.

Ranked (any-k) enumeration (DESIGN.md §10): ``order="hops"|"weight"``
replaces the LIFO chunk walk with a priority-ordered frontier.  The host
runs a best-first heap over partial-path lower bounds (`_drive_ranked_heap`
— bound = accumulated cost + the index's distance-to-t array, or its
min-plus weighted analogue from rank.py); the device path runs
rank-bucketed chunk scheduling (`_drive_ranked_buckets`) that drains one
integer hop-bound bucket at a time through the *unchanged* Pallas kernel.
Both emit paths in non-decreasing ``(cost, lexicographic sequence)``
order, so ``first_n`` returns the top-n and a deadline truncation is a
rank-optimal prefix.  With ``order=None``, exhausted results are
canonicalized to the same key, so every backend/plan returns the same
ordered list on a full enumeration.
"""
from __future__ import annotations

import dataclasses
import heapq
import os
from typing import List, Optional, Tuple

import numpy as np

from . import clock, rank
from .graph import PAD
from .index import LightweightIndex

# Auto-selection rule for backend="auto" (DESIGN.md §9): the device wins
# when chunks are wide (dense frontiers — many index edges feeding each
# hop) and the path matrix is narrow (small k keeps the fixed-width
# layout and the prefix compare cheap).  On CPU the kernel only runs in
# interpret mode, so auto never picks it there unless forced for CI
# (REPRO_DEVICE_ENUM=force).
DEVICE_AUTO_MAX_K = 8
DEVICE_AUTO_MIN_EDGES = 2048


def resolve_backend(idx: LightweightIndex, backend: Optional[str],
                    constraint=None, order: Optional[str] = None) -> str:
    """Resolve a requested backend to the one that will run (DESIGN.md §9
    fallback matrix).  Constraints are host-only state machines, so any
    constrained query runs on the host; ``order="weight"`` likewise runs
    on the host (float rank buckets don't exist — the device scheduler
    drains integer hop buckets, DESIGN.md §10); ``auto`` additionally
    requires small k, a dense-enough index, and a real accelerator (or
    ``REPRO_DEVICE_ENUM=force``, which lets CPU CI cover the device leg
    in interpret mode).  ``REPRO_DEVICE_ENUM=off|0`` is the uniform kill
    switch (same spelling as ``REPRO_SHARING`` / ``REPRO_PALLAS``): every
    query runs on the host, including explicit ``backend="device"``
    requests — the operator escape hatch when a device path misbehaves
    in production."""
    if backend is not None and backend not in ("host", "device", "auto"):
        raise ValueError(f"unknown backend {backend!r}")
    if os.environ.get("REPRO_DEVICE_ENUM", "").lower() in ("off", "0"):
        return "host"
    if backend is None or backend == "host":
        return "host"
    if constraint is not None:
        return "host"
    if order == "weight":
        return "host"
    if backend == "device":
        return "device"
    # backend == "auto"
    if idx.k > DEVICE_AUTO_MAX_K:
        return "host"
    if idx.num_index_edges < DEVICE_AUTO_MIN_EDGES:
        return "host"
    if os.environ.get("REPRO_DEVICE_ENUM") == "force":
        return "device"
    import jax
    return "device" if jax.default_backend() != "cpu" else "host"


class EngineLimit(RuntimeError):
    """Raised when a configured result/partial budget would be exceeded."""


@dataclasses.dataclass
class EnumStats:
    edges_accessed: int = 0
    invalid_partials: int = 0
    partials_generated: int = 0
    results: int = 0
    chunks: int = 0

    def merge(self, other: "EnumStats") -> None:
        self.edges_accessed += other.edges_accessed
        self.invalid_partials += other.invalid_partials
        self.partials_generated += other.partials_generated
        self.results += other.results
        self.chunks += other.chunks


@dataclasses.dataclass
class EnumResult:
    paths: np.ndarray          # (r, k+1) int32, PAD after the t column
    lengths: np.ndarray        # (r,) int32 — number of edges
    count: int                 # total results (== r unless count_only)
    stats: EnumStats
    exhausted: bool = True     # False when stopped early by first_n

    def as_tuples(self) -> List[Tuple[int, ...]]:
        out = []
        for row, l in zip(self.paths, self.lengths):
            out.append(tuple(int(x) for x in row[: l + 1]))
        return out


def _expand_chunk(idx: LightweightIndex, paths: np.ndarray, depth: int,
                  stats: EnumStats):
    """One hop for every row of `paths` (all at the same depth).

    Returns (emit_rows, cont_rows, parent_of_cont, parent_of_emit).
    """
    k, t = idx.k, idx.t
    last = paths[:, depth].astype(np.int64)
    b = k - depth - 1
    begin = idx.fwd_begin[last]
    end = idx.fwd_end[last, max(b, 0)] if b >= 0 else begin
    cnt = (end - begin).astype(np.int64)
    total = int(cnt.sum())
    stats.edges_accessed += total
    if total == 0:
        stats.invalid_partials += paths.shape[0]
        return None
    parent = np.repeat(np.arange(paths.shape[0], dtype=np.int64), cnt)
    offs = np.zeros(paths.shape[0], dtype=np.int64)
    np.cumsum(cnt[:-1], out=offs[1:])
    pos = np.arange(total, dtype=np.int64) - offs[parent] + begin[parent]
    vnew = idx.fwd_dst[pos].astype(np.int32)

    prefix = paths[parent, : depth + 1]
    dup = (prefix == vnew[:, None]).any(axis=1)
    is_t = vnew == t
    emit = is_t & ~dup
    cont = ~is_t & ~dup

    stats.partials_generated += total
    stats.invalid_partials += int(dup.sum())
    # rows whose every expansion died contribute to invalid partials
    alive = np.zeros(paths.shape[0], dtype=bool)
    alive[parent[emit | cont]] = True
    stats.invalid_partials += int((~alive).sum())
    return parent, pos, vnew, emit, cont


def enumerate_paths_idx(
    idx: LightweightIndex,
    chunk_size: int = 16384,
    count_only: bool = False,
    first_n: Optional[int] = None,
    max_results: Optional[int] = None,
    constraint=None,
    deadline: Optional[float] = None,
    backend: Optional[str] = None,
    order: Optional[str] = None,
    weights: Optional[np.ndarray] = None,
) -> EnumResult:
    """Enumerate P(s,t,k,G) from the light-weight index (Algorithm 4).

    ``constraint`` is an optional Appendix-E extension object (see
    constraints.py) carrying vectorized per-partial state.

    ``deadline`` is a cooperative chunk budget: an absolute
    ``core.clock.now()`` timestamp checked between chunks.  Once it
    passes, the results emitted so far come back with ``exhausted=False``
    — the anytime contract of ``first_n``, keyed on time instead of
    count.  Emitted results are never discarded, so the return value is
    always a correct (possibly partial) subset of the full result set.

    ``backend`` selects where frontier expansion runs (DESIGN.md §9):
    ``"host"``/None (numpy, the default), ``"device"`` (the Pallas
    frontier kernel; constrained queries fall back to the host), or
    ``"auto"`` (`resolve_backend`'s small-k/dense-frontier rule).  Both
    backends plug an expansion step into the one driver loop below, so
    paths, counts, ``EnumStats`` and chunk boundaries are identical by
    construction — only the expansion engine changes.

    ``order`` switches to ranked (any-k) enumeration (DESIGN.md §10):
    paths come back in non-decreasing rank — hop count or edge-weight
    sum (``weights``, graph edge order) — with lexicographic vertex
    sequences breaking ties, identically across backends.  ``first_n``
    then means the top-n and a deadline truncation is a rank-optimal
    prefix.  Ranked enumeration and ``constraint`` are mutually
    exclusive (the heap frontier carries rank state where the chunk
    walk carries constraint state).
    """
    spec = rank.make_rank_spec(order, weights)
    if spec is not None and constraint is not None:
        raise ValueError("order= cannot be combined with constraint= "
                         "(constrained ranked enumeration is not "
                         "supported; post-filter instead)")
    resolved = resolve_backend(idx, backend, constraint, order=order)
    if spec is None:
        if resolved == "device" and constraint is None \
                and first_n is None and max_results is None \
                and os.environ.get("REPRO_DEVICE_DEQUE", "").lower() \
                not in ("off", "0"):
            # full unconstrained device enumerations keep the work deque
            # resident on device (DESIGN.md §9); anytime contracts
            # (first_n / max_results) need per-chunk host decisions and
            # stay on the host-looped driver below
            return _drive_resident(idx, chunk_size=chunk_size,
                                   count_only=count_only,
                                   deadline=deadline)
        step = _device_step(idx) if resolved == "device" \
            else _host_step(idx, constraint)
        return _drive(idx, step, chunk_size=chunk_size,
                      count_only=count_only, first_n=first_n,
                      max_results=max_results, constraint=constraint,
                      deadline=deadline)
    if resolved == "device":
        return _drive_ranked_buckets(idx, _device_step(idx),
                                     chunk_size=chunk_size,
                                     count_only=count_only, first_n=first_n,
                                     max_results=max_results,
                                     deadline=deadline)
    return _drive_ranked_heap(idx, spec, chunk_size=chunk_size,
                              count_only=count_only, first_n=first_n,
                              max_results=max_results, deadline=deadline)


def _drive(idx: LightweightIndex, step, chunk_size: int, count_only: bool,
           first_n: Optional[int], max_results: Optional[int], constraint,
           deadline: Optional[float]) -> EnumResult:
    """The backend-independent IDX-DFS driver (DESIGN.md §9).

    Owns every anytime contract — the LIFO chunk walk, the per-chunk
    deadline check, first_n's exact-n trim, the max_results limit, and
    chunk_size splitting — so host and device expansion cannot diverge
    on them.  ``step(paths, depth, cstate, stats, want_cont)`` performs
    one hop for one chunk and returns ``None`` (chunk fully dead, stats
    already updated) or ``(emit_rows, cont_rows, cont_state)`` with rows
    in emission order; ``want_cont`` is False on the last hop, where
    survivors could never be extended.
    """
    k, s = idx.k, idx.s
    root = np.full((1, k + 1), PAD, dtype=np.int32)
    root[0, 0] = s
    cstate0 = constraint.init(1) if constraint is not None else None
    # LIFO deque of (paths, depth, constraint_state) — deepest first = DFS
    work: List[Tuple[np.ndarray, int, object]] = [(root, 0, cstate0)]
    return _drive_from(idx, step, work, EnumStats(), [], [], 0,
                       chunk_size=chunk_size, count_only=count_only,
                       first_n=first_n, max_results=max_results,
                       constraint=constraint, deadline=deadline)


def _drive_from(idx: LightweightIndex, step,
                work: List[Tuple[np.ndarray, int, object]],
                stats: EnumStats, out_paths: List[np.ndarray],
                out_lens: List[np.ndarray], count: int, chunk_size: int,
                count_only: bool, first_n: Optional[int],
                max_results: Optional[int], constraint,
                deadline: Optional[float]) -> EnumResult:
    """`_drive`'s loop, resumable from mid-walk state — the entry point
    both for a fresh walk (`_drive` seeds the root) and for the
    device-resident deque's capacity-stall fallback (`_drive_resident`
    rebuilds ``work``/``stats``/outputs from the arena and continues
    here, so a stalled walk finishes with identical semantics)."""
    k = idx.k

    while work:
        if deadline is not None and clock.expired(deadline):
            return _finalize(idx, out_paths, out_lens, count, stats,
                             exhausted=False)
        paths, depth, cstate = work.pop()
        stats.chunks += 1
        expanded = step(paths, depth, cstate, stats, depth + 1 < k)
        if expanded is None:
            continue
        emit_rows, cont_rows, cont_state = expanded

        if emit_rows is not None and emit_rows.shape[0]:
            count += emit_rows.shape[0]
            stats.results += emit_rows.shape[0]
            if not count_only:
                out_paths.append(emit_rows)
                out_lens.append(np.full(emit_rows.shape[0], depth + 1,
                                        np.int32))
            if max_results is not None and count > max_results:
                raise EngineLimit(f"more than {max_results} results")
            if first_n is not None and count >= first_n:
                count = _trim_to_first_n(out_paths, out_lens, count,
                                         first_n, count_only, stats)
                return _finalize(idx, out_paths, out_lens, count, stats,
                                 exhausted=False)

        if cont_rows is not None and cont_rows.shape[0]:
            # split into chunks; push in reverse so earlier rows pop first
            pieces = range(0, cont_rows.shape[0], chunk_size)
            for st in reversed(list(pieces)):
                sl = slice(st, st + chunk_size)
                piece_cs = constraint.slice(cont_state, sl) \
                    if constraint is not None else None
                work.append((cont_rows[sl], depth + 1, piece_cs))

    return _finalize(idx, out_paths, out_lens, count, stats, exhausted=True,
                     canonical=True)


def _host_step(idx: LightweightIndex, constraint):
    """The numpy expansion step: `_expand_chunk` plus the Appendix-E
    constraint machinery (extend/accept/gather), folded to the driver's
    (emit_rows, cont_rows, cont_state) contract."""

    def step(paths, depth, cstate, stats, want_cont):
        expanded = _expand_chunk(idx, paths, depth, stats)
        if expanded is None:
            return None
        parent, pos, vnew, emit, cont = expanded

        if constraint is not None:
            eids = idx.fwd_eid[pos]
            cstate_new, keep = constraint.extend(cstate, parent, eids, vnew)
            pruned = (emit | cont) & ~keep
            stats.invalid_partials += int(pruned.sum())
            emit = emit & keep
            cont = cont & keep
        else:
            cstate_new = None

        def rows_of(sel):
            rows = paths[parent[sel]].copy()
            rows[:, depth + 1] = vnew[sel]
            return rows

        emit_rows = None
        if emit.any():
            sel = np.nonzero(emit)[0]
            if constraint is not None:
                acc = constraint.accept(cstate_new, sel)
                stats.invalid_partials += int((~acc).sum())
                sel = sel[acc]
            if sel.size:
                emit_rows = rows_of(sel)

        cont_rows, cont_state = None, None
        if want_cont and cont.any():
            sel = np.nonzero(cont)[0]
            cont_rows = rows_of(sel)
            cont_state = constraint.gather(cstate_new, sel) \
                if constraint is not None else None
        return emit_rows, cont_rows, cont_state

    return step


# Per-kernel-launch candidate-slot budget: a chunk whose (rows × padded
# fan-out) rectangle exceeds it is cut into contiguous row segments, so
# one hub vertex in a wide chunk cannot inflate the dense slot matrices
# past memory (the host path's work is proportional to actual candidates;
# the device rectangle is rows × max fan-out).  Segment outputs
# concatenate in row order, so emission order — and therefore every
# first_n prefix — is unchanged.
DEVICE_SLOT_BUDGET = 1 << 19


def _fanout_segments(cnt: np.ndarray, budget: int) -> List[Tuple[int, int]]:
    """Contiguous [start, end) row segments whose rows × next-pow2(max
    fan-out) rectangles each fit the slot budget (single rows always
    form a valid segment)."""
    # common case first, vectorized: the whole chunk's rectangle fits,
    # so the O(rows) scan below never runs on ordinary chunks
    whole = 1 << (max(int(cnt.max(initial=0)), 1) - 1).bit_length()
    if cnt.shape[0] * whole <= budget:
        return [(0, cnt.shape[0])]
    segments: List[Tuple[int, int]] = []
    start, seg_max = 0, 1
    for i in range(cnt.shape[0]):
        c = max(int(cnt[i]), 1)
        new_max = max(seg_max, 1 << (c - 1).bit_length())
        if i > start and (i - start + 1) * new_max > budget:
            segments.append((start, i))
            start, seg_max = i, 1 << (c - 1).bit_length()
        else:
            seg_max = new_max
    segments.append((start, cnt.shape[0]))
    return segments


def _device_step(idx: LightweightIndex):
    """The Pallas expansion step (DESIGN.md §9): one kernel launch per
    fan-out segment of the chunk, Fig.-6 counters accumulated from the
    kernel's device scalars.  The host keeps two cheap responsibilities:
    sizing segments off the offset arrays (which also shortcuts all-dead
    chunks without a launch), and the driver's usual splitting."""
    from ..kernels import ops as kops   # lazy: pallas only on this path
    k, t = idx.k, idx.t
    dev = idx.device_arrays()

    def step(paths, depth, cstate, stats, want_cont):
        last = paths[:, depth].astype(np.int64)
        b = k - depth - 1
        cnt = (idx.fwd_end[last, b] - idx.fwd_begin[last]) if b >= 0 \
            else np.zeros(paths.shape[0], np.int64)
        if int(cnt.sum()) == 0:
            stats.invalid_partials += paths.shape[0]
            return None
        emit_parts: List[np.ndarray] = []
        cont_parts: List[np.ndarray] = []
        for lo, hi in _fanout_segments(cnt, DEVICE_SLOT_BUDGET):
            emit_rows, cont_rows, n_emit, n_cont, counters = \
                kops.frontier_expand(paths[lo:hi], dev.begin, dev.end,
                                     dev.dst, depth=depth, t=t,
                                     max_deg=max(int(cnt[lo:hi].max()), 1),
                                     want_cont=want_cont)
            edges, partials, invalid, _ = (int(x) for x in
                                           np.asarray(counters))
            stats.edges_accessed += edges
            stats.partials_generated += partials
            stats.invalid_partials += invalid
            ne, nc = int(n_emit), int(n_cont)
            if ne:
                emit_parts.append(np.asarray(emit_rows[:ne]))
            if want_cont and nc:
                cont_parts.append(np.asarray(cont_rows[:nc]))
        # one array per chunk, like the host step: _trim_to_first_n
        # trims only the driver's last appended block
        emit_out = (np.concatenate(emit_parts, axis=0)
                    if emit_parts else None)
        cont_out = (np.concatenate(cont_parts, axis=0)
                    if cont_parts else None)
        return emit_out, cont_out, None

    return step


def _drive_resident(idx: LightweightIndex, chunk_size: int,
                    count_only: bool,
                    deadline: Optional[float]) -> EnumResult:
    """Device-resident deque driver (DESIGN.md §9, the tentpole of the
    device enumeration column): the LIFO chunk stack lives in a device
    arena and ``ops.frontier_deque_round`` runs many pop→expand→push
    iterations per host round-trip — the host syncs only to drain the
    round's emitted paths, fold its counters into ``EnumStats`` and
    check the cooperative ``deadline``.

    Semantics are `_drive` + `_device_step` bit-for-bit on every full
    enumeration: the in-arena push replicates the driver's chunk_size
    split and reversed piece order, so the pop sequence (and therefore
    ``stats.chunks`` and every Fig.-6 counter) is identical, and
    exhausted results pass through the same canonical sort.  Two
    escapes return to the host-looped driver: an index whose padded
    ``rows × fan-out`` rectangle exceeds the slot budget never enters
    (the host path segments wide chunks; the resident kernel cannot),
    and a capacity stall mid-walk (arena/emit/meta guard trips with
    chunks still queued) rebuilds the host work list from the arena and
    resumes `_drive_from` — same walk, same stats, different engine.
    ``REPRO_DEVICE_DEQUE=off|0`` disables the resident path entirely.
    """
    from ..kernels import ops as kops   # lazy: pallas only on this path
    k, s, t = idx.k, idx.s, idx.t
    max_deg = int((idx.fwd_end[:, k] - idx.fwd_begin).max(initial=0))
    cfg = kops.deque_config(k + 1, chunk_size, max_deg)
    if max_deg == 0 or cfg.cap > DEVICE_SLOT_BUDGET \
            or chunk_size > cfg.arena_cap:
        return _drive(idx, _device_step(idx), chunk_size=chunk_size,
                      count_only=count_only, first_n=None,
                      max_results=None, constraint=None, deadline=deadline)

    dev = idx.device_arrays()
    stats = EnumStats()
    out_paths: List[np.ndarray] = []
    out_lens: List[np.ndarray] = []
    count = 0
    root = np.full((k + 1,), PAD, dtype=np.int32)
    root[0] = s
    arena, m_depth, m_len, top, n_chunks = \
        kops.frontier_deque_init(root, cfg=cfg)

    while True:
        if deadline is not None and clock.expired(deadline):
            return _finalize(idx, out_paths, out_lens, count, stats,
                             exhausted=False)
        arena, m_depth, m_len, top, n_chunks, emitbuf, emitlen, n_emit, \
            counters, pops = kops.frontier_deque_round(
                arena, m_depth, m_len, top, n_chunks, dev.begin, dev.end,
                dev.dst, t, cfg=cfg)
        stats.chunks += int(pops)
        edges, partials, invalid, _ = (int(x) for x in np.asarray(counters))
        stats.edges_accessed += edges
        stats.partials_generated += partials
        stats.invalid_partials += invalid
        ne = int(n_emit)
        if ne:
            count += ne
            stats.results += ne
            if not count_only:
                out_paths.append(np.asarray(emitbuf[:ne]))
                out_lens.append(np.asarray(emitlen[:ne]))
        nc = int(n_chunks)
        if nc == 0:
            break
        if int(pops) == 0:
            # capacity stall: rebuild the host work list (meta slots
            # bottom→top; list.pop() then takes the top chunk first,
            # preserving the LIFO order) and finish on the host loop
            rows = np.asarray(arena[:int(top)])
            lens = np.asarray(m_len[:nc]).astype(np.int64)
            depths = np.asarray(m_depth[:nc])
            starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
            work: List[Tuple[np.ndarray, int, object]] = [
                (rows[starts[j]:starts[j] + lens[j]], int(depths[j]), None)
                for j in range(nc)]
            return _drive_from(idx, _device_step(idx), work, stats,
                               out_paths, out_lens, count,
                               chunk_size=chunk_size,
                               count_only=count_only, first_n=None,
                               max_results=None, constraint=None,
                               deadline=deadline)

    return _finalize(idx, out_paths, out_lens, count, stats,
                     exhausted=True, canonical=True)


def _drive_ranked_heap(idx: LightweightIndex, spec: "rank.RankSpec",
                       chunk_size: int, count_only: bool,
                       first_n: Optional[int], max_results: Optional[int],
                       deadline: Optional[float]) -> EnumResult:
    """Best-first host driver for ranked enumeration (DESIGN.md §10).

    Two heaps over the canonical ``(cost, sequence)`` key:

      * *partials*, keyed by an admissible lower bound — accumulated
        cost so far plus ``rank.remaining_lower_bound`` at the frontier
        vertex (depth + dist_t for hops; the min-plus analogue for
        weights);
      * *results*, keyed by exact canonical cost.

    The emission gate: pop the minimum result only once it provably
    precedes every completion of every live partial — for hops an exact
    tuple compare against the minimum partial (the lexicographic
    extension property makes the tie case safe: a partial whose key ties
    the result extends to sequences that still compare after it), for
    weights a strict clearance of ``min bound − slack`` (see
    ``rank.WEIGHT_TIE_SLACK``; true ties then meet in the results heap,
    where canonical costs are bit-identical, and break exactly on the
    sequence).  Otherwise a batch of equal-depth partials is popped from
    the heap top and expanded through the same `_expand_chunk` hop the
    unranked driver uses — speculative expansion is always safe because
    emission order is decided solely by the gate.

    Anytime contracts: ``first_n`` stops after the n-th emission (the
    top-n); a deadline returns only the gated emissions — pending
    results cannot be flushed, an undiscovered path could still precede
    them — so the prefix is rank-optimal by construction.
    """
    k, s = idx.k, idx.s
    stats = EnumStats()
    out_paths: List[np.ndarray] = []
    out_lens: List[np.ndarray] = []
    count = 0
    lb = rank.remaining_lower_bound(idx, spec)
    zero = 0.0 if spec.is_weight else 0

    root = np.full(k + 1, PAD, dtype=np.int32)
    root[0] = s
    tick = 0  # heap tiebreak so comparison never reaches the ndarray
    # entry: (bound-or-cost, sequence tuple, tick, depth, row, acc)
    partials = [(zero + lb[s], (int(s),), tick, 0, root, zero)]
    results: List[Tuple] = []

    def gated(res_key, part_key):
        if spec.is_weight:
            return res_key[0] < part_key[0] - rank.weight_slack(part_key[0])
        return res_key[:2] < part_key[:2]

    while partials or results:
        if deadline is not None and clock.expired(deadline):
            return _finalize(idx, out_paths, out_lens, count, stats,
                             exhausted=False)
        if results and (not partials or gated(results[0], partials[0])):
            cost, _seq, _tick, depth, row, _acc = heapq.heappop(results)
            if first_n is not None and count >= first_n:
                return _finalize(idx, out_paths, out_lens, count, stats,
                                 exhausted=False)
            count += 1
            stats.results += 1
            if not count_only:
                out_paths.append(row[None, :])
                out_lens.append(np.full(1, depth, np.int32))
            if max_results is not None and count > max_results:
                raise EngineLimit(f"more than {max_results} results")
            if first_n is not None and count >= first_n:
                return _finalize(idx, out_paths, out_lens, count, stats,
                                 exhausted=False)
            continue

        batch = [heapq.heappop(partials)]
        depth = batch[0][3]
        while partials and len(batch) < chunk_size \
                and partials[0][3] == depth:
            batch.append(heapq.heappop(partials))
        rows = np.stack([e[4] for e in batch])
        accs = np.asarray([e[5] for e in batch])
        stats.chunks += 1
        expanded = _expand_chunk(idx, rows, depth, stats)
        if expanded is None:
            continue
        parent, pos, vnew, emit, cont = expanded
        acc_new = accs[parent] + rank.edge_step_costs(idx, spec, pos)

        for i in np.nonzero(emit)[0]:
            p = int(parent[i])
            row = rows[p].copy()
            row[depth + 1] = vnew[i]
            tick += 1
            heapq.heappush(results, (acc_new[i],
                                     batch[p][1] + (int(vnew[i]),),
                                     tick, depth + 1, row, acc_new[i]))
        if depth + 1 < k:
            for i in np.nonzero(cont)[0]:
                p = int(parent[i])
                row = rows[p].copy()
                row[depth + 1] = vnew[i]
                tick += 1
                heapq.heappush(partials,
                               (acc_new[i] + lb[vnew[i]],
                                batch[p][1] + (int(vnew[i]),),
                                tick, depth + 1, row, acc_new[i]))

    return _finalize(idx, out_paths, out_lens, count, stats, exhausted=True)


def _drive_ranked_buckets(idx: LightweightIndex, step, chunk_size: int,
                          count_only: bool, first_n: Optional[int],
                          max_results: Optional[int],
                          deadline: Optional[float]) -> EnumResult:
    """Rank-bucketed device driver for ``order="hops"`` (DESIGN.md §10).

    Hop bounds are integers, so the best-first frontier collapses into
    buckets: every partial row with lower bound ``b = depth + dist_t
    [last]`` lives in bucket ``b``.  Buckets drain in ascending order
    through the *unchanged* Pallas expansion step — a child either
    emits (cost exactly ``b``: an edge into t pins the parent's dist_t
    at 1) or re-buckets at ``depth+1 + dist_t[child] ≥ b`` (triangle
    inequality of BFS levels), so once bucket ``b`` is empty, its
    collected emissions are the complete cost-``b`` stratum.  One lex
    sort per stratum then yields the canonical ``(cost, sequence)``
    order, bit-identical to the host heap.

    Anytime contracts: ``first_n`` trims inside a sorted stratum; a
    deadline keeps only completed strata (the in-progress bucket's
    emissions are discarded — its stratum is incomplete, so any prefix
    through it could misorder) — again a rank-optimal prefix.
    """
    k, s = idx.k, idx.s
    stats = EnumStats()
    out_paths: List[np.ndarray] = []
    out_lens: List[np.ndarray] = []
    count = 0
    dist_t = idx.dist_t.astype(np.int64)

    root = np.full((1, k + 1), PAD, dtype=np.int32)
    root[0, 0] = s
    bucket_keys = [int(dist_t[s])]
    buckets = {int(dist_t[s]): [(root, 0)]}

    while bucket_keys:
        b = heapq.heappop(bucket_keys)
        pend = buckets.pop(b)
        stratum: List[np.ndarray] = []
        while pend:
            if deadline is not None and clock.expired(deadline):
                return _finalize(idx, out_paths, out_lens, count, stats,
                                 exhausted=False)
            rows, depth = pend.pop()
            stats.chunks += 1
            expanded = step(rows, depth, None, stats, depth + 1 < k)
            if expanded is None:
                continue
            emit_rows, cont_rows, _ = expanded
            if emit_rows is not None and emit_rows.shape[0]:
                stratum.append(emit_rows)
            if cont_rows is not None and cont_rows.shape[0] \
                    and depth + 1 < k:
                nb = depth + 1 + dist_t[cont_rows[:, depth + 1]]
                for val in np.unique(nb):
                    sel = cont_rows[nb == val]
                    if int(val) == b:
                        dest = pend
                    else:
                        dest = buckets.setdefault(int(val), [])
                        if len(dest) == 0:
                            heapq.heappush(bucket_keys, int(val))
                    for st in range(0, sel.shape[0], chunk_size):
                        dest.append((sel[st:st + chunk_size], depth + 1))
        if not stratum:
            continue
        allr = np.concatenate(stratum, axis=0)
        allr = allr[np.lexsort(tuple(allr[:, j] for j in range(k, -1, -1)))]
        nres = allr.shape[0]
        count += nres
        stats.results += nres
        if not count_only:
            out_paths.append(allr)
            out_lens.append(np.full(nres, b, np.int32))
        if max_results is not None and count > max_results:
            raise EngineLimit(f"more than {max_results} results")
        if first_n is not None and count >= first_n:
            count = _trim_to_first_n(out_paths, out_lens, count, first_n,
                                     count_only, stats)
            return _finalize(idx, out_paths, out_lens, count, stats,
                             exhausted=False)

    return _finalize(idx, out_paths, out_lens, count, stats, exhausted=True)


def _trim_to_first_n(out_paths, out_lens, count, first_n, count_only,
                     stats) -> int:
    """Drop the over-emitted tail of the last chunk so exactly ``first_n``
    results come back — the first-n counts then agree between the DFS and
    join paths regardless of either path's emission granularity.

    Which n rows survive is contract-dependent: under ``order`` the
    emitters feed this trim in canonical rank order, so the survivors
    are exactly the top-n; with ``order=None`` a truncated (non-
    exhausted) prefix stays *plan-defined* — DFS emission order for the
    dfs plans, key-group order for join — and only exhausted results are
    canonicalized (`_finalize(canonical=True)`)."""
    excess = count - first_n
    if excess > 0:
        stats.results -= excess
        if not count_only:
            out_paths[-1] = out_paths[-1][:-excess]
            out_lens[-1] = out_lens[-1][:-excess]
        count = first_n
    return count


def _finalize(idx, out_paths, out_lens, count, stats, exhausted,
              canonical: bool = False) -> EnumResult:
    """Concatenate emitted blocks into an EnumResult.  ``canonical``
    applies the hops-canonical ``(length, sequence)`` sort — requested
    only for *exhausted* unranked results, so every backend and plan
    returns the same ordered list on a full enumeration (ranked drivers
    already emit in their own canonical order, and truncated unranked
    prefixes stay plan-defined, see `_trim_to_first_n`)."""
    k = idx.k
    if out_paths:
        paths = np.concatenate(out_paths, axis=0)
        lens = np.concatenate(out_lens, axis=0)
        if canonical and paths.shape[0] > 1:
            perm = rank.canonical_perm(paths, lens.astype(np.int64))
            paths = paths[perm]
            lens = lens[perm]
    else:
        paths = np.zeros((0, k + 1), dtype=np.int32)
        lens = np.zeros((0,), dtype=np.int32)
    return EnumResult(paths=paths, lengths=lens, count=count, stats=stats,
                      exhausted=exhausted)
