"""IDX-DFS adapted to frontiers (Algorithm 4 → chunked level-synchronous).

The recursive DFS of the paper becomes a *chunked depth-first frontier*
walk: partial results are rows of a fixed-width int32 matrix, one hop
expands every row of a chunk simultaneously (gather from the index via the
O(1) offset lookup), and a LIFO deque of chunks preserves the depth-first
memory bound — the live set is O(chunk · k · max_branch/chunk) rather than
the paper's O(k), the standard accelerator transformation (DESIGN.md §2).

Semantics are identical to Algorithm 4:
  * candidates come from I_t(v, k - L(M) - 1)   (budget read off the index)
  * the simple-path check `v' ∉ M` is the vectorized prefix compare
  * a row reaching t is emitted

Instrumentation mirrors the paper's Fig. 6 metrics: #edges accessed,
#invalid partials (generated partials that never reach any result — here:
dup-pruned expansions plus dead-end rows), #results.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, List, Optional, Tuple

import numpy as np

from .graph import PAD
from .index import LightweightIndex


class EngineLimit(RuntimeError):
    """Raised when a configured result/partial budget would be exceeded."""


@dataclasses.dataclass
class EnumStats:
    edges_accessed: int = 0
    invalid_partials: int = 0
    partials_generated: int = 0
    results: int = 0
    chunks: int = 0

    def merge(self, other: "EnumStats") -> None:
        self.edges_accessed += other.edges_accessed
        self.invalid_partials += other.invalid_partials
        self.partials_generated += other.partials_generated
        self.results += other.results
        self.chunks += other.chunks


@dataclasses.dataclass
class EnumResult:
    paths: np.ndarray          # (r, k+1) int32, PAD after the t column
    lengths: np.ndarray        # (r,) int32 — number of edges
    count: int                 # total results (== r unless count_only)
    stats: EnumStats
    exhausted: bool = True     # False when stopped early by first_n

    def as_tuples(self) -> List[Tuple[int, ...]]:
        out = []
        for row, l in zip(self.paths, self.lengths):
            out.append(tuple(int(x) for x in row[: l + 1]))
        return out


def _expand_chunk(idx: LightweightIndex, paths: np.ndarray, depth: int,
                  stats: EnumStats):
    """One hop for every row of `paths` (all at the same depth).

    Returns (emit_rows, cont_rows, parent_of_cont, parent_of_emit).
    """
    k, t = idx.k, idx.t
    last = paths[:, depth].astype(np.int64)
    b = k - depth - 1
    begin = idx.fwd_begin[last]
    end = idx.fwd_end[last, max(b, 0)] if b >= 0 else begin
    cnt = (end - begin).astype(np.int64)
    total = int(cnt.sum())
    stats.edges_accessed += total
    if total == 0:
        stats.invalid_partials += paths.shape[0]
        return None
    parent = np.repeat(np.arange(paths.shape[0], dtype=np.int64), cnt)
    offs = np.zeros(paths.shape[0], dtype=np.int64)
    np.cumsum(cnt[:-1], out=offs[1:])
    pos = np.arange(total, dtype=np.int64) - offs[parent] + begin[parent]
    vnew = idx.fwd_dst[pos].astype(np.int32)

    prefix = paths[parent, : depth + 1]
    dup = (prefix == vnew[:, None]).any(axis=1)
    is_t = vnew == t
    emit = is_t & ~dup
    cont = ~is_t & ~dup

    stats.partials_generated += total
    stats.invalid_partials += int(dup.sum())
    # rows whose every expansion died contribute to invalid partials
    alive = np.zeros(paths.shape[0], dtype=bool)
    alive[parent[emit | cont]] = True
    stats.invalid_partials += int((~alive).sum())
    return parent, pos, vnew, emit, cont


def enumerate_paths_idx(
    idx: LightweightIndex,
    chunk_size: int = 16384,
    count_only: bool = False,
    first_n: Optional[int] = None,
    max_results: Optional[int] = None,
    constraint=None,
    deadline: Optional[float] = None,
) -> EnumResult:
    """Enumerate P(s,t,k,G) from the light-weight index (Algorithm 4).

    ``constraint`` is an optional Appendix-E extension object (see
    constraints.py) carrying vectorized per-partial state.

    ``deadline`` is a cooperative chunk budget: an absolute
    ``time.perf_counter()`` timestamp checked between chunks.  Once it
    passes, the results emitted so far come back with ``exhausted=False``
    — the anytime contract of ``first_n``, keyed on time instead of
    count.  Emitted results are never discarded, so the return value is
    always a correct (possibly partial) subset of the full result set.
    """
    k, s, t = idx.k, idx.s, idx.t
    stats = EnumStats()
    out_paths: List[np.ndarray] = []
    out_lens: List[np.ndarray] = []
    count = 0

    root = np.full((1, k + 1), PAD, dtype=np.int32)
    root[0, 0] = s
    cstate0 = constraint.init(1) if constraint is not None else None
    # LIFO deque of (paths, depth, constraint_state) — deepest first = DFS
    work: List[Tuple[np.ndarray, int, object]] = [(root, 0, cstate0)]

    while work:
        if deadline is not None and time.perf_counter() >= deadline:
            return _finalize(idx, out_paths, out_lens, count, stats,
                             exhausted=False)
        paths, depth, cstate = work.pop()
        stats.chunks += 1
        expanded = _expand_chunk(idx, paths, depth, stats)
        if expanded is None:
            continue
        parent, pos, vnew, emit, cont = expanded

        if constraint is not None:
            eids = idx.fwd_eid[pos]
            cstate_new, keep = constraint.extend(cstate, parent, eids, vnew)
            pruned = (emit | cont) & ~keep
            stats.invalid_partials += int(pruned.sum())
            emit = emit & keep
            cont = cont & keep
        else:
            cstate_new = None

        if emit.any():
            sel = np.nonzero(emit)[0]
            if constraint is not None:
                acc = constraint.accept(cstate_new, sel)
                stats.invalid_partials += int((~acc).sum())
                sel = sel[acc]
            if sel.size:
                rows = paths[parent[sel]].copy()
                rows[:, depth + 1] = vnew[sel]
                count += rows.shape[0]
                stats.results += rows.shape[0]
                if not count_only:
                    out_paths.append(rows)
                    out_lens.append(np.full(rows.shape[0], depth + 1, np.int32))
                if max_results is not None and count > max_results:
                    raise EngineLimit(f"more than {max_results} results")
                if first_n is not None and count >= first_n:
                    count = _trim_to_first_n(out_paths, out_lens, count,
                                             first_n, count_only, stats)
                    return _finalize(idx, out_paths, out_lens, count, stats,
                                     exhausted=False)

        if depth + 1 < k and cont.any():
            sel = np.nonzero(cont)[0]
            rows = paths[parent[sel]].copy()
            rows[:, depth + 1] = vnew[sel]
            cs = constraint.gather(cstate_new, sel) if constraint is not None else None
            # split into chunks; push in reverse so earlier rows pop first
            pieces = range(0, rows.shape[0], chunk_size)
            for st in reversed(list(pieces)):
                sl = slice(st, st + chunk_size)
                piece_cs = constraint.slice(cs, sl) if constraint is not None else None
                work.append((rows[sl], depth + 1, piece_cs))

    return _finalize(idx, out_paths, out_lens, count, stats, exhausted=True)


def _trim_to_first_n(out_paths, out_lens, count, first_n, count_only,
                     stats) -> int:
    """Drop the over-emitted tail of the last chunk so exactly ``first_n``
    results come back — the first-n counts then agree between the DFS and
    join paths regardless of either path's emission granularity."""
    excess = count - first_n
    if excess > 0:
        stats.results -= excess
        if not count_only:
            out_paths[-1] = out_paths[-1][:-excess]
            out_lens[-1] = out_lens[-1][:-excess]
        count = first_n
    return count


def _finalize(idx, out_paths, out_lens, count, stats, exhausted) -> EnumResult:
    k = idx.k
    if out_paths:
        paths = np.concatenate(out_paths, axis=0)
        lens = np.concatenate(out_lens, axis=0)
    else:
        paths = np.zeros((0, k + 1), dtype=np.int32)
        lens = np.zeros((0,), dtype=np.int32)
    return EnumResult(paths=paths, lengths=lens, count=count, stats=stats,
                      exhausted=exhausted)
