"""Ground-truth reference for HcPE: plain recursive backtracking (Alg. 1).

Pure Python + numpy, deliberately simple.  Every engine path (IDX-DFS
frontier enumerator, IDX-JOIN, constrained variants) is validated against
this oracle as an exact *set* comparison — HcPE is set enumeration, emit
order is not part of the contract.

Under ``order=`` (ranked / any-k mode, DESIGN.md §10) the contract
tightens to the exact *sequence*: the oracle sorts by ``(cost,
lexicographic vertex sequence)`` where cost is the hop count or the
left-to-right edge-weight sum — python floats accumulated in the same
order as the engines' float64, so ties and near-ties agree bit-for-bit
— and the rank-order fuzz layer asserts ordered-list equality against
every backend.
"""
from __future__ import annotations

from typing import Callable, Iterable, List, Optional, Set, Tuple

import numpy as np

from .graph import Graph


def bfs_dist_np(graph: Graph, src: int, k: int, reverse: bool = False,
                excluded: Optional[int] = None) -> np.ndarray:
    """Bounded BFS distance from ``src`` (or *to* src if reverse) ≤ k+1.

    ``excluded`` is forbidden as a *transit* vertex (paper's G-{v}): it may
    receive a distance (query endpoints must stay addressable so that
    C_0 = {s} and t ∈ C_k) but is never expanded.
    """
    INF = k + 1
    dist = np.full(graph.n, INF, dtype=np.int32)
    dist[src] = 0
    frontier = [src]
    d = 0
    indptr = graph.rindptr if reverse else graph.indptr
    indices = graph.rindices if reverse else graph.indices
    while frontier and d < k:
        nxt = []
        for u in frontier:
            if u == excluded:
                continue
            for v in indices[indptr[u]:indptr[u + 1]]:
                v = int(v)
                if dist[v] > d + 1:
                    dist[v] = d + 1
                    nxt.append(v)
        frontier = nxt
        d += 1
    return dist


def path_cost(p: Tuple[int, ...], order: str,
              wmap: Optional[dict] = None) -> float:
    """Canonical rank cost of one path tuple: hop count, or the
    left-to-right edge-weight sum (``wmap``: (u, v) -> weight), summed
    in the engines' canonical accumulation order."""
    if order == "hops":
        return len(p) - 1
    cost = 0.0
    for a, b in zip(p, p[1:]):
        cost = cost + float(wmap[(a, b)])
    return cost


def rank_sorted(paths: Iterable[Tuple[int, ...]], order: Optional[str],
                weights=None, graph: Optional[Graph] = None,
                ) -> List[Tuple[int, ...]]:
    """Sort path tuples into the canonical ranked order (DESIGN.md §10):
    ``(cost, vertex sequence)`` — the exact sequence every backend must
    emit under ``order=``.  ``order=None`` uses the hops key (the
    canonicalization applied to exhausted unranked results)."""
    wmap = None
    if order == "weight":
        if graph is None or weights is None:
            raise ValueError("order='weight' needs graph and weights")
        wmap = {(int(a), int(b)): float(w)
                for a, b, w in zip(graph.esrc, graph.edst, weights)}
    key_order = order or "hops"
    return sorted(paths, key=lambda p: (path_cost(p, key_order, wmap), p))


def enumerate_paths(graph: Graph, s: int, t: int, k: int,
                    edge_pred: Optional[Callable[[int, int], bool]] = None,
                    order: Optional[str] = None,
                    weights=None) -> List[Tuple[int, ...]]:
    """All simple paths s->t with ≤ k edges (interior vertices ∉ {s,t}).

    Sorted plainly (tuple order) by default; ``order=`` returns the
    canonical ranked sequence instead (see `rank_sorted`).
    """
    if s == t:
        raise ValueError("s and t must be distinct")
    # B(v): distance to t (for the standard hop-feasibility pruning of Alg. 1;
    # does not change the result set, only the constant).
    B = bfs_dist_np(graph, t, k, reverse=True)
    out: List[Tuple[int, ...]] = []
    M = [s]
    on_path = {s}

    def search() -> None:
        v = M[-1]
        if v == t:
            out.append(tuple(M))
            return
        if len(M) - 1 >= k:
            return
        for v2 in graph.neighbors(v):
            v2 = int(v2)
            if v2 in on_path:
                continue
            if v2 == s:
                continue
            if edge_pred is not None and not edge_pred(v, v2):
                continue
            if (len(M) - 1) + 1 + B[v2] <= k:
                M.append(v2)
                on_path.add(v2)
                search()
                M.pop()
                on_path.discard(v2)

    search()
    if order is not None:
        return rank_sorted(out, order, weights=weights, graph=graph)
    return sorted(out)


def count_walks(graph: Graph, s: int, t: int, k: int) -> int:
    """|W(s,t,k,G)| per Definition 2.1 (interior vertices ∉ {s,t}).

    Used to validate the full-fledged cardinality estimator, which counts
    walks exactly (Eq. 6/7) when run to convergence.
    """
    # adjacency restricted: no edges out of t, no edges into s
    counts = np.zeros(graph.n, dtype=np.int64)
    counts[s] = 1
    total = 0
    for _ in range(k):
        nxt = np.zeros(graph.n, dtype=np.int64)
        for u in range(graph.n):
            if counts[u] == 0 or u == t:
                continue
            for v in graph.neighbors(u):
                v = int(v)
                if v == s:
                    continue
                nxt[v] += counts[u]
        total += int(nxt[t])
        nxt[t] = 0  # walks must stop at t (Definition 2.1)
        counts = nxt
    return total


def paths_as_set(paths: Iterable[Tuple[int, ...]]) -> Set[Tuple[int, ...]]:
    return set(tuple(int(x) for x in p) for p in paths)
