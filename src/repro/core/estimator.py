"""Cardinality estimation (Section 6.2) and cut-position search (Alg. 5).

Two estimators, exactly as the paper:

* ``preliminary_estimate`` — Eq. 5: T̂ = Σ_{0≤i≤k-1} Π_{0≤j≤i} γ̂_j using the
  γ̂ statistics gathered during index construction.  O(k²), host scalar math
  (it gates a host-side plan decision, so it never leaves the host).

* ``walk_count_dp`` — the full-fledged estimator, Eq. 6/7 via the DP of
  Algorithm 5.  On TPU this is k edge-parallel plus-times passes over the
  index-filtered edge list (a counting-semiring SpMV); here the host build
  runs in float64 (walk counts overflow int64 on the paper's own workloads,
  Table 6 reports 1e10+).  The (t,t) self-loop of the relation construction
  (§3.1 rule 3) is applied explicitly so that |Q[i:k]| and |Q[0:i]| count
  padded tuples exactly like the join model.

Exactness contract (tested): run to completion, ``dp.q_total`` equals
|W(s,t,k,G)| — the estimator is exact on *walks*; the path/walk gap is the
inherent estimation error the paper discusses in §6.4 and Fig. 18.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .index import LightweightIndex


def preliminary_estimate(index: LightweightIndex) -> float:
    """Eq. 5 — estimated search-space size from γ̂ statistics."""
    total = 0.0
    prod = 1.0
    for j in range(index.k):
        prod *= float(index.gamma[j])
        total += prod
        if prod == 0.0:
            break
    return total


@dataclasses.dataclass
class WalkCountDP:
    k: int
    # c_to[i, v]   = c_k^i(v): #walk-suffixes v@position i -> t (with padding)
    # c_from[i, v] = c_i^0(v): #walk-prefixes s -> v@position i (with padding)
    c_to: np.ndarray     # (k+1, n) float64
    c_from: np.ndarray   # (k+1, n) float64
    q_prefix: np.ndarray  # (k+1,) |Q[0:i]|
    q_suffix: np.ndarray  # (k+1,) |Q[i:k]|
    cut: int              # i* = argmin |Q[0:i]| + |Q[i:k]|
    t_dfs: float          # Σ_{1≤i≤k} |Q[0:i]|   (§6.3 cost of Alg. 4's order)
    t_join: float         # |Q| + Σ… (§6.3 cost of the bushy plan at i*)
    q_total: float        # |Q| = δ_W

    @property
    def est_results(self) -> float:
        return self.q_total


def _level_masks(index: LightweightIndex) -> np.ndarray:
    k = index.k
    ii = np.arange(k + 1)
    return ((index.dist_s[None, :] <= ii[:, None])
            & (index.dist_t[None, :] <= (k - ii)[:, None]))


def walk_count_dp(index: LightweightIndex) -> WalkCountDP:
    idx = index
    n, k, s, t = idx.n, idx.k, idx.s, idx.t
    lvl = _level_masks(idx)

    # index edge list (any order works for scatter-add); budgets are enforced
    # per-level with the dist arrays, mirroring I_t(v, k-i-1) / I_s(v, i-1).
    eu = np.repeat(np.arange(n, dtype=np.int64),
                   (idx.fwd_end[:, k] - idx.fwd_begin).astype(np.int64))
    ev = idx.fwd_dst.astype(np.int64)
    du = idx.dist_s[eu].astype(np.int64)
    dv = idx.dist_t[ev].astype(np.int64)

    # ---- backward: c_to[i] = c_k^i  (Alg. 5 lines 1-5) ----
    c_to = np.zeros((k + 1, n), dtype=np.float64)
    c_to[k, :] = np.where(lvl[k], 1.0, 0.0)  # C_k = {t} when query feasible
    for i in range(k - 1, -1, -1):
        nxt = c_to[i + 1]
        contrib = np.zeros(n, dtype=np.float64)
        m = dv <= (k - i - 1)          # I_t(u, k-i-1) membership for edge u->v
        np.add.at(contrib, eu[m], nxt[ev[m]])
        contrib[t] += nxt[t]           # virtual (t,t) self-loop (§3.1 rule 3)
        c_to[i] = np.where(lvl[i], contrib, 0.0)

    # ---- forward: c_from[i] = c_i^0  (Alg. 5 lines 6-10) ----
    c_from = np.zeros((k + 1, n), dtype=np.float64)
    c_from[0, :] = np.where(lvl[0], 1.0, 0.0)  # C_0 = {s}
    for i in range(1, k + 1):
        prv = c_from[i - 1]
        contrib = np.zeros(n, dtype=np.float64)
        m = du <= (i - 1)              # I_s(v, i-1) membership for edge u->v
        np.add.at(contrib, ev[m], prv[eu[m]])
        contrib[t] += prv[t]           # virtual (t,t) self-loop
        c_from[i] = np.where(lvl[i], contrib, 0.0)

    q_prefix = c_from.sum(axis=1)      # |Q[0:i]| = Σ_{v∈I(i)} c_i^0(v)
    q_suffix = c_to.sum(axis=1)        # |Q[i:k]| = Σ_{v∈I(i)} c_k^i(v)
    cut = int(np.argmin(q_prefix + q_suffix))
    q_total = float(c_from[k, t])

    # §6.3 cost comparison
    t_dfs = float(q_prefix[1:].sum())
    t_join = float(q_total + q_prefix[1:cut + 1].sum() + q_suffix[cut:].sum())
    return WalkCountDP(k=k, c_to=c_to, c_from=c_from, q_prefix=q_prefix,
                       q_suffix=q_suffix, cut=cut, t_dfs=t_dfs, t_join=t_join,
                       q_total=q_total)
