"""Cardinality estimation (Section 6.2) and cut-position search (Alg. 5).

Two estimators, exactly as the paper:

* ``preliminary_estimate`` — Eq. 5: T̂ = Σ_{0≤i≤k-1} Π_{0≤j≤i} γ̂_j using the
  γ̂ statistics gathered during index construction.  O(k²), host scalar math
  (it gates a host-side plan decision, so it never leaves the host).

* ``walk_count_dp`` — the full-fledged estimator, Eq. 6/7 via the DP of
  Algorithm 5.  The host build runs in float64 (walk counts overflow int64
  on the paper's own workloads, Table 6 reports 1e10+).  The (t,t)
  self-loop of the relation construction (§3.1 rule 3) is applied
  explicitly so that |Q[i:k]| and |Q[0:i]| count padded tuples exactly
  like the join model.

  ``backend="device"`` runs the same DP through the Pallas semiring
  kernels (DESIGN.md §9): the level masks come from min-plus BFS
  relaxations over the dense index adjacency (kernels/ops.bfs_dense —
  exact on index vertices because shortest s→v / v→t paths stay inside
  the light-weight index, §3.2), and each DP level is one
  counting-semiring matmul (kernels/ops.counting_spmm).  The matmul
  accumulates in f32, which is exact only for integers below 2^24
  (EXACT_COUNT_MAX) — any level value at or past it may have been
  rounded, so the device build *promotes itself to the host float64 DP*
  whenever a count reaches the bound (``WalkCountDP.backend_used``
  records which build produced the numbers).  Below the bound the device
  DP is bit-identical to the host DP: every partial sum is an exact f32
  integer, so accumulation order cannot matter.

Exactness contract (tested): run to completion, ``dp.q_total`` equals
|W(s,t,k,G)| — the estimator is exact on *walks*; the path/walk gap is the
inherent estimation error the paper discusses in §6.4 and Fig. 18.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .index import LightweightIndex

# f32 counting-semiring accumulation is exact strictly below 2^24: all DP
# values are non-negative integers and partial sums are bounded by the
# final sum, so a device build whose levels all stay below this bound is
# bit-exact; a level that *reaches* it may already have rounded (a true
# 2^24+1 rounds to 2^24), hence the >= promotion test.
EXACT_COUNT_MAX = float(1 << 24)

# dense-tile ceiling for the device DP: the kernels run on an (n, n)
# dense adjacency, so past this the host edge-list scatter wins
DEVICE_DP_MAX_N = 2048


def preliminary_estimate(index: LightweightIndex) -> float:
    """Eq. 5 — estimated search-space size from γ̂ statistics."""
    total = 0.0
    prod = 1.0
    for j in range(index.k):
        prod *= float(index.gamma[j])
        total += prod
        if prod == 0.0:
            break
    return total


@dataclasses.dataclass
class WalkCountDP:
    k: int
    # c_to[i, v]   = c_k^i(v): #walk-suffixes v@position i -> t (with padding)
    # c_from[i, v] = c_i^0(v): #walk-prefixes s -> v@position i (with padding)
    c_to: np.ndarray     # (k+1, n) float64
    c_from: np.ndarray   # (k+1, n) float64
    q_prefix: np.ndarray  # (k+1,) |Q[0:i]|
    q_suffix: np.ndarray  # (k+1,) |Q[i:k]|
    cut: int              # i* = argmin |Q[0:i]| + |Q[i:k]|
    t_dfs: float          # Σ_{1≤i≤k} |Q[0:i]|   (§6.3 cost of Alg. 4's order)
    t_join: float         # |Q| + Σ… (§6.3 cost of the bushy plan at i*)
    q_total: float        # |Q| = δ_W
    # which build produced the numbers: "host" (float64 edge-list DP) or
    # "device" (semiring kernels; promotes itself back to "host" when a
    # count reaches EXACT_COUNT_MAX, so "device" certifies exactness)
    backend_used: str = "host"

    @property
    def est_results(self) -> float:
        return self.q_total


def _level_masks(index: LightweightIndex) -> np.ndarray:
    k = index.k
    ii = np.arange(k + 1)
    return ((index.dist_s[None, :] <= ii[:, None])
            & (index.dist_t[None, :] <= (k - ii)[:, None]))


def _index_edge_list(index: LightweightIndex):
    """Index edge list (eu, ev) as int64 arrays — any order works for the
    scatter/matmul; budgets are enforced per level with the dist arrays,
    mirroring I_t(v, k-i-1) / I_s(v, i-1)."""
    eu = np.repeat(np.arange(index.n, dtype=np.int64),
                   (index.fwd_end[:, index.k]
                    - index.fwd_begin).astype(np.int64))
    ev = index.fwd_dst.astype(np.int64)
    return eu, ev


def _finish_dp(k: int, c_to: np.ndarray, c_from: np.ndarray, t: int,
               backend_used: str) -> WalkCountDP:
    """Derive the §6.3 cost model from the level tables.  Shared by the
    host and device builds so that equal tables give a bit-identical
    WalkCountDP regardless of which backend produced them."""
    q_prefix = c_from.sum(axis=1)      # |Q[0:i]| = Σ_{v∈I(i)} c_i^0(v)
    q_suffix = c_to.sum(axis=1)        # |Q[i:k]| = Σ_{v∈I(i)} c_k^i(v)
    cut = int(np.argmin(q_prefix + q_suffix))
    q_total = float(c_from[k, t])
    t_dfs = float(q_prefix[1:].sum())
    t_join = float(q_total + q_prefix[1:cut + 1].sum() + q_suffix[cut:].sum())
    return WalkCountDP(k=k, c_to=c_to, c_from=c_from, q_prefix=q_prefix,
                       q_suffix=q_suffix, cut=cut, t_dfs=t_dfs, t_join=t_join,
                       q_total=q_total, backend_used=backend_used)


def device_index_distances(index: LightweightIndex):
    """(dist_s, dist_t) derived *on device* by min-plus BFS relaxation
    (kernels/ops.bfs_dense) over the dense index adjacency, int64 with the
    index's own k+1 unreachable sentinel.

    Exactness (the §3.2 closure argument, asserted by the parity suite):
    for any index vertex v, some shortest s→v path lies entirely inside
    the index — each vertex x_i at position i of it has
    dist_s(x_i) = i and dist_t(x_i) ≤ dist_s(v) - i + dist_t(v), so
    x_i and the edge to its successor satisfy the index criterion.
    Hence k rounds of min-plus over index edges reproduce the graph BFS
    distances for every index vertex (and only overestimate — to the
    k+1 sentinel — on vertices outside the index, where every DP level
    mask is empty on both clocks anyway)."""
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    idx = index
    n, k, s, t = idx.n, idx.k, idx.s, idx.t
    eu, ev = _index_edge_list(idx)
    inf = 1e9
    wadj = np.full((n, n), inf, dtype=np.float32)
    wadj[eu, ev] = 1.0                    # multi-edges collapse for BFS
    dd_s = kops.bfs_dense(jnp.asarray(wadj), s, k, inf=inf)
    dd_t = kops.bfs_dense(jnp.asarray(np.ascontiguousarray(wadj.T)), t, k,
                          inf=inf)
    dist_s = np.minimum(np.asarray(dd_s), k + 1).astype(np.int64)
    dist_t = np.minimum(np.asarray(dd_t), k + 1).astype(np.int64)
    return dist_s, dist_t


def _walk_count_dp_device(index: LightweightIndex):
    """Alg. 5 through the Pallas semiring kernels (DESIGN.md §9): level
    masks from min-plus BFS distances, one counting-semiring matmul per
    DP level, f32 accumulation.  Returns None when any level count
    reaches EXACT_COUNT_MAX — the caller promotes to the host float64
    build (the overflow bugfix: f32 silently loses exactness past 2^24,
    so past it the device numbers are not trusted)."""
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    idx = index
    n, k, t = idx.n, idx.k, idx.t
    eu, ev = _index_edge_list(idx)
    dist_s, dist_t = device_index_distances(idx)

    # dense counting-semiring adjacency: A[u, v] = #edges u -> v (parallel
    # edges contribute walks separately, exactly like the host scatter)
    amat = np.zeros((n, n), dtype=np.float32)
    np.add.at(amat, (eu, ev), 1.0)
    a_fwd = jnp.asarray(amat)                              # for A @ x
    a_rev = jnp.asarray(np.ascontiguousarray(amat.T))      # for Aᵀ @ x

    ii = np.arange(k + 1)
    lvl_np = ((dist_s[None, :] <= ii[:, None])
              & (dist_t[None, :] <= (k - ii)[:, None]))
    lvl = jnp.asarray(lvl_np)
    dt_j = jnp.asarray(dist_t)
    ds_j = jnp.asarray(dist_s)

    # ---- backward: c_to[i] = c_k^i — one counting SpMM per level ----
    cur = jnp.where(lvl[k], 1.0, 0.0).astype(a_fwd.dtype)
    c_to_levels = [cur]
    for i in range(k - 1, -1, -1):
        vec = jnp.where(dt_j <= (k - i - 1), cur, 0.0)     # I_t budget
        contrib = kops.counting_spmm(a_fwd, vec[:, None])[:, 0]
        contrib = contrib.at[t].add(cur[t])                # (t,t) self-loop
        cur = jnp.where(lvl[i], contrib, 0.0)
        c_to_levels.append(cur)
    c_to = np.stack([np.asarray(x) for x in reversed(c_to_levels)]
                    ).astype(np.float64)

    # ---- forward: c_from[i] = c_i^0 — mirrored through Aᵀ ----
    cur = jnp.where(lvl[0], 1.0, 0.0).astype(a_fwd.dtype)
    c_from_levels = [cur]
    for i in range(1, k + 1):
        vec = jnp.where(ds_j <= (i - 1), cur, 0.0)         # I_s budget
        contrib = kops.counting_spmm(a_rev, vec[:, None])[:, 0]
        contrib = contrib.at[t].add(cur[t])                # (t,t) self-loop
        cur = jnp.where(lvl[i], contrib, 0.0)
        c_from_levels.append(cur)
    c_from = np.stack([np.asarray(x) for x in c_from_levels]
                      ).astype(np.float64)

    # overflow fence: every intermediate partial sum is bounded by some
    # level value (non-negative terms), so scanning the level tables
    # covers the whole computation
    if max(c_to.max(initial=0.0), c_from.max(initial=0.0)) \
            >= EXACT_COUNT_MAX:
        return None
    return _finish_dp(k, c_to, c_from, t, backend_used="device")


def walk_count_dp(index: LightweightIndex,
                  backend: str | None = None) -> WalkCountDP:
    """Alg. 5 / Eq. 6-7.  ``backend`` picks the build: None/"host" is the
    float64 edge-list DP; "device" runs the Pallas semiring kernels and
    silently promotes back to the host build on f32 overflow (the
    ``backend_used`` field says which one produced the numbers).  Both
    builds are bit-identical whenever the device build is returned."""
    if backend not in (None, "host", "device"):
        raise ValueError(f"unknown walk_count_dp backend {backend!r}")
    if backend == "device":
        dp = _walk_count_dp_device(index)
        if dp is not None:
            return dp
    idx = index
    n, k, s, t = idx.n, idx.k, idx.s, idx.t
    lvl = _level_masks(idx)

    eu, ev = _index_edge_list(idx)
    du = idx.dist_s[eu].astype(np.int64)
    dv = idx.dist_t[ev].astype(np.int64)

    # ---- backward: c_to[i] = c_k^i  (Alg. 5 lines 1-5) ----
    c_to = np.zeros((k + 1, n), dtype=np.float64)
    c_to[k, :] = np.where(lvl[k], 1.0, 0.0)  # C_k = {t} when query feasible
    for i in range(k - 1, -1, -1):
        nxt = c_to[i + 1]
        contrib = np.zeros(n, dtype=np.float64)
        m = dv <= (k - i - 1)          # I_t(u, k-i-1) membership for edge u->v
        np.add.at(contrib, eu[m], nxt[ev[m]])
        contrib[t] += nxt[t]           # virtual (t,t) self-loop (§3.1 rule 3)
        c_to[i] = np.where(lvl[i], contrib, 0.0)

    # ---- forward: c_from[i] = c_i^0  (Alg. 5 lines 6-10) ----
    c_from = np.zeros((k + 1, n), dtype=np.float64)
    c_from[0, :] = np.where(lvl[0], 1.0, 0.0)  # C_0 = {s}
    for i in range(1, k + 1):
        prv = c_from[i - 1]
        contrib = np.zeros(n, dtype=np.float64)
        m = du <= (i - 1)              # I_s(v, i-1) membership for edge u->v
        np.add.at(contrib, ev[m], prv[eu[m]])
        contrib[t] += prv[t]           # virtual (t,t) self-loop
        c_from[i] = np.where(lvl[i], contrib, 0.0)

    return _finish_dp(k, c_to, c_from, t, backend_used="host")
