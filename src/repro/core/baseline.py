"""Baselines the paper compares against.

``generic_dfs`` is Algorithm 1 — the backtracking framework shared by
BC-DFS / T-DFS / T-DFS2 — with the static barrier B(v) = S(v,t|G) from one
reverse BFS (the initialization BC-DFS uses before its dynamic barrier
updates kick in).  It traverses the *raw* graph: each step scans all of
N(v) and re-checks the hop bound, which is precisely the per-step cost the
light-weight index eliminates.  Instrumented with the same Fig.-6 metrics
as the index enumerator (#edges accessed, #invalid partials, #results) so
benchmarks/paper_tables.py can reproduce the paper's detailed comparison.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from .enumerate import EnumStats
from .graph import Graph
from .oracle import bfs_dist_np


@dataclasses.dataclass
class BaselineResult:
    paths: List[Tuple[int, ...]]
    count: int
    stats: EnumStats
    exhausted: bool = True


def generic_dfs(graph: Graph, s: int, t: int, k: int,
                count_only: bool = False,
                first_n: Optional[int] = None,
                max_steps: Optional[int] = None) -> BaselineResult:
    B = bfs_dist_np(graph, t, k, reverse=True)
    stats = EnumStats()
    out: List[Tuple[int, ...]] = []
    count = 0
    M = [s]
    on_path = {s}
    steps = 0
    stop = False

    def search() -> bool:
        """Returns True iff this subtree emitted at least one result."""
        nonlocal count, steps, stop
        v = M[-1]
        if v == t:
            count += 1
            stats.results += 1
            if not count_only:
                out.append(tuple(M))
            if first_n is not None and count >= first_n:
                stop = True
            return True
        any_emit = False
        nbrs = graph.neighbors(v)
        stats.edges_accessed += len(nbrs)
        steps += len(nbrs)
        if max_steps is not None and steps > max_steps:
            stop = True
        for v2 in nbrs:
            if stop:
                break
            v2 = int(v2)
            # Alg. 1 line 7: v' ∉ M and L(M) + 1 + B(v') <= k
            if v2 in on_path or v2 == s:
                stats.partials_generated += 1
                stats.invalid_partials += 1
                continue
            if (len(M) - 1) + 1 + B[v2] > k:
                stats.partials_generated += 1
                stats.invalid_partials += 1
                continue
            stats.partials_generated += 1
            M.append(v2)
            on_path.add(v2)
            emitted = search()
            if not emitted:
                stats.invalid_partials += 1
            any_emit = any_emit or emitted
            M.pop()
            on_path.discard(v2)
        return any_emit

    search()
    return BaselineResult(paths=sorted(out) if not count_only else [],
                          count=count, stats=stats, exhausted=not stop)
