"""Cross-query structure sharing for BatchPathEnum (DESIGN.md §13).

PR 1's batch engine shares *artifacts* across a batch — result dedup,
the index LRU, the stacked BFS — but every distinct ``(s, t, k)`` query
still enumerates alone.  Batch HcPE (Yuan et al., arXiv:2312.01424)
shows that on skewed traffic the enumeration work itself is shared:
queries fanning out of one hub vertex walk the same prefixes.  This
module adds that level of sharing in two layers:

  * **Level A — merged group index.**  ``detect_groups`` partitions a
    batch's distinct keys by shared source (and, for construction
    sharing, shared target) under the same ``(graph_id, graph_version,
    edge_mask_hash)``.  ``build_member_indexes`` refactors Algorithm 3
    so the batch's per-query distance pruning becomes per-member
    *masks* over one shared edge arena (each member's
    ``LightweightIndex`` is still byte-identical to ``build_index``).
    ``MergedGroupIndex`` is the enumeration-time form: the union of
    the members' index edges sorted by ``(src, kmax - slack)`` so one
    offset lookup yields every edge *some* member could still use at a
    given depth, plus the per-member boolean masks.

  * **Level B — shared-prefix enumeration.**  ``run_shared_groups``
    walks the merged index's prefix tree *once* per shared-s group
    (``_walk_group``), capturing per-member candidate counts,
    dup-prune counts and emission/continuation edges.  Each DFS-plan
    member then *replays* the capture (``_replay_dfs``) — an exact
    re-enactment of the ``_drive`` chunk loop over tree node ids, so
    results, ``EnumStats`` and chunk boundaries are byte-identical to
    a solo run — and each join-plan member derives its R_a relation
    from the same capture (``_derive_join_ra``) and finishes through
    the unchanged sort-merge join.

Sharing is semantics-free by contract: ``sharing="off"`` (or the
``REPRO_SHARING=off`` escape hatch) must be byte-identical to sharing
on, and tests/test_sharing.py locks every backend × plan × grouping
shape down to that.  When a group is unprofitable or unsafe — ranked
(``order=``) queries, join members with ``first_n``, a walk past
``SHARING_MAX_NODES``, a deadline expiring mid-walk — the group falls
back to per-member enumeration (``SharingFallback``), never to an
approximation.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import clock
from .enumerate import EnumResult, EnumStats, EngineLimit, _finalize, \
    _trim_to_first_n
from .graph import Graph, PAD
from .index import LightweightIndex, _offsets_from_sorted
from .join import enumerate_paths_join

#: Union-walk node budget: a shared prefix tree larger than this falls
#: back to per-member enumeration (the capture's (N, M) count matrices
#: stop paying for themselves long before memory becomes a concern).
SHARING_MAX_NODES = 1 << 18

#: Largest member count one merged group serves; bigger buckets are
#: chunked so the (N, M) capture matrices and the per-chunk member loop
#: stay narrow.
GROUP_MAX_MEMBERS = 32


class SharingFallback(Exception):
    """Raised inside a shared walk to abandon the group and fall back to
    per-member enumeration (node budget exceeded, deadline expired).
    Never escapes ``run_shared_groups``."""


def resolve_sharing(value: Optional[str]) -> str:
    """Resolve a sharing knob to ``"auto"`` or ``"off"``.

    ``None`` means "engine default" and resolves like ``"auto"``.  The
    ``REPRO_SHARING`` environment variable is the operational escape
    hatch (DESIGN.md §13): ``off``/``0`` forces sharing off process-wide
    regardless of what the caller asked for — mirroring how
    ``REPRO_DEVICE_ENUM`` steers the backend fallback matrix.
    """
    if value is not None and value not in ("auto", "off"):
        raise ValueError(f"unknown sharing mode {value!r}")
    if os.environ.get("REPRO_SHARING", "").lower() in ("off", "0"):
        return "off"
    return "auto" if value is None else value


@dataclasses.dataclass
class QueryGroup:
    """One batch overlap group: member ``QueryKey``s sharing ``kind``
    (``"s"`` or ``"t"``) anchored at vertex ``anchor``."""
    kind: str
    anchor: int
    keys: List[tuple]


def detect_groups(keys: Sequence[tuple], kinds: Tuple[str, ...] = ("s", "t"),
                  min_size: int = 2,
                  max_size: int = GROUP_MAX_MEMBERS) -> List[QueryGroup]:
    """The grouping pass (DESIGN.md §13): partition distinct query keys
    into overlap groups.

    Keys are ``(graph_id, s, t, k, edge_mask_hash, graph_version)``
    tuples of one batch, so graph identity / mask / version already
    agree.  Shared-s buckets are formed first (they share the walk
    root), then shared-t buckets over the leftovers; buckets smaller
    than ``min_size`` stay solo and buckets larger than ``max_size``
    are chunked.  Deterministic: buckets and members keep first-seen
    order.
    """
    out: List[QueryGroup] = []
    remaining = list(keys)
    for kind, col in (("s", 1), ("t", 2)):
        if kind not in kinds:
            continue
        buckets: "collections.OrderedDict[int, List[tuple]]" = \
            collections.OrderedDict()
        for key in remaining:
            buckets.setdefault(int(key[col]), []).append(key)
        leftover: List[tuple] = []
        for anchor, members in buckets.items():
            if len(members) < min_size:
                leftover.extend(members)
                continue
            for lo in range(0, len(members), max_size):
                chunk = members[lo:lo + max_size]
                if len(chunk) >= min_size:
                    out.append(QueryGroup(kind=kind, anchor=anchor,
                                          keys=chunk))
                else:
                    leftover.extend(chunk)
        remaining = leftover
    return out


# ---------------------------------------------------------------------------
# Level A: shared construction — per-member masks over one edge arena
# ---------------------------------------------------------------------------

def _member_index_from_selection(n: int, k: int, s: int, t: int,
                                 dist_s: np.ndarray, dist_t: np.ndarray,
                                 u_sel: np.ndarray, v_sel: np.ndarray,
                                 orig_sel: np.ndarray) -> LightweightIndex:
    """Assemble one member's ``LightweightIndex`` from its selected
    (u, v, original-edge-id) triples — the tail of Algorithm 3 with the
    keep-filter already applied.  The explicit ``orig`` tiebreak in both
    lexsorts reproduces ``build_index``'s stable sort over ascending
    edge ids, so the output is byte-identical no matter what order the
    selection arrives in."""
    order_f = np.lexsort((orig_sel, dist_t[v_sel], u_sel))
    fu_s, fv_s = u_sel[order_f], v_sel[order_f]
    fwd_eid = orig_sel[order_f]
    fwd_begin, fwd_end = _offsets_from_sorted(fu_s, dist_t[fv_s], n, k)

    order_r = np.lexsort((orig_sel, dist_s[u_sel], v_sel))
    ru_s, rv_s = u_sel[order_r], v_sel[order_r]
    rev_begin, rev_end = _offsets_from_sorted(rv_s, dist_s[ru_s], n, k)

    ii = np.arange(k + 1)
    lvl = (dist_s[None, :] <= ii[:, None]) \
        & (dist_t[None, :] <= (k - ii)[:, None])
    level_count = lvl.sum(axis=1).astype(np.int64)
    gamma = np.zeros(k, dtype=np.float64)
    for j in range(k):
        cj = np.nonzero(lvl[j])[0]
        if cj.size:
            b = k - j - 1
            cnts = fwd_end[cj, b] - fwd_begin[cj]
            gamma[j] = float(cnts.mean())

    return LightweightIndex(
        n=n, k=k, s=s, t=t, dist_s=dist_s, dist_t=dist_t,
        fwd_dst=fv_s.astype(np.int32), fwd_eid=fwd_eid.astype(np.int64),
        fwd_begin=fwd_begin, fwd_end=fwd_end,
        rev_src=ru_s.astype(np.int32), rev_begin=rev_begin, rev_end=rev_end,
        level_count=level_count, gamma=gamma)


def build_member_indexes(
        graph: Graph, triples: Sequence[Tuple[int, int, int]],
        dists: Sequence[Tuple[np.ndarray, np.ndarray]]
) -> List[LightweightIndex]:
    """Algorithm 3 refactored for a group (DESIGN.md §13): build every
    member's index over one shared edge arena.

    The per-query build filters the whole edge list per query; here the
    edge arrays are read once, each member's Prop-4.3 keep rule becomes
    a boolean *mask*, and the union of the masks defines a shared arena
    the per-member sorts select from.  Each returned index is
    byte-identical to ``build_index(graph, s, t, k, dist_fn=...)`` with
    the same injected distances (tests/test_batch.py property-checks
    this), so callers can mix grouped and solo construction freely.
    """
    g = graph
    u, v = g.esrc.astype(np.int64), g.edst.astype(np.int64)
    keeps: List[np.ndarray] = []
    union = np.zeros(u.shape[0], dtype=bool)
    for (s, t, k), (d_s, d_t) in zip(triples, dists):
        d_s = np.asarray(d_s, dtype=np.int32)
        d_t = np.asarray(d_t, dtype=np.int32)
        keep = ((d_s[u] + 1 + d_t[v]) <= k) & (v != s) & (u != t)
        keeps.append(keep)
        union |= keep
    arena_ids = np.nonzero(union)[0]          # ascending original edge ids
    u_a, v_a = u[arena_ids], v[arena_ids]

    out: List[LightweightIndex] = []
    for (s, t, k), (d_s, d_t), keep in zip(triples, dists, keeps):
        d_s = np.asarray(d_s, dtype=np.int32)
        d_t = np.asarray(d_t, dtype=np.int32)
        mask = keep[arena_ids]
        out.append(_member_index_from_selection(
            g.n, k, s, t, d_s, d_t, u_a[mask], v_a[mask], arena_ids[mask]))
    return out


@dataclasses.dataclass
class MergedGroupIndex:
    """One index serving a *set* of (s, t) pairs (DESIGN.md §13).

    The arena is the union of the member indexes' edges, addressed like
    a ``LightweightIndex`` but with the per-edge *slack* replacing the
    per-query distance: ``slack(e) = max_j (k_j - dist_t_j[dst(e)])``
    over the members keeping ``e``.  Sorting by ``(src, kmax - slack,
    edge id)`` makes ``a_begin[v] .. a_end[v, kmax - d - 1]`` the exact
    set of arena edges *some* member could still traverse at depth
    ``d`` — every member's budgeted candidate slice is a sub-sequence
    of it, selected by that member's boolean ``member_mask`` row plus
    its own ``dist_t`` budget check.
    """
    kind: str                      # "s" | "t"
    anchor: int                    # the shared vertex
    n: int
    kmax: int
    a_src: np.ndarray              # (A,) int64 arena edge sources
    a_dst: np.ndarray              # (A,) int32 arena edge destinations
    a_orig: np.ndarray             # (A,) int64 original edge ids
    a_begin: np.ndarray            # (n,) int64
    a_end: np.ndarray              # (n, kmax+1) int64 — end at slack budget
    member_mask: np.ndarray        # (M, A) bool — member keeps arena edge
    members: List[LightweightIndex]

    @classmethod
    def from_members(cls, members: Sequence[LightweightIndex], kind: str,
                     anchor: int) -> "MergedGroupIndex":
        """Merge member indexes into one arena.  Per-member edges are
        recovered from the forward index arrays (source ids re-expanded
        from the offset matrix), unioned by original edge id, and the
        slack-sorted offsets rebuilt with the same histogram+cumsum
        scheme as Algorithm 3."""
        n = members[0].n
        kmax = max(m.k for m in members)
        us, vs, es, sl = [], [], [], []
        for m in members:
            per_u = (m.fwd_end[:, m.k] - m.fwd_begin).astype(np.int64)
            mu = np.repeat(np.arange(n, dtype=np.int64), per_u)
            us.append(mu)
            vs.append(m.fwd_dst.astype(np.int64))
            es.append(m.fwd_eid.astype(np.int64))
            sl.append(m.k - m.dist_t[m.fwd_dst].astype(np.int64))
        all_u = np.concatenate(us) if us else np.zeros(0, np.int64)
        all_v = np.concatenate(vs) if vs else np.zeros(0, np.int64)
        all_e = np.concatenate(es) if es else np.zeros(0, np.int64)
        all_s = np.concatenate(sl) if sl else np.zeros(0, np.int64)
        if all_e.size:
            order = np.argsort(all_e, kind="stable")
            all_u, all_v, all_e, all_s = (all_u[order], all_v[order],
                                          all_e[order], all_s[order])
            first = np.ones(all_e.shape[0], dtype=bool)
            first[1:] = all_e[1:] != all_e[:-1]
            starts = np.nonzero(first)[0]
            arena_e = all_e[starts]
            arena_u = all_u[starts]
            arena_v = all_v[starts]
            slack = np.maximum.reduceat(all_s, starts)
        else:
            arena_e = arena_u = arena_v = np.zeros(0, np.int64)
            slack = np.zeros(0, np.int64)
        pseudo = kmax - slack                        # in [0, kmax - 1]
        order2 = np.lexsort((arena_e, pseudo, arena_u))
        a_src, a_dst, a_orig = (arena_u[order2], arena_v[order2],
                                arena_e[order2])
        a_begin, a_end = _offsets_from_sorted(a_src, pseudo[order2], n, kmax)
        mask = np.stack([np.isin(a_orig, m.fwd_eid) for m in members]) \
            if members else np.zeros((0, 0), bool)
        return cls(kind=kind, anchor=anchor, n=n, kmax=kmax,
                   a_src=a_src, a_dst=a_dst.astype(np.int32), a_orig=a_orig,
                   a_begin=a_begin, a_end=a_end, member_mask=mask,
                   members=list(members))

    @property
    def union_edge_ids(self) -> np.ndarray:
        """Sorted original edge ids of the arena — by construction the
        union of the members' ``fwd_eid`` sets (property-tested)."""
        return np.sort(self.a_orig)

    def member_view(self, j: int) -> LightweightIndex:
        """Re-derive member ``j``'s full ``LightweightIndex`` from the
        arena and its mask row.  This is the no-over-/under-pruning
        contract of the merged layout: the view must be byte-identical
        to the member's own ``build_index`` output (property-tested in
        tests/test_batch.py)."""
        m = self.members[j]
        sel = self.member_mask[j]
        return _member_index_from_selection(
            self.n, m.k, m.s, m.t, m.dist_s, m.dist_t,
            self.a_src[sel], self.a_dst[sel].astype(np.int64),
            self.a_orig[sel])


class GroupIndexCache:
    """Small LRU over ``MergedGroupIndex`` keyed on ``(graph_id, kind,
    anchor, member QueryKeys)`` (DESIGN.md §13).  Member keys embed
    ``edge_mask_hash`` and ``graph_version``, so a registry mutation
    makes stale merged indexes unreachable by construction — the
    eager ``drop_tenant`` purge (wired through
    ``GraphRegistry._drop_from_engines``) only frees their memory."""

    def __init__(self, capacity: int = 64) -> None:
        self.capacity = capacity
        self._entries: "collections.OrderedDict[tuple, MergedGroupIndex]" \
            = collections.OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: tuple) -> Optional[MergedGroupIndex]:
        """Look one group key up; a hit refreshes its LRU position."""
        hit = self._entries.get(key)
        if hit is not None:
            self._entries.move_to_end(key)
        return hit

    def put(self, key: tuple, value: MergedGroupIndex) -> None:
        """Insert one entry, evicting the LRU past ``capacity``."""
        if self.capacity <= 0:
            return
        self._entries[key] = value
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    def drop_tenant(self, graph_id: str) -> int:
        """Drop every merged index belonging to one tenant (the group
        half of ``GraphRegistry.retire``/``mutate``'s engine purge).
        Returns the number of entries dropped."""
        doomed = [k for k in self._entries if k[0] == graph_id]
        for k in doomed:
            del self._entries[k]
        return len(doomed)

    def clear(self) -> None:
        """Drop all entries."""
        self._entries.clear()


# ---------------------------------------------------------------------------
# Level B: the shared-prefix walk and its per-member replays
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _MemberSpec:
    """Per-member walk parameters: ``node_limit`` is the deepest tree
    node the member may own (k-1 for DFS continuations, the cut for a
    join half), ``expand_limit`` the deepest node it needs expanded."""
    slot: int
    idx: LightweightIndex
    k: int
    t: int
    dist_t: np.ndarray
    node_limit: int
    expand_limit: int


@dataclasses.dataclass
class _GroupCapture:
    """The walk's output: the union prefix tree (``parent``/``vertex``/
    ``depth`` per node id) plus, per member slot, the node-level Fig.-6
    ingredients (candidate count, dup count, validity) and the
    emission/continuation edges sorted by parent id for segment
    lookups.  Path rows are *not* stored — replays materialize them by
    chasing ``parent`` chains, so capture memory is O(nodes · members),
    not O(nodes · k)."""
    parent: np.ndarray            # (N,) int64
    vertex: np.ndarray            # (N,) int32
    depth: np.ndarray             # (N,) int32
    valid: np.ndarray             # (N, M) bool
    cnt: np.ndarray               # (N, M) int64 — member candidates of node
    dup: np.ndarray               # (N, M) int64 — member dup-pruned of node
    emit_par: List[np.ndarray]    # per member: parent node ids (sorted)
    emit_v: List[np.ndarray]      # per member: emitted vertex (== t_j)
    cont_par: List[np.ndarray]    # per member: parent node ids (sorted)
    cont_child: List[np.ndarray]  # per member: child node ids


def _segment_take(left: np.ndarray, right: np.ndarray) -> np.ndarray:
    """Flatten per-query [left, right) segment slices into one gather
    index array, segments concatenated in query order."""
    cnt = (right - left).astype(np.int64)
    total = int(cnt.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    par = np.repeat(np.arange(cnt.shape[0], dtype=np.int64), cnt)
    offs = np.zeros(cnt.shape[0], dtype=np.int64)
    np.cumsum(cnt[:-1], out=offs[1:])
    return np.arange(total, dtype=np.int64) - offs[par] + left[par]


def _materialize_rows(cap: _GroupCapture, parents: np.ndarray,
                      vnew: np.ndarray, depth: int,
                      width: int) -> np.ndarray:
    """Path rows for emissions: each row is the parent node's vertex
    chain (positions 0..depth) plus ``vnew`` at depth+1, PAD after."""
    rows = np.full((parents.shape[0], width), PAD, dtype=np.int32)
    rows[:, depth + 1] = vnew
    p = parents
    for d in range(depth, -1, -1):
        rows[:, d] = cap.vertex[p]
        p = cap.parent[p]
    return rows


def _walk_group(merged: MergedGroupIndex, specs: Sequence[_MemberSpec],
                chunk_size: int, deadline: Optional[float],
                max_nodes: Optional[int]) -> _GroupCapture:
    """Walk the merged index's prefix tree once, capturing per-member
    candidate/dup counts and emission/continuation edges.

    The LIFO chunk discipline mirrors `_drive` exactly — one pop per
    union chunk, candidates gathered through the arena offsets, one
    vectorized prefix compare — and the per-candidate classification is
    a single (total, M) matrix pass: per-member mask and distance
    budget fold into one static int8 arena table (``maxdep[e, j]`` =
    deepest depth member j may still take arena edge ``e``; -1 when
    masked out), so a chunk costs one fancy-index gather plus boolean
    matrix algebra — no per-member gathers or sorts in the hot loop.
    Emissions and continuations are captured unsorted with a static
    per-member *rank* (the member's own ``(dist_t_j, edge id)`` order
    within a source block, precomputed once per group) and sorted once
    per member at finalize, so replays still reproduce solo emission
    order bit-for-bit.  Raises ``SharingFallback`` past ``max_nodes``
    or the deadline.
    """
    M = len(specs)
    kmax = merged.kmax
    s = merged.anchor
    arena = merged.a_dst.shape[0]
    # static per-member tables over the arena: the walk's entire
    # member-specific state, amortized across every chunk
    maxdep = np.full((arena, M), -1, np.int8)
    rank_of: List[np.ndarray] = []
    a_dst64 = merged.a_dst.astype(np.int64)
    for j, spec in enumerate(specs):
        dist = spec.dist_t[a_dst64]
        md = np.clip(spec.k - 1 - dist, -1, 127).astype(np.int8)
        maxdep[:, j] = np.where(merged.member_mask[spec.slot], md,
                                np.int8(-1))
        order_j = np.lexsort((merged.a_orig, dist, merged.a_src))
        r = np.empty(arena, np.int32)
        r[order_j] = np.arange(arena, dtype=np.int32)
        rank_of.append(r)
    t_vec = np.array([spec.t for spec in specs], np.int32)
    node_limits = np.array([spec.node_limit for spec in specs], np.int64)
    expand_limits = np.array([spec.expand_limit for spec in specs],
                             np.int64)
    node_parent = [np.zeros(1, np.int64)]
    node_vertex = [np.full(1, s, np.int32)]
    node_depth = [np.zeros(1, np.int32)]
    valid_blocks: List[Tuple[np.ndarray, np.ndarray]] = \
        [(np.zeros(1, np.int64), np.ones((1, M), bool))]
    stat_blocks: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
    emit_par: List[List[np.ndarray]] = [[] for _ in range(M)]
    emit_v: List[List[np.ndarray]] = [[] for _ in range(M)]
    emit_rank: List[List[np.ndarray]] = [[] for _ in range(M)]
    cont_par: List[List[np.ndarray]] = [[] for _ in range(M)]
    cont_child: List[List[np.ndarray]] = [[] for _ in range(M)]
    cont_rank: List[List[np.ndarray]] = [[] for _ in range(M)]
    n_nodes = 1

    root_rows = np.full((1, kmax + 1), PAD, np.int32)
    root_rows[0, 0] = s
    work: List[Tuple[np.ndarray, np.ndarray, np.ndarray, int]] = \
        [(np.zeros(1, np.int64), root_rows, np.ones((1, M), bool), 0)]

    while work:
        if deadline is not None and clock.expired(deadline):
            raise SharingFallback("deadline expired during shared walk")
        ids, rows, vmat, depth = work.pop()
        last = rows[:, depth].astype(np.int64)
        ub = kmax - depth - 1
        begin = merged.a_begin[last]
        end = merged.a_end[last, ub] if ub >= 0 else begin
        cnt_u = (end - begin).astype(np.int64)
        total = int(cnt_u.sum())
        if total == 0:
            continue
        ppos = np.repeat(np.arange(ids.shape[0], dtype=np.int64), cnt_u)
        offs = np.zeros(ids.shape[0], np.int64)
        np.cumsum(cnt_u[:-1], out=offs[1:])
        apos = np.arange(total, dtype=np.int64) - offs[ppos] + begin[ppos]
        vnew = merged.a_dst[apos]
        prefix = rows[ppos, : depth + 1]
        dup = (prefix == vnew[:, None]).any(axis=1)
        par_ids = ids[ppos]

        # one (total, M) classification pass: gather the static table,
        # everything else is boolean matrix algebra
        ok = (maxdep[apos] >= depth) & vmat[ppos]
        live = ok & ~dup[:, None]
        is_t = vnew[:, None] == t_vec[None, :]
        em_mat = live & is_t
        cm_mat = live & ~is_t & (depth + 1 <= node_limits)[None, :]

        # per-parent per-member counts as cumsum differences over the
        # candidate axis (axis-0 reduceat on a wide bool matrix walks
        # strided memory; two contiguous cumsums don't)
        cnt_mat = np.zeros((ids.shape[0], M), np.int64)
        dup_mat = np.zeros((ids.shape[0], M), np.int64)
        nonempty = np.nonzero(cnt_u > 0)[0]
        starts = offs[nonempty]
        ends = (offs + cnt_u)[nonempty]
        csum = np.cumsum(ok, axis=0, dtype=np.int64)
        dsum = np.cumsum(ok & dup[:, None], axis=0, dtype=np.int64)
        top_c, top_d = csum[ends - 1], dsum[ends - 1]
        has_prev = starts > 0
        bot_c = np.zeros_like(top_c)
        bot_d = np.zeros_like(top_d)
        bot_c[has_prev] = csum[starts[has_prev] - 1]
        bot_d[has_prev] = dsum[starts[has_prev] - 1]
        cnt_mat[nonempty] = top_c - bot_c
        dup_mat[nonempty] = top_d - bot_d
        stat_blocks.append((ids, cnt_mat, dup_mat))

        if em_mat.any():
            nz_m, nz_c = np.nonzero(em_mat.T)       # member-major
            ecnt = np.bincount(nz_m, minlength=M)
            eoff = np.zeros(M + 1, np.int64)
            np.cumsum(ecnt, out=eoff[1:])
            for j in range(M):
                sel = nz_c[eoff[j]:eoff[j + 1]]
                if sel.size:
                    emit_par[j].append(par_ids[sel])
                    emit_v[j].append(vnew[sel])
                    emit_rank[j].append(rank_of[j][apos[sel]])

        union_cont = cm_mat.any(axis=1)
        sel_u = np.nonzero(union_cont)[0]
        if sel_u.size == 0:
            continue
        child_ids = np.arange(n_nodes, n_nodes + sel_u.size, dtype=np.int64)
        n_nodes += sel_u.size
        if max_nodes is not None and n_nodes > max_nodes:
            raise SharingFallback(f"union tree exceeded {max_nodes} nodes")
        node_parent.append(par_ids[sel_u])
        node_vertex.append(vnew[sel_u])
        node_depth.append(np.full(sel_u.size, depth + 1, np.int32))
        vchild = cm_mat[sel_u]
        valid_blocks.append((child_ids, vchild))
        cand2node = np.full(total, -1, np.int64)
        cand2node[sel_u] = child_ids

        nz_m, nz_c = np.nonzero(cm_mat.T)           # member-major
        ccnt = np.bincount(nz_m, minlength=M)
        coff = np.zeros(M + 1, np.int64)
        np.cumsum(ccnt, out=coff[1:])
        for j in range(M):
            sel = nz_c[coff[j]:coff[j + 1]]
            if sel.size:
                cont_par[j].append(par_ids[sel])
                cont_child[j].append(cand2node[sel])
                cont_rank[j].append(rank_of[j][apos[sel]])

        want = (vchild & (depth + 1 <= expand_limits)[None, :]).any(axis=1)
        selx = np.nonzero(want)[0]
        if selx.size:
            gpos = sel_u[selx]
            rows_new = rows[ppos[gpos]].copy()
            rows_new[:, depth + 1] = vnew[gpos]
            xids = child_ids[selx]
            xval = vchild[selx]
            for st in reversed(range(0, selx.size, chunk_size)):
                work.append((xids[st:st + chunk_size],
                             rows_new[st:st + chunk_size],
                             xval[st:st + chunk_size], depth + 1))

    parent = np.concatenate(node_parent)
    vertex = np.concatenate(node_vertex)
    dep = np.concatenate(node_depth)
    valid = np.zeros((n_nodes, M), bool)
    for ids_b, v_b in valid_blocks:
        valid[ids_b] = v_b
    cnt = np.zeros((n_nodes, M), np.int64)
    dupm = np.zeros((n_nodes, M), np.int64)
    for ids_b, c_b, d_b in stat_blocks:
        cnt[ids_b] = c_b
        dupm[ids_b] = d_b

    def _cat_sorted(pars: List[np.ndarray], vals: List[np.ndarray],
                    ranks: List[np.ndarray],
                    vdtype) -> Tuple[np.ndarray, np.ndarray]:
        """Concatenate captures and establish per-parent segments in the
        member's own candidate order — one sort per member total, in
        place of a sort per member per chunk."""
        if not pars:
            return np.zeros(0, np.int64), np.zeros(0, vdtype)
        p = np.concatenate(pars)
        x = np.concatenate(vals)
        r = np.concatenate(ranks)
        order = np.lexsort((r, p))
        return p[order], x[order]

    e_par, e_v, c_par, c_ch = [], [], [], []
    for j in range(M):
        p, x = _cat_sorted(emit_par[j], emit_v[j], emit_rank[j], np.int32)
        e_par.append(p)
        e_v.append(x)
        p, x = _cat_sorted(cont_par[j], cont_child[j], cont_rank[j],
                           np.int64)
        c_par.append(p)
        c_ch.append(x)
    return _GroupCapture(parent=parent, vertex=vertex, depth=dep,
                         valid=valid, cnt=cnt, dup=dupm,
                         emit_par=e_par, emit_v=e_v,
                         cont_par=c_par, cont_child=c_ch)


def _replay_dfs(cap: _GroupCapture, slot: int, idx: LightweightIndex,
                chunk_size: int, count_only: bool, first_n: Optional[int],
                deadline: Optional[float]) -> EnumResult:
    """Replay one member's IDX-DFS run off the capture — a line-for-line
    re-enactment of `_drive` over tree node ids instead of path rows:
    same LIFO pops, same chunk splits, same deadline / first_n exits,
    same Fig.-6 counter order.  Byte-identical to the solo run by
    construction (the parity suite asserts it, stats included)."""
    k = idx.k
    stats = EnumStats()
    out_paths: List[np.ndarray] = []
    out_lens: List[np.ndarray] = []
    count = 0
    ep, ev = cap.emit_par[slot], cap.emit_v[slot]
    cp, cc = cap.cont_par[slot], cap.cont_child[slot]
    work: List[Tuple[np.ndarray, int]] = [(np.zeros(1, np.int64), 0)]

    while work:
        if deadline is not None and clock.expired(deadline):
            return _finalize(idx, out_paths, out_lens, count, stats,
                             exhausted=False)
        ids, depth = work.pop()
        stats.chunks += 1
        cnts = cap.cnt[ids, slot]
        total = int(cnts.sum())
        stats.edges_accessed += total
        if total == 0:
            stats.invalid_partials += int(ids.shape[0])
            continue
        dups = cap.dup[ids, slot]
        stats.partials_generated += total
        stats.invalid_partials += int(dups.sum())
        stats.invalid_partials += int(np.count_nonzero(cnts == dups))

        el = np.searchsorted(ep, ids, side="left")
        er = np.searchsorted(ep, ids, side="right")
        ne = int((er - el).sum())
        if ne:
            count += ne
            stats.results += ne
            if not count_only:
                take = _segment_take(el, er)
                out_paths.append(_materialize_rows(cap, ep[take], ev[take],
                                                   depth, k + 1))
                out_lens.append(np.full(ne, depth + 1, np.int32))
            if first_n is not None and count >= first_n:
                count = _trim_to_first_n(out_paths, out_lens, count,
                                         first_n, count_only, stats)
                return _finalize(idx, out_paths, out_lens, count, stats,
                                 exhausted=False)

        if depth + 1 < k:
            cl = np.searchsorted(cp, ids, side="left")
            cr = np.searchsorted(cp, ids, side="right")
            take = _segment_take(cl, cr)
            if take.size:
                childs = cc[take]
                for st in reversed(range(0, childs.shape[0], chunk_size)):
                    work.append((childs[st:st + chunk_size], depth + 1))

    return _finalize(idx, out_paths, out_lens, count, stats, exhausted=True,
                     canonical=True)


def _derive_join_ra(cap: _GroupCapture, slot: int, idx: LightweightIndex,
                    cut: int, stats: EnumStats,
                    max_partials: Optional[int]) -> np.ndarray:
    """Derive one join member's R_a relation from the capture — the
    shared stand-in for `_expand_to_width(idx, [s], 0, cut+1, ...)`.

    The per-depth accounting re-enacts the solo expansion exactly:
    finished (t-reaching) rows persist as width-1 pads contributing to
    ``partials_generated`` but not ``edges_accessed``, the
    ``max_partials`` limit trips at the same step with the same
    message, and an all-dead step returns the same empty relation.  Row
    *order* is deterministic but not the solo order — irrelevant
    downstream: join keys come from ``np.unique``, the sort-merge sort
    is stable per key group, and exhausted outputs canonicalize.
    """
    t = idx.t
    valid_ids = np.nonzero(cap.valid[:, slot])[0]
    vdep = cap.depth[valid_ids]
    epar, ev = cap.emit_par[slot], cap.emit_v[slot]
    edep = (cap.depth[epar] + 1).astype(np.int64) if epar.size \
        else np.zeros(0, np.int64)
    e_hist = np.bincount(edep, minlength=cut + 2) if edep.size \
        else np.zeros(cut + 2, np.int64)
    finished = 0
    for d in range(cut):
        nd = valid_ids[vdep == d]
        cnt_d = int(cap.cnt[nd, slot].sum())
        stats.edges_accessed += cnt_d
        total = cnt_d + finished
        if total == 0:
            return np.zeros((0, cut + 1), np.int32)
        if max_partials is not None and total > max_partials:
            raise EngineLimit(f"join half exceeded {max_partials} partials")
        stats.partials_generated += total
        stats.invalid_partials += int(cap.dup[nd, slot].sum())
        finished += int(e_hist[d + 1])

    leaves = valid_ids[vdep == cut]
    rows_leaf = np.zeros((leaves.shape[0], cut + 1), np.int32)
    p = leaves
    for d in range(cut, -1, -1):
        rows_leaf[:, d] = cap.vertex[p]
        p = cap.parent[p]

    sel = np.nonzero(edep <= cut)[0]
    rows_emit = np.full((sel.shape[0], cut + 1), t, np.int32)
    sdep = edep[sel]
    for dd in np.unique(sdep):
        m = sdep == dd
        p = epar[sel[m]]
        for d in range(int(dd) - 1, -1, -1):
            rows_emit[m, d] = cap.vertex[p]
            p = cap.parent[p]
    return np.concatenate([rows_leaf, rows_emit], axis=0)


def run_shared_groups(engine, resolved: Dict[tuple, tuple],
                      plans: Dict[tuple, object], *, count_only: bool,
                      first_n: Optional[int], deadline: Optional[float],
                      graph_id: str):
    """Execute every shareable group of a batch (DESIGN.md §13).

    ``plans`` maps the batch's distinct keys (first-occurrence order) to
    their per-query plans; ``resolved`` maps them to built indexes.
    Shared-s groups with at least two *eligible* members — DFS plans
    always, join plans only without ``first_n`` (the join's first-n
    contract trims mid-emission, which a shared R_a cannot reproduce
    mid-group) — get one merged index (LRU-cached on the engine), one
    prefix walk, and per-member replays.  Any ``SharingFallback`` quietly
    returns the group to the caller's per-query path.  Returns
    ``(results, latencies, n_groups)`` where ``latencies`` charge each
    member its replay plus an equal share of the walk.
    """
    results: Dict[tuple, EnumResult] = {}
    latencies: Dict[tuple, float] = {}
    n_groups = 0
    for grp in detect_groups(list(plans.keys()), kinds=("s",)):
        eligible: List[Tuple[tuple, str, Optional[int]]] = []
        for key in grp.keys:
            plan = plans[key]
            if plan.method == "dfs":
                eligible.append((key, "dfs", None))
            elif plan.method == "join" and first_n is None and plan.cut:
                eligible.append((key, "join", int(plan.cut)))
        if len(eligible) < 2:
            continue
        eligible.sort(key=lambda e: e[0])
        member_keys = tuple(key for key, _, _ in eligible)
        gkey = (graph_id, grp.kind, grp.anchor, member_keys)
        merged = engine.group_cache.get(gkey)
        if merged is None:
            merged = MergedGroupIndex.from_members(
                [resolved[key][0] for key, _, _ in eligible],
                kind=grp.kind, anchor=grp.anchor)
            engine.group_cache.put(gkey, merged)
        specs: List[_MemberSpec] = []
        for slot, (key, meth, cut) in enumerate(eligible):
            idx = resolved[key][0]
            if meth == "dfs":
                specs.append(_MemberSpec(slot=slot, idx=idx, k=idx.k,
                                         t=idx.t, dist_t=idx.dist_t,
                                         node_limit=idx.k - 1,
                                         expand_limit=idx.k - 1))
            else:
                specs.append(_MemberSpec(slot=slot, idx=idx, k=idx.k,
                                         t=idx.t, dist_t=idx.dist_t,
                                         node_limit=int(cut),
                                         expand_limit=int(cut) - 1))
        t_w0 = time.perf_counter()
        try:
            cap = _walk_group(merged, specs, engine.engine.chunk_size,
                              deadline, SHARING_MAX_NODES)
        except SharingFallback:
            continue
        walk_share = (time.perf_counter() - t_w0) / len(specs)
        n_groups += 1
        for slot, (key, meth, cut) in enumerate(eligible):
            idx = resolved[key][0]
            t0 = time.perf_counter()
            if meth == "dfs":
                res = _replay_dfs(cap, slot, idx, engine.engine.chunk_size,
                                  count_only, first_n, deadline)
            else:
                def _ra(stats, max_partials, _slot=slot, _idx=idx,
                        _cut=int(cut)):
                    return _derive_join_ra(cap, _slot, _idx, _cut, stats,
                                           max_partials)
                res = enumerate_paths_join(
                    idx, cut=int(cut), count_only=count_only, first_n=None,
                    max_partials=engine.engine.max_partials,
                    deadline=deadline, _shared_ra=_ra)
            results[key] = res
            latencies[key] = (time.perf_counter() - t0) + walk_share
    return results, latencies, n_groups
