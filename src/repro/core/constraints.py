"""Appendix E — HcPE with variant constraints.

Three extensions, each mapping onto the motivation examples of Section 1:

* ``EdgePredicate``      — predicate on edge attributes (fraud example 2):
                           filter edges *before* the index BFS, the engine
                           is otherwise unchanged (Appendix E: "conduct the
                           filtering when computing the distance").
* ``AccumulativeValue``  — ⊕-accumulated edge values with a final predicate
                           f_a (money-laundering risk example 1, Alg. 7);
                           optional monotone bound enables in-flight pruning.
* ``ActionSequence``     — DFA over edge labels (KG example 3, Alg. 8).

The stateful constraints carry vectorized per-partial state through the
frontier enumerator (one array slot per live partial) — the accelerator
version of Alg. 7/8's extra recursion arguments.  For the join enumerator
they are applied on full tuples at join time, as Appendix E prescribes
("the DFS method can terminate the invalid search path at an earlier stage
than the join method").
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from .graph import Graph
from .index import LightweightIndex


def edge_predicate_mask(graph: Graph, pred: Callable[[np.ndarray, np.ndarray], np.ndarray]) -> np.ndarray:
    """Vectorized predicate over (esrc, edst) -> bool mask, fed to
    build_index(edge_mask=...)."""
    return np.asarray(pred(graph.esrc, graph.edst), dtype=bool)


class AccumulativeValue:
    """Alg. 7: accumulate ⊕ over edge values; accept iff f_a(β) at emit.

    op: associative+commutative ufunc-style callable (e.g. np.add)
    weights: (m,) values aligned with graph edge order (index carries the
             original edge ids, so lookups survive the index permutation).
    monotone_upper: if not None, partials whose accumulator already exceeds
             this bound are pruned in flight (valid only for monotone ⊕ with
             non-negative values — the Appendix-E caveat about negative
             weights is honored by leaving this None).
    """

    def __init__(self, weights: np.ndarray, op=np.add, init: float = 0.0,
                 accept: Callable[[np.ndarray], np.ndarray] = lambda b: b >= 0,
                 monotone_upper: Optional[float] = None):
        self.weights = np.asarray(weights, dtype=np.float64)
        self.op = op
        self.init_value = float(init)
        self.accept_fn = accept
        self.monotone_upper = monotone_upper

    # --- frontier-enumerator hooks (vectorized over partials) ---
    def init(self, rows: int) -> np.ndarray:
        return np.full(rows, self.init_value, dtype=np.float64)

    def extend(self, state, parent, eids, vnew):
        beta = self.op(state[parent], self.weights[eids])
        keep = np.ones(beta.shape[0], dtype=bool)
        if self.monotone_upper is not None:
            keep = beta <= self.monotone_upper
        return beta, keep

    def accept(self, state, sel):
        return np.asarray(self.accept_fn(state[sel]), dtype=bool)

    def gather(self, state, sel):
        return state[sel]

    def slice(self, state, sl):
        return state[sl]

    # --- join-enumerator hook (full tuples) ---
    def check_full(self, idx: LightweightIndex, rows: np.ndarray,
                   lens: np.ndarray) -> np.ndarray:
        # recompute β along each tuple via an edge-weight lookup table
        keep = np.ones(rows.shape[0], dtype=bool)
        betas = np.full(rows.shape[0], self.init_value, dtype=np.float64)
        wmap = self._weight_lookup(idx)
        for j in range(rows.shape[1] - 1):
            act = lens > j
            if not act.any():
                break
            u = rows[act, j].astype(np.int64)
            v = rows[act, j + 1].astype(np.int64)
            betas[act] = self.op(betas[act], wmap(u, v))
        return keep & np.asarray(self.accept_fn(betas), dtype=bool)

    def _weight_lookup(self, idx: LightweightIndex):
        n = idx.n
        table = {}
        # index edges only — every tuple edge is an index edge by construction
        eu = np.repeat(np.arange(n, dtype=np.int64),
                       (idx.fwd_end[:, idx.k] - idx.fwd_begin).astype(np.int64))
        ev = idx.fwd_dst.astype(np.int64)
        w = self.weights[idx.fwd_eid]
        dense = {}
        for a, b, ww in zip(eu.tolist(), ev.tolist(), w.tolist()):
            dense[(a, b)] = ww

        def look(u, v):
            return np.array([dense.get((a, b), 0.0)
                             for a, b in zip(u.tolist(), v.tolist())])
        return look


class ActionSequence:
    """Alg. 8: DFA over edge labels.

    A: (num_states, num_labels) int matrix; -1 = invalid transition.
    labels: (m,) int edge labels aligned with graph edge order.
    start, accepting: DFA start state and accepting-state mask.
    """

    def __init__(self, A: np.ndarray, labels: np.ndarray, start: int,
                 accepting: np.ndarray):
        self.A = np.asarray(A, dtype=np.int64)
        self.labels = np.asarray(labels, dtype=np.int64)
        self.start = int(start)
        self.accepting = np.asarray(accepting, dtype=bool)

    def init(self, rows: int) -> np.ndarray:
        return np.full(rows, self.start, dtype=np.int64)

    def extend(self, state, parent, eids, vnew):
        nxt = self.A[np.maximum(state[parent], 0), self.labels[eids]]
        keep = (state[parent] >= 0) & (nxt >= 0)
        return nxt, keep

    def accept(self, state, sel):
        st = state[sel]
        ok = st >= 0
        out = np.zeros(st.shape[0], dtype=bool)
        out[ok] = self.accepting[st[ok]]
        return out

    def gather(self, state, sel):
        return state[sel]

    def slice(self, state, sl):
        return state[sl]

    def check_full(self, idx: LightweightIndex, rows: np.ndarray,
                   lens: np.ndarray) -> np.ndarray:
        lmap = self._label_lookup(idx)
        st = np.full(rows.shape[0], self.start, dtype=np.int64)
        for j in range(rows.shape[1] - 1):
            act = (lens > j) & (st >= 0)
            if not act.any():
                break
            u = rows[act, j].astype(np.int64)
            v = rows[act, j + 1].astype(np.int64)
            lab = lmap(u, v)
            st_act = st[act]
            nxt = np.where(lab >= 0, self.A[np.maximum(st_act, 0),
                                            np.maximum(lab, 0)], -1)
            st[act] = nxt
        ok = st >= 0
        out = np.zeros(rows.shape[0], dtype=bool)
        out[ok] = self.accepting[st[ok]]
        return out

    def _label_lookup(self, idx: LightweightIndex):
        eu = np.repeat(np.arange(idx.n, dtype=np.int64),
                       (idx.fwd_end[:, idx.k] - idx.fwd_begin).astype(np.int64))
        ev = idx.fwd_dst.astype(np.int64)
        lab = self.labels[idx.fwd_eid]
        dense = {}
        for a, b, ll in zip(eu.tolist(), ev.tolist(), lab.tolist()):
            dense[(a, b)] = ll

        def look(u, v):
            return np.array([dense.get((a, b), -1)
                             for a, b in zip(u.tolist(), v.tolist())],
                            dtype=np.int64)
        return look
