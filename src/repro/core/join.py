"""IDX-JOIN (Algorithm 6): bushy plan — evaluate Q[0:i*] and Q[i*:k] by
frontier DFS, then join on the cut vertex.

TPU adaptation (DESIGN.md §2): the paper's hash join becomes a sort-merge
join — both relations are sorted by the cut key (numpy lexsort here; bitonic
sort network on device), matched by segment, and the cross products emitted
per key group.  The `(t,t)` virtual self-loop of the relation construction
(§3.1 rule 3) appears explicitly: a partial that reaches t before its target
width is padded with t, so sub-queries cover all path lengths ≤ k in one
evaluation — exactly the trick that lets the paper avoid k separate joins.

The within-half simple-path check runs during expansion; the cross-half
check runs at join time (the paper: "we check whether a result is a valid
path when performing the join operation").

Ranked mode (DESIGN.md §10): ``order=`` keeps the same halves and the
same per-group join, but schedules cut-key groups by a lower bound on
their cheapest joinable result (min half cost on each side), processes
them in ascending bound order, and gates emission on the next group's
bound — results strictly below it can no longer be preceded, so anytime
truncations (deadline, early ``first_n``) return rank-optimal prefixes
and the full run returns the exact canonical ``(cost, sequence)`` order
that the DFS backends produce.
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional

import numpy as np

from . import clock, estimator, rank
from .enumerate import (DEVICE_AUTO_MIN_EDGES, EngineLimit, EnumResult,
                        EnumStats, _finalize, _trim_to_first_n)
from .graph import PAD
from .index import LightweightIndex


@dataclasses.dataclass
class JoinStats(EnumStats):
    ra_size: int = 0
    rb_size: int = 0
    pairs: int = 0


def resolve_join_backend(idx: LightweightIndex,
                         backend: Optional[str]) -> str:
    """The join/count column of the §9 fallback matrix: where the
    hop-count DP (Alg. 5, the join plan's cut derivation) runs.

    ``host``/None is the float64 edge-list DP.  ``device`` runs the
    Pallas semiring kernels (min-plus BFS level masks + counting-semiring
    matmul per level) but falls back to the host for indexes wider than
    the dense-tile ceiling (estimator.DEVICE_DP_MAX_N — the kernels work
    on an (n, n) dense adjacency).  ``auto`` additionally requires a
    dense-enough index and a real accelerator (or
    ``REPRO_DEVICE_ENUM=force`` for CPU CI).  ``REPRO_DEVICE_ENUM=off|0``
    is the same uniform kill switch as the enumeration column.  Note the
    resolved backend only picks *where the numbers are computed*: the
    device DP promotes itself back to the host build on f32 overflow
    (estimator.EXACT_COUNT_MAX), so plans are identical either way."""
    if backend is not None and backend not in ("host", "device", "auto"):
        raise ValueError(f"unknown backend {backend!r}")
    if os.environ.get("REPRO_DEVICE_ENUM", "").lower() in ("off", "0"):
        return "host"
    if backend is None or backend == "host":
        return "host"
    if idx.n > estimator.DEVICE_DP_MAX_N:
        return "host"
    if backend == "device":
        return "device"
    # backend == "auto"
    if idx.num_index_edges < DEVICE_AUTO_MIN_EDGES:
        return "host"
    if os.environ.get("REPRO_DEVICE_ENUM") == "force":
        return "device"
    import jax
    return "device" if jax.default_backend() != "cpu" else "host"


def hop_count_dp(idx: LightweightIndex,
                 backend: Optional[str] = None) -> estimator.WalkCountDP:
    """The join/count plan's hop-count derivation (Alg. 5 / Eq. 6-7)
    behind the §9 ``host|device|auto`` knob: resolves the backend with
    `resolve_join_backend` and runs estimator.walk_count_dp there.  The
    returned DP is bit-identical across backends — the device build is
    exact below 2^24 and promotes itself to the host build past it
    (``dp.backend_used`` records which one ran)."""
    return estimator.walk_count_dp(
        idx, backend=resolve_join_backend(idx, backend))


def _expand_to_width(idx: LightweightIndex, start_vertices: np.ndarray,
                     start_pos: int, width: int, stats: EnumStats,
                     max_partials: Optional[int]) -> np.ndarray:
    """All walk tuples of `width` vertices starting at position `start_pos`
    from the given start vertices, with t-padding (Alg. 6 Search procedure).

    Budget at depth L(M): I_t(v, k - start_pos - L(M) - 1) per Alg. 6 L12.
    Within-half dup-check applied (padding-t exempt).
    """
    k, t = idx.k, idx.t
    rows = np.full((start_vertices.shape[0], width), PAD, dtype=np.int32)
    rows[:, 0] = start_vertices
    for d in range(width - 1):
        last = rows[:, d].astype(np.int64)
        finished = rows[:, d] == t
        # finished rows pad with t; unfinished expand via the index
        b = k - start_pos - d - 1
        begin = idx.fwd_begin[last]
        end = idx.fwd_end[last, b] if b >= 0 else begin
        cnt = np.where(finished, 1, (end - begin)).astype(np.int64)
        stats.edges_accessed += int(cnt[~finished].sum())
        total = int(cnt.sum())
        if total == 0:
            return rows[:0, :]
        if max_partials is not None and total > max_partials:
            raise EngineLimit(f"join half exceeded {max_partials} partials")
        parent = np.repeat(np.arange(rows.shape[0], dtype=np.int64), cnt)
        offs = np.zeros(rows.shape[0], dtype=np.int64)
        np.cumsum(cnt[:-1], out=offs[1:])
        slot = np.arange(total, dtype=np.int64) - offs[parent]
        vnew = np.where(
            finished[parent], t,
            idx.fwd_dst[np.minimum(begin[parent] + slot,
                                   idx.fwd_dst.shape[0] - 1)]
            if idx.fwd_dst.size else t).astype(np.int32)
        new_rows = rows[parent].copy()
        new_rows[:, d + 1] = vnew
        # within-half simple-path check (t-padding exempt)
        dup = ((new_rows[:, : d + 1] == vnew[:, None]).any(axis=1)
               & (vnew != t))
        stats.partials_generated += total
        stats.invalid_partials += int(dup.sum())
        rows = new_rows[~dup]
        if rows.shape[0] == 0:
            return rows
    return rows


def enumerate_paths_join(
    idx: LightweightIndex,
    cut: int,
    count_only: bool = False,
    first_n: Optional[int] = None,
    max_partials: Optional[int] = None,
    max_results: Optional[int] = None,
    constraint=None,
    deadline: Optional[float] = None,
    order: Optional[str] = None,
    weights: Optional[np.ndarray] = None,
    _shared_ra=None,
) -> EnumResult:
    """Algorithm 6 with cut position ``cut`` (i*).

    ``_shared_ra`` is the cross-query sharing hook (DESIGN.md §13): a
    callable ``(stats, max_partials) -> ndarray`` that stands in for
    the R_a half expansion, deriving the same width-``cut+1`` relation
    (same rows, same stats accrual, same ``EngineLimit`` behavior) from
    a group's shared prefix walk instead of a private one.  R_b, the
    sort-merge join and every output contract are unchanged.

    ``first_n`` is the paper's response-time mode on the join plan: both
    halves are still evaluated in full (the join needs them), but emission
    stops after exactly ``first_n`` results with ``exhausted=False`` — the
    same truncation contract as enumerate_paths_idx.

    ``deadline`` (absolute ``core.clock.now()``) is the cooperative
    time analogue, checked at the join's natural chunk boundaries: before
    each half expansion and between cut-key groups.  Past it, the paths
    joined so far return with ``exhausted=False``.

    ``order`` switches to ranked enumeration (DESIGN.md §10): key groups
    are scheduled by cost lower bound and results come back in the same
    canonical ``(cost, sequence)`` order as the DFS backends; anytime
    truncations are then rank-optimal prefixes.  Mutually exclusive with
    ``constraint``, mirroring enumerate_paths_idx.
    """
    k, s, t = idx.k, idx.s, idx.t
    if not 0 < cut < k:
        raise ValueError(f"cut must be in (0, k), got {cut}")
    spec = rank.make_rank_spec(order, weights)
    if spec is not None and constraint is not None:
        raise ValueError("order= cannot be combined with constraint= "
                         "(constrained ranked enumeration is not "
                         "supported; post-filter instead)")
    if spec is not None:
        return _join_ranked(idx, cut, spec, count_only=count_only,
                            first_n=first_n, max_partials=max_partials,
                            max_results=max_results, deadline=deadline)
    stats = JoinStats()

    def _expired() -> bool:
        return deadline is not None and clock.expired(deadline)

    if _expired():
        return _finalize(idx, [], [], 0, stats, exhausted=False)

    # R_a = Q[0:cut]: tuples of cut+1 vertices starting at s (position 0)
    if _shared_ra is not None:
        ra = _shared_ra(stats, max_partials)
    else:
        ra = _expand_to_width(idx, np.array([s], np.int32), 0, cut + 1,
                              stats, max_partials)
    stats.ra_size = ra.shape[0]
    if ra.shape[0] == 0:
        return _finalize(idx, [], [], 0, stats, exhausted=True)
    if _expired():
        return _finalize(idx, [], [], 0, stats, exhausted=False)

    # C = join keys realized in R_a (Alg. 6 L3)
    keys = np.unique(ra[:, cut])
    # R_b = Q[cut:k]: tuples of k-cut+1 vertices starting at position cut
    rb = _expand_to_width(idx, keys.astype(np.int32), cut, k - cut + 1, stats,
                          max_partials)
    stats.rb_size = rb.shape[0]
    if rb.shape[0] == 0:
        return _finalize(idx, [], [], 0, stats, exhausted=True)

    # ---- sort-merge join on the cut vertex ----
    order_a = np.argsort(ra[:, cut], kind="stable")
    order_b = np.argsort(rb[:, 0], kind="stable")
    ra_s, rb_s = ra[order_a], rb[order_b]
    ka, kb = ra_s[:, cut], rb_s[:, 0]

    out_paths: List[np.ndarray] = []
    out_lens: List[np.ndarray] = []
    count = 0
    # segment boundaries per key
    a_start = np.searchsorted(ka, keys, side="left")
    a_end = np.searchsorted(ka, keys, side="right")
    b_start = np.searchsorted(kb, keys, side="left")
    b_end = np.searchsorted(kb, keys, side="right")

    A_BLOCK = 256  # bound the (na_blk, nb, cut, k-cut) clash tensor
    for ki in range(keys.shape[0]):
        if _expired():
            return _finalize(idx, out_paths, out_lens, count, stats,
                             exhausted=False)
        na, nb = a_end[ki] - a_start[ki], b_end[ki] - b_start[ki]
        if na == 0 or nb == 0:
            continue
        stats.pairs += int(na * nb)
        A = ra_s[a_start[ki]:a_end[ki]]             # (na, cut+1)
        B = rb_s[b_start[ki]:b_end[ki]]             # (nb, k-cut+1)
        bi = B[:, 1:]                                # positions cut+1..k
        bmask = bi != t
        for a0 in range(0, na, A_BLOCK):
            ai = A[a0:a0 + A_BLOCK, :cut]            # positions 0..cut-1
            # cross-half simple-path check: a non-t vertex of the prefix
            # interior must not reappear in the suffix interior.
            clash = ((ai[:, None, :, None] == bi[None, :, None, :])
                     & (ai != t)[:, None, :, None]
                     & bmask[None, :, None, :]).any(axis=(2, 3))
            ia, ib = np.nonzero(~clash)
            if ia.size == 0:
                continue
            tuples = np.concatenate([ai[ia], B[ib]], axis=1)  # (r, k+1)
            # trim t-padding: length = index of first t
            is_t = tuples == t
            lens = np.argmax(is_t, axis=1).astype(np.int32)
            rows = tuples.copy()
            col = np.arange(k + 1)[None, :]
            rows[col > lens[:, None]] = PAD
            if constraint is not None:
                keep = constraint.check_full(idx, rows, lens)
                rows, lens = rows[keep], lens[keep]
            count += rows.shape[0]
            stats.results += rows.shape[0]
            if max_results is not None and count > max_results:
                raise EngineLimit(f"more than {max_results} results")
            if not count_only:
                out_paths.append(rows)
                out_lens.append(lens)
            if first_n is not None and count >= first_n:
                count = _trim_to_first_n(out_paths, out_lens, count,
                                         first_n, count_only, stats)
                return _finalize(idx, out_paths, out_lens, count, stats,
                                 exhausted=False)

    return _finalize(idx, out_paths, out_lens, count, stats, exhausted=True,
                     canonical=True)


# ---------------------------------------------------------------------------
# ranked join (DESIGN.md §10)
# ---------------------------------------------------------------------------

def _half_costs(idx: LightweightIndex, rows: np.ndarray,
                spec: "rank.RankSpec") -> np.ndarray:
    """Per-row cost of a (possibly t-padded) join half: edges up to the
    first t occurrence (or the full width when t is absent), hop-counted
    or weight-accumulated left to right like every other backend."""
    t = idx.t
    is_t = rows == t
    has = is_t.any(axis=1)
    hops = np.where(has, np.argmax(is_t, axis=1),
                    rows.shape[1] - 1).astype(np.int64)
    if not spec.is_weight:
        return hops
    keys, vals = rank.index_edge_table(idx, spec.weights)
    n = np.int64(idx.n)
    costs = np.zeros(rows.shape[0], dtype=np.float64)
    for j in range(rows.shape[1] - 1):
        act = hops > j
        if not act.any():
            break
        q = rows[act, j].astype(np.int64) * n + rows[act, j + 1]
        costs[act] = costs[act] + vals[np.searchsorted(keys, q)]
    return costs


def _join_ranked(idx: LightweightIndex, cut: int, spec: "rank.RankSpec",
                 count_only: bool, first_n: Optional[int],
                 max_partials: Optional[int], max_results: Optional[int],
                 deadline: Optional[float]) -> EnumResult:
    """Ranked Algorithm 6: identical halves and per-group join, ordered
    group scheduling (DESIGN.md §10).

    Each realized cut key gets a lower bound ``lb = min cost_a(key) +
    min cost_b(key)`` on its cheapest joinable result; groups run in
    ascending ``(lb, key)`` order.  After any group, every accumulated
    result whose canonical cost lies strictly below the *next* group's
    bound (minus ``rank.weight_slack`` for floats) can no longer be
    preceded, so deadline expiry and early ``first_n`` emit exactly
    those, canonically sorted — a rank-optimal prefix.  A full run sorts
    everything, matching the DFS backends bit-for-bit.
    """
    k, s, t = idx.k, idx.s, idx.t
    stats = JoinStats()

    def _expired() -> bool:
        return deadline is not None and clock.expired(deadline)

    if _expired():
        return _finalize(idx, [], [], 0, stats, exhausted=False)

    ra = _expand_to_width(idx, np.array([s], np.int32), 0, cut + 1, stats,
                          max_partials)
    stats.ra_size = ra.shape[0]
    if ra.shape[0] == 0:
        return _finalize(idx, [], [], 0, stats, exhausted=True)
    if _expired():
        return _finalize(idx, [], [], 0, stats, exhausted=False)

    keys = np.unique(ra[:, cut])
    rb = _expand_to_width(idx, keys.astype(np.int32), cut, k - cut + 1, stats,
                          max_partials)
    stats.rb_size = rb.shape[0]
    if rb.shape[0] == 0:
        return _finalize(idx, [], [], 0, stats, exhausted=True)

    order_a = np.argsort(ra[:, cut], kind="stable")
    order_b = np.argsort(rb[:, 0], kind="stable")
    ra_s, rb_s = ra[order_a], rb[order_b]
    ka, kb = ra_s[:, cut], rb_s[:, 0]
    a_start = np.searchsorted(ka, keys, side="left")
    a_end = np.searchsorted(ka, keys, side="right")
    b_start = np.searchsorted(kb, keys, side="left")
    b_end = np.searchsorted(kb, keys, side="right")

    cost_a = _half_costs(idx, ra_s, spec)
    cost_b = _half_costs(idx, rb_s, spec)
    lb = np.full(keys.shape[0], np.inf, dtype=np.float64)
    for ki in range(keys.shape[0]):
        if b_end[ki] > b_start[ki]:
            lb[ki] = cost_a[a_start[ki]:a_end[ki]].min() \
                + cost_b[b_start[ki]:b_end[ki]].min()
    group_order = np.lexsort((keys, lb))

    acc_rows: List[np.ndarray] = []
    acc_lens: List[np.ndarray] = []
    acc_costs: List[np.ndarray] = []
    total = 0

    def _emit(threshold: float, exhausted: bool) -> EnumResult:
        """Emit the accumulated results safely below ``threshold`` (the
        min bound of unprocessed groups; inf once none remain), sorted
        into canonical order and first_n-trimmed."""
        if total == 0:
            return _finalize(idx, [], [], 0, stats, exhausted=exhausted)
        costs = np.concatenate(acc_costs)
        if np.isfinite(threshold):
            eff = threshold - rank.weight_slack(threshold) \
                if spec.is_weight else threshold
            safe = costs < eff
        else:
            safe = np.ones(costs.shape[0], dtype=bool)
        n_emit = int(safe.sum())
        if first_n is not None:
            n_emit = min(n_emit, first_n)
        stats.results = n_emit
        if count_only:
            return _finalize(idx, [], [], n_emit, stats,
                             exhausted=exhausted)
        rows = np.concatenate(acc_rows, axis=0)[safe]
        lens = np.concatenate(acc_lens)[safe]
        perm = rank.canonical_perm(rows, costs[safe])
        rows, lens = rows[perm][:n_emit], lens[perm][:n_emit]
        return _finalize(idx, [rows], [lens], n_emit, stats,
                         exhausted=exhausted)

    A_BLOCK = 256
    for j in range(group_order.shape[0]):
        ki = group_order[j]
        if not np.isfinite(lb[ki]):
            break                       # dead groups sort last
        if _expired():
            return _emit(float(lb[ki]), exhausted=False)
        na, nb = a_end[ki] - a_start[ki], b_end[ki] - b_start[ki]
        stats.pairs += int(na * nb)
        A = ra_s[a_start[ki]:a_end[ki]]
        B = rb_s[b_start[ki]:b_end[ki]]
        bi = B[:, 1:]
        bmask = bi != t
        for a0 in range(0, na, A_BLOCK):
            ai = A[a0:a0 + A_BLOCK, :cut]
            clash = ((ai[:, None, :, None] == bi[None, :, None, :])
                     & (ai != t)[:, None, :, None]
                     & bmask[None, :, None, :]).any(axis=(2, 3))
            ia, ib = np.nonzero(~clash)
            if ia.size == 0:
                continue
            tuples = np.concatenate([ai[ia], B[ib]], axis=1)
            is_t = tuples == t
            lens = np.argmax(is_t, axis=1).astype(np.int32)
            rows = tuples.copy()
            col = np.arange(k + 1)[None, :]
            rows[col > lens[:, None]] = PAD
            total += rows.shape[0]
            if max_results is not None and total > max_results:
                raise EngineLimit(f"more than {max_results} results")
            acc_rows.append(rows)
            acc_lens.append(lens)
            acc_costs.append(np.asarray(
                rank.path_costs(idx, rows, lens, spec), dtype=np.float64))
        nxt = float(lb[group_order[j + 1]]) \
            if j + 1 < group_order.shape[0] else np.inf
        # max(first_n, 1): first_n=0 still needs one result to exist
        # before the cut counts as truncation (matching the DFS drivers,
        # where an empty exhaustive run reports exhausted=True)
        if first_n is not None and total >= max(first_n, 1) \
                and np.isfinite(nxt):
            costs = np.concatenate(acc_costs)
            eff = nxt - rank.weight_slack(nxt) if spec.is_weight else nxt
            if int((costs < eff).sum()) >= first_n:
                return _emit(nxt, exhausted=False)

    exhausted = not (first_n is not None and total >= max(first_n, 1))
    return _emit(np.inf, exhausted=exhausted)
