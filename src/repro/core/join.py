"""IDX-JOIN (Algorithm 6): bushy plan — evaluate Q[0:i*] and Q[i*:k] by
frontier DFS, then join on the cut vertex.

TPU adaptation (DESIGN.md §2): the paper's hash join becomes a sort-merge
join — both relations are sorted by the cut key (numpy lexsort here; bitonic
sort network on device), matched by segment, and the cross products emitted
per key group.  The `(t,t)` virtual self-loop of the relation construction
(§3.1 rule 3) appears explicitly: a partial that reaches t before its target
width is padded with t, so sub-queries cover all path lengths ≤ k in one
evaluation — exactly the trick that lets the paper avoid k separate joins.

The within-half simple-path check runs during expansion; the cross-half
check runs at join time (the paper: "we check whether a result is a valid
path when performing the join operation").
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from .enumerate import (EngineLimit, EnumResult, EnumStats, _finalize,
                        _trim_to_first_n)
from .graph import PAD
from .index import LightweightIndex


@dataclasses.dataclass
class JoinStats(EnumStats):
    ra_size: int = 0
    rb_size: int = 0
    pairs: int = 0


def _expand_to_width(idx: LightweightIndex, start_vertices: np.ndarray,
                     start_pos: int, width: int, stats: EnumStats,
                     max_partials: Optional[int]) -> np.ndarray:
    """All walk tuples of `width` vertices starting at position `start_pos`
    from the given start vertices, with t-padding (Alg. 6 Search procedure).

    Budget at depth L(M): I_t(v, k - start_pos - L(M) - 1) per Alg. 6 L12.
    Within-half dup-check applied (padding-t exempt).
    """
    k, t = idx.k, idx.t
    rows = np.full((start_vertices.shape[0], width), PAD, dtype=np.int32)
    rows[:, 0] = start_vertices
    for d in range(width - 1):
        last = rows[:, d].astype(np.int64)
        finished = rows[:, d] == t
        # finished rows pad with t; unfinished expand via the index
        b = k - start_pos - d - 1
        begin = idx.fwd_begin[last]
        end = idx.fwd_end[last, b] if b >= 0 else begin
        cnt = np.where(finished, 1, (end - begin)).astype(np.int64)
        stats.edges_accessed += int(cnt[~finished].sum())
        total = int(cnt.sum())
        if total == 0:
            return rows[:0, :]
        if max_partials is not None and total > max_partials:
            raise EngineLimit(f"join half exceeded {max_partials} partials")
        parent = np.repeat(np.arange(rows.shape[0], dtype=np.int64), cnt)
        offs = np.zeros(rows.shape[0], dtype=np.int64)
        np.cumsum(cnt[:-1], out=offs[1:])
        rank = np.arange(total, dtype=np.int64) - offs[parent]
        vnew = np.where(
            finished[parent], t,
            idx.fwd_dst[np.minimum(begin[parent] + rank,
                                   idx.fwd_dst.shape[0] - 1)]
            if idx.fwd_dst.size else t).astype(np.int32)
        new_rows = rows[parent].copy()
        new_rows[:, d + 1] = vnew
        # within-half simple-path check (t-padding exempt)
        dup = ((new_rows[:, : d + 1] == vnew[:, None]).any(axis=1)
               & (vnew != t))
        stats.partials_generated += total
        stats.invalid_partials += int(dup.sum())
        rows = new_rows[~dup]
        if rows.shape[0] == 0:
            return rows
    return rows


def enumerate_paths_join(
    idx: LightweightIndex,
    cut: int,
    count_only: bool = False,
    first_n: Optional[int] = None,
    max_partials: Optional[int] = None,
    max_results: Optional[int] = None,
    constraint=None,
    deadline: Optional[float] = None,
) -> EnumResult:
    """Algorithm 6 with cut position ``cut`` (i*).

    ``first_n`` is the paper's response-time mode on the join plan: both
    halves are still evaluated in full (the join needs them), but emission
    stops after exactly ``first_n`` results with ``exhausted=False`` — the
    same truncation contract as enumerate_paths_idx.

    ``deadline`` (absolute ``time.perf_counter()``) is the cooperative
    time analogue, checked at the join's natural chunk boundaries: before
    each half expansion and between cut-key groups.  Past it, the paths
    joined so far return with ``exhausted=False``.
    """
    k, s, t = idx.k, idx.s, idx.t
    if not 0 < cut < k:
        raise ValueError(f"cut must be in (0, k), got {cut}")
    stats = JoinStats()

    def _expired() -> bool:
        return deadline is not None and time.perf_counter() >= deadline

    if _expired():
        return _finalize(idx, [], [], 0, stats, exhausted=False)

    # R_a = Q[0:cut]: tuples of cut+1 vertices starting at s (position 0)
    ra = _expand_to_width(idx, np.array([s], np.int32), 0, cut + 1, stats,
                          max_partials)
    stats.ra_size = ra.shape[0]
    if ra.shape[0] == 0:
        return _finalize(idx, [], [], 0, stats, exhausted=True)
    if _expired():
        return _finalize(idx, [], [], 0, stats, exhausted=False)

    # C = join keys realized in R_a (Alg. 6 L3)
    keys = np.unique(ra[:, cut])
    # R_b = Q[cut:k]: tuples of k-cut+1 vertices starting at position cut
    rb = _expand_to_width(idx, keys.astype(np.int32), cut, k - cut + 1, stats,
                          max_partials)
    stats.rb_size = rb.shape[0]
    if rb.shape[0] == 0:
        return _finalize(idx, [], [], 0, stats, exhausted=True)

    # ---- sort-merge join on the cut vertex ----
    order_a = np.argsort(ra[:, cut], kind="stable")
    order_b = np.argsort(rb[:, 0], kind="stable")
    ra_s, rb_s = ra[order_a], rb[order_b]
    ka, kb = ra_s[:, cut], rb_s[:, 0]

    out_paths: List[np.ndarray] = []
    out_lens: List[np.ndarray] = []
    count = 0
    # segment boundaries per key
    a_start = np.searchsorted(ka, keys, side="left")
    a_end = np.searchsorted(ka, keys, side="right")
    b_start = np.searchsorted(kb, keys, side="left")
    b_end = np.searchsorted(kb, keys, side="right")

    A_BLOCK = 256  # bound the (na_blk, nb, cut, k-cut) clash tensor
    for ki in range(keys.shape[0]):
        if _expired():
            return _finalize(idx, out_paths, out_lens, count, stats,
                             exhausted=False)
        na, nb = a_end[ki] - a_start[ki], b_end[ki] - b_start[ki]
        if na == 0 or nb == 0:
            continue
        stats.pairs += int(na * nb)
        A = ra_s[a_start[ki]:a_end[ki]]             # (na, cut+1)
        B = rb_s[b_start[ki]:b_end[ki]]             # (nb, k-cut+1)
        bi = B[:, 1:]                                # positions cut+1..k
        bmask = bi != t
        for a0 in range(0, na, A_BLOCK):
            ai = A[a0:a0 + A_BLOCK, :cut]            # positions 0..cut-1
            # cross-half simple-path check: a non-t vertex of the prefix
            # interior must not reappear in the suffix interior.
            clash = ((ai[:, None, :, None] == bi[None, :, None, :])
                     & (ai != t)[:, None, :, None]
                     & bmask[None, :, None, :]).any(axis=(2, 3))
            ia, ib = np.nonzero(~clash)
            if ia.size == 0:
                continue
            tuples = np.concatenate([ai[ia], B[ib]], axis=1)  # (r, k+1)
            # trim t-padding: length = index of first t
            is_t = tuples == t
            lens = np.argmax(is_t, axis=1).astype(np.int32)
            rows = tuples.copy()
            col = np.arange(k + 1)[None, :]
            rows[col > lens[:, None]] = PAD
            if constraint is not None:
                keep = constraint.check_full(idx, rows, lens)
                rows, lens = rows[keep], lens[keep]
            count += rows.shape[0]
            stats.results += rows.shape[0]
            if max_results is not None and count > max_results:
                raise EngineLimit(f"more than {max_results} results")
            if not count_only:
                out_paths.append(rows)
                out_lens.append(lens)
            if first_n is not None and count >= first_n:
                count = _trim_to_first_n(out_paths, out_lens, count,
                                         first_n, count_only, stats)
                return _finalize(idx, out_paths, out_lens, count, stats,
                                 exhausted=False)

    return _finalize(idx, out_paths, out_lens, count, stats, exhausted=True)
