"""PathEnum core — the paper's contribution (index, estimators, optimizer,
enumerators) as a composable JAX/numpy engine.  See DESIGN.md §1-2."""

from .graph import Graph, from_edges, erdos_renyi, power_law, layered_dag, grid, complete
from .index import (DeviceIndexArrays, LightweightIndex, build_index,
                    build_index_jax)
from .estimator import preliminary_estimate, walk_count_dp, WalkCountDP
from .planner import Plan, plan_query, DEFAULT_TAU
from .enumerate import (EnumResult, EnumStats, EngineLimit,
                        enumerate_paths_idx, resolve_backend)
from .join import enumerate_paths_join
from .pathenum import PathEnum, QueryOutput, QueryTiming
from .batch import (BatchItem, BatchOutput, BatchPathEnum, BatchTiming,
                    CacheStats, DEFAULT_GRAPH_ID, IndexCache,
                    batched_index_distances, edge_mask_hash, tenant_of)
from .baseline import generic_dfs
from .rank import RankSpec, make_rank_spec
from . import clock, oracle, constraints, rank, relations

__all__ = [
    "Graph", "from_edges", "erdos_renyi", "power_law", "layered_dag", "grid",
    "complete", "LightweightIndex", "build_index", "build_index_jax",
    "preliminary_estimate", "walk_count_dp", "WalkCountDP", "Plan",
    "plan_query", "DEFAULT_TAU", "EnumResult", "EnumStats", "EngineLimit",
    "enumerate_paths_idx", "enumerate_paths_join", "PathEnum", "QueryOutput",
    "QueryTiming", "generic_dfs", "oracle", "constraints", "relations",
    "BatchPathEnum", "BatchOutput", "BatchItem", "BatchTiming", "CacheStats",
    "IndexCache", "batched_index_distances", "edge_mask_hash",
    "DEFAULT_GRAPH_ID", "tenant_of", "DeviceIndexArrays", "resolve_backend",
    "RankSpec", "make_rank_spec", "rank",
]
