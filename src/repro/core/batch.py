"""BatchPathEnum — the online-workload engine (DESIGN.md §4).

The paper's headline metrics are measured on *batches* of queries (the
1000-query online sets of §7.1), yet the Figure-2 pipeline is strictly
per-query.  Batch HcPE processing (Yuan et al., arXiv:2312.01424) shows the
serving wins come from cross-query sharing; this module brings three of
those sharing levers to the PathEnum pipeline:

  1. **result dedup** — identical ``(s, t, k)`` queries in a batch run the
     pipeline once; duplicates receive the same ``EnumResult`` object.
  2. **index cache** — ``LightweightIndex`` builds are cached in an LRU
     keyed on ``(s, t, k, edge_mask_hash)`` that persists across batches,
     so recurring queries (the hot s-t pairs of a production workload) skip
     the build entirely.  Cache stats (hits / misses / evictions) are
     first-class so callers can assert on reuse.
  3. **stacked BFS** — the two bounded-BFS distance passes of every
     cache-missing query are stacked into one (Q, n) frontier matrix and
     relaxed together: one ``minimum.reduceat`` over the CSR per hop
     serves all Q queries (the batched analogue of bfs.bfs_edge_relax,
     and the host mirror of the mesh-vmapped BFS in distributed/engine.py).

The planner still runs once per *distinct* query — plans are per-query
decisions (§6) and do not share — and enumeration reuses the per-query
machinery unchanged, so every count is byte-identical to sequential
``PathEnum.count`` (tests/test_batch.py asserts this).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import planner as planner_mod
from .enumerate import EnumResult, enumerate_paths_idx
from .graph import Graph
from .index import LightweightIndex, build_index
from .join import enumerate_paths_join
from .pathenum import PathEnum
from .planner import DEFAULT_TAU, Plan

QueryKey = Tuple[int, int, int, int]  # (s, t, k, edge_mask_hash)


def edge_mask_hash(edge_mask: Optional[np.ndarray]) -> int:
    """Stable 64-bit hash of an edge mask (0 for the unmasked graph)."""
    if edge_mask is None:
        return 0
    packed = np.packbits(np.asarray(edge_mask, dtype=bool))
    return int.from_bytes(hashlib.blake2b(packed.tobytes(),
                                          digest_size=8).digest(), "big")


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def snapshot(self) -> "CacheStats":
        return CacheStats(self.hits, self.misses, self.evictions)

    def delta(self, since: "CacheStats") -> "CacheStats":
        return CacheStats(self.hits - since.hits, self.misses - since.misses,
                          self.evictions - since.evictions)


class IndexCache:
    """LRU over ``LightweightIndex`` keyed on ``(s, t, k, edge_mask_hash)``.

    A hit moves the entry to the MRU slot; inserting past ``capacity``
    evicts the LRU entry.  Indexes are immutable once built, so sharing one
    object across queries (and across batches) is safe.
    """

    def __init__(self, capacity: int = 256):
        if capacity < 0:
            raise ValueError("capacity must be >= 0")
        self.capacity = capacity
        self.stats = CacheStats()
        self._entries: "collections.OrderedDict[QueryKey, LightweightIndex]" \
            = collections.OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: QueryKey) -> Optional[LightweightIndex]:
        idx = self._entries.get(key)
        if idx is None:
            self.stats.misses += 1
            return None
        self._entries.move_to_end(key)
        self.stats.hits += 1
        return idx

    def put(self, key: QueryKey, idx: LightweightIndex) -> None:
        if self.capacity == 0:
            return
        if key in self._entries:
            self._entries.move_to_end(key)
            self._entries[key] = idx
            return
        while len(self._entries) >= self.capacity:
            self._entries.popitem(last=False)
            self.stats.evictions += 1
        self._entries[key] = idx

    def clear(self) -> None:
        """Drop all entries and reset stats — a fresh-cache baseline, so
        post-clear hit/miss/eviction counters describe only the new epoch."""
        self._entries.clear()
        self.stats = CacheStats()


# ---------------------------------------------------------------------------
# Stacked-frontier BFS: all cache-missing queries relax together
# ---------------------------------------------------------------------------

def batched_bounded_bfs(indptr: np.ndarray, indices: np.ndarray, n: int,
                        srcs: np.ndarray, excluded: np.ndarray,
                        kmax: int) -> np.ndarray:
    """(Q, n) bounded distances via stacked edge-parallel relaxation.

    ``indices`` must hold, per CSR segment of ``indptr``, the *predecessor*
    ids of each vertex (the reverse CSR for forward distances, the forward
    CSR for reverse distances).  Semantics match oracle.bfs_dist_np: the
    per-row ``excluded`` vertex contributes no relaxations (no transit) but
    may still receive a distance.  Rows relax simultaneously — one
    ``minimum.reduceat`` per hop covers every query — which is the whole
    point: the per-hop cost is one O(Q·m) segmented min instead of Q queue
    traversals.  Returns distances with sentinel ``kmax + 1``.
    """
    Q = int(len(srcs))
    INF = np.int32(kmax + 1)
    dist = np.full((Q, n), INF, dtype=np.int32)
    if Q == 0:
        return dist
    dist[np.arange(Q), np.asarray(srcs, np.int64)] = 0
    m = int(indices.shape[0])
    if m == 0:
        return dist
    starts = indptr[:-1].astype(np.int64)
    has_pred = (np.diff(indptr) > 0)[None, :]        # (1, n)
    pred = indices.astype(np.int64)                   # (m,) grouped by vertex
    exc = np.asarray(excluded, np.int64)[:, None]     # (Q, 1)
    # pred-free vertices have starts == m, out of reduceat's index range;
    # an INF pad column makes index m valid WITHOUT clamping (clamping to
    # m-1 would truncate the preceding vertex's segment and drop its last
    # predecessor edge from the min)
    pad_col = np.full((Q, 1), INF, dtype=np.int32)
    for _ in range(kmax):
        gathered = dist[:, pred]                      # (Q, m) gather
        np.putmask(gathered, pred[None, :] == exc, INF)
        contrib = np.concatenate([gathered, pad_col], axis=1)  # (Q, m+1)
        seg = np.minimum.reduceat(contrib, starts, axis=1)     # (Q, n)
        seg = np.where(has_pred, seg, INF)
        new = np.minimum(dist, np.minimum(seg, INF - 1) + 1)
        if np.array_equal(new, dist):
            break
        dist = new
    return dist


def batched_index_distances(graph: Graph, queries: Sequence[Tuple[int, int, int]],
                            block: int = 128) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Per-query ``(dist_s, dist_t)`` for a list of ``(s, t, k)`` queries.

    Stacks every query's forward pass into one relaxation (and likewise the
    reverse passes), runs to the batch's max k, then clips each row to its
    own hop budget — values ≤ k equal the bounded queue BFS exactly, values
    beyond collapse onto the same ``k + 1`` sentinel, so the downstream
    index build is byte-identical to the sequential path.  ``block`` bounds
    the (block, m) gather working set.
    """
    out: List[Tuple[np.ndarray, np.ndarray]] = []
    for lo in range(0, len(queries), max(block, 1)):
        chunk = queries[lo:lo + max(block, 1)]
        ss = np.array([q[0] for q in chunk], np.int64)
        tt = np.array([q[1] for q in chunk], np.int64)
        kk = np.array([q[2] for q in chunk], np.int64)
        kmax = int(kk.max())
        # forward: predecessors of v are the reverse-CSR neighbors
        ds = batched_bounded_bfs(graph.rindptr, graph.rindices, graph.n,
                                 ss, tt, kmax)
        # reverse: predecessors (in the reverse graph) are forward neighbors
        dt = batched_bounded_bfs(graph.indptr, graph.indices, graph.n,
                                 tt, ss, kmax)
        for row, k in enumerate(kk):
            k = int(k)
            d_s = np.minimum(ds[row], k + 1).astype(np.int32)
            d_t = np.minimum(dt[row], k + 1).astype(np.int32)
            out.append((d_s, d_t))
    return out


# ---------------------------------------------------------------------------
# Batch results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BatchItem:
    """Per-query outcome inside a batch (duplicates share ``result``)."""
    s: int
    t: int
    k: int
    result: EnumResult
    plan: Plan
    index_cached: bool          # index came from the LRU (no build)
    deduplicated: bool          # enumeration reused an earlier item's result
    latency_seconds: float      # attributable work for THIS query


@dataclasses.dataclass
class BatchTiming:
    distance_seconds: float = 0.0
    index_seconds: float = 0.0
    optimize_seconds: float = 0.0
    enumerate_seconds: float = 0.0
    total_seconds: float = 0.0
    # wall-clock span of the batch in time.perf_counter() coordinates;
    # lets concurrent batches merge as max-of-overlapping rather than a
    # sum (serving/hcpe._merge_outputs).  0.0 = span unknown.
    started_at: float = 0.0
    ended_at: float = 0.0


@dataclasses.dataclass
class BatchOutput:
    items: List[BatchItem]
    timing: BatchTiming
    cache_stats: CacheStats          # delta for this batch
    distinct_queries: int

    @property
    def counts(self) -> np.ndarray:
        return np.array([it.result.count for it in self.items], np.int64)

    @property
    def total_results(self) -> int:
        return int(self.counts.sum())

    def latency_percentiles(self, qs=(50, 90, 99)) -> Dict[str, float]:
        lats = np.array([it.latency_seconds for it in self.items])
        if lats.size == 0:
            return {f"p{q}_ms": 0.0 for q in qs}
        return {f"p{q}_ms": float(np.percentile(lats, q) * 1e3) for q in qs}

    @property
    def throughput_qps(self) -> float:
        return len(self.items) / max(self.timing.total_seconds, 1e-12)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class BatchPathEnum:
    """Batched front-end over the Figure-2 pipeline.

    Accepts ``(s, t, k)`` triples against one graph; shares work across the
    batch (dedup, index LRU, stacked BFS) and across calls (the LRU
    persists on the engine).  ``engine`` parameters mirror PathEnum.
    """

    def __init__(self, tau: float = DEFAULT_TAU, chunk_size: int = 16384,
                 max_partials: Optional[int] = 20_000_000,
                 cache_capacity: int = 256, bfs_block: int = 128):
        self.engine = PathEnum(tau=tau, chunk_size=chunk_size,
                               max_partials=max_partials)
        self.cache = IndexCache(capacity=cache_capacity)
        self.bfs_block = bfs_block

    # -- index acquisition --------------------------------------------------
    def _indexes_for(self, graph: Graph, keys: List[QueryKey],
                     edge_mask: Optional[np.ndarray],
                     precomputed: Optional[Dict[QueryKey, Tuple[np.ndarray,
                                                                np.ndarray]]],
                     timing: BatchTiming) -> Dict[QueryKey, Tuple[LightweightIndex, bool]]:
        """Resolve each distinct key to (index, was_cached).

        Cache misses on the unmasked graph batch their BFS passes through
        the stacked relaxation; masked queries fall back to the per-query
        build (the mask changes the graph under the BFS).
        """
        resolved: Dict[QueryKey, Tuple[LightweightIndex, bool]] = {}
        missing: List[QueryKey] = []
        for key in keys:
            if key in resolved:
                # duplicate occurrence shares the resolved (or in-flight)
                # build — that's a cache hit: no rebuild happens for it
                self.cache.stats.hits += 1
                continue
            idx = self.cache.get(key)
            if idx is not None:
                resolved[key] = (idx, True)
            else:
                resolved[key] = (None, False)  # type: ignore[assignment]
                missing.append(key)

        if not missing:
            return resolved

        dists: Dict[QueryKey, Tuple[np.ndarray, np.ndarray]] = {}
        if precomputed:
            dists.update({k: precomputed[k] for k in missing
                          if k in precomputed})
        unmasked = [k for k in missing if k[3] == 0 and k not in dists]
        if unmasked:
            t0 = time.perf_counter()
            stacked = batched_index_distances(
                graph, [(s, t, k) for (s, t, k, _) in unmasked],
                block=self.bfs_block)
            timing.distance_seconds += time.perf_counter() - t0
            dists.update(dict(zip(unmasked, stacked)))

        for key in missing:
            s, t, k, _ = key
            t0 = time.perf_counter()
            if key in dists:
                d_s, d_t = dists[key]
                idx = build_index(graph, s, t, k,
                                  dist_fn=lambda *_a, _d=(d_s, d_t): _d,
                                  edge_mask=None)
            else:  # masked query — BFS must run on the filtered graph
                idx = build_index(graph, s, t, k, edge_mask=edge_mask)
            timing.index_seconds += time.perf_counter() - t0
            self.cache.put(key, idx)
            resolved[key] = (idx, False)
        return resolved

    # -- enumeration --------------------------------------------------------
    def _enumerate(self, idx: LightweightIndex, plan: Plan, count_only: bool,
                   first_n: Optional[int],
                   deadline: Optional[float]) -> EnumResult:
        if plan.method == "dfs":
            return enumerate_paths_idx(idx, chunk_size=self.engine.chunk_size,
                                       count_only=count_only, first_n=first_n,
                                       deadline=deadline)
        return enumerate_paths_join(idx, cut=plan.cut, count_only=count_only,
                                    first_n=first_n,
                                    max_partials=self.engine.max_partials,
                                    deadline=deadline)

    def run(self, graph: Graph, queries: Sequence[Tuple[int, int, int]],
            count_only: bool = True, first_n: Optional[int] = None,
            mode: str = "auto", edge_mask: Optional[np.ndarray] = None,
            deadline: Optional[float] = None,
            _precomputed_distances: Optional[Dict[QueryKey, Tuple[np.ndarray,
                                                                  np.ndarray]]] = None,
            ) -> BatchOutput:
        """Serve a batch; returns per-query items in input order.

        ``deadline`` (absolute ``time.perf_counter()``) is the batch's
        cooperative stop: enumeration halts at the next chunk boundary
        after it passes, queries not yet enumerated return empty with
        ``exhausted=False``, and everything already emitted is kept.  The
        index/planner phases are not interrupted (they are the cheap,
        bounded part of the pipeline); only chunked enumeration — where
        the unbounded work lives — honors the budget.

        ``_precomputed_distances`` is the distributed hand-off: the mesh BFS
        of distributed/engine.py injects (dist_s, dist_t) per key so the
        host build skips its own distance passes.
        """
        t_batch = time.perf_counter()
        timing = BatchTiming()
        stats_before = self.cache.stats.snapshot()
        for (s, t, k) in queries:
            if k < 2:
                raise ValueError("paper assumes k >= 2")
            if s == t:
                raise ValueError("s and t must be distinct")
        mh = edge_mask_hash(edge_mask)
        keys = [(int(s), int(t), int(k), mh) for (s, t, k) in queries]

        resolved = self._indexes_for(graph, keys, edge_mask,
                                     _precomputed_distances, timing)

        items: List[Optional[BatchItem]] = [None] * len(keys)
        memo: Dict[QueryKey, BatchItem] = {}
        for pos, key in enumerate(keys):
            t0 = time.perf_counter()
            prior = memo.get(key)
            if prior is not None:
                items[pos] = dataclasses.replace(
                    prior, deduplicated=True, index_cached=True,
                    latency_seconds=time.perf_counter() - t0)
                continue
            idx, was_cached = resolved[key]
            if mode == "auto":
                plan = planner_mod.plan_query(idx, tau=self.engine.tau)
            elif mode == "dfs":
                plan = Plan(method="dfs", cut=None, preliminary=-1.0,
                            used_full_estimator=False)
            elif mode == "join":
                dp_plan = planner_mod.plan_query(idx, tau=-1.0)
                cut = dp_plan.cut if dp_plan.cut else max(1, key[2] // 2)
                plan = Plan(method="join", cut=cut, preliminary=-1.0,
                            used_full_estimator=True)
            else:
                raise ValueError(f"unknown mode {mode!r}")
            timing.optimize_seconds += plan.optimize_seconds
            t1 = time.perf_counter()
            res = self._enumerate(idx, plan, count_only, first_n, deadline)
            timing.enumerate_seconds += time.perf_counter() - t1
            item = BatchItem(s=key[0], t=key[1], k=key[2], result=res,
                             plan=plan, index_cached=was_cached,
                             deduplicated=False,
                             latency_seconds=time.perf_counter() - t0)
            memo[key] = item
            items[pos] = item

        timing.started_at = t_batch
        timing.ended_at = time.perf_counter()
        timing.total_seconds = timing.ended_at - t_batch
        return BatchOutput(items=list(items), timing=timing,  # type: ignore[arg-type]
                           cache_stats=self.cache.stats.delta(stats_before),
                           distinct_queries=len(memo))

    def counts(self, graph: Graph, queries: Sequence[Tuple[int, int, int]],
               **kw) -> np.ndarray:
        return self.run(graph, queries, count_only=True, **kw).counts
