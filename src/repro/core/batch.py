"""BatchPathEnum — the online-workload engine (DESIGN.md §4).

The paper's headline metrics are measured on *batches* of queries (the
1000-query online sets of §7.1), yet the Figure-2 pipeline is strictly
per-query.  Batch HcPE processing (Yuan et al., arXiv:2312.01424) shows the
serving wins come from cross-query sharing; this module brings three of
those sharing levers to the PathEnum pipeline:

  1. **result dedup** — identical ``(s, t, k)`` queries in a batch run the
     pipeline once; duplicates receive the same ``EnumResult`` object.
  2. **index cache** — ``LightweightIndex`` builds are cached in an LRU
     keyed on ``(graph_id, s, t, k, edge_mask_hash, graph_version)`` that
     persists across batches, so recurring queries (the hot s-t pairs of a
     production workload) skip the build entirely.  Cache stats (hits /
     misses / evictions) are first-class — globally and per tenant — so
     callers can assert on reuse; per-tenant capacity quotas bound a noisy
     tenant's cache footprint (DESIGN.md §8).  ``graph_version`` is the
     streaming-mutation epoch (DESIGN.md §12): a mutated graph's queries
     key to fresh entries, so a pre-mutation index can never serve them.
  3. **stacked BFS** — the two bounded-BFS distance passes of every
     cache-missing query are stacked into one (Q, n) frontier matrix and
     relaxed together: one ``minimum.reduceat`` over the CSR per hop
     serves all Q queries (the batched analogue of bfs.bfs_edge_relax,
     and the host mirror of the mesh-vmapped BFS in distributed/engine.py).

The planner still runs once per *distinct* query — plans are per-query
decisions (§6) and do not share — and enumeration reuses the per-query
machinery unchanged, so every count is byte-identical to sequential
``PathEnum.count`` (tests/test_batch.py asserts this).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from . import planner as planner_mod
from . import sharing as sharing_mod
from .enumerate import EnumResult, EnumStats, enumerate_paths_idx
from .graph import Graph, from_edges
from .index import LightweightIndex, build_index
from .join import enumerate_paths_join
from .pathenum import PathEnum
from .planner import DEFAULT_TAU, Plan

# The engine's cache key.  ``graph_id`` is the tenant dimension
# (DESIGN.md §8): one engine — and therefore one LRU — serves many tenant
# graphs, and the id keeps their entries (and stats, and eviction
# pressure) apart.  Single-graph callers never see it: every entry point
# defaults to ``DEFAULT_GRAPH_ID``.  ``graph_version`` is the tenant
# graph's streaming-mutation epoch (DESIGN.md §12): mutating a graph bumps
# it, so every post-mutation lookup misses the pre-mutation entries by
# construction — correctness never depends on an eager purge.
# (graph_id, s, t, k, edge_mask_hash, graph_version)
QueryKey = Tuple[str, int, int, int, int, int]

DEFAULT_GRAPH_ID = "default"


def tenant_of(key: Union[QueryKey, Tuple[int, ...]]) -> str:
    """The tenant a cache key belongs to.

    ``QueryKey``s carry their ``graph_id`` first (6-tuples since the
    streaming ``graph_version`` dimension, 5-tuples before it — both
    fold the same way); legacy all-int ``(s, t, k, edge_mask_hash)``
    keys (pre-tenancy callers poking the cache directly) fold onto
    ``DEFAULT_GRAPH_ID`` (DESIGN.md §8's single-graph compatibility
    contract).
    """
    if isinstance(key, tuple) and key and isinstance(key[0], str):
        return key[0]
    return DEFAULT_GRAPH_ID


def edge_mask_hash(edge_mask: Optional[np.ndarray]) -> int:
    """Stable 64-bit hash of an edge mask (0 for the unmasked graph)."""
    if edge_mask is None:
        return 0
    packed = np.packbits(np.asarray(edge_mask, dtype=bool))
    return int.from_bytes(hashlib.blake2b(packed.tobytes(),
                                          digest_size=8).digest(), "big")


@dataclasses.dataclass
class CacheStats:
    """Monotone hit/miss/eviction counters for one cache scope — the whole
    ``IndexCache`` or one tenant's slice of it (DESIGN.md §4, §8)."""
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def lookups(self) -> int:
        """Total lookups: hits + misses (evictions are not lookups)."""
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        """Hits over lookups; 0.0 (not NaN) when nothing was looked up."""
        return self.hits / self.lookups if self.lookups else 0.0

    def snapshot(self) -> "CacheStats":
        """A value copy, for later ``delta`` arithmetic."""
        return CacheStats(self.hits, self.misses, self.evictions)

    def delta(self, since: "CacheStats") -> "CacheStats":
        """Counters accumulated since ``since`` (an earlier snapshot)."""
        return CacheStats(self.hits - since.hits, self.misses - since.misses,
                          self.evictions - since.evictions)


class IndexCache:
    """Tenant-aware LRU over ``LightweightIndex`` keyed on ``QueryKey``
    (``(graph_id, s, t, k, edge_mask_hash, graph_version)``; legacy
    all-int 4-tuple keys fold onto ``DEFAULT_GRAPH_ID`` via
    ``tenant_of``).  DESIGN.md §4, §8 and — for the ``graph_version``
    dimension — §12.

    A hit moves the entry to the MRU slot; inserting past ``capacity``
    evicts the global LRU entry.  On top of the global bound, each tenant
    may carry a *quota* (``set_quota``): inserting past it evicts that
    tenant's own LRU entry first, so a noisy tenant churns its own slice
    of the cache and never squeezes out its neighbors' entries.  Stats are
    kept both globally (``stats``) and per tenant (``stats_for``).
    Indexes are immutable once built, so sharing one object across
    queries, batches and tenants is safe.
    """

    def __init__(self, capacity: int = 256,
                 tenant_quotas: Optional[Dict[str, int]] = None) -> None:
        if capacity < 0:
            raise ValueError("capacity must be >= 0")
        self.capacity = capacity
        self.stats = CacheStats()
        self._entries: "collections.OrderedDict[QueryKey, LightweightIndex]" \
            = collections.OrderedDict()
        self._quotas: Dict[str, int] = {}
        self._tenant_stats: Dict[str, CacheStats] = {}
        # per-tenant LRU-ordered key index (mirrors _entries' recency per
        # tenant) so quota eviction pops a tenant's LRU in O(1) instead
        # of scanning the global OrderedDict
        self._tenant_keys: "Dict[str, collections.OrderedDict]" = {}
        for gid, quota in (tenant_quotas or {}).items():
            self.set_quota(gid, quota)

    def __len__(self) -> int:
        return len(self._entries)

    def tenant_len(self, graph_id: str) -> int:
        """Entries currently held for one tenant."""
        return len(self._tenant_keys.get(graph_id, ()))

    def stats_for(self, graph_id: str) -> CacheStats:
        """This tenant's live hit/miss/eviction counters (zero if never
        seen); the same mutable object is returned across calls, so
        ``snapshot``/``delta`` arithmetic works per tenant too."""
        return self._tenant_stats.setdefault(graph_id, CacheStats())

    def tenant_ids(self) -> Tuple[str, ...]:
        """Every tenant the cache knows about — ids holding live entries
        plus ids with historical stats (a retired tenant's counters
        survive ``drop_tenant`` for post-mortems, DESIGN.md §8).  This is
        the iteration surface of the metrics control plane
        (serving/metrics.py, DESIGN.md §12)."""
        ids = dict.fromkeys(self._tenant_keys)
        ids.update(dict.fromkeys(self._tenant_stats))
        return tuple(ids)

    def quota_for(self, graph_id: str) -> Optional[int]:
        """The tenant's entry quota, or None when only the global
        ``capacity`` bounds it."""
        return self._quotas.get(graph_id)

    def set_quota(self, graph_id: str, quota: Optional[int]) -> None:
        """Bound (or unbound, with None) one tenant's entry count; if the
        tenant already exceeds the new quota its LRU entries are evicted
        immediately."""
        if quota is None:
            self._quotas.pop(graph_id, None)
            return
        if quota < 0:
            raise ValueError("tenant quota must be >= 0")
        self._quotas[graph_id] = quota
        while self.tenant_len(graph_id) > quota:
            self._evict_tenant_lru(graph_id)

    def get(self, key: QueryKey) -> Optional[LightweightIndex]:
        """Look one key up; a hit refreshes its LRU position.  Updates the
        global and the key's tenant counters."""
        tenant = tenant_of(key)
        tstats = self.stats_for(tenant)
        idx = self._entries.get(key)
        if idx is None:
            self.stats.misses += 1
            tstats.misses += 1
            return None
        self._entries.move_to_end(key)
        self._tenant_keys[tenant].move_to_end(key)
        self.stats.hits += 1
        tstats.hits += 1
        return idx

    def put(self, key: QueryKey, idx: LightweightIndex) -> None:
        """Insert (or refresh) one entry, evicting first the owning
        tenant's LRU past its quota, then the global LRU past
        ``capacity``.  A zero quota (or zero capacity) stores nothing."""
        tenant = tenant_of(key)
        quota = self._quotas.get(tenant)
        if self.capacity == 0 or quota == 0:
            return
        if key in self._entries:
            self._entries.move_to_end(key)
            self._tenant_keys[tenant].move_to_end(key)
            self._entries[key] = idx
            return
        if quota is not None:
            while self.tenant_len(tenant) >= quota:
                self._evict_tenant_lru(tenant)
        while len(self._entries) >= self.capacity:
            self._evict(next(iter(self._entries)))
        self._entries[key] = idx
        self._tenant_keys.setdefault(
            tenant, collections.OrderedDict())[key] = None

    def _evict(self, key: QueryKey) -> None:
        tenant = tenant_of(key)
        del self._entries[key]
        tkeys = self._tenant_keys[tenant]
        del tkeys[key]
        if not tkeys:
            del self._tenant_keys[tenant]
        self.stats.evictions += 1
        self.stats_for(tenant).evictions += 1

    def _evict_tenant_lru(self, graph_id: str) -> None:
        self._evict(next(iter(self._tenant_keys[graph_id])))

    def drop_tenant(self, graph_id: str) -> int:
        """Administratively drop every entry (and the quota) of one tenant
        — the cache half of ``GraphRegistry.retire``.  Returns the number
        of entries dropped; unlike quota/capacity pressure this is not
        counted as evictions (it is a retirement, not churn), but the
        tenant's historical stats survive for post-mortems."""
        doomed = self._tenant_keys.pop(graph_id, None) or ()
        for k in doomed:
            del self._entries[k]
        self._quotas.pop(graph_id, None)
        return len(doomed)

    def clear(self) -> None:
        """Drop all entries and reset stats (global and per-tenant) — a
        fresh-cache baseline, so post-clear hit/miss/eviction counters
        describe only the new epoch.  Tenant quotas survive: they are
        configuration, not state."""
        self._entries.clear()
        self._tenant_keys.clear()
        self._tenant_stats.clear()
        self.stats = CacheStats()


# ---------------------------------------------------------------------------
# Stacked-frontier BFS: all cache-missing queries relax together
# ---------------------------------------------------------------------------

def batched_bounded_bfs(indptr: np.ndarray, indices: np.ndarray, n: int,
                        srcs: np.ndarray, excluded: np.ndarray,
                        kmax: int) -> np.ndarray:
    """(Q, n) bounded distances via stacked edge-parallel relaxation.

    ``indices`` must hold, per CSR segment of ``indptr``, the *predecessor*
    ids of each vertex (the reverse CSR for forward distances, the forward
    CSR for reverse distances).  Semantics match oracle.bfs_dist_np: the
    per-row ``excluded`` vertex contributes no relaxations (no transit) but
    may still receive a distance.  Rows relax simultaneously — one
    ``minimum.reduceat`` per hop covers every query — which is the whole
    point: the per-hop cost is one O(Q·m) segmented min instead of Q queue
    traversals.  Returns distances with sentinel ``kmax + 1``.
    """
    Q = int(len(srcs))
    INF = np.int32(kmax + 1)
    dist = np.full((Q, n), INF, dtype=np.int32)
    if Q == 0:
        return dist
    dist[np.arange(Q), np.asarray(srcs, np.int64)] = 0
    m = int(indices.shape[0])
    if m == 0:
        return dist
    starts = indptr[:-1].astype(np.int64)
    has_pred = (np.diff(indptr) > 0)[None, :]        # (1, n)
    pred = indices.astype(np.int64)                   # (m,) grouped by vertex
    exc = np.asarray(excluded, np.int64)[:, None]     # (Q, 1)
    # pred-free vertices have starts == m, out of reduceat's index range;
    # an INF pad column makes index m valid WITHOUT clamping (clamping to
    # m-1 would truncate the preceding vertex's segment and drop its last
    # predecessor edge from the min)
    pad_col = np.full((Q, 1), INF, dtype=np.int32)
    for _ in range(kmax):
        gathered = dist[:, pred]                      # (Q, m) gather
        np.putmask(gathered, pred[None, :] == exc, INF)
        contrib = np.concatenate([gathered, pad_col], axis=1)  # (Q, m+1)
        seg = np.minimum.reduceat(contrib, starts, axis=1)     # (Q, n)
        seg = np.where(has_pred, seg, INF)
        new = np.minimum(dist, np.minimum(seg, INF - 1) + 1)
        if np.array_equal(new, dist):
            break
        dist = new
    return dist


def batched_index_distances(graph: Graph, queries: Sequence[Tuple[int, int, int]],
                            block: int = 128) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Per-query ``(dist_s, dist_t)`` for a list of ``(s, t, k)`` queries.

    Stacks every query's forward pass into one relaxation (and likewise the
    reverse passes), runs to the batch's max k, then clips each row to its
    own hop budget — values ≤ k equal the bounded queue BFS exactly, values
    beyond collapse onto the same ``k + 1`` sentinel, so the downstream
    index build is byte-identical to the sequential path.  ``block`` bounds
    the (block, m) gather working set.
    """
    out: List[Tuple[np.ndarray, np.ndarray]] = []
    for lo in range(0, len(queries), max(block, 1)):
        chunk = queries[lo:lo + max(block, 1)]
        ss = np.array([q[0] for q in chunk], np.int64)
        tt = np.array([q[1] for q in chunk], np.int64)
        kk = np.array([q[2] for q in chunk], np.int64)
        kmax = int(kk.max())
        # forward: predecessors of v are the reverse-CSR neighbors
        ds = batched_bounded_bfs(graph.rindptr, graph.rindices, graph.n,
                                 ss, tt, kmax)
        # reverse: predecessors (in the reverse graph) are forward neighbors
        dt = batched_bounded_bfs(graph.indptr, graph.indices, graph.n,
                                 tt, ss, kmax)
        for row, k in enumerate(kk):
            k = int(k)
            d_s = np.minimum(ds[row], k + 1).astype(np.int32)
            d_t = np.minimum(dt[row], k + 1).astype(np.int32)
            out.append((d_s, d_t))
    return out


# ---------------------------------------------------------------------------
# Batch results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BatchItem:
    """Per-query outcome inside a batch (duplicates share ``result``)."""
    s: int
    t: int
    k: int
    result: EnumResult
    plan: Plan
    index_cached: bool          # index came from the LRU (no build)
    deduplicated: bool          # enumeration reused an earlier item's result
    latency_seconds: float      # attributable work for THIS query
    shared: bool = False        # enumerated via a shared group walk (§13)
    fused: bool = False         # enumerated via a fused device launch (§9)


@dataclasses.dataclass
class BatchTiming:
    """Per-phase attributable seconds for one batch (DESIGN.md §4);
    component times are CPU work and merge as sums, the wall-clock span
    merges as interval union (serving/hcpe._merge_outputs)."""
    distance_seconds: float = 0.0
    index_seconds: float = 0.0
    optimize_seconds: float = 0.0
    enumerate_seconds: float = 0.0
    total_seconds: float = 0.0
    # wall-clock span of the batch in time.perf_counter() coordinates;
    # lets concurrent batches merge as max-of-overlapping rather than a
    # sum (serving/hcpe._merge_outputs).  0.0 = span unknown.
    started_at: float = 0.0
    ended_at: float = 0.0


@dataclasses.dataclass
class BatchOutput:
    """One ``BatchPathEnum.run``'s results: per-query items (input order),
    phase timing, the cache-stats delta observed during the run, and the
    tenant (``graph_id``) the batch ran against (DESIGN.md §4, §8)."""
    items: List[BatchItem]
    timing: BatchTiming
    cache_stats: CacheStats          # delta for this batch
    distinct_queries: int
    graph_id: str = DEFAULT_GRAPH_ID  # the tenant this batch served
    sharing_groups: int = 0          # shared walks executed (DESIGN.md §13)
    shared_queries: int = 0          # distinct queries served off a walk
    fused_queries: int = 0           # distinct queries in the fused launch
    fused_dispatches: int = 0        # kernel dispatches the fusion issued

    @property
    def counts(self) -> np.ndarray:
        """Per-query result counts, input order."""
        return np.array([it.result.count for it in self.items], np.int64)

    @property
    def enum_stats(self) -> EnumStats:
        """Merged Fig.-6 enumeration counters (edges accessed, partials,
        invalid partials, results, chunks) across the batch's *distinct*
        results — deduplicated items share their twin's ``EnumResult``
        object and are counted once, so the merge reflects work done,
        not work served."""
        agg = EnumStats()
        seen = set()
        for it in self.items:
            if id(it.result) in seen:
                continue
            seen.add(id(it.result))
            agg.merge(it.result.stats)
        return agg

    @property
    def total_results(self) -> int:
        """Sum of all per-query counts."""
        return int(self.counts.sum())

    def latency_percentiles(self, qs: Sequence[int] = (50, 90, 99)
                            ) -> Dict[str, float]:
        """Attributable per-query latency percentiles in milliseconds."""
        lats = np.array([it.latency_seconds for it in self.items])
        if lats.size == 0:
            return {f"p{q}_ms": 0.0 for q in qs}
        return {f"p{q}_ms": float(np.percentile(lats, q) * 1e3) for q in qs}

    @property
    def throughput_qps(self) -> float:
        """Queries served per wall-clock second of this batch."""
        return len(self.items) / max(self.timing.total_seconds, 1e-12)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class BatchPathEnum:
    """Batched front-end over the Figure-2 pipeline (DESIGN.md §4, §8).

    Accepts ``(s, t, k)`` triples against one graph per call; shares work
    across the batch (dedup, index LRU, stacked BFS) and across calls (the
    LRU persists on the engine).  The engine itself is graph-agnostic:
    each ``run`` names its tenant via ``graph_id`` and the cache keeps the
    tenants' entries apart, so one engine (one LRU, one set of knobs)
    serves a whole ``GraphRegistry``.  ``engine`` parameters mirror
    PathEnum, including the ``backend`` knob steering IDX-DFS expansion
    onto the host or the Pallas device kernel (DESIGN.md §9).
    """

    def __init__(self, tau: float = DEFAULT_TAU, chunk_size: int = 16384,
                 max_partials: Optional[int] = 20_000_000,
                 cache_capacity: int = 256, bfs_block: int = 128,
                 tenant_quotas: Optional[Dict[str, int]] = None,
                 backend: str = "host", sharing: str = "auto",
                 fused: str = "auto") -> None:
        if sharing not in ("auto", "off"):
            raise ValueError(f"unknown sharing mode {sharing!r}")
        if fused not in ("auto", "off"):
            raise ValueError(f"unknown fused mode {fused!r}")
        self.engine = PathEnum(tau=tau, chunk_size=chunk_size,
                               max_partials=max_partials, backend=backend)
        self.cache = IndexCache(capacity=cache_capacity,
                                tenant_quotas=tenant_quotas)
        self.bfs_block = bfs_block
        # cross-query sharing knob (DESIGN.md §13): "auto" groups and
        # shares where profitable, "off" pins the exact solo pipeline;
        # either way results are byte-identical (tests/test_sharing.py).
        self.sharing = sharing
        # fused-launch knob (DESIGN.md §9): "auto" packs the batch's
        # device-eligible dfs-plan queries into fused multi-query kernel
        # launches (one dispatch per expansion round for the whole
        # micro-batch), "off" pins the solo per-query dispatch stream;
        # results are byte-identical either way
        # (tests/test_fused_launch.py).
        self.fused = fused
        self.group_cache = sharing_mod.GroupIndexCache(capacity=64)

    # -- index acquisition --------------------------------------------------
    def _indexes_for(self, graph: Graph, keys: List[QueryKey],
                     edge_mask: Optional[np.ndarray],
                     precomputed: Optional[Dict[QueryKey, Tuple[np.ndarray,
                                                                np.ndarray]]],
                     timing: BatchTiming,
                     group_builds: bool = False
                     ) -> Dict[QueryKey, Tuple[LightweightIndex, bool]]:
        """Resolve each distinct key to (index, was_cached).

        Cache misses on the unmasked graph batch their BFS passes through
        the stacked relaxation; masked queries fall back to the per-query
        build (the mask changes the graph under the BFS).

        With ``group_builds`` (sharing enabled, DESIGN.md §13) two more
        construction levers engage, both byte-identical to the solo
        build: masked batches filter the graph *once* (so every masked
        miss builds — and batch-BFSes — on one shared filtered graph
        instead of re-filtering per key), and misses sharing an s or t
        build through ``sharing.build_member_indexes``'s common edge
        arena.
        """
        resolved: Dict[QueryKey, Tuple[LightweightIndex, bool]] = {}
        missing: List[QueryKey] = []
        for key in keys:
            if key in resolved:
                # duplicate occurrence shares the resolved (or in-flight)
                # build — that's a cache hit: no rebuild happens for it.
                # The tenant counter moves with the global one, or
                # per-tenant stats drift from the global delta
                # (BatchServeReport.tenant_cache under-reports)
                self.cache.stats.hits += 1
                self.cache.stats_for(tenant_of(key)).hits += 1
                continue
            idx = self.cache.get(key)
            if idx is not None:
                resolved[key] = (idx, True)
            else:
                resolved[key] = (None, False)  # type: ignore[assignment]
                missing.append(key)

        if not missing:
            return resolved

        dists: Dict[QueryKey, Tuple[np.ndarray, np.ndarray]] = {}
        if precomputed:
            dists.update({k: precomputed[k] for k in missing
                          if k in precomputed})
        unmasked = [k for k in missing if k[4] == 0 and k not in dists]
        if unmasked:
            t0 = time.perf_counter()
            dists.update(self._stacked_dists(graph, unmasked, group_builds))
            timing.distance_seconds += time.perf_counter() - t0

        build_graph = graph
        eff_mask = edge_mask
        if group_builds and edge_mask is not None and len(missing) > 1:
            # one filtered graph serves every masked miss; building on it
            # (mask dropped) is byte-identical to the per-key masked
            # build, which constructs exactly this graph internally
            t0 = time.perf_counter()
            keep = np.asarray(edge_mask, dtype=bool)
            edges = np.stack([graph.esrc[keep], graph.edst[keep]], axis=1)
            build_graph = from_edges(graph.n, edges, dedup=False)
            eff_mask = None
            masked_missing = [kk for kk in missing if kk not in dists]
            if masked_missing:
                dists.update(self._stacked_dists(build_graph, masked_missing,
                                                 group_builds))
            timing.distance_seconds += time.perf_counter() - t0

        built: Dict[QueryKey, LightweightIndex] = {}
        if group_builds:
            groupable = [kk for kk in missing if kk in dists]
            for grp in sharing_mod.detect_groups(groupable):
                t0 = time.perf_counter()
                idxs = sharing_mod.build_member_indexes(
                    build_graph,
                    [(kk[1], kk[2], kk[3]) for kk in grp.keys],
                    [dists[kk] for kk in grp.keys])
                timing.index_seconds += time.perf_counter() - t0
                built.update(zip(grp.keys, idxs))

        for key in missing:
            _, s, t, k, _mh, _gv = key
            t0 = time.perf_counter()
            if key in built:
                idx = built[key]
            elif key in dists:
                # the mask still threads through: build_index must filter
                # the edge set even when the distances are precomputed,
                # or masked-out edges leak into the index (the distances
                # themselves are the caller's contract — computed on the
                # same filtered graph)
                d_s, d_t = dists[key]
                idx = build_index(build_graph, s, t, k,
                                  dist_fn=lambda *_a, _d=(d_s, d_t): _d,
                                  edge_mask=eff_mask)
            else:  # masked query — BFS must run on the filtered graph
                idx = build_index(build_graph, s, t, k, edge_mask=eff_mask)
            timing.index_seconds += time.perf_counter() - t0
            self.cache.put(key, idx)
            resolved[key] = (idx, False)
        return resolved

    def _stacked_dists(self, graph: Graph, keys: List[QueryKey],
                       dedup_pairs: bool
                       ) -> Dict[QueryKey, Tuple[np.ndarray, np.ndarray]]:
        """Stacked BFS for a list of distinct keys.

        With ``dedup_pairs`` (sharing enabled, DESIGN.md §13) the BFS runs
        one row per distinct ``(s, t)`` *pair* at the pair's max hop
        budget, then clips each key's copy to its own ``k + 1`` sentinel.
        That is byte-identical to the per-key rows — the stacked
        relaxation already runs every row to the block's max k and clips,
        so values ≤ k match the bounded queue BFS exactly and everything
        beyond collapses onto the same sentinel — but it collapses the
        hot Zipfian case exact-key dedup cannot touch: the same pair
        queried under many hop budgets pays for one BFS pair, not one
        per budget.
        """
        if not dedup_pairs:
            stacked = batched_index_distances(
                graph, [(s, t, k) for (_, s, t, k, _, _) in keys],
                block=self.bfs_block)
            return dict(zip(keys, stacked))
        pair_k: Dict[Tuple[int, int], int] = {}
        for (_, s, t, k, _mh, _gv) in keys:
            pair_k[(s, t)] = max(pair_k.get((s, t), 0), k)
        pairs = list(pair_k)
        stacked = batched_index_distances(
            graph, [(s, t, pair_k[(s, t)]) for (s, t) in pairs],
            block=self.bfs_block)
        by_pair = dict(zip(pairs, stacked))
        out: Dict[QueryKey, Tuple[np.ndarray, np.ndarray]] = {}
        for key in keys:
            _, s, t, k, _mh, _gv = key
            d_s, d_t = by_pair[(s, t)]
            out[key] = (np.minimum(d_s, k + 1).astype(np.int32),
                        np.minimum(d_t, k + 1).astype(np.int32))
        return out

    # -- planning -----------------------------------------------------------
    def _plan_for(self, idx: LightweightIndex, k: int, mode: str) -> Plan:
        """One distinct query's plan under the batch ``mode`` knob.  The
        engine backend steers where the full DP runs (join.hop_count_dp,
        DESIGN.md §9); the plan itself is backend-independent."""
        if mode == "auto":
            return planner_mod.plan_query(idx, tau=self.engine.tau,
                                          backend=self.engine.backend)
        if mode == "dfs":
            return Plan(method="dfs", cut=None, preliminary=-1.0,
                        used_full_estimator=False)
        if mode == "join":
            dp_plan = planner_mod.plan_query(idx, tau=-1.0,
                                             backend=self.engine.backend)
            cut = dp_plan.cut if dp_plan.cut else max(1, k // 2)
            return Plan(method="join", cut=cut, preliminary=-1.0,
                        used_full_estimator=True)
        raise ValueError(f"unknown mode {mode!r}")

    # -- enumeration --------------------------------------------------------
    def _enumerate(self, idx: LightweightIndex, plan: Plan, count_only: bool,
                   first_n: Optional[int], deadline: Optional[float],
                   order: Optional[str] = None,
                   weights: Optional[np.ndarray] = None) -> EnumResult:
        if plan.method == "dfs":
            return enumerate_paths_idx(idx, chunk_size=self.engine.chunk_size,
                                       count_only=count_only, first_n=first_n,
                                       deadline=deadline,
                                       backend=self.engine.backend,
                                       order=order, weights=weights)
        return enumerate_paths_join(idx, cut=plan.cut, count_only=count_only,
                                    first_n=first_n,
                                    max_partials=self.engine.max_partials,
                                    deadline=deadline,
                                    order=order, weights=weights)

    def run(self, graph: Graph, queries: Sequence[Tuple[int, int, int]],
            count_only: bool = True, first_n: Optional[int] = None,
            mode: str = "auto", edge_mask: Optional[np.ndarray] = None,
            deadline: Optional[float] = None,
            graph_id: str = DEFAULT_GRAPH_ID,
            order: Optional[str] = None,
            weights: Optional[np.ndarray] = None,
            sharing: Optional[str] = None,
            _precomputed_distances: Optional[Dict[QueryKey, Tuple[np.ndarray,
                                                                  np.ndarray]]] = None,
            ) -> BatchOutput:
        """Serve a batch; returns per-query items in input order.

        ``sharing`` overrides the engine's cross-query sharing knob for
        this run (DESIGN.md §13): ``"auto"`` detects overlap groups
        (shared s/t under this run's graph/mask/version), builds merged
        group indexes and walks shared prefixes once; ``"off"`` pins the
        per-query pipeline.  Results are byte-identical either way —
        sharing only changes *where* the work happens, and unprofitable
        or unsafe groups (ranked batches, over-budget walks) fall back
        to the solo path automatically.  ``REPRO_SHARING=off`` in the
        environment force-disables it regardless of this argument.

        ``order`` requests ranked (any-k) enumeration for the whole batch
        (DESIGN.md §10): each query's paths come back in non-decreasing
        hop/weight rank with the lexicographic tie-break, ``first_n``
        means the per-query top-n, and a ``deadline`` truncation is a
        rank-optimal prefix per query.  ``weights`` (graph edge order,
        non-negative) feeds ``order="weight"``.

        ``graph_id`` names the tenant ``graph`` belongs to (DESIGN.md §8):
        it prefixes every cache key this run touches, so two tenants'
        identical ``(s, t, k)`` queries never share an index entry.  All
        queries of one ``run`` are against one graph — multi-tenant
        callers group by ``graph_id`` first (serving/hcpe.group_requests)
        and run one batch per group.  The default id keeps single-graph
        callers on the exact pre-tenancy behavior.

        ``deadline`` (absolute ``core.clock.now()``) is the batch's
        cooperative stop: enumeration halts at the next chunk boundary
        after it passes, queries not yet enumerated return empty with
        ``exhausted=False``, and everything already emitted is kept.  The
        index/planner phases are not interrupted (they are the cheap,
        bounded part of the pipeline); only chunked enumeration — where
        the unbounded work lives — honors the budget.

        ``_precomputed_distances`` is the distributed hand-off: the mesh BFS
        of distributed/engine.py injects (dist_s, dist_t) per key so the
        host build skips its own distance passes.  Keys are full
        ``QueryKey`` tuples — including ``edge_mask_hash`` and
        ``graph.version`` — and for masked keys the distances must have
        been computed on the same filtered graph (the mask still filters
        the index build; only the BFS is skipped).
        """
        t_batch = time.perf_counter()
        timing = BatchTiming()
        stats_before = self.cache.stats.snapshot()
        for (s, t, k) in queries:
            if k < 2:
                raise ValueError("paper assumes k >= 2")
            if s == t:
                raise ValueError("s and t must be distinct")
        mh = edge_mask_hash(edge_mask)
        gv = int(graph.version)
        keys = [(graph_id, int(s), int(t), int(k), mh, gv)
                for (s, t, k) in queries]
        eff_sharing: str = sharing_mod.resolve_sharing(
            self.sharing if sharing is None else sharing)

        resolved = self._indexes_for(graph, keys, edge_mask,
                                     _precomputed_distances, timing,
                                     group_builds=eff_sharing == "auto")

        # sharing phase (DESIGN.md §13): plan the distinct keys up front,
        # then serve whole overlap groups off one shared prefix walk.
        # Ranked batches opt out — their drivers emit in rank order, which
        # a shared walk does not reproduce — and keep Level-A (construction)
        # sharing only.
        shared_results: Dict[QueryKey, EnumResult] = {}
        shared_latency: Dict[QueryKey, float] = {}
        plans_pre: Dict[QueryKey, Plan] = {}
        plan_wall: Dict[QueryKey, float] = {}
        n_groups = 0
        if eff_sharing == "auto" and order is None:
            for key in keys:
                if key in plans_pre:
                    continue
                t0 = time.perf_counter()
                plan = self._plan_for(resolved[key][0], key[3], mode)
                plan_wall[key] = time.perf_counter() - t0
                timing.optimize_seconds += plan.optimize_seconds
                plans_pre[key] = plan
            if len(plans_pre) > 1:
                t1 = time.perf_counter()
                shared_results, shared_latency, n_groups = \
                    sharing_mod.run_shared_groups(
                        self, resolved, plans_pre, count_only=count_only,
                        first_n=first_n, deadline=deadline,
                        graph_id=graph_id)
                timing.enumerate_seconds += time.perf_counter() - t1

        # fused device phase (DESIGN.md §9): the remaining dfs-plan
        # queries that resolve to the device backend enumerate together
        # through fused multi-query launches — one kernel dispatch per
        # expansion round for the whole micro-batch instead of one
        # dispatch stream per query.  Shared-walk results, join plans,
        # ranked batches and host-resolved queries keep the solo path.
        fused_results: Dict[QueryKey, EnumResult] = {}
        fused_latency: Dict[QueryKey, float] = {}
        fused_dispatches = 0
        if (order is None and self.fused != "off"
                and self.engine.backend in ("device", "auto")):
            from ..kernels import ops as kops   # lazy: pallas path only
            from . import fused as fused_mod
            from .enumerate import resolve_backend
            for key in keys:
                if key in plans_pre:
                    continue
                t0 = time.perf_counter()
                plan = self._plan_for(resolved[key][0], key[3], mode)
                plan_wall[key] = time.perf_counter() - t0
                timing.optimize_seconds += plan.optimize_seconds
                plans_pre[key] = plan
            elig = [kk for kk in dict.fromkeys(keys)
                    if kk not in shared_results
                    and plans_pre[kk].method == "dfs"
                    and resolve_backend(resolved[kk][0],
                                        self.engine.backend) == "device"]
            if len(elig) >= 2:
                t1 = time.perf_counter()
                before = kops.device_dispatch_count()
                res_list = fused_mod.enumerate_fused_device(
                    [resolved[kk][0] for kk in elig],
                    chunk_size=self.engine.chunk_size,
                    count_only=count_only, first_n=first_n,
                    deadline=deadline)
                fused_dispatches = kops.device_dispatch_count() - before
                wall = time.perf_counter() - t1
                timing.enumerate_seconds += wall
                fused_results = dict(zip(elig, res_list))
                share = wall / len(elig)
                fused_latency = {kk: share for kk in elig}

        items: List[Optional[BatchItem]] = [None] * len(keys)
        memo: Dict[QueryKey, BatchItem] = {}
        for pos, key in enumerate(keys):
            t0 = time.perf_counter()
            prior = memo.get(key)
            if prior is not None:
                items[pos] = dataclasses.replace(
                    prior, deduplicated=True, index_cached=True,
                    latency_seconds=time.perf_counter() - t0)
                continue
            idx, was_cached = resolved[key]
            plan_opt = plans_pre.get(key)
            if plan_opt is None:
                plan = self._plan_for(idx, key[3], mode)
                timing.optimize_seconds += plan.optimize_seconds
            else:
                plan = plan_opt
            res_opt = shared_results.get(key)
            fused_opt = fused_results.get(key)
            if res_opt is not None:
                res = res_opt
                extra = shared_latency[key] + plan_wall.get(key, 0.0)
            elif fused_opt is not None:
                res = fused_opt
                extra = fused_latency[key] + plan_wall.get(key, 0.0)
            else:
                extra = plan_wall.get(key, 0.0)
                t1 = time.perf_counter()
                res = self._enumerate(idx, plan, count_only, first_n,
                                      deadline, order=order, weights=weights)
                timing.enumerate_seconds += time.perf_counter() - t1
            item = BatchItem(s=key[1], t=key[2], k=key[3], result=res,
                             plan=plan, index_cached=was_cached,
                             deduplicated=False,
                             latency_seconds=(time.perf_counter() - t0
                                              + extra),
                             shared=res_opt is not None,
                             fused=fused_opt is not None)
            memo[key] = item
            items[pos] = item

        timing.started_at = t_batch
        timing.ended_at = time.perf_counter()
        timing.total_seconds = timing.ended_at - t_batch
        return BatchOutput(items=list(items), timing=timing,  # type: ignore[arg-type]
                           cache_stats=self.cache.stats.delta(stats_before),
                           distinct_queries=len(memo), graph_id=graph_id,
                           sharing_groups=n_groups,
                           shared_queries=len(shared_results),
                           fused_queries=len(fused_results),
                           fused_dispatches=fused_dispatches)

    def counts(self, graph: Graph, queries: Sequence[Tuple[int, int, int]],
               **kw) -> np.ndarray:
        """Convenience: ``run(..., count_only=True)`` reduced to the
        per-query count vector."""
        return self.run(graph, queries, count_only=True, **kw).counts
