"""Data pipeline: deterministic sharded token streams + the PathEnum bridge.

Two sources:
  * ``SyntheticLM`` — seeded zipfian token stream (infinite, restartable:
    the stream position is part of the checkpoint manifest, so restarts
    resume mid-epoch without data skew).
  * ``PathCorpus`` — the paper-bridge (DESIGN.md §3): PathEnum result
    batches rendered as token sequences ``[BOS, s, v1, ..., t, EOS]`` for
    KG-completion-style training (motivation example 3 of the paper).

Both emit host numpy batches shaped for `jax.device_put` with the batch
sharding from distributed/sharding.py; per-host sharding takes
(host_index, num_hosts) so each host materializes only its slice — the
multi-host pattern the launcher uses.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from ..core.graph import Graph
from ..core.pathenum import PathEnum


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    num_hosts: int = 1
    zipf_a: float = 1.3

    def __post_init__(self):
        assert self.global_batch % self.num_hosts == 0
        self.local_batch = self.global_batch // self.num_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a global step (restart-safe)."""
        rng = np.random.default_rng(
            (self.seed, step, self.host_index))
        toks = rng.zipf(self.zipf_a, size=(self.local_batch, self.seq_len))
        toks = np.minimum(toks, self.vocab - 1).astype(np.int32)
        return {"tokens": toks, "labels": toks.copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


BOS, EOS, SEP = 0, 1, 2
VERTEX_OFFSET = 3


@dataclasses.dataclass
class PathCorpus:
    """Tokenized hop-constrained paths from the PathEnum engine."""
    graph: Graph
    k: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    num_hosts: int = 1
    max_paths_per_query: int = 4096

    def __post_init__(self):
        assert self.global_batch % self.num_hosts == 0
        self.local_batch = self.global_batch // self.num_hosts
        self.engine = PathEnum()
        self.vocab = self.graph.n + VERTEX_OFFSET

    def _paths_for(self, rng) -> np.ndarray:
        for _ in range(32):
            s, t = rng.integers(0, self.graph.n, size=2)
            if s == t:
                continue
            out = self.engine.query(self.graph, int(s), int(t), self.k,
                                    mode="dfs",
                                    first_n=self.max_paths_per_query)
            if out.result.count > 0:
                return out.result.paths, out.result.lengths
        return (np.zeros((0, self.k + 1), np.int32),
                np.zeros((0,), np.int32))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step, self.host_index))
        rows = np.full((self.local_batch, self.seq_len), -1, np.int32)
        filled = 0
        while filled < self.local_batch:
            paths, lens = self._paths_for(rng)
            if paths.shape[0] == 0:
                rows[filled:, :] = EOS
                break
            take = min(self.local_batch - filled, paths.shape[0])
            for i in range(take):
                seq = [BOS] + [int(v) + VERTEX_OFFSET
                               for v in paths[i, : lens[i] + 1]] + [EOS]
                seq = seq[: self.seq_len]
                rows[filled + i, : len(seq)] = seq
            filled += take
        tokens = np.where(rows >= 0, rows, EOS).astype(np.int32)
        labels = np.where(rows >= 0, rows, -1).astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_frontend_stub(rng: np.random.Generator, batch: int, prefix_len: int,
                       d_model: int) -> np.ndarray:
    """Precomputed frame/patch embeddings for [vlm]/[audio] frontends."""
    return (rng.standard_normal((batch, prefix_len, d_model)) * 0.02
            ).astype(np.float32)
