from .pipeline import PathCorpus, SyntheticLM, make_frontend_stub
