"""Render §Dry-run and §Roofline markdown tables from the sweep records.

  PYTHONPATH=src python -m benchmarks.report > experiments/roofline.md
"""
from __future__ import annotations

import sys

from .roofline import load_records, roofline_terms


def fmt_bytes(b: float) -> str:
    return f"{b/1e9:.2f}"


def main() -> None:
    recs = [r for r in load_records() if "arch" in r]
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))

    print("### §Dry-run — 80 cells (10 archs × 4 shapes × {single 256, "
          "multi 512} chips)\n")
    print("| arch | shape | mesh | status | compile s | HBM GB/dev | "
          "flops/dev | wire GB/dev | kv shard |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("status") == "skipped":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"skipped | — | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"**{r.get('status')}** | — | — | — | — | — |")
            continue
        m = r["memory"]["peak_estimate_bytes"] / 1e9
        fl = r["cost"]["flops_per_device"]
        w = r["collectives_per_device_bytes"].get("wire_bytes", 0) / 1e9
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
              f"{r.get('compile_seconds', 0):.0f}+"
              f"{r.get('analysis_compile_seconds', 0):.0f} | {m:.1f} | "
              f"{fl:.2e} | {w:.1f} | {r.get('kv_shard','-')} |")

    print("\n### §Roofline — three terms per cell (single-pod table)\n")
    print("| arch | shape | t_comp s | t_mem s | t_coll s | bottleneck | "
          "MODEL_FLOPs/HLO | MFU-UB |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("mesh") != "single" or r.get("status") != "ok":
            continue
        rt = roofline_terms(r)
        print(f"| {rt['arch']} | {rt['shape']} | {rt['t_compute_s']:.2e} | "
              f"{rt['t_memory_s']:.2e} | {rt['t_collective_s']:.2e} | "
              f"**{rt['bottleneck']}** | {rt['useful_ratio']:.2f} | "
              f"{rt['mfu_upper_bound']:.3f} |")


if __name__ == "__main__":
    main()
