"""fig_ranked_enum — the any-k payoff: time-to-first / time-to-top-10.

The trajectory row for DESIGN.md §10: ranked enumeration exists so a
caller can get the *best* paths without paying for all of them.  For
each workload this suite times, per order and backend,

  * ``full``  — the complete ranked sequence,
  * ``top10`` — ``first_n=10`` (the top-10, rank-optimal), and
  * ``first`` — ``first_n=1`` (time-to-first-best),

and the derived column carries the total result count so the top-n rows
can be read as "n of N".  The top-n prefixes are asserted to equal the
full sequence's head, so the wall numbers always compare correct work —
a ranked driver that cheated on order would fail here before it could
report a flattering time.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import build_index, enumerate_paths_idx

from .workloads import GRAPHS, high_degree_queries

Row = Tuple[str, float, str]

WORKLOADS = (("dag", 5), ("dense", 4))
BACKENDS = ("host", "device")   # device: hops buckets / weight host fallback


def run() -> List[Row]:
    rows: List[Row] = []
    for gname, k in WORKLOADS:
        g = GRAPHS[gname]()
        s, t = high_degree_queries(g, 1, seed=13)[0]
        idx = build_index(g, s, t, k)
        weights = np.random.default_rng(13).uniform(0.0, 3.0, size=g.m)
        for order in ("hops", "weight"):
            w = weights if order == "weight" else None
            for backend in BACKENDS:
                t0 = time.perf_counter()
                full = enumerate_paths_idx(idx, backend=backend,
                                           order=order, weights=w)
                full_ms = (time.perf_counter() - t0) * 1e3
                seq = full.as_tuples()
                for tag, n in (("top10", 10), ("first", 1)):
                    t0 = time.perf_counter()
                    got = enumerate_paths_idx(idx, backend=backend,
                                              order=order, weights=w,
                                              first_n=n)
                    ms = (time.perf_counter() - t0) * 1e3
                    assert got.as_tuples() == seq[:n], (gname, order, tag)
                    rows.append((f"fig_ranked_enum/{gname}_{order}_"
                                 f"{backend}_{tag}_ms", ms,
                                 f"of={full.count}"))
                rows.append((f"fig_ranked_enum/{gname}_{order}_"
                             f"{backend}_full_ms", full_ms,
                             f"results={full.count}"))
    return rows
