"""Streaming mutation + metrics-plane benchmark (DESIGN.md §12).

    PYTHONPATH=src python -m benchmarks.run --only streaming

Three costs an operator of a streaming deployment budgets for:

  * **mutation** — ``GraphRegistry.mutate`` is a versioned-copy rebuild
    (``with_edges`` = edge-set diff + ``from_edges``), so its cost is a
    full CSR build regardless of delta size; the rows report µs per
    mutate against the cost of the cold ``from_edges`` build it wraps
    (the ratio is the diff overhead, expected near 1).
  * **re-warm** — a mutation purges the tenant's cache slice, so the
    first post-mutation serve pays cold index builds; the rows report
    the warm-serve, post-mutation-serve and re-warmed-serve costs of one
    fixed workload (the middle row is the invalidation price).
  * **observation** — ``snapshot()`` + exports must be cheap enough to
    scrape every few seconds: µs per capture, per ``to_json``, per
    ``to_prometheus`` on a many-tenant server.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import erdos_renyi, from_edges
from repro.serving import GraphRegistry, HcPEServer, PathQueryRequest
from repro.serving.metrics import snapshot

Row = Tuple[str, float, str]


def _time_us(fn, reps: int) -> float:
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def run(delta: int = 64, reps: int = 10) -> List[Row]:
    """One suite run; returns ``(name, value, derived)`` CSV rows."""
    rows: List[Row] = []
    rng = np.random.default_rng(0)

    # -- mutation cost vs the cold build it wraps ---------------------------
    for n, deg in ((2_000, 8.0), (20_000, 8.0)):
        g = erdos_renyi(n, deg, seed=1)
        add = np.stack([rng.integers(0, n, delta),
                        rng.integers(0, n, delta)], axis=1)
        drop = g.edge_list()[rng.choice(g.m, delta, replace=False)]
        mut_us = _time_us(lambda: g.with_edges(add=add, remove=drop), reps)
        edges = g.edge_list()
        build_us = _time_us(lambda: from_edges(n, edges), reps)
        rows.append((f"streaming/mutate_n{n}_us", mut_us,
                     f"delta={delta};rebuild_ratio="
                     f"{mut_us / max(build_us, 1e-9):.2f}"))
        rows.append((f"streaming/cold_build_n{n}_us", build_us, f"m={g.m}"))

    # -- invalidation price: warm vs post-mutation vs re-warmed serve -------
    g = erdos_renyi(3_000, 6.0, seed=2)
    reg = GraphRegistry()
    reg.register("t", g)
    srv = HcPEServer(reg)
    reqs = []
    while len(reqs) < 40:
        s, t = map(int, rng.choice(g.n, 2, replace=False))
        reqs.append(PathQueryRequest(uid=len(reqs), s=s, t=t, k=4,
                                     graph_id="t"))
    srv.serve(reqs)                                       # warm the cache
    warm_us = _time_us(lambda: srv.serve(reqs), 3)
    reg.mutate("t", add=np.array([[0, 1]]))
    t0 = time.perf_counter()
    srv.serve(reqs)                                       # all misses
    cold_us = (time.perf_counter() - t0) * 1e6
    rewarm_us = _time_us(lambda: srv.serve(reqs), 3)
    rows.append(("streaming/warm_serve_us", warm_us, "40 queries"))
    rows.append(("streaming/post_mutation_serve_us", cold_us,
                 f"invalidation_ratio={cold_us / max(warm_us, 1e-9):.1f}"))
    rows.append(("streaming/rewarmed_serve_us", rewarm_us, "40 queries"))

    # -- observation cost on a many-tenant server ---------------------------
    reg2 = GraphRegistry()
    srv2 = HcPEServer(reg2)
    for i in range(16):
        gi = erdos_renyi(300, 4.0, seed=10 + i)
        reg2.register(f"tenant_{i:02d}", gi, cache_quota=8)
        qs = [PathQueryRequest(uid=j, s=j, t=j + 5, k=3,
                               graph_id=f"tenant_{i:02d}") for j in range(6)]
        srv2.serve(qs)
    snap_us = _time_us(lambda: snapshot(srv2), 50)
    snap = snapshot(srv2)
    json_us = _time_us(snap.to_json, 50)
    prom_us = _time_us(snap.to_prometheus, 50)
    rows.append(("streaming/snapshot_us", snap_us, "16 tenants"))
    rows.append(("streaming/snapshot_to_json_us", json_us,
                 f"bytes={len(snap.to_json())}"))
    rows.append(("streaming/snapshot_to_prometheus_us", prom_us,
                 f"lines={len(snap.to_prometheus().splitlines())}"))
    assert snap.violations() == []
    return rows
