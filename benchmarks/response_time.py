"""fig_response_time — p50/p99 time-to-first-n under open-loop arrivals.

The paper's online metric (§7.1) is response time: how fast the first
results reach the client, not how fast the whole batch drains.  This
suite replays one open-loop workload — arrival times drawn up front,
independent of server progress, the standard way to expose queueing
delay — against both HcPE front-ends:

  * sync ``HcPEServer``: a greedy drain loop (serve whatever has arrived,
    block until done); a heavy analytics query stalls everything behind it.
  * async ``AsyncHcPEServer``: deadline-aware micro-batching + EDF, so
    tight-SLO interactive queries jump the heavy one.

Interactive queries use first_n (the first-results contract); the heavy
query enumerates in full.  Reported per class and front-end: p50/p99
completion latency, plus the async SLO hit-rate.
"""
from __future__ import annotations

import asyncio
import time
from typing import List, Tuple

import numpy as np

from repro.core import BatchPathEnum, erdos_renyi
from repro.serving import AsyncHcPEServer, HcPEServer, PathQueryRequest

FIRST_N = 100          # the paper's first-1000, scaled to benchmark size
LIGHT_SLO_MS = 50.0


def _workload(rng, g, n_light=24, n_heavy=2):
    """(arrival_offset_s, request) pairs — arrivals fixed up front."""
    events: List[Tuple[float, PathQueryRequest]] = []
    t = 0.0
    uid = 0
    for i in range(n_light + n_heavy):
        t += float(rng.exponential(0.012))
        heavy = i % (n_light // n_heavy + 1) == (n_light // n_heavy)
        if heavy:
            req = PathQueryRequest(uid=uid, s=0, t=1, k=8,
                                   deadline_ms=60_000.0)
        else:
            s, d = rng.integers(0, g.n, 2)
            while s == d:
                s, d = rng.integers(0, g.n, 2)
            req = PathQueryRequest(uid=uid, s=int(s), t=int(d), k=3,
                                   count_only=False, first_n=FIRST_N,
                                   deadline_ms=LIGHT_SLO_MS)
        events.append((t, req))
        uid += 1
    return events


def _run_sync(g, events):
    """Greedy drain loop: serve every arrived request, block, repeat."""
    server = HcPEServer(g, BatchPathEnum())
    t0 = time.perf_counter()
    done: dict = {}
    i = 0
    while i < len(events):
        now = time.perf_counter() - t0
        batch = []
        while i < len(events) and events[i][0] <= now:
            batch.append(events[i][1])
            i += 1
        if not batch:
            time.sleep(max(events[i][0] - now, 0.0))
            continue
        resps, _ = server.serve(batch)
        end = time.perf_counter() - t0
        for req, resp in zip(batch, resps):
            arrival = next(a for a, r in events if r.uid == req.uid)
            done[req.uid] = (end - arrival, resp)
    return done


async def _run_async(g, events):
    done: dict = {}
    async with AsyncHcPEServer(g, BatchPathEnum(),
                               batch_window_ms=2.0) as server:
        t0 = time.perf_counter()

        async def one(arrival, req):
            await asyncio.sleep(max(arrival - (time.perf_counter() - t0), 0))
            resp = await server.submit(req)
            done[req.uid] = (time.perf_counter() - t0 - arrival, resp)

        await asyncio.gather(*(one(a, r) for a, r in events))
    return done


def _rows(prefix, events, done):
    rows = []
    for cls, pick in (("light", lambda r: r.first_n is not None),
                      ("heavy", lambda r: r.first_n is None)):
        lats = [done[r.uid][0] * 1e3 for _, r in events if pick(r)]
        rows.append((f"fig_response_time/{prefix}/{cls}_p50_ms",
                     float(np.percentile(lats, 50)), f"n={len(lats)}"))
        rows.append((f"fig_response_time/{prefix}/{cls}_p99_ms",
                     float(np.percentile(lats, 99)),
                     f"time-to-first-{FIRST_N}" if cls == "light" else "full"))
    return rows


def run() -> List[Tuple[str, float, str]]:
    g = erdos_renyi(200, 12.0, seed=3)
    rng = np.random.default_rng(42)
    events = _workload(rng, g)

    sync_done = _run_sync(g, events)
    async_done = asyncio.run(_run_async(g, events))

    # both engines, cold caches each: counts must agree before timings mean
    # anything
    mismatch = [u for u in sync_done
                if sync_done[u][1].count != async_done[u][1].count]
    if mismatch:
        raise AssertionError(f"count mismatch sync vs async: {mismatch}")

    rows = _rows("sync", events, sync_done) + _rows("async", events, async_done)
    lights = [r for _, r in events if r.first_n is not None]
    met = sum(1 for r in lights if async_done[r.uid][1].slo_met)
    rows.append(("fig_response_time/async/light_slo_hit_rate",
                 met / len(lights), f"slo={LIGHT_SLO_MS}ms"))
    return rows


if __name__ == "__main__":
    for name, val, derived in run():
        print(f"{name},{val},{derived}")
