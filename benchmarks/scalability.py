"""Fig. 12 analogue — scalability on a large graph.

The paper's tm graph (1.96B edges) doesn't fit this container's budget;
a 20M-edge power-law graph exercises the same regime: index construction
dominated by the two BFS passes, enumeration throughput ≥1e6 results/s.
BFS here runs through the jitted edge-relaxation (core/bfs.py) — the
vectorized path that maps to the Pallas min-plus kernel on TPU.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import erdos_renyi, build_index
from repro.core import bfs as bfs_mod
from repro.core.enumerate import EngineLimit, enumerate_paths_idx
from repro.core.estimator import walk_count_dp

Row = Tuple[str, float, str]


def run(n: int = 200_000, avg_deg: int = 50, k: int = 5,
        nq: int = 3) -> List[Row]:
    rows: List[Row] = []
    t0 = time.time()
    g = erdos_renyi(n, float(avg_deg), seed=5)
    rows.append(("fig12/graph_build_s", time.time() - t0,
                 f"V={g.n};E={g.m}"))

    rng = np.random.default_rng(0)

    bfs_t = idx_t = opt_t = enum_t = 0.0
    results = 0
    for qi in range(nq):
        s = int(rng.integers(0, n))
        # pick a target within 3 hops so the query has results (§7.1 rule)
        ds = np.asarray(bfs_mod.bfs_edge_relax(
            __import__("jax.numpy", fromlist=["x"]).asarray(g.esrc),
            __import__("jax.numpy", fromlist=["x"]).asarray(g.edst),
            g.n, 3, s, -1))
        cand = np.nonzero((ds >= 2) & (ds <= 3))[0]
        if cand.size == 0:
            continue
        t = int(cand[rng.integers(0, cand.size)])
        t0 = time.time()
        bfs_mod.index_distances(g, int(s), int(t), k)
        bfs_t += time.time() - t0
        t0 = time.time()
        idx = build_index(g, int(s), int(t), k,
                          dist_fn=bfs_mod.index_distances)
        idx_t += time.time() - t0
        t0 = time.time()
        walk_count_dp(idx)
        opt_t += time.time() - t0
        t0 = time.time()
        try:
            r = enumerate_paths_idx(idx, count_only=True, first_n=2_000_000)
            results += r.count
        except EngineLimit:
            pass
        enum_t += time.time() - t0
    rows.append(("fig12/bfs_s_per_query", bfs_t / nq, ""))
    rows.append(("fig12/index_s_per_query", idx_t / nq, "includes BFS"))
    rows.append(("fig12/optimize_s_per_query", opt_t / nq, ""))
    rows.append(("fig12/throughput_results_per_s",
                 results / max(enum_t, 1e-9), f"results={results}"))
    return rows
