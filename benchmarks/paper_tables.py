"""One benchmark per paper table/figure (§7 of the paper).

Reported metrics follow the paper; the machine-neutral counters (#edges
accessed, #invalid partials, #results — Fig. 6) are the faithful
reproduction axis, wall-clock is indicative (the paper compares C++
implementations; here the baseline is recursive Python while the engine is
vectorized numpy — same algorithmic story, different constants; both
directions of the comparison are printed).

Each function returns a list of (name, value, derived) rows for run.py.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import (BatchPathEnum, PathEnum, build_index,
                        enumerate_paths_idx, enumerate_paths_join, oracle,
                        plan_query, preliminary_estimate, walk_count_dp)
from repro.core.baseline import generic_dfs
from repro.core.enumerate import EngineLimit

from .workloads import GRAPHS, high_degree_queries

Row = Tuple[str, float, str]
CAP = 2_000_000  # result cap per query keeps the harness bounded


def _run_queries(g, queries, k, mode, engine) -> Dict[str, float]:
    times, results, first1k = [], 0, []
    for (s, t) in queries:
        t0 = time.perf_counter()
        try:
            out = engine.query(g, s, t, k, mode=mode, count_only=True)
            results += out.result.count
        except EngineLimit:
            pass
        times.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        engine.query(g, s, t, k, mode="dfs", first_n=1000, count_only=False)
        first1k.append(time.perf_counter() - t0)
    total = sum(times)
    return {"query_ms": 1e3 * total / len(queries),
            "throughput": results / max(total, 1e-9),
            "response_ms": 1e3 * float(np.mean(first1k))}


def table3_overall(k: int = 5, nq: int = 8) -> List[Row]:
    """Table 3 analogue: query time / throughput / response per graph."""
    rows: List[Row] = []
    eng = PathEnum(max_partials=CAP)
    for gname, build in GRAPHS.items():
        g = build()
        queries = high_degree_queries(g, nq, seed=7)
        if not queries:
            continue
        # BC-DFS stand-in (Alg. 1 + static barrier), capped for sanity
        t0 = time.perf_counter()
        base_results = 0
        for (s, t) in queries:
            r = generic_dfs(g, s, t, k, count_only=True, max_steps=CAP)
            base_results += r.count
        base_time = time.perf_counter() - t0
        rows.append((f"table3/{gname}/BCDFS_query_ms",
                     1e3 * base_time / len(queries), f"results={base_results}"))
        for mode in ("dfs", "join", "auto"):
            m = _run_queries(g, queries, k, mode, eng)
            tag = {"dfs": "IDXDFS", "join": "IDXJOIN", "auto": "PathEnum"}[mode]
            rows.append((f"table3/{gname}/{tag}_query_ms", m["query_ms"],
                         f"thr={m['throughput']:.3e};resp_ms={m['response_ms']:.2f}"))
    return rows


def fig6_detailed_metrics(ks=(4, 5, 6)) -> List[Row]:
    """Fig. 6: #edges accessed / #invalid partials, index vs baseline."""
    rows: List[Row] = []
    g = GRAPHS["pl_hub"]()
    queries = high_degree_queries(g, 5, seed=11)
    eng = PathEnum()
    for k in ks:
        be = bi = ie = ii = res = 0
        for (s, t) in queries:
            b = generic_dfs(g, s, t, k, count_only=True, max_steps=CAP)
            out = eng.query(g, s, t, k, mode="dfs", count_only=True)
            be += b.stats.edges_accessed
            bi += b.stats.invalid_partials
            ie += out.result.stats.edges_accessed
            ii += out.result.stats.invalid_partials
            res += out.result.count
        ratio = be / max(ie, 1)
        rows.append((f"fig6/k{k}/edge_access_ratio", ratio,
                     f"baseline={be};index={ie};results={res}"))
        rows.append((f"fig6/k{k}/invalid_partials", ii,
                     f"baseline_invalid={bi}"))
    return rows


def fig7_breakdown(ks=(3, 4, 5)) -> List[Row]:
    """Fig. 7/17: index vs optimization vs enumeration time."""
    rows: List[Row] = []
    g = GRAPHS["pl_hub"]()
    queries = high_degree_queries(g, 5, seed=13)
    eng = PathEnum(tau=10)
    for k in ks:
        tid = top = ten = 0.0
        for (s, t) in queries:
            out = eng.query(g, s, t, k, count_only=True)
            tid += out.timing.index_seconds
            top += out.timing.optimize_seconds
            ten += out.timing.enumerate_seconds
        n = len(queries)
        rows.append((f"fig7/k{k}/index_ms", 1e3 * tid / n, ""))
        rows.append((f"fig7/k{k}/optimize_ms", 1e3 * top / n, ""))
        rows.append((f"fig7/k{k}/enumerate_ms", 1e3 * ten / n, ""))
    return rows


def table6_result_counts(ks=(3, 4, 5)) -> List[Row]:
    """Table 6: avg/max number of results with k varied."""
    rows: List[Row] = []
    for gname in ("pl_hub", "dense"):
        g = GRAPHS[gname]()
        queries = high_degree_queries(g, 5, seed=17)
        eng = PathEnum(max_partials=CAP)
        for k in ks:
            counts = []
            for (s, t) in queries:
                try:
                    counts.append(eng.query(g, s, t, k, mode="dfs",
                                            count_only=True).result.count)
                except EngineLimit:
                    counts.append(CAP)
            rows.append((f"table6/{gname}/k{k}/avg", float(np.mean(counts)),
                         f"max={max(counts)}"))
    return rows


def fig18_estimator_accuracy(ks=(3, 4, 5)) -> List[Row]:
    """Fig. 18: full-fledged estimate (δ_W) vs actual results (δ_P)."""
    rows: List[Row] = []
    g = GRAPHS["uniform"]()
    queries = high_degree_queries(g, 5, seed=19)
    for k in ks:
        ratios, prelim_ratios = [], []
        for (s, t) in queries:
            idx = build_index(g, s, t, k)
            dp = walk_count_dp(idx)
            actual = enumerate_paths_idx(idx, count_only=True).count
            if actual:
                ratios.append(dp.q_total / actual)
                prelim_ratios.append(
                    max(preliminary_estimate(idx), 1e-9) / actual)
        if ratios:
            rows.append((f"fig18/k{k}/full_est_over_actual",
                         float(np.mean(ratios)),
                         f"prelim_ratio={np.mean(prelim_ratios):.3f}"))
    return rows


def table7_memory(ks=(3, 4, 5)) -> List[Row]:
    """Table 7: index memory vs join partial-result memory."""
    rows: List[Row] = []
    g = GRAPHS["pl_hub"]()
    queries = high_degree_queries(g, 3, seed=23)
    for k in ks:
        idx_mb, partials_mb = [], []
        for (s, t) in queries:
            idx = build_index(g, s, t, k)
            idx_mb.append(idx.memory_bytes() / 1e6)
            dp = walk_count_dp(idx)
            cut = min(max(dp.cut, 1), k - 1)
            try:
                r = enumerate_paths_join(idx, cut=cut, count_only=True,
                                         max_partials=CAP)
                partials_mb.append(
                    (r.stats.ra_size + r.stats.rb_size) * (k + 1) * 4 / 1e6)
            except EngineLimit:
                partials_mb.append(float("nan"))
        rows.append((f"table7/k{k}/index_MB", float(np.mean(idx_mb)),
                     f"join_partials_MB={np.nanmean(partials_mb):.3f}"))
    return rows


def fig9_spectrum(k: int = 5) -> List[Row]:
    """Fig. 9: enumeration time of every plan vs the optimizer's choice."""
    rows: List[Row] = []
    for gname in ("dense", "uniform"):
        g = GRAPHS[gname]()
        queries = high_degree_queries(g, 2, seed=29)
        if not queries:
            continue
        s, t = queries[0]
        idx = build_index(g, s, t, k)
        t0 = time.perf_counter()
        enumerate_paths_idx(idx, count_only=True)
        dfs_time = time.perf_counter() - t0
        plan_times = {"dfs": dfs_time}
        for cut in range(1, k):
            t0 = time.perf_counter()
            try:
                enumerate_paths_join(idx, cut=cut, count_only=True,
                                     max_partials=CAP)
                plan_times[f"cut{cut}"] = time.perf_counter() - t0
            except EngineLimit:
                plan_times[f"cut{cut}"] = float("inf")
        plan = plan_query(idx, tau=10)
        chosen = "dfs" if plan.method == "dfs" else f"cut{plan.cut}"
        best = min(plan_times, key=plan_times.get)
        rows.append((f"fig9/{gname}/chosen_ms",
                     1e3 * plan_times[chosen],
                     f"chosen={chosen};best={best};"
                     f"best_ms={1e3*plan_times[best]:.2f}"))
    return rows


def fig12_batch_throughput(k: int = 4, distinct: int = 12,
                           batch: int = 40) -> List[Row]:
    """Batch serving (arXiv:2312.01424 axis): BatchPathEnum vs sequential.

    Workload shape follows a production query log: ``batch`` queries drawn
    with replacement from ``distinct`` hot (s, t) pairs (≥30% duplicates by
    construction), the paper's §7.1 endpoint distribution.  Rows report
    per-query time for sequential PathEnum vs one batched call (cold cache)
    vs a repeat batch (warm cache), the speedup, and the cache hit rate.
    Counts are asserted identical — the batch engine must not change
    results, only amortize work.
    """
    rows: List[Row] = []
    rng = np.random.default_rng(42)
    for gname in ("pl_hub", "uniform", "dense"):
        g = GRAPHS[gname]()
        pool = high_degree_queries(g, distinct, seed=31)
        if not pool:
            continue
        picks = rng.integers(0, len(pool), size=batch)
        queries = [(pool[i][0], pool[i][1], k) for i in picks]

        seq = PathEnum(max_partials=CAP)
        t0 = time.perf_counter()
        seq_counts = []
        for (s, t, kk) in queries:
            try:
                seq_counts.append(seq.count(g, s, t, kk))
            except EngineLimit:
                seq_counts.append(-1)
        seq_s = time.perf_counter() - t0

        eng = BatchPathEnum(max_partials=CAP)
        try:
            t0 = time.perf_counter()
            out_cold = eng.run(g, queries)
            cold_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            out_warm = eng.run(g, queries)
            warm_s = time.perf_counter() - t0
        except EngineLimit:
            # a capped query aborts the whole batch run; record and move on
            rows.append((f"fig12b/{gname}/capped", -1.0, f"cap={CAP}"))
            continue

        if -1 not in seq_counts:  # -1 marks seq queries that hit the cap
            assert out_cold.counts.tolist() == seq_counts, \
                f"batch/sequential count mismatch on {gname}"
        assert out_cold.cache_stats.hits > 0, "expected dup-driven hits"

        pct = out_cold.latency_percentiles((50, 99))
        rows.append((f"fig12b/{gname}/seq_ms_per_query",
                     1e3 * seq_s / batch, f"results={sum(seq_counts)}"))
        rows.append((f"fig12b/{gname}/batch_ms_per_query",
                     1e3 * cold_s / batch,
                     f"speedup={seq_s / max(cold_s, 1e-12):.2f}x;"
                     f"hit_rate={out_cold.cache_stats.hit_rate:.2f};"
                     f"p50_ms={pct['p50_ms']:.3f};p99_ms={pct['p99_ms']:.3f}"))
        rows.append((f"fig12b/{gname}/warm_ms_per_query",
                     1e3 * warm_s / batch,
                     f"speedup={seq_s / max(warm_s, 1e-12):.2f}x;"
                     f"hit_rate={out_warm.cache_stats.hit_rate:.2f}"))
        rows.append((f"fig12b/{gname}/throughput_qps",
                     out_cold.throughput_qps,
                     f"distinct={out_cold.distinct_queries}/{batch}"))
    return rows


ALL = [table3_overall, fig6_detailed_metrics, fig7_breakdown,
       table6_result_counts, fig18_estimator_accuracy, table7_memory,
       fig9_spectrum, fig12_batch_throughput]
