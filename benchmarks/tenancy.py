"""Multi-graph tenancy benchmark (DESIGN.md §8): isolation cost + fairness.

    PYTHONPATH=src python -m benchmarks.run --only tenancy

Two axes:

  * **isolation overhead** — the same mixed workload served (a) as two
    single-tenant servers, one per graph, and (b) as one registry-backed
    ``HcPEServer`` with interleaved per-tenant requests.  The tenant
    dimension only re-keys the cache and regroups the batch, so the
    per-query cost of (b) must track (a); the row reports the ratio.
  * **quota fairness** — a hot tenant with a tight ``cache_quota``
    churning through many distinct (s, t) pairs must not evict a quiet
    tenant's warm entries: the quiet tenant's second pass is asserted
    100% hits, and the row reports both tenants' hit rates.

Counts are asserted byte-identical between (a) and (b) — tenancy must
never change results, only who pays for which cache entry.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import BatchPathEnum, power_law
from repro.serving import GraphRegistry, HcPEServer, PathQueryRequest

Row = Tuple[str, float, str]


def _hot_requests(g, graph_id, count, distinct, k, seed, uid0=0):
    rng = np.random.default_rng(seed)
    deg = np.diff(g.indptr)
    hubs = np.argsort(deg)[-max(2 * distinct, 8):]
    pool = []
    while len(pool) < distinct:
        s, t = rng.choice(hubs, 2, replace=False)
        if (int(s), int(t)) not in pool:
            pool.append((int(s), int(t)))
    picks = rng.integers(0, len(pool), size=count)
    return [PathQueryRequest(uid=uid0 + i, s=pool[j][0], t=pool[j][1], k=k,
                             graph_id=graph_id)
            for i, j in enumerate(picks)]


def run(k: int = 4, per_tenant: int = 30, distinct: int = 8) -> List[Row]:
    """One suite run; returns ``(name, value, derived)`` CSV rows."""
    rows: List[Row] = []
    g_a = power_law(1500, 6.0, seed=5)
    g_b = power_law(1500, 5.0, seed=23)

    reqs_a = _hot_requests(g_a, "tenant_a", per_tenant, distinct, k, seed=1)
    reqs_b = _hot_requests(g_b, "tenant_b", per_tenant, distinct, k, seed=2,
                           uid0=per_tenant)

    # (a) two single-tenant servers, each its own engine
    t0 = time.perf_counter()
    solo_a, _ = HcPEServer(g_a).serve(
        [PathQueryRequest(uid=r.uid, s=r.s, t=r.t, k=r.k) for r in reqs_a])
    solo_b, _ = HcPEServer(g_b).serve(
        [PathQueryRequest(uid=r.uid, s=r.s, t=r.t, k=r.k) for r in reqs_b])
    solo_s = time.perf_counter() - t0

    # (b) one registry-backed server, requests interleaved per tenant
    registry = GraphRegistry()
    registry.register("tenant_a", g_a)
    registry.register("tenant_b", g_b)
    server = HcPEServer(registry)
    interleaved = [r for pair in zip(reqs_a, reqs_b) for r in pair]
    t0 = time.perf_counter()
    multi, report = server.serve(interleaved)
    multi_s = time.perf_counter() - t0

    solo_counts = {r.uid: r.count for r in solo_a + solo_b}
    multi_counts = {r.uid: r.count for r in multi}
    assert multi_counts == solo_counts, "tenancy changed results"

    n = len(interleaved)
    rows.append(("tenancy/solo_ms_per_query", 1e3 * solo_s / n,
                 f"tenants=2;per_tenant={per_tenant}"))
    rows.append(("tenancy/multi_ms_per_query", 1e3 * multi_s / n,
                 f"overhead={multi_s / max(solo_s, 1e-12):.2f}x;"
                 f"hit_rate={report.cache.hit_rate:.2f}"))

    # quota fairness: quiet tenant's warm entries survive a churning hot
    # tenant bounded by a tight cache quota
    registry2 = GraphRegistry()
    registry2.register("quiet", g_a)
    registry2.register("hot", g_b, cache_quota=4)
    srv = HcPEServer(registry2, BatchPathEnum(cache_capacity=64))
    quiet = _hot_requests(g_a, "quiet", 20, 10, k, seed=3)
    srv.serve(quiet)                              # warm the quiet tenant
    churn = _hot_requests(g_b, "hot", 60, 40, k, seed=4, uid0=100)
    _, churn_rep = srv.serve(churn)               # hot tenant churns
    _, warm_rep = srv.serve(quiet)                # quiet tenant returns
    quiet_stats = warm_rep.tenant_cache["quiet"]
    assert quiet_stats.misses == 0, "hot tenant evicted quiet tenant"
    rows.append(("tenancy/quiet_warm_hit_rate", quiet_stats.hit_rate,
                 f"hot_evictions={churn_rep.tenant_cache['hot'].evictions};"
                 f"hot_cache_len={srv.engine.cache.tenant_len('hot')}"))
    return rows
