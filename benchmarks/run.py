"""Benchmark harness — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only substr]

Prints ``name,us_per_call,derived`` CSV rows (times already in the unit
named by each row's suffix: *_ms rows are milliseconds, *_bytes raw).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--skip-collectives", action="store_true")
    args = ap.parse_args()

    from . import kernels_bench, paper_tables, roofline

    suites = []
    for fn in paper_tables.ALL:
        suites.append((fn.__name__, fn))
    from . import scalability
    suites.append(("fig12_scalability", scalability.run))
    from . import response_time
    suites.append(("fig_response_time", response_time.run))
    from . import tenancy
    suites.append(("tenancy", tenancy.run))
    from . import device_enum
    suites.append(("fig_device_enum", device_enum.run))
    from . import ranked_enum
    suites.append(("fig_ranked_enum", ranked_enum.run))
    from . import streaming
    suites.append(("streaming", streaming.run))
    from . import sharing
    suites.append(("fig_sharing", sharing.run))
    suites.append(("kernels", kernels_bench.run))
    suites.append(("roofline", roofline.run))
    if not args.skip_collectives:
        from . import collectives
        suites.append(("collectives", collectives.run))

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,-1,{e!r}")
            failures += 1
            continue
        for rname, val, derived in rows:
            print(f"{rname},{val},{derived}")
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
