"""Benchmark workloads.

The paper's 15 real graphs aren't redistributable inside this container, so
each benchmark runs on seeded synthetic stand-ins chosen to span the same
regimes (Table 2: social/web hubs, dense biological graphs, sparse
citation): power-law hub graphs (ep/sl-like), uniform sparse (up/gg-like),
dense small (ye-like), layered DAGs (walk==path regime of Example 5.2).
Query generation follows §7.1: s, t sampled from the top-10%-degree set
(V'), distance(s, t) ≤ 3 so results exist, k = 6 default.
"""
from __future__ import annotations

import numpy as np

from repro.core import erdos_renyi, layered_dag, power_law
from repro.core.graph import Graph
from repro.core.oracle import bfs_dist_np

GRAPHS = {
    # name: (builder, kwargs) — sizes keep CPU wall time sane
    "pl_hub": lambda: power_law(3000, 8.0, seed=1),      # ep/sl-like
    "uniform": lambda: erdos_renyi(4000, 4.0, seed=2),   # gg/up-like
    "dense": lambda: erdos_renyi(600, 40.0, seed=3),     # ye-like
    "dag": lambda: layered_dag(5, 40, 10.0, seed=4),     # Example 5.2 G0
}


def high_degree_queries(g: Graph, count: int, seed: int = 0,
                        max_dist: int = 3):
    """§7.1 query sets: endpoints from V' (top 10% by degree), dist ≤ 3."""
    deg = np.diff(g.indptr)
    cutoff = np.quantile(deg, 0.9)
    vprime = np.nonzero(deg >= max(cutoff, 1))[0]
    rng = np.random.default_rng(seed)
    out = []
    tries = 0
    while len(out) < count and tries < count * 200:
        tries += 1
        s, t = rng.choice(vprime, size=2)
        if s == t:
            continue
        d = bfs_dist_np(g, int(s), max_dist, excluded=int(t))
        if d[int(t)] <= max_dist:
            out.append((int(s), int(t)))
    return out
