"""§Roofline: three-term roofline per (arch × shape × mesh) from the
dry-run JSON records (experiments/dryrun/*.json).

  compute term    = HLO_FLOPs  / (chips × peak)   = flops_per_device / peak
  memory term     = HLO_bytes  / (chips × HBM bw) = bytes_per_device / bw
  collective term = wire bytes per device / ICI bw (ring model; the raw
                    operand-sum convention from the assignment is also
                    recorded as `coll_s_operand`)

MODEL_FLOPS uses the kind-appropriate analytic count:
  train:   6 · N_active · tokens      (fwd 2 + bwd 4)
  prefill: 2 · N_active · tokens
  decode:  2 · N_active · batch  (+ attention cache term, reported via
           HLO ratio — dominated by the cache-bound memory term anyway)
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional, Tuple

from repro.launch.mesh import HARDWARE


def model_flops(rec: Dict) -> float:
    n_active = rec.get("active_params_B", 0.0) * 1e9
    shape = rec["shape"]
    toks = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
            "decode_32k": 128, "long_500k": 1}[shape]
    mult = 6.0 if shape == "train_4k" else 2.0
    return mult * n_active * toks


def roofline_terms(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = rec["chips"]
    peak = HARDWARE["peak_flops_bf16"]
    hbm = HARDWARE["hbm_bandwidth"]
    ici = HARDWARE["ici_bandwidth"]
    fl = rec["cost"]["flops_per_device"]
    by = rec["cost"]["bytes_accessed_per_device"]
    coll = rec["collectives_per_device_bytes"]
    t_comp = fl / peak
    t_mem = by / hbm
    t_coll = coll.get("wire_bytes", 0.0) / ici
    dom = max((t_comp, "compute"), (t_mem, "memory"),
              (t_coll, "collective"))[1]
    mf = model_flops(rec)
    hlo_total = fl * chips
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "coll_s_operand": coll.get("total_operand", 0.0) / (ici),
        "bottleneck": dom,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "roofline_bound_s": max(t_comp, t_mem, t_coll),
        "compute_fraction": t_comp / max(t_comp, t_mem, t_coll, 1e-30),
        "hbm_gb_per_device": rec["memory"]["peak_estimate_bytes"] / 1e9,
        "mfu_upper_bound": mf / (max(t_comp, t_mem, t_coll, 1e-30)
                                 * chips * peak),
    }


def load_records(dirpath: str = "experiments/dryrun",
                 variant: str = "baseline") -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if variant is not None and r.get("variant", "baseline") != variant:
            continue
        recs.append(r)
    return recs


def run(dirpath: str = "experiments/dryrun",
        csv_out: str = "experiments/roofline.csv") -> List[Tuple[str, float, str]]:
    rows = []
    table = []
    for rec in load_records(dirpath):
        if "arch" not in rec:
            continue
        rt = roofline_terms(rec)
        cell = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rt is None:
            status = rec.get("status")
            if status == "skipped":
                rows.append((f"roofline/{cell}", 0.0,
                             rec.get("reason", "skipped")))
            continue
        table.append(rt)
        rows.append((
            f"roofline/{cell}", rt["roofline_bound_s"],
            f"bound={rt['bottleneck']};comp={rt['t_compute_s']:.3e}s;"
            f"mem={rt['t_memory_s']:.3e}s;coll={rt['t_collective_s']:.3e}s;"
            f"useful={rt['useful_ratio']:.2f};"
            f"mfu_ub={rt['mfu_upper_bound']:.3f};"
            f"hbm={rt['hbm_gb_per_device']:.1f}GB"))
    if csv_out and table:
        os.makedirs(os.path.dirname(csv_out), exist_ok=True)
        keys = list(table[0].keys())
        with open(csv_out, "w") as f:
            f.write(",".join(keys) + "\n")
            for rt in table:
                f.write(",".join(str(rt[k]) for k in keys) + "\n")
    return rows
