"""Gradient-compression collective benchmark (beyond-paper §Perf item).

Lowers the same data-parallel train gradient twice on an 8-device host
mesh — plain psum vs int8-compressed psum — and parses the collective
bytes out of both compiled modules.  The byte ratio is mesh-size-invariant
(payload / 4 with f32 grads), which is what transfers to the 256-chip pod.

Runs in a subprocess so the 8-device XLA flag doesn't leak into the
benchmark process.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from typing import List, Tuple

Row = Tuple[str, float, str]

_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, json, sys
sys.path.insert(0, "src")
from repro.configs.base import ArchConfig
from repro.models import init_params
from repro.training.step import make_loss_fn
from repro.distributed.compression import make_compressed_grad_fn
from repro.launch.dryrun import collective_bytes
from repro.compat import make_mesh, shard_map
from jax.sharding import PartitionSpec as P

cfg = ArchConfig(name="b", family="dense", num_layers=2, d_model=256,
                 num_heads=4, kv_heads=2, d_ff=512, vocab=1024, head_dim=64,
                 attn_chunk=64, tie_embeddings=True)
params = init_params(cfg, jax.random.PRNGKey(0))
loss_fn = make_loss_fn(cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (16, 64), 0, 1024)
batch = {"tokens": toks, "labels": toks}
mesh = make_mesh((8,), ("data",))

def plain(params, batch):
    def local(p, b):
        (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
        l = jax.lax.pmean(l, "data")
        g = jax.tree.map(lambda x: jax.lax.pmean(x, "data"), g)
        return l, g
    return shard_map(local, mesh=mesh, in_specs=(P(), P("data")),
                     out_specs=(P(), P()))(params, batch)

comp = make_compressed_grad_fn(loss_fn, mesh)
c_plain = jax.jit(plain).lower(params, batch).compile()
c_comp = comp.lower(params, batch).compile()
b_plain = collective_bytes(c_plain.as_text())
b_comp = collective_bytes(c_comp.as_text())
print(json.dumps({"plain": b_plain, "comp": b_comp}))
"""


def run() -> List[Row]:
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_BODY)],
        capture_output=True, text=True, timeout=540,
        env={**os.environ, "PYTHONPATH": "src"})
    if out.returncode != 0:
        return [("collectives/error", -1.0, out.stderr[-200:])]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    plain_b = rec["plain"]["wire_bytes"]
    comp_b = rec["comp"]["wire_bytes"]
    return [
        ("collectives/plain_psum_wire_bytes", plain_b, ""),
        ("collectives/int8_psum_wire_bytes", comp_b,
         f"reduction={plain_b / max(comp_b, 1):.2f}x"),
    ]
