"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute through the interpreter
(numerics only, not speed), so wall numbers here time the *jnp reference*
path — the structural costs (FLOPs, bytes) per call are derived
analytically and printed alongside.  On TPU the same entry points compile
to Mosaic; the derived column is what the roofline predicts per call.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_index
from repro.core.enumerate import EnumStats, _expand_chunk
from repro.core.graph import PAD
from repro.kernels import ops, ref

Row = Tuple[str, float, str]


def _time(fn, *args, repeat=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(repeat):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeat * 1e6


def _frontier_workload(gname: str, k: int):
    """A representative chunk of one workload graph: build the index for
    a §7.1-style high-degree query, walk the frontier down on the host,
    and hand back the *widest* chunk seen — the shape the device kernel
    spends its time on.  Returns (idx, chunk, depth)."""
    from .workloads import GRAPHS, high_degree_queries
    g = GRAPHS[gname]()
    # widest corridor among a few §7.1 queries: kernel throughput is only
    # meaningful on the chunk shapes the workload actually produces
    idx = max((build_index(g, s, t, k)
               for s, t in high_degree_queries(g, 8, seed=7)),
              key=lambda i: i.num_index_edges)
    def fanout(paths, depth):
        last = paths[:, depth].astype(np.int64)
        return int((idx.fwd_end[last, k - depth - 1]
                    - idx.fwd_begin[last]).sum())

    chunk = np.full((1, k + 1), PAD, np.int32)
    chunk[0, 0] = idx.s
    best = (chunk, 0, fanout(chunk, 0))
    paths, depth = chunk, 0
    while depth + 1 < k:
        exp = _expand_chunk(idx, paths, depth, EnumStats())
        if exp is None:
            break
        parent, pos, vnew, emit, cont = exp
        sel = np.nonzero(cont)[0]
        if not sel.size:
            break
        rows = paths[parent[sel]].copy()
        rows[:, depth + 1] = vnew[sel]
        paths, depth = rows, depth + 1
        if fanout(rows, depth) >= best[2]:
            best = (rows, depth, fanout(rows, depth))
    assert best[2] > 0, (gname, idx.s, idx.t)
    return idx, best[0], best[1]


def frontier_expand() -> List[Row]:
    """Frontier-expansion (device backend) throughput on two workload
    graphs — the enumeration-kernel perf trajectory (DESIGN.md §9).  On
    CPU the kernel runs interpreted, so the wall number tracks the
    interpreter; the derived column carries the structural per-call work
    (edges gathered, candidate slots) that the TPU roofline prices.
    """
    rows: List[Row] = []
    # two regimes on purpose: dense = the wide-frontier case the §9 auto
    # rule routes to the device; pl_hub = the thin-corridor case it keeps
    # on the host (the index prunes hub graphs to a handful of edges)
    for gname, k in (("dense", 4), ("pl_hub", 6)):
        idx, chunk, depth = _frontier_workload(gname, k)
        dev = idx.device_arrays()
        last = chunk[:, depth].astype(np.int64)
        cnt = idx.fwd_end[last, k - depth - 1] - idx.fwd_begin[last]
        max_deg = int(cnt.max())

        def call(_chunk=chunk, _dev=dev, _t=idx.t, _md=max_deg, _d=depth):
            return ops.frontier_expand(_chunk, _dev.begin, _dev.end,
                                       _dev.dst, depth=_d, t=_t, max_deg=_md)

        us = _time(lambda: call()[4])
        edges = int(cnt.sum())
        slots = chunk.shape[0] * max_deg
        rows.append((f"kernels/frontier_expand_{gname}_r{chunk.shape[0]}", us,
                     f"edges={edges};slots={slots};"
                     f"edges_per_s={edges / max(us, 1e-9) * 1e6:.0f}"))
    return rows


def run() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    rows.extend(frontier_expand())

    n = 1024
    adj = np.where(rng.random((n, n)) < 0.01, 1.0, 1e9).astype(np.float32)
    dist = np.full(n, 1e9, np.float32)
    dist[0] = 0
    f = jax.jit(lambda a, d: ref.minplus_spmv_ref(a, d, 1e9))
    us = _time(f, jnp.array(adj), jnp.array(dist))
    rows.append(("kernels/minplus_spmv_n1024", us,
                 f"bytes={(n*n+2*n)*4};tpu_mem_term_us="
                 f"{(n*n+2*n)*4/819e9*1e6:.2f}"))

    q = 128
    adjm = (rng.random((n, n)) < 0.01).astype(np.float32)
    cnts = rng.random((n, q)).astype(np.float32)
    f2 = jax.jit(ref.counting_spmm_ref)
    us = _time(f2, jnp.array(adjm), jnp.array(cnts))
    flops = 2 * n * n * q
    rows.append(("kernels/counting_spmm_n1024_q128", us,
                 f"flops={flops};tpu_compute_term_us={flops/197e12*1e6:.3f}"))

    B, L, H, D = 1, 1024, 8, 64
    qq = jnp.array(rng.standard_normal((B, L, H, D)), jnp.float32)
    kk = jnp.array(rng.standard_normal((B, L, H, D)), jnp.float32)
    vv = jnp.array(rng.standard_normal((B, L, H, D)), jnp.float32)
    f3 = jax.jit(lambda a, b, c: ref.mha_ref(a, b, c, causal=True))
    us = _time(f3, qq, kk, vv)
    flops = 4 * B * H * L * L * D
    rows.append(("kernels/attention_L1024", us,
                 f"flops={flops};tpu_compute_term_us={flops/197e12*1e6:.3f}"))

    S = 8192
    q1 = jnp.array(rng.standard_normal((4, H, D)), jnp.float32)
    kc = jnp.array(rng.standard_normal((4, S, 2, D)), jnp.float32)
    vc = jnp.array(rng.standard_normal((4, S, 2, D)), jnp.float32)
    lens = jnp.array([S, S, S // 2, 7], jnp.int32)
    f4 = jax.jit(ref.decode_attention_ref)
    us = _time(f4, q1, kc, vc, lens)
    bytes_ = 4 * S * 2 * D * 2 * 4
    rows.append(("kernels/decode_attn_S8192", us,
                 f"bytes={bytes_};tpu_mem_term_us={bytes_/819e9*1e6:.3f}"))
    return rows
