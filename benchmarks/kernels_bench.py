"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute through the interpreter
(numerics only, not speed), so wall numbers here time the *jnp reference*
path — the structural costs (FLOPs, bytes) per call are derived
analytically and printed alongside.  On TPU the same entry points compile
to Mosaic; the derived column is what the roofline predicts per call.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

Row = Tuple[str, float, str]


def _time(fn, *args, repeat=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(repeat):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeat * 1e6


def run() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)

    n = 1024
    adj = np.where(rng.random((n, n)) < 0.01, 1.0, 1e9).astype(np.float32)
    dist = np.full(n, 1e9, np.float32)
    dist[0] = 0
    f = jax.jit(lambda a, d: ref.minplus_spmv_ref(a, d, 1e9))
    us = _time(f, jnp.array(adj), jnp.array(dist))
    rows.append(("kernels/minplus_spmv_n1024", us,
                 f"bytes={(n*n+2*n)*4};tpu_mem_term_us="
                 f"{(n*n+2*n)*4/819e9*1e6:.2f}"))

    q = 128
    adjm = (rng.random((n, n)) < 0.01).astype(np.float32)
    cnts = rng.random((n, q)).astype(np.float32)
    f2 = jax.jit(ref.counting_spmm_ref)
    us = _time(f2, jnp.array(adjm), jnp.array(cnts))
    flops = 2 * n * n * q
    rows.append(("kernels/counting_spmm_n1024_q128", us,
                 f"flops={flops};tpu_compute_term_us={flops/197e12*1e6:.3f}"))

    B, L, H, D = 1, 1024, 8, 64
    qq = jnp.array(rng.standard_normal((B, L, H, D)), jnp.float32)
    kk = jnp.array(rng.standard_normal((B, L, H, D)), jnp.float32)
    vv = jnp.array(rng.standard_normal((B, L, H, D)), jnp.float32)
    f3 = jax.jit(lambda a, b, c: ref.mha_ref(a, b, c, causal=True))
    us = _time(f3, qq, kk, vv)
    flops = 4 * B * H * L * L * D
    rows.append(("kernels/attention_L1024", us,
                 f"flops={flops};tpu_compute_term_us={flops/197e12*1e6:.3f}"))

    S = 8192
    q1 = jnp.array(rng.standard_normal((4, H, D)), jnp.float32)
    kc = jnp.array(rng.standard_normal((4, S, 2, D)), jnp.float32)
    vc = jnp.array(rng.standard_normal((4, S, 2, D)), jnp.float32)
    lens = jnp.array([S, S, S // 2, 7], jnp.int32)
    f4 = jax.jit(ref.decode_attention_ref)
    us = _time(f4, q1, kc, vc, lens)
    bytes_ = 4 * S * 2 * D * 2 * 4
    rows.append(("kernels/decode_attn_S8192", us,
                 f"bytes={bytes_};tpu_mem_term_us={bytes_/819e9*1e6:.3f}"))
    return rows
