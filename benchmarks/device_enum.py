"""fig_device_enum — host vs device IDX-DFS enumeration, end to end.

The trajectory row for DESIGN.md §9: the same `enumerate_paths_idx` walk
with frontier expansion on the host (numpy) and on the device backend
(the Pallas kernel — interpreted on this CPU container, Mosaic on TPU),
over two workload graphs from workloads.py.  Counts are asserted equal,
so the wall numbers always compare identical work; the derived column
records the Fig.-6 counters the kernel returned as device scalars.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core import build_index, enumerate_paths_idx

from .workloads import GRAPHS, high_degree_queries

Row = Tuple[str, float, str]

WORKLOADS = (("dag", 5), ("dense", 4))


def run() -> List[Row]:
    rows: List[Row] = []
    for gname, k in WORKLOADS:
        g = GRAPHS[gname]()
        s, t = high_degree_queries(g, 1, seed=11)[0]
        idx = build_index(g, s, t, k)
        res = {}
        for backend in ("host", "device"):
            t0 = time.perf_counter()
            res[backend] = enumerate_paths_idx(idx, count_only=True,
                                               backend=backend)
            ms = (time.perf_counter() - t0) * 1e3
            st = res[backend].stats
            rows.append((f"fig_device_enum/{gname}_{backend}_ms", ms,
                         f"results={res[backend].count};"
                         f"edges={st.edges_accessed};chunks={st.chunks}"))
        assert res["host"].count == res["device"].count, gname
        assert res["host"].stats == res["device"].stats, gname
    return rows
