"""fig_device_enum — host vs device PathEnum execution, end to end.

The trajectory rows for DESIGN.md §9, three columns:

* **dfs**: the same `enumerate_paths_idx` walk with frontier expansion
  on the host (numpy) and on the device backend (the Pallas kernel —
  interpreted on this CPU container, Mosaic on TPU; the device leg runs
  the resident work deque unless ``REPRO_DEVICE_DEQUE=off``).
* **join**: the join/count plan's hop-count DP (Alg. 5) on the host
  float64 edge-list build vs the device semiring-SpMM build, with the
  DP tables asserted bit-equal and the downstream join enumeration
  asserted to produce identical counts/stats from either build.
* **fused**: a micro-batch of queries through `core.batch.BatchPathEnum`
  with fused multi-query launches vs the solo host batch — counts and
  stats asserted equal per query, and the dispatch count the fusion
  issued recorded in the row (the whole point: one dispatch per
  expansion round for the batch, not per query).

Counts are asserted equal in every column, so the wall numbers always
compare identical work.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import build_index, enumerate_paths_idx
from repro.core.batch import BatchPathEnum
from repro.core.join import enumerate_paths_join, hop_count_dp

from .workloads import GRAPHS, high_degree_queries

Row = Tuple[str, float, str]

WORKLOADS = (("dag", 5), ("dense", 4))
FUSED_QUERIES = 4


def run() -> List[Row]:
    rows: List[Row] = []
    for gname, k in WORKLOADS:
        g = GRAPHS[gname]()
        s, t = high_degree_queries(g, 1, seed=11)[0]
        idx = build_index(g, s, t, k)

        # dfs column: host walk vs device walk (resident deque)
        res = {}
        for backend in ("host", "device"):
            t0 = time.perf_counter()
            res[backend] = enumerate_paths_idx(idx, count_only=True,
                                               backend=backend)
            ms = (time.perf_counter() - t0) * 1e3
            st = res[backend].stats
            rows.append((f"fig_device_enum/{gname}_{backend}_ms", ms,
                         f"results={res[backend].count};"
                         f"edges={st.edges_accessed};chunks={st.chunks}"))
        assert res["host"].count == res["device"].count, gname
        assert res["host"].stats == res["device"].stats, gname

        # join column: hop-count DP host vs device builds, bit-equal
        # tables, identical join enumeration from either
        dps = {}
        for backend in ("host", "device"):
            t0 = time.perf_counter()
            dps[backend] = hop_count_dp(idx, backend=backend)
            ms = (time.perf_counter() - t0) * 1e3
            rows.append((f"fig_device_enum/{gname}_join_{backend}_ms", ms,
                         f"cut={dps[backend].cut};"
                         f"q={dps[backend].q_total:.0f};"
                         f"built={dps[backend].backend_used}"))
        assert np.array_equal(dps["host"].c_to, dps["device"].c_to), gname
        assert np.array_equal(dps["host"].c_from,
                              dps["device"].c_from), gname
        assert dps["host"].cut == dps["device"].cut, gname
        cut = {b: min(max(dps[b].cut, 1), k - 1)
               for b in ("host", "device")}  # DP may prefer dfs (cut=0)
        jres = {b: enumerate_paths_join(idx, cut[b], count_only=True)
                for b in ("host", "device")}
        assert jres["host"].count == jres["device"].count, gname
        assert jres["host"].stats == jres["device"].stats, gname
        rows.append((f"fig_device_enum/{gname}_join_results",
                     float(jres["device"].count),
                     f"cut={cut['device']}"))

    # fused-launch row: a micro-batch through fused multi-query device
    # launches vs the solo host batch — same counts/stats per query,
    # dispatch count recorded
    g = GRAPHS["dag"]()
    qs = [(s, t, 5) for s, t in
          high_degree_queries(g, FUSED_QUERIES, seed=23)]
    host_eng = BatchPathEnum(backend="host", fused="off")
    t0 = time.perf_counter()
    host_out = host_eng.run(g, qs, count_only=True)
    host_ms = (time.perf_counter() - t0) * 1e3
    fused_eng = BatchPathEnum(backend="device", fused="auto")
    t0 = time.perf_counter()
    fused_out = fused_eng.run(g, qs, count_only=True)
    fused_ms = (time.perf_counter() - t0) * 1e3
    for hi, fi in zip(host_out.items, fused_out.items):
        assert hi.result.count == fi.result.count, (hi.s, hi.t)
        assert hi.result.stats == fi.result.stats, (hi.s, hi.t)
    rows.append(("fig_device_enum/fused_batch_host_ms", host_ms,
                 f"queries={len(qs)};"
                 f"results={sum(i.result.count for i in host_out.items)}"))
    rows.append(("fig_device_enum/fused_batch_device_ms", fused_ms,
                 f"queries={fused_out.fused_queries};"
                 f"dispatches={fused_out.fused_dispatches}"))
    return rows
