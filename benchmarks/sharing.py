"""Cross-query structure sharing benchmark (DESIGN.md §13).

    PYTHONPATH=src python -m benchmarks.run --only fig_sharing

The workload is the Zipfian hub shape realistic traffic produces
(millions of users querying the same hub vertices): batches of
*distinct* queries fanning out of a handful of high-degree hubs on the
``pl_hub`` power-law graph, each hot (hub, target) pair served under a
spread of hop budgets (different users, different SLAs) — the case
PR 1's exact-key dedup cannot collapse, because no two queries are
equal, yet almost all of the work is common: the BFS distance passes
of the same pair at different ``k`` coincide, and the prefix trees out
of each hub overlap.

Each row pair serves the same batch on two cold engines — ``sharing=
"off"`` (the dedup-only baseline: per-query indexes, per-query walks)
vs ``sharing="auto"`` (merged group indexes + one shared-prefix walk
per hub group) — and the headline row is the throughput multiple.
Counts are asserted byte-identical first: sharing that changed an
answer would be a bug, not a speedup (tests/test_sharing.py holds the
full byte-identity contract; the benchmark just refuses to price a
wrong answer).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import BatchPathEnum, power_law

Row = Tuple[str, float, str]


def hub_fanout_queries(g, hubs: int, fanout: int,
                       budgets: Tuple[int, ...] = (2, 3, 4, 5),
                       seed: int = 0) -> List[Tuple[int, int, int]]:
    """Distinct (hub, t, k) queries out of the top-degree hubs: targets
    drawn from each hub's 2-hop out-cone (so the prefix trees overlap),
    each hot pair queried under every hop budget in ``budgets`` (so the
    distance passes overlap — no two queries equal, exact-key dedup
    collapses nothing)."""
    rng = np.random.default_rng(seed)
    deg = np.diff(g.indptr)
    queries: List[Tuple[int, int, int]] = []
    for hub in map(int, np.argsort(deg)[::-1][:hubs]):
        # 2-hop cone: the targets shared prefixes can actually reach
        one = g.indices[g.indptr[hub]:g.indptr[hub + 1]]
        two = np.unique(np.concatenate(
            [g.indices[g.indptr[v]:g.indptr[v + 1]] for v in one]
            + [one])) if one.size else np.array([], np.int64)
        cone = two[two != hub]
        if cone.size == 0:
            continue
        picks = rng.choice(cone, size=min(fanout, cone.size), replace=False)
        queries.extend((hub, int(t), k) for t in picks for k in budgets)
    return queries


def _serve(queries, g, sharing: str, count_only: bool, reps: int) -> Tuple[
        float, "object"]:
    """Best-of-reps wall seconds on a cold engine per rep (cold = the
    honest baseline: warm LRUs would hide the construction share)."""
    best, out = float("inf"), None
    for _ in range(reps):
        eng = BatchPathEnum(sharing=sharing)
        t0 = time.perf_counter()
        o = eng.run(g, queries, count_only=count_only)
        best = min(best, time.perf_counter() - t0)
        out = o
    return best, out


def run(hubs: int = 3, fanout: int = 12,
        budgets: Tuple[int, ...] = (2, 3, 4, 5),
        reps: int = 3) -> List[Row]:
    """One suite run; returns ``(name, value, derived)`` CSV rows."""
    rows: List[Row] = []
    g = power_law(3000, 8.0, seed=1)          # the pl_hub workload graph
    queries = hub_fanout_queries(g, hubs, fanout, budgets)

    for count_only in (True, False):
        tag = "count" if count_only else "paths"
        off_s, off = _serve(queries, g, "off", count_only, reps)
        on_s, on = _serve(queries, g, "auto", count_only, reps)
        for a, b in zip(on.items, off.items):
            assert a.result.count == b.result.count, "sharing changed counts"
        mult = off_s / max(on_s, 1e-12)
        qps_on = len(queries) / max(on_s, 1e-12)
        rows.append((f"fig_sharing/{tag}_dedup_only_ms", off_s * 1e3,
                     f"q={len(queries)}"))
        rows.append((f"fig_sharing/{tag}_shared_ms", on_s * 1e3,
                     f"groups={on.sharing_groups} "
                     f"shared={on.shared_queries}"))
        rows.append((f"fig_sharing/{tag}_throughput_multiple", mult,
                     f"{qps_on:.0f}qps_shared"))
    return rows
