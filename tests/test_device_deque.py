"""Device-resident work deque tests (DESIGN.md §9).

The deque driver (`core.enumerate._drive_resident`) keeps the LIFO
chunk stack in a device arena and runs many pop→expand→push iterations
per host sync through ``ops.frontier_deque_round``.  Its contract is
bit-for-bit agreement with the host-looped device driver (and therefore
with the host backend): same paths, same count, same ``EnumStats``
including ``chunks`` (the in-arena push replicates the driver's
chunk_size split and reversed piece order, so the pop sequence is
identical).  These tests pin that contract, the ``REPRO_DEVICE_DEQUE``
kill switch, the capacity-stall fallback that rebuilds the host work
list mid-walk, and the cooperative deadline.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import build_index, clock, erdos_renyi, layered_dag
from repro.core import enumerate as en
from repro.core.enumerate import enumerate_paths_idx
from repro.kernels import ops as kops


def _assert_equal(a, b, tag=""):
    assert a.count == b.count, tag
    assert a.exhausted == b.exhausted, tag
    assert a.stats == b.stats, tag
    assert a.as_tuples() == b.as_tuples(), tag


def _graphs():
    yield erdos_renyi(40, 4.0, seed=7), 0, 39, 4
    yield erdos_renyi(25, 8.0, seed=8), 0, 24, 4
    yield layered_dag(4, 12, 6.0, seed=9), 0, 47, 4


@pytest.mark.parametrize("chunk_size", [5, 64, 16384])
def test_deque_bitwise_parity_with_host_and_loop(chunk_size, monkeypatch):
    monkeypatch.delenv("REPRO_DEVICE_DEQUE", raising=False)
    for g, s, t, k in _graphs():
        idx = build_index(g, s, t, k)
        if idx is None:
            continue
        host = enumerate_paths_idx(idx, backend="host",
                                   chunk_size=chunk_size)
        deque = enumerate_paths_idx(idx, backend="device",
                                    chunk_size=chunk_size)
        monkeypatch.setenv("REPRO_DEVICE_DEQUE", "off")
        loop = enumerate_paths_idx(idx, backend="device",
                                   chunk_size=chunk_size)
        monkeypatch.delenv("REPRO_DEVICE_DEQUE")
        _assert_equal(deque, host, f"cs={chunk_size} vs host")
        _assert_equal(deque, loop, f"cs={chunk_size} vs loop")
        assert deque.exhausted


def test_deque_count_only_parity(monkeypatch):
    monkeypatch.delenv("REPRO_DEVICE_DEQUE", raising=False)
    g, s, t, k = next(_graphs())
    idx = build_index(g, s, t, k)
    host = enumerate_paths_idx(idx, backend="host")
    co = enumerate_paths_idx(idx, backend="device", count_only=True)
    assert co.count == host.count
    assert co.stats == host.stats
    assert co.paths.shape[0] == 0


@pytest.mark.parametrize("val", ["off", "0"])
def test_deque_env_kill_switch(val, monkeypatch):
    """REPRO_DEVICE_DEQUE=off|0 pins the host-looped device driver."""
    called = []
    real = en._drive_resident

    def spy(*a, **kw):
        called.append(True)
        return real(*a, **kw)

    monkeypatch.setattr(en, "_drive_resident", spy)
    g, s, t, k = next(_graphs())
    idx = build_index(g, s, t, k)
    monkeypatch.setenv("REPRO_DEVICE_DEQUE", val)
    off = enumerate_paths_idx(idx, backend="device")
    assert not called
    monkeypatch.delenv("REPRO_DEVICE_DEQUE")
    on = enumerate_paths_idx(idx, backend="device")
    assert called
    _assert_equal(on, off)


def test_deque_ineligible_args_take_loop_driver(monkeypatch):
    """first_n / max_results / constraints stay on the host-looped path."""
    called = []
    real = en._drive_resident
    monkeypatch.setattr(en, "_drive_resident",
                        lambda *a, **kw: called.append(True) or real(*a, **kw))
    g, s, t, k = next(_graphs())
    idx = build_index(g, s, t, k)
    host = enumerate_paths_idx(idx, backend="host", first_n=3)
    dev = enumerate_paths_idx(idx, backend="device", first_n=3)
    assert not called
    _assert_equal(dev, host)


def test_deque_capacity_stall_resumes_on_host(monkeypatch):
    """A tripped arena guard rebuilds the host work list mid-walk and
    finishes on `_drive_from` with identical results and stats."""
    real_cfg = kops.deque_config

    def tiny(k1, chunk_size, max_deg, round_pops=64):
        cfg = real_cfg(k1, chunk_size, max_deg, round_pops)
        # arena barely fits one expansion: the push guard trips with
        # chunks still queued, forcing the stall branch
        return dataclasses.replace(cfg, arena_cap=cfg.cap + 2,
                                   arena_rows=cfg.cap + 2 + cfg.cap)

    monkeypatch.setattr(kops, "deque_config", tiny)
    resumed = []
    real_from = en._drive_from
    monkeypatch.setattr(
        en, "_drive_from",
        lambda *a, **kw: resumed.append(True) or real_from(*a, **kw))
    monkeypatch.delenv("REPRO_DEVICE_DEQUE", raising=False)

    g = erdos_renyi(30, 6.0, seed=5)
    idx = build_index(g, 0, 29, 5)
    assert idx is not None
    host = enumerate_paths_idx(idx, backend="host", chunk_size=5)
    dev = enumerate_paths_idx(idx, backend="device", chunk_size=5)
    assert resumed, "stall branch never triggered"
    _assert_equal(dev, host)


def test_deque_deadline_expired_returns_empty_nonexhausted(monkeypatch):
    monkeypatch.delenv("REPRO_DEVICE_DEQUE", raising=False)
    g, s, t, k = next(_graphs())
    idx = build_index(g, s, t, k)
    r = enumerate_paths_idx(idx, backend="device",
                            deadline=clock.now() - 1.0)
    assert not r.exhausted
    assert r.count == 0


def test_deque_round_trip_state_shapes():
    """frontier_deque_init/round structural contract: arena rows, meta
    slots and the monotone pop counter."""
    g = erdos_renyi(16, 3.0, seed=3)
    idx = build_index(g, 2, 15, 3)
    if idx is None:
        pytest.skip("no index for this seed")
    max_deg = int((idx.fwd_end[:, idx.k] - idx.fwd_begin).max(initial=0))
    cfg = kops.deque_config(4, 8, max(max_deg, 1))
    root = np.array([2, -1, -1, -1], np.int32)
    arena, md, ml, top, nc = kops.frontier_deque_init(root, cfg=cfg)
    assert arena.shape == (cfg.arena_rows, 4)
    assert int(top) == 1 and int(nc) == 1
    assert int(ml[0]) == 1
    dev = idx.device_arrays()
    out = kops.frontier_deque_round(arena, md, ml, top, nc, dev.begin,
                                    dev.end, dev.dst, idx.t, cfg=cfg)
    arena2, md2, ml2, top2, nc2, emitbuf, emitlen, n_emit, ctr, pops = out
    assert int(pops) >= 1
    assert int(top2) >= 0 and int(nc2) >= 0
    assert np.asarray(ctr).shape == (4,)
