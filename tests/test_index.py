"""Light-weight index semantics (Alg. 3), jit build parity, and the
Appendix-B pruning-power equivalence against the full reducer (Alg. 2)."""
import numpy as np
import pytest

from repro.core import erdos_renyi, power_law, build_index, build_index_jax
from repro.core.oracle import bfs_dist_np
from repro.core.relations import build_relations, relation_neighbors


def brute_it(g, dist_t, v, b, k, s, t):
    out = []
    for v2 in g.neighbors(v):
        v2 = int(v2)
        if v2 == s or v == t:
            continue
        if dist_t[v2] <= b:
            out.append(v2)
    return sorted(out)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("k", [3, 5])
def test_index_lookups_match_bruteforce(seed, k):
    g = erdos_renyi(50, 4.0, seed=seed)
    s, t = 0, g.n - 1
    idx = build_index(g, s, t, k)
    ds, dt = idx.dist_s, idx.dist_t
    for v in range(g.n):
        for b in range(k + 1):
            got = sorted(int(x) for x in idx.it(v, b))
            want = [v2 for v2 in brute_it(g, dt, v, b, k, s, t)
                    if ds[v] + 1 + dt[v2] <= k]
            assert got == sorted(want), (v, b)


@pytest.mark.parametrize("seed", [0, 3])
def test_level_sets_match_prop43(seed):
    k = 5
    g = power_law(80, 4.0, seed=seed)
    s, t = 1, 2
    idx = build_index(g, s, t, k)
    ds = bfs_dist_np(g, s, k, reverse=False, excluded=t)
    dt = bfs_dist_np(g, t, k, reverse=True, excluded=s)
    for i in range(k + 1):
        want = sorted(v for v in range(g.n)
                      if ds[v] <= i and dt[v] <= k - i)
        assert sorted(idx.level(i).tolist()) == want
    assert idx.level_count[0] in (0, 1)  # C_0 ⊆ {s}


@pytest.mark.parametrize("seed", range(5))
def test_jax_build_bitwise_equals_host_build(seed):
    rng = np.random.default_rng(seed)
    g = erdos_renyi(int(rng.integers(10, 80)), 3.5, seed=seed + 40)
    k = int(rng.integers(2, 7))
    a = build_index(g, 0, g.n - 1, k)
    b = build_index_jax(g, 0, g.n - 1, k)
    for f in ["dist_s", "dist_t", "fwd_dst", "fwd_eid", "fwd_begin",
              "fwd_end", "rev_src", "rev_begin", "rev_end", "level_count"]:
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    assert np.allclose(a.gamma, b.gamma, atol=1e-5)


@pytest.mark.parametrize("seed", [0, 1])
def test_appendix_b_pruning_equivalence(seed):
    """After the full reducer, R_i(u_{i-1}:v, u_i) == I_t(v, k-i)."""
    k = 4
    g = erdos_renyi(40, 3.0, seed=seed + 7)
    s, t = 0, g.n - 1
    idx = build_index(g, s, t, k)
    rels = build_relations(g, s, t, k)
    for i in range(1, k + 1):
        ri = rels[i - 1]
        for v in set(int(x) for x in ri[:, 0]):
            if v == t:
                continue
            want = relation_neighbors(rels, i, v) - {t} \
                if False else relation_neighbors(rels, i, v)
            want.discard(-1)
            got = set(int(x) for x in idx.it(v, k - i))
            assert want == got, (i, v)


def test_reverse_index_symmetry():
    g = erdos_renyi(40, 4.0, seed=5)
    k = 4
    s, t = 0, g.n - 1
    idx = build_index(g, s, t, k)
    # every forward edge must appear in the reverse index with the same
    # budget semantics: u in I_s(v, dist_s[u]) iff v in I_t(u, dist_t[v])
    for v in range(g.n):
        for b in range(k + 1):
            got = sorted(int(x) for x in idx.is_(v, b))
            want = []
            for u in g.in_neighbors(v):
                u = int(u)
                if u == t or v == s:
                    continue
                if idx.dist_s[u] <= b and \
                        idx.dist_s[u] + 1 + idx.dist_t[v] <= idx.k:
                    want.append(u)
            assert got == sorted(want), (v, b)


def test_edge_predicate_mask_filters():
    g = erdos_renyi(40, 4.0, seed=8)
    k = 4
    # forbid all edges into even vertices; index must contain none
    mask = (g.edst % 2) == 1
    idx = build_index(g, 0, g.n - 1, k, edge_mask=np.asarray(mask))
    assert np.all(idx.fwd_dst % 2 == 1) or idx.fwd_dst.size == 0
