"""Device (semiring-kernel) hop-count DP: parity, overflow, fallback.

The join/count plan's hop-count derivation (Alg. 5 / Eq. 6-7) gained a
device backend (DESIGN.md §9): level masks from min-plus BFS relaxations
(`kernels/ops.bfs_dense`) and one counting-semiring matmul per DP level
(`kernels/ops.counting_spmm`), resolved through
``join.resolve_join_backend`` behind the engine's host|device|auto knob.

Contracts pinned here:
  * **bit-match** — the device DP equals the host float64 DP *and* an
    int64 reference DP field-for-field on every random case (the f32
    matmul is exact below 2^24 because every partial sum is an exact
    integer, so accumulation order can't matter);
  * **overflow promotion** — at or past 2^24 (estimator.EXACT_COUNT_MAX)
    the device build promotes itself to the host build instead of
    silently returning rounded counts (``backend_used`` records it);
  * **distance parity** — the min-plus distances agree with the index's
    BFS arrays on every index vertex (the §3.2 closure argument);
  * **fallback matrix** — off/0 kill switch, the dense-tile n ceiling,
    and the CI force spelling, mirroring the enumeration column.

On CPU the kernels run in interpret mode (JAX_PLATFORMS=cpu CI leg), so
this suite covers the device leg everywhere tier-1 runs.
"""
import numpy as np
import pytest

from repro.core import (BatchPathEnum, build_index, complete, erdos_renyi,
                        from_edges, oracle, walk_count_dp)
from repro.core import estimator as est
from repro.core.join import hop_count_dp, resolve_join_backend
from repro.core.planner import plan_query

DP_FIELDS = ("c_to", "c_from", "q_prefix", "q_suffix")


def _random_case(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 34))
    m = max(1, int(n * float(rng.choice([0.5, 1.5, 3.0, 5.0]))))
    edges = rng.integers(0, n, size=(m, 2))      # dups/self-loops ok
    g = from_edges(n, edges)
    s, t = map(int, rng.choice(n, 2, replace=False))
    k = int(rng.integers(2, 7))
    return g, build_index(g, s, t, k)


def _int64_dp(idx):
    """Independent int64 reference build of Alg. 5 (no shared code with
    estimator.py beyond the index arrays)."""
    n, k, t = idx.n, idx.k, idx.t
    ii = np.arange(k + 1)
    lvl = ((idx.dist_s[None, :] <= ii[:, None])
           & (idx.dist_t[None, :] <= (k - ii)[:, None]))
    eu = np.repeat(np.arange(n), np.asarray(idx.fwd_end[:, k]
                                            - idx.fwd_begin))
    ev = np.asarray(idx.fwd_dst, dtype=np.intp)
    du, dv = idx.dist_s[eu], idx.dist_t[ev]
    c_to = np.zeros((k + 1, n), dtype=np.int64)
    c_to[k] = lvl[k]
    for i in range(k - 1, -1, -1):
        contrib = np.zeros(n, dtype=np.int64)
        m = dv <= (k - i - 1)
        np.add.at(contrib, eu[m], c_to[i + 1][ev[m]])
        contrib[t] += c_to[i + 1][t]
        c_to[i] = np.where(lvl[i], contrib, 0)
    c_from = np.zeros((k + 1, n), dtype=np.int64)
    c_from[0] = lvl[0]
    for i in range(1, k + 1):
        contrib = np.zeros(n, dtype=np.int64)
        m = du <= (i - 1)
        np.add.at(contrib, ev[m], c_from[i - 1][eu[m]])
        contrib[t] += c_from[i - 1][t]
        c_from[i] = np.where(lvl[i], contrib, 0)
    return c_to, c_from


@pytest.mark.parametrize("seed", range(16))
def test_device_dp_bit_matches_host_and_int64(seed):
    _g, idx = _random_case(seed)
    host = walk_count_dp(idx)
    dev = walk_count_dp(idx, backend="device")
    assert dev.backend_used == "device"
    assert host.backend_used == "host"
    for f in DP_FIELDS:
        assert np.array_equal(getattr(host, f), getattr(dev, f)), (seed, f)
    assert (host.cut, host.q_total, host.t_dfs, host.t_join) == \
        (dev.cut, dev.q_total, dev.t_dfs, dev.t_join)
    # the satellite's exactness bar: bit-match against an int64 build
    c_to64, c_from64 = _int64_dp(idx)
    assert np.array_equal(dev.c_to, c_to64.astype(np.float64)), seed
    assert np.array_equal(dev.c_from, c_from64.astype(np.float64)), seed


@pytest.mark.parametrize("seed", range(16))
def test_minplus_distances_match_index_bfs(seed):
    _g, idx = _random_case(seed)
    k = idx.k
    ds, dt = est.device_index_distances(idx)
    eu, ev = est._index_edge_list(idx)
    iv = np.unique(np.concatenate([eu, ev])).astype(np.intp)
    assert np.array_equal(ds[iv], np.minimum(idx.dist_s, k + 1)[iv]), seed
    assert np.array_equal(dt[iv], np.minimum(idx.dist_t, k + 1)[iv]), seed
    # off-index vertices may only *overestimate* (to the k+1 sentinel):
    # enough for mask parity, which the DP bit-match above relies on
    assert np.all(ds >= np.minimum(idx.dist_s, k + 1))
    assert np.all(dt >= np.minimum(idx.dist_t, k + 1))


def test_device_dp_walk_count_exact_vs_oracle():
    """dp.q_total is exact on walks; on a DAG walks == paths, so the
    device build must reproduce the oracle's path count exactly."""
    from repro.core import layered_dag
    g = layered_dag(4, 6, 3.0, seed=9)
    s, t = 0, g.n - 1
    idx = build_index(g, s, t, 4)
    want = len(oracle.enumerate_paths(g, s, t, 4))
    dev = walk_count_dp(idx, backend="device")
    assert dev.backend_used == "device"
    assert dev.q_total == float(want)


# ---------------------------------------------------------------------------
# overflow: detect and promote, never silently round
# ---------------------------------------------------------------------------

def test_overflow_promotes_to_host_build():
    """A dense-enough query really does push level counts past 2^24 —
    the device build must hand the numbers back to the host float64 DP
    (which is exact far beyond int32/f32 ranges)."""
    g = complete(34)
    idx = build_index(g, 0, 1, 6)
    host = walk_count_dp(idx)
    assert host.c_from.max() >= est.EXACT_COUNT_MAX   # case really overflows
    dev = walk_count_dp(idx, backend="device")
    assert dev.backend_used == "host"                 # promoted
    for f in DP_FIELDS:
        assert np.array_equal(getattr(host, f), getattr(dev, f))
    assert dev.q_total == host.q_total


def test_overflow_threshold_is_the_f32_exactness_bound(monkeypatch):
    """Lowering the bound forces promotion on an otherwise-exact case:
    the fence is checked against every level value, not just q_total."""
    g = erdos_renyi(20, 3.0, seed=2)
    idx = build_index(g, 0, 5, 4)
    dev = walk_count_dp(idx, backend="device")
    if dev.backend_used != "device":      # degenerate seed: nothing to test
        pytest.skip("case overflowed for real")
    top = max(dev.c_to.max(), dev.c_from.max())
    if top <= 1.0:
        pytest.skip("trivial counts")
    monkeypatch.setattr(est, "EXACT_COUNT_MAX", float(top))
    promoted = walk_count_dp(idx, backend="device")
    assert promoted.backend_used == "host"
    assert np.array_equal(promoted.c_from, dev.c_from)


# ---------------------------------------------------------------------------
# fallback matrix (join/count column) + knob threading
# ---------------------------------------------------------------------------

def test_resolve_join_backend_matrix(monkeypatch):
    g = erdos_renyi(30, 3.0, seed=7)
    idx = build_index(g, 0, 5, 4)
    assert resolve_join_backend(idx, None) == "host"
    assert resolve_join_backend(idx, "host") == "host"
    assert resolve_join_backend(idx, "device") == "device"
    assert resolve_join_backend(idx, "auto") == "host"    # sparse and/or CPU
    with pytest.raises(ValueError):
        resolve_join_backend(idx, "gpu")
    # the uniform kill switch beats every knob value
    for off in ("off", "0"):
        monkeypatch.setenv("REPRO_DEVICE_ENUM", off)
        assert resolve_join_backend(idx, "device") == "host"
        assert resolve_join_backend(idx, "auto") == "host"
    # force flips auto onto the device only past the density threshold
    monkeypatch.setenv("REPRO_DEVICE_ENUM", "force")
    from repro.core.enumerate import DEVICE_AUTO_MIN_EDGES
    want = ("device" if idx.num_index_edges >= DEVICE_AUTO_MIN_EDGES
            else "host")
    assert resolve_join_backend(idx, "auto") == want
    monkeypatch.delenv("REPRO_DEVICE_ENUM")
    # the dense-tile ceiling sends even explicit device requests home
    monkeypatch.setattr(est, "DEVICE_DP_MAX_N", idx.n - 1)
    assert resolve_join_backend(idx, "device") == "host"


def test_plan_is_backend_independent():
    """plan_query(backend=...) must return the identical plan either way
    — the knob moves the DP derivation, never the decision."""
    for seed in range(6):
        _g, idx = _random_case(100 + seed)
        ph = plan_query(idx, tau=-1.0)
        pd = plan_query(idx, tau=-1.0, backend="device")
        assert (ph.method, ph.cut) == (pd.method, pd.cut), seed
        assert ph.dp is not None and pd.dp is not None
        assert ph.dp.q_total == pd.dp.q_total
        assert pd.dp.backend_used in ("device", "host")
        dp2 = hop_count_dp(idx, "device")
        assert np.array_equal(dp2.c_from, ph.dp.c_from)


def test_batch_join_mode_parity_across_backends():
    """End-to-end: BatchPathEnum(mode="join") on the device backend plans
    through the semiring DP and must reproduce the host engine's results
    and stats exactly."""
    g = erdos_renyi(26, 3.5, seed=11)
    queries = [(0, 7, 4), (1, 9, 4), (2, 11, 3)]
    host = BatchPathEnum().run(g, queries, count_only=False, mode="join")
    dev = BatchPathEnum(backend="device").run(g, queries, count_only=False,
                                              mode="join")
    for hi, di in zip(host.items, dev.items):
        assert hi.plan.cut == di.plan.cut
        assert hi.result.as_tuples() == di.result.as_tuples()
        assert hi.result.stats == di.result.stats
