"""Regression suite for the single deadline clock (core/clock.py).

Cooperative truncation is a producer/consumer contract: serving mints an
*absolute* deadline at admission and the enumeration drivers compare
against it between chunks.  The historical bug class this suite pins
down is a clock-origin mismatch — producer and consumer reading
different time sources, which silently *disables* truncation (deadline
forever in the consumer's future) or permanently *trips* it (deadline
forever in the past) depending on the skew sign.

The technique: skew ``clock._source`` a million seconds away from
``time.perf_counter()`` and drive every deadline consumer (DFS driver,
device driver, ranked heap + bucket drivers, join, the shared walk, the
async server's enforced deadlines).  Deadlines minted from ``clock.now()``
must still truncate exactly when expired *on that clock* — any code path
still reading ``time.perf_counter()`` directly sees timestamps 1e6 s
away and fails these assertions immediately.
"""
import asyncio
import time

import numpy as np
import pytest

from repro.core import (BatchPathEnum, build_index, clock,
                        enumerate_paths_idx, enumerate_paths_join,
                        erdos_renyi)
from repro.serving import AsyncHcPEServer, PathQueryRequest, STATUS_OK

SKEW = 1.0e6   # seconds between the skewed clock and time.perf_counter()


@pytest.fixture
def skewed_clock(monkeypatch):
    """Shift the deadline clock's origin far away from perf_counter."""
    monkeypatch.setattr(clock, "_source",
                        lambda: time.perf_counter() + SKEW)


def _case(seed=7, n=30, deg=3.0, k=4):
    g = erdos_renyi(n, deg, seed=seed)
    rng = np.random.default_rng(seed)
    while True:
        s, t = map(int, rng.choice(n, 2, replace=False))
        idx = build_index(g, s, t, k)
        if idx.num_index_edges:
            full = enumerate_paths_idx(idx)
            if full.count:
                return g, idx, full


# ---------------------------------------------------------------------------
# clock primitives
# ---------------------------------------------------------------------------

def test_clock_primitives(monkeypatch):
    tick = [100.0]
    monkeypatch.setattr(clock, "_source", lambda: tick[0])
    assert clock.now() == 100.0
    assert clock.deadline_in(None) is None
    assert clock.deadline_in(2.5) == 102.5
    assert not clock.expired(None)
    assert not clock.expired(100.5)
    tick[0] = 100.5
    assert clock.expired(100.5)    # boundary: >= is expired
    assert clock.expired(100.0)


# ---------------------------------------------------------------------------
# every driver honors a clock.now()-minted deadline under heavy skew
# ---------------------------------------------------------------------------

def test_drivers_truncate_on_skewed_clock(skewed_clock):
    _g, idx, full = _case()
    past = clock.now() - 1.0
    future = clock.now() + 3600.0

    legs = [
        lambda dl: enumerate_paths_idx(idx, deadline=dl),
        lambda dl: enumerate_paths_idx(idx, backend="device", deadline=dl),
        lambda dl: enumerate_paths_idx(idx, order="hops", deadline=dl),
        lambda dl: enumerate_paths_idx(idx, order="hops", backend="device",
                                       deadline=dl),
        lambda dl: enumerate_paths_join(idx, cut=max(1, idx.k // 2),
                                        deadline=dl),
    ]
    for leg in legs:
        # expired on the shared clock -> truncates to nothing...
        res = leg(past)
        assert res.count == 0 and not res.exhausted
        # ...while a live deadline does not truncate at all: a consumer
        # still on raw perf_counter would invert exactly one of these
        res = leg(future)
        assert res.exhausted and res.count == full.count


def test_batch_and_shared_walk_truncate_on_skewed_clock(skewed_clock):
    g = erdos_renyi(24, 3.0, seed=3)
    queries = [(0, 5, 4), (0, 6, 4), (1, 5, 3)]
    eng = BatchPathEnum()          # sharing="auto": shared walk leg included
    out = eng.run(g, queries, deadline=clock.now() - 1.0)
    assert all(not it.result.exhausted and it.result.count == 0
               for it in out.items)
    out = eng.run(g, queries, deadline=clock.now() + 3600.0)
    ref = BatchPathEnum().run(g, queries)
    assert [it.result.count for it in out.items] == \
        [it.result.count for it in ref.items]
    assert all(it.result.exhausted for it in out.items)


# ---------------------------------------------------------------------------
# serving: admission (producer) and enforcement (consumer) share the source
# ---------------------------------------------------------------------------

def test_async_server_slo_consistent_under_skew(skewed_clock):
    g = erdos_renyi(40, 3.0, seed=5)
    reqs = [PathQueryRequest(uid=i, s=0, t=5 + i, k=4, deadline_ms=60_000.0)
            for i in range(3)]

    async def drive():
        async with AsyncHcPEServer(g, batch_window_ms=1.0,
                                   enforce_deadlines=True) as srv:
            return await srv.serve(reqs)

    resps = asyncio.run(drive())
    for r in resps:
        # a consumer on the raw clock would see these deadlines as ~1e6 s
        # in the past and truncate every query to an empty response
        assert r.status == STATUS_OK
        assert r.exhausted
        assert r.slo_met


def test_async_server_expired_deadline_truncates_under_skew(skewed_clock):
    g = erdos_renyi(40, 3.0, seed=5)
    # a deadline that expires during the batching window: with the shared
    # clock the engine sees it as expired and truncates cooperatively
    reqs = [PathQueryRequest(uid=0, s=0, t=5, k=4, deadline_ms=0.0)]

    async def drive():
        async with AsyncHcPEServer(g, batch_window_ms=20.0,
                                   enforce_deadlines=True) as srv:
            return await srv.serve(reqs)

    (r,) = asyncio.run(drive())
    assert r.status == STATUS_OK
    assert not r.exhausted and r.count == 0
    assert r.slo_met is False
