"""Metrics control plane: snapshots bit-match the live counters they copy.

Three layers (DESIGN.md §12): exactness — every number in a
``MetricsSnapshot`` equals the engine/server counter it was copied from,
and stays equal after more traffic (value copies, not references);
export — ``to_json`` and ``to_prometheus`` round-trip the same numbers;
invariants — ``violations()`` is empty on a healthy stack (a fuzzed
async property test drives mixed accept/reject/deadline traffic through
it) and non-empty when a counter identity is deliberately broken.
"""
import asyncio
import dataclasses
import json

import numpy as np
import pytest

from repro.core import BatchPathEnum, erdos_renyi
from repro.core.batch import CacheStats
from repro.core.enumerate import EnumStats
from repro.serving import (AsyncHcPEServer, GraphRegistry, HcPEServer,
                           MetricsSnapshot, PathQueryRequest, STATUS_OK,
                           snapshot)


def _requests(g, count, rng, graph_id, uid0=0, dup_every=3, **kw):
    reqs = []
    while len(reqs) < count:
        s, t = map(int, rng.choice(g.n, 2, replace=False))
        if reqs and len(reqs) % dup_every == 0:
            s, t = reqs[0].s, reqs[0].t        # force in-batch duplicates
        reqs.append(PathQueryRequest(uid=uid0 + len(reqs), s=s, t=t,
                                     k=int(rng.integers(2, 5)),
                                     graph_id=graph_id, **kw))
    return reqs


def _two_tenant_server():
    rng = np.random.default_rng(0)
    reg = GraphRegistry()
    reg.register("a", erdos_renyi(40, 3.0, seed=1), cache_quota=8)
    reg.register("b", erdos_renyi(50, 4.0, seed=2))
    srv = HcPEServer(reg)
    for gid in ("a", "b"):
        g = reg.get(gid)
        srv.serve(_requests(g, 9, rng, gid))
        srv.serve(_requests(g, 9, rng, gid))   # second wave: warm hits
    return srv


# ---------------------------------------------------------------------------
# exactness: the snapshot is the ground truth, bit for bit
# ---------------------------------------------------------------------------

def test_sync_snapshot_bit_matches_engine_counters():
    srv = _two_tenant_server()
    cache = srv.engine.cache
    snap = snapshot(srv)

    assert snap.serve is None and snap.queue_depth == 0   # sync front-end
    assert dataclasses.asdict(snap.cache) == dataclasses.asdict(cache.stats)
    assert snap.cache_entries == len(cache)
    assert snap.cache_capacity == cache.capacity
    assert dataclasses.asdict(snap.enum_stats) == \
        dataclasses.asdict(srv.enum_totals)
    assert set(snap.tenants) == {"a", "b"}
    for gid in ("a", "b"):
        tm = snap.tenants[gid]
        assert tm.registered
        assert dataclasses.asdict(tm.cache) == \
            dataclasses.asdict(cache.stats_for(gid))
        assert tm.cache_entries == cache.tenant_len(gid)
        assert tm.cache_quota == srv.registry.entry(gid).cache_quota
        entry = srv.registry.entry(gid)
        assert (tm.graph_version, tm.vertices, tm.edges) == \
            (entry.graph.version, entry.graph.n, entry.graph.m)
    assert snap.violations() == []


def test_snapshot_is_a_value_copy_not_a_view():
    srv = _two_tenant_server()
    snap = snapshot(srv)
    frozen = snap.to_dict()
    # new traffic (cold tenant, fresh misses) must not retro-mutate snap
    srv.registry.register("c", erdos_renyi(30, 3.0, seed=3))
    srv.serve(_requests(srv.registry.get("c"), 6,
                        np.random.default_rng(9), "c"))
    assert snap.to_dict() == frozen
    assert "c" not in snap.tenants
    later = snapshot(srv)
    assert "c" in later.tenants
    assert later.cache.misses > snap.cache.misses


def test_enum_totals_accumulate_across_serves():
    """The server-lifetime Fig.-6 totals are the merge of every batch's
    EnumStats — assert against independently re-served ground truth."""
    rng = np.random.default_rng(4)
    g = erdos_renyi(40, 3.0, seed=5)
    srv = HcPEServer(g)
    want = EnumStats()
    for uid0 in (0, 100):
        reqs = _requests(g, 7, rng, "default", uid0=uid0, count_only=False)
        _, _ = srv.serve(reqs)
        ref = BatchPathEnum().run(g, [(q.s, q.t, q.k) for q in reqs],
                                  count_only=False)
        want.merge(ref.enum_stats)
    snap = snapshot(srv)
    assert dataclasses.asdict(snap.enum_stats) == dataclasses.asdict(want)
    assert snap.enum_stats.results > 0


def test_retired_tenant_survives_as_unregistered_stats():
    srv = _two_tenant_server()
    misses_before = srv.engine.cache.stats_for("a").misses
    srv.registry.retire("a")
    snap = snapshot(srv)
    tm = snap.tenants["a"]
    assert not tm.registered and tm.graph_version == -1
    assert tm.cache_entries == 0                  # entries purged at retire
    assert tm.cache.misses == misses_before       # history kept (§8)
    assert snap.violations() == []


def test_async_snapshot_bit_matches_server_stats():
    rng = np.random.default_rng(6)
    reg = GraphRegistry()
    g = erdos_renyi(50, 3.0, seed=7)
    reg.register("live", g)

    async def drive():
        async with AsyncHcPEServer(reg, batch_window_ms=1.0) as srv:
            reqs = _requests(g, 10, rng, "live", deadline_ms=500.0)
            reqs.append(PathQueryRequest(uid=99, s=0, t=1, k=3,
                                         graph_id="ghost"))
            resps = await srv.serve(reqs)
            return srv, snapshot(srv), resps

    srv, snap, resps = asyncio.run(drive())
    assert snap.serve is not None
    assert dataclasses.asdict(snap.serve) == dataclasses.asdict(srv.stats)
    assert snap.serve.submitted == 11
    assert snap.serve.rejected_unknown_graph == 1
    assert snap.serve.completed == \
        sum(1 for r in resps if r.status == STATUS_OK)
    assert snap.queue_depth == 0                  # drained before capture
    assert snap.violations() == []
    assert dataclasses.asdict(snap.enum_stats) == \
        dataclasses.asdict(srv.enum_totals)


# ---------------------------------------------------------------------------
# export formats
# ---------------------------------------------------------------------------

def test_json_export_round_trips_to_dict():
    srv = _two_tenant_server()
    snap = snapshot(srv)
    assert json.loads(snap.to_json()) == json.loads(
        json.dumps(snap.to_dict()))
    assert json.loads(snap.to_json(indent=2)) == json.loads(snap.to_json())
    doc = json.loads(snap.to_json())
    assert doc["cache"]["hits"] == srv.engine.cache.stats.hits
    assert doc["tenants"]["a"]["cache"]["hits"] == \
        srv.engine.cache.stats_for("a").hits


def test_prometheus_export_shape_and_values():
    srv = _two_tenant_server()
    snap = snapshot(srv)
    text = snap.to_prometheus()
    assert text.endswith("\n")
    lines = text.splitlines()
    headers = [l for l in lines if l.startswith("# TYPE")]
    assert len(headers) == len(set(headers))      # one TYPE header a family
    assert f"pathenum_cache_hits_total {snap.cache.hits}" in lines
    for gid in ("a", "b"):
        want = snap.tenants[gid].cache.hits
        assert (f'pathenum_tenant_cache_hits_total{{graph_id="{gid}"}} '
                f"{want}") in lines
    # "b" has no quota: no fake bound exported
    assert not any('pathenum_tenant_cache_quota{graph_id="b"}' in l
                   for l in lines)
    assert any('pathenum_tenant_cache_quota{graph_id="a"} 8' == l
               for l in lines)
    # sync snapshot: no serve family at all
    assert not any("pathenum_serve_" in l for l in lines)


def test_prometheus_label_escaping():
    snap = MetricsSnapshot(captured_at=0.0, cache=CacheStats(),
                           cache_entries=0, cache_capacity=0,
                           enum_stats=EnumStats(), tenants={})
    lines = []
    snap._sample(lines, "m", "gauge", 1, 'we"ird\\id\n')
    assert lines[1] == 'm{graph_id="we\\"ird\\\\id\\n"} 1'


# ---------------------------------------------------------------------------
# invariants: violations() is empty on healthy stacks, loud on broken ones
# ---------------------------------------------------------------------------

def test_violations_catch_injected_tenant_drift():
    srv = _two_tenant_server()
    snap = snapshot(srv)
    assert snap.violations() == []
    snap.tenants["a"].cache.hits += 1             # re-introduce the drift bug
    bad = snap.violations()
    assert len(bad) == 1 and "hits" in bad[0]


def test_violations_catch_broken_admission_identity():
    rng = np.random.default_rng(8)
    g = erdos_renyi(30, 3.0, seed=8)

    async def drive():
        async with AsyncHcPEServer(g, batch_window_ms=0.0) as srv:
            await srv.serve(_requests(g, 5, rng, "default"))
            return snapshot(srv)

    snap = asyncio.run(drive())
    assert snap.violations() == []
    snap.serve.accepted -= 1
    assert any("admission" in v or "settlement" in v
               for v in snap.violations())


@pytest.mark.parametrize("seed", range(6))
def test_fuzzed_async_traffic_keeps_counter_identities(seed):
    """The counter-consistency property: under mixed traffic — duplicate
    queries, unknown tenants, per-tenant admission quotas, tight and
    absent deadlines — the admission and settlement identities hold, the
    SLO counters agree with the responses, and the snapshot reports no
    violations."""
    rng = np.random.default_rng(100 + seed)
    reg = GraphRegistry()
    graphs = {"a": erdos_renyi(30, 3.0, seed=seed),
              "b": erdos_renyi(45, 4.0, seed=seed + 50)}
    reg.register("a", graphs["a"], cache_quota=3, max_pending=2)
    reg.register("b", graphs["b"])
    gids = ["a", "b", "ghost"]

    reqs = []
    for uid in range(int(rng.integers(20, 40))):
        gid = gids[int(rng.integers(0, 3))]
        g = graphs.get(gid, graphs["a"])
        s, t = map(int, rng.choice(g.n, 2, replace=False))
        dl = [None, 0.05, 50.0, 2000.0][int(rng.integers(0, 4))]
        reqs.append(PathQueryRequest(uid=uid, s=s, t=t,
                                     k=int(rng.integers(2, 5)),
                                     graph_id=gid, deadline_ms=dl))

    async def drive():
        async with AsyncHcPEServer(
                reg, batch_window_ms=float(rng.choice([0.0, 1.0])),
                max_queue_depth=8) as srv:
            resps = await srv.serve(reqs)
            return srv, snapshot(srv), resps

    srv, snap, resps = asyncio.run(drive())
    s = snap.serve
    assert s.submitted == len(reqs)
    assert s.submitted == s.accepted + s.rejected_total
    assert s.accepted == (s.completed + s.rejected_mid_flight + s.cancelled
                          + s.failed)                 # fully drained
    assert s.failed == 0 and s.cancelled == 0
    assert s.completed == sum(1 for r in resps if r.status == STATUS_OK)
    assert s.slo_met == sum(1 for r in resps if r.slo_met is True)
    assert s.slo_missed == sum(1 for r in resps if r.slo_met is False)
    assert snap.violations() == []
    # the exports stay serializable under every traffic mix
    json.loads(snap.to_json())
    assert snap.to_prometheus().count("# TYPE") > 10


# ---------------------------------------------------------------------------
# server-side conveniences
# ---------------------------------------------------------------------------

def test_metrics_snapshot_methods_match_free_function():
    srv = _two_tenant_server()
    a = srv.metrics_snapshot()
    b = snapshot(srv)
    da, db = a.to_dict(), b.to_dict()
    da.pop("captured_at"), db.pop("captured_at")
    assert da == db

    async def drive():
        async with AsyncHcPEServer(srv.registry.get("a"),
                                   batch_window_ms=0.0) as asrv:
            await asrv.serve(_requests(srv.registry.get("a"), 3,
                                       np.random.default_rng(1), "default"))
            return asrv.metrics_snapshot()

    snap = asyncio.run(drive())
    assert snap.serve is not None and snap.violations() == []
