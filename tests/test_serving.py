"""Serving engine: continuous batching correctness vs a manual decode loop."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import decode_step, init_cache, init_params
from repro.serving.engine import Request, ServeEngine

CFG = ArchConfig(name="tiny_serve", family="dense", num_layers=2, d_model=64,
                 num_heads=4, kv_heads=2, d_ff=128, vocab=97, head_dim=16,
                 attn_chunk=16, tie_embeddings=True)


def manual_greedy(params, prompt, n_tokens, max_len=64):
    cache = init_cache(CFG, 1, max_len)
    lens = jnp.zeros((1,), jnp.int32)
    tok = None
    for p in prompt:
        logits, cache = decode_step(params, CFG,
                                    jnp.array([p], jnp.int32), cache, lens)
        lens = lens + 1
        tok = int(jnp.argmax(logits, -1)[0])
    out = []
    for _ in range(n_tokens):
        out.append(tok)
        logits, cache = decode_step(params, CFG,
                                    jnp.array([tok], jnp.int32), cache, lens)
        lens = lens + 1
        tok = int(jnp.argmax(logits, -1)[0])
    return out


def test_engine_matches_manual_decode():
    params = init_params(CFG, jax.random.PRNGKey(0))
    prompts = [np.array([5, 9, 13], np.int32), np.array([2, 7], np.int32),
               np.array([40, 41, 42, 43], np.int32)]
    n = 6
    engine = ServeEngine(CFG, params, batch_slots=2, max_len=64)
    for uid, p in enumerate(prompts):
        engine.submit(Request(uid=uid, prompt=p, max_tokens=n))
    results = engine.run()
    assert set(results) == {0, 1, 2}
    for uid, p in enumerate(prompts):
        want = manual_greedy(params, p.tolist(), n)
        assert results[uid] == want, (uid, results[uid], want)


def test_engine_more_requests_than_slots():
    params = init_params(CFG, jax.random.PRNGKey(1))
    engine = ServeEngine(CFG, params, batch_slots=2, max_len=32)
    for uid in range(5):
        engine.submit(Request(uid=uid,
                              prompt=np.array([uid + 3], np.int32),
                              max_tokens=3))
    results = engine.run()
    assert len(results) == 5
    assert all(len(v) == 3 for v in results.values())
