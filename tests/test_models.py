"""Per-arch smoke tests (reduced configs) + decode parity + attention parity.

Smoke contract per the assignment: instantiate the REDUCED config of every
assigned architecture, run one forward + one train step on CPU, assert
output shapes and no NaNs.  The FULL configs are exercised only via the
dry-run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, all_archs, get_arch
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn)
from repro.models.attention import _xla_attention
from repro.kernels import ref as kref
from repro.optim import adamw
from repro.training import step as step_mod

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=32):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend != "none":
        batch["prefix_emb"] = 0.02 * jax.random.normal(
            KEY, (B, cfg.frontend_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_arch(arch).reduced()
    params = init_params(cfg, KEY)
    batch = make_batch(cfg)
    logits, _ = forward(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    opt_cfg = adamw.OptimizerConfig(total_steps=10)
    ts = step_mod.make_train_step(cfg, opt_cfg)
    opt_state = adamw.init(params)
    p2, o2, metrics = jax.jit(ts)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode_step(arch):
    cfg = get_arch(arch).reduced()
    params = init_params(cfg, KEY)
    cache = init_cache(cfg, 2, 16)
    logits, cache2 = decode_step(params, cfg,
                                 jnp.array([1, 2], jnp.int32), cache,
                                 jnp.zeros((2,), jnp.int32))
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ["llama3p2_1b", "qwen3_moe_30b_a3b",
                                  "mamba2_780m", "recurrentgemma_9b"])
def test_decode_matches_parallel_forward(arch):
    cfg = all_archs()[arch].reduced()
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_params(cfg, KEY)
    B, S = 2, 20
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    logits_par, _ = forward(params, cfg, {"tokens": tokens, "labels": tokens})
    cache = init_cache(cfg, B, S)
    lens = jnp.zeros((B,), jnp.int32)
    outs = []
    step = jax.jit(lambda p, tok, c, l: decode_step(p, cfg, tok, c, l))
    for i in range(S):
        lg, cache = step(params, tokens[:, i], cache, lens)
        lens = lens + 1
        outs.append(lg)
    err = float(jnp.abs(logits_par - jnp.stack(outs, 1)).max())
    assert err < 2e-3, err


def test_xla_attention_matches_reference():
    B, L, H, Hkv, D = 2, 128, 8, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(1), (B, L, H, D))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, L, Hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, L, Hkv, D))
    got = _xla_attention(q, k, v, causal=True, window=None, q_chunk=32)
    want = kref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    got_w = _xla_attention(q, k, v, causal=True, window=16, q_chunk=32)
    want_w = kref.mha_ref(q, k, v, causal=True, window=16)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w),
                               atol=2e-5)


def test_vocab_parallel_loss_equals_naive_ce():
    cfg = get_arch("llama3p2_1b").reduced()
    params = init_params(cfg, KEY)
    batch = make_batch(cfg)
    loss, _ = loss_fn(params, cfg, batch)
    logits, _ = forward(params, cfg, batch)
    logp = jax.nn.log_softmax(np.asarray(logits[:, :-1], np.float32), -1)
    lbl = np.asarray(batch["labels"][:, 1:])
    ll = np.take_along_axis(logp, lbl[:, :, None], axis=-1)[..., 0]
    want = -ll.mean()
    assert abs(float(loss) - float(want)) < 1e-3


def test_param_counts_match_spec():
    expected = {
        "phi3_vision_4p2b": (3.5, 4.6),
        "mistral_large_123b": (118, 127),
        "llama3p2_1b": (1.0, 1.5),
        "starcoder2_7b": (6.0, 11.0),
        "internlm2_1p8b": (1.5, 2.2),
        "llama4_maverick_400b_a17b": (360, 440),
        "qwen3_moe_30b_a3b": (27, 33),
        "mamba2_780m": (0.6, 0.95),
        "recurrentgemma_9b": (8.0, 11.0),
        "musicgen_large": (2.8, 4.2),
    }
    for arch, (lo, hi) in expected.items():
        got = get_arch(arch).param_count() / 1e9
        assert lo <= got <= hi, (arch, got)
