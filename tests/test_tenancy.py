"""Multi-graph tenancy (DESIGN.md §8): registry, isolation, quotas.

The acceptance contract: two registered tenant graphs served through
both ``HcPEServer.serve`` and ``AsyncHcPEServer`` return byte-identical
path sets to per-graph single-tenant runs, with per-tenant cache stats
and quota rejections observable in the responses/reports — and
single-graph callers run unchanged under ``DEFAULT_GRAPH_ID``.
"""
import asyncio

import numpy as np
import pytest

from repro.core import DEFAULT_GRAPH_ID, PathEnum, erdos_renyi, power_law
from repro.core.graph import PAD
from repro.serving import (AsyncHcPEServer, GraphRegistry, HcPEServer,
                           PathQueryRequest, STATUS_OK,
                           STATUS_REJECTED_TENANT_QUOTA,
                           STATUS_REJECTED_UNKNOWN_GRAPH)


def _requests(g, graph_id, count, rng, k=4, uid0=0, **kw):
    reqs = []
    while len(reqs) < count:
        s, t = rng.integers(0, g.n, 2)
        if s != t:
            reqs.append(PathQueryRequest(uid=uid0 + len(reqs), s=int(s),
                                         t=int(t), k=k, graph_id=graph_id,
                                         **kw))
    return reqs


def _two_tenants():
    return erdos_renyi(70, 4.0, seed=3), power_law(90, 5.0, seed=8)


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_registry_register_retire_lookup():
    g_a, g_b = _two_tenants()
    reg = GraphRegistry()
    reg.register("a", g_a)
    entry = reg.register("b", g_b, cache_quota=7, max_pending=3)
    assert set(reg.graph_ids()) == {"a", "b"}
    assert "a" in reg and len(reg) == 2
    assert reg.get("b") is g_b
    assert (entry.cache_quota, entry.max_pending) == (7, 3)
    retired = reg.retire("a")
    assert retired.graph is g_a
    assert "a" not in reg
    with pytest.raises(KeyError):
        reg.get("a")


def test_registry_empty_graph_id_rejected():
    with pytest.raises(ValueError):
        GraphRegistry().register("", erdos_renyi(10, 2.0, seed=0))


def test_registry_binds_quota_to_engine_cache():
    g_a, g_b = _two_tenants()
    reg = GraphRegistry()
    reg.register("a", g_a, cache_quota=2)
    server = HcPEServer(reg)                       # binds its engine
    assert server.engine.cache.quota_for("a") == 2
    # registering after binding propagates too
    reg.register("b", g_b, cache_quota=5)
    assert server.engine.cache.quota_for("b") == 5


def test_retire_drops_tenant_cache_entries():
    g_a, g_b = _two_tenants()
    reg = GraphRegistry()
    reg.register("a", g_a)
    reg.register("b", g_b)
    server = HcPEServer(reg)
    rng = np.random.default_rng(0)
    server.serve(_requests(g_a, "a", 4, rng) + _requests(g_b, "b", 4, rng,
                                                         uid0=4))
    cache = server.engine.cache
    assert cache.tenant_len("a") > 0 and cache.tenant_len("b") > 0
    reg.retire("a")
    assert cache.tenant_len("a") == 0              # purged from the engine
    assert cache.tenant_len("b") > 0               # neighbor untouched


def test_reregister_same_id_invalidates_old_graph_entries():
    """Replacing a tenant's graph must drop indexes built on the old one —
    they would answer queries against the wrong graph."""
    g_old, g_new = _two_tenants()
    reg = GraphRegistry()
    reg.register("x", g_old)
    server = HcPEServer(reg)
    rng = np.random.default_rng(1)
    server.serve(_requests(g_old, "x", 3, rng))
    assert server.engine.cache.tenant_len("x") > 0
    reg.register("x", g_new)
    assert server.engine.cache.tenant_len("x") == 0
    # fresh queries hit the new graph, byte-identical to a solo engine
    reqs = _requests(g_new, "x", 5, rng)
    resps, _ = server.serve(reqs)
    seq = PathEnum()
    for r, q in zip(resps, reqs):
        assert r.count == seq.count(g_new, q.s, q.t, q.k)


# ---------------------------------------------------------------------------
# sync server: two tenants == two single-tenant runs, byte-identical
# ---------------------------------------------------------------------------

def test_sync_two_tenants_byte_identical_to_single_tenant_runs():
    g_a, g_b = _two_tenants()
    rng = np.random.default_rng(7)
    reqs_a = _requests(g_a, "a", 8, rng, count_only=False)
    reqs_b = _requests(g_b, "b", 8, rng, uid0=8, count_only=False)

    reg = GraphRegistry()
    reg.register("a", g_a)
    reg.register("b", g_b)
    interleaved = [r for pair in zip(reqs_a, reqs_b) for r in pair]
    resps, report = HcPEServer(reg).serve(interleaved)

    # per-graph single-tenant baselines (default graph_id path)
    solo_a, _ = HcPEServer(g_a).serve(
        [PathQueryRequest(uid=r.uid, s=r.s, t=r.t, k=r.k, count_only=False)
         for r in reqs_a])
    solo_b, _ = HcPEServer(g_b).serve(
        [PathQueryRequest(uid=r.uid, s=r.s, t=r.t, k=r.k, count_only=False)
         for r in reqs_b])
    solo = {r.uid: r for r in solo_a + solo_b}

    assert [r.uid for r in resps] == [q.uid for q in interleaved]
    for r, q in zip(resps, interleaved):
        assert r.status == STATUS_OK and r.graph_id == q.graph_id
        want = solo[r.uid]
        assert r.count == want.count
        if want.paths is None:
            assert r.paths is None or r.paths.shape[0] == 0
        else:  # exact path sets, not just counts
            assert sorted(map(tuple, r.paths.tolist())) == \
                sorted(map(tuple, want.paths.tolist()))
    # per-tenant cache stats are observable and partition the batch delta
    assert set(report.tenant_cache) == {"a", "b"}
    assert report.tenant_cache["a"].misses + \
        report.tenant_cache["b"].misses == report.cache.misses


def test_sync_unknown_graph_is_rejection_response():
    g_a, _ = _two_tenants()
    reg = GraphRegistry()
    reg.register("a", g_a)
    reqs = [PathQueryRequest(uid=0, s=0, t=1, k=3, graph_id="a"),
            PathQueryRequest(uid=1, s=0, t=1, k=3, graph_id="ghost")]
    resps, report = HcPEServer(reg).serve(reqs)
    assert resps[0].status == STATUS_OK
    assert resps[1].status == STATUS_REJECTED_UNKNOWN_GRAPH
    assert resps[1].rejected and resps[1].count == 0
    assert resps[1].graph_id == "ghost"
    assert report.batch_size == 1                  # rejected did no work
    assert report.distinct_queries == 1


def test_single_graph_caller_unchanged_default_graph_id():
    """The compatibility contract: a bare-graph server is the default
    tenant, requests without graph_id serve against it, and the engine's
    cache keys carry DEFAULT_GRAPH_ID."""
    g = erdos_renyi(50, 4.0, seed=11)
    server = HcPEServer(g)
    assert server.graph is g
    reqs = _requests(g, DEFAULT_GRAPH_ID, 5, np.random.default_rng(2))
    resps, report = server.serve(reqs)
    seq = PathEnum()
    for r, q in zip(resps, reqs):
        assert r.graph_id == DEFAULT_GRAPH_ID
        assert r.count == seq.count(g, q.s, q.t, q.k)
    assert set(report.tenant_cache) == {DEFAULT_GRAPH_ID}


def test_tenants_with_same_stk_do_not_share_cache_entries():
    """Two tenants issuing the same (s, t, k) must each build (and hit)
    their own index — a shared entry would answer one tenant's query on
    the other tenant's graph."""
    g_a, g_b = _two_tenants()
    reg = GraphRegistry()
    reg.register("a", g_a)
    reg.register("b", g_b)
    server = HcPEServer(reg)
    reqs = [PathQueryRequest(uid=0, s=2, t=5, k=4, graph_id="a"),
            PathQueryRequest(uid=1, s=2, t=5, k=4, graph_id="b")]
    resps, report = server.serve(reqs)
    # both missed: no cross-tenant sharing despite identical (s, t, k)
    assert report.tenant_cache["a"].misses == 1
    assert report.tenant_cache["b"].misses == 1
    seq = PathEnum()
    assert resps[0].count == seq.count(g_a, 2, 5, 4)
    assert resps[1].count == seq.count(g_b, 2, 5, 4)
    # warm repeat: each tenant hits its own entry
    _, warm = server.serve(reqs)
    assert warm.tenant_cache["a"].hits == 1
    assert warm.tenant_cache["b"].hits == 1
    assert warm.cache.misses == 0


# ---------------------------------------------------------------------------
# async server: tenancy through admission + micro-batching
# ---------------------------------------------------------------------------

def test_async_two_tenants_byte_identical_to_single_tenant_runs():
    g_a, g_b = _two_tenants()
    rng = np.random.default_rng(9)
    reqs_a = _requests(g_a, "a", 6, rng, count_only=False)
    reqs_b = _requests(g_b, "b", 6, rng, uid0=6, count_only=False)
    interleaved = [r for pair in zip(reqs_a, reqs_b) for r in pair]

    reg = GraphRegistry()
    reg.register("a", g_a)
    reg.register("b", g_b)

    async def drive():
        async with AsyncHcPEServer(reg, batch_window_ms=2.0) as srv:
            resps = await srv.serve(interleaved)
            return resps, srv.drain_report()

    resps, report = asyncio.run(drive())
    seq = PathEnum()
    graphs = {"a": g_a, "b": g_b}
    for r, q in zip(resps, interleaved):
        assert r.status == STATUS_OK and r.graph_id == q.graph_id
        want = sorted(seq.query(graphs[q.graph_id], q.s, q.t,
                                q.k).result.as_tuples())
        rows = r.paths if r.paths is not None else np.zeros((0, q.k + 1))
        got = sorted(tuple(int(x) for x in row if x != PAD) for row in rows)
        assert got == want                       # exact per-tenant path sets
        assert r.count == len(want)
    # micro-batches never mixed tenants; per-tenant stats observable
    assert set(report.tenant_cache) <= {"a", "b"}
    assert report.tenant_cache["a"].lookups > 0
    assert report.tenant_cache["b"].lookups > 0


def test_async_unknown_graph_rejected_at_admission():
    g_a, _ = _two_tenants()

    async def drive():
        async with AsyncHcPEServer(g_a) as srv:
            resp = await srv.submit(PathQueryRequest(uid=0, s=0, t=1, k=3,
                                                     graph_id="ghost"))
            return resp, srv.stats

    resp, stats = asyncio.run(drive())
    assert resp.status == STATUS_REJECTED_UNKNOWN_GRAPH
    assert stats.rejected_unknown_graph == 1


def test_async_per_tenant_quota_rejection():
    """One tenant floods past its registry max_pending while the other
    tenant's requests sail through — per-tenant admission, not global."""
    g_a, g_b = _two_tenants()
    reg = GraphRegistry()
    reg.register("flooded", g_a, max_pending=1)
    reg.register("calm", g_b)

    flood = [PathQueryRequest(uid=i, s=0, t=1 + i, k=3, graph_id="flooded")
             for i in range(4)]
    calm = [PathQueryRequest(uid=10 + i, s=0, t=1 + i, k=3, graph_id="calm")
            for i in range(3)]

    async def drive():
        async with AsyncHcPEServer(reg, batch_window_ms=10.0) as srv:
            return await srv.serve(flood + calm), srv.stats

    resps, stats = asyncio.run(drive())
    flood_status = [r.status for r in resps[:4]]
    assert flood_status[0] == STATUS_OK
    assert flood_status.count(STATUS_REJECTED_TENANT_QUOTA) == 3
    assert all(r.status == STATUS_OK for r in resps[4:])
    assert stats.rejected_tenant_quota == 3


def test_async_server_wide_tenant_quota_default():
    """max_pending_per_graph applies to tenants without their own
    registry quota."""
    g_a, _ = _two_tenants()

    async def drive():
        async with AsyncHcPEServer(g_a, batch_window_ms=10.0,
                                   max_pending_per_graph=2) as srv:
            reqs = [PathQueryRequest(uid=i, s=0, t=1 + i, k=3)
                    for i in range(5)]
            return await srv.serve(reqs)

    resps = asyncio.run(drive())
    statuses = [r.status for r in resps]
    assert statuses.count(STATUS_OK) == 2
    assert statuses.count(STATUS_REJECTED_TENANT_QUOTA) == 3


def test_async_tenant_retired_mid_flight_fails_soft():
    """A tenant retired between admission and dispatch resolves to
    unknown-graph rejections, and the scheduler keeps serving others."""
    g_a, g_b = _two_tenants()
    reg = GraphRegistry()
    reg.register("doomed", g_a)
    reg.register("stable", g_b)

    async def drive():
        async with AsyncHcPEServer(reg, batch_window_ms=30.0) as srv:
            doomed = asyncio.ensure_future(srv.submit(
                PathQueryRequest(uid=0, s=0, t=1, k=3, graph_id="doomed")))
            await asyncio.sleep(0.005)           # admitted; batch in window
            reg.retire("doomed")
            stable = await srv.submit(
                PathQueryRequest(uid=1, s=0, t=1, k=3, graph_id="stable"))
            return await doomed, stable

    doomed, stable = asyncio.run(drive())
    assert doomed.status == STATUS_REJECTED_UNKNOWN_GRAPH
    assert stable.status == STATUS_OK
