"""Streaming graph mutation: versioned copies, cache invalidation, hot-swap.

The contract under test (DESIGN.md §12) has two layers: ``Graph.with_edges``
must behave exactly like a fresh ``from_edges`` build of the mutated edge
set (plus a monotone ``version`` bump), and the serving stack above it —
cache keys, registry ``mutate``/``register``, both front-ends — must never
answer a post-mutation query with a pre-mutation index.  The stale-index
regression tests pin the second layer by diffing against a cold engine:
byte-identical counts, zero cache hits across the mutation boundary.
"""
import asyncio

import numpy as np
import pytest

from repro.core import BatchPathEnum, PathEnum, erdos_renyi, from_edges
from repro.serving import (AsyncHcPEServer, GraphRegistry, HcPEServer,
                           PathQueryRequest, STATUS_OK,
                           STATUS_REJECTED_TENANT_QUOTA)


def _edge_set(g):
    return {(int(u), int(v)) for u, v in g.edge_list()}


# ---------------------------------------------------------------------------
# Graph.with_edges: versioned copy == fresh build
# ---------------------------------------------------------------------------

def test_with_edges_add_remove_matches_fresh_build():
    g = erdos_renyi(40, 3.0, seed=5)
    rng = np.random.default_rng(1)
    drop = g.edge_list()[rng.choice(g.m, 5, replace=False)]
    new = np.array([[0, 39], [39, 0], [7, 11]])
    g2 = g.with_edges(add=new, remove=drop)

    want = _edge_set(g) - {(int(u), int(v)) for u, v in drop}
    want |= {(int(u), int(v)) for u, v in new}
    assert _edge_set(g2) == want
    assert g2.version == g.version + 1
    # the mutated CSR must be indistinguishable from a cold build of the
    # same edge set — reverse CSR included (the index build walks both)
    fresh = from_edges(g.n, np.array(sorted(want)))
    np.testing.assert_array_equal(g2.indptr, fresh.indptr)
    np.testing.assert_array_equal(g2.indices, fresh.indices)
    np.testing.assert_array_equal(g2.rindptr, fresh.rindptr)
    np.testing.assert_array_equal(g2.rindices, fresh.rindices)
    # original untouched (versioned copy, not in-place)
    assert g.version == 0 and _edge_set(g) != want


def test_with_edges_version_is_monotone_per_mutation():
    g = from_edges(4, np.array([[0, 1], [1, 2]]))
    g1 = g.add_edges(np.array([[2, 3]]))
    g2 = g1.remove_edges(np.array([[2, 3]]))
    g3 = g2.with_edges()            # no-op mutation still advances the epoch
    assert [g.version, g1.version, g2.version, g3.version] == [0, 1, 2, 3]
    assert _edge_set(g2) == _edge_set(g)


def test_with_edges_duplicate_insert_is_setlike_and_self_loops_drop():
    g = from_edges(4, np.array([[0, 1]]))
    g2 = g.add_edges(np.array([[0, 1], [0, 1], [2, 2], [1, 2]]))
    assert _edge_set(g2) == {(0, 1), (1, 2)}


def test_with_edges_remove_then_add_same_edge_reinserts():
    g = from_edges(3, np.array([[0, 1], [1, 2]]))
    g2 = g.with_edges(add=np.array([[0, 1]]), remove=np.array([[0, 1]]))
    assert _edge_set(g2) == {(0, 1), (1, 2)}


def test_with_edges_rejects_missing_removal_and_bad_endpoints():
    g = from_edges(4, np.array([[0, 1], [1, 2]]))
    with pytest.raises(ValueError, match=r"cannot remove edge \(2, 3\)"):
        g.remove_edges(np.array([[2, 3]]))
    with pytest.raises(ValueError, match="endpoints"):
        g.add_edges(np.array([[0, 4]]))
    with pytest.raises(ValueError, match="endpoints"):
        g.remove_edges(np.array([[-1, 0]]))


# ---------------------------------------------------------------------------
# stale-index regression: a mutated graph never serves a pre-mutation index
# ---------------------------------------------------------------------------

def test_mutated_graph_never_serves_stale_index():
    """The acceptance criterion: warm an engine on v0, mutate, and the v1
    run must miss the cache and agree with a cold engine byte-for-byte."""
    g = erdos_renyi(60, 3.0, seed=8)
    rng = np.random.default_rng(3)
    queries = []
    while len(queries) < 8:
        s, t = map(int, rng.choice(g.n, 2, replace=False))
        queries.append((s, t, int(rng.integers(2, 6))))

    eng = BatchPathEnum()
    eng.run(g, queries)                       # warm the cache on version 0
    g2 = g.with_edges(add=np.array([[0, 1], [1, 0]]),
                      remove=g.edge_list()[:3])

    before = eng.cache.stats.snapshot()
    warm = eng.run(g2, queries)
    delta = eng.cache.stats.delta(before)
    assert delta.hits == 0                    # v0 entries unreachable
    assert delta.misses == len(queries)
    cold = BatchPathEnum().run(g2, queries)
    assert warm.counts.tolist() == cold.counts.tolist()

    # and the v0 entries still serve v0 queries (coexisting epochs)
    before = eng.cache.stats.snapshot()
    again = eng.run(g, queries)
    assert eng.cache.stats.delta(before).hits == len(queries)
    seq = PathEnum()
    assert again.counts.tolist() == [seq.count(g, s, t, k)
                                     for (s, t, k) in queries]


def test_registry_mutate_purges_engine_entries_and_keeps_quota():
    g = erdos_renyi(40, 3.0, seed=2)
    reg = GraphRegistry()
    reg.register("fraud", g, cache_quota=4)
    srv = HcPEServer(reg)
    reqs = [PathQueryRequest(uid=i, s=i, t=i + 10, k=3, graph_id="fraud")
            for i in range(6)]
    srv.serve(reqs)
    assert srv.engine.cache.tenant_len("fraud") == 4     # quota bound held

    entry = reg.mutate("fraud", add=np.array([[0, 39]]))
    assert entry.graph.version == 1
    assert srv.engine.cache.tenant_len("fraud") == 0     # purged
    assert srv.engine.cache.quota_for("fraud") == 4      # quota survives

    before = srv.engine.cache.stats_for("fraud").snapshot()
    resp, _ = srv.serve(reqs)
    assert all(r.status == STATUS_OK for r in resp)
    delta = srv.engine.cache.stats_for("fraud").delta(before)
    assert delta.hits == 0                               # nothing stale served
    cold = BatchPathEnum().run(entry.graph, [(q.s, q.t, q.k) for q in reqs])
    assert [r.count for r in resp] == cold.counts.tolist()


def test_register_hot_swap_is_equivalent_to_mutate():
    """register() over a live id is the hot-swap path: v2 in, v1 entries
    out, answers immediately match a cold engine on v2."""
    g1 = erdos_renyi(40, 3.0, seed=6)
    reg = GraphRegistry()
    reg.register("social", g1)
    srv = HcPEServer(reg)
    reqs = [PathQueryRequest(uid=i, s=i, t=i + 5, k=3, graph_id="social")
            for i in range(5)]
    srv.serve(reqs)
    assert srv.engine.cache.tenant_len("social") > 0

    g2 = g1.with_edges(remove=g1.edge_list()[:4])
    reg.register("social", g2)
    assert srv.engine.cache.tenant_len("social") == 0
    resp, _ = srv.serve(reqs)
    cold = BatchPathEnum().run(g2, [(q.s, q.t, q.k) for q in reqs])
    assert [r.count for r in resp] == cold.counts.tolist()


def test_mutate_weighted_tenant_requires_new_weights():
    g = from_edges(4, np.array([[0, 1], [1, 2], [2, 3]]))
    reg = GraphRegistry()
    reg.register("w", g, edge_weights=np.ones(g.m))
    with pytest.raises(ValueError, match="edge_weights"):
        reg.mutate("w", add=np.array([[0, 2]]))
    entry = reg.mutate("w", add=np.array([[0, 2]]),
                       edge_weights=np.full(4, 2.0))
    assert entry.graph.m == 4 and entry.edge_weights.shape == (4,)
    with pytest.raises(ValueError, match="shape"):
        reg.mutate("w", remove=np.array([[0, 2]]),
                   edge_weights=np.ones(4))   # stale length for mutated graph


def test_async_server_crosses_mutation_epoch():
    """Mutation between async waves: the second wave's answers must match
    a cold engine on the mutated graph (no stale index via the cache)."""
    g = erdos_renyi(50, 3.0, seed=9)
    reg = GraphRegistry()
    reg.register("live", g)

    reqs = [PathQueryRequest(uid=i, s=i, t=i + 7, k=3, graph_id="live")
            for i in range(6)]

    async def drive():
        async with AsyncHcPEServer(reg, batch_window_ms=1.0) as srv:
            first = await srv.serve(reqs)
            entry = reg.mutate("live", add=np.array([[0, 49], [49, 0]]))
            second = await srv.serve(reqs)
            return first, second, entry.graph

    first, second, g2 = asyncio.run(drive())
    assert all(r.status == STATUS_OK for r in first + second)
    cold1 = BatchPathEnum().run(g, [(q.s, q.t, q.k) for q in reqs])
    cold2 = BatchPathEnum().run(g2, [(q.s, q.t, q.k) for q in reqs])
    assert [r.count for r in first] == cold1.counts.tolist()
    assert [r.count for r in second] == cold2.counts.tolist()


# ---------------------------------------------------------------------------
# live quota adjustment (the control plane's write path)
# ---------------------------------------------------------------------------

def test_set_cache_quota_live_sheds_to_new_bound():
    g = erdos_renyi(40, 3.0, seed=4)
    reg = GraphRegistry()
    reg.register("t", g)
    srv = HcPEServer(reg)
    reqs = [PathQueryRequest(uid=i, s=i, t=i + 9, k=3, graph_id="t")
            for i in range(6)]
    srv.serve(reqs)
    assert srv.engine.cache.tenant_len("t") == 6
    entry = reg.set_cache_quota("t", 2)
    assert entry.cache_quota == 2
    assert srv.engine.cache.tenant_len("t") == 2       # shed immediately
    reg.set_cache_quota("t", None)                     # unbound again
    srv.serve(reqs)
    assert srv.engine.cache.tenant_len("t") == 6


def test_set_max_pending_applies_at_next_admission():
    g = erdos_renyi(30, 3.0, seed=7)
    reg = GraphRegistry()
    reg.register("t", g)

    async def drive():
        async with AsyncHcPEServer(reg, batch_window_ms=1.0) as srv:
            reg.set_max_pending("t", 0)        # live clamp: admit nothing
            r1 = await srv.submit(PathQueryRequest(uid=1, s=0, t=5, k=3,
                                                   graph_id="t"))
            reg.set_max_pending("t", None)     # lift it
            r2 = await srv.submit(PathQueryRequest(uid=2, s=0, t=5, k=3,
                                                   graph_id="t"))
            return r1, r2

    r1, r2 = asyncio.run(drive())
    assert r1.status == STATUS_REJECTED_TENANT_QUOTA
    assert r2.status == STATUS_OK
