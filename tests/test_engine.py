"""End-to-end PathEnum engine behaviour vs the reference oracle."""
import numpy as np
import pytest

from repro.core import (PathEnum, build_index, build_index_jax, erdos_renyi,
                        enumerate_paths_idx, enumerate_paths_join, grid,
                        layered_dag, oracle, plan_query, power_law,
                        walk_count_dp)
from repro.core.baseline import generic_dfs


GRAPHS = {
    "er": erdos_renyi(64, 3.0, seed=0),
    "er_dense": erdos_renyi(40, 6.0, seed=1),
    "pl": power_law(96, 4.0, seed=2),
    "dag": layered_dag(4, 8, 3.0, seed=3),
    "grid": grid(5, 5),
}


def queries_for(g, count=3, seed=0, k_reach=None):
    """Random (s, t) pairs; with k_reach set, only pairs with distance ≤ 3
    (the paper's query-generation rule, §7.1) so results exist."""
    rng = np.random.default_rng(seed)
    out = []
    tries = 0
    while len(out) < count and tries < 500:
        tries += 1
        s, t = rng.integers(0, g.n, size=2)
        if s == t:
            continue
        if k_reach is not None:
            d = oracle.bfs_dist_np(g, int(s), 3, excluded=int(t))
            if d[int(t)] > 3:
                continue
        out.append((int(s), int(t)))
    return out


@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("k", [2, 4, 6])
def test_engine_matches_oracle(gname, k):
    g = GRAPHS[gname]
    eng = PathEnum(tau=50)  # low tau: exercise the full optimizer often
    for (s, t) in queries_for(g, 3, seed=k):
        want = oracle.enumerate_paths(g, s, t, k)
        out = eng.query(g, s, t, k, mode="auto")
        assert sorted(out.result.as_tuples()) == want
        assert out.result.count == len(want)


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_join_equals_dfs_any_cut(gname):
    g = GRAPHS[gname]
    k = 5
    eng = PathEnum()
    for (s, t) in queries_for(g, 2, seed=17):
        base = eng.query(g, s, t, k, mode="dfs")
        want = sorted(base.result.as_tuples())
        for cut in range(1, k):
            out = eng.query(g, s, t, k, mode="join", cut=cut)
            assert sorted(out.result.as_tuples()) == want, f"cut={cut}"


@pytest.mark.parametrize("backend", ["host", "device"])
def test_first_n_is_prefix_and_fast_path(backend):
    """first_n's exact-n trim is a backend contract: the device leg
    (Pallas interpret on CPU, DESIGN.md §9) must trim identically."""
    g = GRAPHS["dag"]
    s, t, k = g.n - 2, g.n - 1, 5
    eng = PathEnum(backend=backend)
    full = eng.query(g, s, t, k, mode="dfs")
    part = eng.query(g, s, t, k, mode="dfs", first_n=10)
    assert part.result.count == 10
    assert part.result.paths.shape[0] == 10
    assert not part.result.exhausted
    got = set(part.result.as_tuples())
    assert got.issubset(set(full.result.as_tuples()))
    # the trimmed prefix is the same across backends (same DFS order)
    host_part = PathEnum().query(g, s, t, k, mode="dfs", first_n=10)
    assert np.array_equal(part.result.paths, host_part.result.paths)


def test_first_n_on_join_path_matches_dfs():
    """Regression: first_n used to be dropped whenever the join plan ran —
    mode="join" (and auto→join) enumerated the full result set."""
    g = GRAPHS["er_dense"]
    eng = PathEnum()
    for (s, t) in queries_for(g, 3, seed=11):
        total = eng.count(g, s, t, 5, mode="dfs")
        full_set = set(eng.query(g, s, t, 5, mode="dfs").result.as_tuples())
        for n in (1, 7, total + 10):
            dfs = eng.query(g, s, t, 5, mode="dfs", first_n=n).result
            join = eng.query(g, s, t, 5, mode="join", first_n=n).result
            want = min(n, total)
            assert dfs.count == join.count == want
            assert join.paths.shape[0] == want
            assert join.exhausted == (total < n)
            assert set(join.as_tuples()).issubset(full_set)


def test_first_n_when_auto_planner_selects_join():
    g = GRAPHS["er_dense"]
    eng = PathEnum(tau=0.0)  # skip the preliminary fast path: plan via DP
    hit_join = False
    for (s, t) in queries_for(g, 8, seed=7):
        out = eng.query(g, s, t, 5, mode="auto", first_n=5)
        if out.plan.method == "join":
            hit_join = True
            total = eng.count(g, s, t, 5, mode="dfs")
            assert out.result.count == min(5, total)
            assert out.result.paths.shape[0] == out.result.count
    assert hit_join, "no auto query exercised the join plan"


def test_count_only_matches_materialized():
    g = GRAPHS["er_dense"]
    eng = PathEnum()
    for (s, t) in queries_for(g, 3, seed=5):
        a = eng.query(g, s, t, 5, mode="dfs", count_only=True)
        b = eng.query(g, s, t, 5, mode="dfs")
        assert a.result.count == b.result.count


def test_baseline_agrees_and_index_saves_edge_accesses():
    g = GRAPHS["pl"]
    eng = PathEnum()
    checked = 0
    for (s, t) in queries_for(g, 5, seed=2, k_reach=5):
        want = oracle.enumerate_paths(g, s, t, 5)
        base = generic_dfs(g, s, t, 5)
        out = eng.query(g, s, t, 5, mode="dfs")
        assert base.paths == want
        assert sorted(out.result.as_tuples()) == want
        if len(want) > 0:
            # Fig. 6 claim: the index accesses far fewer edges per step
            assert out.result.stats.edges_accessed <= base.stats.edges_accessed
            checked += 1
    assert checked > 0


def test_k_less_than_two_rejected():
    g = GRAPHS["er"]
    with pytest.raises(ValueError):
        PathEnum().query(g, 0, 1, 1)


def test_no_results_query_is_fast_and_empty():
    # target unreachable within k
    g = layered_dag(6, 4, 2.0, seed=9)
    s, t = g.n - 2, g.n - 1
    out = PathEnum().query(g, s, t, 2)  # needs >= 7 hops
    assert out.result.count == 0


def test_planner_cost_model_fields():
    g = GRAPHS["dag"]
    s, t = g.n - 2, g.n - 1
    idx = build_index(g, s, t, 5)
    plan = plan_query(idx, tau=-1.0)  # force the full estimator
    assert plan.used_full_estimator
    assert plan.t_dfs is not None and plan.t_join is not None
    dp = walk_count_dp(idx)
    assert dp.q_prefix[0] == 1.0  # C_0 = {s}
    # |Q| consistency: forward and backward totals agree
    assert np.isclose(dp.q_prefix[idx.k], dp.q_suffix[0])
