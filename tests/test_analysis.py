"""repro-lint: the analysis framework's own test suite (DESIGN.md §11).

Every rule is held to a paired-fixture contract: a known-bad snippet
under ``tests/fixtures/repro_lint/`` it must flag, and a known-good
twin it must not.  On top of that: suppression-comment semantics
(line, file, ``all``), the CLI's exit codes and JSON shape, the
"repo lints clean" end-to-end run, and (when mypy is installed) the
strict type gate over the annotated core.
"""
import json
import os
import shutil
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (ALL_PASSES, PASS_BY_NAME, lint_repo,
                            run_passes)

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "repro_lint"

# rule name -> fixture basename stem
RULE_FIXTURES = {
    "kernel-contract": "kernel_contract",
    "compat-boundary": "compat_boundary",
    "async-safety": "async_safety",
    "deadline-hook": "deadline_hook",
    "rank-cost-dtype": "rank_dtype",
    "docstring-coverage": "docstring_coverage",
    "doc-links": "doc_links",
    "unused-import": "unused_import",
    "mutable-default": "mutable_default",
    "bare-except": "bare_except",
}


def run_rule(rule, *paths):
    """One rule over explicit paths (scope patterns bypassed)."""
    return run_passes([PASS_BY_NAME[rule]], paths=list(paths))


# ---------------------------------------------------------------------------
# paired fixtures: every rule flags its bad twin, passes its good twin
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule", sorted(RULE_FIXTURES))
def test_bad_fixture_is_flagged(rule):
    report = run_rule(rule, FIXTURES / f"{RULE_FIXTURES[rule]}_bad.py")
    assert report.findings, f"{rule} missed its known-bad fixture"
    assert all(f.rule == rule for f in report.findings)
    assert report.exit_code() == 1


@pytest.mark.parametrize("rule", sorted(RULE_FIXTURES))
def test_good_fixture_is_clean(rule):
    report = run_rule(rule, FIXTURES / f"{RULE_FIXTURES[rule]}_good.py")
    assert not report.findings, (
        f"{rule} false-positived on its known-good fixture:\n"
        + "\n".join(f.render() for f in report.findings))
    assert report.exit_code(strict=True) == 0


def test_kernel_contract_bad_covers_every_clause():
    report = run_rule("kernel-contract",
                      FIXTURES / "kernel_contract_bad.py")
    messages = " ".join(f.message for f in report.findings)
    assert "interpret=" in messages
    assert "grid=" in messages
    assert "int64" in messages
    assert "PAD" in messages


def test_kernel_contract_ops_registration(tmp_path):
    """The ref-oracle clause keys off the ops.py basename."""
    target = tmp_path / "ops.py"
    shutil.copy(FIXTURES / "ops_registration_bad.py", target)
    report = run_rule("kernel-contract", target)
    messages = " ".join(f.message for f in report.findings)
    assert "ref.py oracle" in messages
    assert "forwarding interpret=" in messages
    # the same content under a non-ops basename is out of scope
    clean = run_rule("kernel-contract",
                     FIXTURES / "ops_registration_bad.py")
    assert not clean.findings


def test_deadline_hook_ignores_functions_without_deadline():
    report = run_rule("deadline-hook", FIXTURES / "deadline_hook_good.py")
    assert not report.findings


def test_parse_error_is_reported(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    report = run_rule("bare-except", bad)
    assert [f.rule for f in report.findings] == ["parse-error"]
    assert report.exit_code() == 1


# ---------------------------------------------------------------------------
# suppression comments
# ---------------------------------------------------------------------------


def test_line_suppressions_are_honored_and_counted():
    report = run_rule("unused-import", FIXTURES / "suppression_demo.py")
    # json (rule-named) and os (all) suppressed; sys survives
    assert len(report.findings) == 1
    assert "'sys'" in report.findings[0].message
    assert report.suppressed == 2


def test_file_suppression_silences_whole_file():
    report = run_rule("unused-import",
                      FIXTURES / "suppression_file_demo.py")
    assert not report.findings
    assert report.suppressed == 3


def test_suppression_is_rule_specific(tmp_path):
    src = tmp_path / "mod.py"
    src.write_text('"""Doc."""\n'
                   "import os  # repro-lint: disable=bare-except\n")
    report = run_rule("unused-import", src)
    assert len(report.findings) == 1  # wrong rule name: not silenced


# ---------------------------------------------------------------------------
# the repo itself lints clean (the CI gate, in-process)
# ---------------------------------------------------------------------------


def test_repo_lints_clean():
    report = lint_repo()
    assert not report.findings, (
        "repo must lint clean (python -m repro.analysis --strict):\n"
        + "\n".join(f.render() for f in report.findings))
    assert report.exit_code(strict=True) == 0


def test_registry_names_are_unique_and_catalogued():
    assert len(PASS_BY_NAME) == len(ALL_PASSES)
    design = (REPO / "DESIGN.md").read_text()
    for p in ALL_PASSES:
        assert p.scope, f"{p.name} declares no scope"
        assert p.description, f"{p.name} has no description"
        assert f"`{p.name}`" in design, (
            f"rule {p.name} missing from the DESIGN.md §11 catalogue")


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _cli(*argv):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        capture_output=True, text=True, env=env, cwd=REPO)


def test_cli_exits_nonzero_on_bad_fixture():
    proc = _cli("--rules", "unused-import",
                str(FIXTURES / "unused_import_bad.py"))
    assert proc.returncode == 1
    assert "[unused-import]" in proc.stdout


def test_cli_exits_zero_on_good_fixture():
    proc = _cli("--rules", "unused-import",
                str(FIXTURES / "unused_import_good.py"))
    assert proc.returncode == 0


def test_cli_json_output_shape():
    proc = _cli("--json", "--rules", "mutable-default",
                str(FIXTURES / "mutable_default_bad.py"))
    payload = json.loads(proc.stdout)
    assert payload["findings"]
    assert {"rule", "path", "line", "message", "severity"} <= set(
        payload["findings"][0])


def test_cli_list_rules():
    proc = _cli("--list-rules")
    assert proc.returncode == 0
    for rule in RULE_FIXTURES:
        assert rule in proc.stdout


def test_cli_unknown_rule_is_a_usage_error():
    proc = _cli("--rules", "no-such-rule")
    assert proc.returncode == 2
    assert "no-such-rule" in proc.stderr


# ---------------------------------------------------------------------------
# the typed-core gate (runs where mypy is installed, e.g. the CI lint job)
# ---------------------------------------------------------------------------

TYPED_MODULES = [
    "src/repro/core/batch.py",
    "src/repro/core/rank.py",
    "src/repro/kernels/ops.py",
    "src/repro/serving/__init__.py",
    "src/repro/serving/engine.py",
    "src/repro/serving/hcpe.py",
    "src/repro/serving/async_server.py",
    "src/repro/serving/registry.py",
]


def test_typed_core_passes_mypy_strict():
    pytest.importorskip("mypy")
    proc = subprocess.run(
        [sys.executable, "-m", "mypy", "--strict", *TYPED_MODULES],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
