"""Distributed runtime tests.

Multi-device behaviour (shard_map engine, compressed all-reduce, sharded
train step) needs >1 device, so those cases run in a subprocess with
``--xla_force_host_platform_device_count=8`` — the same pattern the
dry-run uses, kept out of this process so the rest of the suite sees one
device.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.distributed.compression import dequantize, quantize

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        import json
    """) + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=540,
                         env={**os.environ, "PYTHONPATH": SRC})
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = rng.standard_normal(1000).astype(np.float32) * 3.0
    q, scale = quantize(x)
    err = np.abs(dequantize(np.asarray(q), scale) - x).max()
    assert err <= float(scale) * 0.5 + 1e-6
    assert q.dtype == np.int8


def test_distributed_pathenum_matches_host():
    out = run_sub("""
        from repro.core import erdos_renyi, build_index, walk_count_dp
        from repro.distributed.engine import DistributedPathEnum
        from repro.compat import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        g = erdos_renyi(60, 4.0, seed=5)
        k = 4
        eng = DistributedPathEnum(mesh, g, k)
        qs = []
        rng = np.random.default_rng(0)
        while len(qs) < 8:
            s, t = rng.integers(0, g.n, 2)
            if s != t: qs.append((int(s), int(t)))
        qp, qsx, tot, (ds, dt) = eng.query_batch_stats(np.array(qs))
        host = []
        for (s, t) in qs:
            idx = build_index(g, s, t, k)
            dp = walk_count_dp(idx)
            host.append((dp.q_prefix.tolist(), dp.q_suffix.tolist(),
                         dp.q_total))
        ok = True
        for i, (hp, hs, ht) in enumerate(host):
            ok &= np.allclose(qp[i], hp, rtol=1e-5)
            ok &= np.allclose(qsx[i], hs, rtol=1e-5)
            ok &= abs(tot[i] - ht) < 1e-4 * max(1.0, ht)
        print(json.dumps({"ok": bool(ok)}))
    """)
    assert json.loads(out.strip().splitlines()[-1])["ok"]


def test_distributed_tenant_router_matches_host_per_graph():
    """DESIGN.md §8, distributed leg: tagged (graph_id, s, t) queries
    route per-graph across the data axis through one shared host engine,
    counts byte-identical to per-graph host runs, cache tenant-keyed."""
    out = run_sub("""
        from repro.core import BatchPathEnum, PathEnum, erdos_renyi, power_law
        from repro.distributed.engine import (DistributedPathEnum,
                                              DistributedTenantRouter)
        from repro.compat import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        k = 4
        g_a = erdos_renyi(50, 4.0, seed=5)
        g_b = power_law(60, 5.0, seed=9)
        engine = BatchPathEnum()
        router = DistributedTenantRouter(
            {"a": DistributedPathEnum(mesh, g_a, k),
             "b": DistributedPathEnum(mesh, g_b, k)}, engine=engine)
        rng = np.random.default_rng(1)
        tagged = []
        while len(tagged) < 10:
            s, t = rng.integers(0, 50, 2)
            if s != t:
                tagged.append((("a", "b")[len(tagged) % 2], int(s), int(t)))
        items, outputs = router.enumerate(tagged)
        seq = PathEnum()
        graphs = {"a": g_a, "b": g_b}
        ok = all(it.result.count == seq.count(graphs[gid], s, t, k)
                 for (gid, s, t), it in zip(tagged, items))
        unknown_raises = False
        try:
            router.enumerate([("ghost", 0, 1)])
        except KeyError:
            unknown_raises = True
        print(json.dumps({
            "ok": bool(ok),
            "tenants": sorted(outputs),
            "tenant_entries": [engine.cache.tenant_len("a") > 0,
                               engine.cache.tenant_len("b") > 0],
            "unknown_raises": unknown_raises}))
    """)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["ok"]
    assert rec["tenants"] == ["a", "b"]
    assert rec["tenant_entries"] == [True, True]
    assert rec["unknown_raises"]


def test_compressed_psum_close_to_exact():
    out = run_sub("""
        from repro.distributed.compression import make_compressed_grad_fn
        from repro.configs.base import ArchConfig
        from repro.models import init_params
        from repro.training.step import make_loss_fn
        cfg = ArchConfig(name="t", family="dense", num_layers=1, d_model=32,
                         num_heads=2, kv_heads=1, d_ff=64, vocab=64,
                         head_dim=16, attn_chunk=8, tie_embeddings=True)
        params = init_params(cfg, jax.random.PRNGKey(0))
        loss_fn = make_loss_fn(cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 64)
        batch = {"tokens": toks, "labels": toks}
        from repro.compat import make_mesh
        mesh = make_mesh((8,), ("data",))
        f = make_compressed_grad_fn(loss_fn, mesh)
        loss, grads = f(params, batch)
        # exact reference
        (l2, _), g2 = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        rel = []
        for a, b in zip(jax.tree.leaves(grads), jax.tree.leaves(g2)):
            denom = np.abs(np.asarray(b)).max() + 1e-9
            rel.append(float(np.abs(np.asarray(a) - np.asarray(b)).max()
                       / denom))
        print(json.dumps({"loss_close": bool(abs(float(loss) - float(l2))
                                             < 1e-4),
                          "max_rel": max(rel)}))
    """)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["loss_close"]
    assert rec["max_rel"] < 0.05  # int8 grid: ~1/127 per-tensor


def test_sharded_train_step_runs_and_matches_single_device():
    out = run_sub("""
        from repro.configs.base import ArchConfig
        from repro.models import init_params
        from repro.optim import adamw
        from repro.training.step import make_train_step
        from repro.distributed import sharding as S
        cfg = ArchConfig(name="t", family="dense", num_layers=2, d_model=64,
                         num_heads=4, kv_heads=2, d_ff=128, vocab=128,
                         head_dim=16, attn_chunk=16)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init(params)
        ocfg = adamw.OptimizerConfig(total_steps=5)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 128)
        batch = {"tokens": toks, "labels": toks}
        ts = make_train_step(cfg, ocfg)

        from repro.compat import make_mesh, set_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        rules = S.ShardingRules(mesh)
        pspecs = S.tree_specs(params, rules.param_spec)
        psh = S.tree_shardings(mesh, pspecs)
        osh = S.tree_shardings(mesh, S.opt_shardings(pspecs, opt))
        bsh = S.tree_shardings(mesh, S.tree_specs(batch, rules.batch_spec))
        with set_mesh(mesh):
            jf = jax.jit(ts, in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, None))
            p1, o1, m1 = jf(params, opt, batch)
        p2, o2, m2 = jax.jit(ts)(params, opt, batch)
        diffs = [float(np.abs(np.asarray(a, np.float32)
                              - np.asarray(b, np.float32)).max())
                 for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))]
        print(json.dumps({"loss_diff": abs(float(m1["loss"])
                                           - float(m2["loss"])),
                          "max_param_diff": max(diffs)}))
    """)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["loss_diff"] < 1e-4
    assert rec["max_param_diff"] < 1e-3


def test_sharding_rules_divisibility_properties():
    """Every spec must name axes whose sizes divide the dim they shard."""
    out = run_sub("""
        from repro.configs import ARCH_IDS, get_arch
        from repro.distributed import sharding as S
        from repro.launch import specs as sp
        from repro.compat import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        rules = S.ShardingRules(mesh)
        bad = []
        for arch in ARCH_IDS:
            cfg = get_arch(arch).reduced()
            t = sp.param_specs(cfg, dtype=jnp.float32)
            specs = S.tree_specs(t, rules.param_spec)
            leaves_t = jax.tree.leaves(t)
            leaves_s = jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec))
            for leaf, spec in zip(leaves_t, leaves_s):
                for dim, ax in zip(leaf.shape, tuple(spec)):
                    if ax is None: continue
                    size = 1
                    for a in ([ax] if isinstance(ax, str) else ax):
                        size *= mesh.shape[a]
                    if dim % size != 0:
                        bad.append((arch, leaf.shape, str(spec)))
        print(json.dumps({"bad": bad[:5], "count": len(bad)}))
    """)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["count"] == 0, rec["bad"]


def test_seq_shard_activations_numerically_identical():
    """The SP lever (§Perf) only changes layout, never math."""
    out = run_sub("""
        import dataclasses
        from repro.configs.base import ArchConfig
        from repro.models import init_params
        from repro.optim import adamw
        from repro.training.step import make_train_step
        from repro.distributed import sharding as S
        base = ArchConfig(name="t", family="dense", num_layers=2, d_model=64,
                          num_heads=4, kv_heads=2, d_ff=128, vocab=128,
                          head_dim=16, attn_chunk=16)
        sp = dataclasses.replace(base, seq_shard_activations=True)
        params = init_params(base, jax.random.PRNGKey(0))
        opt = adamw.init(params)
        ocfg = adamw.OptimizerConfig(total_steps=5)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 128)
        batch = {"tokens": toks, "labels": toks}
        from repro.compat import make_mesh, set_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        rules = S.ShardingRules(mesh)
        pspecs = S.tree_specs(params, rules.param_spec)
        psh = S.tree_shardings(mesh, pspecs)
        osh = S.tree_shardings(mesh, S.opt_shardings(pspecs, opt))
        bsh = S.tree_shardings(mesh, S.tree_specs(batch, rules.batch_spec))
        with set_mesh(mesh):
            losses = []
            for cfg in (base, sp):
                ts = make_train_step(cfg, ocfg)
                jf = jax.jit(ts, in_shardings=(psh, osh, bsh),
                             out_shardings=(psh, osh, None))
                _, _, m = jf(params, opt, batch)
                losses.append(float(m["loss"]))
        print(json.dumps({"diff": abs(losses[0] - losses[1])}))
    """)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["diff"] < 1e-5
