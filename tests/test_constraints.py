"""Appendix-E constrained variants vs post-filtered oracle."""
import numpy as np
import pytest

from repro.core import PathEnum, build_index, erdos_renyi, oracle
from repro.core.constraints import (AccumulativeValue, ActionSequence,
                                    edge_predicate_mask)


def edge_weight_map(g, weights):
    return {(int(a), int(b)): w
            for a, b, w in zip(g.esrc, g.edst, weights)}


@pytest.mark.parametrize("seed", [0, 1])
def test_accumulative_constraint_matches_postfilter(seed):
    rng = np.random.default_rng(seed)
    g = erdos_renyi(40, 4.0, seed=seed + 20)
    weights = rng.uniform(0.0, 10.0, size=g.m)
    wmap = edge_weight_map(g, weights)
    s, t, k = 0, g.n - 1, 5
    thresh = 18.0

    want = []
    for p in oracle.enumerate_paths(g, s, t, k):
        beta = sum(wmap[(a, b)] for a, b in zip(p, p[1:]))
        if beta >= thresh:
            want.append(p)

    cons = AccumulativeValue(weights=weights, op=np.add, init=0.0,
                             accept=lambda b: b >= thresh)
    eng = PathEnum()
    got = eng.query(g, s, t, k, mode="dfs", constraint=cons)
    assert sorted(got.result.as_tuples()) == sorted(want)
    # join mode applies the same constraint at join time
    got_j = eng.query(g, s, t, k, mode="join", cut=2, constraint=cons)
    assert sorted(got_j.result.as_tuples()) == sorted(want)


def test_accumulative_monotone_pruning_is_safe():
    rng = np.random.default_rng(3)
    g = erdos_renyi(40, 4.0, seed=30)
    weights = rng.uniform(0.0, 5.0, size=g.m)
    wmap = edge_weight_map(g, weights)
    s, t, k = 0, g.n - 1, 5
    upper = 10.0
    want = []
    for p in oracle.enumerate_paths(g, s, t, k):
        beta = sum(wmap[(a, b)] for a, b in zip(p, p[1:]))
        if beta <= upper:
            want.append(p)
    cons = AccumulativeValue(weights=weights, op=np.add, init=0.0,
                             accept=lambda b: b <= upper,
                             monotone_upper=upper)
    got = PathEnum().query(g, s, t, k, mode="dfs", constraint=cons)
    assert sorted(got.result.as_tuples()) == sorted(want)


@pytest.mark.parametrize("seed", [0, 1])
def test_action_sequence_dfa(seed):
    rng = np.random.default_rng(seed)
    g = erdos_renyi(36, 4.0, seed=seed + 50)
    labels = rng.integers(0, 2, size=g.m)  # two actions: 0, 1
    lmap = edge_weight_map(g, labels)
    s, t, k = 0, g.n - 1, 4
    # DFA: accept label sequences matching 0*1* (all 0s then all 1s)
    # states: 0 = "in zeros", 1 = "in ones"; A[state][label]
    A = np.array([[0, 1], [-1, 1]])
    accepting = np.array([True, True])

    def seq_ok(p):
        st = 0
        for a, b in zip(p, p[1:]):
            lab = int(lmap[(a, b)])
            st = A[st][lab]
            if st < 0:
                return False
        return accepting[st]

    want = [p for p in oracle.enumerate_paths(g, s, t, k) if seq_ok(p)]
    cons = ActionSequence(A=A, labels=labels, start=0, accepting=accepting)
    eng = PathEnum()
    got = eng.query(g, s, t, k, mode="dfs", constraint=cons)
    assert sorted(got.result.as_tuples()) == sorted(want)
    got_j = eng.query(g, s, t, k, mode="join", cut=2, constraint=cons)
    assert sorted(got_j.result.as_tuples()) == sorted(want)


@pytest.mark.parametrize("mode,cut", [("dfs", None), ("join", 2)])
def test_accumulative_zero_weight_edges(mode, cut):
    """Zero-weight edges: accumulation must be a no-op on them — a
    threshold predicate over a mostly-zero weight vector keeps exactly
    the paths whose few weighted edges clear it."""
    rng = np.random.default_rng(9)
    g = erdos_renyi(36, 4.0, seed=90)
    weights = np.where(rng.random(g.m) < 0.7, 0.0,
                       rng.uniform(1.0, 3.0, size=g.m))
    wmap = edge_weight_map(g, weights)
    s, t, k = 0, g.n - 1, 5
    want = [p for p in oracle.enumerate_paths(g, s, t, k)
            if sum(wmap[(a, b)] for a, b in zip(p, p[1:])) >= 2.0]
    cons = AccumulativeValue(weights=weights, op=np.add, init=0.0,
                             accept=lambda b: b >= 2.0)
    got = PathEnum().query(g, s, t, k, mode=mode, cut=cut, constraint=cons)
    assert sorted(got.result.as_tuples()) == sorted(want)


@pytest.mark.parametrize("mode,cut", [("dfs", None), ("join", 2)])
def test_accumulative_float_tie_at_threshold(mode, cut):
    """Exact float ties on the accept boundary: integer-valued float
    weights make path sums land exactly ON the threshold, and >= must
    keep them — both in the engine's vectorized accumulation and the
    python-sum post-filter, which agree bit-for-bit on these values."""
    rng = np.random.default_rng(10)
    g = erdos_renyi(36, 4.0, seed=91)
    weights = rng.integers(0, 3, size=g.m).astype(np.float64)
    wmap = edge_weight_map(g, weights)
    s, t, k = 0, g.n - 1, 5
    thresh = 4.0   # hit exactly by many 4-edge paths of small-int weights
    all_paths = oracle.enumerate_paths(g, s, t, k)
    sums = {p: sum(wmap[(a, b)] for a, b in zip(p, p[1:]))
            for p in all_paths}
    assert any(v == thresh for v in sums.values())   # ties actually occur
    want = [p for p in all_paths if sums[p] >= thresh]
    cons = AccumulativeValue(weights=weights, op=np.add, init=0.0,
                             accept=lambda b: b >= thresh)
    got = PathEnum().query(g, s, t, k, mode=mode, cut=cut, constraint=cons)
    assert sorted(got.result.as_tuples()) == sorted(want)


def test_accumulative_init_and_op_overrides():
    """Non-default ``init``/``op``: max-accumulation (bottleneck width)
    seeded from -inf, and multiplicative accumulation seeded from 1.0,
    both against the oracle post-filter."""
    rng = np.random.default_rng(11)
    g = erdos_renyi(32, 4.0, seed=92)
    s, t, k = 0, g.n - 1, 5
    all_paths = oracle.enumerate_paths(g, s, t, k)

    widths = rng.uniform(0.5, 4.0, size=g.m)
    wmap = edge_weight_map(g, widths)
    want_max = [p for p in all_paths
                if max(wmap[(a, b)] for a, b in zip(p, p[1:])) >= 3.0]
    cons_max = AccumulativeValue(weights=widths, op=np.maximum,
                                 init=-np.inf, accept=lambda b: b >= 3.0)
    got = PathEnum().query(g, s, t, k, mode="dfs", constraint=cons_max)
    assert sorted(got.result.as_tuples()) == sorted(want_max)

    # multiplicative: probabilities along the path, keep the likely ones
    probs = rng.uniform(0.5, 1.0, size=g.m)
    pmap = edge_weight_map(g, probs)
    want_mul = []
    for p in all_paths:
        prod = 1.0
        for a, b in zip(p, p[1:]):
            prod = prod * pmap[(a, b)]
        if prod >= 0.25:
            want_mul.append(p)
    cons_mul = AccumulativeValue(weights=probs, op=np.multiply, init=1.0,
                                 accept=lambda b: b >= 0.25)
    got = PathEnum().query(g, s, t, k, mode="dfs", constraint=cons_mul)
    assert sorted(got.result.as_tuples()) == sorted(want_mul)


def test_edge_predicate_matches_subgraph_oracle():
    g = erdos_renyi(40, 4.0, seed=77)
    pred = lambda u, v: (u + v) % 3 != 0
    mask = edge_predicate_mask(g, pred)
    s, t, k = 0, g.n - 1, 5
    want = oracle.enumerate_paths(g, s, t, k,
                                  edge_pred=lambda a, b: (a + b) % 3 != 0)
    got = PathEnum().query(g, s, t, k, mode="dfs", edge_mask=mask)
    assert sorted(got.result.as_tuples()) == sorted(want)
