"""Ranked (any-k) enumeration contracts (DESIGN.md §10).

The oracle-fuzz suite (test_oracle_fuzz.py) pins the full ordered
sequence to the rank-order oracle; this file pins everything else the
``order=`` contract promises:

  * **anytime prefix-optimality** — any truncation (``first_n`` or a
    deadline) of a ranked run is exactly a prefix of the full ranked
    sequence, on every backend, under both orders (seeded sweep + a
    hypothesis layer);
  * **unranked canonicalization** — ``order=None`` exhausted results are
    the same (length, lex) canonical sequence on every backend, so plan
    choice never leaks into result order (the PR-6 regression fix);
  * **validation** — ``make_rank_spec`` input checking, the
    order × constraint exclusion, registry ``edge_weights`` checking;
  * **serving** — order threading through both front-ends, the
    ``STATUS_REJECTED_NO_WEIGHTS`` admission path, and async EDF
    truncations returning rank-optimal prefixes.
"""
import asyncio
import time

import numpy as np
import pytest

from repro.core import (BatchPathEnum, PathEnum, build_index,
                        enumerate_paths_idx, enumerate_paths_join,
                        erdos_renyi, from_edges, make_rank_spec, oracle)
from repro.core.constraints import AccumulativeValue
from repro.core.graph import PAD
from repro.serving import (AsyncHcPEServer, GraphRegistry, HcPEServer,
                           PathQueryRequest, STATUS_OK,
                           STATUS_REJECTED_NO_WEIGHTS)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

ORDERS = ("hops", "weight")


def _case(seed):
    """One random digraph + query with tie-heavy integer weights."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(6, 26))
    m = max(1, int(n * float(rng.choice([1.0, 2.0, 3.5]))))
    g = from_edges(n, rng.integers(0, n, size=(m, 2)))
    s, t = map(int, rng.choice(n, 2, replace=False))
    k = int(rng.integers(3, 7))
    w = rng.integers(0, 4, size=g.m).astype(np.float64)
    return g, s, t, k, w


def _runners(idx, k):
    """Every ranked backend as (label, fn(order, weights, **kw))."""
    return [
        ("dfs", lambda **kw: enumerate_paths_idx(idx, **kw)),
        ("device", lambda **kw: enumerate_paths_idx(idx, backend="device",
                                                    **kw)),
        ("join", lambda **kw: enumerate_paths_join(idx, cut=max(1, k // 2),
                                                   **kw)),
    ]


# ---------------------------------------------------------------------------
# input validation
# ---------------------------------------------------------------------------

def test_make_rank_spec_validation():
    assert make_rank_spec(None, None) is None
    assert make_rank_spec("hops", None).order == "hops"
    spec = make_rank_spec("weight", np.ones(3))
    assert spec.is_weight and spec.weights.dtype == np.float64
    with pytest.raises(ValueError):
        make_rank_spec("cheapest", None)          # unknown order string
    with pytest.raises(ValueError):
        make_rank_spec("weight", None)            # weight order needs weights
    with pytest.raises(ValueError):
        make_rank_spec("weight", np.array([1.0, -0.5]))   # negative
    with pytest.raises(ValueError):
        make_rank_spec("weight", np.array([1.0, np.nan]))  # non-finite
    with pytest.raises(ValueError):
        make_rank_spec("weight", np.ones((2, 2)))          # not 1-D


def test_order_and_constraint_are_mutually_exclusive():
    g, s, t, k, w = _case(0)
    idx = build_index(g, s, t, k)
    cons = AccumulativeValue(weights=w, op=np.add, init=0.0,
                             accept=lambda b: True)
    with pytest.raises(ValueError, match="constraint"):
        enumerate_paths_idx(idx, order="hops", constraint=cons)
    with pytest.raises(ValueError, match="constraint"):
        enumerate_paths_join(idx, cut=1, order="weight", weights=w,
                             constraint=cons)


def test_registry_edge_weights_shape_validation():
    g = erdos_renyi(12, 2.0, seed=1)
    reg = GraphRegistry()
    with pytest.raises(ValueError, match="edge_weights"):
        reg.register("g", g, edge_weights=np.ones(g.m + 1))
    entry = reg.register("g", g, edge_weights=np.ones(g.m, dtype=np.float32))
    assert entry.edge_weights.dtype == np.float64    # canonical accumulation


# ---------------------------------------------------------------------------
# anytime prefix-optimality: first_n is the top-n, on every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("order", ORDERS)
def test_first_n_is_rank_optimal_prefix(seed, order):
    g, s, t, k, w = _case(100 + seed)
    weights = w if order == "weight" else None
    idx = build_index(g, s, t, k)
    for label, run in _runners(idx, k):
        full = run(order=order, weights=weights)
        assert full.exhausted
        total = full.count
        seq = full.as_tuples()
        for n in {0, 1, 2, max(0, total - 1), total, total + 5}:
            got = run(order=order, weights=weights, first_n=n)
            assert got.as_tuples() == seq[:n], (label, n, seed)
            # exhausted=False iff the cut actually bit: n results were
            # reached (first_n=0 on an empty run still exhausts)
            assert got.exhausted == (max(n, 1) > total), (label, n, seed)


@pytest.mark.parametrize("order", ORDERS)
def test_batch_first_n_is_rank_optimal_prefix(order):
    g, s, t, k, w = _case(7)
    weights = w if order == "weight" else None
    eng = BatchPathEnum()
    full = eng.run(g, [(s, t, k)], count_only=False, order=order,
                   weights=weights).items[0].result
    for mode in ("dfs", "join"):
        got = BatchPathEnum().run(g, [(s, t, k)], count_only=False, mode=mode,
                                  first_n=2, order=order,
                                  weights=weights).items[0].result
        assert got.as_tuples() == full.as_tuples()[:2]


# ---------------------------------------------------------------------------
# anytime prefix-optimality: every deadline cut is a ranked prefix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("order", ORDERS)
def test_expired_deadline_returns_empty_unexhausted(order):
    g, s, t, k, w = _case(11)
    weights = w if order == "weight" else None
    idx = build_index(g, s, t, k)
    for label, run in _runners(idx, k):
        got = run(order=order, weights=weights,
                  deadline=time.perf_counter() - 1.0)
        assert got.count == 0 and not got.exhausted, label


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("order", ORDERS)
def test_mid_run_deadline_is_rank_optimal_prefix(seed, order):
    """Whatever instant the budget expires at, the emitted paths must be
    exactly the best-ranked prefix — never a mid-rank subset."""
    rng = np.random.default_rng(300 + seed)
    g = erdos_renyi(40, 4.0, seed=300 + seed)
    s, t = map(int, rng.choice(g.n, 2, replace=False))
    k = 7
    w = rng.integers(0, 4, size=g.m).astype(np.float64)
    weights = w if order == "weight" else None
    idx = build_index(g, s, t, k)
    full = enumerate_paths_idx(idx, order=order, weights=weights).as_tuples()
    for label, run in _runners(idx, k):
        for budget in (0.0005, 0.002, 0.01):
            got = run(order=order, weights=weights,
                      deadline=time.perf_counter() + budget)
            seq = got.as_tuples()
            assert seq == full[:len(seq)], (label, budget)
            if got.exhausted:
                assert len(seq) == len(full), (label, budget)


# ---------------------------------------------------------------------------
# unranked canonicalization: order=None exhausted output is plan-invariant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(10))
def test_unranked_exhausted_order_is_canonical_across_backends(seed):
    """Regression (PR 6): pre-canonicalization, dfs/join/device emitted
    the same *set* in different orders, so downstream pagination flapped
    with the optimizer's plan choice.  Exhausted unranked results are now
    (length, lex)-sorted everywhere."""
    g, s, t, k, w = _case(400 + seed)
    idx = build_index(g, s, t, k)
    want = sorted(oracle.enumerate_paths(g, s, t, k),
                  key=lambda p: (len(p), p))
    assert enumerate_paths_idx(idx).as_tuples() == want
    assert enumerate_paths_idx(idx, backend="device").as_tuples() == want
    for cut in {1, max(1, k // 2), k - 1}:
        assert enumerate_paths_join(idx, cut=cut).as_tuples() == want
    for mode in ("auto", "dfs", "join"):
        out = BatchPathEnum().run(g, [(s, t, k)], count_only=False, mode=mode)
        assert out.items[0].result.as_tuples() == want


# ---------------------------------------------------------------------------
# PathEnum front door
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ("dfs", "join"))
@pytest.mark.parametrize("order", ORDERS)
def test_pathenum_query_order_threading(mode, order):
    g, s, t, k, w = _case(21)
    weights = w if order == "weight" else None
    want = oracle.enumerate_paths(g, s, t, k, order=order, weights=weights)
    out = PathEnum().query(g, s, t, k, mode=mode, order=order,
                           weights=weights)
    assert out.result.as_tuples() == want
    top = PathEnum().query(g, s, t, k, mode=mode, first_n=3, order=order,
                           weights=weights)
    assert top.result.as_tuples() == want[:3]


# ---------------------------------------------------------------------------
# serving: sync front-end
# ---------------------------------------------------------------------------

def _resp_paths(resp):
    if resp.paths is None:
        return []
    return [tuple(int(x) for x in row if x != PAD) for row in resp.paths]


def _two_tenant_registry(seed):
    g, s, t, k, w = _case(600 + seed)
    reg = GraphRegistry()
    reg.register("weighted", g, edge_weights=w)
    reg.register("plain", g)                      # no weights registered
    return reg, g, s, t, k, w


def test_sync_server_ranked_and_no_weights_rejection():
    reg, g, s, t, k, w = _two_tenant_registry(0)
    reqs = [
        PathQueryRequest(uid=0, s=s, t=t, k=k, count_only=False,
                         graph_id="weighted", order="weight"),
        PathQueryRequest(uid=1, s=s, t=t, k=k, count_only=False,
                         graph_id="plain", order="weight"),
        PathQueryRequest(uid=2, s=s, t=t, k=k, count_only=False,
                         graph_id="plain", order="hops"),
    ]
    resps, _ = HcPEServer(reg).serve(reqs)
    want_w = oracle.enumerate_paths(g, s, t, k, order="weight", weights=w)
    assert resps[0].status == STATUS_OK
    assert _resp_paths(resps[0]) == want_w
    # weight rank against a weightless tenant: admission rejection,
    # never an exception, zero results
    assert resps[1].status == STATUS_REJECTED_NO_WEIGHTS
    assert resps[1].count == 0
    # hops rank needs no weights
    assert resps[2].status == STATUS_OK
    assert _resp_paths(resps[2]) == oracle.enumerate_paths(g, s, t, k,
                                                           order="hops")


def test_sync_server_groups_by_order():
    """Same (graph, count_only, first_n) but different order must not
    share an engine batch — the 4-tuple GroupKey keeps them apart."""
    reg, g, s, t, k, w = _two_tenant_registry(1)
    reqs = [
        PathQueryRequest(uid=0, s=s, t=t, k=k, count_only=False,
                         graph_id="weighted", order="weight"),
        PathQueryRequest(uid=1, s=s, t=t, k=k, count_only=False,
                         graph_id="weighted", order="hops"),
        PathQueryRequest(uid=2, s=s, t=t, k=k, count_only=False,
                         graph_id="weighted"),
    ]
    resps, _ = HcPEServer(reg).serve(reqs)
    assert _resp_paths(resps[0]) == oracle.enumerate_paths(
        g, s, t, k, order="weight", weights=w)
    assert _resp_paths(resps[1]) == oracle.enumerate_paths(
        g, s, t, k, order="hops")
    assert oracle.paths_as_set(_resp_paths(resps[2])) == \
        oracle.paths_as_set(oracle.enumerate_paths(g, s, t, k))


# ---------------------------------------------------------------------------
# serving: async front-end
# ---------------------------------------------------------------------------

def test_async_server_rejects_unknown_order_string():
    g = erdos_renyi(10, 2.0, seed=2)

    async def drive():
        async with AsyncHcPEServer(g) as srv:
            with pytest.raises(ValueError):
                await srv.submit(PathQueryRequest(uid=0, s=0, t=1, k=3,
                                                  order="fastest"))

    asyncio.run(drive())


def test_async_server_ranked_serving_and_admission():
    reg, g, s, t, k, w = _two_tenant_registry(2)
    want_w = oracle.enumerate_paths(g, s, t, k, order="weight", weights=w)

    async def drive():
        async with AsyncHcPEServer(reg, batch_window_ms=1.0) as srv:
            ok, rej, topn = await asyncio.gather(
                srv.submit(PathQueryRequest(
                    uid=0, s=s, t=t, k=k, count_only=False,
                    graph_id="weighted", order="weight")),
                srv.submit(PathQueryRequest(
                    uid=1, s=s, t=t, k=k, count_only=False,
                    graph_id="plain", order="weight")),
                srv.submit(PathQueryRequest(
                    uid=2, s=s, t=t, k=k, count_only=False, first_n=2,
                    graph_id="weighted", order="weight")),
            )
            return ok, rej, topn, srv.stats.rejected_no_weights

    ok, rej, topn, n_rej = asyncio.run(drive())
    assert ok.status == STATUS_OK and _resp_paths(ok) == want_w
    assert rej.status == STATUS_REJECTED_NO_WEIGHTS and rej.count == 0
    assert n_rej == 1
    # EDF front-end under order: first_n is the top-n, not "some n"
    assert _resp_paths(topn) == want_w[:2]


# ---------------------------------------------------------------------------
# hypothesis layer: prefix-optimality as a property
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @st.composite
    def ranked_cut(draw):
        n = draw(st.integers(5, 18))
        m = draw(st.integers(2, 3 * n))
        edges = draw(st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=m, max_size=m))
        g = from_edges(n, np.array(edges, dtype=np.int64))
        s = draw(st.integers(0, n - 1))
        t = draw(st.integers(0, n - 1).filter(lambda x: x != s))
        k = draw(st.integers(2, 6))
        order = draw(st.sampled_from(["hops", "weight"]))
        weights = None
        if order == "weight":
            weights = np.array(draw(st.lists(
                st.sampled_from([0.0, 1.0, 1.5]),
                min_size=g.m, max_size=g.m)), dtype=np.float64)
        first_n = draw(st.integers(0, 12))
        return g, s, t, k, order, weights, first_n

    @pytest.mark.slow
    @settings(max_examples=60, deadline=None)
    @given(ranked_cut())
    def test_hypothesis_any_first_n_cut_is_prefix(case):
        g, s, t, k, order, weights, first_n = case
        idx = build_index(g, s, t, k)
        full = enumerate_paths_idx(idx, order=order,
                                   weights=weights).as_tuples()
        for label, run in _runners(idx, k):
            got = run(order=order, weights=weights, first_n=first_n)
            assert got.as_tuples() == full[:first_n], label
            assert got.exhausted == (max(first_n, 1) > len(full)), label
