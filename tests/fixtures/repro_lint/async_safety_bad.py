"""Known-bad serving module: blocking calls inside async def."""
import time


class Server:
    async def submit(self, req):
        time.sleep(0.1)  # blocks the event loop
        out = self.engine.run([req])  # enumeration on the loop
        out.arr.block_until_ready()  # device sync on the loop
        return out
