"""Known-bad module: a bare except swallowing everything."""


def load(path):
    try:
        return path.read_text()
    except:  # noqa: E722 — the rule under test
        return None
