"""Known-bad rank-cost module: narrow float dtypes in cost arithmetic."""
import numpy as np


def path_costs(weights, paths):
    acc = np.zeros(len(paths), dtype=np.float32)  # attribute spelling
    for col in paths.T:
        acc += weights[col].astype("float16")  # string spelling
    return acc
