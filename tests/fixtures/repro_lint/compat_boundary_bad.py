"""Known-bad module: spells a jax version-skew symbol directly."""
import jax


def shard(f, mesh, specs):
    return jax.shard_map(f, mesh=mesh, in_specs=specs, out_specs=specs)
