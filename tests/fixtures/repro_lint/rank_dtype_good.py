"""Known-good rank-cost module: float64 end to end, int32 untouched."""
import numpy as np


def path_costs(weights, paths):
    acc = np.zeros(len(paths), dtype=np.float64)
    idx = paths.astype(np.int32)  # integer dtypes are out of scope
    for col in idx.T:
        acc += weights[col].astype("float64")
    return acc
