"""Known-good module: every anchored section exists.

See DESIGN.md §1 and the range DESIGN.md §6-7.
"""
