"""Known-good serving module: the sanctioned async idioms."""
import asyncio


class Server:
    async def submit(self, req):
        await asyncio.sleep(0.1)
        # bound method passed as an argument, not called on the loop
        out = await asyncio.to_thread(self.engine.run, [req])
        return out

    def run_sync(self, req):
        # blocking calls outside async def are out of scope
        return self.engine.run([req])
