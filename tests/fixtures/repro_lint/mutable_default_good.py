"""Known-good module: None defaults, constructed inside."""


def collect(item, bucket=None):
    bucket = [] if bucket is None else bucket
    bucket.append(item)
    return bucket


def scale(x, factor=1.0, label=""):
    return x * factor, label
