"""Known-bad module: anchors a DESIGN.md section that does not exist.

See DESIGN.md §99 for the rationale.
"""
