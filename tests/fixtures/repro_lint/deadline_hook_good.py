"""Known-good driver shapes for the deadline-hook rule."""
import time


def drive(chunks, stats, deadline=None):
    results = []
    for chunk in chunks:
        if deadline is not None and time.monotonic() >= deadline:
            break
        stats.chunks += 1
        for row in chunk:  # inner loop rides the outer check
            stats.results += 1
            results.append(row)
    return results


def drive_expired_idiom(chunks, stats, deadline=None):
    def _expired():
        return deadline is not None and time.monotonic() >= deadline

    results = []
    for chunk in chunks:
        if _expired():
            break
        stats.chunks += 1
        results.extend(chunk)
    return results


def no_deadline_param(chunks, stats):
    # functions without a deadline parameter are out of scope
    for chunk in chunks:
        stats.chunks += 1
    return stats
