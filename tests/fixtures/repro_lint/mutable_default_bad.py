"""Known-bad module: mutable default arguments."""


def collect(item, bucket=[]):
    bucket.append(item)
    return bucket


def tally(key, counts=dict(), *, seen={}):
    counts[key] = counts.get(key, 0) + 1
    seen[key] = True
    return counts
