"""Known-good audited module, fully documented (DESIGN.md §7)."""


class Server:
    """A documented public class."""

    def submit(self, req):
        """A documented public method."""
        return req

    def _internal(self, req):
        return req  # private slots are out of scope


def helper(x):
    """A documented public function."""
    return x
