"""Known-bad kernel module: violates every kernel-contract clause."""
import jax.numpy as jnp
from jax.experimental import pallas as pl

PAD = 0  # wrong sentinel: contract pins -1


def kernel_body(x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(jnp.int64)  # wide dtype


def launch(x):
    # no grid=, no interpret=
    return pl.pallas_call(kernel_body, out_shape=x)(x)
