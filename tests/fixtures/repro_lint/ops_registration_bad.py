"""Known-bad ops.py shape: Pallas dispatch without the ref oracle.

Linted under the basename ``ops.py`` semantics only when named so; the
test copies this file to a temp ``ops.py`` before aiming the rule.
"""
from .kernel_contract_good import launch as frontier_pallas


def frontier(x):
    # calls a *_pallas entry: no ref.* fallback, interpret= not forwarded
    return frontier_pallas(x)
