"""Known-good kernel module: every kernel-contract clause satisfied."""
import jax.numpy as jnp
from jax.experimental import pallas as pl

PAD = -1


def kernel_body(x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(jnp.int32)


def launch(x, *, interpret=False):
    return pl.pallas_call(kernel_body, out_shape=x, grid=(1,),
                          interpret=interpret)(x)
