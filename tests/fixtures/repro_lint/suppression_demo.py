"""Fixture exercising the suppression machinery (unused-import rule)."""
import json  # repro-lint: disable=unused-import
import os  # repro-lint: disable=all
import sys  # no suppression: this one must still be flagged
