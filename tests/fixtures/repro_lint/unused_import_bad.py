"""Known-bad module: imports nothing uses."""
import json
import os as operating_system
from typing import Dict, List


def ls(path):
    return sorted(path.iterdir())
