"""Known-good module: reaches the skew API through the compat layer."""
from repro.compat import shard_map


def shard(f, mesh, specs):
    return shard_map(f, mesh=mesh, in_specs=specs, out_specs=specs)
