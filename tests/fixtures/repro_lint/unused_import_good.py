"""Known-good module: every import used, including via quoted annotation."""
from __future__ import annotations

import collections
import json
from typing import List

__all__ = ["dump", "Cache"]


def dump(items: List[int]) -> str:
    return json.dumps(items)


class Cache:
    store: "collections.OrderedDict[str, int]"
