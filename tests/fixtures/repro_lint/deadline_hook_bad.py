"""Known-bad driver: an emitting loop that never consults its deadline."""


def drive(chunks, stats, deadline=None):
    results = []
    for chunk in chunks:  # outermost, touches stats.*, no deadline ref
        stats.chunks += 1
        results.extend(chunk)
    return results
