"""Known-good module: named exceptions, deliberate BaseException."""


def load(path):
    try:
        return path.read_text()
    except (OSError, UnicodeDecodeError):
        return None


def guard(fn):
    try:
        return fn()
    except BaseException:
        raise
