"""Fixture exercising file-level suppression (unused-import rule)."""
# repro-lint: disable-file=unused-import
import json
import os
import sys
