"""Known-bad audited module: public slots undocumented, no §N anchor."""


class Server:
    def submit(self, req):
        return req


def helper(x):
    return x
