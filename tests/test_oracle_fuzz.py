"""Oracle fuzz suite: every engine path vs core.oracle on random digraphs.

HcPE is *set* enumeration: the one contract every engine path must honor
is exact path-set equality with the backtracking oracle (Alg. 1).  This
suite fuzzes that contract over random digraphs of varying size/density —
a three-way backend sweep (dfs / join / the Pallas device backend, which
runs in interpret mode so CPU CI covers it; DESIGN.md §9) through the
per-query plans, ``BatchPathEnum.run``, and the async server — plus the
named edge cases (k at the engine's floor, s adjacent to t, t
unreachable, in-batch duplicates).

Two layers:
  * a deterministic seeded sweep — a fast smoke slice that always runs,
    and a ``slow``-marked 200-case sweep (the CI fast leg skips it; the
    scheduled full-fuzz leg and local tier-1 run it);
  * a hypothesis layer (shrinking finds minimal counterexamples) that
    activates when hypothesis is installed and is likewise ``slow``.
"""
import asyncio

import numpy as np
import pytest

from repro.core import (BatchPathEnum, PathEnum, build_index,
                        enumerate_paths_idx, enumerate_paths_join,
                        from_edges, oracle)
from repro.core.graph import PAD
from repro.serving import (AsyncHcPEServer, GraphRegistry, HcPEServer,
                           PathQueryRequest)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

FAST_CASES = 24
SWEEP_CASES = 200


# ---------------------------------------------------------------------------
# case generation: deterministic per seed
# ---------------------------------------------------------------------------

def _random_case(seed):
    """(graph, s, t, k) spanning sparse→dense digraphs, n in [4, 26]."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 27))
    density = float(rng.choice([0.5, 1.0, 2.0, 3.5]))   # mean out-degree
    m = max(1, int(n * density))
    edges = rng.integers(0, n, size=(m, 2))             # dups/self-loops ok
    g = from_edges(n, edges)
    s, t = map(int, rng.choice(n, 2, replace=False))
    k = int(rng.integers(2, 7))
    return g, s, t, k


def _check_engines_match_oracle(seed):
    g, s, t, k = _random_case(seed)
    want = oracle.paths_as_set(oracle.enumerate_paths(g, s, t, k))
    label = f"seed={seed} n={g.n} m={g.m} q=({s},{t},{k})"

    idx = build_index(g, s, t, k)
    got_dfs = oracle.paths_as_set(enumerate_paths_idx(idx).as_tuples())
    assert got_dfs == want, f"dfs != oracle [{label}]"

    # device leg of the three-way sweep: same IDX-DFS walk, frontier
    # expansion on the Pallas kernel (interpret mode on CPU, DESIGN.md §9)
    got_dev = oracle.paths_as_set(
        enumerate_paths_idx(idx, backend="device").as_tuples())
    assert got_dev == want, f"device != oracle [{label}]"

    for cut in {1, max(1, k // 2), k - 1}:
        got_join = oracle.paths_as_set(
            enumerate_paths_join(idx, cut=cut).as_tuples())
        assert got_join == want, f"join(cut={cut}) != oracle [{label}]"

    eng = BatchPathEnum()
    for mode in ("auto", "dfs", "join"):
        out = eng.run(g, [(s, t, k)], count_only=False, mode=mode)
        got = oracle.paths_as_set(out.items[0].result.as_tuples())
        assert got == want, f"batch/{mode} != oracle [{label}]"

    out = BatchPathEnum(backend="device").run(g, [(s, t, k)],
                                              count_only=False, mode="dfs")
    got = oracle.paths_as_set(out.items[0].result.as_tuples())
    assert got == want, f"batch/device != oracle [{label}]"


@pytest.mark.parametrize("seed", range(FAST_CASES))
def test_engines_match_oracle_smoke(seed):
    _check_engines_match_oracle(seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(FAST_CASES, FAST_CASES + SWEEP_CASES))
def test_engines_match_oracle_sweep(seed):
    _check_engines_match_oracle(seed)


def _check_fused_batch_matches_oracle(seed):
    """Multi-query fused-launch leg of the device slice (DESIGN.md §9):
    several queries on one random digraph through the batch engine's
    fused device path, each path set against the oracle."""
    rng = np.random.default_rng(seed + 9_000)
    n = int(rng.integers(8, 27))
    m = max(2, int(n * float(rng.choice([1.0, 2.0, 3.5]))))
    g = from_edges(n, rng.integers(0, n, size=(m, 2)))
    queries = []
    while len(queries) < 3:
        s, t = map(int, rng.choice(n, 2, replace=False))
        queries.append((s, t, int(rng.integers(2, 6))))
    out = BatchPathEnum(backend="device", fused="auto").run(
        g, queries, count_only=False, mode="dfs")
    for (s, t, k), item in zip(queries, out.items):
        want = oracle.paths_as_set(oracle.enumerate_paths(g, s, t, k))
        got = oracle.paths_as_set(item.result.as_tuples())
        assert got == want, f"fused seed={seed} q=({s},{t},{k})"


@pytest.mark.parametrize("seed", range(6))
def test_fused_batch_matches_oracle_smoke(seed):
    _check_fused_batch_matches_oracle(seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(6, 40))
def test_fused_batch_matches_oracle_sweep(seed):
    _check_fused_batch_matches_oracle(seed)


# ---------------------------------------------------------------------------
# batch semantics: dedup of repeated (s,t,k), warm-cache stability
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_batch_with_duplicates_matches_oracle(seed):
    g, s, t, k = _random_case(1000 + seed)
    rng = np.random.default_rng(2000 + seed)
    pool = [(s, t, k)]
    while len(pool) < 4:
        a, b = map(int, rng.choice(g.n, 2, replace=False))
        pool.append((a, b, int(rng.integers(2, 6))))
    # repeat every query: dedup must collapse them without changing sets
    queries = pool + pool[::-1]
    out = BatchPathEnum().run(g, queries, count_only=False)
    assert out.distinct_queries == len(set(pool))
    for (a, b, kk), item in zip(queries, out.items):
        want = oracle.paths_as_set(oracle.enumerate_paths(g, a, b, kk))
        assert oracle.paths_as_set(item.result.as_tuples()) == want
    first = {}
    for q, item in zip(queries, out.items):
        if q in first:
            assert item.result is first[q].result     # shared, not recomputed
            assert item.deduplicated
        else:
            first[q] = item


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(10))
def test_async_server_matches_oracle(seed):
    g, s, t, k = _random_case(3000 + seed)
    rng = np.random.default_rng(4000 + seed)
    reqs = [PathQueryRequest(uid=0, s=s, t=t, k=k, count_only=False)]
    while len(reqs) < 6:
        a, b = map(int, rng.choice(g.n, 2, replace=False))
        reqs.append(PathQueryRequest(uid=len(reqs), s=a, t=b,
                                     k=int(rng.integers(2, 6)),
                                     count_only=False,
                                     deadline_ms=float(rng.choice(
                                         [20.0, 5000.0]))))
    reqs.append(PathQueryRequest(uid=len(reqs), s=s, t=t, k=k,
                                 count_only=False))   # in-batch duplicate

    async def drive():
        async with AsyncHcPEServer(g, batch_window_ms=1.0) as srv:
            return await srv.serve(reqs)

    for r, q in zip(asyncio.run(drive()), reqs):
        want = oracle.paths_as_set(oracle.enumerate_paths(g, q.s, q.t, q.k))
        rows = r.paths if r.paths is not None else np.zeros((0, q.k + 1))
        got = oracle.paths_as_set(
            tuple(int(x) for x in row if x != PAD) for row in rows)
        assert got == want, (q.s, q.t, q.k)
        assert r.count == len(want)


# ---------------------------------------------------------------------------
# cross-tenant: two graphs behind one server, exact per-tenant path sets
# ---------------------------------------------------------------------------

def _paths_of(resp, k):
    rows = resp.paths if resp.paths is not None else np.zeros((0, k + 1))
    return oracle.paths_as_set(
        tuple(int(x) for x in row if x != PAD) for row in rows)


def _cross_tenant_workload(seed):
    """Two random tenant graphs + an interleaved count_only=False request
    stream over both (including same-(s,t,k) collisions across tenants,
    the case a mis-keyed cache would get wrong)."""
    g_a, s_a, t_a, k_a = _random_case(seed)
    g_b, s_b, t_b, k_b = _random_case(seed + 100_000)
    rng = np.random.default_rng(seed)
    reqs = [PathQueryRequest(uid=0, s=s_a, t=t_a, k=k_a, count_only=False,
                             graph_id="a"),
            PathQueryRequest(uid=1, s=s_b, t=t_b, k=k_b, count_only=False,
                             graph_id="b")]
    n_min = min(g_a.n, g_b.n)
    while len(reqs) < 8:
        s, t = map(int, rng.choice(n_min, 2, replace=False))
        k = int(rng.integers(2, 6))
        # the SAME (s, t, k) submitted against BOTH tenants
        reqs.append(PathQueryRequest(uid=len(reqs), s=s, t=t, k=k,
                                     count_only=False, graph_id="a"))
        reqs.append(PathQueryRequest(uid=len(reqs), s=s, t=t, k=k,
                                     count_only=False, graph_id="b"))
    registry = GraphRegistry()
    registry.register("a", g_a)
    registry.register("b", g_b)
    return registry, {"a": g_a, "b": g_b}, reqs


@pytest.mark.parametrize("seed", range(6))
def test_cross_tenant_sync_server_matches_oracle(seed):
    registry, graphs, reqs = _cross_tenant_workload(7000 + seed)
    resps, report = HcPEServer(registry).serve(reqs)
    for r, q in zip(resps, reqs):
        want = oracle.paths_as_set(
            oracle.enumerate_paths(graphs[q.graph_id], q.s, q.t, q.k))
        assert _paths_of(r, q.k) == want, (q.graph_id, q.s, q.t, q.k)
        assert r.count == len(want)
    # both tenants' cache traffic is visible and sums to the batch delta
    assert set(report.tenant_cache) == {"a", "b"}


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(6))
def test_cross_tenant_async_server_matches_oracle(seed):
    registry, graphs, reqs = _cross_tenant_workload(8000 + seed)

    async def drive():
        async with AsyncHcPEServer(registry, batch_window_ms=1.0) as srv:
            return await srv.serve(reqs)

    for r, q in zip(asyncio.run(drive()), reqs):
        want = oracle.paths_as_set(
            oracle.enumerate_paths(graphs[q.graph_id], q.s, q.t, q.k))
        assert _paths_of(r, q.k) == want, (q.graph_id, q.s, q.t, q.k)
        assert r.count == len(want)


# ---------------------------------------------------------------------------
# named edge cases
# ---------------------------------------------------------------------------

def test_k_floor_engines_reject_k1_oracle_handles_it():
    """k=1 is below the paper's k>=2 floor: every engine path must refuse
    it the same way, while the oracle (no floor) degrades to 'is there a
    direct edge'."""
    g = from_edges(4, np.array([[0, 1], [1, 2], [0, 3]]))
    assert oracle.enumerate_paths(g, 0, 1, 1) == [(0, 1)]
    assert oracle.enumerate_paths(g, 0, 2, 1) == []
    with pytest.raises(ValueError):
        PathEnum().query(g, 0, 1, 1)
    with pytest.raises(ValueError):
        BatchPathEnum().run(g, [(0, 1, 1)])

    async def drive():
        async with AsyncHcPEServer(g) as srv:
            with pytest.raises(ValueError):
                await srv.submit(PathQueryRequest(uid=0, s=0, t=1, k=1))

    asyncio.run(drive())


@pytest.mark.parametrize("seed", range(12))
def test_s_adjacent_to_t_direct_edge_always_included(seed):
    g, s, t, k = _random_case(5000 + seed)
    # rebuild with the direct edge s->t guaranteed present
    old = np.column_stack([np.repeat(np.arange(g.n), np.diff(g.indptr)),
                           g.indices])
    g2 = from_edges(g.n, np.vstack([old, np.array([[s, t]])]))
    want = oracle.paths_as_set(oracle.enumerate_paths(g2, s, t, k))
    assert (s, t) in want                     # the 1-hop path survives
    idx = build_index(g2, s, t, k)
    assert oracle.paths_as_set(enumerate_paths_idx(idx).as_tuples()) == want
    out = BatchPathEnum().run(g2, [(s, t, k)], count_only=False)
    assert oracle.paths_as_set(out.items[0].result.as_tuples()) == want


@pytest.mark.parametrize("seed", range(12))
def test_t_unreachable_yields_empty_everywhere(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 20))
    m = max(1, 2 * n)
    # t = n-1 isolated: no edge touches it
    edges = rng.integers(0, n - 1, size=(m, 2))
    g = from_edges(n, edges)
    s, t = int(rng.integers(0, n - 1)), n - 1
    k = int(rng.integers(2, 7))
    assert oracle.enumerate_paths(g, s, t, k) == []
    idx = build_index(g, s, t, k)
    assert enumerate_paths_idx(idx).count == 0
    assert enumerate_paths_join(idx, cut=max(1, k // 2)).count == 0
    out = BatchPathEnum().run(g, [(s, t, k)], count_only=False)
    assert out.items[0].result.count == 0
    assert out.items[0].result.exhausted


# ---------------------------------------------------------------------------
# ranked (any-k) layer: ordered-SEQUENCE equality vs the rank-order oracle
# ---------------------------------------------------------------------------
#
# Set equality is not enough under ``order=``: the contract is the exact
# emission sequence — non-decreasing rank, lexicographic vertex tie-break
# — identical bit-for-bit across dfs / join / device (DESIGN.md §10).

RANKED_FAST_CASES = 12
RANKED_SWEEP_CASES = 200


def _random_weights(g, seed):
    """Duplicate-heavy non-negative weights: small integers (zeros
    included) so many distinct paths share an exact cost — the case that
    puts the lexicographic tie-break on the hook."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, 4, size=g.m).astype(np.float64)


def _check_ranked_match_oracle(seed):
    g, s, t, k = _random_case(seed)
    w = _random_weights(g, seed + 500_000)
    for order in ("hops", "weight"):
        weights = w if order == "weight" else None
        want = oracle.enumerate_paths(g, s, t, k, order=order,
                                      weights=weights)
        label = f"seed={seed} order={order} n={g.n} m={g.m} q=({s},{t},{k})"

        idx = build_index(g, s, t, k)
        got = enumerate_paths_idx(idx, order=order, weights=weights)
        assert got.as_tuples() == want, f"dfs != oracle [{label}]"
        assert got.exhausted

        # device leg: order="hops" runs the rank-bucketed Pallas driver;
        # order="weight" exercises the documented host fallback
        got_dev = enumerate_paths_idx(idx, backend="device", order=order,
                                      weights=weights)
        assert got_dev.as_tuples() == want, f"device != oracle [{label}]"

        for cut in {1, max(1, k // 2), k - 1}:
            got_join = enumerate_paths_join(idx, cut=cut, order=order,
                                            weights=weights)
            assert got_join.as_tuples() == want, \
                f"join(cut={cut}) != oracle [{label}]"

        for mode in ("auto", "dfs", "join"):
            out = BatchPathEnum().run(g, [(s, t, k)], count_only=False,
                                      mode=mode, order=order,
                                      weights=weights)
            assert out.items[0].result.as_tuples() == want, \
                f"batch/{mode} != oracle [{label}]"


@pytest.mark.parametrize("seed", range(RANKED_FAST_CASES))
def test_ranked_engines_match_oracle_smoke(seed):
    _check_ranked_match_oracle(seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(RANKED_FAST_CASES,
                                       RANKED_FAST_CASES + RANKED_SWEEP_CASES))
def test_ranked_engines_match_oracle_sweep(seed):
    _check_ranked_match_oracle(seed)


# ---------------------------------------------------------------------------
# hypothesis layer (property-based shrinkable counterexamples)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @st.composite
    def graph_query(draw):
        n = draw(st.integers(4, 22))
        m = draw(st.integers(1, 3 * n))
        edges = draw(st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=m, max_size=m))
        g = from_edges(n, np.array(edges, dtype=np.int64))
        s = draw(st.integers(0, n - 1))
        t = draw(st.integers(0, n - 1).filter(lambda x: x != s))
        k = draw(st.integers(2, 6))
        return g, s, t, k

    @pytest.mark.slow
    @settings(max_examples=60, deadline=None)
    @given(graph_query())
    def test_hypothesis_all_plans_match_oracle(gq):
        g, s, t, k = gq
        want = oracle.paths_as_set(oracle.enumerate_paths(g, s, t, k))
        eng = BatchPathEnum()
        for mode in ("auto", "dfs", "join"):
            out = eng.run(g, [(s, t, k)], count_only=False, mode=mode)
            assert oracle.paths_as_set(out.items[0].result.as_tuples()) == want

    @st.composite
    def ranked_query(draw):
        """graph_query plus an order and (for weight) a tie-heavy weight
        vector drawn from a 4-value pool — shrinking drives toward all-
        equal weights, the hardest tie-break case."""
        g, s, t, k = draw(graph_query())
        order = draw(st.sampled_from(["hops", "weight"]))
        weights = None
        if order == "weight":
            weights = np.array(draw(st.lists(
                st.sampled_from([0.0, 0.5, 1.0, 2.0]),
                min_size=g.m, max_size=g.m)), dtype=np.float64)
        return g, s, t, k, order, weights

    @pytest.mark.slow
    @settings(max_examples=60, deadline=None)
    @given(ranked_query())
    def test_hypothesis_ranked_sequence_equality(rq):
        g, s, t, k, order, weights = rq
        want = oracle.enumerate_paths(g, s, t, k, order=order,
                                      weights=weights)
        idx = build_index(g, s, t, k)
        assert enumerate_paths_idx(
            idx, order=order, weights=weights).as_tuples() == want
        assert enumerate_paths_idx(
            idx, backend="device", order=order,
            weights=weights).as_tuples() == want
        assert enumerate_paths_join(
            idx, cut=max(1, k // 2), order=order,
            weights=weights).as_tuples() == want


# ---------------------------------------------------------------------------
# masked + precomputed distances: the streaming/distributed hand-off leg
# ---------------------------------------------------------------------------

def _check_masked_precomputed_matches_oracle(seed):
    """Fuzz the masked precomputed-distance hand-off (the leak regression,
    DESIGN.md §12): distances computed on the mask-filtered graph and
    injected via ``_precomputed_distances`` must yield exactly the oracle
    path set of the filtered graph — never a masked-out edge."""
    from repro.core import DEFAULT_GRAPH_ID
    from repro.core.batch import edge_mask_hash

    g, s, t, k = _random_case(seed)
    rng = np.random.default_rng(seed)
    mask = rng.random(g.m) < 0.7
    gf = from_edges(g.n, g.edge_list()[mask])      # ground-truth graph
    want = oracle.paths_as_set(oracle.enumerate_paths(gf, s, t, k))

    mh = edge_mask_hash(mask)
    idx = build_index(g, s, t, k, edge_mask=mask)
    pre = {(DEFAULT_GRAPH_ID, s, t, k, mh, g.version):
           (idx.dist_s, idx.dist_t)}
    out = BatchPathEnum().run(g, [(s, t, k)], count_only=False,
                              edge_mask=mask, _precomputed_distances=pre)
    got = oracle.paths_as_set(out.items[0].result.as_tuples())
    assert got == want, f"seed={seed} n={g.n} m={g.m} q=({s},{t},{k})"


@pytest.mark.parametrize("seed", range(8))
def test_masked_precomputed_matches_oracle_smoke(seed):
    _check_masked_precomputed_matches_oracle(3000 + seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(3008, 3008 + 96))
def test_masked_precomputed_matches_oracle_sweep(seed):
    _check_masked_precomputed_matches_oracle(seed)


# ---------------------------------------------------------------------------
# structure sharing: sharing == no-sharing == oracle on hub-shaped batches
# ---------------------------------------------------------------------------
#
# The §13 contract fuzzed three ways at once: a shared batch's per-query
# path sets equal the backtracking oracle's, and the materialized
# results (paths, lengths, stats, exhausted) are byte-identical to the
# sharing="off" run.  Batches are hub-shaped on purpose — overlapping
# shared-s and shared-t groups around one hub vertex, duplicate (s, t)
# at different k, disjoint strays — the overlap patterns real Zipfian
# traffic produces and exact-key dedup cannot collapse.

SHARING_FAST_CASES = 10
SHARING_SWEEP_CASES = 120


def _hub_batch(seed):
    """Random digraph + an overlapping-group batch around one hub."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 26))
    density = float(rng.choice([1.0, 2.0, 3.5]))
    m = max(n, int(n * density))
    g = from_edges(n, rng.integers(0, n, size=(m, 2)))
    hub = int(rng.integers(0, n))
    queries = []
    for t in map(int, rng.choice(n, size=int(rng.integers(2, 5)),
                                 replace=False)):
        if t != hub:
            queries.append((hub, t, int(rng.integers(2, 7))))
    for s in map(int, rng.choice(n, size=int(rng.integers(2, 5)),
                                 replace=False)):
        if s != hub:
            queries.append((s, hub, int(rng.integers(2, 7))))
    if queries:
        s0, t0, k0 = queries[0]
        queries.append((s0, t0, min(6, k0 + 1)))   # same (s,t), other k
    for _ in range(int(rng.integers(0, 3))):
        a, b = map(int, rng.choice(n, 2, replace=False))
        queries.append((a, b, int(rng.integers(2, 6))))
    return g, queries


def _check_sharing_matches_oracle(seed):
    g, queries = _hub_batch(seed)
    if len(queries) < 2:
        return
    for mode in ("auto", "dfs", "join"):
        on = BatchPathEnum(sharing="auto").run(g, queries,
                                               count_only=False, mode=mode)
        off = BatchPathEnum(sharing="off").run(g, queries,
                                               count_only=False, mode=mode)
        for (s, t, k), a, b in zip(queries, on.items, off.items):
            label = f"seed={seed} mode={mode} q=({s},{t},{k})"
            want = oracle.paths_as_set(oracle.enumerate_paths(g, s, t, k))
            assert oracle.paths_as_set(a.result.as_tuples()) == want, \
                f"sharing != oracle [{label}]"
            assert np.array_equal(a.result.paths, b.result.paths), label
            assert np.array_equal(a.result.lengths, b.result.lengths), label
            assert a.result.stats == b.result.stats, label
            assert a.result.exhausted == b.result.exhausted, label


@pytest.mark.parametrize("seed", range(SHARING_FAST_CASES))
def test_sharing_matches_oracle_smoke(seed):
    _check_sharing_matches_oracle(9000 + seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(9000 + SHARING_FAST_CASES,
                                       9000 + SHARING_FAST_CASES
                                       + SHARING_SWEEP_CASES))
def test_sharing_matches_oracle_sweep(seed):
    _check_sharing_matches_oracle(seed)


if HAVE_HYPOTHESIS:

    @st.composite
    def hub_batch(draw):
        """graph_query scaled up to a batch: overlapping shared-s and
        shared-t groups around a drawn hub, duplicate (s, t) at two
        different k — shrinking drives toward the minimal overlapping
        pair that still disagrees."""
        g, _s, _t, _k = draw(graph_query())
        hub = draw(st.integers(0, g.n - 1))
        outs = draw(st.lists(
            st.integers(0, g.n - 1).filter(lambda x: x != hub),
            min_size=2, max_size=5, unique=True))
        ins = draw(st.lists(
            st.integers(0, g.n - 1).filter(lambda x: x != hub),
            min_size=0, max_size=4, unique=True))
        queries = [(hub, t, draw(st.integers(2, 6))) for t in outs]
        queries += [(s, hub, draw(st.integers(2, 6))) for s in ins]
        s0, t0, k0 = queries[0]
        queries.append((s0, t0, draw(st.integers(2, 6))))
        return g, queries

    @pytest.mark.slow
    @settings(max_examples=60, deadline=None)
    @given(hub_batch())
    def test_hypothesis_sharing_parity(hb):
        g, queries = hb
        want = [oracle.paths_as_set(oracle.enumerate_paths(g, s, t, k))
                for (s, t, k) in queries]
        for mode in ("auto", "dfs", "join"):
            on = BatchPathEnum(sharing="auto").run(
                g, queries, count_only=False, mode=mode)
            off = BatchPathEnum(sharing="off").run(
                g, queries, count_only=False, mode=mode)
            for w, a, b in zip(want, on.items, off.items):
                assert oracle.paths_as_set(a.result.as_tuples()) == w
                assert np.array_equal(a.result.paths, b.result.paths)
                assert a.result.stats == b.result.stats
