"""AsyncHcPEServer: admission, EDF scheduling, deadlines, parity with sync.

No pytest-asyncio dependency: each test drives its own event loop via
``asyncio.run`` so the suite runs wherever tier-1 runs (the plugin is in
requirements-dev.txt for authoring convenience, not a test requirement).
"""
import asyncio
import time

import numpy as np
import pytest

from repro.core import BatchPathEnum, PathEnum, erdos_renyi
from repro.core.batch import BatchOutput, BatchTiming, CacheStats
from repro.serving import (AsyncHcPEServer, HcPEServer, PathQueryRequest,
                           STATUS_OK, STATUS_REJECTED_QUEUE_FULL,
                           STATUS_REJECTED_QUOTA, STATUS_REJECTED_SHUTDOWN)
from repro.serving.hcpe import _merge_outputs


def _light_requests(g, count, rng, k=3, deadline_ms=None, uid0=0):
    reqs = []
    while len(reqs) < count:
        s, t = rng.integers(0, g.n, 2)
        if s != t:
            reqs.append(PathQueryRequest(uid=uid0 + len(reqs), s=int(s),
                                         t=int(t), k=k,
                                         deadline_ms=deadline_ms))
    return reqs


# ---------------------------------------------------------------------------
# correctness: async == sync == sequential
# ---------------------------------------------------------------------------

def test_async_counts_match_sync_engine():
    g = erdos_renyi(80, 4.0, seed=4)
    rng = np.random.default_rng(0)
    reqs = _light_requests(g, 12, rng, k=4, deadline_ms=200.0)

    async def drive():
        async with AsyncHcPEServer(g, batch_window_ms=1.0) as srv:
            return await srv.serve(reqs)

    resps = asyncio.run(drive())
    assert [r.uid for r in resps] == [q.uid for q in reqs]
    seq = PathEnum()
    for r, q in zip(resps, reqs):
        assert r.status == STATUS_OK
        assert r.exhausted
        assert r.count == seq.count(g, q.s, q.t, q.k)


def test_async_latency_split_and_slo_flag():
    g = erdos_renyi(50, 3.0, seed=1)
    reqs = [PathQueryRequest(uid=0, s=0, t=1, k=3, deadline_ms=60_000.0),
            PathQueryRequest(uid=1, s=0, t=2, k=3)]  # no deadline

    async def drive():
        async with AsyncHcPEServer(g, batch_window_ms=1.0) as srv:
            return await srv.serve(reqs)

    with_slo, without_slo = asyncio.run(drive())
    assert with_slo.slo_met is True          # 60 s budget cannot miss
    assert without_slo.slo_met is None       # no deadline -> not graded
    for r in (with_slo, without_slo):
        assert r.queue_ms >= 0.0 and r.service_ms > 0.0
        assert r.total_ms == pytest.approx(r.queue_ms + r.service_ms,
                                           rel=1e-6, abs=1e-6)


def test_async_dedup_inside_micro_batch():
    g = erdos_renyi(60, 4.0, seed=2)
    reqs = [PathQueryRequest(uid=i, s=0, t=1, k=4, deadline_ms=500.0)
            for i in range(4)]

    async def drive():
        async with AsyncHcPEServer(g, batch_window_ms=5.0) as srv:
            return await srv.serve(reqs), srv.stats

    resps, stats = asyncio.run(drive())
    # burst of identical queries lands in one window -> one micro-batch,
    # engine dedup collapses the duplicates
    assert stats.micro_batches == 1
    assert sum(r.deduplicated for r in resps) == len(reqs) - 1
    assert len({r.count for r in resps}) == 1


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_queue_depth_rejection_is_a_response():
    g = erdos_renyi(40, 3.0, seed=3)
    reqs = _light_requests(g, 6, np.random.default_rng(1), deadline_ms=100.0)

    async def drive():
        async with AsyncHcPEServer(g, batch_window_ms=10.0,
                                   max_queue_depth=2) as srv:
            return await srv.serve(reqs), srv.stats

    resps, stats = asyncio.run(drive())
    ok = [r for r in resps if r.status == STATUS_OK]
    shed = [r for r in resps if r.status == STATUS_REJECTED_QUEUE_FULL]
    assert len(ok) == 2 and len(shed) == 4
    assert stats.rejected_queue_full == 4
    for r in shed:
        assert r.rejected and r.count == 0 and r.paths is None
        assert r.slo_met is False            # had a deadline, never served
    # stats agree with the responses: shed deadline requests are SLO misses
    assert stats.slo_missed >= 4


def test_per_uid_quota_rejection():
    g = erdos_renyi(40, 3.0, seed=3)
    # one tenant floods, one stays within quota
    flood = [PathQueryRequest(uid=7, s=0, t=i, k=3) for i in range(1, 5)]
    fair = [PathQueryRequest(uid=8, s=0, t=5, k=3)]

    async def drive():
        async with AsyncHcPEServer(g, batch_window_ms=10.0,
                                   max_pending_per_uid=1) as srv:
            return await srv.serve(flood + fair)

    resps = asyncio.run(drive())
    assert [r.status for r in resps[:4]].count(STATUS_REJECTED_QUOTA) == 3
    assert resps[0].status == STATUS_OK      # first of the flood admitted
    assert resps[4].status == STATUS_OK      # other tenant unaffected


def test_shutdown_rejects_new_but_drains_admitted():
    g = erdos_renyi(40, 3.0, seed=5)

    async def drive():
        srv = AsyncHcPEServer(g, batch_window_ms=30.0)
        await srv.start()
        admitted = asyncio.ensure_future(
            srv.submit(PathQueryRequest(uid=0, s=0, t=1, k=3)))
        await asyncio.sleep(0.005)           # admitted; scheduler in window
        stop = asyncio.ensure_future(srv.stop())
        await asyncio.sleep(0)               # stop() has set closing
        late = await srv.submit(PathQueryRequest(uid=1, s=0, t=2, k=3))
        first = await admitted
        await stop
        return first, late

    first, late = asyncio.run(drive())
    assert first.status == STATUS_OK
    assert late.status == STATUS_REJECTED_SHUTDOWN


def test_stop_drains_without_waiting_out_the_batch_window():
    """Regression: the scheduler used to sleep the full ``batch_window_ms``
    between drain batches even while closing, so shutdown latency scaled
    with the window instead of the service time.  With a multi-second
    window, stop() must still complete in a service-bound instant."""
    g = erdos_renyi(40, 3.0, seed=5)

    async def drive():
        srv = AsyncHcPEServer(g, batch_window_ms=5_000.0)
        await srv.start()
        futs = [asyncio.ensure_future(
            srv.submit(PathQueryRequest(uid=i, s=i, t=i + 3, k=3)))
            for i in range(4)]
        await asyncio.sleep(0.005)           # admitted; scheduler in window
        t0 = time.perf_counter()
        await srv.stop()                     # must interrupt the window
        drained_ms = (time.perf_counter() - t0) * 1e3
        return await asyncio.gather(*futs), drained_ms

    resps, drained_ms = asyncio.run(drive())
    assert all(r.status == STATUS_OK for r in resps)
    assert drained_ms < 1_000.0              # far below the 5 s window


def test_malformed_queries_raise_not_reject():
    """Malformed queries must fail their own submit (and never reach the
    engine, where they would poison every co-batched request)."""
    g = erdos_renyi(20, 2.0, seed=0)

    async def drive():
        async with AsyncHcPEServer(g, batch_window_ms=1.0) as srv:
            with pytest.raises(ValueError):
                await srv.submit(PathQueryRequest(uid=0, s=0, t=1, k=1))
            with pytest.raises(ValueError):
                await srv.submit(PathQueryRequest(uid=0, s=3, t=3, k=4))
            with pytest.raises(ValueError):      # out of range for g.n == 20
                await srv.submit(PathQueryRequest(uid=0, s=999, t=1, k=4))
            # an innocent request sharing the window still gets served
            ok = await srv.submit(PathQueryRequest(uid=1, s=0, t=1, k=4))
            assert ok.status == STATUS_OK

    asyncio.run(drive())


def test_cancelled_submit_does_not_kill_scheduler():
    """Regression: resolving a cancelled future raised InvalidStateError
    inside the scheduler task, hanging every later request."""
    g = erdos_renyi(40, 3.0, seed=5)

    async def drive():
        async with AsyncHcPEServer(g, batch_window_ms=5.0) as srv:
            doomed = asyncio.ensure_future(
                srv.submit(PathQueryRequest(uid=0, s=0, t=1, k=3)))
            await asyncio.sleep(0.001)           # admitted, batch in window
            doomed.cancel()
            with pytest.raises(asyncio.CancelledError):
                await doomed
            # the scheduler survived: later submissions still complete
            resp = await asyncio.wait_for(
                srv.submit(PathQueryRequest(uid=1, s=0, t=2, k=3)), timeout=5)
            assert resp.status == STATUS_OK

    asyncio.run(drive())


def test_submit_before_start_raises():
    g = erdos_renyi(20, 2.0, seed=0)
    srv = AsyncHcPEServer(g)

    async def drive():
        with pytest.raises(RuntimeError):
            await srv.submit(PathQueryRequest(uid=0, s=0, t=1, k=3))

    asyncio.run(drive())


# ---------------------------------------------------------------------------
# deadline enforcement (cooperative chunk budget)
# ---------------------------------------------------------------------------

def test_enforce_deadlines_truncates_with_exhausted_false():
    g = erdos_renyi(200, 12.0, seed=3)
    req = PathQueryRequest(uid=0, s=0, t=1, k=8, count_only=False,
                           deadline_ms=1.0)  # cannot finish: ~1.7M paths

    async def drive():
        async with AsyncHcPEServer(g, batch_window_ms=0.0,
                                   enforce_deadlines=True) as srv:
            return await srv.submit(req)

    resp = asyncio.run(drive())
    assert resp.status == STATUS_OK          # served, not rejected
    assert not resp.exhausted                # stopped at the chunk budget
    assert resp.slo_met is False
    full = PathEnum().count(g, 0, 1, 8)
    assert resp.count < full
    # whatever was emitted is a correct subset of the true result set
    if resp.count:
        assert resp.paths.shape[0] == resp.count


@pytest.mark.parametrize("backend", ["host", "device"])
def test_engine_deadline_noop_when_far_future(backend):
    """Deadline semantics are a backend contract (DESIGN.md §9): a far
    future deadline changes nothing on either expansion engine."""
    g = erdos_renyi(60, 4.0, seed=9)
    eng = BatchPathEnum(backend=backend)
    queries = [(0, 1, 4), (2, 3, 4)]
    far = eng.run(g, queries, count_only=False,
                  deadline=time.perf_counter() + 3600.0)
    ref = BatchPathEnum().run(g, queries, count_only=False)
    assert far.counts.tolist() == ref.counts.tolist()
    assert all(it.result.exhausted for it in far.items)


@pytest.mark.parametrize("backend", ["host", "device"])
def test_engine_deadline_already_passed_yields_empty_unexhausted(backend):
    """…and an already-passed deadline truncates to the empty prefix
    with ``exhausted=False`` on both backends, before any chunk runs."""
    g = erdos_renyi(60, 4.0, seed=9)
    out = BatchPathEnum(backend=backend).run(g, [(0, 1, 4)],
                                             count_only=False,
                                             deadline=time.perf_counter() - 1.0)
    item = out.items[0]
    assert item.result.count == 0
    assert not item.result.exhausted
    assert item.result.paths.shape == (0, 5)


# ---------------------------------------------------------------------------
# the acceptance workload: EDF beats the blocking batch on tail latency
# ---------------------------------------------------------------------------

def test_light_p99_beats_sync_serve_under_mixed_workload():
    """1 heavy + 20 light queries, light deadlines tighter: the async
    server's light-query p99 time-to-completion must be strictly lower
    than HcPEServer.serve on the same workload, with identical counts
    (deadlines unenforced -> scheduling only, results untouched)."""
    g = erdos_renyi(200, 12.0, seed=3)
    rng = np.random.default_rng(11)
    heavy = PathQueryRequest(uid=0, s=0, t=1, k=8, deadline_ms=60_000.0)
    lights = _light_requests(g, 20, rng, k=3, deadline_ms=50.0, uid0=1)
    workload = [heavy] + lights              # heavy first: FIFO's worst case

    # -- sync: one blocking batch; every request completes when serve returns
    t0 = time.perf_counter()
    sync_resps, _ = HcPEServer(g, BatchPathEnum()).serve(workload)
    sync_wall = time.perf_counter() - t0
    sync_counts = {r.uid: r.count for r in sync_resps}
    sync_light_p99 = float(np.percentile([sync_wall] * len(lights), 99))

    # -- async: same workload, cold engine, completion timed per request
    async def drive():
        async with AsyncHcPEServer(g, BatchPathEnum(),
                                   batch_window_ms=2.0) as srv:
            t0 = time.perf_counter()

            async def timed(req):
                resp = await srv.submit(req)
                return resp, time.perf_counter() - t0

            return await asyncio.gather(*(timed(r) for r in workload))

    completions = asyncio.run(drive())
    async_counts = {r.uid: r.count for r, _ in completions}
    light_times = [dt for r, dt in completions if r.uid != heavy.uid]
    async_light_p99 = float(np.percentile(light_times, 99))

    assert async_counts == sync_counts       # byte-identical result counts
    assert async_light_p99 < sync_light_p99, (async_light_p99, sync_light_p99)
    # the tight-SLO lights actually jumped the heavy query
    heavy_time = next(dt for r, dt in completions if r.uid == heavy.uid)
    assert max(light_times) < heavy_time


# ---------------------------------------------------------------------------
# _merge_outputs timing semantics (regression for the async scheduler)
# ---------------------------------------------------------------------------

def _span_output(start, end):
    return BatchOutput(items=[], cache_stats=CacheStats(), distinct_queries=0,
                       timing=BatchTiming(total_seconds=end - start,
                                          started_at=start, ended_at=end))


def test_merge_outputs_overlapping_groups_use_union_span():
    """Regression: per-group walls were summed, overstating batch latency
    once groups run concurrently under the async scheduler."""
    a = _span_output(10.0, 12.0)             # 2 s
    b = _span_output(11.0, 13.5)             # 2.5 s, overlaps a
    merged = _merge_outputs([a, b])
    assert merged.timing.total_seconds == pytest.approx(3.5)  # not 4.5
    assert merged.timing.started_at == 10.0
    assert merged.timing.ended_at == 13.5


def test_merge_outputs_idle_gaps_not_billed_as_serving_time():
    """Two 1 s micro-batches separated by 9 s of idle server: busy time
    is 2 s (interval union), not the 11 s end-to-start span — otherwise
    drain_report deflates throughput on any non-back-to-back workload."""
    a = _span_output(10.0, 11.0)
    b = _span_output(20.0, 21.0)
    merged = _merge_outputs([a, b])
    assert merged.timing.total_seconds == pytest.approx(2.0)
    assert (merged.timing.started_at, merged.timing.ended_at) == (10.0, 21.0)


def test_merge_outputs_without_spans_falls_back_to_sum():
    a = BatchOutput(items=[], cache_stats=CacheStats(), distinct_queries=0,
                    timing=BatchTiming(total_seconds=1.0))
    b = BatchOutput(items=[], cache_stats=CacheStats(), distinct_queries=0,
                    timing=BatchTiming(total_seconds=2.0))
    merged = _merge_outputs([a, b])
    assert merged.timing.total_seconds == pytest.approx(3.0)


def test_real_engine_outputs_carry_spans():
    g = erdos_renyi(40, 3.0, seed=6)
    out = BatchPathEnum().run(g, [(0, 1, 3)])
    assert out.timing.ended_at > out.timing.started_at > 0.0
    assert out.timing.total_seconds == pytest.approx(
        out.timing.ended_at - out.timing.started_at)
