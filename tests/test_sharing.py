"""Shared-enumeration parity suite (DESIGN.md §13).

Cross-query structure sharing has exactly one contract: byte-identity.
A batch served with ``sharing="auto"`` must return, for every query,
the same paths, lengths, counts, ``exhausted`` flags *and* Fig.-6
``EnumStats`` as (a) the same batch with ``sharing="off"`` and (b) a
per-query ``PathEnum.query`` run — across every backend (host + the
Pallas device leg), every plan (auto / dfs / join) and every grouping
shape (shared-s fan-out, shared-t fan-in, disjoint, duplicate (s, t)
at mixed k).  The suite also pins the serving-option edges (``first_n``
exact-n trims, deadline ``exhausted=False`` truncations), the
``REPRO_SHARING=off`` escape hatch, the ranked-batch exclusion, and
mutation invalidation of the merged group-index cache (§12 × §13).
"""
import time

import numpy as np
import pytest

from repro.core import BatchPathEnum, PathEnum, from_edges
from repro.core import sharing as sharing_mod
from repro.serving import GraphRegistry, HcPEServer, PathQueryRequest


def _graph(seed, n=18, mean_deg=4.0):
    rng = np.random.default_rng(seed)
    m = max(n, int(n * mean_deg))
    return from_edges(n, rng.integers(0, n, size=(m, 2)))


# grouping shapes over an 18-vertex graph: every predicate branch of
# sharing.detect_groups, plus a no-group control
SHAPES = {
    "shared_s": [(1, t, 4) for t in (2, 3, 5, 7, 9, 11)],
    "shared_t": [(s, 2, 4) for s in (1, 3, 5, 7, 9)],
    "disjoint": [(1, 2, 4), (3, 4, 5), (5, 6, 3), (7, 8, 4)],
    "mixed_k": [(1, 5, 3), (1, 5, 5), (1, 6, 4), (1, 7, 6), (2, 5, 4)],
}


def _assert_result_equal(a, b, label):
    assert a.count == b.count, f"count {label}"
    assert np.array_equal(a.paths, b.paths), f"paths {label}"
    assert np.array_equal(a.lengths, b.lengths), f"lengths {label}"
    assert a.exhausted == b.exhausted, f"exhausted {label}"
    assert a.stats == b.stats, f"stats {label}"


def _run_parity(g, queries, *, mode="auto", backend="host",
                count_only=False, first_n=None, check_solo=True):
    """sharing on vs off vs per-query PathEnum, byte-for-byte."""
    on = BatchPathEnum(sharing="auto", backend=backend).run(
        g, queries, count_only=count_only, first_n=first_n, mode=mode)
    off = BatchPathEnum(sharing="off", backend=backend).run(
        g, queries, count_only=count_only, first_n=first_n, mode=mode)
    assert off.sharing_groups == 0 and off.shared_queries == 0
    solo = PathEnum(backend=backend)
    for (s, t, k), a, b in zip(queries, on.items, off.items):
        label = f"q=({s},{t},{k}) mode={mode} backend={backend}"
        _assert_result_equal(a.result, b.result, f"on-vs-off {label}")
        assert a.plan.method == b.plan.method, label
        if check_solo:
            want = solo.query(g, s, t, k, mode=mode, count_only=count_only,
                              first_n=first_n)
            _assert_result_equal(a.result, want.result,
                                 f"on-vs-solo {label}")
    return on


# ---------------------------------------------------------------------------
# the parity matrix: plan x grouping shape x serving options (host)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["auto", "dfs", "join"])
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_parity_host(mode, shape):
    for seed in (0, 1, 2):
        _run_parity(_graph(seed), SHAPES[shape], mode=mode)


@pytest.mark.parametrize("mode", ["auto", "dfs", "join"])
@pytest.mark.parametrize("count_only", [True, False])
def test_parity_serving_options(mode, count_only):
    g = _graph(3)
    _run_parity(g, SHAPES["shared_s"], mode=mode, count_only=count_only)


@pytest.mark.parametrize("mode", ["dfs", "join"])
@pytest.mark.parametrize("first_n", [1, 3])
def test_first_n_exact_trim(mode, first_n):
    """first_n trims to exactly n when more exist — identical trim point
    with sharing on, off, and solo (join members with first_n never
    share, so the join leg pins the exclusion path)."""
    g = _graph(4, mean_deg=6.0)
    out = _run_parity(g, SHAPES["shared_s"], mode=mode, first_n=first_n)
    for item in out.items:
        res = item.result
        assert res.count <= first_n
        if not res.exhausted:
            assert res.count == first_n      # exact-n, never first_n-ish
    if mode == "join":
        # the §13 join/first_n exclusion: no query shares, parity holds
        assert out.shared_queries == 0


def test_deadline_truncation_parity():
    """An already-expired deadline: the walk falls back (SharingFallback)
    and every item reports the truncation contract, identically on/off."""
    g = _graph(5)
    dl = time.perf_counter()          # in the past by the time run() looks
    on = BatchPathEnum(sharing="auto").run(
        g, SHAPES["shared_s"], count_only=False, deadline=dl)
    off = BatchPathEnum(sharing="off").run(
        g, SHAPES["shared_s"], count_only=False, deadline=dl)
    assert on.shared_queries == 0     # deadline pressure kills the group
    for a, b in zip(on.items, off.items):
        assert not a.result.exhausted
        _assert_result_equal(a.result, b.result, "deadline")


# ---------------------------------------------------------------------------
# device leg: the Pallas frontier kernel under a shared walk (DESIGN.md §9)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["auto", "dfs"])
def test_parity_device_backend(monkeypatch, mode):
    """Replay parity on the device backend (interpret mode on CPU): §9's
    host/device bit-parity composes with §13's sharing byte-identity."""
    monkeypatch.setenv("REPRO_DEVICE_ENUM", "force")
    g = _graph(6)
    out = _run_parity(g, SHAPES["shared_s"], mode=mode, backend="device")
    assert out.shared_queries >= 2    # sharing really was on


def test_member_view_chunks_through_fused_device_path(monkeypatch):
    """§13 × §9 fused launches: group-built ``member_view`` indexes,
    denied the shared walk, flow through the fused multi-query device
    path and stay byte-identical to the solo host pipeline."""
    monkeypatch.setenv("REPRO_DEVICE_ENUM", "force")
    # keep Level-A group *builds* but disable the Level-B shared walk,
    # so every member_view index reaches the batch's fused device phase
    monkeypatch.setattr(sharing_mod, "run_shared_groups",
                        lambda *a, **kw: ({}, {}, 0))
    g = _graph(6)
    queries = SHAPES["shared_s"]
    fused = BatchPathEnum(sharing="auto", backend="device",
                          fused="auto").run(g, queries, count_only=False)
    assert fused.fused_queries >= 2      # the fused path really ran
    assert fused.shared_queries == 0     # ...and the shared walk did not
    assert any(i.fused for i in fused.items)
    host = BatchPathEnum(sharing="off", backend="host",
                         fused="off").run(g, queries, count_only=False)
    for (s, t, k), a, b in zip(queries, fused.items, host.items):
        _assert_result_equal(a.result, b.result,
                             f"fused-member-view q=({s},{t},{k})")


# ---------------------------------------------------------------------------
# sharing observability + the escape hatch
# ---------------------------------------------------------------------------

def test_sharing_fires_and_is_flagged():
    g = _graph(7, mean_deg=6.0)
    out = BatchPathEnum(sharing="auto").run(g, SHAPES["shared_s"],
                                            count_only=False, mode="dfs")
    assert out.sharing_groups >= 1
    assert out.shared_queries >= 2
    assert sum(item.shared for item in out.items) == out.shared_queries
    off = BatchPathEnum(sharing="off").run(g, SHAPES["shared_s"],
                                           count_only=False, mode="dfs")
    assert not any(item.shared for item in off.items)


def test_env_escape_hatch_forces_off(monkeypatch):
    """REPRO_SHARING=off wins over both the engine and per-run knobs —
    the operational kill switch mirrors REPRO_DEVICE_ENUM (§9)."""
    g = _graph(8)
    monkeypatch.setenv("REPRO_SHARING", "off")
    out = BatchPathEnum(sharing="auto").run(
        g, SHAPES["shared_s"], count_only=False, sharing="auto")
    assert out.sharing_groups == 0 and out.shared_queries == 0
    monkeypatch.delenv("REPRO_SHARING")
    ref = BatchPathEnum(sharing="off").run(g, SHAPES["shared_s"],
                                           count_only=False)
    for a, b in zip(out.items, ref.items):
        _assert_result_equal(a.result, b.result, "escape hatch")


def test_resolve_sharing_matrix():
    assert sharing_mod.resolve_sharing(None) == "auto"
    assert sharing_mod.resolve_sharing("auto") == "auto"
    assert sharing_mod.resolve_sharing("off") == "off"
    with pytest.raises(ValueError):
        sharing_mod.resolve_sharing("on")
    with pytest.raises(ValueError):
        BatchPathEnum(sharing="maybe")


def test_per_run_override_beats_engine_knob():
    g = _graph(9)
    eng = BatchPathEnum(sharing="auto")
    out = eng.run(g, SHAPES["shared_s"], count_only=False, sharing="off")
    assert out.sharing_groups == 0
    out2 = eng.run(g, SHAPES["shared_s"], count_only=False)
    assert out2.shared_queries >= 2
    for a, b in zip(out.items, out2.items):
        _assert_result_equal(a.result, b.result, "per-run override")


# ---------------------------------------------------------------------------
# PR-6 interaction: ranked batches never share the walk (DESIGN.md §10 x §13)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("order", ["hops", "weight"])
def test_ranked_batches_skip_shared_walk(order):
    """Rank-order emission is per query; a shared walk cannot reproduce
    it, so ranked batches enumerate solo — and stay byte-identical."""
    g = _graph(10, mean_deg=5.0)
    w = np.random.default_rng(0).integers(0, 4, size=g.m).astype(np.float64)
    weights = w if order == "weight" else None
    on = BatchPathEnum(sharing="auto").run(
        g, SHAPES["shared_s"], count_only=False, order=order,
        weights=weights)
    off = BatchPathEnum(sharing="off").run(
        g, SHAPES["shared_s"], count_only=False, order=order,
        weights=weights)
    assert on.shared_queries == 0
    for (s, t, k), a, b in zip(SHAPES["shared_s"], on.items, off.items):
        _assert_result_equal(a.result, b.result, f"ranked {order}")
        assert a.result.as_tuples() == b.result.as_tuples()


# ---------------------------------------------------------------------------
# PR-8 interaction: mutation invalidates merged group indexes (§12 x §13)
# ---------------------------------------------------------------------------

def test_mutate_invalidates_group_cache():
    """graph_version sits inside every member QueryKey, so a §12 mutation
    makes the old merged index unreachable; the registry purge frees it.
    Post-mutation results must reflect the new topology, not the cached
    group."""
    g = _graph(11)
    registry = GraphRegistry()
    registry.register("a", g)
    server = HcPEServer(registry, sharing="auto")
    reqs = [PathQueryRequest(uid=i, s=1, t=t, k=4, count_only=False)
            for i, t in enumerate((2, 3, 5, 7))]
    for r in reqs:
        r.graph_id = "a"
    resps1, report1 = server.serve(reqs)
    assert report1.shared_queries >= 2
    assert len(server.engine.group_cache) >= 1
    # drop every edge out of the hub: the shared-s group's answers change
    keep = g.edge_list()[g.edge_list()[:, 0] != 1]
    registry.mutate("a", remove=g.edge_list()[g.edge_list()[:, 0] == 1])
    assert len(server.engine.group_cache) == 0      # purged on mutate
    resps2, _ = server.serve(reqs)
    for r in resps2:
        assert r.count == 0                         # hub unplugged
    # parity against a cold engine on the mutated graph
    g2 = registry.get("a")
    cold = BatchPathEnum(sharing="off").run(
        g2, [(1, t, 4) for t in (2, 3, 5, 7)], count_only=False)
    for r, item in zip(resps2, cold.items):
        assert r.count == item.result.count
    assert keep.shape[0] == g2.m


def test_group_cache_reuse_across_batches():
    """The second identical batch serves its merged index off the LRU:
    same results, no growth, observable reuse."""
    g = _graph(12, mean_deg=6.0)
    eng = BatchPathEnum(sharing="auto")
    out1 = eng.run(g, SHAPES["shared_s"], count_only=False, mode="dfs")
    assert out1.shared_queries >= 2
    size = len(eng.group_cache)
    assert size >= 1
    out2 = eng.run(g, SHAPES["shared_s"], count_only=False, mode="dfs")
    assert len(eng.group_cache) == size
    for a, b in zip(out1.items, out2.items):
        _assert_result_equal(a.result, b.result, "warm group cache")


# ---------------------------------------------------------------------------
# serving plumbing: the knob reaches the servers, counters reach reports
# ---------------------------------------------------------------------------

def test_server_reports_sharing_counters():
    g = _graph(13, mean_deg=6.0)
    server = HcPEServer(g, sharing="auto")
    reqs = [PathQueryRequest(uid=i, s=1, t=t, k=4, count_only=False)
            for i, t in enumerate((2, 3, 5, 7, 9))]
    _, report = server.serve(reqs)
    assert report.shared_queries >= 2
    assert report.sharing_groups >= 1
    off_server = HcPEServer(g, sharing="off")
    resps_on, _ = server.serve(reqs)
    resps_off, report_off = off_server.serve(reqs)
    assert report_off.shared_queries == 0
    for a, b in zip(resps_on, resps_off):
        assert a.count == b.count
        assert np.array_equal(a.paths, b.paths)


def test_walk_fallback_on_oversized_group(monkeypatch):
    """A walk over SHARING_MAX_NODES raises SharingFallback and the group
    quietly runs per query — results identical, nothing shared."""
    monkeypatch.setattr(sharing_mod, "SHARING_MAX_NODES", 1)
    g = _graph(14, mean_deg=6.0)
    out = BatchPathEnum(sharing="auto").run(g, SHAPES["shared_s"],
                                            count_only=False, mode="dfs")
    assert out.shared_queries == 0
    ref = BatchPathEnum(sharing="off").run(g, SHAPES["shared_s"],
                                           count_only=False, mode="dfs")
    for a, b in zip(out.items, ref.items):
        _assert_result_equal(a.result, b.result, "oversized fallback")
