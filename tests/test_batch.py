"""BatchPathEnum: dedup, index-cache reuse, batched == sequential, edges.

The batch engine's contract is "same answers, amortized work": every count
must be byte-identical to sequential PathEnum.count, with the sharing
(dedup / LRU / stacked BFS) observable only through stats and timing.
"""
import numpy as np
import pytest

from repro.core import (BatchPathEnum, IndexCache, PathEnum, build_index,
                        erdos_renyi, power_law)
from repro.core.batch import CacheStats, batched_index_distances
from repro.core.graph import random_graph_suite
from repro.serving.hcpe import HcPEServer, PathQueryRequest


def _random_queries(g, count, rng, kmin=2, kmax=5):
    out = []
    while len(out) < count:
        s, t = rng.integers(0, g.n, 2)
        if s != t:
            out.append((int(s), int(t), int(rng.integers(kmin, kmax + 1))))
    return out


# ---------------------------------------------------------------------------
# correctness: batched == sequential
# ---------------------------------------------------------------------------

def test_batched_counts_equal_sequential_on_random_graphs():
    seq = PathEnum()
    eng = BatchPathEnum()
    rng = np.random.default_rng(7)
    for name, g in random_graph_suite(11).items():
        queries = _random_queries(g, 10, rng)
        out = eng.run(g, queries)
        want = [seq.count(g, s, t, k) for (s, t, k) in queries]
        assert out.counts.tolist() == want, name


def test_batched_distances_match_sequential_bfs():
    """The stacked-frontier BFS must reproduce the queue BFS bit-for-bit."""
    rng = np.random.default_rng(3)
    g = power_law(200, 5.0, seed=9)
    queries = _random_queries(g, 15, rng, kmin=2, kmax=6)
    got = batched_index_distances(g, queries, block=4)
    for (s, t, k), (ds, dt) in zip(queries, got):
        idx = build_index(g, s, t, k)
        np.testing.assert_array_equal(ds, idx.dist_s)
        np.testing.assert_array_equal(dt, idx.dist_t)


def test_batched_distances_with_trailing_pred_free_vertices():
    """Regression: vertices with empty CSR rows at the top of the id range
    must not truncate the preceding vertex's reduceat segment."""
    from repro.core import from_edges

    g = from_edges(4, np.array([[0, 1], [2, 1], [1, 0]]))
    (ds, dt), = batched_index_distances(g, [(2, 0, 3)])
    idx = build_index(g, 2, 0, 3)
    np.testing.assert_array_equal(ds, idx.dist_s)
    np.testing.assert_array_equal(dt, idx.dist_t)
    seq = PathEnum()
    assert BatchPathEnum().counts(g, [(2, 0, 3)]).tolist() == \
        [seq.count(g, 2, 0, 3)]
    # sweep: graphs whose high-id vertices are isolated
    rng = np.random.default_rng(17)
    for _ in range(40):
        n = int(rng.integers(4, 20))
        m = int(rng.integers(1, 3 * n))
        edges = rng.integers(0, max(n - 2, 2), size=(m, 2))  # top ids isolated
        g = from_edges(n, edges)
        s, t = rng.choice(n, 2, replace=False)
        k = int(rng.integers(2, 6))
        (ds, dt), = batched_index_distances(g, [(int(s), int(t), k)])
        idx = build_index(g, int(s), int(t), k)
        np.testing.assert_array_equal(ds, idx.dist_s)
        np.testing.assert_array_equal(dt, idx.dist_t)


def test_batch_materialized_paths_match_sequential():
    g = erdos_renyi(60, 4.0, seed=2)
    rng = np.random.default_rng(5)
    queries = _random_queries(g, 6, rng)
    seq = PathEnum()
    out = BatchPathEnum().run(g, queries, count_only=False)
    for (s, t, k), item in zip(queries, out.items):
        want = sorted(seq.query(g, s, t, k).result.as_tuples())
        assert sorted(item.result.as_tuples()) == want


# ---------------------------------------------------------------------------
# sharing: dedup + cache stats
# ---------------------------------------------------------------------------

def test_duplicate_queries_dedup_to_identical_results():
    g = erdos_renyi(80, 4.0, seed=4)
    rng = np.random.default_rng(1)
    distinct = _random_queries(g, 5, rng)
    queries = distinct + distinct + distinct[:2]      # >50% duplicates
    out = BatchPathEnum().run(g, queries)
    assert out.distinct_queries == len(set(distinct))
    first = {q: it for q, it in zip(queries[:5], out.items[:5])}
    for q, item in zip(queries[5:], out.items[5:]):
        assert item.deduplicated
        assert item.result is first[q].result          # same object, no rerun
    # ≥30% duplicate workload must show cache hits (acceptance criterion)
    assert out.cache_stats.hits > 0


def test_index_cache_hit_avoids_rebuild():
    g = erdos_renyi(80, 4.0, seed=8)
    rng = np.random.default_rng(2)
    queries = _random_queries(g, 6, rng)
    eng = BatchPathEnum()
    cold = eng.run(g, queries)
    assert cold.cache_stats.misses == len(queries)
    assert not any(it.index_cached for it in cold.items)
    warm = eng.run(g, queries)
    # warm batch: zero misses means zero rebuilds — asserted via the counter
    assert warm.cache_stats.misses == 0
    assert warm.cache_stats.hits == len(queries)
    assert all(it.index_cached for it in warm.items)
    assert warm.counts.tolist() == cold.counts.tolist()
    assert warm.timing.index_seconds == 0.0
    assert warm.timing.distance_seconds == 0.0


def test_lru_eviction_keeps_capacity_and_correctness():
    g = erdos_renyi(60, 4.0, seed=6)
    rng = np.random.default_rng(3)
    queries = _random_queries(g, 8, rng)
    eng = BatchPathEnum(cache_capacity=3)
    out = eng.run(g, queries)
    assert len(eng.cache) <= 3
    assert eng.cache.stats.evictions >= len(queries) - 3
    seq = PathEnum()
    assert out.counts.tolist() == [seq.count(g, s, t, k)
                                   for (s, t, k) in queries]


def test_lru_eviction_order_is_least_recently_used():
    cache = IndexCache(capacity=2)
    cache.put((0, 1, 2, 0), "a")
    cache.put((0, 2, 2, 0), "b")
    assert cache.get((0, 1, 2, 0)) == "a"              # refresh 'a'
    cache.put((0, 3, 2, 0), "c")                       # evicts 'b', not 'a'
    assert cache.get((0, 1, 2, 0)) == "a"
    assert cache.get((0, 2, 2, 0)) is None
    assert cache.stats.evictions == 1


def test_capacity_one_lru_thrash():
    """Alternating keys through a capacity-1 cache: every get misses,
    every put past the first evicts, and len never exceeds 1."""
    cache = IndexCache(capacity=1)
    keys = [(0, 1, 2, 0), (0, 2, 2, 0)]
    for round_ in range(4):
        key = keys[round_ % 2]
        assert cache.get(key) is None                  # always thrashed out
        cache.put(key, f"idx{round_}")
        assert len(cache) == 1
    assert cache.stats.misses == 4
    assert cache.stats.hits == 0
    assert cache.stats.evictions == 3                  # first put fills, rest evict
    # the survivor is the last inserted
    assert cache.get(keys[1]) == "idx3"


def test_cache_clear_resets_entries_and_stats():
    cache = IndexCache(capacity=4)
    cache.put((0, 1, 2, 0), "a")
    cache.get((0, 1, 2, 0))
    cache.get((9, 9, 9, 9))
    assert cache.stats.lookups == 2
    cache.clear()
    assert len(cache) == 0
    assert cache.get((0, 1, 2, 0)) is None             # entry really gone
    # stats describe only the post-clear epoch: the one miss above
    assert (cache.stats.hits, cache.stats.misses,
            cache.stats.evictions) == (0, 1, 0)


def test_cache_stats_snapshot_delta_arithmetic():
    stats = CacheStats(hits=5, misses=3, evictions=2)
    snap = stats.snapshot()
    assert snap is not stats                           # value copy, not alias
    stats.hits += 10
    stats.misses += 4
    stats.evictions += 1
    assert (snap.hits, snap.misses, snap.evictions) == (5, 3, 2)
    d = stats.delta(snap)
    assert (d.hits, d.misses, d.evictions) == (10, 4, 1)
    assert d.lookups == 14
    assert d.hit_rate == pytest.approx(10 / 14)
    # delta against self is all-zero
    z = stats.delta(stats.snapshot())
    assert (z.hits, z.misses, z.evictions) == (0, 0, 0)


def test_cache_hit_rate_zero_lookups_is_zero_not_nan():
    assert CacheStats().hit_rate == 0.0
    assert CacheStats(evictions=3).hit_rate == 0.0     # evictions aren't lookups


def test_tenant_quota_evicts_own_lru_not_neighbors():
    """A tenant over its quota churns its own LRU slice; other tenants'
    entries (and the global LRU order) are untouched."""
    cache = IndexCache(capacity=16, tenant_quotas={"hot": 2})
    cache.put(("quiet", 0, 1, 2, 0), "q0")
    for i in range(5):
        cache.put(("hot", 0, i, 2, 0), f"h{i}")
    assert cache.tenant_len("hot") == 2            # quota enforced
    assert cache.tenant_len("quiet") == 1          # neighbor untouched
    assert cache.get(("quiet", 0, 1, 2, 0)) == "q0"
    # survivors are the hot tenant's two most recent inserts
    assert cache.get(("hot", 0, 4, 2, 0)) == "h4"
    assert cache.get(("hot", 0, 3, 2, 0)) == "h3"
    assert cache.get(("hot", 0, 0, 2, 0)) is None
    assert cache.stats_for("hot").evictions == 3
    assert cache.stats_for("quiet").evictions == 0


def test_tenant_stats_partition_global_stats():
    cache = IndexCache(capacity=8)
    cache.put(("a", 0, 1, 2, 0), "ia")
    cache.put(("b", 0, 1, 2, 0), "ib")
    cache.get(("a", 0, 1, 2, 0))                   # a: hit
    cache.get(("b", 9, 9, 9, 0))                   # b: miss
    a, b = cache.stats_for("a"), cache.stats_for("b")
    assert (a.hits, a.misses) == (1, 0)
    assert (b.hits, b.misses) == (0, 1)
    assert cache.stats.hits == a.hits + b.hits
    assert cache.stats.misses == a.misses + b.misses


def test_tenant_zero_quota_stores_nothing():
    cache = IndexCache(capacity=8, tenant_quotas={"banned": 0})
    cache.put(("banned", 0, 1, 2, 0), "idx")
    assert len(cache) == 0
    cache.put(("other", 0, 1, 2, 0), "idx")        # unquota'd tenant fine
    assert cache.tenant_len("other") == 1


def test_set_quota_shrinks_existing_tenant_entries():
    cache = IndexCache(capacity=8)
    for i in range(4):
        cache.put(("t", 0, i, 2, 0), f"i{i}")
    cache.set_quota("t", 1)
    assert cache.tenant_len("t") == 1
    assert cache.get(("t", 0, 3, 2, 0)) == "i3"    # MRU survives
    assert cache.stats_for("t").evictions == 3
    cache.set_quota("t", None)                     # unbound again
    assert cache.quota_for("t") is None


def test_drop_tenant_purges_entries_without_eviction_churn():
    cache = IndexCache(capacity=8)
    cache.put(("a", 0, 1, 2, 0), "ia")
    cache.put(("a", 0, 2, 2, 0), "ia2")
    cache.put(("b", 0, 1, 2, 0), "ib")
    assert cache.drop_tenant("a") == 2
    assert cache.tenant_len("a") == 0 and len(cache) == 1
    assert cache.stats.evictions == 0              # retirement, not churn
    assert cache.get(("b", 0, 1, 2, 0)) == "ib"


def test_legacy_4tuple_keys_fold_onto_default_tenant():
    """Pre-tenancy callers poking the cache with (s, t, k, mh) keys land
    on DEFAULT_GRAPH_ID — the single-graph compatibility contract."""
    from repro.core import DEFAULT_GRAPH_ID, tenant_of

    assert tenant_of((0, 1, 2, 0)) == DEFAULT_GRAPH_ID
    assert tenant_of(("g2", 0, 1, 2, 0)) == "g2"
    cache = IndexCache(capacity=4)
    cache.put((0, 1, 2, 0), "legacy")
    assert cache.tenant_len(DEFAULT_GRAPH_ID) == 1
    assert cache.get((0, 1, 2, 0)) == "legacy"
    assert cache.stats_for(DEFAULT_GRAPH_ID).hits == 1


def test_global_capacity_still_bounds_quota_free_tenants():
    """Tenants without quotas compete in the global LRU exactly as before
    (and cross-tenant eviction under global pressure is expected)."""
    cache = IndexCache(capacity=2)
    cache.put(("a", 0, 1, 2, 0), "ia")
    cache.put(("b", 0, 1, 2, 0), "ib")
    cache.put(("c", 0, 1, 2, 0), "ic")             # evicts a's entry (LRU)
    assert len(cache) == 2
    assert cache.get(("a", 0, 1, 2, 0)) is None
    assert cache.stats_for("a").evictions == 1


def test_engine_runs_keyed_by_graph_id_isolate_tenants():
    """Same (s, t, k) on two different graphs through ONE engine: each
    run must build (and later hit) its own tenant's index and return the
    graph-correct counts."""
    g_a = erdos_renyi(50, 4.0, seed=1)
    g_b = power_law(50, 5.0, seed=2)
    eng = BatchPathEnum()
    q = [(2, 7, 4)]
    out_a = eng.run(g_a, q, graph_id="a")
    out_b = eng.run(g_b, q, graph_id="b")
    assert out_a.graph_id == "a" and out_b.graph_id == "b"
    assert out_b.cache_stats.misses == 1           # no cross-tenant reuse
    seq = PathEnum()
    assert out_a.counts[0] == seq.count(g_a, 2, 7, 4)
    assert out_b.counts[0] == seq.count(g_b, 2, 7, 4)
    warm_a = eng.run(g_a, q, graph_id="a")
    assert warm_a.cache_stats.hits == 1 and warm_a.cache_stats.misses == 0
    assert eng.cache.tenant_len("a") == 1 and eng.cache.tenant_len("b") == 1


def test_zero_capacity_cache_never_stores():
    g = erdos_renyi(40, 3.0, seed=1)
    eng = BatchPathEnum(cache_capacity=0)
    queries = [(0, 1, 3), (0, 1, 3)]
    out = eng.run(g, queries)
    assert len(eng.cache) == 0
    # in-batch dedup still collapses the duplicate
    assert out.items[1].deduplicated


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------

def test_empty_batch():
    g = erdos_renyi(20, 2.0, seed=0)
    out = BatchPathEnum().run(g, [])
    assert out.counts.size == 0
    assert out.total_results == 0
    assert out.distinct_queries == 0
    assert out.latency_percentiles()["p50_ms"] == 0.0


def test_invalid_queries_rejected():
    g = erdos_renyi(20, 2.0, seed=0)
    eng = BatchPathEnum()
    with pytest.raises(ValueError):
        eng.run(g, [(0, 1, 1)])                        # k < 2
    with pytest.raises(ValueError):
        eng.run(g, [(3, 3, 4)])                        # s == t


def test_edge_mask_queries_cached_separately():
    g = erdos_renyi(50, 4.0, seed=12)
    eng = BatchPathEnum()
    q = [(1, 2, 4)]
    full = eng.run(g, q)
    mask = np.ones(g.m, dtype=bool)
    mask[: g.m // 2] = False
    masked = eng.run(g, q, edge_mask=mask)
    # distinct cache keys: the masked run must not reuse the unmasked index
    assert masked.cache_stats.misses == 1
    seq = PathEnum()
    assert masked.counts[0] == seq.count(g, 1, 2, 4, edge_mask=mask)
    assert full.counts[0] == seq.count(g, 1, 2, 4)


# ---------------------------------------------------------------------------
# serving front-end
# ---------------------------------------------------------------------------

def test_hcpe_server_reports_percentiles_and_reuse():
    g = power_law(300, 5.0, seed=21)
    rng = np.random.default_rng(4)
    pool = _random_queries(g, 5, rng, kmin=4, kmax=4)
    picks = rng.integers(0, len(pool), size=20)
    reqs = [PathQueryRequest(uid=i, s=pool[j][0], t=pool[j][1], k=pool[j][2])
            for i, j in enumerate(picks)]
    server = HcPEServer(g)
    resps, report = server.serve(reqs)
    assert [r.uid for r in resps] == list(range(len(reqs)))
    assert report.batch_size == len(reqs)
    assert report.distinct_queries == len({(q.s, q.t, q.k) for q in reqs})
    assert report.p50_ms <= report.p90_ms <= report.p99_ms
    seq = PathEnum()
    for r in resps:
        req = reqs[r.uid]
        assert r.count == seq.count(g, req.s, req.t, req.k)
    # second serve: the whole batch rides the warm LRU
    _, report2 = server.serve(reqs)
    assert report2.cache.misses == 0
    assert report2.cache.hit_rate == 1.0


def test_hcpe_server_mixed_serving_options():
    g = erdos_renyi(60, 4.0, seed=13)
    reqs = [PathQueryRequest(uid=0, s=0, t=1, k=4),
            PathQueryRequest(uid=1, s=0, t=1, k=4, count_only=False),
            PathQueryRequest(uid=2, s=0, t=1, k=4, count_only=False,
                             first_n=1)]
    resps, report = HcPEServer(g).serve(reqs)
    assert resps[0].paths is None
    if resps[1].count:
        assert resps[1].paths is not None
        assert resps[2].count == 1
        assert resps[2].paths.shape[0] == 1
    assert report.batch_size == 3


def test_hcpe_server_empty_batch_zero_report():
    """Regression: serve([]) must fold to a well-formed all-zero report,
    not choke on percentiles of an empty latency list."""
    g = erdos_renyi(30, 3.0, seed=2)
    resps, report = HcPEServer(g).serve([])
    assert resps == []
    assert report.batch_size == 0
    assert report.distinct_queries == 0
    assert report.total_results == 0
    assert report.throughput_qps == 0.0
    assert report.results_per_second == 0.0
    assert report.p50_ms == report.p90_ms == report.p99_ms == 0.0
    assert report.cache.hits == report.cache.misses == 0


def test_batch_first_n_respected_under_join_mode():
    """Regression: BatchPathEnum dropped first_n whenever the plan was
    join — response-time mode silently enumerated everything."""
    g = erdos_renyi(40, 6.0, seed=1)
    eng = BatchPathEnum()
    triples = _random_queries(g, 4, np.random.default_rng(9), kmin=5, kmax=5)
    totals = BatchPathEnum().counts(g, triples, mode="dfs")
    for mode in ("dfs", "join", "auto"):
        out = eng.run(g, triples, count_only=False, first_n=3, mode=mode)
        for item, total in zip(out.items, totals):
            want = min(3, int(total))
            assert item.result.count == want, mode
            assert item.result.paths.shape[0] == want, mode


# ---------------------------------------------------------------------------
# enumeration-stats aggregation: EnumStats.merge + chunks in the report
# ---------------------------------------------------------------------------

def test_enum_stats_merge_roundtrip():
    """EnumStats.merge is plain field-wise accumulation: merging deltas
    reproduces the sum, merging a zero stats object is the identity."""
    from repro.core import EnumStats
    a = EnumStats(edges_accessed=1, invalid_partials=2, partials_generated=3,
                  results=4, chunks=5)
    b = EnumStats(edges_accessed=10, invalid_partials=20,
                  partials_generated=30, results=40, chunks=50)
    acc = EnumStats()
    acc.merge(a)
    acc.merge(b)
    assert acc == EnumStats(11, 22, 33, 44, 55)
    ident = EnumStats(11, 22, 33, 44, 55)
    ident.merge(EnumStats())
    assert ident == acc


def test_batch_output_enum_stats_counts_distinct_results_once():
    """BatchOutput.enum_stats merges per-distinct-result stats: in-batch
    duplicates share one EnumResult and must not double-count."""
    from repro.core import EnumStats
    g = erdos_renyi(40, 4.0, seed=2)
    triples = [(0, 1, 4), (2, 3, 4), (0, 1, 4)]          # one duplicate
    out = BatchPathEnum().run(g, triples, count_only=False)
    want = EnumStats()
    seen = set()
    for it in out.items:
        if id(it.result) not in seen:
            seen.add(id(it.result))
            want.merge(it.result.stats)
    assert out.enum_stats == want
    assert len(seen) == 2
    assert out.enum_stats.chunks > 0
    assert out.enum_stats.results == sum(
        it.result.count for i, it in enumerate(out.items)
        if not it.deduplicated)


def test_batch_serve_report_surfaces_chunks():
    """Regression: ``chunks`` used to be dropped on the way into
    BatchServeReport — the report now carries the merged EnumStats and a
    ``chunks`` accessor, for the sync server path too."""
    from repro.serving.hcpe import BatchServeReport
    g = erdos_renyi(40, 4.0, seed=3)
    out = BatchPathEnum().run(g, [(0, 1, 4), (2, 3, 4)], count_only=False)
    report = BatchServeReport.from_output(out)
    assert report.enum_stats == out.enum_stats
    assert report.chunks == out.enum_stats.chunks > 0

    srv = HcPEServer(g)
    reqs = [PathQueryRequest(uid=0, s=0, t=1, k=4, count_only=False),
            PathQueryRequest(uid=1, s=2, t=3, k=4)]      # two serve groups
    _, srv_report = srv.serve(reqs)
    per_group = [o.enum_stats.chunks for o in [
        srv.engine.run(g, [(0, 1, 4)], count_only=False),
        srv.engine.run(g, [(2, 3, 4)], count_only=True)]]
    assert srv_report.chunks == sum(per_group) > 0


# ---------------------------------------------------------------------------
# streaming-era regressions (DESIGN.md §12)
# ---------------------------------------------------------------------------

def test_duplicate_hits_land_in_tenant_stats_too():
    """Regression (tenant-stat drift): a duplicate-inside-the-batch cache
    hit used to bump only the global counter, so per-tenant hit rates
    drifted low on duplicate-heavy traffic.  The global delta must equal
    the sum of tenant deltas, hit for hit."""
    g_a = erdos_renyi(40, 4.0, seed=1)
    g_b = erdos_renyi(40, 4.0, seed=2)
    eng = BatchPathEnum()
    # 3 distinct queries, each submitted 3x in one batch, on two tenants
    distinct = [(0, 1, 3), (2, 3, 4), (4, 5, 3)]
    queries = distinct * 3
    before = eng.cache.stats.snapshot()
    before_t = {gid: eng.cache.stats_for(gid).snapshot()
                for gid in ("a", "b")}
    eng.run(g_a, queries, graph_id="a")
    eng.run(g_b, queries, graph_id="b")
    delta = eng.cache.stats.delta(before)
    deltas = {gid: eng.cache.stats_for(gid).delta(before_t[gid])
              for gid in ("a", "b")}
    # each tenant: 3 misses (first occurrence) + 6 duplicate hits
    for gid in ("a", "b"):
        assert (deltas[gid].hits, deltas[gid].misses) == (6, 3), gid
    assert delta.hits == sum(d.hits for d in deltas.values())
    assert delta.misses == sum(d.misses for d in deltas.values())


def test_masked_precomputed_distances_keep_the_mask():
    """Regression (masked precomputed-index leak): a masked query whose
    key sits in ``_precomputed_distances`` used to build its index with
    ``edge_mask=None``, silently enumerating masked-out edges.  The
    precomputed path must match the non-precomputed masked run and the
    sequential masked count exactly."""
    from repro.core import DEFAULT_GRAPH_ID
    from repro.core.batch import edge_mask_hash

    g = erdos_renyi(50, 4.0, seed=12)
    rng = np.random.default_rng(5)
    mask = np.ones(g.m, dtype=bool)
    mask[rng.choice(g.m, g.m // 2, replace=False)] = False
    queries = _random_queries(g, 6, rng, kmin=3, kmax=5)

    mh = edge_mask_hash(mask)
    pre = {}
    for (s, t, k) in queries:
        idx = build_index(g, s, t, k, edge_mask=mask)  # mask-true distances
        pre[(DEFAULT_GRAPH_ID, s, t, k, mh, g.version)] = \
            (idx.dist_s, idx.dist_t)

    got = BatchPathEnum().run(g, queries, edge_mask=mask,
                              _precomputed_distances=pre)
    want = BatchPathEnum().run(g, queries, edge_mask=mask)
    assert got.counts.tolist() == want.counts.tolist()
    seq = PathEnum()
    assert got.counts.tolist() == [seq.count(g, s, t, k, edge_mask=mask)
                                   for (s, t, k) in queries]
    # the unmasked counts differ somewhere, or the mask proved nothing
    free = BatchPathEnum().run(g, queries)
    assert free.counts.tolist() != got.counts.tolist()


# ---------------------------------------------------------------------------
# structure sharing: the merged-group-index identities (DESIGN.md §13)
# ---------------------------------------------------------------------------
#
# Two properties lock the Level-A layout: the merged arena's edge set is
# the exact union of the members' light-index edge sets (no over- or
# under-pruning), and each member's mask row re-derives that member's
# solo ``build_index`` output byte-for-byte (``member_view``), as does
# the grouped construction path (``build_member_indexes``).  Checked on
# a deterministic seeded sweep always, and under hypothesis (shrinking
# toward the minimal disagreeing group) when it is installed.

import dataclasses as _dc

from repro.core import from_edges
from repro.core import sharing as _sharing
from repro.core.bfs import index_distances_np
from repro.core.index import LightweightIndex

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _index_mismatch(a, b):
    """Name of the first LightweightIndex field that differs, or None."""
    for f in _dc.fields(LightweightIndex):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            if va.dtype != vb.dtype or not np.array_equal(va, vb):
                return f.name
        elif va != vb:
            return f.name
    return None


def _check_merged_index_identities(g, s0, triples):
    dists = [index_distances_np(g, s, t, k) for (s, t, k) in triples]
    solos = [build_index(g, s, t, k, dist_fn=lambda *_a, _d=d: _d)
             for (s, t, k), d in zip(triples, dists)]
    # grouped construction == solo construction, field for field
    grouped = _sharing.build_member_indexes(g, triples, dists)
    for gi, si, tr in zip(grouped, solos, triples):
        bad = _index_mismatch(gi, si)
        assert bad is None, f"build_member_indexes.{bad} differs for {tr}"
    merged = _sharing.MergedGroupIndex.from_members(solos, kind="s",
                                                    anchor=s0)
    # arena edge set == exact union of the members' index edge sets
    union = set()
    for m in solos:
        union |= set(m.fwd_eid.tolist())
    assert set(merged.union_edge_ids.tolist()) == union
    # each member's mask re-derives its solo index byte-for-byte
    for j, (si, tr) in enumerate(zip(solos, triples)):
        bad = _index_mismatch(merged.member_view(j), si)
        assert bad is None, f"member_view.{bad} differs for {tr}"


@pytest.mark.parametrize("seed", range(20))
def test_merged_group_index_identities(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 24))
    g = from_edges(n, rng.integers(0, n, size=(int(rng.integers(n, 4 * n)),
                                               2)))
    s0 = int(rng.integers(0, n))
    triples = []
    for t in map(int, rng.choice(n, size=4, replace=False)):
        if t != s0:
            triples.append((s0, t, int(rng.integers(2, 7))))
    if len(triples) < 2:
        pytest.skip("degenerate draw")
    _check_merged_index_identities(g, s0, triples)


if HAVE_HYPOTHESIS:

    @st.composite
    def shared_s_group(draw):
        n = draw(st.integers(6, 20))
        m = draw(st.integers(n, 3 * n))
        edges = draw(st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=m, max_size=m))
        g = from_edges(n, np.array(edges, dtype=np.int64))
        s0 = draw(st.integers(0, n - 1))
        targets = draw(st.lists(
            st.integers(0, n - 1).filter(lambda x: x != s0),
            min_size=2, max_size=5, unique=True))
        triples = [(s0, t, draw(st.integers(2, 6))) for t in targets]
        return g, s0, triples

    @pytest.mark.slow
    @settings(max_examples=60, deadline=None)
    @given(shared_s_group())
    def test_hypothesis_merged_group_index_identities(case):
        g, s0, triples = case
        _check_merged_index_identities(g, s0, triples)
