"""BatchPathEnum: dedup, index-cache reuse, batched == sequential, edges.

The batch engine's contract is "same answers, amortized work": every count
must be byte-identical to sequential PathEnum.count, with the sharing
(dedup / LRU / stacked BFS) observable only through stats and timing.
"""
import numpy as np
import pytest

from repro.core import (BatchPathEnum, IndexCache, PathEnum, build_index,
                        erdos_renyi, power_law)
from repro.core.batch import CacheStats, batched_index_distances
from repro.core.graph import random_graph_suite
from repro.serving.hcpe import HcPEServer, PathQueryRequest


def _random_queries(g, count, rng, kmin=2, kmax=5):
    out = []
    while len(out) < count:
        s, t = rng.integers(0, g.n, 2)
        if s != t:
            out.append((int(s), int(t), int(rng.integers(kmin, kmax + 1))))
    return out


# ---------------------------------------------------------------------------
# correctness: batched == sequential
# ---------------------------------------------------------------------------

def test_batched_counts_equal_sequential_on_random_graphs():
    seq = PathEnum()
    eng = BatchPathEnum()
    rng = np.random.default_rng(7)
    for name, g in random_graph_suite(11).items():
        queries = _random_queries(g, 10, rng)
        out = eng.run(g, queries)
        want = [seq.count(g, s, t, k) for (s, t, k) in queries]
        assert out.counts.tolist() == want, name


def test_batched_distances_match_sequential_bfs():
    """The stacked-frontier BFS must reproduce the queue BFS bit-for-bit."""
    rng = np.random.default_rng(3)
    g = power_law(200, 5.0, seed=9)
    queries = _random_queries(g, 15, rng, kmin=2, kmax=6)
    got = batched_index_distances(g, queries, block=4)
    for (s, t, k), (ds, dt) in zip(queries, got):
        idx = build_index(g, s, t, k)
        np.testing.assert_array_equal(ds, idx.dist_s)
        np.testing.assert_array_equal(dt, idx.dist_t)


def test_batched_distances_with_trailing_pred_free_vertices():
    """Regression: vertices with empty CSR rows at the top of the id range
    must not truncate the preceding vertex's reduceat segment."""
    from repro.core import from_edges

    g = from_edges(4, np.array([[0, 1], [2, 1], [1, 0]]))
    (ds, dt), = batched_index_distances(g, [(2, 0, 3)])
    idx = build_index(g, 2, 0, 3)
    np.testing.assert_array_equal(ds, idx.dist_s)
    np.testing.assert_array_equal(dt, idx.dist_t)
    seq = PathEnum()
    assert BatchPathEnum().counts(g, [(2, 0, 3)]).tolist() == \
        [seq.count(g, 2, 0, 3)]
    # sweep: graphs whose high-id vertices are isolated
    rng = np.random.default_rng(17)
    for _ in range(40):
        n = int(rng.integers(4, 20))
        m = int(rng.integers(1, 3 * n))
        edges = rng.integers(0, max(n - 2, 2), size=(m, 2))  # top ids isolated
        g = from_edges(n, edges)
        s, t = rng.choice(n, 2, replace=False)
        k = int(rng.integers(2, 6))
        (ds, dt), = batched_index_distances(g, [(int(s), int(t), k)])
        idx = build_index(g, int(s), int(t), k)
        np.testing.assert_array_equal(ds, idx.dist_s)
        np.testing.assert_array_equal(dt, idx.dist_t)


def test_batch_materialized_paths_match_sequential():
    g = erdos_renyi(60, 4.0, seed=2)
    rng = np.random.default_rng(5)
    queries = _random_queries(g, 6, rng)
    seq = PathEnum()
    out = BatchPathEnum().run(g, queries, count_only=False)
    for (s, t, k), item in zip(queries, out.items):
        want = sorted(seq.query(g, s, t, k).result.as_tuples())
        assert sorted(item.result.as_tuples()) == want


# ---------------------------------------------------------------------------
# sharing: dedup + cache stats
# ---------------------------------------------------------------------------

def test_duplicate_queries_dedup_to_identical_results():
    g = erdos_renyi(80, 4.0, seed=4)
    rng = np.random.default_rng(1)
    distinct = _random_queries(g, 5, rng)
    queries = distinct + distinct + distinct[:2]      # >50% duplicates
    out = BatchPathEnum().run(g, queries)
    assert out.distinct_queries == len(set(distinct))
    first = {q: it for q, it in zip(queries[:5], out.items[:5])}
    for q, item in zip(queries[5:], out.items[5:]):
        assert item.deduplicated
        assert item.result is first[q].result          # same object, no rerun
    # ≥30% duplicate workload must show cache hits (acceptance criterion)
    assert out.cache_stats.hits > 0


def test_index_cache_hit_avoids_rebuild():
    g = erdos_renyi(80, 4.0, seed=8)
    rng = np.random.default_rng(2)
    queries = _random_queries(g, 6, rng)
    eng = BatchPathEnum()
    cold = eng.run(g, queries)
    assert cold.cache_stats.misses == len(queries)
    assert not any(it.index_cached for it in cold.items)
    warm = eng.run(g, queries)
    # warm batch: zero misses means zero rebuilds — asserted via the counter
    assert warm.cache_stats.misses == 0
    assert warm.cache_stats.hits == len(queries)
    assert all(it.index_cached for it in warm.items)
    assert warm.counts.tolist() == cold.counts.tolist()
    assert warm.timing.index_seconds == 0.0
    assert warm.timing.distance_seconds == 0.0


def test_lru_eviction_keeps_capacity_and_correctness():
    g = erdos_renyi(60, 4.0, seed=6)
    rng = np.random.default_rng(3)
    queries = _random_queries(g, 8, rng)
    eng = BatchPathEnum(cache_capacity=3)
    out = eng.run(g, queries)
    assert len(eng.cache) <= 3
    assert eng.cache.stats.evictions >= len(queries) - 3
    seq = PathEnum()
    assert out.counts.tolist() == [seq.count(g, s, t, k)
                                   for (s, t, k) in queries]


def test_lru_eviction_order_is_least_recently_used():
    cache = IndexCache(capacity=2)
    cache.put((0, 1, 2, 0), "a")
    cache.put((0, 2, 2, 0), "b")
    assert cache.get((0, 1, 2, 0)) == "a"              # refresh 'a'
    cache.put((0, 3, 2, 0), "c")                       # evicts 'b', not 'a'
    assert cache.get((0, 1, 2, 0)) == "a"
    assert cache.get((0, 2, 2, 0)) is None
    assert cache.stats.evictions == 1


def test_capacity_one_lru_thrash():
    """Alternating keys through a capacity-1 cache: every get misses,
    every put past the first evicts, and len never exceeds 1."""
    cache = IndexCache(capacity=1)
    keys = [(0, 1, 2, 0), (0, 2, 2, 0)]
    for round_ in range(4):
        key = keys[round_ % 2]
        assert cache.get(key) is None                  # always thrashed out
        cache.put(key, f"idx{round_}")
        assert len(cache) == 1
    assert cache.stats.misses == 4
    assert cache.stats.hits == 0
    assert cache.stats.evictions == 3                  # first put fills, rest evict
    # the survivor is the last inserted
    assert cache.get(keys[1]) == "idx3"


def test_cache_clear_resets_entries_and_stats():
    cache = IndexCache(capacity=4)
    cache.put((0, 1, 2, 0), "a")
    cache.get((0, 1, 2, 0))
    cache.get((9, 9, 9, 9))
    assert cache.stats.lookups == 2
    cache.clear()
    assert len(cache) == 0
    assert cache.get((0, 1, 2, 0)) is None             # entry really gone
    # stats describe only the post-clear epoch: the one miss above
    assert (cache.stats.hits, cache.stats.misses,
            cache.stats.evictions) == (0, 1, 0)


def test_cache_stats_snapshot_delta_arithmetic():
    stats = CacheStats(hits=5, misses=3, evictions=2)
    snap = stats.snapshot()
    assert snap is not stats                           # value copy, not alias
    stats.hits += 10
    stats.misses += 4
    stats.evictions += 1
    assert (snap.hits, snap.misses, snap.evictions) == (5, 3, 2)
    d = stats.delta(snap)
    assert (d.hits, d.misses, d.evictions) == (10, 4, 1)
    assert d.lookups == 14
    assert d.hit_rate == pytest.approx(10 / 14)
    # delta against self is all-zero
    z = stats.delta(stats.snapshot())
    assert (z.hits, z.misses, z.evictions) == (0, 0, 0)


def test_cache_hit_rate_zero_lookups_is_zero_not_nan():
    assert CacheStats().hit_rate == 0.0
    assert CacheStats(evictions=3).hit_rate == 0.0     # evictions aren't lookups


def test_zero_capacity_cache_never_stores():
    g = erdos_renyi(40, 3.0, seed=1)
    eng = BatchPathEnum(cache_capacity=0)
    queries = [(0, 1, 3), (0, 1, 3)]
    out = eng.run(g, queries)
    assert len(eng.cache) == 0
    # in-batch dedup still collapses the duplicate
    assert out.items[1].deduplicated


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------

def test_empty_batch():
    g = erdos_renyi(20, 2.0, seed=0)
    out = BatchPathEnum().run(g, [])
    assert out.counts.size == 0
    assert out.total_results == 0
    assert out.distinct_queries == 0
    assert out.latency_percentiles()["p50_ms"] == 0.0


def test_invalid_queries_rejected():
    g = erdos_renyi(20, 2.0, seed=0)
    eng = BatchPathEnum()
    with pytest.raises(ValueError):
        eng.run(g, [(0, 1, 1)])                        # k < 2
    with pytest.raises(ValueError):
        eng.run(g, [(3, 3, 4)])                        # s == t


def test_edge_mask_queries_cached_separately():
    g = erdos_renyi(50, 4.0, seed=12)
    eng = BatchPathEnum()
    q = [(1, 2, 4)]
    full = eng.run(g, q)
    mask = np.ones(g.m, dtype=bool)
    mask[: g.m // 2] = False
    masked = eng.run(g, q, edge_mask=mask)
    # distinct cache keys: the masked run must not reuse the unmasked index
    assert masked.cache_stats.misses == 1
    seq = PathEnum()
    assert masked.counts[0] == seq.count(g, 1, 2, 4, edge_mask=mask)
    assert full.counts[0] == seq.count(g, 1, 2, 4)


# ---------------------------------------------------------------------------
# serving front-end
# ---------------------------------------------------------------------------

def test_hcpe_server_reports_percentiles_and_reuse():
    g = power_law(300, 5.0, seed=21)
    rng = np.random.default_rng(4)
    pool = _random_queries(g, 5, rng, kmin=4, kmax=4)
    picks = rng.integers(0, len(pool), size=20)
    reqs = [PathQueryRequest(uid=i, s=pool[j][0], t=pool[j][1], k=pool[j][2])
            for i, j in enumerate(picks)]
    server = HcPEServer(g)
    resps, report = server.serve(reqs)
    assert [r.uid for r in resps] == list(range(len(reqs)))
    assert report.batch_size == len(reqs)
    assert report.distinct_queries == len({(q.s, q.t, q.k) for q in reqs})
    assert report.p50_ms <= report.p90_ms <= report.p99_ms
    seq = PathEnum()
    for r in resps:
        req = reqs[r.uid]
        assert r.count == seq.count(g, req.s, req.t, req.k)
    # second serve: the whole batch rides the warm LRU
    _, report2 = server.serve(reqs)
    assert report2.cache.misses == 0
    assert report2.cache.hit_rate == 1.0


def test_hcpe_server_mixed_serving_options():
    g = erdos_renyi(60, 4.0, seed=13)
    reqs = [PathQueryRequest(uid=0, s=0, t=1, k=4),
            PathQueryRequest(uid=1, s=0, t=1, k=4, count_only=False),
            PathQueryRequest(uid=2, s=0, t=1, k=4, count_only=False,
                             first_n=1)]
    resps, report = HcPEServer(g).serve(reqs)
    assert resps[0].paths is None
    if resps[1].count:
        assert resps[1].paths is not None
        assert resps[2].count == 1
        assert resps[2].paths.shape[0] == 1
    assert report.batch_size == 3


def test_hcpe_server_empty_batch_zero_report():
    """Regression: serve([]) must fold to a well-formed all-zero report,
    not choke on percentiles of an empty latency list."""
    g = erdos_renyi(30, 3.0, seed=2)
    resps, report = HcPEServer(g).serve([])
    assert resps == []
    assert report.batch_size == 0
    assert report.distinct_queries == 0
    assert report.total_results == 0
    assert report.throughput_qps == 0.0
    assert report.results_per_second == 0.0
    assert report.p50_ms == report.p90_ms == report.p99_ms == 0.0
    assert report.cache.hits == report.cache.misses == 0


def test_batch_first_n_respected_under_join_mode():
    """Regression: BatchPathEnum dropped first_n whenever the plan was
    join — response-time mode silently enumerated everything."""
    g = erdos_renyi(40, 6.0, seed=1)
    eng = BatchPathEnum()
    triples = _random_queries(g, 4, np.random.default_rng(9), kmin=5, kmax=5)
    totals = BatchPathEnum().counts(g, triples, mode="dfs")
    for mode in ("dfs", "join", "auto"):
        out = eng.run(g, triples, count_only=False, first_n=3, mode=mode)
        for item, total in zip(out.items, totals):
            want = min(3, int(total))
            assert item.result.count == want, mode
            assert item.result.paths.shape[0] == want, mode
