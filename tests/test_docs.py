"""Docs gates: docstring coverage + link integrity (both run in CI).

Thin wrappers over the ``docstring-coverage`` and ``doc-links`` lint
rules (DESIGN.md §11) — the rules own the audited-module list, the
public-slot definition and the anchor/link regexes; these tests keep
the gates inside the tier-1 pytest run so a docs regression fails the
same job a code regression does.
"""
from repro.analysis import lint_repo


def test_docstring_coverage_gate():
    """Every public slot in the audited modules (serving/*.py +
    core/batch.py) is documented and anchored into DESIGN.md."""
    report = lint_repo(rules=["docstring-coverage"])
    assert not report.findings, (
        "audited public surface has undocumented slots:\n"
        + "\n".join(f.render() for f in report.findings))


def test_doc_references_resolve():
    """Every DESIGN.md §N anchor and every relative link in the top
    docs resolves."""
    report = lint_repo(rules=["doc-links"])
    assert not report.findings, (
        "dangling doc references:\n"
        + "\n".join(f.render() for f in report.findings))
