"""Docs gates: docstring coverage + link integrity (both run in CI).

Two enforced-not-advisory checks (the docs analogue of
test_compat.py's skew-symbol scan):

  * **docstring coverage ≥ 90%** over the public surface of ``serving/``
    and ``core/batch.py`` — an ``interrogate``-equivalent implemented on
    ``ast`` so it needs no extra dependency.  Public = module docstring,
    non-underscore classes, and non-underscore functions/methods.  Each
    audited module's docstring must also carry its ``DESIGN.md §N``
    anchor, so every public module is reachable from the design doc.
  * **no dangling doc references** — every ``DESIGN.md §N`` anchor
    spelled anywhere in README/DESIGN/EXPERIMENTS or a source/example
    docstring must name a section that exists, and every relative
    markdown link in the top-level docs must point at a real file.
"""
import ast
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src" / "repro"

# the audited set: the serving surface + the batch engine it fronts
AUDITED_MODULES = sorted((SRC / "serving").glob("*.py")) + \
    [SRC / "core" / "batch.py"]
MIN_COVERAGE = 0.90


def _public_docstring_slots(tree):
    """Yield (qualname, has_docstring) for the module, public classes and
    public functions/methods (nested defs excluded, like interrogate)."""
    yield "<module>", ast.get_docstring(tree) is not None
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and not node.name.startswith("_"):
            yield node.name, ast.get_docstring(node) is not None
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and not sub.name.startswith("_"):
                    yield f"{node.name}.{sub.name}", \
                        ast.get_docstring(sub) is not None
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and not node.name.startswith("_"):
            yield node.name, ast.get_docstring(node) is not None


def test_docstring_coverage_gate():
    covered, missing = 0, []
    total = 0
    for path in AUDITED_MODULES:
        tree = ast.parse(path.read_text())
        for qualname, has_doc in _public_docstring_slots(tree):
            total += 1
            if has_doc:
                covered += 1
            else:
                missing.append(f"{path.relative_to(REPO)}::{qualname}")
    coverage = covered / total
    assert coverage >= MIN_COVERAGE, (
        f"docstring coverage {coverage:.1%} < {MIN_COVERAGE:.0%} "
        f"({covered}/{total}); missing: {missing}")


@pytest.mark.parametrize("path", AUDITED_MODULES,
                         ids=lambda p: str(p.relative_to(SRC)))
def test_audited_modules_anchor_into_design_doc(path):
    """Every audited module's docstring names its DESIGN.md section, so
    readers can jump from code to design rationale."""
    doc = ast.get_docstring(ast.parse(path.read_text())) or ""
    assert re.search(r"DESIGN\.md §\d+", doc), (
        f"{path.relative_to(REPO)} module docstring lacks a "
        f"'DESIGN.md §N' anchor")


# ---------------------------------------------------------------------------
# doc-link integrity
# ---------------------------------------------------------------------------

TOP_DOCS = ["README.md", "DESIGN.md", "EXPERIMENTS.md"]


def _design_sections():
    text = (REPO / "DESIGN.md").read_text()
    return {int(m) for m in re.findall(r"^## §(\d+)", text, re.MULTILINE)}


def _anchor_sources():
    for name in TOP_DOCS:
        yield REPO / name
    for sub in ("src", "examples", "benchmarks", "tests"):
        yield from sorted((REPO / sub).rglob("*.py"))


def test_design_section_references_resolve():
    sections = _design_sections()
    assert sections, "DESIGN.md defines no '## §N' sections"
    dangling = []
    for path in _anchor_sources():
        for m in re.finditer(r"DESIGN\.md §(\d+)(?:-(\d+))?",
                             path.read_text()):
            lo = int(m.group(1))
            hi = int(m.group(2)) if m.group(2) else lo
            for n in range(lo, hi + 1):
                if n not in sections:
                    dangling.append(
                        f"{path.relative_to(REPO)}: DESIGN.md §{n}")
    assert not dangling, f"dangling DESIGN.md section references: {dangling}"


def test_relative_links_in_top_docs_resolve():
    broken = []
    for name in TOP_DOCS:
        text = (REPO / name).read_text()
        for m in re.finditer(r"\]\(([^)]+)\)", text):
            target = m.group(1).split("#")[0].strip()
            if not target or target.startswith(("http://", "https://",
                                                "mailto:")):
                continue
            if not (REPO / target).exists():
                broken.append(f"{name}: ({m.group(1)})")
    assert not broken, f"broken relative links: {broken}"
