"""Hypothesis property tests on the engine's core invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (PathEnum, build_index, enumerate_paths_idx,
                        enumerate_paths_join, from_edges, oracle,
                        preliminary_estimate, walk_count_dp)


@st.composite
def graph_query(draw):
    n = draw(st.integers(6, 28))
    m = draw(st.integers(n, 4 * n))
    edges = draw(st.lists(
        st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
        min_size=m, max_size=m))
    g = from_edges(n, np.array(edges, dtype=np.int64))
    s = draw(st.integers(0, n - 1))
    t = draw(st.integers(0, n - 1).filter(lambda x: x != s))
    k = draw(st.integers(2, 6))
    return g, s, t, k


@settings(max_examples=40, deadline=None)
@given(graph_query())
def test_dfs_enumeration_matches_oracle(gq):
    g, s, t, k = gq
    want = oracle.enumerate_paths(g, s, t, k)
    idx = build_index(g, s, t, k)
    got = enumerate_paths_idx(idx)
    assert sorted(got.as_tuples()) == want


@settings(max_examples=25, deadline=None)
@given(graph_query(), st.integers(1, 4))
def test_join_enumeration_matches_oracle(gq, cut_raw):
    g, s, t, k = gq
    cut = 1 + (cut_raw % (k - 1))
    want = oracle.enumerate_paths(g, s, t, k)
    idx = build_index(g, s, t, k)
    got = enumerate_paths_join(idx, cut=cut)
    assert sorted(got.as_tuples()) == want


@settings(max_examples=30, deadline=None)
@given(graph_query())
def test_walk_dp_is_exact_on_walks(gq):
    g, s, t, k = gq
    idx = build_index(g, s, t, k)
    dp = walk_count_dp(idx)
    assert abs(dp.q_total - oracle.count_walks(g, s, t, k)) < 1e-6


@settings(max_examples=30, deadline=None)
@given(graph_query())
def test_paths_bounded_by_walks(gq):
    """δ_P ≤ δ_W — the estimator upper-bounds the result count (§6.4)."""
    g, s, t, k = gq
    idx = build_index(g, s, t, k)
    dp = walk_count_dp(idx)
    res = enumerate_paths_idx(idx, count_only=True)
    assert res.count <= dp.q_total + 1e-6


@settings(max_examples=25, deadline=None)
@given(graph_query())
def test_emitted_paths_are_valid_simple_paths(gq):
    g, s, t, k = gq
    edge_set = set(zip(g.esrc.tolist(), g.edst.tolist()))
    idx = build_index(g, s, t, k)
    got = enumerate_paths_idx(idx)
    for p in got.as_tuples():
        assert p[0] == s and p[-1] == t
        assert 1 <= len(p) - 1 <= k
        assert len(set(p)) == len(p)
        for a, b in zip(p, p[1:]):
            assert (a, b) in edge_set
        assert all(v not in (s, t) for v in p[1:-1])


@settings(max_examples=20, deadline=None)
@given(graph_query())
def test_preliminary_estimator_nonnegative_and_finite(gq):
    g, s, t, k = gq
    idx = build_index(g, s, t, k)
    est = preliminary_estimate(idx)
    assert est >= 0.0 and np.isfinite(est)


@settings(max_examples=15, deadline=None)
@given(graph_query(), st.integers(1, 50))
def test_first_n_returns_at_least_n_or_all(gq, n):
    g, s, t, k = gq
    idx = build_index(g, s, t, k)
    total = enumerate_paths_idx(idx, count_only=True).count
    got = enumerate_paths_idx(idx, first_n=n)
    if total >= n:
        assert got.count >= n
    else:
        assert got.count == total


@settings(max_examples=15, deadline=None)
@given(graph_query(), st.integers(1, 50))
def test_first_n_dfs_join_consistent(gq, n):
    """Response-time mode truncates identically on both plans: exactly
    min(n, total) results, same exhausted flag, all drawn from P(s,t,k)."""
    g, s, t, k = gq
    idx = build_index(g, s, t, k)
    total = enumerate_paths_idx(idx, count_only=True).count
    dfs = enumerate_paths_idx(idx, first_n=n)
    join = enumerate_paths_join(idx, cut=max(1, k // 2), first_n=n)
    want = min(n, total)
    assert dfs.count == join.count == want
    assert dfs.paths.shape[0] == want and join.paths.shape[0] == want
    assert dfs.exhausted == join.exhausted == (total < n)
    full = set(enumerate_paths_idx(idx).as_tuples())
    assert set(dfs.as_tuples()) <= full
    assert set(join.as_tuples()) <= full
