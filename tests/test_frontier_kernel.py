"""Frontier-expansion kernel unit tests (DESIGN.md §9).

The kernel's contract is *bit-for-bit* agreement with the host hot loop
``core/enumerate._expand_chunk``: same candidate set, same emit/continue
partition in the same order, and the same Fig.-6 counter deltas
(edges_accessed / partials_generated / invalid_partials).  On this CPU
container the kernel runs through the Pallas interpreter; on TPU the
same entry point compiles to Mosaic.

Layers: direct mask checks (PAD rows inert, prefix dedup vs a numpy
reference, emit/cont partition), counter parity against host EnumStats
over full enumerations, the backend contract regressions are in
test_engine.py / test_async_server.py (parametrized over backends), and
a hypothesis property drives random chunks through both expansions.
"""
import numpy as np
import pytest

from repro.core import build_index, erdos_renyi, from_edges, power_law
from repro.core.enumerate import (EnumStats, _expand_chunk,
                                  enumerate_paths_idx, resolve_backend)
from repro.core.graph import PAD
from repro.kernels import ops
from repro.kernels.frontier_expand import PAD as KERNEL_PAD

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _host_expand(idx, paths, depth):
    """Host `_expand_chunk` folded to (emit_rows, cont_rows, stats)."""
    stats = EnumStats()
    exp = _expand_chunk(idx, paths, depth, stats)
    empty = np.zeros((0, paths.shape[1]), np.int32)
    if exp is None:
        return empty, empty, stats
    parent, pos, vnew, emit, cont = exp

    def rows_of(mask):
        sel = np.nonzero(mask)[0]
        rows = paths[parent[sel]].copy()
        rows[:, depth + 1] = vnew[sel]
        return rows

    return rows_of(emit), rows_of(cont), stats


def _device_expand(idx, paths, depth):
    """Device expansion folded to the same (emit, cont, stats) triple.
    Returns None for zero-fanout chunks (the driver's host shortcut)."""
    last = paths[:, depth].astype(np.int64)
    b = idx.k - depth - 1
    cnt = idx.fwd_end[last, b] - idx.fwd_begin[last] if b >= 0 else 0 * last
    cnt = np.where(last >= 0, cnt, 0)
    if int(cnt.sum()) == 0:
        return None
    dev = idx.device_arrays()
    emit_rows, cont_rows, n_emit, n_cont, counters = ops.frontier_expand(
        paths, dev.begin, dev.end, dev.dst, depth=depth, t=idx.t,
        max_deg=int(cnt.max()))
    ne, nc = int(n_emit), int(n_cont)
    cs = np.asarray(counters)
    stats = EnumStats(edges_accessed=int(cs[0]), partials_generated=int(cs[1]),
                      invalid_partials=int(cs[2]), results=ne)
    return np.asarray(emit_rows[:ne]), np.asarray(cont_rows[:nc]), stats


def _chunk_at_depth(idx, depth):
    """A real chunk: the host frontier walked down to ``depth``."""
    paths = np.full((1, idx.k + 1), PAD, np.int32)
    paths[0, 0] = idx.s
    for d in range(depth):
        _, cont, _ = _host_expand(idx, paths, d)
        if cont.shape[0] == 0:
            return None
        paths = cont
    return paths


def test_pad_constant_matches_core():
    """The kernel's PAD sentinel is pinned to the core layout constant."""
    assert KERNEL_PAD == PAD == -1


def test_pad_rows_are_inert():
    """PAD padding rows contribute no candidates and no counters: the
    device output on a PAD-interleaved chunk equals the host output on
    the valid rows alone."""
    g = erdos_renyi(40, 4.0, seed=1)
    idx = build_index(g, 0, 7, 4)
    chunk = _chunk_at_depth(idx, 1)
    assert chunk is not None and chunk.shape[0] >= 2
    padded = np.full((chunk.shape[0] * 2, idx.k + 1), PAD, np.int32)
    padded[::2] = chunk                     # valid rows interleaved with PAD
    he, hc, hs = _host_expand(idx, chunk, 1)
    got = _device_expand(idx, padded, 1)
    assert got is not None
    de, dc, ds = got
    assert np.array_equal(de, he)
    assert np.array_equal(dc, hc)
    assert (ds.edges_accessed, ds.partials_generated, ds.invalid_partials) \
        == (hs.edges_accessed, hs.partials_generated, hs.invalid_partials)


def test_prefix_dedup_matches_numpy_reference():
    """The in-kernel simple-path check prunes exactly the candidates that
    appear in their row's prefix — checked against an explicit numpy
    recomputation on a cycle-heavy graph."""
    # hub-and-cycle digraph where depth-2 expansion revisits a prefix
    # vertex (found by search; the dup assertion below pins it)
    g = from_edges(8, np.array(
        [[0, 2], [0, 4], [0, 5], [0, 6], [1, 6], [2, 0], [2, 6], [3, 0],
         [3, 6], [4, 0], [4, 2], [4, 5], [5, 0], [5, 4], [5, 7], [6, 1],
         [6, 5], [7, 5], [4, 7]]))
    idx = build_index(g, 0, 2, 4)
    chunk = _chunk_at_depth(idx, 2)
    assert chunk is not None
    got = _device_expand(idx, chunk, 2)
    assert got is not None
    de, dc, ds = got
    # numpy reference: expand every row by its I_t list, drop prefix dups
    emit_ref, cont_ref, dup_n = [], [], 0
    for row in chunk:
        v = int(row[2])
        for vn in idx.it(v, idx.k - 3):
            if vn in row[:3]:
                dup_n += 1
            elif vn == idx.t:
                emit_ref.append(np.concatenate([row[:3], [vn], [PAD]]))
            else:
                cont_ref.append(np.concatenate([row[:3], [vn], [PAD]]))
    stack = lambda rs: (np.array(rs, np.int32) if rs
                        else np.zeros((0, 5), np.int32))
    assert np.array_equal(de, stack(emit_ref))
    assert np.array_equal(dc, stack(cont_ref))
    assert ds.invalid_partials >= dup_n       # dups plus dead rows
    assert dup_n > 0, "case must actually exercise the dedup"


def test_emit_cont_partition():
    """Every emitted row ends at t in column depth+1; no continue row
    does; emit + cont + pruned accounts for every generated partial."""
    g = power_law(80, 5.0, seed=4)
    idx = build_index(g, 0, 3, 4)
    chunk = _chunk_at_depth(idx, 1)
    assert chunk is not None
    got = _device_expand(idx, chunk, 1)
    assert got is not None
    de, dc, ds = got
    if de.size:
        assert (de[:, 2] == idx.t).all()
    if dc.size:
        assert (dc[:, 2] != idx.t).all()
    # partition: every generated partial is emitted, continued, or
    # dup-pruned (invalid_partials = dups + dead rows, so subtract dead)
    dups = ds.invalid_partials - _dead_rows(idx, chunk, 1)
    assert ds.partials_generated == de.shape[0] + dc.shape[0] + dups


def _dead_rows(idx, chunk, depth):
    """Rows of ``chunk`` none of whose expansions survive."""
    he, hc, _ = _host_expand(idx, chunk, depth)
    alive = set()
    for rows in (he, hc):
        for r in rows:
            alive.add(tuple(int(x) for x in r[: depth + 1]))
    return sum(1 for r in chunk
               if tuple(int(x) for x in r[: depth + 1]) not in alive)


@pytest.mark.parametrize("seed,s,t,k", [(0, 0, 7, 4), (1, 2, 9, 5),
                                        (2, 1, 5, 3)])
def test_counter_parity_with_host_enumstats(seed, s, t, k):
    """Full enumerations agree bit-for-bit across backends: paths,
    lengths, count, exhausted and every EnumStats field (including
    chunks — the chunk walk itself is shared)."""
    g = erdos_renyi(48, 4.0, seed=seed)
    idx = build_index(g, s, t, k)
    host = enumerate_paths_idx(idx)
    dev = enumerate_paths_idx(idx, backend="device")
    assert np.array_equal(host.paths, dev.paths)
    assert np.array_equal(host.lengths, dev.lengths)
    assert host.count == dev.count
    assert host.exhausted == dev.exhausted
    assert host.stats == dev.stats


def test_resolve_backend_fallback_matrix():
    """The §9 fallback matrix: host stays host; device always runs the
    kernel except for constrained queries; auto requires small k, a
    dense index and (on CPU) the CI force flag."""
    g = erdos_renyi(30, 3.0, seed=7)
    idx = build_index(g, 0, 5, 4)

    class _FakeConstraint:  # only identity matters to resolve_backend
        pass

    assert resolve_backend(idx, None) == "host"
    assert resolve_backend(idx, "host") == "host"
    assert resolve_backend(idx, "device") == "device"
    assert resolve_backend(idx, "device", _FakeConstraint()) == "host"
    assert resolve_backend(idx, "auto") == "host"  # sparse index and/or CPU
    with pytest.raises(ValueError):
        resolve_backend(idx, "gpu")
    with pytest.raises(ValueError):
        # a typo'd backend must raise even when the constraint fallback
        # would otherwise short-circuit to the host
        resolve_backend(idx, "devcie", _FakeConstraint())


def test_resolve_device_enum_env_matrix(monkeypatch):
    """The §9 escape-hatch row: REPRO_DEVICE_ENUM=off (or 0) is a uniform
    kill switch over every backend value — same spelling contract as
    REPRO_SHARING=off|0 / REPRO_PALLAS=off — while unrecognized values
    change nothing (only the documented force/off/0 spellings act)."""
    g = erdos_renyi(30, 3.0, seed=7)
    idx = build_index(g, 0, 5, 4)
    for off in ("off", "0", "OFF", "Off"):
        monkeypatch.setenv("REPRO_DEVICE_ENUM", off)
        for req in (None, "host", "device", "auto"):
            assert resolve_backend(idx, req) == "host", (off, req)
        # the kill switch silences even the CI force spelling wherever
        # both appear (off is the operator override, force the CI one)
        with pytest.raises(ValueError):
            resolve_backend(idx, "gpu")   # validation still runs first
    monkeypatch.setenv("REPRO_DEVICE_ENUM", "banana")  # unrecognized
    assert resolve_backend(idx, "device") == "device"
    monkeypatch.delenv("REPRO_DEVICE_ENUM")
    assert resolve_backend(idx, "device") == "device"
    # end-to-end: an explicit device request with the kill switch set
    # must produce the host path's results through the host expander
    monkeypatch.setenv("REPRO_DEVICE_ENUM", "off")
    res_off = enumerate_paths_idx(idx, backend="device")
    monkeypatch.delenv("REPRO_DEVICE_ENUM")
    res_host = enumerate_paths_idx(idx)
    assert res_off.as_tuples() == res_host.as_tuples()
    assert res_off.stats == res_host.stats


def test_auto_rule_forces_device_only_when_dense(monkeypatch):
    """REPRO_DEVICE_ENUM=force flips auto onto the device on CPU — but
    only for indexes dense enough to clear the threshold."""
    from repro.core import enumerate as en
    g = erdos_renyi(120, 20.0, seed=3)
    idx = build_index(g, 0, 9, 4)
    monkeypatch.setenv("REPRO_DEVICE_ENUM", "force")
    want = ("device" if idx.num_index_edges >= en.DEVICE_AUTO_MIN_EDGES
            else "host")
    assert resolve_backend(idx, "auto") == want
    monkeypatch.delenv("REPRO_DEVICE_ENUM")
    assert resolve_backend(idx, "auto") == "host"   # CPU, not forced


def test_resolve_sharing_env_matrix(monkeypatch):
    """The §13 escape hatch row of the fallback matrix: REPRO_SHARING=off
    (or 0) wins over every knob value, mirroring REPRO_DEVICE_ENUM."""
    from repro.core import sharing as sharing_mod
    assert sharing_mod.resolve_sharing("auto") == "auto"
    assert sharing_mod.resolve_sharing(None) == "auto"
    monkeypatch.setenv("REPRO_SHARING", "off")
    assert sharing_mod.resolve_sharing("auto") == "off"
    assert sharing_mod.resolve_sharing(None) == "off"
    monkeypatch.setenv("REPRO_SHARING", "0")
    assert sharing_mod.resolve_sharing("auto") == "off"
    monkeypatch.delenv("REPRO_SHARING")
    assert sharing_mod.resolve_sharing("auto") == "auto"


# ---------------------------------------------------------------------------
# random-chunk parity: host and device _expand_chunk agree bit-for-bit.
# Two layers: a deterministic seeded sweep that always runs (hypothesis
# is absent in some containers), and a shrinking hypothesis property.
# ---------------------------------------------------------------------------

def _random_chunk_case(seed):
    """(idx, paths, depth): a random index plus an arbitrary well-formed
    chunk at one depth — the expansion contract must hold for any chunk,
    reachable or not."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 30))
    m = max(1, int(n * float(rng.choice([1.0, 2.5, 4.0]))))
    g = from_edges(n, rng.integers(0, n, size=(m, 2)))
    s, t = map(int, rng.choice(n, 2, replace=False))
    k = int(rng.integers(2, 6))
    idx = build_index(g, s, t, k)
    depth = int(rng.integers(0, k - 1))
    rows = int(rng.integers(1, 18))
    paths = np.full((rows, k + 1), PAD, np.int32)
    paths[:, : depth + 1] = rng.integers(0, n, size=(rows, depth + 1))
    return idx, paths, depth


def _assert_host_device_chunk_parity(case):
    idx, paths, depth = case
    he, hc, hs = _host_expand(idx, paths, depth)
    got = _device_expand(idx, paths, depth)
    if got is None:       # zero fanout: host returned None too
        assert he.shape[0] == 0 and hc.shape[0] == 0
        assert hs.edges_accessed == 0
        return
    de, dc, ds = got
    assert np.array_equal(de, he)
    assert np.array_equal(dc, hc)
    assert (ds.edges_accessed, ds.partials_generated, ds.invalid_partials) \
        == (hs.edges_accessed, hs.partials_generated, hs.invalid_partials)


@pytest.mark.parametrize("seed", range(20))
def test_host_device_expand_bitwise_equal_seeded(seed):
    _assert_host_device_chunk_parity(_random_chunk_case(seed * 7919))


def test_fanout_segmentation_preserves_order_and_stats(monkeypatch):
    """A chunk cut into many fan-out segments (tiny DEVICE_SLOT_BUDGET)
    must produce the same paths, order and EnumStats as one launch — the
    memory guard may never change results."""
    from repro.core import enumerate as en
    g = erdos_renyi(48, 5.0, seed=6)
    idx = build_index(g, 0, 7, 4)
    host = enumerate_paths_idx(idx)
    monkeypatch.setattr(en, "DEVICE_SLOT_BUDGET", 4)
    dev = enumerate_paths_idx(idx, backend="device")
    assert np.array_equal(host.paths, dev.paths)
    assert host.stats == dev.stats


def test_fanout_segments_respect_budget_and_cover():
    """Segment rectangles fit the budget (except unavoidable single-row
    segments) and tile the chunk contiguously."""
    from repro.core.enumerate import _fanout_segments
    rng = np.random.default_rng(0)
    for _ in range(50):
        cnt = rng.integers(0, 40, size=int(rng.integers(1, 30)))
        budget = int(rng.choice([4, 16, 64]))
        segs = _fanout_segments(cnt, budget)
        assert segs[0][0] == 0 and segs[-1][1] == cnt.shape[0]
        for (a, b), (c, _) in zip(segs, segs[1:]):
            assert b == c
        for a, b in segs:
            assert b > a
            md = 1 << (max(int(cnt[a:b].max()), 1) - 1).bit_length()
            assert (b - a) * md <= budget or b - a == 1


if HAVE_HYPOTHESIS:

    @st.composite
    def random_chunk(draw):
        seed = draw(st.integers(0, 2**16))
        rng = np.random.default_rng(seed)
        n = int(rng.integers(5, 30))
        m = max(1, int(n * float(rng.choice([1.0, 2.5, 4.0]))))
        g = from_edges(n, rng.integers(0, n, size=(m, 2)))
        s, t = map(int, rng.choice(n, 2, replace=False))
        k = draw(st.integers(2, 5))
        idx = build_index(g, s, t, k)
        depth = draw(st.integers(0, k - 2))
        rows = draw(st.integers(1, 17))
        # arbitrary (not necessarily reachable) partials at this depth:
        # the expansion contract must hold for any well-formed chunk
        paths = np.full((rows, k + 1), PAD, np.int32)
        paths[:, : depth + 1] = rng.integers(0, n, size=(rows, depth + 1))
        return idx, paths, depth

    @settings(max_examples=25, deadline=None)
    @given(random_chunk())
    def test_hypothesis_host_device_expand_bitwise_equal(case):
        _assert_host_device_chunk_parity(case)
