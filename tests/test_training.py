"""Trainer, checkpoint/restart, microbatch equivalence, constraints."""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data.pipeline import PathCorpus, SyntheticLM
from repro.models import init_params
from repro.optim import adamw
from repro.training import step as step_mod
from repro.training.trainer import Trainer, TrainerConfig

TINY = ArchConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, kv_heads=2, d_ff=128, vocab=256, head_dim=16,
                  attn_chunk=16, tie_embeddings=True)


def test_loss_decreases():
    data = SyntheticLM(vocab=TINY.vocab, seq_len=32, global_batch=4)
    opt = adamw.OptimizerConfig(peak_lr=1e-3, warmup_steps=3, total_steps=25)
    tr = Trainer(TINY, opt, TrainerConfig(steps=25, log_every=5))
    tr.fit(data)
    assert tr.metrics_log[-1]["loss"] < tr.metrics_log[0]["loss"]


def test_checkpoint_roundtrip_and_restart(tmp_path):
    data = SyntheticLM(vocab=TINY.vocab, seq_len=16, global_batch=2)
    opt = adamw.OptimizerConfig(peak_lr=1e-3, total_steps=12)
    d = str(tmp_path / "ckpt")

    tr1 = Trainer(TINY, opt, TrainerConfig(steps=6, ckpt_every=3,
                                           ckpt_dir=d, log_every=1))
    p1, o1 = tr1.fit(data)
    mgr = CheckpointManager(d)
    assert mgr.latest_step() == 6

    # restart continues from step 6 and reaches 12
    tr2 = Trainer(TINY, opt, TrainerConfig(steps=12, ckpt_every=3,
                                           ckpt_dir=d, log_every=1))
    p2, o2 = tr2.fit(data)
    assert tr2.metrics_log[0]["step"] >= 6  # resumed, not restarted
    assert mgr.latest_step() == 12

    # deterministic equivalence: uninterrupted 12-step run matches restart
    tr3 = Trainer(TINY, opt, TrainerConfig(steps=12, log_every=1))
    p3, _ = tr3.fit(data)
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p3)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-5)


def test_checkpoint_retention_and_manifest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = {"a": np.arange(5), "b": {"c": np.ones((2, 2))}}
    for s in (1, 2, 3):
        mgr.save(s, {"state": tree}, extra={"data_step": s})
    assert mgr.all_steps() == [2, 3]
    restored, manifest = mgr.restore(3, {"state": tree})
    np.testing.assert_array_equal(restored["state"]["a"], tree["a"])
    assert manifest["extra"]["data_step"] == 3


def test_emergency_save_handler(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    saved = {}
    mgr.install_signal_handler(lambda: saved.setdefault("hit", True))
    with pytest.raises(SystemExit):
        signal.raise_signal(signal.SIGTERM)
    assert saved.get("hit")


def test_microbatch_accumulation_matches_full_batch():
    params = init_params(TINY, jax.random.PRNGKey(0))
    opt = adamw.OptimizerConfig(peak_lr=1e-3, total_steps=10)
    data = SyntheticLM(vocab=TINY.vocab, seq_len=16, global_batch=4)
    batch = jax.tree.map(jnp.asarray, data.batch_at(0))

    s1 = step_mod.make_train_step(TINY, opt, microbatches=1)
    s2 = step_mod.make_train_step(TINY, opt, microbatches=2)
    st = adamw.init(params)
    p1, _, m1 = jax.jit(s1)(params, st, batch)
    p2, _, m2 = jax.jit(s2)(params, st, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_path_corpus_batches_are_valid():
    from repro.core import power_law
    g = power_law(200, 5.0, seed=4)
    pc = PathCorpus(graph=g, k=4, seq_len=16, global_batch=4)
    b = pc.batch_at(0)
    assert b["tokens"].shape == (4, 16)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < pc.vocab
    assert (b["labels"] >= -1).all()


def test_data_stream_deterministic_restart():
    d1 = SyntheticLM(vocab=64, seq_len=8, global_batch=2, seed=9)
    d2 = SyntheticLM(vocab=64, seq_len=8, global_batch=2, seed=9)
    np.testing.assert_array_equal(d1.batch_at(7)["tokens"],
                                  d2.batch_at(7)["tokens"])


def test_cosine_schedule_shape():
    opt = adamw.OptimizerConfig(peak_lr=1.0, warmup_steps=10,
                                total_steps=100, min_lr_ratio=0.1)
    lrs = [float(adamw.cosine_schedule(opt, jnp.int32(s)))
           for s in (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 0.1) < 1e-2
