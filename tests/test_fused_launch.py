"""Fused multi-query device launch tests (DESIGN.md §9).

`core.fused.enumerate_fused_device` packs chunks from many queries into
single ``ops.frontier_expand_fused`` dispatches; `core.batch` routes a
batch's device-eligible dfs-plan queries through it.  The contract is
two-sided:

* **semantics**: every query's result is byte-identical to its solo
  ``enumerate_paths_idx(idx, backend="device")`` run — paths, count,
  ``EnumStats`` (chunk accounting included) and the ``first_n`` /
  ``count_only`` / deadline behaviors;
* **dispatch granularity** (the point of the tentpole): a micro-batch
  of N device-eligible queries issues ONE kernel dispatch per expansion
  round, not N — asserted here through ``ops.device_dispatch_count``
  deltas and ``BatchOutput.fused_dispatches``.
"""
import numpy as np
import pytest

from repro.core import build_index, clock, erdos_renyi, layered_dag
from repro.core.batch import BatchPathEnum
from repro.core.enumerate import enumerate_paths_idx
from repro.core.fused import enumerate_fused_device
from repro.kernels import ops as kops


def _assert_equal(a, b, tag=""):
    assert a.count == b.count, tag
    assert a.exhausted == b.exhausted, tag
    assert a.stats == b.stats, tag
    assert a.as_tuples() == b.as_tuples(), tag


def _graph_and_queries():
    g = erdos_renyi(40, 5.0, seed=17)
    qs = [(0, 39, 4), (1, 38, 4), (2, 37, 3), (3, 36, 4)]
    return g, qs


def _indexes(g, qs):
    out = []
    for s, t, k in qs:
        idx = build_index(g, s, t, k)
        if idx is not None:
            out.append(idx)
    return out


CHUNK = 7


def test_fused_bitwise_parity_with_solo_device(monkeypatch):
    monkeypatch.delenv("REPRO_DEVICE_DEQUE", raising=False)
    g, qs = _graph_and_queries()
    idxs = _indexes(g, qs)
    assert len(idxs) >= 2
    fused = enumerate_fused_device(idxs, chunk_size=CHUNK)
    # the solo oracle is the host-looped device driver (the deque takes
    # a different — but equivalent — chunk walk, so pin it off here)
    monkeypatch.setenv("REPRO_DEVICE_DEQUE", "off")
    for idx, fr in zip(idxs, fused):
        solo = enumerate_paths_idx(idx, backend="device", chunk_size=CHUNK)
        _assert_equal(fr, solo, f"s={idx.s} t={idx.t}")
        host = enumerate_paths_idx(idx, backend="host", chunk_size=CHUNK)
        _assert_equal(fr, host, f"s={idx.s} t={idx.t} vs host")


def test_fused_issues_one_dispatch_per_round_not_per_query(monkeypatch):
    monkeypatch.setenv("REPRO_DEVICE_DEQUE", "off")
    g, qs = _graph_and_queries()
    idxs = _indexes(g, qs)
    assert len(idxs) >= 2
    solo_chunks = 0
    solo_dispatches = 0
    for idx in idxs:
        before = kops.device_dispatch_count()
        r = enumerate_paths_idx(idx, backend="device", chunk_size=CHUNK)
        solo_dispatches += kops.device_dispatch_count() - before
        solo_chunks += r.stats.chunks
    before = kops.device_dispatch_count()
    enumerate_fused_device(idxs, chunk_size=CHUNK)
    fused_dispatches = kops.device_dispatch_count() - before
    # N queries × per-query chunk walks collapse into per-round launches
    assert 1 <= fused_dispatches < solo_dispatches
    assert fused_dispatches < solo_chunks


def test_fused_count_only_and_first_n(monkeypatch):
    monkeypatch.setenv("REPRO_DEVICE_DEQUE", "off")
    g, qs = _graph_and_queries()
    idxs = _indexes(g, qs)
    co = enumerate_fused_device(idxs, chunk_size=CHUNK, count_only=True)
    fn = enumerate_fused_device(idxs, chunk_size=CHUNK, first_n=2)
    for idx, c, f in zip(idxs, co, fn):
        solo_co = enumerate_paths_idx(idx, backend="device",
                                      chunk_size=CHUNK, count_only=True)
        assert c.count == solo_co.count and c.stats == solo_co.stats
        assert c.paths.shape[0] == 0
        solo_fn = enumerate_paths_idx(idx, backend="device",
                                      chunk_size=CHUNK, first_n=2)
        _assert_equal(f, solo_fn, "first_n")


def test_fused_deadline_expired_returns_nonexhausted():
    g, qs = _graph_and_queries()
    idxs = _indexes(g, qs)
    res = enumerate_fused_device(idxs, deadline=clock.now() - 1.0)
    for r in res:
        assert not r.exhausted
        assert r.count == 0


def test_fused_rejects_mixed_graphs():
    g1 = erdos_renyi(20, 4.0, seed=1)
    g2 = erdos_renyi(30, 4.0, seed=2)
    i1 = _indexes(g1, [(0, 19, 3)])
    i2 = _indexes(g2, [(0, 29, 3)])
    if not i1 or not i2:
        pytest.skip("no index")
    with pytest.raises(ValueError):
        enumerate_fused_device(i1 + i2)


def test_fused_ref_oracle_leg(monkeypatch):
    """REPRO_PALLAS=off routes the fused dispatch through the pure-jnp
    oracle; results stay byte-identical."""
    monkeypatch.setenv("REPRO_DEVICE_DEQUE", "off")
    g, qs = _graph_and_queries()
    idxs = _indexes(g, qs)
    fused = enumerate_fused_device(idxs, chunk_size=CHUNK)
    monkeypatch.setenv("REPRO_PALLAS", "off")
    fused_ref = enumerate_fused_device(idxs, chunk_size=CHUNK)
    for a, b in zip(fused, fused_ref):
        _assert_equal(a, b, "pallas vs ref")


# -- batch engine wiring ----------------------------------------------------

def test_batch_fused_parity_and_dispatch_count():
    g, qs = _graph_and_queries()
    host = BatchPathEnum(backend="host", fused="off", chunk_size=CHUNK)
    out_host = host.run(g, qs, count_only=True)
    fused = BatchPathEnum(backend="device", fused="auto", chunk_size=CHUNK)
    out_fused = fused.run(g, qs, count_only=True)
    for hi, fi in zip(out_host.items, out_fused.items):
        assert hi.result.count == fi.result.count, (hi.s, hi.t)
        assert hi.result.stats == fi.result.stats, (hi.s, hi.t)
    assert out_fused.fused_queries >= 2
    assert out_fused.fused_dispatches >= 1
    fused_items = [i for i in out_fused.items if i.fused]
    assert len(fused_items) >= 2
    # dispatch granularity: fewer launches than the members' summed
    # chunk walks (each round serves every member at once)
    total_chunks = sum(i.result.stats.chunks for i in fused_items)
    assert out_fused.fused_dispatches < total_chunks


def test_batch_fused_off_knob_pins_solo_path():
    g, qs = _graph_and_queries()
    off = BatchPathEnum(backend="device", fused="off", chunk_size=CHUNK)
    out = off.run(g, qs, count_only=True)
    assert out.fused_queries == 0
    assert out.fused_dispatches == 0
    assert not any(i.fused for i in out.items)
    on = BatchPathEnum(backend="device", fused="auto", chunk_size=CHUNK)
    out_on = on.run(g, qs, count_only=True)
    for a, b in zip(out.items, out_on.items):
        assert a.result.count == b.result.count
        assert a.result.stats == b.result.stats


def test_batch_fused_mixed_plans_auto_mode():
    """auto-mode batches with a mix of dfs and join plans fuse only the
    dfs-plan queries; join-plan queries run their normal pipeline."""
    g = erdos_renyi(120, 12.0, seed=21)
    qs = [(0, 119, 4), (1, 118, 4), (2, 117, 4), (3, 116, 4)]
    host = BatchPathEnum(backend="host", fused="off")
    out_host = host.run(g, qs, count_only=True, mode="auto")
    dev = BatchPathEnum(backend="device", fused="auto")
    out_dev = dev.run(g, qs, count_only=True, mode="auto")
    for hi, fi in zip(out_host.items, out_dev.items):
        assert hi.result.count == fi.result.count, (hi.s, hi.t)
        assert hi.result.stats == fi.result.stats, (hi.s, hi.t)
        assert hi.plan.method == fi.plan.method
    for item in out_dev.items:
        if item.fused:
            assert item.plan.method == "dfs"


def test_batch_fused_ranked_batches_never_fuse():
    g, qs = _graph_and_queries()
    dev = BatchPathEnum(backend="device", fused="auto")
    out = dev.run(g, qs, count_only=False, order="hops", first_n=3)
    assert out.fused_queries == 0
    assert not any(i.fused for i in out.items)


def test_batch_single_query_skips_fusion():
    g, qs = _graph_and_queries()
    dev = BatchPathEnum(backend="device", fused="auto")
    out = dev.run(g, qs[:1], count_only=True)
    assert out.fused_queries == 0


def test_kernel_fused_matches_ref_oracle():
    """Direct kernel-vs-oracle check on a packed multi-member chunk."""
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.frontier_expand import frontier_fused_masks

    rng = np.random.default_rng(3)
    m, n, mfm, C, k1, max_deg = 4, 16, 32, 16, 4, 8
    paths = rng.integers(-1, n, (C, k1)).astype(np.int32)
    rank = np.sort(rng.integers(0, m, C)).astype(np.int32)
    tvec = rng.integers(0, n, m).astype(np.int32)
    depthv = rng.integers(0, k1 - 1, m).astype(np.int32)
    begin = rng.integers(0, mfm, m * n).astype(np.int32)
    endb = (begin + rng.integers(0, max_deg, m * n)).astype(np.int32)
    dst = rng.integers(0, n, m * mfm).astype(np.int32)
    args = tuple(jnp.asarray(a) for a in
                 (paths, rank, tvec, depthv, begin, endb, dst))
    got = frontier_fused_masks(*args, max_deg=max_deg, interpret=True)
    want = ref.frontier_fused_masks_ref(*args, max_deg=max_deg)
    for gv, wv, name in zip(got, want, ("vnew", "emit", "cont", "ctr")):
        np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv), name)
