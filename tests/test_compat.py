"""Pins repro.compat's version dispatch (DESIGN.md §6).

These run on whichever jax the environment ships; every assertion is
phrased against the capability probes so both sides of the skew stay
exercised (CI runs a pinned-0.4.x leg and a latest-jax leg).  The last
test enforces the layer's policy mechanically: no skew API spelled
outside src/repro/compat.py — it is a thin wrapper over the
``compat-boundary`` lint rule (DESIGN.md §11), which owns the symbol
list and the exemptions.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


def test_probes_match_installed_jax():
    assert compat.HAS_SHARD_MAP == hasattr(jax, "shard_map")
    assert compat.HAS_AXIS_TYPES == hasattr(jax.sharding, "AxisType")
    assert compat.HAS_SET_MESH == hasattr(jax, "set_mesh")
    assert compat.HAS_ABSTRACT_MESH == hasattr(jax.sharding,
                                               "get_abstract_mesh")
    assert compat.JAX_VERSION >= (0, 4)


def test_axis_type_dispatch():
    assert hasattr(compat.AxisType, "Auto")
    if compat.HAS_AXIS_TYPES:
        assert compat.AxisType is jax.sharding.AxisType


def test_make_mesh_defaults_to_auto_axes():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert isinstance(mesh, jax.sharding.Mesh)
    assert dict(mesh.shape) == {"data": 1, "model": 1}
    if compat.HAS_AXIS_TYPES:
        assert all(t == compat.AxisType.Auto for t in mesh.axis_types)


def test_ambient_mesh_roundtrip():
    """set_mesh scopes the mesh get_abstract_mesh sees, on both sides."""
    assert compat.get_abstract_mesh().empty
    mesh = compat.make_mesh((1,), ("data",))
    with compat.set_mesh(mesh):
        ambient = compat.get_abstract_mesh()
        assert not ambient.empty
        assert dict(ambient.shape) == {"data": 1}
    assert compat.get_abstract_mesh().empty


def test_ambient_mesh_drives_constrain():
    """distributed.constraints is a no-op outside a mesh, active inside."""
    from repro.distributed.constraints import current_rules

    assert current_rules() is None
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    with compat.set_mesh(mesh):
        assert current_rules() is not None


def test_shard_map_unified_signature():
    """One spelling covers check_vma (>= 0.6) and check_rep (0.4.x)."""
    mesh = compat.make_mesh((1,), ("data",))
    f = compat.shard_map(lambda x: jax.lax.psum(x, "data"), mesh=mesh,
                         in_specs=(P("data"),), out_specs=P())
    out = jax.jit(f)(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(out), np.arange(4.0))


def test_no_skew_symbol_outside_compat():
    """Thin wrapper over the compat-boundary lint rule (DESIGN.md §11):
    the rule owns the skew-symbol list and the compat.py exemption."""
    from repro.analysis import lint_repo

    report = lint_repo(rules=["compat-boundary"])
    assert not report.findings, (
        "skew jax APIs must go through repro/compat.py:\n"
        + "\n".join(f.render() for f in report.findings))
